#!/usr/bin/env bash
# Quick perf smoke for CI / PR trajectory tracking: runs the
# `perf_hotpath` bench in quick mode (small payloads, few iterations)
# and emits machine-readable rows to BENCH_hotpath.json so future PRs
# can diff hot-path timings.
#
# Usage: scripts/bench_smoke.sh [output.json]
set -euo pipefail

cd "$(dirname "$0")/.."

OUT="${1:-BENCH_hotpath.json}"
export BENCH_QUICK=1
export BENCH_JSON_OUT="$OUT"

cargo bench --bench perf_hotpath

if [[ -f "$OUT" ]]; then
    echo "bench rows -> $OUT"
else
    echo "ERROR: $OUT was not produced" >&2
    exit 1
fi
