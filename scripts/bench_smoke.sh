#!/usr/bin/env bash
# Quick perf smoke for CI / PR trajectory tracking: runs the
# `perf_hotpath` bench in quick mode (small payloads, few iterations)
# and emits machine-readable rows to BENCH_hotpath.json plus a
# BENCH_hierarchical.json section (flat vs hierarchical pooled step time
# at a fixed synthetic 2M2G world) and a BENCH_input_pipeline.json
# section (tokens/s, input_stall_s, data_efficiency for the synchronous
# vs prefetched input path on a masking-heavy workload) and a
# BENCH_checkpoint.json section (save latency on vs off the hot loop,
# bytes/s of the background writer) and a BENCH_intranode.json section
# (serialized-leader vs chunked-pipelined intra-node exchange at a
# fixed synthetic 2M4G world) and a BENCH_elastic.json section
# (post-write verify throughput and the ledger-consult + full-load
# restart-to-restore latency of the elastic resume path) and a
# BENCH_transport.json section (in-proc vs loopback-socket pooled
# exchange throughput plus the per-bucket network latency the socket
# hop adds) and a BENCH_rejoin.json section (socket-world teardown +
# re-establish latency at a republished rendezvous epoch, and the
# authenticated vs plain handshake cost) and a BENCH_exchange_rs.json
# section (2-level reduce-scatter vs serialized-leader vs pipelined
# exchange at the fixed synthetic 2M4G world) and a
# BENCH_sparsify.json section (dense vs topk:1.0 vs topk:0.01 pooled
# step time and modeled network bytes at a fixed synthetic 2M1G world,
# top-k selection throughput, and the netsim EF-weighted ratio sweep
# with its interior optimum) so future PRs can diff the hot-path,
# comm-mode, input-pipeline, checkpoint, intra-node, elastic,
# transport, rejoin, exchange-schedule, and sparsification
# trajectories.
#
# Usage: scripts/bench_smoke.sh [output.json] [hier_output.json] \
#                               [input_output.json] [ckpt_output.json] \
#                               [intra_output.json] [elastic_output.json] \
#                               [transport_output.json] [rejoin_output.json] \
#                               [exchange_rs_output.json] \
#                               [sparsify_output.json]
set -euo pipefail

cd "$(dirname "$0")/.."

OUT="${1:-BENCH_hotpath.json}"
HIER_OUT="${2:-BENCH_hierarchical.json}"
INPUT_OUT="${3:-BENCH_input_pipeline.json}"
CKPT_OUT="${4:-BENCH_checkpoint.json}"
INTRA_OUT="${5:-BENCH_intranode.json}"
ELASTIC_OUT="${6:-BENCH_elastic.json}"
TRANSPORT_OUT="${7:-BENCH_transport.json}"
REJOIN_OUT="${8:-BENCH_rejoin.json}"
RS_OUT="${9:-BENCH_exchange_rs.json}"
SPARSIFY_OUT="${10:-BENCH_sparsify.json}"
export BENCH_QUICK=1
export BENCH_JSON_OUT="$OUT"
export BENCH_HIER_JSON_OUT="$HIER_OUT"
export BENCH_INPUT_JSON_OUT="$INPUT_OUT"
export BENCH_CKPT_JSON_OUT="$CKPT_OUT"
export BENCH_INTRA_JSON_OUT="$INTRA_OUT"
export BENCH_ELASTIC_JSON_OUT="$ELASTIC_OUT"
export BENCH_TRANSPORT_JSON_OUT="$TRANSPORT_OUT"
export BENCH_REJOIN_JSON_OUT="$REJOIN_OUT"
export BENCH_EXCHANGE_RS_JSON_OUT="$RS_OUT"
export BENCH_SPARSIFY_JSON_OUT="$SPARSIFY_OUT"

cargo bench --bench perf_hotpath

for f in "$OUT" "$HIER_OUT" "$INPUT_OUT" "$CKPT_OUT" "$INTRA_OUT" \
         "$ELASTIC_OUT" "$TRANSPORT_OUT" "$REJOIN_OUT" "$RS_OUT" \
         "$SPARSIFY_OUT"; do
    if [[ -f "$f" ]]; then
        echo "bench rows -> $f"
    else
        echo "ERROR: $f was not produced" >&2
        exit 1
    fi
done
