#!/usr/bin/env bash
# Quick perf smoke for CI / PR trajectory tracking: runs the
# `perf_hotpath` bench in quick mode (small payloads, few iterations)
# and emits machine-readable rows to BENCH_hotpath.json plus a
# BENCH_hierarchical.json section (flat vs hierarchical pooled step time
# at a fixed synthetic 2M2G world) so future PRs can diff both the
# hot-path timings and the comm-mode trajectory.
#
# Usage: scripts/bench_smoke.sh [output.json] [hier_output.json]
set -euo pipefail

cd "$(dirname "$0")/.."

OUT="${1:-BENCH_hotpath.json}"
HIER_OUT="${2:-BENCH_hierarchical.json}"
export BENCH_QUICK=1
export BENCH_JSON_OUT="$OUT"
export BENCH_HIER_JSON_OUT="$HIER_OUT"

cargo bench --bench perf_hotpath

for f in "$OUT" "$HIER_OUT"; do
    if [[ -f "$f" ]]; then
        echo "bench rows -> $f"
    else
        echo "ERROR: $f was not produced" >&2
        exit 1
    fi
done
