#!/usr/bin/env bash
# CI gate: formatting, lints (warnings are errors), docs (rustdoc
# warnings are errors + doc-tests), and the full test suite.  Run from
# anywhere; mirrors what a PR must pass.
#
# Usage: scripts/ci_check.sh
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo doc --no-deps (rustdoc warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> cargo test --doc"
cargo test --doc -q

# the elasticity/fault-injection suite is the robustness gate for the
# supervised-restart path; run it explicitly so a filtered or flaky
# harness cannot silently skip it before the full suite
echo "==> cargo test -q --test failure_injection"
cargo test -q --test failure_injection

# the transport suite proves the socket path bitwise-equal to the
# in-process exchange (golden wire fixture + loopback worlds) and the
# authenticated-handshake accept/reject matrix; run it explicitly so
# the multi-process guarantees cannot be silently skipped
echo "==> cargo test -q --test transport"
cargo test -q --test transport

# the exchange-schedule suite proves the 2-level reduce-scatter bitwise
# equal to the serialized/flat/spawn-baseline schedules (both wires,
# both transports) and that truncated or skewed frames fail loudly with
# named protocol errors; run it explicitly so the ISSUE-9 determinism
# and loud-fail contracts cannot be silently skipped
echo "==> cargo test -q --test exchange_rs"
cargo test -q --test exchange_rs

# the sparsification suite proves topk:1.0 bitwise-equal to the dense
# exchange (all schedules, both wires), lossy ratios deterministic
# across transports with bitwise-resumable error-feedback state, and
# tampered sparse frames failing loudly by name on both transports; run
# it explicitly so the ISSUE-10 bitwise/convergence wall cannot be
# silently skipped
echo "==> cargo test -q --test sparsify"
cargo test -q --test sparsify

# the rejoin e2e pair is the grow-back gate: a killed peer re-admitted
# at the same world size inside --rejoin-window (bitwise-equal finish),
# and a window expiry degrading to the shrink restart instead of
# hanging.  Run them by name so a filtered harness cannot skip the
# scale-UP elasticity contract.
echo "==> cargo test -q --test cli rejoin"
cargo test -q --test cli rejoin

echo "==> cargo test -q"
cargo test -q

echo "ci_check OK"
