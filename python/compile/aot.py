"""AOT compiler: lower the L2/L1 stack to HLO text + manifest.json.

This is the ONLY bridge between Python and Rust.  Each jitted function is
lowered to StableHLO, converted to an XlaComputation, and dumped as HLO
**text** (NOT ``.serialize()`` — jax >= 0.5 emits 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly, see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  manifest.json                      — the contract with rust/src/runtime
  <preset>_train_<variant>_b<B>_s<S>.hlo.txt
  <preset>_fwd_<variant>_b<B>_s<S>.hlo.txt
  <preset>_apply_<opt>.hlo.txt

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            --preset bert-tiny --batch 8 --seq 128 [--variants all]

`make artifacts` drives this; it is a no-op when inputs are unchanged.
"""

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time

import jax
import numpy as np

from . import model as M

# (fused, dtype) variants — the Table 4/5 axes (paper §5.1).
VARIANTS = {
    "unfused_f32": dict(fused=False, dtype="f32"),   # "Non-Optimized"
    "bf16": dict(fused=False, dtype="bf16"),         # "FP16" column analogue
    "fused_f32": dict(fused=True, dtype="f32"),      # fusion only
    "fused_bf16": dict(fused=True, dtype="bf16"),    # "FP16 & Fused Kernel"
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    from jax._src.lib import xla_client as xc
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_meta(spec):
    return {"shape": list(spec.shape), "dtype": str(np.dtype(spec.dtype))}


def lower_one(fn, specs, path):
    """Lower ``fn`` at ``specs`` and write HLO text to ``path``."""
    t0 = time.time()
    lowered = fn.lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return {"file": os.path.basename(path),
            "inputs": [_spec_meta(s) for s in specs],
            "hlo_bytes": len(text),
            "lower_seconds": round(time.time() - t0, 2)}


def layout_meta(cfg):
    out = []
    off = 0
    for name, shape in M.param_layout(cfg):
        n = int(np.prod(shape))
        out.append({"name": name, "offset": off, "shape": list(shape)})
        off += n
    return out


def build(out_dir, preset, batch, seq, variants, optimizers, fwd_batch=None,
          phase2=False):
    cfg0 = M.PRESETS[preset]
    os.makedirs(out_dir, exist_ok=True)
    arts = {}

    for vname in variants:
        v = VARIANTS[vname]
        cfg = dataclasses.replace(cfg0, **v)
        fn, specs = M.make_train_step(cfg, batch, seq)
        key = f"train_{vname}_b{batch}_s{seq}"
        path = os.path.join(out_dir, f"{preset}_{key}.hlo.txt")
        print(f"[aot] lowering {preset} {key} ...", flush=True)
        arts[key] = lower_one(fn, specs + (), path)
        arts[key]["outputs"] = ["loss", "mlm_loss", "nsp_loss", "mlm_acc",
                                "grads_flat", "grad_norm"]

    if phase2:
        # phase-2 train step: seq 512, smaller per-GPU batch (paper Table 6).
        b2 = max(1, batch // 8)
        cfg = dataclasses.replace(cfg0, **VARIANTS["fused_f32"])
        fn, specs = M.make_train_step(cfg, b2, 512)
        key = f"train_fused_f32_b{b2}_s512"
        path = os.path.join(out_dir, f"{preset}_{key}.hlo.txt")
        print(f"[aot] lowering {preset} {key} (phase 2) ...", flush=True)
        arts[key] = lower_one(fn, specs, path)
        arts[key]["outputs"] = ["loss", "mlm_loss", "nsp_loss", "mlm_acc",
                                "grads_flat", "grad_norm"]

    # eval-only forward (fused f32)
    fb = fwd_batch or batch
    cfg = dataclasses.replace(cfg0, **VARIANTS["fused_f32"])
    fn, specs = M.make_forward(cfg, fb, seq)
    key = f"fwd_fused_f32_b{fb}_s{seq}"
    path = os.path.join(out_dir, f"{preset}_{key}.hlo.txt")
    print(f"[aot] lowering {preset} {key} ...", flush=True)
    arts[key] = lower_one(fn, specs, path)
    arts[key]["outputs"] = ["loss", "mlm_loss", "nsp_loss", "mlm_acc"]

    for opt in optimizers:
        fn, specs = M.make_apply(cfg0, opt)
        key = f"apply_{opt}"
        path = os.path.join(out_dir, f"{preset}_{key}.hlo.txt")
        print(f"[aot] lowering {preset} {key} ...", flush=True)
        arts[key] = lower_one(fn, specs, path)
        arts[key]["outputs"] = ["params", "m", "v"]

    # fine-tuning (QA span head, paper §3.1.2/§5.3)
    cfg = dataclasses.replace(cfg0, **VARIANTS["fused_f32"])
    fn, specs = M.make_qa_train_step(cfg, batch, seq)
    key = f"qa_train_b{batch}_s{seq}"
    path = os.path.join(out_dir, f"{preset}_{key}.hlo.txt")
    print(f"[aot] lowering {preset} {key} ...", flush=True)
    arts[key] = lower_one(fn, specs, path)
    arts[key]["outputs"] = ["loss", "start_acc", "end_acc", "exact",
                            "grads_flat", "grad_norm"]
    fn, specs = M.make_qa_apply(cfg0)
    key = "qa_apply"
    path = os.path.join(out_dir, f"{preset}_{key}.hlo.txt")
    print(f"[aot] lowering {preset} {key} ...", flush=True)
    arts[key] = lower_one(fn, specs, path)
    arts[key]["outputs"] = ["params", "m", "v"]

    return {
        "preset": preset,
        "config": {
            "vocab_size": cfg0.vocab_size, "hidden": cfg0.hidden,
            "layers": cfg0.layers, "heads": cfg0.heads,
            "intermediate": cfg0.intermediate, "max_seq": cfg0.max_seq,
            "type_vocab": cfg0.type_vocab,
        },
        "param_count": M.param_count(cfg0),
        "finetune_param_count": M.finetune_param_count(cfg0),
        "batch": batch, "seq": seq,
        "layout": layout_meta(cfg0),
        "artifacts": arts,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", action="append", default=None,
                    help="model preset(s); default: bert-micro + bert-tiny")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--variants", default="all",
                    help="comma list or 'all': " + ",".join(VARIANTS))
    ap.add_argument("--optimizers", default="lamb,adam")
    ap.add_argument("--phase2", action="store_true", default=True,
                    help="also emit the seq-512 phase-2 train step")
    ap.add_argument("--no-phase2", dest="phase2", action="store_false")
    args = ap.parse_args()

    presets = args.preset or ["bert-micro", "bert-tiny"]
    variants = (list(VARIANTS) if args.variants == "all"
                else args.variants.split(","))
    optimizers = args.optimizers.split(",") if args.optimizers else []

    manifest = {"version": 1, "jax_version": jax.__version__, "models": {}}
    for preset in presets:
        if preset == "bert-micro":
            # micro: CI-speed integration-test model, tiny shapes
            m = build(args.out_dir, preset, batch=2, seq=32,
                      variants=variants, optimizers=optimizers, phase2=False)
        else:
            m = build(args.out_dir, preset, batch=args.batch, seq=args.seq,
                      variants=variants, optimizers=optimizers,
                      phase2=args.phase2)
        manifest["models"][preset] = m

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {man_path} "
          f"({sum(len(m['artifacts']) for m in manifest['models'].values())} "
          f"artifacts)")


if __name__ == "__main__":
    main()
