"""L2 perf audit: op-count statistics over lowered HLO artifacts.

Used by the EXPERIMENTS.md §Perf pass: counts HLO instructions by opcode
per artifact, so the fused-vs-unfused structural claim (§4.3) and any
regression in graph size are visible without running anything.

Usage: cd python && python -m compile.hlo_stats [--dir ../artifacts]
"""

import argparse
import collections
import os
import re

OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{},\s]*?\s(\w+)\(")

INTERESTING = ["fusion", "tanh", "multiply", "add", "dot", "transpose",
               "reduce", "exponential", "convert", "while", "custom-call"]


def stats_for(path):
    counts = collections.Counter()
    total = 0
    with open(path) as f:
        for line in f:
            m = OP_RE.match(line)
            if m:
                counts[m.group(1)] += 1
                total += 1
    return total, counts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="../artifacts")
    ap.add_argument("--filter", default="")
    args = ap.parse_args()

    rows = []
    for name in sorted(os.listdir(args.dir)):
        if not name.endswith(".hlo.txt") or args.filter not in name:
            continue
        total, counts = stats_for(os.path.join(args.dir, name))
        rows.append((name, total, counts))

    width = max((len(r[0]) for r in rows), default=20)
    print(f"{'artifact':<{width}}  {'ops':>6}  " +
          "  ".join(f"{op:>10}" for op in INTERESTING))
    for name, total, counts in rows:
        print(f"{name:<{width}}  {total:>6}  " +
              "  ".join(f"{counts.get(op, 0):>10}" for op in INTERESTING))


if __name__ == "__main__":
    main()
