"""Layer-2: BERT encoder forward/backward in JAX (build-time only).

The paper (§2.1, §3.3) pretrains BERT-large with the two standard
objectives: masked-LM and next-sentence prediction.  This module defines
the model as a pure function over a SINGLE FLAT f32 parameter vector
(DESIGN.md §4 "flat-parameter convention") so that the Rust coordinator
sees one contiguous gradient buffer — the unit that ring allreduce,
bucketed overlap, and gradient accumulation all operate on.

Variants (paper §4.2 / §4.3):
  * ``fused=True``  — GELU / LayerNorm / attention run as Pallas kernels
    (with fused backward, see kernels.autodiff);
  * ``fused=False`` — the paper's op-by-op decomposition (7-op GELU etc.);
  * ``dtype='bf16'``— AMP-style mixed precision: matmul inputs cast to
    bfloat16 (the TPU analogue of FP16 TensorCore math), accumulation and
    numerically-dangerous ops (softmax, layernorm, exp/log) kept in f32,
    master weights stay f32 — exactly the paper's safe/dangerous split;
  * ``dtype='f32'`` — full precision baseline.

Everything here is lowered ONCE by aot.py to HLO text; Python never runs
on the training path.
"""

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import autodiff as fused
from .kernels import ref as unfused
from .kernels.fused_lamb import fused_lamb

IGNORE_INDEX = -1  # mlm_labels value for unmasked positions


# ------------------------------------------------------------- configs --

@dataclasses.dataclass(frozen=True)
class BertConfig:
    """Architecture hyper-parameters (paper §2.1: BERT-large shapes)."""
    vocab_size: int = 8192
    hidden: int = 256
    layers: int = 4
    heads: int = 4
    intermediate: int = 1024
    max_seq: int = 128
    type_vocab: int = 2
    fused: bool = True
    dtype: str = "f32"  # "f32" | "bf16"

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads


# Named presets.  bert-large is the paper's target; the smaller ones are
# what a 1-core CPU testbed can actually train (DESIGN.md §2 substitution).
PRESETS: Dict[str, BertConfig] = {
    "bert-micro": BertConfig(vocab_size=512, hidden=64, layers=2, heads=2,
                             intermediate=256, max_seq=64),
    # max_seq=512 so phase-2 (seq 512) shares the phase-1 position table,
    # exactly like the paper's two-phase schedule (§3.3).
    "bert-tiny": BertConfig(vocab_size=8192, hidden=128, layers=2, heads=2,
                            intermediate=512, max_seq=512),
    "bert-mini": BertConfig(vocab_size=8192, hidden=256, layers=4, heads=4,
                            intermediate=1024, max_seq=512),
    "bert-medium": BertConfig(vocab_size=8192, hidden=512, layers=8, heads=8,
                              intermediate=2048, max_seq=512),
    "bert-base": BertConfig(vocab_size=30522, hidden=768, layers=12, heads=12,
                            intermediate=3072, max_seq=512),
    "bert-large": BertConfig(vocab_size=30522, hidden=1024, layers=24,
                             heads=16, intermediate=4096, max_seq=512),
}


# ------------------------------------------------------- param layout  --

def param_layout(cfg: BertConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat parameter vector.

    The order is the serialization contract with the Rust side
    (manifest.json) — NEVER reorder without bumping the manifest version.
    Names follow huggingface-style grouping so the Rust `model::layout`
    module can classify tensors into the paper's Figure-4 groups
    (embedding / attention / intermediate / output / other).
    """
    h, i, v = cfg.hidden, cfg.intermediate, cfg.vocab_size
    out: List[Tuple[str, Tuple[int, ...]]] = [
        ("embeddings.word_embeddings", (v, h)),
        ("embeddings.position_embeddings", (cfg.max_seq, h)),
        ("embeddings.token_type_embeddings", (cfg.type_vocab, h)),
        ("embeddings.layernorm.gamma", (h,)),
        ("embeddings.layernorm.beta", (h,)),
    ]
    for l in range(cfg.layers):
        p = f"encoder.layer.{l}"
        out += [
            (f"{p}.attention.query.weight", (h, h)),
            (f"{p}.attention.query.bias", (h,)),
            (f"{p}.attention.key.weight", (h, h)),
            (f"{p}.attention.key.bias", (h,)),
            (f"{p}.attention.value.weight", (h, h)),
            (f"{p}.attention.value.bias", (h,)),
            (f"{p}.attention.output.weight", (h, h)),
            (f"{p}.attention.output.bias", (h,)),
            (f"{p}.attention.layernorm.gamma", (h,)),
            (f"{p}.attention.layernorm.beta", (h,)),
            (f"{p}.intermediate.weight", (h, i)),
            (f"{p}.intermediate.bias", (i,)),
            (f"{p}.output.weight", (i, h)),
            (f"{p}.output.bias", (h,)),
            (f"{p}.output.layernorm.gamma", (h,)),
            (f"{p}.output.layernorm.beta", (h,)),
        ]
    out += [
        ("cls.predictions.transform.weight", (h, h)),
        ("cls.predictions.transform.bias", (h,)),
        ("cls.predictions.layernorm.gamma", (h,)),
        ("cls.predictions.layernorm.beta", (h,)),
        ("cls.predictions.bias", (v,)),           # decoder tied to word emb
        ("cls.pooler.weight", (h, h)),
        ("cls.pooler.bias", (h,)),
        ("cls.seq_relationship.weight", (h, 2)),
        ("cls.seq_relationship.bias", (2,)),
    ]
    return out


def param_count(cfg: BertConfig) -> int:
    return sum(int(np.prod(s)) for _, s in param_layout(cfg))


def init_params(cfg: BertConfig, seed: int = 0) -> np.ndarray:
    """Truncated-normal(0.02) init like BERT; returns the flat f32 vector."""
    rng = np.random.RandomState(seed)
    chunks = []
    for name, shape in param_layout(cfg):
        n = int(np.prod(shape))
        if name.endswith(".gamma"):
            chunks.append(np.ones(n, np.float32))
        elif name.endswith((".beta", ".bias")):
            chunks.append(np.zeros(n, np.float32))
        else:
            w = rng.normal(0.0, 0.02, size=n)
            w = np.clip(w, -0.04, 0.04)  # cheap truncation at 2 sigma
            chunks.append(w.astype(np.float32))
    return np.concatenate(chunks)


def unflatten(flat, cfg: BertConfig):
    """Split the flat vector into the named parameter dict (jit-traceable)."""
    params = {}
    off = 0
    for name, shape in param_layout(cfg):
        n = int(np.prod(shape))
        params[name] = flat[off:off + n].reshape(shape)
        off += n
    return params


# ------------------------------------------------------------ forward  --

def _linear(x, w, b, cfg: BertConfig):
    """Matmul in the compute dtype (bf16 under AMP), f32 accumulate."""
    if cfg.dtype == "bf16":
        y = jnp.dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)
    else:
        y = jnp.dot(x, w)
    return y + b


def _gelu(x, cfg: BertConfig):
    return fused.gelu(x) if cfg.fused else unfused.gelu_unfused(x)


def _layernorm(x, g, b, cfg: BertConfig):
    # LayerNorm is numerically dangerous in half precision (paper §4.2):
    # always computed in f32, mirroring AMP's blacklist.
    x = x.astype(jnp.float32)
    if cfg.fused:
        return fused.layernorm(x, g, b)
    return unfused.layernorm_unfused(x, g, b)


def _attention_block(x, p, prefix, mask, cfg: BertConfig):
    b, s, h = x.shape
    nh, hd = cfg.heads, cfg.head_dim

    def split_heads(t):
        return t.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)

    q = split_heads(_linear(x, p[f"{prefix}.query.weight"],
                            p[f"{prefix}.query.bias"], cfg))
    k = split_heads(_linear(x, p[f"{prefix}.key.weight"],
                            p[f"{prefix}.key.bias"], cfg))
    v = split_heads(_linear(x, p[f"{prefix}.value.weight"],
                            p[f"{prefix}.value.bias"], cfg))
    scale = 1.0 / float(np.sqrt(hd))
    if cfg.fused:
        ctx = fused.attention(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), mask, scale)
    else:
        ctx = unfused.attention(q, k, v, mask, scale)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, h)
    out = _linear(ctx, p[f"{prefix}.output.weight"],
                  p[f"{prefix}.output.bias"], cfg)
    return _layernorm(x + out, p[f"{prefix}.layernorm.gamma"],
                      p[f"{prefix}.layernorm.beta"], cfg)


def encoder_forward(params, input_ids, token_type_ids, attention_mask,
                    cfg: BertConfig):
    """BERT encoder: embeddings + L transformer layers.

    Returns the final hidden states f32[B, S, H].
    """
    b, s = input_ids.shape
    positions = jnp.arange(s)[None, :]
    x = (params["embeddings.word_embeddings"][input_ids]
         + params["embeddings.position_embeddings"][positions]
         + params["embeddings.token_type_embeddings"][token_type_ids])
    x = _layernorm(x, params["embeddings.layernorm.gamma"],
                   params["embeddings.layernorm.beta"], cfg)

    # additive mask: 0 for real tokens, -1e9 for padding
    mask = (1.0 - attention_mask.astype(jnp.float32)) * -1e9
    mask = mask[:, None, None, :]

    for l in range(cfg.layers):
        p = f"encoder.layer.{l}"
        x = _attention_block(x, params, f"{p}.attention", mask, cfg)
        inter = _gelu(_linear(x, params[f"{p}.intermediate.weight"],
                              params[f"{p}.intermediate.bias"], cfg), cfg)
        out = _linear(inter, params[f"{p}.output.weight"],
                      params[f"{p}.output.bias"], cfg)
        x = _layernorm(x + out, params[f"{p}.output.layernorm.gamma"],
                       params[f"{p}.output.layernorm.beta"], cfg)
    return x


def pretrain_loss(flat_params, input_ids, token_type_ids, attention_mask,
                  mlm_labels, nsp_labels, cfg: BertConfig):
    """Masked-LM + NSP loss (paper §2.1 objectives).

    mlm_labels: i32[B,S], IGNORE_INDEX (-1) at unmasked positions.
    nsp_labels: i32[B] in {0,1}.
    Returns (loss, (mlm_loss, nsp_loss, mlm_acc)).
    """
    p = unflatten(flat_params, cfg)
    hidden = encoder_forward(p, input_ids, token_type_ids, attention_mask, cfg)

    # --- MLM head: transform -> layernorm -> tied decoder
    t = _gelu(_linear(hidden, p["cls.predictions.transform.weight"],
                      p["cls.predictions.transform.bias"], cfg), cfg)
    t = _layernorm(t, p["cls.predictions.layernorm.gamma"],
                   p["cls.predictions.layernorm.beta"], cfg)
    logits = _linear(t, p["embeddings.word_embeddings"].T,
                     p["cls.predictions.bias"], cfg)  # [B,S,V]

    mask = (mlm_labels != IGNORE_INDEX)
    safe_labels = jnp.where(mask, mlm_labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1)
    mlm_loss = jnp.sum(jnp.where(mask, nll, 0.0)) / denom
    mlm_acc = jnp.sum(jnp.where(mask, jnp.argmax(logits, -1) == safe_labels,
                                False)) / denom

    # --- NSP head: pooler(tanh) on [CLS] -> 2-way classifier
    cls = hidden[:, 0, :]
    pooled = jnp.tanh(_linear(cls, p["cls.pooler.weight"],
                              p["cls.pooler.bias"], cfg))
    nsp_logits = _linear(pooled, p["cls.seq_relationship.weight"],
                         p["cls.seq_relationship.bias"], cfg)
    nsp_logp = jax.nn.log_softmax(nsp_logits.astype(jnp.float32), axis=-1)
    nsp_loss = -jnp.mean(
        jnp.take_along_axis(nsp_logp, nsp_labels[:, None], axis=-1))

    loss = mlm_loss + nsp_loss
    return loss, (mlm_loss, nsp_loss, mlm_acc)


# --------------------------------------------------------- train step  --

def train_step(flat_params, input_ids, token_type_ids, attention_mask,
               mlm_labels, nsp_labels, loss_scale, cfg: BertConfig):
    """One forward+backward micro-step.

    Loss scaling (paper §4.2): the loss is multiplied by ``loss_scale``
    before differentiation and the gradients divided by it afterwards, so
    small-magnitude gradients survive the reduced dynamic range of the
    half-precision compute path.  The Rust AMP engine owns the dynamic
    adjustment of ``loss_scale`` and checks the returned ``grad_norm`` /
    finiteness for overflow.

    Returns (loss, mlm_loss, nsp_loss, mlm_acc, grads_flat, grad_norm).
    """
    def scaled(fp):
        loss, aux = pretrain_loss(fp, input_ids, token_type_ids,
                                  attention_mask, mlm_labels, nsp_labels, cfg)
        return loss * loss_scale, (loss, aux)

    grads, (loss, aux) = jax.grad(scaled, has_aux=True)(flat_params)
    grads = grads / loss_scale
    mlm_loss, nsp_loss, mlm_acc = aux
    grad_norm = jnp.sqrt(jnp.sum(grads * grads))
    return (loss.astype(jnp.float32), mlm_loss.astype(jnp.float32),
            nsp_loss.astype(jnp.float32), mlm_acc.astype(jnp.float32),
            grads, grad_norm.astype(jnp.float32))


# ------------------------------------------------------- optimizer step --

def apply_lamb(flat_params, flat_grads, flat_m, flat_v, step, lr,
               cfg: BertConfig, clip_norm: float = 1.0):
    """LAMB apply over the flat vector with PER-TENSOR trust ratios.

    The flat vector is sliced along the manifest layout so each tensor
    gets its own layer-wise trust ratio (the point of LAMB, §2.1); each
    slice update is the fused Pallas LAMB kernel.  Global grad-norm
    clipping at ``clip_norm`` matches the NVIDIA BERT recipe the paper
    builds on.
    """
    gnorm = jnp.sqrt(jnp.sum(flat_grads * flat_grads))
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-12))
    g = flat_grads * scale

    new_p, new_m, new_v = [], [], []
    off = 0
    for _name, shape in param_layout(cfg):
        n = int(np.prod(shape))
        sl = slice(off, off + n)
        pn, mn, vn = fused_lamb(flat_params[sl], g[sl], flat_m[sl],
                                flat_v[sl], step, lr)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
        off += n
    return (jnp.concatenate(new_p), jnp.concatenate(new_m),
            jnp.concatenate(new_v))


def apply_adam(flat_params, flat_grads, flat_m, flat_v, step, lr,
               cfg: BertConfig, clip_norm: float = 1.0):
    """AdamW apply over the flat vector (baseline optimizer)."""
    from .kernels.ref import adam_update
    gnorm = jnp.sqrt(jnp.sum(flat_grads * flat_grads))
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-12))
    g = flat_grads * scale
    return adam_update(flat_params, g, flat_m, flat_v, step, lr)


# ------------------------------------------------------------- jitting --

def make_train_step(cfg: BertConfig, batch: int, seq: int):
    """Concrete jit-able train step with shapes baked (AOT unit)."""
    def fn(flat_params, input_ids, token_type_ids, attention_mask,
           mlm_labels, nsp_labels, loss_scale):
        return train_step(flat_params, input_ids, token_type_ids,
                          attention_mask, mlm_labels, nsp_labels,
                          loss_scale, cfg)
    n = param_count(cfg)
    specs = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return jax.jit(fn), specs


def make_apply(cfg: BertConfig, optimizer: str = "lamb"):
    """Concrete jit-able optimizer apply (AOT unit)."""
    apply = apply_lamb if optimizer == "lamb" else apply_adam

    def fn(params, grads, m, v, step, lr):
        return apply(params, grads, m, v, step, lr, cfg)
    n = param_count(cfg)
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return jax.jit(fn), (vec, vec, vec, vec, scalar, scalar)


def make_forward(cfg: BertConfig, batch: int, seq: int):
    """Inference-only forward returning (loss, mlm_acc) — used for eval."""
    def fn(flat_params, input_ids, token_type_ids, attention_mask,
           mlm_labels, nsp_labels):
        loss, (mlm, nsp, acc) = pretrain_loss(
            flat_params, input_ids, token_type_ids, attention_mask,
            mlm_labels, nsp_labels, cfg)
        return (loss.astype(jnp.float32), mlm.astype(jnp.float32),
                nsp.astype(jnp.float32), acc.astype(jnp.float32))
    n = param_count(cfg)
    specs = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    return jax.jit(fn), specs


# ------------------------------------------------- fine-tuning (QA) ----
# Paper §3.1.2/§5.3: the pre-trained checkpoint is fine-tuned on SQuAD
# (extractive QA).  The mechanism: a span-prediction head (hidden -> 2)
# on top of the encoder, trained with start/end cross-entropy.  The flat
# fine-tune parameter vector is the pretraining vector plus the head.

def finetune_layout(cfg: BertConfig):
    """Flat layout for fine-tuning = pretraining layout + QA head."""
    return param_layout(cfg) + [
        ("qa.weight", (cfg.hidden, 2)),
        ("qa.bias", (2,)),
    ]


def finetune_param_count(cfg: BertConfig) -> int:
    return sum(int(np.prod(s)) for _, s in finetune_layout(cfg))


def qa_loss(flat_ft_params, input_ids, token_type_ids, attention_mask,
            start_positions, end_positions, cfg: BertConfig):
    """Extractive-QA span loss (start/end cross-entropy, SQuAD-style)."""
    n_pre = param_count(cfg)
    pre = flat_ft_params[:n_pre]
    head = flat_ft_params[n_pre:]
    p = unflatten(pre, cfg)
    w = head[: cfg.hidden * 2].reshape(cfg.hidden, 2)
    b = head[cfg.hidden * 2:]

    hidden = encoder_forward(p, input_ids, token_type_ids, attention_mask,
                             cfg)
    logits = jnp.dot(hidden, w) + b                      # [B, S, 2]
    # mask out padding positions
    neg = (1.0 - attention_mask.astype(jnp.float32)) * -1e9
    start_logits = logits[..., 0] + neg                  # [B, S]
    end_logits = logits[..., 1] + neg

    def ce(lg, pos):
        lp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, pos[:, None], axis=-1))

    loss = 0.5 * (ce(start_logits, start_positions)
                  + ce(end_logits, end_positions))
    start_acc = jnp.mean(
        (jnp.argmax(start_logits, -1) == start_positions).astype(jnp.float32))
    end_acc = jnp.mean(
        (jnp.argmax(end_logits, -1) == end_positions).astype(jnp.float32))
    exact = jnp.mean(
        ((jnp.argmax(start_logits, -1) == start_positions)
         & (jnp.argmax(end_logits, -1) == end_positions))
        .astype(jnp.float32))
    return loss, (start_acc, end_acc, exact)


def make_qa_train_step(cfg: BertConfig, batch: int, seq: int):
    """Concrete jit-able QA fine-tuning step (AOT unit)."""
    def fn(flat_ft, input_ids, token_type_ids, attention_mask,
           start_positions, end_positions, loss_scale):
        def scaled(fp):
            loss, aux = qa_loss(fp, input_ids, token_type_ids,
                                attention_mask, start_positions,
                                end_positions, cfg)
            return loss * loss_scale, (loss, aux)
        grads, (loss, aux) = jax.grad(scaled, has_aux=True)(flat_ft)
        grads = grads / loss_scale
        start_acc, end_acc, exact = aux
        gnorm = jnp.sqrt(jnp.sum(grads * grads))
        return (loss.astype(jnp.float32), start_acc.astype(jnp.float32),
                end_acc.astype(jnp.float32), exact.astype(jnp.float32),
                grads, gnorm.astype(jnp.float32))
    n = finetune_param_count(cfg)
    specs = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return jax.jit(fn), specs


def make_qa_apply(cfg: BertConfig):
    """AdamW apply over the fine-tune flat vector (SQuAD recipe uses
    Adam; LAMB is a pretraining-scale tool)."""
    from .kernels.ref import adam_update
    def fn(params, grads, m, v, step, lr):
        gnorm = jnp.sqrt(jnp.sum(grads * grads))
        scale = jnp.minimum(1.0, 1.0 / (gnorm + 1e-12))
        return adam_update(params, grads * scale, m, v, step, lr)
    n = finetune_param_count(cfg)
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return jax.jit(fn), (vec, vec, vec, vec, scalar, scalar)
