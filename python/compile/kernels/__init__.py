"""Layer-1 Pallas kernels for bertdist.

All kernels lower with ``interpret=True`` so the emitted HLO runs on any
PJRT backend (the Rust coordinator uses the CPU plugin).  Each kernel has
a pure-jnp oracle in :mod:`ref` and a hypothesis-swept pytest in
``python/tests/test_kernels.py``.
"""

from . import ref
from .fused_gelu import fused_gelu
from .fused_layernorm import fused_layernorm
from .fused_lamb import fused_lamb
from .attention import fused_attention

__all__ = [
    "ref",
    "fused_gelu",
    "fused_layernorm",
    "fused_lamb",
    "fused_attention",
]
