"""Fused LAMB optimizer step as a Pallas kernel (paper §4.3 fuses the
optimizer with Apex; §2.1 motivates LAMB for large-batch BERT).

Unfused LAMB touches each of (p, g, m, v) several times: moment updates,
bias correction, the update direction, two norms, and the final axpy.  The
fused kernel does all elementwise work in ONE pass per tile and
accumulates the two norms (‖p‖², ‖update‖²) into scratch, then a second
tiny pass applies the trust-ratio-scaled update.

Because the trust ratio is a *per-tensor* scalar that depends on a full
reduction, the kernel is structured as a two-phase grid:
  phase A (grid over tiles): m' = β₁m+(1-β₁)g ; v' = β₂v+(1-β₂)g² ;
           u = m̂/(√v̂+ε)+λp ; accumulate Σp², Σu² ; write m', v', u
  phase B (host-level, fused into the same jitted fn): trust = ‖p‖/‖u‖ ;
           p' = p − lr·trust·u   (a single fused axpy pallas pass)

This mirrors how Apex's multi-tensor LAMB splits into two multi-tensor
launches on CUDA.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-6
WEIGHT_DECAY = 0.01
DEFAULT_BLOCK = 65536  # elements per tile: 4 arrays * 256 KiB = 1 MiB VMEM


def _lamb_phase_a_kernel(p_ref, g_ref, m_ref, v_ref, c1_ref, c2_ref,
                         m_out, v_out, u_out, psq_out, usq_out):
    """One fused pass: moments, bias-corrected update dir, norm partials."""
    p = p_ref[...]
    g = g_ref[...]
    m = BETA1 * m_ref[...] + (1.0 - BETA1) * g
    v = BETA2 * v_ref[...] + (1.0 - BETA2) * g * g
    m_hat = m / c1_ref[0]
    v_hat = v / c2_ref[0]
    u = m_hat / (jnp.sqrt(v_hat) + EPS) + WEIGHT_DECAY * p
    m_out[...] = m
    v_out[...] = v
    u_out[...] = u
    psq_out[0] = jnp.sum(p * p)
    usq_out[0] = jnp.sum(u * u)


def _lamb_phase_b_kernel(p_ref, u_ref, s_ref, p_out):
    """Trust-scaled axpy: p' = p - (lr*trust) * u."""
    p_out[...] = p_ref[...] - s_ref[0] * u_ref[...]


@functools.partial(jax.jit, static_argnames=())
def fused_lamb(p, g, m, v, step, lr):
    """Fused LAMB update for one flat f32 tensor.

    Args:
      p, g, m, v: f32[N] parameter, gradient, first/second moments.
      step: f32 scalar (1-based step count, for bias correction).
      lr: f32 scalar learning rate.
    Returns: (p_new, m_new, v_new).
    """
    n = p.shape[0]
    c1 = (1.0 - BETA1 ** step).reshape(1)
    c2 = (1.0 - BETA2 ** step).reshape(1)

    block = DEFAULT_BLOCK if n % DEFAULT_BLOCK == 0 else n
    grid_n = n // block
    m_new, v_new, u, psq, usq = pl.pallas_call(
        _lamb_phase_a_kernel,
        grid=(grid_n,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), p.dtype),
            jax.ShapeDtypeStruct((n,), p.dtype),
            jax.ShapeDtypeStruct((n,), p.dtype),
            jax.ShapeDtypeStruct((grid_n,), p.dtype),
            jax.ShapeDtypeStruct((grid_n,), p.dtype),
        ],
        interpret=True,
    )(p, g, m, v, c1, c2)

    w_norm = jnp.sqrt(jnp.sum(psq))
    u_norm = jnp.sqrt(jnp.sum(usq))
    trust = jnp.where(w_norm > 0.0,
                      jnp.where(u_norm > 0.0, w_norm / u_norm, 1.0), 1.0)
    scale = (lr * trust).reshape(1)

    p_new = pl.pallas_call(
        _lamb_phase_b_kernel,
        grid=(grid_n,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), p.dtype),
        interpret=True,
    )(p, u, scale)
    return p_new, m_new, v_new


def vmem_bytes(block=DEFAULT_BLOCK, dtype_bytes=4):
    """Phase-A VMEM per instance: 4 in tiles + 3 out tiles (+scalars)."""
    return 7 * block * dtype_bytes
