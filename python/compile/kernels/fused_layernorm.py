"""Fused LayerNorm as a Pallas kernel (paper §4.3).

Unfused LayerNorm is 4+ passes over the activation (mean, variance,
normalize, affine).  The fused kernel computes both row statistics and the
normalized/affine output in a single VMEM residency of the tile: one HBM
read, one HBM write per element, plus a broadcast read of gamma/beta.

BlockSpec: tile over rows (token axis), keep the feature axis whole so the
row reduction is a single in-register reduction along lanes.  gamma/beta
are replicated to every program instance (block index map pins them to
block 0).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256
EPS = 1e-12


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    feat = x.shape[-1]
    mu = jnp.sum(x, axis=-1, keepdims=True) / feat
    d = x - mu
    var = jnp.sum(d * d, axis=-1, keepdims=True) / feat
    inv = jax.lax.rsqrt(var + EPS)
    o_ref[...] = d * inv * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def fused_layernorm(x, gamma, beta, block_rows=DEFAULT_BLOCK_ROWS):
    """Fused LayerNorm over the last axis of ``x`` ([..., feat])."""
    orig_shape = x.shape
    feat = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, feat)
    g2 = gamma.reshape(1, feat)
    b2 = beta.reshape(1, feat)

    if rows % block_rows != 0:
        out = pl.pallas_call(
            _layernorm_kernel,
            out_shape=jax.ShapeDtypeStruct((rows, feat), x.dtype),
            interpret=True,
        )(x2, g2, b2)
        return out.reshape(orig_shape)

    grid = (rows // block_rows,)
    out = pl.pallas_call(
        _layernorm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
            pl.BlockSpec((1, feat), lambda i: (0, 0)),  # gamma: replicated
            pl.BlockSpec((1, feat), lambda i: (0, 0)),  # beta: replicated
        ],
        out_specs=pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, feat), x.dtype),
        interpret=True,
    )(x2, g2, b2)
    return out.reshape(orig_shape)


def vmem_bytes(block_rows, feat, dtype_bytes=4):
    """VMEM per instance: in tile + out tile + gamma + beta."""
    return (2 * block_rows + 2) * feat * dtype_bytes
