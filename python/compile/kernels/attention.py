"""Encoder self-attention core as a Pallas kernel.

BERT's encoder attention (paper §2.1) is the compute hot-spot: two batched
matmuls around a masked softmax.  On CUDA the paper relies on cuBLAS +
elementwise kernels; on TPU the insight maps to an MXU-friendly tiled
kernel (DESIGN.md §3 Hardware-Adaptation):

  * one program instance per (batch, head): Q·Kᵀ runs on the MXU with the
    full [S, D] tiles resident in VMEM (S ≤ 512, D = head_dim ≤ 128, so
    QKV + scores fit comfortably: 3·S·D·4 + S·S·4 ≈ 1.8 MiB at S=512),
  * the softmax (max-subtract, exp, normalize) stays fused in the same
    kernel — no HBM round trip for the S×S score matrix, which is the
    whole point (the unfused path materializes scores twice),
  * the additive mask is applied in-register before the max.

For very long sequences this would become a FlashAttention-style k-loop
with running max/denominator; BERT phase-2 tops out at S=512 where the
single-tile variant is already VMEM-resident, so we keep the simpler
schedule (documented trade-off, DESIGN.md §9).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, mask_ref, scale_ref, o_ref):
    """Fused QKᵀ → mask → softmax → ·V for one (batch, head) tile."""
    q = q_ref[0]            # [S, D]
    k = k_ref[0]            # [S, D]
    v = v_ref[0]            # [S, D]
    mask = mask_ref[0]      # [1, S] additive
    scale = scale_ref[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = scores + mask  # broadcast [1,S] over rows
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(probs, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def fused_attention(q, k, v, mask, scale):
    """Fused attention.

    Args:
      q, k, v: f32[B, H, S, D].
      mask: f32[B, 1, 1, S] additive mask (0 keep / -1e9 drop).
      scale: f32 scalar (1/sqrt(D)).
    Returns f32[B, H, S, D].
    """
    b, h, s, d = q.shape
    bh = b * h
    q2 = q.reshape(bh, s, d)
    k2 = k.reshape(bh, s, d)
    v2 = v.reshape(bh, s, d)
    # mask per (batch) broadcast over heads -> [bh, 1, s]
    mask2 = jnp.broadcast_to(mask.reshape(b, 1, 1, s), (b, h, 1, s)).reshape(bh, 1, s)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1)

    out = pl.pallas_call(
        _attention_kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
        interpret=True,
    )(q2, k2, v2, mask2, scale_arr)
    return out.reshape(b, h, s, d)


def vmem_bytes(s, d, dtype_bytes=4):
    """VMEM per (batch, head) instance: Q,K,V,O tiles + SxS scores."""
    return (4 * s * d + s * s) * dtype_bytes


def mxu_utilization_estimate(s, d):
    """Fraction of MXU 128x128 tiles carrying useful work for QK^T.

    The MXU processes ceil(S/128)*ceil(S/128)*ceil(D/128) tiles; useful
    work is S*S*D. Perfectly aligned shapes (S,D multiples of 128) => 1.0.
    """
    import math
    tiles = math.ceil(s / 128) * math.ceil(s / 128) * math.ceil(d / 128)
    useful = (s * s * d) / (tiles * 128 * 128 * 128)
    return useful
