"""custom_vjp wrappers that make the fused Pallas kernels differentiable.

Interpret-mode ``pallas_call`` does not support reverse-mode autodiff, so
each fused forward kernel gets an explicit VJP:

* ``gelu``      — backward is itself a fused Pallas kernel (one pass:
                  recompute tanh(u) and apply the analytic dGELU).
* ``layernorm`` — dx is a fused Pallas kernel (one pass per row tile,
                  using the saved inverse-σ); dγ/dβ are cross-row
                  reductions left to XLA (they fuse into one pass).
* ``attention`` — backward is the standard einsum chain; it is matmul-
                  dominated, which the MXU (and XLA CPU) already handles
                  at peak, so there is nothing to fuse by hand.

This mirrors Apex: fused forward + fused elementwise backward, matmul
backward delegated to the BLAS layer.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .fused_gelu import _gelu_kernel, DEFAULT_BLOCK_ROWS
from .fused_layernorm import EPS
from .attention import fused_attention as _fused_attention_fwd
from .ref import GELU_A, GELU_B, GELU_C


# ---------------------------------------------------------------- GELU --

def _dgelu_kernel(x_ref, dy_ref, dx_ref):
    """Fused dGELU: one VMEM pass, recomputes tanh(u) instead of saving it.

    y  = a*x*(1 + t),  t = tanh(u),  u = b*(x + c*x^3)
    dy/dx = a*(1 + t) + a*x*(1 - t^2)*b*(1 + 3*c*x^2)
    """
    x = x_ref[...]
    dy = dy_ref[...]
    u = GELU_B * (x + GELU_C * x * x * x)
    t = jnp.tanh(u)
    du = GELU_B * (1.0 + 3.0 * GELU_C * x * x)
    dx_ref[...] = dy * (GELU_A * (1.0 + t) + GELU_A * x * (1.0 - t * t) * du)


def _tiled_call_2(kernel, a, b, out_dtype, block_rows=DEFAULT_BLOCK_ROWS):
    """Run a 2-input elementwise kernel tiled over rows of [rows, feat]."""
    rows, feat = a.shape
    if rows % block_rows != 0:
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((rows, feat), out_dtype),
            interpret=True,
        )(a, b)
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, feat), out_dtype),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def gelu(x):
    """Differentiable fused GELU (forward + backward both Pallas)."""
    from .fused_gelu import fused_gelu
    return fused_gelu(x)


def _gelu_fwd(x):
    from .fused_gelu import fused_gelu
    return fused_gelu(x), x


def _gelu_bwd(x, dy):
    shape = x.shape
    feat = shape[-1]
    rows = x.size // feat
    dx = _tiled_call_2(_dgelu_kernel, x.reshape(rows, feat),
                       dy.reshape(rows, feat), x.dtype)
    return (dx.reshape(shape),)


gelu.defvjp(_gelu_fwd, _gelu_bwd)


# ----------------------------------------------------------- LayerNorm --

def _dln_dx_kernel(xhat_ref, dyg_ref, inv_ref, dx_ref):
    """Fused LayerNorm dx given xhat, dy*gamma and inv-sigma per row.

    dx = inv * (dyg - mean(dyg) - xhat * mean(dyg * xhat))
    """
    xhat = xhat_ref[...]
    dyg = dyg_ref[...]
    inv = inv_ref[...]
    feat = xhat.shape[-1]
    m1 = jnp.sum(dyg, axis=-1, keepdims=True) / feat
    m2 = jnp.sum(dyg * xhat, axis=-1, keepdims=True) / feat
    dx_ref[...] = inv * (dyg - m1 - xhat * m2)


@jax.custom_vjp
def layernorm(x, gamma, beta):
    """Differentiable fused LayerNorm over the last axis."""
    from .fused_layernorm import fused_layernorm
    return fused_layernorm(x, gamma, beta)


def _ln_fwd(x, gamma, beta):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    xhat = (x - mu) * inv
    y = xhat * gamma + beta
    return y, (xhat, inv, gamma)


def _ln_bwd(res, dy):
    xhat, inv, gamma = res
    shape = xhat.shape
    feat = shape[-1]
    rows = xhat.size // feat
    dyg = (dy * gamma).reshape(rows, feat)
    xhat2 = xhat.reshape(rows, feat)
    inv2 = jnp.broadcast_to(inv, shape).reshape(rows, feat)

    if rows % DEFAULT_BLOCK_ROWS_LN != 0:
        dx = pl.pallas_call(
            _dln_dx_kernel,
            out_shape=jax.ShapeDtypeStruct((rows, feat), xhat.dtype),
            interpret=True,
        )(xhat2, dyg, inv2)
    else:
        br = DEFAULT_BLOCK_ROWS_LN
        dx = pl.pallas_call(
            _dln_dx_kernel,
            grid=(rows // br,),
            in_specs=[
                pl.BlockSpec((br, feat), lambda i: (i, 0)),
                pl.BlockSpec((br, feat), lambda i: (i, 0)),
                pl.BlockSpec((br, feat), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((br, feat), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, feat), xhat.dtype),
            interpret=True,
        )(xhat2, dyg, inv2)

    axes = tuple(range(len(shape) - 1))
    dgamma = jnp.sum(dy * xhat, axis=axes)
    dbeta = jnp.sum(dy, axis=axes)
    return dx.reshape(shape), dgamma, dbeta


DEFAULT_BLOCK_ROWS_LN = DEFAULT_BLOCK_ROWS
layernorm.defvjp(_ln_fwd, _ln_bwd)


# ----------------------------------------------------------- Attention --

@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def attention(q, k, v, mask, scale):
    """Differentiable fused attention (forward Pallas, backward einsum)."""
    return _fused_attention_fwd(q, k, v, mask, scale)


def _attn_fwd(q, k, v, mask, scale):
    out = _fused_attention_fwd(q, k, v, mask, scale)
    return out, (q, k, v, mask)


def _attn_bwd(scale, res, dout):
    q, k, v, mask = res
    # Recompute probabilities (cheaper than saving the S x S matrix).
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale + mask
    probs = ref.softmax(scores, axis=-1)
    dv = jnp.einsum("bhst,bhsd->bhtd", probs, dout)
    dprobs = jnp.einsum("bhsd,bhtd->bhst", dout, v)
    # softmax backward: dscores = probs * (dprobs - sum(dprobs*probs))
    tmp = jnp.sum(dprobs * probs, axis=-1, keepdims=True)
    dscores = probs * (dprobs - tmp)
    dq = jnp.einsum("bhst,bhtd->bhsd", dscores, k) * scale
    dk = jnp.einsum("bhst,bhsd->bhtd", dscores, q) * scale
    dmask = jnp.sum(dscores, axis=(1, 2), keepdims=True)
    return dq, dk, dv, dmask


attention.defvjp(_attn_fwd, _attn_bwd)
