"""Fused GELU as a Pallas kernel (paper §4.3, Kernel Fusion).

The paper's unfused GELU costs 7 CUDA kernel launches and 7 round trips to
HBM.  The fused version is one kernel: each tile is read from HBM into
VMEM once, the whole elementwise chain runs in registers/VMEM, and the
result is written back once.

TPU adaptation (DESIGN.md §3): the CUDA threadblock tiling becomes a
BlockSpec over the flattened row dimension; the lane dimension stays the
feature axis so the VPU operates on (8, 128)-aligned vregs.  VMEM
footprint per program instance = 2 * block_rows * feat * 4 bytes
(in + out tile), kept well under the ~16 MiB VMEM budget.

Lowered with ``interpret=True`` so the CPU PJRT plugin can execute the
resulting HLO (real-TPU lowering emits a Mosaic custom-call).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import GELU_A, GELU_B, GELU_C

# Rows per program instance. 256 rows x 1024 feats x 4 B x 2 tiles = 2 MiB
# VMEM — comfortable double-buffering headroom on a 16 MiB core.
DEFAULT_BLOCK_ROWS = 256


def _gelu_kernel(x_ref, o_ref):
    """One fused pass: the paper's 7 ops over a single VMEM-resident tile."""
    x = x_ref[...]
    inner = GELU_B * (x + GELU_C * x * x * x)
    o_ref[...] = GELU_A * x * (1.0 + jnp.tanh(inner))


@functools.partial(jax.jit, static_argnames=("block_rows",))
def fused_gelu(x, block_rows=DEFAULT_BLOCK_ROWS):
    """Fused GELU over an array of shape [..., feat].

    The leading dims are flattened into a row axis and tiled by
    ``block_rows``; the feature axis is kept whole (it is the vreg lane
    axis).  Shapes that do not divide evenly fall back to a single-block
    call (grid handles the padding internally via interpret mode).
    """
    orig_shape = x.shape
    feat = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, feat)

    if rows % block_rows != 0:
        # Fallback: single program instance over the whole array.  Still a
        # single fused pass; only the HBM<->VMEM schedule degenerates.
        out = pl.pallas_call(
            _gelu_kernel,
            out_shape=jax.ShapeDtypeStruct((rows, feat), x.dtype),
            interpret=True,
        )(x2)
        return out.reshape(orig_shape)

    grid = (rows // block_rows,)
    out = pl.pallas_call(
        _gelu_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, feat), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, feat), x.dtype),
        interpret=True,
    )(x2)
    return out.reshape(orig_shape)


def vmem_bytes(block_rows, feat, dtype_bytes=4):
    """VMEM footprint estimate for one program instance (in + out tile)."""
    return 2 * block_rows * feat * dtype_bytes
