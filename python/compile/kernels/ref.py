"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every kernel in this package must match its oracle here to within float
tolerance; pytest (python/tests/test_kernels.py) enforces this with
hypothesis sweeps over shapes and dtypes.

These are also the *unfused* baselines: ``gelu_unfused`` deliberately
mirrors the paper's 7-kernel GELU decomposition (§4.3) so the fused-vs-
unfused HLO op-count comparison in the Table 4/5 benchmark is faithful.
"""

import jax.numpy as jnp
import numpy as np

# GELU tanh-approximation constants from the paper (§4.3):
#   GELU(x) = a*x*(1 + tanh(b*(x + c*x^3)))
GELU_A = 0.5
GELU_B = float(np.sqrt(2.0 / np.pi))
GELU_C = 0.044715


def gelu(x):
    """Reference fused GELU (tanh approximation, paper eq. in §4.3)."""
    return GELU_A * x * (1.0 + jnp.tanh(GELU_B * (x + GELU_C * x * x * x)))


def gelu_unfused(x):
    """The paper's 7-step op-by-op GELU decomposition (§4.3 listing).

    Each statement corresponds to one of the 7 CUDA kernels the paper
    counts for the unfused implementation.  Kept as 7 separate ops so the
    lowered HLO reflects the unfused structure.
    """
    f = x * x * x              # 1. f = x^3
    f = GELU_C * f             # 2. f = c * f
    f = x + f                  # 3. f = x + f
    f = GELU_B * f             # 4. f = b * f
    f = jnp.tanh(f) + 1.0      # 5. f = tanh(f) + 1
    f = x * f                  # 6. f = x * f
    f = GELU_A * f             # 7. f = a * f
    return f


def layernorm(x, gamma, beta, eps=1e-12):
    """Reference LayerNorm over the last axis (Ba et al., paper §4.3)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    inv = 1.0 / jnp.sqrt(var + eps)
    return (x - mu) * inv * gamma + beta


def layernorm_unfused(x, gamma, beta, eps=1e-12):
    """Op-by-op LayerNorm: separate mean / var / normalize / affine passes."""
    mu = jnp.sum(x, axis=-1, keepdims=True) / x.shape[-1]
    d = x - mu
    var = jnp.sum(d * d, axis=-1, keepdims=True) / x.shape[-1]
    std = jnp.sqrt(var + eps)
    n = d / std
    return n * gamma + beta


def softmax(x, axis=-1):
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention(q, k, v, mask, scale):
    """Reference scaled-dot-product attention with additive mask.

    q,k,v: [B, H, S, D]; mask: [B, 1, 1, S] additive (0 or -1e9-ish).
    """
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale + mask
    probs = softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v)


def lamb_update(p, g, m, v, step, lr, beta1=0.9, beta2=0.999, eps=1e-6,
                weight_decay=0.01):
    """Reference LAMB (You et al. 2019) update for a single tensor.

    Returns (p_new, m_new, v_new).  Trust ratio is computed over the whole
    tensor (the "layer" granularity of layer-wise adaptation).
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1 ** step)
    v_hat = v_new / (1.0 - beta2 ** step)
    update = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p
    w_norm = jnp.sqrt(jnp.sum(p * p))
    u_norm = jnp.sqrt(jnp.sum(update * update))
    trust = jnp.where(w_norm > 0.0, jnp.where(u_norm > 0.0, w_norm / u_norm, 1.0), 1.0)
    p_new = p - lr * trust * update
    return p_new, m_new, v_new


def adam_update(p, g, m, v, step, lr, beta1=0.9, beta2=0.999, eps=1e-8,
                weight_decay=0.01):
    """Reference AdamW update for a single tensor."""
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1 ** step)
    v_hat = v_new / (1.0 - beta2 ** step)
    p_new = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p)
    return p_new, m_new, v_new
