"""L2 correctness: model shapes, losses, gradients, variant equivalence."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

CFG = M.PRESETS["bert-micro"]


def make_batch(cfg, b, s, seed=0):
    rng = np.random.RandomState(seed)
    ids = jnp.asarray(rng.randint(4, cfg.vocab_size, (b, s)), jnp.int32)
    tt = jnp.asarray(rng.randint(0, 2, (b, s)), jnp.int32)
    am = jnp.ones((b, s), jnp.int32)
    mask = rng.rand(b, s) < 0.15
    ml = jnp.asarray(np.where(mask, np.asarray(ids), M.IGNORE_INDEX), jnp.int32)
    nsp = jnp.asarray(rng.randint(0, 2, (b,)), jnp.int32)
    return ids, tt, am, ml, nsp


def test_param_count_matches_layout():
    flat = M.init_params(CFG, 0)
    assert flat.shape == (M.param_count(CFG),)
    total = sum(int(np.prod(s)) for _, s in M.param_layout(CFG))
    assert total == flat.size


def test_param_counts_match_published_models():
    """bert-base ~110M and bert-large ~340M (paper §1)."""
    base = M.param_count(M.PRESETS["bert-base"])
    large = M.param_count(M.PRESETS["bert-large"])
    assert 105e6 < base < 115e6
    assert 330e6 < large < 345e6


def test_unflatten_roundtrip():
    flat = jnp.asarray(M.init_params(CFG, 1))
    p = M.unflatten(flat, CFG)
    rebuilt = jnp.concatenate([p[n].ravel() for n, _ in M.param_layout(CFG)])
    np.testing.assert_array_equal(rebuilt, flat)


def test_forward_shapes_and_initial_loss():
    flat = jnp.asarray(M.init_params(CFG, 0))
    batch = make_batch(CFG, 2, 32)
    loss, (mlm, nsp, acc) = M.pretrain_loss(flat, *batch, CFG)
    # random init: mlm ~= ln(V), nsp ~= ln(2)
    assert abs(float(mlm) - np.log(CFG.vocab_size)) < 1.0
    assert abs(float(nsp) - np.log(2)) < 0.3
    assert 0.0 <= float(acc) <= 1.0
    assert float(loss) == pytest.approx(float(mlm) + float(nsp), rel=1e-5)


def test_mlm_ignore_index_excluded_from_loss():
    """All-ignored labels must produce zero MLM loss, not NaN."""
    flat = jnp.asarray(M.init_params(CFG, 0))
    ids, tt, am, _, nsp = make_batch(CFG, 2, 32)
    ml = jnp.full_like(ids, M.IGNORE_INDEX)
    loss, (mlm, _, _) = M.pretrain_loss(flat, ids, tt, am, ml, nsp, CFG)
    assert float(mlm) == 0.0
    assert np.isfinite(float(loss))


def test_train_step_gradient_matches_finite_difference():
    """Directional finite-difference check of the full fwd+bwd."""
    flat = jnp.asarray(M.init_params(CFG, 0))
    batch = make_batch(CFG, 1, 16)
    fn, _ = M.make_train_step(CFG, 1, 16)
    out = fn(flat, *batch, jnp.float32(1.0))
    grads = np.asarray(out[4])
    rng = np.random.RandomState(0)
    d = rng.randn(flat.size).astype(np.float32)
    d /= np.linalg.norm(d)
    eps = 1e-2
    lp = M.pretrain_loss(flat + eps * d, *batch, CFG)[0]
    lm = M.pretrain_loss(flat - eps * d, *batch, CFG)[0]
    fd = (float(lp) - float(lm)) / (2 * eps)
    an = float(np.dot(grads, d))
    assert abs(fd - an) < 3e-2 * max(1.0, abs(fd)), (fd, an)


def test_loss_scaling_invariance():
    """Grads must be identical (to fp error) for any loss scale (§4.2)."""
    flat = jnp.asarray(M.init_params(CFG, 0))
    batch = make_batch(CFG, 2, 16)
    fn, _ = M.make_train_step(CFG, 2, 16)
    g1 = np.asarray(fn(flat, *batch, jnp.float32(1.0))[4])
    g2 = np.asarray(fn(flat, *batch, jnp.float32(1024.0))[4])
    np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-3)


def test_fused_and_unfused_agree():
    """Paper Fig. 8 claim: optimizations do not change the function."""
    flat = jnp.asarray(M.init_params(CFG, 0))
    batch = make_batch(CFG, 2, 16)
    cfg_f = dataclasses.replace(CFG, fused=True, dtype="f32")
    cfg_u = dataclasses.replace(CFG, fused=False, dtype="f32")
    lf, (mf, nf, _) = M.pretrain_loss(flat, *batch, cfg_f)
    lu, (mu, nu, _) = M.pretrain_loss(flat, *batch, cfg_u)
    assert float(lf) == pytest.approx(float(lu), rel=1e-4)
    assert float(mf) == pytest.approx(float(mu), rel=1e-4)


def test_bf16_close_to_f32():
    """AMP compute path stays within half-precision error of f32."""
    flat = jnp.asarray(M.init_params(CFG, 0))
    batch = make_batch(CFG, 2, 16)
    cfg32 = dataclasses.replace(CFG, fused=False, dtype="f32")
    cfg16 = dataclasses.replace(CFG, fused=False, dtype="bf16")
    l32 = float(M.pretrain_loss(flat, *batch, cfg32)[0])
    l16 = float(M.pretrain_loss(flat, *batch, cfg16)[0])
    assert abs(l32 - l16) / abs(l32) < 0.05


def test_padding_mask_blocks_contributions():
    """Changing tokens under pad positions must not change the loss."""
    flat = jnp.asarray(M.init_params(CFG, 0))
    ids, tt, am, ml, nsp = make_batch(CFG, 1, 16)
    am = am.at[0, 8:].set(0)
    ml = ml.at[0, 8:].set(M.IGNORE_INDEX)
    l1 = float(M.pretrain_loss(flat, ids, tt, am, ml, nsp, CFG)[0])
    ids2 = ids.at[0, 12].set(7)
    l2 = float(M.pretrain_loss(flat, ids2, tt, am, ml, nsp, CFG)[0])
    # pad tokens still enter embeddings; assert effect is tiny vs a real edit
    ids3 = ids.at[0, 2].set(7)
    l3 = float(M.pretrain_loss(flat, ids3, tt, am, ml, nsp, CFG)[0])
    assert abs(l2 - l1) < abs(l3 - l1) + 1e-6 or abs(l2 - l1) < 1e-4


def test_apply_lamb_moves_params_and_is_finite():
    flat = jnp.asarray(M.init_params(CFG, 0))
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(flat.size).astype(np.float32)) * 0.01
    z = jnp.zeros_like(flat)
    fn, _ = M.make_apply(CFG, "lamb")
    p2, m2, v2 = fn(flat, g, z, z, jnp.float32(1.0), jnp.float32(1e-3))
    assert np.all(np.isfinite(np.asarray(p2)))
    assert float(jnp.linalg.norm(p2 - flat)) > 0


def test_apply_adam_differs_from_lamb():
    flat = jnp.asarray(M.init_params(CFG, 0))
    rng = np.random.RandomState(1)
    g = jnp.asarray(rng.randn(flat.size).astype(np.float32)) * 0.01
    z = jnp.zeros_like(flat)
    lamb, _ = M.make_apply(CFG, "lamb")
    adam, _ = M.make_apply(CFG, "adam")
    pl_, _, _ = lamb(flat, g, z, z, jnp.float32(1.0), jnp.float32(1e-3))
    pa, _, _ = adam(flat, g, z, z, jnp.float32(1.0), jnp.float32(1e-3))
    assert float(jnp.linalg.norm(pl_ - pa)) > 0


def test_short_training_reduces_loss():
    """5 LAMB steps on one repeated batch must reduce the loss."""
    flat = jnp.asarray(M.init_params(CFG, 0))
    batch = make_batch(CFG, 2, 16, seed=3)
    step_fn, _ = M.make_train_step(CFG, 2, 16)
    apply_fn, _ = M.make_apply(CFG, "lamb")
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    losses = []
    for i in range(5):
        out = step_fn(flat, *batch, jnp.float32(1.0))
        losses.append(float(out[0]))
        flat, m, v = apply_fn(flat, out[4], m, v, jnp.float32(i + 1),
                              jnp.float32(5e-3))
    assert losses[-1] < losses[0], losses
