"""QA fine-tuning head (paper §5.3 mechanism): loss, grads, layout."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

CFG = M.PRESETS["bert-micro"]


def qa_batch(cfg, b, s, seed=0):
    rng = np.random.RandomState(seed)
    ids = jnp.asarray(rng.randint(5, cfg.vocab_size, (b, s)), jnp.int32)
    tt = jnp.zeros((b, s), jnp.int32)
    am = jnp.ones((b, s), jnp.int32)
    start = jnp.asarray(rng.randint(0, s // 2, (b,)), jnp.int32)
    end = start + jnp.asarray(rng.randint(0, 3, (b,)), jnp.int32)
    return ids, tt, am, start, end


def ft_params(cfg, seed=0):
    rng = np.random.RandomState(seed)
    pre = M.init_params(cfg, seed)
    head = rng.normal(0, 0.02, cfg.hidden * 2 + 2).astype(np.float32)
    return jnp.asarray(np.concatenate([pre, head]))


def test_finetune_layout_extends_pretraining():
    base = M.param_count(CFG)
    ft = M.finetune_param_count(CFG)
    assert ft == base + CFG.hidden * 2 + 2
    names = [n for n, _ in M.finetune_layout(CFG)]
    assert names[-2:] == ["qa.weight", "qa.bias"]


def test_qa_loss_starts_at_uniform():
    """Random init: span CE ~ ln(seq) per side."""
    flat = ft_params(CFG)
    batch = qa_batch(CFG, 2, 32)
    loss, (sa, ea, ex) = M.qa_loss(flat, *batch, CFG)
    assert abs(float(loss) - np.log(32)) < 0.8, float(loss)
    assert 0.0 <= float(ex) <= 1.0
    assert 0.0 <= float(sa) <= 1.0 and 0.0 <= float(ea) <= 1.0


def test_qa_train_step_outputs_and_grad_shape():
    fn, specs = M.make_qa_train_step(CFG, 2, 32)
    flat = ft_params(CFG)
    batch = qa_batch(CFG, 2, 32)
    out = fn(flat, *batch, jnp.float32(1.0))
    assert len(out) == 6
    grads = out[4]
    assert grads.shape == (M.finetune_param_count(CFG),)
    assert np.all(np.isfinite(np.asarray(grads)))
    # the head's gradient must be nonzero (it is on the path)
    head_g = np.asarray(grads[-(CFG.hidden * 2 + 2):])
    assert np.abs(head_g).max() > 0


def test_qa_loss_scaling_invariance():
    fn, _ = M.make_qa_train_step(CFG, 2, 32)
    flat = ft_params(CFG)
    batch = qa_batch(CFG, 2, 32)
    g1 = np.asarray(fn(flat, *batch, jnp.float32(1.0))[4])
    g2 = np.asarray(fn(flat, *batch, jnp.float32(512.0))[4])
    np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-3)


def test_qa_finetuning_learns_fixed_batch():
    fn, _ = M.make_qa_train_step(CFG, 2, 32)
    apply_fn, _ = M.make_qa_apply(CFG)
    flat = ft_params(CFG)
    batch = qa_batch(CFG, 2, 32, seed=3)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    losses = []
    for i in range(6):
        out = fn(flat, *batch, jnp.float32(1.0))
        losses.append(float(out[0]))
        flat, m, v = apply_fn(flat, out[4], m, v, jnp.float32(i + 1),
                              jnp.float32(3e-3))
    assert losses[-1] < losses[0], losses


def test_padding_positions_never_win_argmax():
    """Masked (pad) positions get -1e9 logits, so predicted spans always
    land inside the attended region."""
    flat = ft_params(CFG)
    b, s = 2, 32
    ids, tt, _, start, end = qa_batch(CFG, b, s)
    am = jnp.ones((b, s), jnp.int32).at[:, 20:].set(0)
    n_pre = M.param_count(CFG)
    pre = M.unflatten(flat[:n_pre], CFG)
    hidden = M.encoder_forward(pre, ids, tt, am, CFG)
    head = flat[n_pre:]
    w = head[: CFG.hidden * 2].reshape(CFG.hidden, 2)
    bia = head[CFG.hidden * 2:]
    logits = jnp.dot(hidden, w) + bia
    neg = (1.0 - am.astype(jnp.float32)) * -1e9
    s_pred = jnp.argmax(logits[..., 0] + neg, -1)
    assert np.all(np.asarray(s_pred) < 20)
