"""AOT contract tests: HLO text + manifest invariants the Rust side relies on."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    meta = aot.build(out, "bert-micro", batch=2, seq=32,
                     variants=["fused_f32"], optimizers=["lamb"],
                     phase2=False)
    return out, meta


def test_hlo_is_text_and_parseable_header(built):
    out, meta = built
    for art in meta["artifacts"].values():
        path = os.path.join(out, art["file"])
        with open(path) as f:
            head = f.read(200)
        # HLO text modules start with "HloModule"
        assert head.lstrip().startswith("HloModule"), art["file"]


def test_layout_offsets_are_dense_and_ordered(built):
    _, meta = built
    off = 0
    for entry in meta["layout"]:
        assert entry["offset"] == off
        off += int(np.prod(entry["shape"]))
    assert off == meta["param_count"]


def test_train_artifact_input_arity(built):
    _, meta = built
    art = meta["artifacts"]["train_fused_f32_b2_s32"]
    # params + 5 batch tensors + loss_scale
    assert len(art["inputs"]) == 7
    assert art["inputs"][0]["shape"] == [meta["param_count"]]
    assert art["inputs"][1]["dtype"] == "int32"
    assert art["outputs"][-2:] == ["grads_flat", "grad_norm"]


def test_apply_artifact_input_arity(built):
    _, meta = built
    art = meta["artifacts"]["apply_lamb"]
    assert len(art["inputs"]) == 6
    n = meta["param_count"]
    assert all(i["shape"] == [n] for i in art["inputs"][:4])
    assert art["outputs"] == ["params", "m", "v"]


def test_manifest_json_round_trips(built, tmp_path):
    _, meta = built
    path = tmp_path / "m.json"
    path.write_text(json.dumps({"models": {"bert-micro": meta}}))
    loaded = json.loads(path.read_text())
    assert loaded["models"]["bert-micro"]["param_count"] == meta["param_count"]


def test_variant_catalog_covers_paper_axes():
    """Table 4/5 axes: non-optimized, fp16-analogue, fused, fused+fp16."""
    assert set(aot.VARIANTS) == {"unfused_f32", "bf16", "fused_f32",
                                 "fused_bf16"}
    v = aot.VARIANTS
    assert not v["unfused_f32"]["fused"] and v["unfused_f32"]["dtype"] == "f32"
    assert v["fused_bf16"]["fused"] and v["fused_bf16"]["dtype"] == "bf16"


def test_fused_hlo_has_fewer_elementwise_ops():
    """Kernel fusion (§4.3) must show up structurally in the lowered HLO:
    the fused GELU keeps the 7-op chain inside one fusion-friendly region
    and avoids materializing 7 intermediates at module scope."""
    import jax
    import jax.numpy as jnp
    from compile.kernels import ref
    from compile.kernels.fused_gelu import fused_gelu

    x = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    fused_txt = aot.to_hlo_text(jax.jit(fused_gelu).lower(x))
    unfused_txt = aot.to_hlo_text(jax.jit(ref.gelu_unfused).lower(x))
    # Both compute tanh exactly once
    assert fused_txt.count("tanh") >= 1
    assert unfused_txt.count("tanh") >= 1
