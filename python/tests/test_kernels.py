"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the block-divisibility boundary) so both the
gridded fast path and the single-block fallback of each kernel are hit.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import autodiff as ad
from compile.kernels.fused_gelu import fused_gelu, vmem_bytes as gelu_vmem
from compile.kernels.fused_layernorm import fused_layernorm, \
    vmem_bytes as ln_vmem
from compile.kernels.fused_lamb import fused_lamb, DEFAULT_BLOCK
from compile.kernels.attention import fused_attention, vmem_bytes as at_vmem, \
    mxu_utilization_estimate

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# ------------------------------------------------------------------ GELU

@settings(**SETTINGS)
@given(rows=st.integers(1, 300), feat=st.sampled_from([8, 64, 128, 256]))
def test_gelu_matches_ref(rows, feat):
    rng = np.random.default_rng(rows * 1000 + feat)
    x = rand(rng, rows, feat)
    np.testing.assert_allclose(fused_gelu(x), ref.gelu(x), atol=1e-5)


@settings(**SETTINGS)
@given(rows=st.sampled_from([1, 7, 256, 512]), feat=st.sampled_from([16, 128]))
def test_gelu_grad_matches_ref(rows, feat):
    rng = np.random.default_rng(rows + feat)
    x = rand(rng, rows, feat)
    g = jax.grad(lambda x: jnp.sum(ad.gelu(x) ** 2))(x)
    g_ref = jax.grad(lambda x: jnp.sum(ref.gelu(x) ** 2))(x)
    np.testing.assert_allclose(g, g_ref, atol=1e-4, rtol=1e-4)


def test_gelu_matches_unfused_decomposition():
    """The paper's 7-op decomposition computes the same function."""
    x = jnp.linspace(-4, 4, 97, dtype=jnp.float32).reshape(1, 97)
    np.testing.assert_allclose(ref.gelu_unfused(x), ref.gelu(x), atol=1e-6)
    np.testing.assert_allclose(fused_gelu(x), ref.gelu_unfused(x), atol=1e-5)


def test_gelu_3d_input():
    rng = np.random.default_rng(0)
    x = rand(rng, 2, 5, 32)
    np.testing.assert_allclose(fused_gelu(x), ref.gelu(x), atol=1e-5)


def test_gelu_vmem_budget():
    # default tile must fit VMEM (~16 MiB) with double-buffer headroom
    assert gelu_vmem(256, 4096) <= 16 * 2 ** 20 / 2


# ------------------------------------------------------------- LayerNorm

@settings(**SETTINGS)
@given(rows=st.integers(1, 300), feat=st.sampled_from([8, 64, 256]))
def test_layernorm_matches_ref(rows, feat):
    rng = np.random.default_rng(rows * 7 + feat)
    x = rand(rng, rows, feat)
    g = rand(rng, feat)
    b = rand(rng, feat)
    np.testing.assert_allclose(fused_layernorm(x, g, b),
                               ref.layernorm(x, g, b), atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(rows=st.sampled_from([3, 256]), feat=st.sampled_from([16, 64]))
def test_layernorm_grads_match_ref(rows, feat):
    rng = np.random.default_rng(rows + feat)
    x, g, b = rand(rng, rows, feat), rand(rng, feat), rand(rng, feat)

    def f(fn):
        return jax.grad(lambda x, g, b: jnp.sum(fn(x, g, b) ** 2),
                        argnums=(0, 1, 2))(x, g, b)

    for got, want in zip(f(ad.layernorm), f(ref.layernorm)):
        np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


def test_layernorm_rows_are_normalized():
    rng = np.random.default_rng(1)
    x = rand(rng, 10, 128) * 5 + 3
    y = fused_layernorm(x, jnp.ones(128), jnp.zeros(128))
    np.testing.assert_allclose(np.mean(y, -1), 0, atol=1e-4)
    np.testing.assert_allclose(np.std(y, -1), 1, atol=1e-3)


def test_layernorm_unfused_matches_fused():
    rng = np.random.default_rng(2)
    x, g, b = rand(rng, 17, 32), rand(rng, 32), rand(rng, 32)
    np.testing.assert_allclose(ref.layernorm_unfused(x, g, b),
                               fused_layernorm(x, g, b), atol=1e-4)


def test_layernorm_vmem_budget():
    assert ln_vmem(256, 4096) < 16 * 2 ** 20 * 0.6


# ------------------------------------------------------------- Attention

@settings(**SETTINGS)
@given(b=st.integers(1, 3), h=st.integers(1, 4),
       s=st.sampled_from([4, 16, 64]), d=st.sampled_from([8, 32]))
def test_attention_matches_ref(b, h, s, d):
    rng = np.random.default_rng(b * 100 + h * 10 + s + d)
    q, k, v = rand(rng, b, h, s, d), rand(rng, b, h, s, d), rand(rng, b, h, s, d)
    mask = jnp.zeros((b, 1, 1, s), jnp.float32)
    scale = 1.0 / np.sqrt(d)
    np.testing.assert_allclose(fused_attention(q, k, v, mask, scale),
                               ref.attention(q, k, v, mask, scale),
                               atol=1e-4, rtol=1e-4)


def test_attention_respects_padding_mask():
    """Masked key positions must receive ~zero attention weight."""
    rng = np.random.default_rng(3)
    s = 8
    q = rand(rng, 1, 1, s, 4)
    k = rand(rng, 1, 1, s, 4)
    v = jnp.zeros((1, 1, s, 4), jnp.float32).at[:, :, s - 1, :].set(1e3)
    mask = jnp.zeros((1, 1, 1, s)).at[..., s - 1].set(-1e9)
    out = fused_attention(q, k, v, mask, 0.5)
    assert float(jnp.max(jnp.abs(out))) < 1e-3  # last key contributed ~0


def test_attention_grad_matches_ref():
    rng = np.random.default_rng(4)
    q = rand(rng, 2, 2, 8, 4)
    mask = jnp.zeros((2, 1, 1, 8))
    g = jax.grad(lambda q: jnp.sum(ad.attention(q, q, q, mask, 0.5) ** 2))(q)
    g_ref = jax.grad(lambda q: jnp.sum(ref.attention(q, q, q, mask, 0.5) ** 2))(q)
    np.testing.assert_allclose(g, g_ref, atol=1e-3, rtol=1e-3)


def test_attention_vmem_and_mxu_estimates():
    # phase-2 shape S=512, D=64: must fit VMEM
    assert at_vmem(512, 64) < 16 * 2 ** 20 / 2
    assert mxu_utilization_estimate(512, 128) == 1.0
    assert 0 < mxu_utilization_estimate(512, 64) <= 0.5  # D=64 half-fills K


# ------------------------------------------------------------------ LAMB

@settings(**SETTINGS)
@given(n=st.sampled_from([8, 1000, DEFAULT_BLOCK, 2 * DEFAULT_BLOCK]),
       step=st.integers(1, 100))
def test_lamb_matches_ref(n, step):
    rng = np.random.default_rng(n + step)
    p, g = rand(rng, n), rand(rng, n) * 0.1
    m, v = rand(rng, n) * 0.01, jnp.abs(rand(rng, n)) * 0.01
    lr = jnp.float32(1e-3)
    got = fused_lamb(p, g, m, v, jnp.float32(step), lr)
    want = ref.lamb_update(p, g, m, v, jnp.float32(step), lr)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)


def test_lamb_zero_gradient_still_decays():
    """With g=0 LAMB still applies weight decay through the update dir."""
    n = 16
    p = jnp.ones(n)
    z = jnp.zeros(n)
    p2, m2, v2 = fused_lamb(p, z, z, z, jnp.float32(1.0), jnp.float32(0.1))
    assert float(jnp.max(p2)) < 1.0  # decay shrank the weights
    np.testing.assert_allclose(m2, 0.0, atol=0)


def test_lamb_trust_ratio_scales_update():
    """Doubling the weights (same grads) scales the step via trust ratio."""
    rng = np.random.default_rng(5)
    n = 64
    g = rand(rng, n)
    z = jnp.zeros(n)
    p1 = jnp.ones(n)
    lr = jnp.float32(0.01)
    a1, _, _ = fused_lamb(p1, g, z, z, jnp.float32(1.0), lr)
    a2, _, _ = fused_lamb(2 * p1, g, z, z, jnp.float32(1.0), lr)
    d1 = float(jnp.linalg.norm(a1 - p1))
    d2 = float(jnp.linalg.norm(a2 - 2 * p1))
    assert d2 > 1.5 * d1  # larger weight norm -> larger trusted step
