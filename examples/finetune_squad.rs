//! Fine-tuning study (paper §3.1.2 / §5.3): fine-tune a QA span head on
//! the SQuAD-mechanism task, starting from (a) a pretrained checkpoint
//! and (b) random init — the §5.3 signal is that pretraining transfers:
//! the pretrained start converges faster/lower.
//!
//! (The real SQuAD v1.1 + full-scale checkpoints are not available
//! offline; DESIGN.md §2 documents the substitution.  The paper's F1
//! numbers are therefore NOT comparable — the *mechanism* and the
//! pretrained-vs-scratch ordering are what this reproduces.)
//!
//! Run: cargo run --release --example finetune_squad -- \
//!        [--steps 60] [--ckpt runs/e2e/model.ckpt]

use bertdist::checkpoint::Checkpoint;
use bertdist::cliopt::Args;
use bertdist::finetune::run_finetune;
use bertdist::runtime::Engine;
use bertdist::trainer::init_params;
use bertdist::util::ascii_plot::{plot_series, Series};
use bertdist::util::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let steps = args.get_parse("steps", 60usize)?;
    let ckpt = args.get_opt("ckpt");
    let preset = args.get("preset", "bert-micro");
    args.finish_strict()?;

    let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
    let model = engine.model(&preset)?;
    let (batch, seq) = if preset == "bert-micro" { (2, 32) } else { (8, 128) };

    // starting points: pretrained (from checkpoint or a quick MLM
    // warm start is not available -> random) vs scratch
    let mut rng = Pcg64::new(1);
    let scratch = init_params(&model.layout, &mut rng);
    let pretrained = match &ckpt {
        Some(path) => {
            let c = Checkpoint::load(std::path::Path::new(path))?;
            anyhow::ensure!(c.params.len() == model.param_count,
                            "checkpoint is for a different preset");
            println!("loaded pretrained checkpoint {path} (step {})",
                     c.step);
            Some(c.params)
        }
        None => {
            println!("no --ckpt given: comparing two random inits \
                      (mechanism demo only)");
            None
        }
    };

    println!("fine-tuning {preset} on the SQuAD-mechanism span task, \
              {steps} steps, batch {batch}x{seq}\n");

    let rep_scratch =
        run_finetune(&engine, &preset, &scratch, steps, batch, seq, 5e-4,
                     7)?;
    println!("scratch   : loss {:.4} -> {:.4}, exact-match {:.1}%",
             rep_scratch.loss.points[0].1, rep_scratch.loss.tail_mean(5),
             rep_scratch.final_exact * 100.0);

    let rep_pre = if let Some(p) = pretrained {
        let r = run_finetune(&engine, &preset, &p, steps, batch, seq, 5e-4,
                             7)?;
        println!("pretrained: loss {:.4} -> {:.4}, exact-match {:.1}%",
                 r.loss.points[0].1, r.loss.tail_mean(5),
                 r.final_exact * 100.0);
        Some(r)
    } else {
        None
    };

    let s_xy = rep_scratch.loss.xy();
    let mut series = vec![Series { name: "scratch", points: &s_xy,
                                   marker: 's' }];
    let p_xy = rep_pre.as_ref().map(|r| r.loss.xy());
    if let Some(ref p) = p_xy {
        series.push(Series { name: "pretrained", points: p, marker: 'p' });
    }
    println!("\n{}", plot_series("QA fine-tuning loss (§5.3 mechanism)",
                                 &series, 70, 14));

    // the task must be learnable at all
    assert!(rep_scratch.loss.tail_mean(5)
            < rep_scratch.loss.points[0].1,
            "fine-tuning made no progress");
    println!("fine-tuning mechanism OK (paper reports 81-83% F1 on real \
              SQuAD vs Google's 90.9% — a hyperparameter gap, §5.3)");
    Ok(())
}
