//! End-to-end pretraining driver (the DESIGN.md §7 headline example).
//!
//! Reproduces the paper's full §3.3 two-phase schedule on the testbed
//! scale: builds a synthetic corpus, shards it per device (§4.1), then
//! pretrains a BERT model with data parallelism, ring allreduce,
//! gradient accumulation (§4.4) and AMP loss scaling (§4.2) —
//! phase 1 at seq 128, phase 2 at seq 512 with Table-6 ratios —
//! and writes the Figure-7 loss curves to CSV.
//!
//! Run:  cargo run --release --example pretrain_e2e -- \
//!         [--preset bert-tiny] [--steps 200] [--phase2-steps 40]
//!         [--topo 1M2G] [--accum 4] [--docs 256] [--out runs/e2e]
//!
//! The run recorded in EXPERIMENTS.md used the defaults.

use bertdist::cliopt::Args;
use bertdist::config::{RunConfig, TwoPhaseSchedule};
use bertdist::coordinator::train_run;
use bertdist::data::corpus::SyntheticCorpus;
use bertdist::data::{build_shards, Vocab};
use bertdist::runtime::Engine;
use bertdist::topology::Topology;
use bertdist::util::ascii_plot::{plot_series, Series};
use bertdist::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let preset = args.get("preset", "bert-tiny");
    let steps = args.get_parse("steps", 200usize)?;
    let phase2_steps = args.get_parse("phase2-steps", 40usize)?;
    let topo = args.get("topo", "1M2G");
    let accum = args.get_parse("accum", 4usize)?;
    let docs_n = args.get_parse("docs", 256usize)?;
    let batch = args.get_parse("batch", 8usize)?;
    let out_dir = std::path::PathBuf::from(args.get("out", "runs/e2e"));
    args.finish_strict()?;

    let mut sw = Stopwatch::new();
    std::fs::create_dir_all(&out_dir)?;

    // ---- data (paper §3.1 + §4.1) ----
    let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
    let model = engine.model(&preset)?;
    let data_dir = out_dir.join("data");
    let world = Topology::parse(&topo).map_err(|e| anyhow::anyhow!(e))?
        .world_size();
    if !data_dir.join("vocab.txt").exists() {
        println!("building corpus + shards under {} ...", data_dir.display());
        let docs = SyntheticCorpus::new(42, 20_000)
            .documents(docs_n, 10, 12);
        let vocab = Vocab::from_documents(&docs, model.config.vocab_size);
        std::fs::create_dir_all(&data_dir)?;
        vocab.save(&data_dir.join("vocab.txt"))?;
        let stats = build_shards(&docs, &vocab, world.max(4), &data_dir,
                                 "train", 42)?;
        println!("  {} examples, {} shards", stats.examples, stats.shards);
    }
    sw.lap("data");

    // ---- two-phase pretraining (paper §3.3, Table 6) ----
    let sched = TwoPhaseSchedule::paper();
    println!(
        "two-phase schedule (paper Table 6 ratios): phase1 seq {} / \
         phase2 seq {}; paper runs {}+{} epochs in {:.1} days on 32M8G",
        sched.phase1.seq_len, sched.phase2.seq_len, sched.phase1.epochs,
        sched.phase2.epochs, sched.paper_total_days()
    );

    let mut cfg = RunConfig::default();
    cfg.train.preset = preset.clone();
    cfg.train.variant = "fused_f32".into();
    cfg.train.optimizer = "lamb".into();
    cfg.train.lr = 2e-4;
    cfg.train.warmup_steps = steps / 10;
    cfg.train.accum_steps = accum;
    cfg.train.log_every = 10;
    cfg.cluster.topo = Topology::parse(&topo).unwrap();

    let ckpt = out_dir.join("model.ckpt");
    let outcome = train_run(&engine, &cfg, &data_dir, steps, phase2_steps,
                            batch, 128, Some(&ckpt))?;
    sw.lap("train");

    // ---- Figure 7 artifact ----
    let p1 = outcome.phase1.loss.xy();
    std::fs::write(out_dir.join("phase1_loss.csv"),
                   outcome.phase1.loss.to_csv())?;
    let mut series = vec![Series { name: "phase1 (seq 128)", points: &p1,
                                   marker: '1' }];
    let p2xy = outcome.phase2.as_ref().map(|r| r.loss.xy());
    if let Some(r2) = &outcome.phase2 {
        std::fs::write(out_dir.join("phase2_loss.csv"), r2.loss.to_csv())?;
    }
    if let Some(ref p2) = p2xy {
        series.push(Series { name: "phase2 (seq 512)", points: p2,
                             marker: '2' });
    }
    println!("{}", plot_series(
        "two-phase pretraining loss (cf. paper Figure 7)", &series, 72, 18));

    let r1 = &outcome.phase1;
    println!("phase 1: {}", r1.summary());
    if let Some(r2) = &outcome.phase2 {
        println!("phase 2: {}", r2.summary());
    }
    println!(
        "loss improved: {} (first-10 mean {:.4} -> last-10 mean {:.4})",
        r1.loss.improved(10),
        r1.loss.points.iter().take(10).map(|p| p.1).sum::<f64>()
            / 10f64.min(r1.loss.points.len() as f64),
        r1.loss.tail_mean(10)
    );
    for (name, dt) in sw.laps() {
        println!("  {name:<6} {dt:.1}s");
    }
    println!("artifacts in {}", out_dir.display());
    Ok(())
}
