//! Quickstart: the whole stack in ~60 lines.
//!
//! Generates a tiny synthetic corpus, shards it (§4.1), then trains
//! bert-micro for 20 data-parallel steps on 2 simulated GPUs with ring
//! allreduce, gradient accumulation and AMP loss scaling.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use bertdist::config::RunConfig;
use bertdist::coordinator::{prepare_datasets, train_run};
use bertdist::data::corpus::SyntheticCorpus;
use bertdist::data::{build_shards, Vocab};
use bertdist::runtime::Engine;
use bertdist::topology::Topology;

fn main() -> anyhow::Result<()> {
    // 1. corpus -> vocab -> shards (one bshard file per simulated GPU)
    let dir = std::env::temp_dir().join("bertdist_quickstart");
    let _ = std::fs::remove_dir_all(&dir);
    let docs = SyntheticCorpus::new(42, 2_000).documents(32, 8, 10);
    let vocab = Vocab::from_documents(&docs, 512); // bert-micro vocab
    std::fs::create_dir_all(&dir)?;
    vocab.save(&dir.join("vocab.txt"))?;
    let stats = build_shards(&docs, &vocab, 2, &dir, "train", 42)?;
    println!("sharded {} examples into {} files", stats.examples,
             stats.shards);

    // 2. engine over the AOT artifacts (built once by `make artifacts`)
    let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
    println!("PJRT platform: {}", engine.platform());

    // 3. a 1-node 2-GPU data-parallel run, accumulation k=2
    let mut cfg = RunConfig::default();
    cfg.train.preset = "bert-micro".into();
    cfg.train.variant = "fused_f32".into();
    cfg.train.lr = 1e-3;
    cfg.train.accum_steps = 2;
    cfg.train.log_every = 5;
    cfg.cluster.topo = Topology::parse("1M2G").unwrap();

    let outcome = train_run(&engine, &cfg, &dir, 20, 0, 2, 32, None)?;
    let r = &outcome.phase1;
    println!("\nquickstart done: {}", r.summary());
    println!("loss {:.4} -> {:.4}",
             r.loss.points.first().map(|p| p.1).unwrap_or(f64::NAN),
             r.loss.tail_mean(3));
    assert!(r.loss.tail_mean(3).is_finite());

    // 4. the datasets really were per-rank shard views
    let ds = prepare_datasets(&dir, 2)?;
    println!("rank 0 sees {} examples, rank 1 sees {}", ds[0].len(),
             ds[1].len());
    Ok(())
}
