//! Weak-scaling study driver (paper Figures 3 and 6).
//!
//! Sweeps topologies with the calibrated cluster model AND cross-checks
//! the small end (1–4 ranks) against REAL threaded ring-allreduce wall
//! time over the actual BERT-large gradient payload.
//!
//! Run: cargo run --release --example weak_scaling -- [--accum 4]
//!        [--grad-mb 128]

use bertdist::cliopt::Args;
use bertdist::collectives::pool::{CollectivePool, MicroStats, RankCompute,
                                  WireFormat};
use bertdist::collectives::CollectiveGroup;
use bertdist::grad::BucketRange;
use bertdist::simulator::scaling::{figure6_topologies, sweep_intra_vs_inter,
                                   weak_scaling};
use bertdist::simulator::IterationModel;
use bertdist::topology::Topology;
use bertdist::util::fmt::render_table;
use bertdist::util::Stopwatch;

/// Constant synthetic gradient so the reduced value is checkable.
struct Ones {
    n: usize,
}

impl RankCompute for Ones {
    fn micro(&self, _rank: usize, _step: usize, _micro: usize, _p: &[f32],
             _scale: f32, out: &mut Vec<f32>) -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        out.fill(1.0);
        Ok(MicroStats::default())
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let accum = args.get_parse("accum", 4usize)?;
    let grad_mb = args.get_parse("grad-mb", 64usize)?;
    args.finish_strict()?;

    // ---- Figure 3: intra vs inter, k=1 ----
    let t1 = IterationModel::paper(Topology::new(1, 1), 1, true);
    let (intra, inter) = sweep_intra_vs_inter(&t1);
    println!("Figure 3 — intra-node vs inter-node weak scaling (k=1):\n");
    let rows: Vec<Vec<String>> = intra
        .iter()
        .zip(&inter)
        .map(|(a, b)| vec![
            a.gpus.to_string(),
            format!("{:.2}x / {:.0}%", a.scaling_factor, a.efficiency * 100.0),
            format!("{:.2}x / {:.0}%", b.scaling_factor, b.efficiency * 100.0),
        ])
        .collect();
    println!("{}", render_table(
        &["GPUs", "intra (PCIe)", "inter (10GbE)"], &rows));

    // ---- Figure 6: multi-node with accumulation ----
    let tk = IterationModel::paper(Topology::new(1, 1), accum, true);
    let pts = weak_scaling(&tk, &figure6_topologies());
    println!("Figure 6 — xM8G weak scaling (k={accum}):\n");
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| vec![
            p.topo.to_string(),
            p.gpus.to_string(),
            format!("{:.1}x", p.scaling_factor),
            format!("{:.1}%", p.efficiency * 100.0),
        ])
        .collect();
    println!("{}", render_table(&["topo", "GPUs", "factor", "efficiency"],
                                &rows));

    // ---- real threaded allreduce cross-check ----
    println!(
        "real ring-allreduce wall time ({grad_mb} MiB f32 payload, \
         in-process threads):\n"
    );
    let n_elems = grad_mb * 1024 * 1024 / 4;
    let mut rows = Vec::new();
    for world in [1usize, 2, 4] {
        let handles = CollectiveGroup::new(world);
        let sw = Stopwatch::new();
        let joins: Vec<_> = handles
            .into_iter()
            .map(|mut h| {
                std::thread::spawn(move || {
                    let mut buf = vec![1.0f32; n_elems];
                    h.allreduce(&mut buf);
                    buf[0]
                })
            })
            .collect();
        for j in joins {
            let v = j.join().unwrap();
            assert_eq!(v, world as f32);
        }
        let dt = sw.elapsed();
        let algbw = (n_elems * 4) as f64 / dt / 1e9;
        rows.push(vec![
            world.to_string(),
            format!("{:.3}s", dt),
            format!("{:.2} GB/s", algbw),
        ]);
    }
    println!("{}", render_table(&["ranks", "wall", "alg bandwidth"], &rows));
    println!("(single-core testbed: ranks time-share one CPU, so wall time \
              grows with ranks; the correctness and traffic pattern are \
              what this cross-check exercises)");

    // ---- persistent pool: amortized repeated-step exchange ----
    // The per-step spawn above pays thread + channel setup on every
    // call; the pool pays it once and reuses workers/channels, which is
    // what the trainer hot loop does (ISSUE 1).
    let steps = 8;
    println!(
        "\npersistent pool, {steps} repeated steps over the same payload \
         (8 buckets, Fig. 2 eager schedule):\n"
    );
    let mut rows = Vec::new();
    for world in [1usize, 2, 4] {
        let ones = Ones { n: n_elems };
        let mut pool =
            CollectivePool::new(world, n_elems,
                                BucketRange::even_split(n_elems, 8),
                                WireFormat::F32);
        pool.step(&[], 1.0, 1, 0, true, &ones)?; // warmup
        let sw = Stopwatch::new();
        let mut exposed = 0.0;
        let mut comm = 0.0;
        for s in 1..=steps {
            let out = pool.step(&[], 1.0, 1, s, true, &ones)?;
            exposed += out.exposed_comm_s;
            comm += out.comm_s;
        }
        let dt = sw.elapsed() / steps as f64;
        // every element must be the sum over ranks
        let got = pool.leader_grads()[0];
        assert_eq!(got, world as f32, "reduced value mismatch");
        let algbw = (n_elems * 4) as f64 / dt / 1e9;
        let eff = if comm > 0.0 {
            (1.0 - exposed / comm).clamp(0.0, 1.0) * 100.0
        } else {
            0.0
        };
        rows.push(vec![
            world.to_string(),
            format!("{:.4}s", dt),
            format!("{:.2} GB/s", algbw),
            format!("{eff:.0}%"),
        ]);
    }
    println!("{}", render_table(
        &["ranks", "wall/step", "alg bandwidth", "overlap eff"], &rows));
    Ok(())
}
