//! AMP in action (paper §4.2): run REAL training steps of the bf16
//! variant next to the f32 baseline, verify the loss curves coincide
//! (the paper's Figure-8 equivalence claim), and demonstrate overflow
//! handling by injecting a poisoned micro-batch gradient.
//!
//! Run: make artifacts && cargo run --release --example amp_loss_scaling

use bertdist::data::masking::{build_batch, MaskingConfig};
use bertdist::data::PairExample;
use bertdist::precision::{has_nonfinite, DynamicLossScaler, StepVerdict};
use bertdist::runtime::Engine;
use bertdist::trainer::init_params;
use bertdist::util::Pcg64;

fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
    let preset = "bert-micro";
    let model = engine.model(preset)?;
    let n = model.param_count;

    // one fixed batch
    let mut rng = Pcg64::new(3);
    let examples: Vec<PairExample> = (0..2)
        .map(|i| PairExample {
            tokens_a: (0..12).map(|t| 10 + t + i).collect(),
            tokens_b: (0..10).map(|t| 40 + t + i).collect(),
            is_next: i % 2 == 0,
        })
        .collect();
    let cfg = MaskingConfig { vocab_size: model.config.vocab_size as u32,
                              ..Default::default() };
    let batch = build_batch(&examples, 32, &cfg, &mut rng);

    // ---- Figure-8 equivalence: f32 vs bf16 short runs, same seed ----
    println!("== optimized (bf16) vs non-optimized (f32) loss equivalence ==");
    let mut curves = Vec::new();
    for variant in ["unfused_f32", "fused_bf16"] {
        let step = engine.train_step(preset, variant, 2, 32)?;
        let apply = engine.apply_step(preset, "lamb")?;
        let mut init_rng = Pcg64::new(7);
        let mut params = init_params(&model.layout, &mut init_rng);
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let scale = if variant.contains("bf16") { 1024.0 } else { 1.0 };
        let mut losses = Vec::new();
        for s in 0..12 {
            let out = step.run(&params, &batch, scale)?;
            losses.push(out.loss);
            apply.run(&mut params, &out.grads, &mut m, &mut v,
                      (s + 1) as f32, 2e-3)?;
        }
        println!("  {variant:<12} loss: {:.4} -> {:.4}", losses[0],
                 losses.last().unwrap());
        curves.push(losses);
    }
    let max_rel: f32 = curves[0]
        .iter()
        .zip(&curves[1])
        .map(|(a, b)| ((a - b) / a).abs())
        .fold(0.0, f32::max);
    println!("  max relative divergence over 12 steps: {:.2}%  \
              (paper Fig. 8: curves are 'highly similar')\n",
             max_rel * 100.0);
    assert!(max_rel < 0.05, "bf16 and f32 curves diverged: {max_rel}");

    // ---- overflow handling with the dynamic scaler ----
    println!("== dynamic loss scaling with an injected overflow ==");
    let step = engine.train_step(preset, "fused_f32", 2, 32)?;
    let apply = engine.apply_step(preset, "lamb")?;
    let mut init_rng = Pcg64::new(7);
    let mut params = init_params(&model.layout, &mut init_rng);
    let mut m = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    let mut scaler = DynamicLossScaler::new(65536.0).with_growth_interval(4);
    let mut applied = 0;
    for s in 0..10 {
        let out = step.run(&params, &batch, scaler.scale() as f32)?;
        let mut grads = out.grads;
        if s == 3 {
            grads[0] = f32::INFINITY; // poison: simulate fp16 overflow
        }
        let overflow = has_nonfinite(&grads) || !out.grad_norm.is_finite();
        match scaler.update(overflow) {
            StepVerdict::Apply => {
                applied += 1;
                apply.run(&mut params, &grads, &mut m, &mut v,
                          applied as f32, 2e-3)?;
                println!("  step {s}: loss {:.4} scale {:>8} APPLY",
                         out.loss, scaler.scale());
            }
            StepVerdict::Skip => {
                println!("  step {s}: OVERFLOW -> skip, scale backs off \
                          to {}", scaler.scale());
            }
        }
    }
    assert_eq!(scaler.skipped_steps, 1);
    assert!(params.iter().all(|p| p.is_finite()),
            "params must stay finite through the overflow");
    println!("\n  params stayed finite; exactly one step skipped. QED §4.2");
    Ok(())
}
