//! Gradient-accumulation tuning study (paper §4.4, Figure 5).
//!
//! Sweeps the accumulation step count k on the paper's 32M8G cluster
//! model and prints the comm:compute ratio, utilization, and effective
//! throughput — showing why the paper settled on k=4 — then renders the
//! Figure-5 stream timeline for k=1 vs k=4.
//!
//! Run: cargo run --release --example grad_accum_tuning

use bertdist::simulator::{simulate_iteration, IterationModel};
use bertdist::topology::Topology;
use bertdist::util::fmt::render_table;

fn main() -> anyhow::Result<()> {
    let topo = Topology::parse("32M8G").unwrap();
    println!(
        "gradient accumulation sweep on {topo} (T4, BERT-large, 10 Gb/s):\n"
    );
    let mut rows = Vec::new();
    for k in [1usize, 2, 4, 8, 16] {
        let m = IterationModel::paper(topo, k, true);
        let r = simulate_iteration(&m);
        let compute = k as f64 * m.micro_compute_s();
        let comm = m.allreduce_s();
        rows.push(vec![
            k.to_string(),
            format!("{:.2}s", compute),
            format!("{:.2}s", comm),
            format!("{:.2}", comm / compute),
            format!("{:.1}%", r.compute_utilization * 100.0),
            format!("{:.0}", r.cluster_tokens_per_sec),
            format!("{}", (k as f64 * m.tokens_per_micro) as usize
                    * topo.world_size() / 128),
        ]);
    }
    println!("{}", render_table(
        &["k", "compute", "comm", "comm:compute", "util", "tokens/s",
          "global batch (sents)"],
        &rows));
    println!(
        "note: k also multiplies the global batch (paper §4.4: \"other \
         hyper-parameters need to be adjusted accordingly\") — k=4 is \
         where utilization saturates without inflating the batch beyond \
         LAMB's comfort zone.\n"
    );

    for k in [1usize, 4] {
        let m = IterationModel::paper(topo, k, true);
        let r = simulate_iteration(&m);
        println!("Figure 5 timeline, k={k} (f=fwd, b=bwd on the gpu \
                  track; b=bucket exchange on the net track, u=update):");
        println!("{}", r.timeline.ascii_gantt(100));
    }
    Ok(())
}
