//! Bench: regenerate paper **Figure 6** — multi-node throughput scaling
//! up to 32M8G (256 GPUs) with k=4 gradient accumulation, asserting the
//! paper's headline 165x weak-scaling factor (±10%).
//!
//! Run: `cargo bench --bench fig6_multinode_scaling`

use bertdist::collectives::hierarchical::nic_bytes_per_node;
use bertdist::netsim::{hierarchical_allreduce_phases,
                       hierarchical_pipelined_phases,
                       hierarchical_rs_phases, ring_allreduce_time,
                       sparse_allgather_time, sparse_ratio_sweep, Fabric};
use bertdist::simulator::scaling::{figure6_topologies, weak_scaling};
use bertdist::simulator::IterationModel;
use bertdist::topology::Topology;
use bertdist::util::ascii_plot::{plot_series, Series};
use bertdist::util::fmt::render_table;

fn main() {
    println!("=== Figure 6: Multi-node Throughput Scaling (k=4) ===\n");
    let template = IterationModel::paper(Topology::new(1, 1), 4, true);
    let pts = weak_scaling(&template, &figure6_topologies());

    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| vec![
            p.topo.to_string(),
            p.gpus.to_string(),
            format!("{:.2e}", p.cluster_tokens_per_sec),
            format!("{:.1}x", p.scaling_factor),
            format!("{:.1}%", p.efficiency * 100.0),
        ])
        .collect();
    println!("{}", render_table(
        &["topology", "GPUs", "tokens/s", "scaling factor", "efficiency"],
        &rows));

    let xy: Vec<(f64, f64)> =
        pts.iter().map(|p| (p.gpus as f64, p.scaling_factor)).collect();
    println!("{}", plot_series("scaling factor vs GPUs",
                               &[Series { name: "xM8G k=4", points: &xy,
                                          marker: '*' }], 60, 14));

    // paper anchors
    let last = pts.last().unwrap();
    assert_eq!(last.gpus, 256);
    assert!((last.scaling_factor - 165.0).abs() / 165.0 < 0.10,
            "headline factor {} vs paper 165", last.scaling_factor);
    for w in pts.windows(2) {
        assert!(w[1].efficiency <= w[0].efficiency + 1e-9,
                "efficiency must decay with machine count");
        assert!(w[1].scaling_factor > w[0].scaling_factor,
                "absolute throughput must still grow");
    }
    println!("headline: {:.0}x at 256 GPUs (paper: 165x, {:.0}% efficiency \
              claimed ~70%)", last.scaling_factor,
             last.efficiency * 100.0);

    // ---- flat vs hierarchical exchange pricing (train.comm_mode) ----
    // The same payload through the four schedules the pooled executor
    // can run, priced by netsim's executed-schedule models: the
    // hierarchy always shrinks the time spent on the 10 Gb/s fabric (an
    // m-leader ring instead of an 8m-rank ring) at the cost of 2(g-1)
    // serialized full-payload PCIe transfers — the chunked pipelined
    // chain (`train.intra_node = ring`) amortizes those transfers
    // across the members, overlapping them with the ring, and the
    // 2-level reduce-scatter (`train.intra_node = rs`) drops the
    // per-link payload to O(n/g) on BOTH fabrics.
    println!("\n=== flat vs hierarchical vs pipelined vs rs allreduce \
              pricing (BERT-large grads, paper fabric) ===\n");
    let fabric = Fabric::paper();
    let bytes = 336_226_108.0 * 4.0;
    let chunk_bytes = 4.0 * (1 << 20) as f64; // 1 Mi elems per chunk
    // ratio grid for the sparse-ring pricing (train.sparsify = topk):
    // wide enough that the interior optimum never saturates an edge
    let sp_grid: Vec<f64> = (0..60)
        .map(|i| 10f64.powf(-6.0 + i as f64 * 6.0 / 59.0))
        .collect();
    let rows: Vec<Vec<String>> = figure6_topologies()
        .iter()
        .filter(|t| t.machines > 1)
        .map(|t| {
            let flat = ring_allreduce_time(t.world_size(), bytes,
                                           fabric.network);
            let p = hierarchical_allreduce_phases(t, bytes, &fabric);
            let pipe = hierarchical_pipelined_phases(t, bytes, &fabric,
                                                     chunk_bytes);
            let rs = hierarchical_rs_phases(t, bytes, &fabric);
            assert!(p.net_s < flat,
                    "{t}: hierarchy must shrink network time \
                     ({} vs {flat})", p.net_s);
            assert!(nic_bytes_per_node(t, bytes, true)
                        < nic_bytes_per_node(t, bytes, false),
                    "{t}: hierarchy must shrink per-NIC bytes");
            if t.gpus_per_machine > 1 {
                assert!(pipe.wall_s < p.total(),
                        "{t}: the pipelined chain must beat the \
                         serialized leader ({} vs {})",
                        pipe.wall_s, p.total());
                assert!(rs.pcie_s < p.pcie_s && rs.net_s < p.net_s,
                        "{t}: the 2-level reduce-scatter must shrink \
                         BOTH phases vs the serialized leader \
                         (pcie {} vs {}, net {} vs {})",
                        rs.pcie_s, p.pcie_s, rs.net_s, p.net_s);
                assert!(rs.total() < p.total(),
                        "{t}: rs must beat the serialized leader \
                         ({} vs {})", rs.total(), p.total());
            }
            // sparse-ring pricing of the leader ring (train.sparsify):
            // topk:1.0 must cost MORE net than the dense leader ring
            // (8 B/entry index tax, m-1 whole-message hops), while the
            // EF-inflation-weighted sweep bottoms out strictly inside
            // the ratio grid — the knob has a real optimum.
            let elems = (bytes / 4.0) as usize;
            let sparse_full =
                sparse_allgather_time(t.machines, elems, 1.0, fabric.network);
            let dense_ring =
                ring_allreduce_time(t.machines, bytes, fabric.network);
            assert!(sparse_full > dense_ring,
                    "{t}: topk:1.0 must price above the dense leader \
                     ring ({sparse_full} vs {dense_ring})");
            let (_, sp_best) = sparse_ratio_sweep(
                t.machines, elems, fabric.network, 0.05, &sp_grid);
            assert!(sp_best.ratio > sp_grid[0] && sp_best.ratio < 1.0,
                    "{t}: sparse ratio optimum saturated an edge \
                     ({sp_best:?})");
            vec![
                t.to_string(),
                format!("{:.2} s", flat),
                format!("{:.2} s", p.total()),
                format!("{:.2} s", p.pcie_s),
                format!("{:.2} s", p.net_s),
                format!("{:.2} s ({})", pipe.wall_s, pipe.chunks),
                format!("{:.2} s", rs.total()),
                format!("{:.2}x", flat / rs.net_s.max(1e-12)),
                format!("{:.4} ({:.2} s)", sp_best.ratio, sp_best.wire_s),
            ]
        })
        .collect();
    println!("{}", render_table(
        &["topology", "flat ring", "hier total", "hier pcie", "hier net",
          "pipelined (chunks)", "rs total", "rs net relief",
          "topk optimum (net)"],
        &rows));
    println!("(hier pcie is the executed leader-accumulate/broadcast \
              cost; pipelined is the chunked intra-node chain at 4 MiB \
              chunks — see netsim::hierarchical_pipelined_phases; rs is \
              the 2-level reduce-scatter moving 1/g of the payload per \
              link — see netsim::hierarchical_rs_phases; topk optimum is \
              the EF-inflation-weighted sparse-ring ratio sweep — \
              netsim::sparse_ratio_sweep — whose topk:1.0 endpoint \
              always prices ABOVE the dense leader ring)");
    println!("\nfig6_multinode_scaling OK");
}
