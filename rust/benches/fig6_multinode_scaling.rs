//! Bench: regenerate paper **Figure 6** — multi-node throughput scaling
//! up to 32M8G (256 GPUs) with k=4 gradient accumulation, asserting the
//! paper's headline 165x weak-scaling factor (±10%).
//!
//! Run: `cargo bench --bench fig6_multinode_scaling`

use bertdist::simulator::scaling::{figure6_topologies, weak_scaling};
use bertdist::simulator::IterationModel;
use bertdist::topology::Topology;
use bertdist::util::ascii_plot::{plot_series, Series};
use bertdist::util::fmt::render_table;

fn main() {
    println!("=== Figure 6: Multi-node Throughput Scaling (k=4) ===\n");
    let template = IterationModel::paper(Topology::new(1, 1), 4, true);
    let pts = weak_scaling(&template, &figure6_topologies());

    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| vec![
            p.topo.to_string(),
            p.gpus.to_string(),
            format!("{:.2e}", p.cluster_tokens_per_sec),
            format!("{:.1}x", p.scaling_factor),
            format!("{:.1}%", p.efficiency * 100.0),
        ])
        .collect();
    println!("{}", render_table(
        &["topology", "GPUs", "tokens/s", "scaling factor", "efficiency"],
        &rows));

    let xy: Vec<(f64, f64)> =
        pts.iter().map(|p| (p.gpus as f64, p.scaling_factor)).collect();
    println!("{}", plot_series("scaling factor vs GPUs",
                               &[Series { name: "xM8G k=4", points: &xy,
                                          marker: '*' }], 60, 14));

    // paper anchors
    let last = pts.last().unwrap();
    assert_eq!(last.gpus, 256);
    assert!((last.scaling_factor - 165.0).abs() / 165.0 < 0.10,
            "headline factor {} vs paper 165", last.scaling_factor);
    for w in pts.windows(2) {
        assert!(w[1].efficiency <= w[0].efficiency + 1e-9,
                "efficiency must decay with machine count");
        assert!(w[1].scaling_factor > w[0].scaling_factor,
                "absolute throughput must still grow");
    }
    println!("headline: {:.0}x at 256 GPUs (paper: 165x, {:.0}% efficiency \
              claimed ~70%)", last.scaling_factor,
             last.efficiency * 100.0);
    println!("\nfig6_multinode_scaling OK");
}
