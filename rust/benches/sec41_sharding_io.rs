//! Bench: the §4.1 data-sharding experiment — startup latency of
//! monolithic load-and-scatter vs per-device shard streams, measured for
//! real on this machine's filesystem.
//!
//! Paper numbers (32-node cluster, full corpus): 8–10 min -> <2 min cold,
//! 3–5 min -> <1 min per-epoch.  Here the corpus is testbed-sized, so the
//! assertion is the *shape*: sharded per-rank open+read beats monolithic
//! parse-and-scatter, and epoch reshuffling is near-free (index
//! permutation, no data movement).
//!
//! Run: `cargo bench --bench sec41_sharding_io`

use bertdist::data::corpus::SyntheticCorpus;
use bertdist::data::{build_shards, ShardedDataset, Vocab};
use bertdist::data::tokenizer::Tokenizer;
use bertdist::util::fmt::render_table;
use bertdist::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    println!("=== §4.1: data loading, monolithic vs sharded ===\n");
    let dir = std::env::temp_dir().join("bertdist_bench_shard_io");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // a corpus big enough to measure (~200k words)
    let docs = SyntheticCorpus::new(3, 20_000).documents(400, 12, 14);
    let vocab = Vocab::from_documents(&docs, 8192);
    let world = 8;

    // ---- monolithic path: every "device" re-tokenizes + scatters ----
    // (what the paper's baseline did: load full data, then truncate per
    // device)
    let text: String = docs
        .iter()
        .map(|d| d.join("\n"))
        .collect::<Vec<_>>()
        .join("\n\n");
    let raw_path = dir.join("corpus.txt");
    std::fs::write(&raw_path, &text)?;

    let sw = Stopwatch::new();
    let loaded = bertdist::data::corpus::load_text_file(&raw_path)?;
    let tok = Tokenizer::new(&vocab);
    let mut total_tokens = 0usize;
    let mut per_device: Vec<usize> = vec![0; world];
    for (i, s) in loaded.iter().flatten().enumerate() {
        let ids = tok.encode(s);
        total_tokens += ids.len();
        per_device[i % world] += ids.len();
    }
    let monolithic = sw.elapsed();

    // ---- sharded path: build once, then per-rank open ----
    let sw = Stopwatch::new();
    build_shards(&docs, &vocab, world, &dir, "train", 3)?;
    let build_time = sw.elapsed();

    let sw = Stopwatch::new();
    let ds: Vec<ShardedDataset> = (0..world)
        .map(|r| ShardedDataset::open(&dir, "train", r, world).unwrap())
        .collect();
    let shard_open = sw.elapsed();

    let sw = Stopwatch::new();
    let _orders: Vec<Vec<usize>> =
        ds.iter().map(|d| d.epoch_order(1, 42)).collect();
    let reshuffle = sw.elapsed();

    println!("{}", render_table(
        &["path", "time", "notes"],
        &[
            vec!["monolithic load+tokenize+scatter".into(),
                 format!("{:.3}s", monolithic),
                 format!("{total_tokens} tokens, every epoch start")],
            vec!["shard build (ONCE, offline)".into(),
                 format!("{:.3}s", build_time), "amortized".into()],
            vec!["per-rank shard open (cold start)".into(),
                 format!("{:.3}s", shard_open),
                 format!("{} ranks", world)],
            vec!["epoch re-shuffle (warm)".into(),
                 format!("{:.6}s", reshuffle),
                 "index permutation only".into()],
        ]));

    let cold_speedup = monolithic / shard_open;
    let warm_speedup = monolithic / reshuffle.max(1e-9);
    println!("cold-start speedup: {cold_speedup:.1}x (paper: 8-10min -> \
              <2min ~ 4-5x)");
    println!("per-epoch speedup: {warm_speedup:.0}x (paper: 3-5min -> \
              <1min ~ 3-5x; ours is an index permutation, so far larger)");
    assert!(cold_speedup > 1.5,
            "sharded open must beat monolithic: {cold_speedup}");
    assert!(reshuffle < shard_open,
            "epoch reshuffle must be cheaper than cold open");
    let _ = std::fs::remove_dir_all(&dir);
    println!("\nsec41_sharding_io OK");
    Ok(())
}
