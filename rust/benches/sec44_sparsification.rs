//! Bench: the §4.4 gradient-sparsification BASELINE study — why the
//! paper rejected it in favor of gradient accumulation.
//!
//! Measures, on REAL BERT gradients from the PJRT substrate:
//!   * signal quality (cosine to dense) vs compression ratio,
//!   * selection overhead (the "extra calculation" §4.4 mentions),
//!   * threshold sensitivity (the "tuning work"),
//! and contrasts with a synthetic heavy-tailed gradient where
//! sparsification DOES work — reproducing the paper's argument that
//! BERT's dense Fig.-4 gradient profile is the wrong fit.
//!
//! Run: `cargo bench --bench sec44_sparsification`

use bertdist::data::masking::{build_batch, MaskingConfig};
use bertdist::data::PairExample;
use bertdist::grad::sparsify::{by_threshold, cosine_to_dense,
                               synth_heavy_tailed, top_k};
use bertdist::runtime::Engine;
use bertdist::trainer::init_params;
use bertdist::util::fmt::render_table;
use bertdist::util::stopwatch::bench_times;
use bertdist::util::Pcg64;

fn main() -> anyhow::Result<()> {
    println!("=== §4.4 baseline: gradient sparsification on BERT ===\n");
    let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
    let model = engine.model("bert-micro")?;
    let step = engine.train_step("bert-micro", "fused_f32", 2, 32)?;
    let mut rng = Pcg64::new(17);
    let params = init_params(&model.layout, &mut rng);
    let ex = PairExample {
        tokens_a: (10..24).collect(),
        tokens_b: (30..44).collect(),
        is_next: true,
    };
    let cfg = MaskingConfig { vocab_size: 512, ..Default::default() };
    let batch = build_batch(&[ex.clone(), ex], 32, &cfg, &mut rng);
    let grads = step.run(&params, &batch, 1.0)?.grads;
    let n = grads.len();

    println!("real BERT gradient ({n} elements) vs synthetic heavy-tailed:\n");
    let heavy = synth_heavy_tailed(n, 3);
    let mut rows = Vec::new();
    for keep_pct in [50.0, 20.0, 10.0, 1.0] {
        let k = (n as f64 * keep_pct / 100.0) as usize;
        let s_bert = top_k(&grads, k);
        let s_heavy = top_k(&heavy, k);
        rows.push(vec![
            format!("{keep_pct}%"),
            format!("{:.1}x", s_bert.compression()),
            format!("{:.3}", cosine_to_dense(&s_bert, &grads)),
            format!("{:.3}", cosine_to_dense(&s_heavy, &heavy)),
        ]);
    }
    println!("{}", render_table(
        &["kept", "compression", "cosine (BERT grads)",
          "cosine (heavy-tailed)"],
        &rows));

    // shape assertions: at 100:1 compression (where sparsification pays
    // for its overheads) the heavy-tailed gradient keeps its signal but
    // BERT's dense gradient visibly degrades.
    let k10 = n / 10;
    let k100 = n / 100;
    let cos_bert = cosine_to_dense(&top_k(&grads, k100), &grads);
    let cos_heavy = cosine_to_dense(&top_k(&heavy, k100), &heavy);
    assert!(cos_heavy > 0.995, "heavy-tailed must stay intact: {cos_heavy}");
    assert!(cos_bert < cos_heavy - 0.02,
            "dense BERT must degrade more: {cos_bert} vs {cos_heavy}");

    // selection overhead
    let (sel_min, _, _) = bench_times(5, || {
        std::hint::black_box(top_k(&grads, k10));
    });
    println!("top-k selection overhead: {:.2} ms for {n} grads \
              ({:.0} Melem/s) — paid EVERY iteration",
             sel_min * 1e3, n as f64 / sel_min / 1e6);

    // threshold sensitivity (the tuning problem)
    println!("\nthreshold sensitivity (the §4.4 tuning risk):\n");
    let mut rows = Vec::new();
    for t in [1e-6f32, 1e-5, 1e-4, 1e-3] {
        let s = by_threshold(&grads, t);
        rows.push(vec![
            format!("{t:.0e}"),
            format!("{:.2}%", 100.0 * s.indices.len() as f64 / n as f64),
            format!("{:.1}x", s.compression()),
            format!("{:.3}", cosine_to_dense(&s, &grads)),
        ]);
    }
    println!("{}", render_table(
        &["threshold", "kept", "compression", "cosine"], &rows));
    println!("a 100x threshold range swings kept-fraction by orders of \
              magnitude — the tuning burden the paper cites.");

    // the alternative the paper chose: gradient accumulation reduces
    // traffic 4x with ZERO signal distortion.
    println!("\ngradient accumulation k=4 (the paper's choice): 4.0x \
              traffic reduction, cosine 1.000 by construction.");
    println!("\nsec44_sparsification OK");
    Ok(())
}
