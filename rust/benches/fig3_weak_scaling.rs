//! Bench: regenerate paper **Figure 3** — weak scaling comparison
//! between intra-node scaling (1M{1..8}G over PCIe) and inter-node
//! scaling ({1..8}M1G over the 10 Gb/s network), no grad accumulation.
//!
//! Run: `cargo bench --bench fig3_weak_scaling`

use bertdist::simulator::scaling::sweep_intra_vs_inter;
use bertdist::simulator::IterationModel;
use bertdist::topology::Topology;
use bertdist::util::ascii_plot::{plot_series, Series};
use bertdist::util::fmt::render_table;

fn main() {
    println!("=== Figure 3: Intra-node vs Inter-node weak scaling ===\n");
    let template = IterationModel::paper(Topology::new(1, 1), 1, true);
    let (intra, inter) = sweep_intra_vs_inter(&template);

    let rows: Vec<Vec<String>> = intra
        .iter()
        .zip(&inter)
        .map(|(a, b)| vec![
            a.gpus.to_string(),
            format!("{}", a.topo),
            format!("{:.2}x ({:.0}%)", a.scaling_factor,
                    a.efficiency * 100.0),
            format!("{}", b.topo),
            format!("{:.2}x ({:.0}%)", b.scaling_factor,
                    b.efficiency * 100.0),
        ])
        .collect();
    println!("{}", render_table(
        &["GPUs", "intra topo", "intra factor", "inter topo",
          "inter factor"],
        &rows));

    let ai: Vec<(f64, f64)> =
        intra.iter().map(|p| (p.gpus as f64, p.scaling_factor)).collect();
    let bi: Vec<(f64, f64)> =
        inter.iter().map(|p| (p.gpus as f64, p.scaling_factor)).collect();
    println!("{}", plot_series(
        "weak scaling factor (i=intra, x=inter)",
        &[Series { name: "intra-node", points: &ai, marker: 'i' },
          Series { name: "inter-node", points: &bi, marker: 'x' }],
        60, 14));

    // Paper shape assertions:
    // 1. near-zero gain 1M1G -> 2M1G
    assert!(inter[1].scaling_factor < 1.5,
            "2M1G factor {}", inter[1].scaling_factor);
    // 2. inter-node efficiency capped around 38%
    assert!((0.30..0.45).contains(&inter[3].efficiency),
            "8M1G eff {}", inter[3].efficiency);
    // 3. intra-node dominates inter-node at every width
    for (a, b) in intra.iter().zip(&inter).skip(1) {
        assert!(a.scaling_factor > b.scaling_factor);
    }
    println!("paper anchors hold: 2M1G ~no gain; inter cap ~38%; \
              intra > inter everywhere");
    println!("\nfig3_weak_scaling OK");
}
