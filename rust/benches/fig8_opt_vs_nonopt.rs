//! Bench: regenerate paper **Figure 8** — optimized vs non-optimized
//! training loss equivalence, measured with REAL training runs on the
//! PJRT substrate: same seed, same data, fused_bf16 vs unfused_f32.
//!
//! The paper's claim: the systems optimizations do not change the
//! training trajectory ("the two loss curve is highly similar").
//!
//! Run: `cargo bench --bench fig8_opt_vs_nonopt`

use bertdist::data::masking::{build_batch, MaskingConfig};
use bertdist::data::PairExample;
use bertdist::runtime::Engine;
use bertdist::trainer::init_params;
use bertdist::util::ascii_plot::{plot_series, Series};
use bertdist::util::Pcg64;

const STEPS: usize = 25;

fn main() -> anyhow::Result<()> {
    println!("=== Figure 8: Optimized vs Non-optimized loss curves ===\n");
    let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
    let preset = "bert-micro";
    let model = engine.model(preset)?;
    let n = model.param_count;

    // fixed mini-dataset of 4 batches, rotated
    let cfg = MaskingConfig { vocab_size: model.config.vocab_size as u32,
                              ..Default::default() };
    let mut rng = Pcg64::new(11);
    let batches: Vec<_> = (0..4)
        .map(|i| {
            let exs: Vec<PairExample> = (0..2)
                .map(|j| PairExample {
                    tokens_a: (0..14).map(|t| 10 + (t * (i + 1) + j) % 480)
                        .collect(),
                    tokens_b: (0..12).map(|t| 20 + (t * (j + 2) + i) % 480)
                        .collect(),
                    is_next: (i + j) % 2 == 0,
                })
                .collect();
            build_batch(&exs, 32, &cfg, &mut rng)
        })
        .collect();

    let mut curves: Vec<Vec<(f64, f64)>> = Vec::new();
    for (variant, scale) in [("unfused_f32", 1.0f32), ("fused_bf16", 1024.0)] {
        let step = engine.train_step(preset, variant, 2, 32)?;
        let apply = engine.apply_step(preset, "lamb")?;
        let mut irng = Pcg64::new(7);
        let mut params = init_params(&model.layout, &mut irng);
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let mut curve = Vec::new();
        for s in 0..STEPS {
            let out = step.run(&params, &batches[s % batches.len()], scale)?;
            curve.push((s as f64, out.loss as f64));
            apply.run(&mut params, &out.grads, &mut m, &mut v,
                      (s + 1) as f32, 3e-3)?;
        }
        println!("{variant:<12}: loss {:.4} -> {:.4}", curve[0].1,
                 curve.last().unwrap().1);
        curves.push(curve);
    }

    println!("{}", plot_series(
        "loss, optimized (o) vs non-optimized (n)",
        &[Series { name: "unfused_f32 (non-optimized)", points: &curves[0],
                   marker: 'n' },
          Series { name: "fused_bf16 (optimized)", points: &curves[1],
                   marker: 'o' }],
        70, 16));

    let max_rel = curves[0]
        .iter()
        .zip(&curves[1])
        .map(|(a, b)| ((a.1 - b.1) / a.1).abs())
        .fold(0.0f64, f64::max);
    println!("max relative divergence over {STEPS} steps: {:.3}%",
             max_rel * 100.0);
    assert!(max_rel < 0.05,
            "optimized curve diverged from baseline: {max_rel}");
    // both must actually learn
    for c in &curves {
        assert!(c.last().unwrap().1 < c[0].1, "no learning happened");
    }
    println!("\nfig8_opt_vs_nonopt OK");
    Ok(())
}
