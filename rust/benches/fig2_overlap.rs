//! Bench: regenerate paper **Figure 2** — timeline comparison between
//! non-overlapping and overlapping communication with computation —
//! first analytically (calibrated simulator), then MEASURED on the
//! persistent collective pool's real worker threads (ISSUE 1): the same
//! deterministic gradients are exchanged with the barrier schedule and
//! with the eager bucket-by-bucket schedule, asserting the reduced
//! results are bitwise identical and reporting the measured
//! overlap-efficiency ratio.
//!
//! Run: `cargo bench --bench fig2_overlap`

use bertdist::collectives::pool::{CollectivePool, MicroStats, RankCompute,
                                  WireFormat};
use bertdist::grad::BucketRange;
use bertdist::simulator::{simulate_iteration, IterationModel};
use bertdist::topology::Topology;
use bertdist::util::human_duration;

/// Deterministic pseudo-backward: fills the gradient vector with a pure
/// function of (rank, step, micro, i) so both schedules see identical
/// inputs.
struct SynthBackward {
    n: usize,
}

impl RankCompute for SynthBackward {
    fn micro(&self, rank: usize, step: usize, micro: usize, _p: &[f32],
             _scale: f32, out: &mut Vec<f32>) -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        for (i, v) in out.iter_mut().enumerate() {
            *v = ((rank * 7 + step * 3 + micro) % 11) as f32 * 0.125
                + (i % 17) as f32 * 0.03125;
        }
        Ok(MicroStats::default())
    }
}

fn main() {
    println!("=== Figure 2: Non-overlapping vs Overlapping timelines ===\n");
    let topo = Topology::parse("2M1G").unwrap();

    let mut results = Vec::new();
    for overlap in [false, true] {
        let m = IterationModel::paper(topo, 1, overlap);
        let r = simulate_iteration(&m);
        println!(
            "{} communication (iteration {}):",
            if overlap { "OVERLAPPING" } else { "NON-OVERLAPPING" },
            human_duration(r.iteration_s)
        );
        println!("{}", r.timeline.ascii_gantt(96));
        results.push(r);
    }
    let (no, yes) = (&results[0], &results[1]);
    let gain = no.iteration_s / yes.iteration_s;
    println!("overlap speedup: {gain:.3}x  (exposed comm {} -> {})",
             human_duration(no.exposed_comm_s),
             human_duration(yes.exposed_comm_s));
    assert!(yes.iteration_s < no.iteration_s,
            "overlap must shorten the iteration");
    // the hidden window is bounded by backward time
    let c = IterationModel::paper(topo, 1, true).micro_compute_s();
    assert!(no.iteration_s - yes.iteration_s <= c * 2.0 / 3.0 + 1e-9);

    // ---- measured on the persistent pool (real worker threads) ----
    println!("\n=== measured: persistent pool, barrier vs eager buckets ===\n");
    let (world, n, buckets, k, steps) = (2usize, 1 << 18, 8usize, 2usize, 6);
    let synth = SynthBackward { n };
    let mut walls = Vec::new();
    let mut reduced: Vec<Vec<f32>> = Vec::new();
    for overlap in [false, true] {
        let mut pool = CollectivePool::new(
            world, n, BucketRange::even_split(n, buckets), WireFormat::F32);
        pool.step(&[], 1.0, k, 0, overlap, &synth).unwrap(); // warmup
        let mut wall = 0.0;
        let mut comm = 0.0;
        let mut exposed = 0.0;
        for s in 1..=steps {
            let out = pool.step(&[], 1.0, k, s, overlap, &synth).unwrap();
            wall += out.wall_s;
            comm += out.comm_s;
            exposed += out.exposed_comm_s;
        }
        let eff = if comm > 0.0 {
            (1.0 - exposed / comm).clamp(0.0, 1.0)
        } else {
            0.0
        };
        println!(
            "{}: wall {:.2} ms/step, comm {:.2} ms, exposed {:.2} ms, \
             overlap_eff {:.0}%",
            if overlap { "eager (Fig. 2)" } else { "barrier       " },
            wall / steps as f64 * 1e3, comm / steps as f64 * 1e3,
            exposed / steps as f64 * 1e3, eff * 100.0
        );
        walls.push(wall);
        reduced.push(pool.leader_grads().clone());
    }
    // identical inputs => bitwise identical reduced gradients
    for (a, b) in reduced[0].iter().zip(reduced[1].iter()) {
        assert_eq!(a.to_bits(), b.to_bits(),
                   "barrier and eager schedules must agree bitwise");
    }
    // the eager schedule must not be slower than barrier beyond noise
    assert!(walls[1] <= walls[0] * 1.25,
            "eager schedule slower than barrier: {:.3}s vs {:.3}s",
            walls[1], walls[0]);
    println!("\nfig2_overlap OK");
}
