//! Bench: regenerate paper **Figure 2** — timeline comparison between
//! non-overlapping and overlapping communication with computation.
//!
//! Run: `cargo bench --bench fig2_overlap`

use bertdist::simulator::{simulate_iteration, IterationModel};
use bertdist::topology::Topology;
use bertdist::util::human_duration;

fn main() {
    println!("=== Figure 2: Non-overlapping vs Overlapping timelines ===\n");
    let topo = Topology::parse("2M1G").unwrap();

    let mut results = Vec::new();
    for overlap in [false, true] {
        let m = IterationModel::paper(topo, 1, overlap);
        let r = simulate_iteration(&m);
        println!(
            "{} communication (iteration {}):",
            if overlap { "OVERLAPPING" } else { "NON-OVERLAPPING" },
            human_duration(r.iteration_s)
        );
        println!("{}", r.timeline.ascii_gantt(96));
        results.push(r);
    }
    let (no, yes) = (&results[0], &results[1]);
    let gain = no.iteration_s / yes.iteration_s;
    println!("overlap speedup: {gain:.3}x  (exposed comm {} -> {})",
             human_duration(no.exposed_comm_s),
             human_duration(yes.exposed_comm_s));
    assert!(yes.iteration_s < no.iteration_s,
            "overlap must shorten the iteration");
    // the hidden window is bounded by backward time
    let c = IterationModel::paper(topo, 1, true).micro_compute_s();
    assert!(no.iteration_s - yes.iteration_s <= c * 2.0 / 3.0 + 1e-9);
    println!("\nfig2_overlap OK");
}
