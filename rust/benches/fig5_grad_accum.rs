//! Bench: regenerate paper **Figure 5** — CUDA-stream-style timeline for
//! gradient-accumulated training, plus the utilization sweep over k.
//!
//! Run: `cargo bench --bench fig5_grad_accum`

use bertdist::simulator::{simulate_iteration, IterationModel};
use bertdist::topology::Topology;
use bertdist::util::fmt::render_table;

fn main() {
    println!("=== Figure 5: Stream timeline with gradient accumulation ===\n");
    let topo = Topology::parse("32M8G").unwrap();

    for k in [1usize, 4] {
        let m = IterationModel::paper(topo, k, true);
        let r = simulate_iteration(&m);
        println!("k={k}: iteration {:.2}s, utilization {:.1}%",
                 r.iteration_s, r.compute_utilization * 100.0);
        println!("{}", r.timeline.ascii_gantt(96));
    }

    println!("utilization sweep (the §4.4 tuning story):\n");
    let mut rows = Vec::new();
    let mut utils = Vec::new();
    for k in [1usize, 2, 4, 8, 16] {
        let m = IterationModel::paper(topo, k, true);
        let r = simulate_iteration(&m);
        utils.push(r.compute_utilization);
        rows.push(vec![
            k.to_string(),
            format!("{:.2}s", k as f64 * m.micro_compute_s()),
            format!("{:.2}s", m.allreduce_s()),
            format!("{:.2}s", r.iteration_s),
            format!("{:.1}%", r.compute_utilization * 100.0),
        ]);
    }
    println!("{}", render_table(
        &["k", "compute", "comm", "iteration", "utilization"], &rows));

    // shape: utilization strictly increases with k and k=4 is a knee
    for w in utils.windows(2) {
        assert!(w[1] > w[0], "utilization must rise with k: {utils:?}");
    }
    let gain_14 = utils[2] - utils[0];
    let gain_416 = utils[4] - utils[2];
    assert!(gain_14 > gain_416,
            "k=1->4 must be the big win (diminishing returns after)");
    println!("k=1->4 utilization gain {:.1}pp > k=4->16 gain {:.1}pp \
              (diminishing returns, why the paper chose k=4)",
             gain_14 * 100.0, gain_416 * 100.0);
    println!("\nfig5_grad_accum OK");
}
