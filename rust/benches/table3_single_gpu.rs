//! Bench: regenerate paper **Table 3** — single-GPU pretraining time
//! estimation (per-device epoch time and 40-epoch total).
//!
//! Run: `cargo bench --bench table3_single_gpu`

use bertdist::simulator::{DeviceModel, Variant, DEVICES,
                          PAPER_TOKENS_PER_EPOCH};
use bertdist::util::fmt::render_table;

// (device index, paper epoch hours, paper 40-epoch days) from Table 3.
const PAPER: [(usize, f64, f64); 3] =
    [(0, 1441.6, 2400.0), (1, 857.1, 1440.0), (2, 432.3, 720.0)];

fn main() {
    println!("=== Table 3: Single GPU Pre-training Time Estimation ===\n");
    let mut rows = Vec::new();
    let mut worst_rel = 0.0f64;
    for &(i, paper_h, paper_d) in &PAPER {
        let d: DeviceModel = DEVICES[i];
        let h = d.epoch_hours(Variant::Fp16Fused, PAPER_TOKENS_PER_EPOCH);
        let days = d.forty_epoch_days(Variant::Fp16Fused,
                                      PAPER_TOKENS_PER_EPOCH);
        worst_rel = worst_rel.max(((h - paper_h) / paper_h).abs());
        rows.push(vec![
            d.name.to_string(),
            format!("{:.1}/s", d.throughput(Variant::Fp16Fused)),
            format!("{:.1} M", PAPER_TOKENS_PER_EPOCH / 1e6),
            format!("{:.1} h ({:.0} days)", h, h / 24.0),
            format!("{:.0} days", days),
            format!("{:.1} h / {:.0} days", paper_h, paper_d),
        ]);
    }
    println!("{}", render_table(
        &["Device", "Optimized Throughput", "Tokens/Epoch",
          "Est. Time/Epoch", "40-Epoch Time", "paper"],
        &rows));
    println!("max relative error vs paper: {:.2}%", worst_rel * 100.0);
    assert!(worst_rel < 0.01, "Table 3 drifted from the paper");
    println!("\ntable3_single_gpu OK");
}
