//! Bench: regenerate paper **Figure 4** — gradient memory profile of
//! BERT-large grouped by layer class, supporting the §4.4 argument that
//! the gradients are dense (sparsification unattractive).
//!
//! Run: `cargo bench --bench fig4_grad_profile`

use bertdist::model::BertConfig;
use bertdist::util::ascii_plot::bar_chart;
use bertdist::util::human_bytes;

fn main() {
    println!("=== Figure 4: Gradient Memory Profile (BERT-large) ===\n");
    let cfg = BertConfig::preset("bert-large").unwrap();
    let layout = cfg.param_layout();
    let profile = layout.gradient_profile();

    let rows: Vec<(String, f64)> = profile
        .sorted_rows()
        .into_iter()
        .map(|(name, bytes)| {
            (format!("{name:<13} {:>10}", human_bytes(bytes)), bytes / 1e6)
        })
        .collect();
    println!("{}", bar_chart("MB of f32 gradients per layer group",
                             &rows, 48));

    let dense = profile.dense_fraction();
    println!("total gradients: {} across {} tensors",
             human_bytes(profile.total() as f64), layout.entries().len());
    println!("dense (attention+intermediate+output) fraction: {:.1}%",
             dense * 100.0);
    // Paper: "the majority of the gradients are in the attention,
    // intermediate, and output layers".
    assert!(dense > 0.7, "Figure-4 shape violated: dense={dense}");
    let rows = profile.sorted_rows();
    assert_eq!(rows[0].0, "attention", "attention must dominate");
    println!("\nfig4_grad_profile OK");
}
