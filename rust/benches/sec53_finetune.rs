//! Bench: the §5.3 experiment — fine-tune the QA span head starting
//! from a PRETRAINED checkpoint vs from scratch, on the SQuAD-mechanism
//! task (DESIGN.md §2 substitution for SQuAD v1.1).
//!
//! The paper's §5.3 signal: the pretrained encoder transfers (81–83% F1
//! on real SQuAD).  Our shape check: after the same number of fine-tune
//! steps, the pretrained start reaches a lower (or equal) QA loss than
//! the random start.
//!
//! Run: `cargo bench --bench sec53_finetune`

use bertdist::config::RunConfig;
use bertdist::coordinator::train_run;
use bertdist::data::corpus::SyntheticCorpus;
use bertdist::data::{build_shards, Vocab};
use bertdist::finetune::run_finetune;
use bertdist::runtime::Engine;
use bertdist::topology::Topology;
use bertdist::trainer::init_params;
use bertdist::util::Pcg64;

fn main() -> anyhow::Result<()> {
    println!("=== §5.3: fine-tuning from pretrained vs scratch ===\n");
    let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
    let preset = "bert-micro";
    let model = engine.model(preset)?;

    // ---- quick MLM pretraining to obtain a checkpoint ----
    let dir = std::env::temp_dir().join("bertdist_sec53");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let docs = SyntheticCorpus::new(31, 2_000).documents(40, 8, 10);
    let vocab = Vocab::from_documents(&docs, model.config.vocab_size);
    vocab.save(&dir.join("vocab.txt"))?;
    build_shards(&docs, &vocab, 2, &dir, "train", 31)?;

    let mut cfg = RunConfig::default();
    cfg.train.preset = preset.into();
    cfg.train.lr = 2e-3;
    cfg.train.warmup_steps = 10;
    cfg.train.accum_steps = 1;
    cfg.train.log_every = 50;
    cfg.cluster.topo = Topology::parse("1M2G").unwrap();
    println!("pretraining {preset} for 150 steps ...");
    let ck = dir.join("pre.ckpt");
    let out = train_run(&engine, &cfg, &dir, 150, 0, 2, 32, Some(&ck))?;
    println!("pretraining: loss {:.4} -> {:.4}\n",
             out.phase1.loss.points[0].1, out.phase1.loss.tail_mean(10));

    // ---- fine-tune: pretrained vs scratch, same seed/steps ----
    let pre = bertdist::checkpoint::Checkpoint::load(&ck)?;
    let mut rng = Pcg64::new(2);
    let scratch = init_params(&model.layout, &mut rng);
    let steps = 80;
    println!("fine-tuning {steps} steps each ...");
    let rep_pre =
        run_finetune(&engine, preset, &pre.params, steps, 2, 32, 1e-3, 9)?;
    let rep_scr =
        run_finetune(&engine, preset, &scratch, steps, 2, 32, 1e-3, 9)?;

    let tail_pre = rep_pre.loss.tail_mean(10);
    let tail_scr = rep_scr.loss.tail_mean(10);
    println!("  pretrained: loss -> {tail_pre:.4}, exact {:.1}%",
             rep_pre.final_exact * 100.0);
    println!("  scratch   : loss -> {tail_scr:.4}, exact {:.1}%",
             rep_scr.final_exact * 100.0);

    // shape assertions
    assert!(rep_pre.loss.tail_mean(10) < rep_pre.loss.points[0].1,
            "pretrained fine-tune must learn");
    assert!(rep_scr.loss.tail_mean(10) < rep_scr.loss.points[0].1,
            "scratch fine-tune must learn");
    assert!(tail_pre <= tail_scr * 1.05,
            "pretrained start must not be worse than scratch \
             ({tail_pre:.4} vs {tail_scr:.4})");
    println!("\npaper context: real SQuAD F1 81-83% (theirs) vs 90.9% \
              (Google) — the gap is a phase-2 hyperparameter issue \
              (§5.2), not a systems issue; this bench reproduces the \
              transfer mechanism.");
    let _ = std::fs::remove_dir_all(&dir);
    println!("\nsec53_finetune OK");
    Ok(())
}
