//! Bench: regenerate paper **Tables 4 & 5** — single-GPU throughput of
//! the optimization variants, two ways:
//!
//! 1. the paper's own measured device table (P100/T4/2080Ti), asserting
//!    the Table-5 speedup ratios;
//! 2. MEASURED on our substrate: wall-clock of the four AOT train-step
//!    variants (unfused_f32 / bf16 / fused_f32 / fused_bf16) on the PJRT
//!    CPU backend — the *shape* check: fused >= unfused for the same
//!    dtype (absolute CPU numbers are not comparable to GPUs).
//!
//! Run: `cargo bench --bench table4_throughput`

use bertdist::collectives::pool::{CollectivePool, CommMode, IntraNodeMode,
                                  MicroStats, RankCompute, WireFormat};
use bertdist::data::masking::{build_batch, MaskingConfig};
use bertdist::topology::Topology;
use bertdist::data::{Batch, PairExample};
use bertdist::grad::sparsify::Sparsify;
use bertdist::grad::BucketRange;
use bertdist::runtime::{Engine, TrainStep};
use bertdist::simulator::{Variant, DEVICES};
use bertdist::trainer::init_params;
use bertdist::util::fmt::render_table;
use bertdist::util::stopwatch::bench_times;
use bertdist::util::Pcg64;

/// Pool compute that replays one fixed batch through the shared compiled
/// train step — measures the persistent executor's dispatch + exchange
/// overhead against the sequential loop.
struct PooledStep<'a> {
    step: &'a TrainStep,
    batch: &'a Batch,
}

impl RankCompute for PooledStep<'_> {
    fn micro(&self, _rank: usize, _step: usize, _micro: usize,
             params: &[f32], scale: f32, out: &mut Vec<f32>)
             -> anyhow::Result<MicroStats> {
        let o = self.step.run(params, self.batch, scale)?;
        *out = o.grads;
        Ok(MicroStats { loss: o.loss as f64, ..Default::default() })
    }
}

fn main() -> anyhow::Result<()> {
    // ---- part 1: the paper's device table ----
    println!("=== Table 4: Throughput Comparison (Tokens/s), seq 128 ===\n");
    let mut rows = Vec::new();
    for d in &DEVICES {
        rows.push(vec![
            d.name.to_string(),
            format!("{:.1}", d.non_optimized),
            format!("{:.1}", d.fp16),
            format!("{:.1}", d.fp16_fused),
        ]);
    }
    println!("{}", render_table(
        &["Device", "Non-Optimized", "FP16", "FP16 & Fused"], &rows));

    println!("=== Table 5: Speedups vs non-optimized ===\n");
    let mut rows = Vec::new();
    let paper = [(1.70, 2.05), (2.27, 2.78), (2.50, 3.05)];
    for (d, (p16, pf)) in DEVICES.iter().zip(paper) {
        let s16 = d.speedup(Variant::Fp16);
        let sf = d.speedup(Variant::Fp16Fused);
        assert!((s16 - p16).abs() < 0.01 && (sf - pf).abs() < 0.01,
                "{}: {s16}/{sf} vs paper {p16}/{pf}", d.name);
        rows.push(vec![d.name.to_string(), "1".into(),
                       format!("{s16:.2}"), format!("{sf:.2}")]);
    }
    println!("{}", render_table(
        &["Device", "Non-Optimized", "FP16", "FP16 & Fused"], &rows));

    // ---- part 2: measured on our PJRT substrate ----
    println!("=== measured on this substrate (bert-micro, PJRT-CPU) ===\n");
    let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
    let model = engine.model("bert-micro")?;
    let mut rng = Pcg64::new(5);
    let params = init_params(&model.layout, &mut rng);
    let ex = PairExample {
        tokens_a: (10..24).collect(),
        tokens_b: (30..42).collect(),
        is_next: true,
    };
    let cfg = MaskingConfig { vocab_size: model.config.vocab_size as u32,
                              ..Default::default() };
    let batch = build_batch(&[ex.clone(), ex], 32, &cfg, &mut rng);
    let tokens = (batch.batch * batch.seq) as f64;

    let mut rows = Vec::new();
    let mut tput = std::collections::BTreeMap::new();
    for variant in ["unfused_f32", "bf16", "fused_f32", "fused_bf16"] {
        let step = engine.train_step("bert-micro", variant, 2, 32)?;
        // warmup
        step.run(&params, &batch, 1.0)?;
        let (min, mean, _max) =
            bench_times(10, || { step.run(&params, &batch, 1.0).unwrap(); });
        let t = tokens / min;
        tput.insert(variant.to_string(), t);
        rows.push(vec![
            variant.to_string(),
            format!("{:.2} ms", min * 1e3),
            format!("{:.2} ms", mean * 1e3),
            format!("{:.0} tok/s", t),
        ]);
    }
    println!("{}", render_table(
        &["variant", "min step", "mean step", "throughput"], &rows));

    // ---- pooled data-parallel step on the persistent workers ----
    // The Fig. 2 path end-to-end with real XLA compute: world ranks run
    // the same compiled step in parallel on the pool's workers and
    // exchange gradients through the reusable ring.
    println!("=== pooled data-parallel step (persistent workers) ===\n");
    let step = engine.train_step("bert-micro", "fused_f32", 2, 32)?;
    let world = 2;
    let n = step.n_params;
    let ranges: std::sync::Arc<[BucketRange]> =
        std::sync::Arc::from(vec![BucketRange { start: 0, end: n }]);
    let mut pool = CollectivePool::new(world, n, ranges, WireFormat::F32);
    let compute = PooledStep { step: &step, batch: &batch };
    pool.step(&params, 1.0, 1, 0, true, &compute)?; // warmup
    let (seq_min, _, _) = bench_times(5, || {
        for _ in 0..world {
            step.run(&params, &batch, 1.0).unwrap();
        }
    });
    let mut s_idx = 0usize;
    let (pool_min, _, _) = bench_times(5, || {
        s_idx += 1;
        pool.step(&params, 1.0, 1, s_idx, true, &compute).unwrap();
    });
    let mut rows = Vec::new();
    rows.push(vec![
        format!("sequential loop x{world}"),
        format!("{:.2} ms", seq_min * 1e3),
        format!("{:.0} tok/s", tokens * world as f64 / seq_min),
    ]);
    rows.push(vec![
        format!("persistent pool x{world} (+allreduce)"),
        format!("{:.2} ms", pool_min * 1e3),
        format!("{:.0} tok/s", tokens * world as f64 / pool_min),
    ]);
    println!("{}", render_table(&["executor", "min step", "throughput"],
                                &rows));
    {
        let g = pool.leader_grads();
        assert!(g.iter().all(|v| v.is_finite()),
                "pooled exchange produced non-finite grads");
    }

    // ---- flat vs hierarchical pooled exchange (train.comm_mode) ----
    // Same compiled step, same gradients, world 4 laid out as 2M2G: one
    // pool runs the flat world ring, the others the §4.4 hierarchy
    // (serialized leader, chunked pipelined chain, and the 2-level
    // reduce-scatter).  Results must agree (different summation
    // association, so allclose not bitwise); the timing split shows
    // where the bytes traveled.
    println!("=== pooled exchange: flat vs hierarchical (2M2G) ===\n");
    let topo = Topology::parse("2M2G").unwrap();
    let ranges22: std::sync::Arc<[BucketRange]> = BucketRange::even_split(n, 4);
    let mut flat_pool = CollectivePool::with_topology(
        topo, n, ranges22.clone(), WireFormat::F32, CommMode::Flat);
    // serialized leader vs the chunked pipelined chain, same hierarchy
    let mut hier_pool = CollectivePool::with_intra(
        topo, n, ranges22.clone(), WireFormat::F32, CommMode::Hierarchical,
        IntraNodeMode::Serial, n);
    let mut ring_pool = CollectivePool::with_intra(
        topo, n, ranges22.clone(), WireFormat::F32, CommMode::Hierarchical,
        IntraNodeMode::Ring, (n / 16).max(1));
    let mut rs_pool = CollectivePool::with_intra(
        topo, n, ranges22.clone(), WireFormat::F32, CommMode::Hierarchical,
        IntraNodeMode::ReduceScatter, n);
    // topk:1.0 sparsifies the leader ring without dropping anything:
    // the sums must agree with the dense schedules (to rounding — the
    // allgather-of-messages reconstruction associates differently)
    let mut sp_pool = CollectivePool::with_sparsify(
        topo, n, ranges22, WireFormat::F32, CommMode::Hierarchical,
        IntraNodeMode::Serial, n, Sparsify::TopK(1.0));
    assert!(!flat_pool.is_hierarchical() && hier_pool.is_hierarchical());
    assert!(!hier_pool.is_intra_ring() && ring_pool.is_intra_ring());
    assert!(rs_pool.is_intra_rs() && !rs_pool.is_intra_ring());
    assert!(sp_pool.sparsify_active(),
            "2M2G crosses machines: topk must be live on the leader ring");
    flat_pool.step(&params, 1.0, 1, 0, true, &compute)?; // warmup
    hier_pool.step(&params, 1.0, 1, 0, true, &compute)?;
    ring_pool.step(&params, 1.0, 1, 0, true, &compute)?;
    rs_pool.step(&params, 1.0, 1, 0, true, &compute)?;
    sp_pool.step(&params, 1.0, 1, 0, true, &compute)?;
    let mut rows = Vec::new();
    let mut idx = 0usize;
    let (flat_min, _, _) = bench_times(5, || {
        idx += 1;
        flat_pool.step(&params, 1.0, 1, idx, true, &compute).unwrap();
    });
    let mut last_hier = None;
    let (hier_min, _, _) = bench_times(5, || {
        idx += 1;
        last_hier = Some(
            hier_pool.step(&params, 1.0, 1, idx, true, &compute).unwrap());
    });
    let (ring_min, _, _) = bench_times(5, || {
        idx += 1;
        ring_pool.step(&params, 1.0, 1, idx, true, &compute).unwrap();
    });
    let (rs_min, _, _) = bench_times(5, || {
        idx += 1;
        rs_pool.step(&params, 1.0, 1, idx, true, &compute).unwrap();
    });
    let (sp_min, _, _) = bench_times(5, || {
        idx += 1;
        sp_pool.step(&params, 1.0, 1, idx, true, &compute).unwrap();
    });
    let hout = last_hier.unwrap();
    rows.push(vec!["flat ring x4".to_string(),
                   format!("{:.2} ms", flat_min * 1e3),
                   format!("{:.0} tok/s", tokens * 4.0 / flat_min)]);
    rows.push(vec!["hierarchical (serial) x4".to_string(),
                   format!("{:.2} ms", hier_min * 1e3),
                   format!("{:.0} tok/s", tokens * 4.0 / hier_min)]);
    rows.push(vec!["hierarchical (pipelined) x4".to_string(),
                   format!("{:.2} ms", ring_min * 1e3),
                   format!("{:.0} tok/s", tokens * 4.0 / ring_min)]);
    rows.push(vec!["hierarchical (rs) x4".to_string(),
                   format!("{:.2} ms", rs_min * 1e3),
                   format!("{:.0} tok/s", tokens * 4.0 / rs_min)]);
    rows.push(vec!["hierarchical (serial, topk:1.0) x4".to_string(),
                   format!("{:.2} ms", sp_min * 1e3),
                   format!("{:.0} tok/s", tokens * 4.0 / sp_min)]);
    println!("{}", render_table(&["comm mode", "min step", "throughput"],
                                &rows));
    println!("hierarchical split: pcie {:.3} ms / net {:.3} ms per step",
             hout.comm_pcie_s * 1e3, hout.comm_net_s * 1e3);
    assert!(hout.comm_net_s <= hout.comm_s + 1e-12);
    {
        // all five schedules compute the same sums (to rounding) —
        // topk:1.0 drops nothing, so its EF residual stays zero and the
        // sparse reconstruction is just another association order
        let a = flat_pool.leader_grads();
        let b = hier_pool.leader_grads();
        let c = ring_pool.leader_grads();
        let d = rs_pool.leader_grads();
        let e = sp_pool.leader_grads();
        let max_rel = a.iter().zip(b.iter())
            .chain(a.iter().zip(c.iter()))
            .chain(a.iter().zip(d.iter()))
            .chain(a.iter().zip(e.iter()))
            .map(|(x, y)| {
                let d = (x - y).abs();
                d / x.abs().max(y.abs()).max(1e-6)
            })
            .fold(0.0f32, f32::max);
        assert!(max_rel < 1e-3,
                "flat/hierarchical/pipelined/rs/topk sums diverged: \
                 {max_rel}");
    }

    let f32_speedup = tput["fused_f32"] / tput["unfused_f32"];
    println!("fused/unfused (f32): {:.2}x  — paper's fusion gain on GPU \
              was ~1.2x; on XLA-CPU the compiler already fuses the \
              unfused graph, so parity (>=0.9x) is the expected shape",
             f32_speedup);
    assert!(f32_speedup > 0.80,
            "fused variant regressed badly: {f32_speedup}");
    println!("(bf16 on CPU has no TensorCore analog — its column checks \
              numerics, not speed)");
    println!("\ntable4_throughput OK");
    Ok(())
}
