//! Bench: regenerate paper **Tables 4 & 5** — single-GPU throughput of
//! the optimization variants, two ways:
//!
//! 1. the paper's own measured device table (P100/T4/2080Ti), asserting
//!    the Table-5 speedup ratios;
//! 2. MEASURED on our substrate: wall-clock of the four AOT train-step
//!    variants (unfused_f32 / bf16 / fused_f32 / fused_bf16) on the PJRT
//!    CPU backend — the *shape* check: fused >= unfused for the same
//!    dtype (absolute CPU numbers are not comparable to GPUs).
//!
//! Run: `cargo bench --bench table4_throughput`

use bertdist::data::masking::{build_batch, MaskingConfig};
use bertdist::data::PairExample;
use bertdist::runtime::Engine;
use bertdist::simulator::{Variant, DEVICES};
use bertdist::trainer::init_params;
use bertdist::util::fmt::render_table;
use bertdist::util::stopwatch::bench_times;
use bertdist::util::Pcg64;

fn main() -> anyhow::Result<()> {
    // ---- part 1: the paper's device table ----
    println!("=== Table 4: Throughput Comparison (Tokens/s), seq 128 ===\n");
    let mut rows = Vec::new();
    for d in &DEVICES {
        rows.push(vec![
            d.name.to_string(),
            format!("{:.1}", d.non_optimized),
            format!("{:.1}", d.fp16),
            format!("{:.1}", d.fp16_fused),
        ]);
    }
    println!("{}", render_table(
        &["Device", "Non-Optimized", "FP16", "FP16 & Fused"], &rows));

    println!("=== Table 5: Speedups vs non-optimized ===\n");
    let mut rows = Vec::new();
    let paper = [(1.70, 2.05), (2.27, 2.78), (2.50, 3.05)];
    for (d, (p16, pf)) in DEVICES.iter().zip(paper) {
        let s16 = d.speedup(Variant::Fp16);
        let sf = d.speedup(Variant::Fp16Fused);
        assert!((s16 - p16).abs() < 0.01 && (sf - pf).abs() < 0.01,
                "{}: {s16}/{sf} vs paper {p16}/{pf}", d.name);
        rows.push(vec![d.name.to_string(), "1".into(),
                       format!("{s16:.2}"), format!("{sf:.2}")]);
    }
    println!("{}", render_table(
        &["Device", "Non-Optimized", "FP16", "FP16 & Fused"], &rows));

    // ---- part 2: measured on our PJRT substrate ----
    println!("=== measured on this substrate (bert-micro, PJRT-CPU) ===\n");
    let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
    let model = engine.model("bert-micro")?;
    let mut rng = Pcg64::new(5);
    let params = init_params(&model.layout, &mut rng);
    let ex = PairExample {
        tokens_a: (10..24).collect(),
        tokens_b: (30..42).collect(),
        is_next: true,
    };
    let cfg = MaskingConfig { vocab_size: model.config.vocab_size as u32,
                              ..Default::default() };
    let batch = build_batch(&[ex.clone(), ex], 32, &cfg, &mut rng);
    let tokens = (batch.batch * batch.seq) as f64;

    let mut rows = Vec::new();
    let mut tput = std::collections::BTreeMap::new();
    for variant in ["unfused_f32", "bf16", "fused_f32", "fused_bf16"] {
        let step = engine.train_step("bert-micro", variant, 2, 32)?;
        // warmup
        step.run(&params, &batch, 1.0)?;
        let (min, mean, _max) =
            bench_times(10, || { step.run(&params, &batch, 1.0).unwrap(); });
        let t = tokens / min;
        tput.insert(variant.to_string(), t);
        rows.push(vec![
            variant.to_string(),
            format!("{:.2} ms", min * 1e3),
            format!("{:.2} ms", mean * 1e3),
            format!("{:.0} tok/s", t),
        ]);
    }
    println!("{}", render_table(
        &["variant", "min step", "mean step", "throughput"], &rows));

    let f32_speedup = tput["fused_f32"] / tput["unfused_f32"];
    println!("fused/unfused (f32): {:.2}x  — paper's fusion gain on GPU \
              was ~1.2x; on XLA-CPU the compiler already fuses the \
              unfused graph, so parity (>=0.9x) is the expected shape",
             f32_speedup);
    assert!(f32_speedup > 0.80,
            "fused variant regressed badly: {f32_speedup}");
    println!("(bf16 on CPU has no TensorCore analog — its column checks \
              numerics, not speed)");
    println!("\ntable4_throughput OK");
    Ok(())
}
