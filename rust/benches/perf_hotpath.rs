//! Perf bench: L3 hot-path microbenchmarks for the EXPERIMENTS.md §Perf
//! iteration loop — allreduce bandwidth, the persistent-pool vs
//! per-step-spawn step executor comparison (ISSUE 1 tentpole), the
//! data-bound prefetch-vs-synchronous input pipeline (ISSUE 3 tentpole,
//! emitted to BENCH_input_pipeline.json), batch assembly, bucket
//! planning, LAMB host step, f16 conversion throughput, the elastic
//! checkpoint verify/restore path (ISSUE 6, emitted to
//! BENCH_elastic.json), the in-proc vs loopback-socket transport cost
//! (ISSUE 7, emitted to BENCH_transport.json), the socket-world
//! rejoin/re-admission cost with and without the authenticated
//! handshake (ISSUE 8, emitted to BENCH_rejoin.json), the 2-level
//! reduce-scatter vs serialized-leader exchange (ISSUE 9, emitted to
//! BENCH_exchange_rs.json), the top-k sparsified network ring — select
//! cost, sparse-vs-dense pooled exchange, netsim ratio sweep (ISSUE 10,
//! emitted to BENCH_sparsify.json) — and the end-to-end PJRT step
//! overhead breakdown.
//!
//! Run: `cargo bench --bench perf_hotpath`
//!
//! Quick mode (CI smoke, see `scripts/bench_smoke.sh`): set `BENCH_QUICK=1`
//! to shrink payloads/iterations and emit machine-readable rows to
//! `BENCH_hotpath.json` (override the path with `BENCH_JSON_OUT`), so the
//! perf trajectory can be tracked across PRs.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use bertdist::collectives::pool::{CollectivePool, CommMode, IntraNodeMode,
                                  MicroStats, RankCompute, WireFormat};
use bertdist::topology::Topology;
use bertdist::collectives::ring::ring_allreduce_inplace;
use bertdist::collectives::socket::write_stamp;
use bertdist::collectives::{CollectiveGroup, InProcTransport,
                            RendezvousStamp, SocketTransport};
use bertdist::data::corpus::SyntheticCorpus;
use bertdist::data::masking::{build_batch, Batch, MaskingConfig};
use bertdist::data::prefetch::{BatchCursor, Prefetcher};
use bertdist::data::{build_shards, PairExample, ShardedDataset, Vocab};
use bertdist::grad::sparsify::{top_k_into, Sparsify};
use bertdist::grad::{build_buckets, Bucket, BucketRange, GradAccumulator};
use bertdist::half::F16;
use bertdist::jsonlite::Json;
use bertdist::model::BertConfig;
use bertdist::netsim;
use bertdist::optimizer::{lamb_step, OptHyper, OptState};
use bertdist::runtime::Engine;
use bertdist::trainer::{allreduce_buckets, init_params};
use bertdist::util::fmt::render_table;
use bertdist::util::stopwatch::bench_times;
use bertdist::util::{Pcg64, Stopwatch};

/// One table row + its machine-readable twin.
struct Rows {
    table: Vec<Vec<String>>,
    json: Vec<(String, f64, String)>, // (name, min ms, rate text)
}

impl Rows {
    fn push(&mut self, name: &str, min_s: f64, rate: String) {
        self.table.push(vec![
            name.to_string(),
            format!("{:.3} ms", min_s * 1e3),
            rate.clone(),
        ]);
        self.json.push((name.to_string(), min_s * 1e3, rate));
    }
}

/// Trivial deterministic compute for pool dispatch benchmarks: fills the
/// gradient vector without touching XLA.
struct FillCompute {
    n: usize,
}

impl RankCompute for FillCompute {
    fn micro(&self, rank: usize, _step: usize, micro: usize, _p: &[f32],
             _scale: f32, out: &mut Vec<f32>) -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        out.fill((rank + micro + 1) as f32);
        Ok(MicroStats::default())
    }
}

/// How the data-bound bench feeds its compute workers.
enum InputFeed<'a> {
    Prefetch(Prefetcher<'a>),
    Sync(Vec<Mutex<(BatchCursor<'a>, Batch)>>),
}

/// Data-bound [`RankCompute`]: pull the rank's next masked batch (from
/// the prefetch ring or built in-line), then burn a fixed amount of
/// deterministic "compute" over it.  Gradients are a tiny checksum fill
/// so the exchange stays negligible — the bench isolates the input side.
struct InputBound<'a> {
    feed: InputFeed<'a>,
    work: usize,
}

/// Deterministic pseudo-compute proportional to `work`, reading the
/// batch so the build cannot be optimized away.
fn burn(b: &Batch, work: usize) -> f32 {
    let ids = &b.input_ids;
    let mut acc = 0i64;
    for i in 0..work {
        acc = acc
            .wrapping_mul(6364136223846793005)
            .wrapping_add(ids[i % ids.len()] as i64);
    }
    std::hint::black_box((acc & 0xFFFF) as f32 * 1e-6)
}

impl RankCompute for InputBound<'_> {
    fn micro(&self, rank: usize, _step: usize, _micro: usize, _p: &[f32],
             _scale: f32, out: &mut Vec<f32>) -> anyhow::Result<MicroStats> {
        let (checksum, stall_s) = match &self.feed {
            InputFeed::Prefetch(p) => {
                let (b, stall_s) = p.pop(rank)?;
                let c = burn(&b, self.work);
                p.recycle(rank, b);
                (c, stall_s)
            }
            InputFeed::Sync(lanes) => {
                let mut lane = lanes[rank].lock().expect("bench lane");
                let t0 = Instant::now();
                let (cursor, buf) = &mut *lane;
                cursor.fill_next(buf);
                let stall_s = t0.elapsed().as_secs_f64();
                (burn(buf, self.work), stall_s)
            }
        };
        out.resize(16 * 1024, 0.0);
        out.fill(checksum);
        Ok(MicroStats { input_stall_s: stall_s, ..Default::default() })
    }
}

fn even_buckets(n: usize, pieces: usize) -> Vec<Bucket> {
    BucketRange::even_split(n, pieces)
        .iter()
        .enumerate()
        .map(|(i, r)| Bucket {
            start: r.start,
            end: r.end,
            tensors: Vec::new(),
            order: i,
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    println!(
        "=== perf_hotpath: coordinator hot-path microbenches{} ===\n",
        if quick { " (quick mode)" } else { "" }
    );
    let mut rows = Rows { table: Vec::new(), json: Vec::new() };

    // ---- threaded ring allreduce bandwidth (the §4.4 data path) ----
    let payload_bytes = if quick { 1 << 20 } else { 16 << 20 };
    let elems = payload_bytes / 4;
    for world in [2usize, 4] {
        let (min, _, _) = bench_times(3, || {
            let handles = CollectiveGroup::new(world);
            let joins: Vec<_> = handles
                .into_iter()
                .map(|mut h| {
                    std::thread::spawn(move || {
                        let mut buf = vec![1.0f32; elems];
                        h.allreduce(&mut buf);
                    })
                })
                .collect();
            for j in joins {
                j.join().unwrap();
            }
        });
        rows.push(
            &format!("threaded allreduce x{world} ({} MiB)",
                     payload_bytes >> 20),
            min,
            format!("{:.2} GB/s alg", elems as f64 * 4.0 / min / 1e9),
        );
    }

    // ---- persistent pool vs per-step spawn (ISSUE 1 tentpole) ----
    // Small payloads over many repeated steps: the per-step thread /
    // channel / allocation churn of the old hot loop is what the pool
    // amortizes away.
    let world = 4;
    let n = if quick { 16 * 1024 } else { 64 * 1024 };
    let steps = if quick { 20 } else { 40 };
    let buckets = even_buckets(n, 4);
    let fill = FillCompute { n };
    let grads_proto = vec![1.0f32; n];
    let (spawn_min, _, _) = bench_times(3, || {
        // the OLD path: fresh CollectiveGroup + per-rank spawn per step
        let mut accs: Vec<GradAccumulator> =
            (0..world).map(|_| GradAccumulator::new(n)).collect();
        for _ in 0..steps {
            for a in accs.iter_mut() {
                a.reset();
                a.add(&grads_proto);
            }
            allreduce_buckets(&mut accs, &buckets);
        }
    });
    let mut pool =
        CollectivePool::new(world, n, BucketRange::even_split(n, 4), WireFormat::F32);
    // warmup (first step populates the recycled wire buffers)
    pool.step(&[], 1.0, 1, 0, true, &fill)?;
    let (pool_min, _, _) = bench_times(3, || {
        for s in 0..steps {
            pool.step(&[], 1.0, 1, s, true, &fill).unwrap();
        }
    });
    let speedup = spawn_min / pool_min;
    rows.push(
        &format!("per-step spawn allreduce x{world} ({steps} steps)"),
        spawn_min,
        format!("{:.1} steps/s", steps as f64 / spawn_min),
    );
    rows.push(
        &format!("persistent pool allreduce x{world} ({steps} steps)"),
        pool_min,
        format!("{:.1} steps/s ({speedup:.2}x vs spawn)",
                steps as f64 / pool_min),
    );
    println!("pool vs spawn @ world={world}, {} KiB, {steps} steps: \
              {speedup:.2}x", n * 4 / 1024);
    assert!(
        speedup >= 2.0,
        "persistent pool must give >=2x amortized step throughput over \
         per-step spawn at world=4 (got {speedup:.2}x)"
    );

    // ---- f16 wire variant of the pooled exchange ----
    let mut pool16 =
        CollectivePool::new(world, n, BucketRange::even_split(n, 4), WireFormat::F16);
    pool16.step(&[], 1.0, 1, 0, true, &fill)?;
    let (p16_min, _, _) = bench_times(3, || {
        for s in 0..steps {
            pool16.step(&[], 1.0, 1, s, true, &fill).unwrap();
        }
    });
    rows.push(
        &format!("persistent pool f16 wire x{world} ({steps} steps)"),
        p16_min,
        format!("{:.1} steps/s", steps as f64 / p16_min),
    );

    // ---- flat vs hierarchical pooled exchange (fixed 2M2G world) ----
    // The same synthetic world through both `train.comm_mode` schedules;
    // emitted to BENCH_hierarchical.json so the new path's perf
    // trajectory is tracked across PRs alongside BENCH_hotpath.json.
    let topo22 = Topology::parse("2M2G").unwrap();
    let mut hier_rows: Vec<(String, f64, String)> = Vec::new();
    for (label, mode) in [("flat", CommMode::Flat),
                          ("hierarchical", CommMode::Hierarchical)] {
        let mut p = CollectivePool::with_topology(
            topo22, n, BucketRange::even_split(n, 4), WireFormat::F32,
            mode);
        assert_eq!(p.is_hierarchical(), mode == CommMode::Hierarchical);
        p.step(&[], 1.0, 1, 0, true, &fill)?; // warmup
        let (hmin, _, _) = bench_times(3, || {
            for s in 0..steps {
                p.step(&[], 1.0, 1, s, true, &fill).unwrap();
            }
        });
        let name = format!("pooled {label} exchange 2M2G ({steps} steps)");
        let rate = format!("{:.1} steps/s", steps as f64 / hmin);
        rows.push(&name, hmin, rate.clone());
        hier_rows.push((label.to_string(), hmin * 1e3, rate));
    }

    // ---- serialized vs chunked-pipelined intra-node exchange (2M4G) --
    // ISSUE 5 tentpole: under `intra_node = serial` the node leader
    // pays (g-1) whole-bucket adds + (g-1) whole-bucket broadcast
    // copies on ONE thread per bucket; the pipelined chain distributes
    // that work across the member comm workers and overlaps it with
    // the leader ring.  g = 4 here, so 3 members share the load.
    let topo24 = Topology::parse("2M4G").unwrap();
    let n_intra = if quick { 256 * 1024 } else { 1 << 21 };
    let steps_intra = if quick { 10 } else { 25 };
    let chunk_intra = n_intra / 32; // 4 buckets -> 8 chunks per bucket
    let fill_intra = FillCompute { n: n_intra };
    let mut intra_rows: Vec<(String, f64, String)> = Vec::new();
    for (label, intra) in [("serial", IntraNodeMode::Serial),
                           ("ring", IntraNodeMode::Ring),
                           ("rs", IntraNodeMode::ReduceScatter)] {
        let mut p = CollectivePool::with_intra(
            topo24, n_intra, BucketRange::even_split(n_intra, 4),
            WireFormat::F32, CommMode::Hierarchical, intra, chunk_intra);
        assert!(p.is_hierarchical());
        assert_eq!(p.is_intra_ring(), intra == IntraNodeMode::Ring);
        assert_eq!(p.is_intra_rs(), intra == IntraNodeMode::ReduceScatter);
        p.step(&[], 1.0, 1, 0, true, &fill_intra)?; // warmup
        let (imin, _, _) = bench_times(3, || {
            for s in 0..steps_intra {
                p.step(&[], 1.0, 1, s + 1, true, &fill_intra).unwrap();
            }
        });
        let name =
            format!("intra-node {label} exchange 2M4G ({steps_intra} steps)");
        let rate = format!("{:.1} steps/s", steps_intra as f64 / imin);
        rows.push(&name, imin, rate.clone());
        intra_rows.push((label.to_string(), imin * 1e3, rate));
    }
    let (serial_min, ring_min) =
        (intra_rows[0].1 / 1e3, intra_rows[1].1 / 1e3);
    let intra_speedup = serial_min / ring_min;
    println!("intra-node pipelined vs serialized @ 2M4G, {} KiB, chunk \
              {} KiB: {intra_speedup:.2}x",
             n_intra * 4 / 1024, chunk_intra * 4 / 1024);
    // The win needs the member comm workers to actually run in
    // parallel; on a core-starved box the chain physically cannot
    // overlap, so only report there instead of failing on scheduling
    // noise (same policy as the prefetch-vs-sync assertion).
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    if cores >= topo24.world_size() {
        assert!(
            ring_min < serial_min,
            "chunked pipelined intra-node exchange must beat the \
             serialized leader gather at g=4 (serial {serial_min:.4}s vs \
             ring {ring_min:.4}s on {cores} cores)"
        );
    } else {
        println!(
            "note: only {cores} cores — skipping the pipelined-beats-\
             serialized assertion (needs {})",
            topo24.world_size()
        );
    }

    // ---- 2-level reduce-scatter vs serialized leader (ISSUE 9) ----
    // Same 2M4G world: the rs schedule moves O(n/g) bytes per link
    // where the serialized leader funnels O(n) through one thread, so
    // its wall clock must win whenever the node is wide.
    let rs_min = intra_rows[2].1 / 1e3;
    let rs_speedup = serial_min / rs_min;
    println!("intra-node reduce-scatter vs serialized @ 2M4G, {} KiB: \
              {rs_speedup:.2}x",
             n_intra * 4 / 1024);
    if cores >= topo24.world_size() {
        assert!(
            rs_min < serial_min,
            "2-level reduce-scatter exchange must beat the serialized \
             leader gather at g=4 (serial {serial_min:.4}s vs rs \
             {rs_min:.4}s on {cores} cores)"
        );
    } else {
        println!(
            "note: only {cores} cores — skipping the rs-beats-serialized \
             assertion (needs {})",
            topo24.world_size()
        );
    }

    // ---- in-process channels vs loopback sockets (ISSUE 7) ----
    // The pluggable Transport prices the process boundary: the SAME
    // flat world=2 pooled exchange once over in-memory channels and
    // once as two single-rank "processes" (threads here, each owning
    // its own SocketTransport) over loopback TCP.  Socket ring hops
    // bill to the network phase, so the mean per-bucket net latency
    // falls out of the same StepOutcome counters the trainer reports.
    let n_net = if quick { 64 * 1024 } else { 512 * 1024 };
    let steps_net = if quick { 10 } else { 25 };
    let nbuckets_net = 4usize;
    let topo_net = Topology::parse("1M2G").unwrap();
    let ranges_net = BucketRange::even_split(n_net, nbuckets_net);
    let mut transport_rows: Vec<(String, f64, String, f64)> = Vec::new();
    {
        let fill_net = FillCompute { n: n_net };
        let mut t = InProcTransport::new(2);
        let mut p = CollectivePool::with_transport(
            topo_net, n_net, ranges_net.clone(), WireFormat::F32,
            CommMode::Flat, IntraNodeMode::Auto, 1 << 16, Sparsify::None,
            &mut t)?;
        p.step(&[], 1.0, 1, 0, true, &fill_net)?; // warmup
        let (tmin, _, _) = bench_times(3, || {
            for s in 0..steps_net {
                p.step(&[], 1.0, 1, s + 1, true, &fill_net).unwrap();
            }
        });
        let rate = format!("{:.1} steps/s", steps_net as f64 / tmin);
        rows.push(
            &format!("transport in-proc exchange x2 ({steps_net} steps)"),
            tmin, rate.clone());
        transport_rows.push(("inproc".to_string(), tmin * 1e3, rate, 0.0));
    }
    {
        let peers: Vec<String> = (0..2)
            .map(|_| {
                // probe a free loopback port; with_hosts rebinds it
                let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
                let a = l.local_addr().unwrap().to_string();
                drop(l);
                a
            })
            .collect();
        let barrier = std::sync::Barrier::new(2);
        let reps = 3;
        let results: Vec<(f64, f64)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2)
                .map(|pi| {
                    let peers = peers.clone();
                    let ranges = ranges_net.clone();
                    let barrier = &barrier;
                    scope.spawn(move || {
                        let mut t = SocketTransport::with_hosts(
                            2, &peers[pi], peers.clone(), 30.0)
                            .expect("socket transport");
                        let fill = FillCompute { n: n_net };
                        let mut p = CollectivePool::with_transport(
                            topo_net, n_net, ranges, WireFormat::F32,
                            CommMode::Flat, IntraNodeMode::Auto, 1 << 16,
                            Sparsify::None, &mut t)
                            .expect("socket pool");
                        p.step(&[], 1.0, 1, 0, true, &fill)
                            .expect("warmup");
                        // barrier-fenced reps so both "processes" time
                        // the same synchronized window; keep the best
                        let mut best = f64::INFINITY;
                        let mut best_net = 0.0;
                        for _ in 0..reps {
                            barrier.wait();
                            let t0 = Instant::now();
                            let mut net = 0.0;
                            for s in 0..steps_net {
                                let out = p
                                    .step(&[], 1.0, 1, s + 1, true, &fill)
                                    .expect("socket step");
                                net += out.bucket_net_s.iter()
                                    .sum::<f64>();
                            }
                            barrier.wait();
                            let wall = t0.elapsed().as_secs_f64();
                            if wall < best {
                                best = wall;
                                best_net = net;
                            }
                        }
                        (best, best_net)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let smin = results.iter().map(|r| r.0).fold(0.0f64, f64::max);
        let net_bucket_ms =
            results[0].1 / (steps_net * nbuckets_net) as f64 * 1e3;
        let rate = format!("{:.1} steps/s, net/bucket {net_bucket_ms:.3} ms",
                           steps_net as f64 / smin);
        rows.push(
            &format!("transport loopback-socket exchange x2 \
                      ({steps_net} steps)"),
            smin, rate.clone());
        let inproc_s = transport_rows[0].1 / 1e3;
        println!("transport loopback socket vs in-proc @ world=2, {} KiB: \
                  {:.2}x the in-proc wall, per-bucket net \
                  {net_bucket_ms:.3} ms",
                 n_net * 4 / 1024, smin / inproc_s.max(1e-12));
        transport_rows.push(("socket_loopback".to_string(), smin * 1e3,
                             rate, net_bucket_ms));
    }

    // ---- rejoin: socket-world re-admission cost (ISSUE 8) ----
    // Prices the grow-back path: forming a fresh 2-process socket
    // world at a stamped rendezvous (epoch 0), tearing it down and
    // re-forming it at a republished epoch (what the supervised
    // rejoin does at a restart boundary), and the same join with the
    // authenticated v2 handshake — the per-connection MAC cost.
    let n_rejoin = if quick { 16 * 1024 } else { 128 * 1024 };
    let ranges_rejoin = BucketRange::even_split(n_rejoin, 4);
    let rejoin_dir = std::env::temp_dir()
        .join(format!("bertdist_bench_rejoin_{}", std::process::id()));
    std::fs::create_dir_all(&rejoin_dir)?;
    let rdv_s = rejoin_dir.join("rdv.txt").to_str().unwrap().to_string();
    let rejoin_run_id = [0x42u8; 8];
    // One timed join: republish the rendezvous at `epoch`, then both
    // "processes" (threads) adopt it, build the pool (links dial and
    // shake hands here), and run one step.  Returns the wall time of
    // the whole world formation.
    let join_world = |epoch: u64, key: Option<Vec<u8>>| -> f64 {
        let _ = std::fs::remove_file(&rdv_s);
        write_stamp(&rdv_s, rejoin_run_id, epoch).expect("stamp");
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let ranges = ranges_rejoin.clone();
                    let key = key.clone();
                    let rdv_s = rdv_s.clone();
                    scope.spawn(move || {
                        let stamp = RendezvousStamp {
                            run_id: rejoin_run_id,
                            min_generation: epoch,
                            window_s: None,
                        };
                        let mut t =
                            SocketTransport::with_rendezvous_stamped(
                                2, "127.0.0.1:0", &rdv_s, 2, 30.0,
                                Some(&stamp))
                            .expect("rejoin rendezvous");
                        if let Some(k) = &key {
                            t.set_auth(k, [epoch as u8; 8]);
                        }
                        let fill = FillCompute { n: n_rejoin };
                        let mut p = CollectivePool::with_transport(
                            topo_net, n_rejoin, ranges, WireFormat::F32,
                            CommMode::Flat, IntraNodeMode::Auto, 1 << 16,
                            Sparsify::None, &mut t)
                            .expect("rejoin pool");
                        p.step(&[], 1.0, 1, 0, true, &fill)
                            .expect("rejoin step");
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        t0.elapsed().as_secs_f64()
    };
    let best_of = |epoch0: u64, key: Option<&[u8]>| -> f64 {
        (0..2)
            .map(|i| join_world(epoch0 + i, key.map(|k| k.to_vec())))
            .fold(f64::INFINITY, f64::min)
    };
    let mut rejoin_rows: Vec<(String, f64)> = Vec::new();
    let t_join = best_of(0, None);
    rows.push("rejoin: fresh rendezvous world + first step (x2)", t_join,
              String::new());
    rejoin_rows.push(("join_fresh".to_string(), t_join * 1e3));
    let t_re = best_of(10, None);
    rows.push("rejoin: republished-epoch world + first step (x2)", t_re,
              String::new());
    rejoin_rows.push(("rejoin_republished".to_string(), t_re * 1e3));
    let t_auth = best_of(20, Some(b"bench-key"));
    rows.push("rejoin: authenticated (--net-key) world + first step (x2)",
              t_auth, String::new());
    rejoin_rows.push(("join_authenticated".to_string(), t_auth * 1e3));
    println!("rejoin @ world=2: fresh {:.1} ms, republished epoch {:.1} \
              ms, authenticated {:.1} ms",
             t_join * 1e3, t_re * 1e3, t_auth * 1e3);
    let _ = std::fs::remove_dir_all(&rejoin_dir);

    // ---- top-k sparsified network ring (ISSUE 10) ----
    // The three costs of `train.sparsify = topk`: the O(n) magnitude
    // select (top_k_into over recycled scratch), the executed sparse
    // exchange vs the dense ring at 2M1G (a flat 2-rank world whose
    // single ring link crosses machines, so the sparsifier is ACTIVE),
    // and the netsim-priced ratio sweep whose interior optimum lands in
    // BENCH_sparsify.json.
    let n_sp = if quick { 64 * 1024 } else { 512 * 1024 };
    let steps_sp = if quick { 10 } else { 25 };
    let topo_sp = Topology::parse("2M1G").unwrap();
    let ranges_sp = BucketRange::even_split(n_sp, 4);
    let sel_grads: Vec<f32> = {
        let mut rng = Pcg64::new(0x5A);
        (0..n_sp).map(|_| rng.next_f32() - 0.5).collect()
    };
    let k_sel = (n_sp / 100).max(1);
    let (mut sel_order, mut sel_idx, mut sel_val) =
        (Vec::new(), Vec::new(), Vec::new());
    let (sel_min, _, _) = bench_times(if quick { 5 } else { 20 }, || {
        top_k_into(&sel_grads, k_sel, &mut sel_order, &mut sel_idx,
                   &mut sel_val);
        std::hint::black_box(sel_idx.len());
    });
    rows.push(
        &format!("top-k select 1% of {} KiB grads", n_sp * 4 / 1024),
        sel_min,
        format!("{:.1} Melem/s", n_sp as f64 / sel_min / 1e6),
    );
    // (mode, min ms, modeled per-rank network bytes per step)
    let mut sparsify_rows: Vec<(String, f64, f64)> = Vec::new();
    for (label, sp) in [("dense", Sparsify::None),
                        ("topk_1.0", Sparsify::TopK(1.0)),
                        ("topk_0.01", Sparsify::TopK(0.01))] {
        let fill = FillCompute { n: n_sp };
        let mut t = InProcTransport::new(2);
        let mut p = CollectivePool::with_transport(
            topo_sp, n_sp, ranges_sp.clone(), WireFormat::F32,
            CommMode::Flat, IntraNodeMode::Auto, 1 << 16, sp, &mut t)?;
        p.step(&[], 1.0, 1, 0, true, &fill)?; // warmup
        let (tmin, _, _) = bench_times(3, || {
            for s in 0..steps_sp {
                p.step(&[], 1.0, 1, s + 1, true, &fill).unwrap();
            }
        });
        // per-rank network bytes each step, by the wire's own
        // accounting: dense ring 2(w-1)/w of the payload; sparse
        // allgather (w-1) messages of k 8B entries + 17B frame header
        let w = topo_sp.world_size();
        let wire_bytes: f64 = ranges_sp
            .iter()
            .map(|r| {
                let len = r.end - r.start;
                match sp {
                    Sparsify::None => {
                        2.0 * (w - 1) as f64 / w as f64 * (len * 4) as f64
                    }
                    Sparsify::TopK(_) => {
                        (w - 1) as f64
                            * (sp.entries(len) as f64
                                * netsim::SPARSE_ENTRY_BYTES
                                + netsim::SPARSE_FRAME_OVERHEAD_BYTES)
                    }
                }
            })
            .sum();
        rows.push(
            &format!("sparsify {label} pooled x2 2M1G ({steps_sp} steps)"),
            tmin,
            format!("{:.1} steps/s, {:.0} KiB/step net",
                    steps_sp as f64 / tmin, wire_bytes / 1024.0),
        );
        sparsify_rows.push((label.to_string(), tmin * 1e3, wire_bytes));
    }
    // topk:1.0 pays the 8B/entry index tax over the dense wire — the
    // accounting must show it, and the 1% ratio must undercut dense
    assert!(sparsify_rows[1].2 > sparsify_rows[0].2,
            "topk:1.0 must cost MORE wire than dense ({:?})",
            sparsify_rows);
    assert!(sparsify_rows[2].2 < sparsify_rows[0].2 / 10.0,
            "topk:0.01 must cut the wire >10x ({:?})", sparsify_rows);
    // netsim ratio sweep: wire time grows with the ratio, EF staleness
    // shrinks with it — the effective cost bottoms out strictly inside
    // the grid (the acceptance optimum BENCH_sparsify.json carries)
    let sp_grid: Vec<f64> = (0..40)
        .map(|i| 10f64.powf(-4.0 + i as f64 * 4.0 / 39.0))
        .collect();
    let sp_elems = 336_226_108usize / 26; // one of ~26 BERT-large buckets
    let sp_machines = 4usize;
    let (sp_pts, sp_best) = netsim::sparse_ratio_sweep(
        sp_machines, sp_elems, netsim::Fabric::paper().network, 0.05,
        &sp_grid);
    assert!(sp_best.ratio > sp_grid[0] && sp_best.ratio < 1.0,
            "sparse ratio optimum must be interior, got {sp_best:?}");
    let sp_dense_s = netsim::ring_allreduce_time(
        sp_machines, (sp_elems * 4) as f64,
        netsim::Fabric::paper().network);
    assert!(sp_pts.last().unwrap().wire_s > sp_dense_s,
            "priced topk:1.0 must exceed the dense ring");
    println!("sparsify model @ {sp_machines}M, {:.1}M elems: optimum \
              topk:{:.4} ({} entries, {:.2}x inflation), dense ring \
              {:.1} ms vs topk:1.0 {:.1} ms",
             sp_elems as f64 / 1e6, sp_best.ratio, sp_best.entries,
             sp_best.inflation, sp_dense_s * 1e3,
             sp_pts.last().unwrap().wire_s * 1e3);

    // ---- single-threaded reference allreduce ----
    let (min, _, _) = bench_times(3, || {
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; elems / 4])
            .collect();
        ring_allreduce_inplace(&mut bufs);
    });
    rows.push(
        &format!("reference allreduce x4 ({:.2} MiB each)",
                 payload_bytes as f64 / 4.0 / (1 << 20) as f64),
        min,
        String::new(),
    );

    // ---- batch assembly (masking pipeline) ----
    let cfg = MaskingConfig::default();
    let exs: Vec<PairExample> = (0..8)
        .map(|i| PairExample {
            tokens_a: (0..60).map(|t| 10 + (t + i) % 8000).collect(),
            tokens_b: (0..60).map(|t| 10 + (t * 2 + i) % 8000).collect(),
            is_next: i % 2 == 0,
        })
        .collect();
    let mut rng = Pcg64::new(1);
    let (min, _, _) = bench_times(if quick { 10 } else { 50 }, || {
        std::hint::black_box(build_batch(&exs, 128, &cfg, &mut rng));
    });
    rows.push("batch assembly 8x128 (mask+pack)", min,
              format!("{:.1} Mtok/s", 8.0 * 128.0 / min / 1e6));

    // ---- data-bound hot path: prefetch ring vs synchronous input ----
    // (ISSUE 3 tentpole.)  A masking-heavy input stream against a fixed
    // synthetic per-micro compute, both run through the REAL pooled step
    // executor: the synchronous path pays build + compute in series on
    // every micro, the depth-2 prefetch ring builds batch i+1 on the
    // producer thread while the worker computes batch i.  Identical
    // batch streams (bitwise — asserted in tests/zero_copy_hotpath.rs);
    // only the schedule differs.
    {
        let dir = std::env::temp_dir().join("bertdist_bench_input");
        let _ = std::fs::remove_dir_all(&dir);
        let docs = SyntheticCorpus::new(17, 1500).documents(16, 8, 40);
        let vocab = Vocab::from_documents(&docs, 4096);
        build_shards(&docs, &vocab, 4, &dir, "train", 11)?;
        let world = 2;
        let datasets: Vec<ShardedDataset> = (0..world)
            .map(|r| ShardedDataset::open(&dir, "train", r, world))
            .collect::<anyhow::Result<_>>()?;
        let mcfg = MaskingConfig {
            vocab_size: vocab.len() as u32,
            max_predictions: 80, // masking-heavy (§3.1 phase-2 budget)
            ..Default::default()
        };
        let (dbatch, dseq) = (8usize, 128usize);
        let accum = 2usize;
        let psteps = if quick { 10 } else { 30 };
        let n_grad = 16 * 1024;
        // Per-micro synthetic compute sized in the same ballpark as one
        // masked batch build, the regime where overlap pays.
        let work = if quick { 400_000 } else { 1_200_000 };

        let mut section: Vec<(String, f64, f64, f64, f64)> = Vec::new();
        for (mode, depth) in [("sync", 0usize), ("prefetch", 2usize)] {
            let (wall, compute_s, stall_s) = std::thread::scope(
                |scope| -> anyhow::Result<(f64, f64, f64)> {
                    let feed = if depth == 0 {
                        InputFeed::Sync(
                            datasets
                                .iter()
                                .map(|d| {
                                    Mutex::new((
                                        BatchCursor::new(d, mcfg.clone(),
                                                         3, dbatch, dseq,
                                                         0),
                                        Batch::zeros(dbatch, dseq),
                                    ))
                                })
                                .collect(),
                        )
                    } else {
                        InputFeed::Prefetch(Prefetcher::spawn(
                            scope, &datasets, &mcfg, 3, dbatch, dseq, 0,
                            depth))
                    };
                    let compute = InputBound { feed, work };
                    let mut pool = CollectivePool::new(
                        world, n_grad, BucketRange::even_split(n_grad, 2),
                        WireFormat::F32);
                    pool.step(&[], 1.0, accum, 0, true, &compute)?; // warmup
                    let t0 = Instant::now();
                    let mut compute_s = 0.0;
                    let mut stall_s = 0.0;
                    for s in 0..psteps {
                        let out = pool.step(&[], 1.0, accum, s + 1, true,
                                            &compute)?;
                        compute_s += out.compute_s;
                        stall_s += out.input_stall_s;
                    }
                    Ok((t0.elapsed().as_secs_f64(), compute_s, stall_s))
                },
            )?;
            let toks = (dbatch * dseq * accum * world * psteps) as f64;
            let data_eff = if compute_s > 0.0 {
                (1.0 - stall_s / compute_s).clamp(0.0, 1.0)
            } else {
                1.0
            };
            rows.push(
                &format!("data-bound pooled step, {mode} input \
                          ({psteps} steps)"),
                wall / psteps as f64,
                format!("{:.0} tok/s stall={:.3}s data_eff={:.0}%",
                        toks / wall, stall_s, data_eff * 100.0),
            );
            section.push((mode.to_string(), wall, toks / wall, stall_s,
                          data_eff));
        }
        let (sync_wall, pf_wall) = (section[0].1, section[1].1);
        let speedup = sync_wall / pf_wall;
        println!("prefetch vs sync input @ world={world}, \
                  {dbatch}x{dseq} k={accum}, {psteps} steps: \
                  {speedup:.2}x (stall {:.3}s -> {:.3}s)",
                 section[0].3, section[1].3);
        // The wall-clock win requires the producers to actually run in
        // parallel with the compute workers (2 workers + 2 producers):
        // on a core-starved or heavily loaded box the overlap physically
        // cannot happen, so only report there instead of failing the
        // whole bench on scheduling noise.
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        if cores >= 2 * world {
            assert!(
                pf_wall < sync_wall,
                "prefetch+recycling must beat the synchronous input path \
                 on a data-bound workload (sync {sync_wall:.3}s vs \
                 prefetch {pf_wall:.3}s on {cores} cores)"
            );
            assert!(
                section[1].3 <= section[0].3,
                "prefetch must not increase the measured input stall \
                 ({:.3}s -> {:.3}s)",
                section[0].3, section[1].3
            );
        } else {
            println!(
                "note: only {cores} cores — skipping the prefetch-beats-\
                 sync assertions (needs {} to overlap)",
                2 * world
            );
        }

        // machine-readable rows for cross-PR tracking
        if quick || std::env::var("BENCH_JSON_OUT").is_ok() {
            let path = std::env::var("BENCH_INPUT_JSON_OUT")
                .unwrap_or_else(|_| "BENCH_input_pipeline.json".to_string());
            let entries: Vec<Json> = section
                .iter()
                .map(|(mode, wall, tps, stall, eff)| {
                    let mut m = BTreeMap::new();
                    m.insert("mode".to_string(), Json::Str(mode.clone()));
                    m.insert("wall_ms".to_string(), Json::Num(wall * 1e3));
                    m.insert("tokens_per_s".to_string(), Json::Num(*tps));
                    m.insert("input_stall_s".to_string(),
                             Json::Num(*stall));
                    m.insert("data_efficiency".to_string(),
                             Json::Num(*eff));
                    Json::Obj(m)
                })
                .collect();
            let mut root = BTreeMap::new();
            root.insert("bench".to_string(),
                        Json::Str("input_pipeline".to_string()));
            root.insert("speedup".to_string(), Json::Num(speedup));
            root.insert("rows".to_string(), Json::Arr(entries));
            std::fs::write(&path, Json::Obj(root).to_string())?;
            println!("wrote {path}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- checkpoint save: sync on-loop write vs async snapshot
    //      (ISSUE 4: periodic saving must be off the hot loop) ----
    {
        use bertdist::checkpoint::{v2_file_len, AsyncCheckpointWriter,
                                   Checkpoint};
        let n = if quick { 1 << 20 } else { 1 << 23 };
        let dir = std::env::temp_dir().join("bertdist_bench_ckpt");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir)?;
        let mut state = Checkpoint::new(n);
        for (i, x) in state.params.iter_mut().enumerate() {
            *x = i as f32 * 1e-6;
        }
        let file_bytes = v2_file_len(n) as f64;
        let iters = if quick { 3 } else { 8 };

        // synchronous save: the whole atomic temp+rename on the caller
        let sync_path = dir.join("sync.bckp");
        let (sync_min, sync_mean, _) = bench_times(iters, || {
            state.save(&sync_path).unwrap();
        });
        rows.push(
            &format!("ckpt sync save ({:.0} MiB)",
                     file_bytes / (1 << 20) as f64),
            sync_min,
            format!("{:.0} MiB/s", file_bytes / sync_min
                        / (1 << 20) as f64),
        );

        // async path: the hot loop only pays the recycled-buffer
        // snapshot; the write + rotation run on the writer thread
        let mut w = AsyncCheckpointWriter::new(&dir.join("rot"), 2)?;
        let mut step = 0u64;
        let (async_min, async_mean, _) = bench_times(iters, || {
            step += 1;
            w.save(|c| {
                c.step = step;
                c.data_step = step;
                c.fill_arrays(&state.params, &state.m, &state.v);
            })
            .unwrap();
        });
        let stats = w.finish()?;
        rows.push(
            "ckpt async snapshot (hot-loop cost)",
            async_min,
            format!("{:.0} MiB/s off-loop", stats.bytes_per_sec()
                        / (1 << 20) as f64),
        );
        println!(
            "checkpoint: sync save mean {:.2} ms vs async hot-loop mean \
             {:.2} ms ({:.1}x less exposed); writer did {} files, {:.0} \
             MiB/s",
            sync_mean * 1e3, async_mean * 1e3,
            sync_mean / async_mean.max(1e-9),
            stats.writes,
            stats.bytes_per_sec() / (1 << 20) as f64
        );

        if quick || std::env::var("BENCH_JSON_OUT").is_ok() {
            let path = std::env::var("BENCH_CKPT_JSON_OUT")
                .unwrap_or_else(|_| "BENCH_checkpoint.json".to_string());
            let mut mk = |mode: &str, ms: f64, bps: f64| {
                let mut m = BTreeMap::new();
                m.insert("mode".to_string(), Json::Str(mode.to_string()));
                m.insert("min_ms".to_string(), Json::Num(ms));
                m.insert("bytes_per_s".to_string(), Json::Num(bps));
                Json::Obj(m)
            };
            let entries = vec![
                mk("sync_save", sync_min * 1e3, file_bytes / sync_min),
                mk("async_hot_loop_snapshot", async_min * 1e3,
                   stats.bytes_per_sec()),
            ];
            let mut root = BTreeMap::new();
            root.insert("bench".to_string(),
                        Json::Str("checkpoint".to_string()));
            root.insert("file_bytes".to_string(), Json::Num(file_bytes));
            root.insert("exposed_speedup".to_string(),
                        Json::Num(sync_mean / async_mean.max(1e-9)));
            root.insert("rows".to_string(), Json::Arr(entries));
            std::fs::write(&path, Json::Obj(root).to_string())?;
            println!("wrote {path}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- elastic restore: post-write verify + restart latency
    //      (ISSUE 6: the ledger must stay cheap off-loop, and the
    //      supervised-relaunch path pays ledger consult + full load
    //      before its first step) ----
    {
        use bertdist::checkpoint::{v2_file_len, verify_checkpoint,
                                   AsyncCheckpointWriter, Checkpoint,
                                   Ledger};
        let n = if quick { 1 << 20 } else { 1 << 23 };
        let dir = std::env::temp_dir().join("bertdist_bench_elastic");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir)?;
        let mut state = Checkpoint::new(n);
        for (i, x) in state.params.iter_mut().enumerate() {
            *x = i as f32 * 1e-6;
        }
        let file_bytes = v2_file_len(n) as f64;

        // a small rotation history through the verifying writer: its
        // stats expose what the CRC re-read costs off the hot loop
        let mut w = AsyncCheckpointWriter::new(&dir, 3)?;
        for step in 1..=3u64 {
            w.save(|c| {
                c.step = step;
                c.data_step = step;
                c.fill_arrays(&state.params, &state.m, &state.v);
            })?;
        }
        let stats = w.finish()?;
        let per_verify = stats.verify_s / stats.verified.max(1) as f64;
        rows.push(
            &format!("ckpt post-write verify ({:.0} MiB, off-loop)",
                     file_bytes / (1 << 20) as f64),
            per_verify,
            format!("{:.0} MiB/s", file_bytes / per_verify.max(1e-9)
                        / (1 << 20) as f64),
        );

        // standalone verify throughput on the newest ledger entry
        let ledger = Ledger::load(&dir);
        let newest = ledger
            .newest_verified()
            .expect("writer left a verified entry")
            .file
            .clone();
        let newest_path = dir.join(&newest);
        let iters = if quick { 3 } else { 8 };
        let (verify_min, _, _) = bench_times(iters, || {
            verify_checkpoint(&newest_path).unwrap();
        });
        rows.push(
            "ckpt verify re-read",
            verify_min,
            format!("{:.0} MiB/s", file_bytes / verify_min
                        / (1 << 20) as f64),
        );

        // restart-to-restore latency: what a supervised relaunch
        // (`--max-restarts`) pays between "attempt died" and "state in
        // memory" — ledger consult, newest-verified selection, full load
        let (restore_min, _, _) = bench_times(iters, || {
            let l = Ledger::load(&dir);
            let e = l.newest_verified().expect("verified entry");
            let ck = Checkpoint::load(&dir.join(&e.file)).unwrap();
            std::hint::black_box(ck.step);
        });
        rows.push(
            "elastic restart restore (ledger + load)",
            restore_min,
            format!("{:.0} MiB/s", file_bytes / restore_min
                        / (1 << 20) as f64),
        );

        if quick || std::env::var("BENCH_JSON_OUT").is_ok() {
            let path = std::env::var("BENCH_ELASTIC_JSON_OUT")
                .unwrap_or_else(|_| "BENCH_elastic.json".to_string());
            let mut mk = |name: &str, ms: f64, bps: f64| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(name.to_string()));
                m.insert("min_ms".to_string(), Json::Num(ms));
                m.insert("bytes_per_s".to_string(), Json::Num(bps));
                Json::Obj(m)
            };
            let entries = vec![
                mk("post_write_verify", per_verify * 1e3,
                   file_bytes / per_verify.max(1e-9)),
                mk("verify_re_read", verify_min * 1e3,
                   file_bytes / verify_min),
                mk("restart_restore", restore_min * 1e3,
                   file_bytes / restore_min),
            ];
            let mut root = BTreeMap::new();
            root.insert("bench".to_string(),
                        Json::Str("elastic".to_string()));
            root.insert("file_bytes".to_string(), Json::Num(file_bytes));
            root.insert("verified_files".to_string(),
                        Json::Num(stats.verified as f64));
            root.insert("rows".to_string(), Json::Arr(entries));
            std::fs::write(&path, Json::Obj(root).to_string())?;
            println!("wrote {path}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- bucket planning on bert-large ----
    let layout = BertConfig::preset("bert-large").unwrap().param_layout();
    let (min, _, _) = bench_times(if quick { 5 } else { 20 }, || {
        std::hint::black_box(build_buckets(&layout, 1 << 22));
    });
    rows.push("bucket planning (bert-large, 4M elems)", min, String::new());

    // ---- host LAMB step on bert-mini-sized flat vector ----
    let mini = BertConfig::preset("bert-mini").unwrap().param_layout();
    let n = mini.total_len();
    let mut p = vec![0.01f32; n];
    let mut g = vec![0.001f32; n];
    let mut st = OptState::new(n);
    let h = OptHyper::default();
    let (min, _, _) = bench_times(if quick { 2 } else { 5 }, || {
        lamb_step(&mut p, &mut g, &mut st, &mini, 1e-3, &h);
    });
    rows.push(
        &format!("host LAMB step ({:.1}M params)", n as f64 / 1e6),
        min,
        format!("{:.0} Melem/s", n as f64 / min / 1e6),
    );

    // ---- f16 conversion throughput (AMP overflow scans + wire) ----
    let count = if quick { 100_000 } else { 1_000_000 };
    let xs: Vec<f32> = (0..count).map(|i| i as f32 * 1e-3).collect();
    let (min, _, _) = bench_times(5, || {
        let s: u32 = xs.iter().map(|&x| F16::from_f32(x).0 as u32).sum();
        std::hint::black_box(s);
    });
    rows.push(
        &format!("f16 convert {}k values", count / 1000),
        min,
        format!("{:.0} Melem/s", count as f64 / min / 1e6),
    );

    // ---- PJRT step overhead breakdown ----
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
        let model = engine.model("bert-micro")?;
        let mut rng = Pcg64::new(2);
        let params = init_params(&model.layout, &mut rng);
        let sw = Stopwatch::new();
        let step = engine.train_step("bert-micro", "fused_f32", 2, 32)?;
        let compile_s = sw.elapsed();
        let batch = build_batch(&exs[..2], 32, &MaskingConfig {
            vocab_size: model.config.vocab_size as u32,
            ..Default::default()
        }, &mut rng);
        step.run(&params, &batch, 1.0)?; // warmup
        let (min, mean, _) = bench_times(10, || {
            step.run(&params, &batch, 1.0).unwrap();
        });
        rows.push("XLA compile train step (once)", compile_s, String::new());
        rows.push(
            "PJRT train step bert-micro 2x32",
            min,
            format!("{:.0} tok/s (mean {:.2} ms)", 64.0 / min, mean * 1e3),
        );
    }

    println!("{}", render_table(&["hot path", "time", "rate"], &rows.table));

    // ---- machine-readable emission for the perf trajectory ----
    if quick || std::env::var("BENCH_JSON_OUT").is_ok() {
        let path = std::env::var("BENCH_JSON_OUT")
            .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
        let entries: Vec<Json> = rows
            .json
            .iter()
            .map(|(name, ms, rate)| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(name.clone()));
                m.insert("min_ms".to_string(), Json::Num(*ms));
                m.insert("rate".to_string(), Json::Str(rate.clone()));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(),
                    Json::Str("perf_hotpath".to_string()));
        root.insert("quick".to_string(),
                    Json::Str(quick.to_string()));
        root.insert("rows".to_string(), Json::Arr(entries));
        std::fs::write(&path, Json::Obj(root).to_string())?;
        println!("wrote {path}");

        // flat-vs-hierarchical section in its own file so the comm-mode
        // trajectory can be diffed independently of the hot-path rows
        let hier_path = std::env::var("BENCH_HIER_JSON_OUT")
            .unwrap_or_else(|_| "BENCH_hierarchical.json".to_string());
        let entries: Vec<Json> = hier_rows
            .iter()
            .map(|(name, ms, rate)| {
                let mut m = BTreeMap::new();
                m.insert("comm_mode".to_string(), Json::Str(name.clone()));
                m.insert("min_ms".to_string(), Json::Num(*ms));
                m.insert("rate".to_string(), Json::Str(rate.clone()));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(),
                    Json::Str("pooled_comm_mode".to_string()));
        root.insert("topology".to_string(), Json::Str("2M2G".to_string()));
        root.insert("rows".to_string(), Json::Arr(entries));
        std::fs::write(&hier_path, Json::Obj(root).to_string())?;
        println!("wrote {hier_path}");

        // serialized-vs-pipelined intra-node section in its own file so
        // the ISSUE-5 schedule's trajectory can be diffed independently
        let intra_path = std::env::var("BENCH_INTRA_JSON_OUT")
            .unwrap_or_else(|_| "BENCH_intranode.json".to_string());
        let entries: Vec<Json> = intra_rows
            .iter()
            .map(|(name, ms, rate)| {
                let mut m = BTreeMap::new();
                m.insert("intra_node".to_string(), Json::Str(name.clone()));
                m.insert("min_ms".to_string(), Json::Num(*ms));
                m.insert("rate".to_string(), Json::Str(rate.clone()));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(),
                    Json::Str("intra_node_exchange".to_string()));
        root.insert("topology".to_string(), Json::Str("2M4G".to_string()));
        root.insert("chunk_elems".to_string(),
                    Json::Num(chunk_intra as f64));
        root.insert("speedup".to_string(), Json::Num(intra_speedup));
        root.insert("rows".to_string(), Json::Arr(entries));
        std::fs::write(&intra_path, Json::Obj(root).to_string())?;
        println!("wrote {intra_path}");

        // in-proc vs loopback-socket section in its own file so the
        // ISSUE-7 transport cost can be diffed independently
        let transport_path = std::env::var("BENCH_TRANSPORT_JSON_OUT")
            .unwrap_or_else(|_| "BENCH_transport.json".to_string());
        let entries: Vec<Json> = transport_rows
            .iter()
            .map(|(name, ms, rate, net_ms)| {
                let mut m = BTreeMap::new();
                m.insert("transport".to_string(), Json::Str(name.clone()));
                m.insert("min_ms".to_string(), Json::Num(*ms));
                m.insert("rate".to_string(), Json::Str(rate.clone()));
                m.insert("net_per_bucket_ms".to_string(),
                         Json::Num(*net_ms));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(),
                    Json::Str("transport".to_string()));
        root.insert("world".to_string(), Json::Num(2.0));
        root.insert("payload_elems".to_string(), Json::Num(n_net as f64));
        root.insert("rows".to_string(), Json::Arr(entries));
        std::fs::write(&transport_path, Json::Obj(root).to_string())?;
        println!("wrote {transport_path}");

        // rejoin/grow-back section in its own file so the ISSUE-8
        // re-admission cost can be diffed independently
        let rejoin_path = std::env::var("BENCH_REJOIN_JSON_OUT")
            .unwrap_or_else(|_| "BENCH_rejoin.json".to_string());
        let entries: Vec<Json> = rejoin_rows
            .iter()
            .map(|(name, ms)| {
                let mut m = BTreeMap::new();
                m.insert("phase".to_string(), Json::Str(name.clone()));
                m.insert("min_ms".to_string(), Json::Num(*ms));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("rejoin".to_string()));
        root.insert("world".to_string(), Json::Num(2.0));
        root.insert("payload_elems".to_string(),
                    Json::Num(n_rejoin as f64));
        root.insert("rows".to_string(), Json::Arr(entries));
        std::fs::write(&rejoin_path, Json::Obj(root).to_string())?;
        println!("wrote {rejoin_path}");

        // 2-level reduce-scatter section in its own file so the ISSUE-9
        // schedule's trajectory can be diffed independently; carries all
        // three intra-node schedules so the rs row always ships with its
        // comparators
        let rs_path = std::env::var("BENCH_EXCHANGE_RS_JSON_OUT")
            .unwrap_or_else(|_| "BENCH_exchange_rs.json".to_string());
        let entries: Vec<Json> = intra_rows
            .iter()
            .map(|(name, ms, rate)| {
                let mut m = BTreeMap::new();
                m.insert("intra_node".to_string(), Json::Str(name.clone()));
                m.insert("min_ms".to_string(), Json::Num(*ms));
                m.insert("rate".to_string(), Json::Str(rate.clone()));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(),
                    Json::Str("exchange_rs".to_string()));
        root.insert("topology".to_string(), Json::Str("2M4G".to_string()));
        root.insert("payload_elems".to_string(),
                    Json::Num(n_intra as f64));
        root.insert("speedup_vs_serial".to_string(), Json::Num(rs_speedup));
        root.insert("rows".to_string(), Json::Arr(entries));
        std::fs::write(&rs_path, Json::Obj(root).to_string())?;
        println!("wrote {rs_path}");

        // sparsified-ring section in its own file: executed dense vs
        // sparse exchange, select cost, and the netsim ratio sweep with
        // its interior optimum (ISSUE 10 acceptance artifact)
        let sp_path = std::env::var("BENCH_SPARSIFY_JSON_OUT")
            .unwrap_or_else(|_| "BENCH_sparsify.json".to_string());
        let entries: Vec<Json> = sparsify_rows
            .iter()
            .map(|(name, ms, wire_bytes)| {
                let mut m = BTreeMap::new();
                m.insert("sparsify".to_string(), Json::Str(name.clone()));
                m.insert("min_ms".to_string(), Json::Num(*ms));
                m.insert("net_bytes_per_step".to_string(),
                         Json::Num(*wire_bytes));
                Json::Obj(m)
            })
            .collect();
        let sweep: Vec<Json> = sp_pts
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("ratio".to_string(), Json::Num(p.ratio));
                m.insert("entries".to_string(),
                         Json::Num(p.entries as f64));
                m.insert("wire_ms".to_string(), Json::Num(p.wire_s * 1e3));
                m.insert("inflation".to_string(), Json::Num(p.inflation));
                m.insert("effective_ms".to_string(),
                         Json::Num(p.effective_s * 1e3));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("sparsify".to_string()));
        root.insert("topology".to_string(), Json::Str("2M1G".to_string()));
        root.insert("payload_elems".to_string(), Json::Num(n_sp as f64));
        root.insert("select_melem_per_s".to_string(),
                    Json::Num(n_sp as f64 / sel_min / 1e6));
        root.insert("entry_bytes".to_string(),
                    Json::Num(netsim::SPARSE_ENTRY_BYTES));
        root.insert("frame_overhead_bytes".to_string(),
                    Json::Num(netsim::SPARSE_FRAME_OVERHEAD_BYTES));
        // net bytes saved per step at topk:0.01 vs dense, after the
        // 8 B/entry index overhead the sparse wire pays
        root.insert("net_bytes_saved_topk_0.01".to_string(),
                    Json::Num(sparsify_rows[0].2 - sparsify_rows[2].2));
        root.insert("compression_topk_0.01".to_string(),
                    Json::Num(sparsify_rows[0].2
                              / sparsify_rows[2].2.max(1.0)));
        root.insert("rows".to_string(), Json::Arr(entries));
        root.insert("model_machines".to_string(),
                    Json::Num(sp_machines as f64));
        root.insert("model_elems".to_string(), Json::Num(sp_elems as f64));
        root.insert("model_dense_ring_ms".to_string(),
                    Json::Num(sp_dense_s * 1e3));
        root.insert("model_optimal_ratio".to_string(),
                    Json::Num(sp_best.ratio));
        root.insert("model_sweep".to_string(), Json::Arr(sweep));
        std::fs::write(&sp_path, Json::Obj(root).to_string())?;
        println!("wrote {sp_path}");
    }

    println!("perf_hotpath OK");
    Ok(())
}
