//! Perf bench: L3 hot-path microbenchmarks for the EXPERIMENTS.md §Perf
//! iteration loop — allreduce bandwidth, batch assembly, shard read,
//! bucket planning, LAMB host step, f16 conversion throughput, and the
//! end-to-end PJRT step overhead breakdown.
//!
//! Run: `cargo bench --bench perf_hotpath`

use bertdist::collectives::ring::ring_allreduce_inplace;
use bertdist::collectives::CollectiveGroup;
use bertdist::data::masking::{build_batch, MaskingConfig};
use bertdist::data::PairExample;
use bertdist::grad::build_buckets;
use bertdist::half::F16;
use bertdist::model::BertConfig;
use bertdist::optimizer::{lamb_step, OptHyper, OptState};
use bertdist::runtime::Engine;
use bertdist::trainer::init_params;
use bertdist::util::fmt::render_table;
use bertdist::util::stopwatch::bench_times;
use bertdist::util::{Pcg64, Stopwatch};

fn main() -> anyhow::Result<()> {
    println!("=== perf_hotpath: coordinator hot-path microbenches ===\n");
    let mut rows = Vec::new();

    // ---- threaded ring allreduce bandwidth (the §4.4 data path) ----
    let elems = 16 * 1024 * 1024 / 4; // 16 MiB payload
    for world in [2usize, 4] {
        let (min, _, _) = bench_times(3, || {
            let handles = CollectiveGroup::new(world);
            let joins: Vec<_> = handles
                .into_iter()
                .map(|mut h| {
                    std::thread::spawn(move || {
                        let mut buf = vec![1.0f32; elems];
                        h.allreduce(&mut buf);
                    })
                })
                .collect();
            for j in joins {
                j.join().unwrap();
            }
        });
        rows.push(vec![
            format!("threaded allreduce x{world} (16 MiB)"),
            format!("{:.2} ms", min * 1e3),
            format!("{:.2} GB/s alg", elems as f64 * 4.0 / min / 1e9),
        ]);
    }

    // ---- single-threaded reference allreduce ----
    let (min, _, _) = bench_times(3, || {
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; elems / 4])
            .collect();
        ring_allreduce_inplace(&mut bufs);
    });
    rows.push(vec!["reference allreduce x4 (4 MiB each)".into(),
                   format!("{:.2} ms", min * 1e3), String::new()]);

    // ---- batch assembly (masking pipeline) ----
    let cfg = MaskingConfig::default();
    let exs: Vec<PairExample> = (0..8)
        .map(|i| PairExample {
            tokens_a: (0..60).map(|t| 10 + (t + i) % 8000).collect(),
            tokens_b: (0..60).map(|t| 10 + (t * 2 + i) % 8000).collect(),
            is_next: i % 2 == 0,
        })
        .collect();
    let mut rng = Pcg64::new(1);
    let (min, _, _) = bench_times(50, || {
        std::hint::black_box(build_batch(&exs, 128, &cfg, &mut rng));
    });
    rows.push(vec!["batch assembly 8x128 (mask+pack)".into(),
                   format!("{:.3} ms", min * 1e3),
                   format!("{:.1} Mtok/s", 8.0 * 128.0 / min / 1e6)]);

    // ---- bucket planning on bert-large ----
    let layout = BertConfig::preset("bert-large").unwrap().param_layout();
    let (min, _, _) = bench_times(20, || {
        std::hint::black_box(build_buckets(&layout, 1 << 22));
    });
    rows.push(vec!["bucket planning (bert-large, 4M elems)".into(),
                   format!("{:.3} ms", min * 1e3), String::new()]);

    // ---- host LAMB step on bert-mini-sized flat vector ----
    let mini = BertConfig::preset("bert-mini").unwrap().param_layout();
    let n = mini.total_len();
    let mut p = vec![0.01f32; n];
    let mut g = vec![0.001f32; n];
    let mut st = OptState::new(n);
    let h = OptHyper::default();
    let (min, _, _) = bench_times(5, || {
        lamb_step(&mut p, &mut g, &mut st, &mini, 1e-3, &h);
    });
    rows.push(vec![
        format!("host LAMB step ({:.1}M params)", n as f64 / 1e6),
        format!("{:.2} ms", min * 1e3),
        format!("{:.0} Melem/s", n as f64 / min / 1e6),
    ]);

    // ---- f16 conversion throughput (AMP overflow scans) ----
    let xs: Vec<f32> = (0..1_000_000).map(|i| i as f32 * 1e-3).collect();
    let (min, _, _) = bench_times(5, || {
        let s: u32 = xs.iter().map(|&x| F16::from_f32(x).0 as u32).sum();
        std::hint::black_box(s);
    });
    rows.push(vec!["f16 convert 1M values".into(),
                   format!("{:.2} ms", min * 1e3),
                   format!("{:.0} Melem/s", 1.0 / min)]);

    // ---- PJRT step overhead breakdown ----
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Engine::cpu(std::path::Path::new("artifacts"))?;
        let model = engine.model("bert-micro")?;
        let mut rng = Pcg64::new(2);
        let params = init_params(&model.layout, &mut rng);
        let sw = Stopwatch::new();
        let step = engine.train_step("bert-micro", "fused_f32", 2, 32)?;
        let compile_s = sw.elapsed();
        let batch = build_batch(&exs[..2], 32, &MaskingConfig {
            vocab_size: model.config.vocab_size as u32,
            ..Default::default()
        }, &mut rng);
        step.run(&params, &batch, 1.0)?; // warmup
        let (min, mean, _) = bench_times(10, || {
            step.run(&params, &batch, 1.0).unwrap();
        });
        rows.push(vec!["XLA compile train step (once)".into(),
                       format!("{:.0} ms", compile_s * 1e3), String::new()]);
        rows.push(vec!["PJRT train step bert-micro 2x32".into(),
                       format!("{:.2} ms (mean {:.2})", min * 1e3,
                               mean * 1e3),
                       format!("{:.0} tok/s", 64.0 / min)]);
    }

    println!("{}", render_table(&["hot path", "time", "rate"], &rows));
    println!("perf_hotpath OK");
    Ok(())
}
