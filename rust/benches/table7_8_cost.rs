//! Bench: regenerate paper **Tables 7 & 8** — cloud rental vs DGX
//! acquisition cost estimation, plus the §6 break-even analysis.
//!
//! Run: `cargo bench --bench table7_8_cost`

use bertdist::costmodel::{break_even, cloud_cost, dgx_clusters,
                          paper_cluster};
use bertdist::util::fmt::render_table;

fn main() {
    println!("=== Table 7: Google Cloud Price Estimation ===\n");
    let cloud = cloud_cost(256, 12.0);
    println!("{}", render_table(
        &["Devices", "Number", "Price/hour", "Training Time",
          "Total Cost (USD)", "paper"],
        &[vec!["NVIDIA T4".into(), "256".into(), "$0.35".into(),
               "12 Days".into(), format!("${cloud:.1}"),
               "$25804.8".into()]],
    ));
    assert!((cloud - 25_804.8).abs() < 0.01);

    println!("=== Table 8: NVIDIA DGX Cluster Price Estimation ===\n");
    let mut rows = Vec::new();
    let own = paper_cluster();
    rows.push(vec![own.name.clone(), own.units.to_string(),
                   format!("${:.0}", own.unit_cost_usd),
                   format!("${:.0}", own.total()), "$624000".into()]);
    let paper_totals = [4_768_000.0, 12_768_000.0];
    for (c, want) in dgx_clusters().iter().zip(paper_totals) {
        assert_eq!(c.total(), want);
        rows.push(vec![c.name.clone(), c.units.to_string(),
                       format!("${:.0}", c.unit_cost_usd),
                       format!("${:.0}", c.total()),
                       format!("${want:.0}")]);
    }
    println!("{}", render_table(
        &["Devices", "Number", "Price (USD)", "Total Cost (USD)", "paper"],
        &rows));

    let b = break_even(12.0);
    println!("§6 break-even: {:.0} experiments per 3-year cycle; \
              own ${:.0}/exp vs cloud ${:.0}/exp",
             b.experiments_per_cycle, b.own_cost_per_experiment,
             b.cloud_cost_per_experiment);
    assert!((b.experiments_per_cycle - 91.25).abs() < 1.0);
    println!("\ntable7_8_cost OK");
}
