//! # bertdist
//!
//! Cost-efficient multi-node BERT pretraining — a reproduction of
//! *"Multi-node BERT-pretraining: Cost-efficient Approach"*
//! (Lin, Li, Pekhimenko, 2020) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate is the **Layer-3 coordinator**: it owns data sharding
//! (paper §4.1), the AMP loss-scaling state machine (§4.2), the
//! data-parallel trainer with ring allreduce, communication/computation
//! overlap and gradient accumulation (§4.4), plus the discrete-event
//! cluster simulator that regenerates every table and figure of the
//! paper's evaluation (§5).  Model math lives in AOT-compiled XLA
//! artifacts produced once by `python/compile` (Layers 1–2); Python is
//! never on the training path.
//!
//! Module map (see DESIGN.md §5 for the paper-section cross-reference):
//!
//! * substrates: [`util`], [`testkit`], [`half`], [`cliopt`], [`config`],
//!   [`jsonlite`]
//! * cluster model: [`topology`], [`netsim`], [`collectives`]
//! * data path: [`shard`], [`data`]
//! * numerics: [`precision`], [`grad`], [`optimizer`], [`model`]
//! * execution: [`runtime`], [`trainer`], [`metrics`], [`checkpoint`]
//! * evaluation: [`simulator`], [`costmodel`]
//! * wiring: [`coordinator`]

pub mod checkpoint;
pub mod cliopt;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod finetune;
pub mod grad;
pub mod half;
pub mod metrics;
pub mod model;
pub mod optimizer;
pub mod precision;
pub mod runtime;
pub mod jsonlite;
pub mod netsim;
pub mod shard;
pub mod simulator;
pub mod testkit;
pub mod topology;
pub mod trainer;
pub mod util;
