//! Checkpointing: exact-state save/restore for the trainer, to a single
//! binary file with CRC integrity.  Own format — no serde offline
//! (DESIGN.md §10).
//!
//! ## v2 format (this version) — the exact-resume contract
//!
//! A v2 checkpoint captures the FULL training stream position, so a
//! resumed run is bitwise-indistinguishable from one that never
//! stopped:
//!
//! * `step` — optimizer steps actually applied;
//! * `data_step` — the monotone data-consumption counter, which keeps
//!   moving across AMP-skipped steps (a skipped step consumed its
//!   batches but applied nothing).  v1 checkpoints lacked this and
//!   resumed with the `data_step = step` guess, silently replaying the
//!   wrong batches after any overflow skip;
//! * the dynamic loss scaler's complete state ([`ScalerState`]): scale,
//!   growth/backoff factors and bounds, the growth-streak counter, and
//!   the reporting counters — so the post-resume scale schedule is
//!   identical, not merely "the same scale right now";
//! * a config [`Fingerprint`] (topology, comm mode, wire format,
//!   bucket layout, accumulation, prefetch depth, per-rank batch
//!   geometry, seed, optimizer kind, artifact variant, lr, warmup,
//!   masking config) that is validated on restore: a mismatched resume
//!   fails loudly instead of diverging silently.
//!
//! Byte layout (all little-endian; see [`v2_sections`]):
//!
//! ```text
//! BCKP | version u32 = 2 | step u64 | data_step u64 |
//! scaler  (5 f64 + 6 u64 = 88 B) |
//! fingerprint (10 u32 + 4 u64 + 2 f64 + 4 u64 + sparsify (u32 + f64)
//! = 132 B, first u32 is a present flag) |
//! n u64 | params f32*n | m f32*n | v f32*n |
//! ef_ranks u32 | per rank: len u32 + residual f32*len | crc32 u32
//! ```
//!
//! **v2.2** (this revision) adds the sparsification state, following
//! the v2.1 in-place-growth precedent: the fingerprint block gains
//! `train.sparsify` (a u32 kind + f64 ratio — the knob changes the
//! gradient values, so resume gates on it STRICTLY, even under
//! `--resume-reshape`), and a variable-length error-feedback section
//! follows the `v` moments: one residual vector per local rank
//! ([`Checkpoint::ef_residuals`]), empty (4 bytes) for dense runs.  The
//! residuals must round-trip bitwise — with `topk(ratio < 1)` the
//! dropped gradient mass lives there, and an exact resume replays it
//! into the next step.  The fixed header is now 252 bytes (`n` moved
//! from 232 to 244).  As with v2.1, no pre-v2.2 files exist outside
//! this repo's own runs, so the version stays 2 — an old file surfaces
//! as a clean `SizeMismatch`.
//!
//! **v2.1** grew the fingerprint block in place: the
//! formerly-reserved 10th u32 now carries the intra-node exchange mode
//! (`train.intra_node`), and two u64 fields follow `max_predictions` —
//! `chunk_elems` (the pipelined-exchange chunk size; like the intra
//! mode it changes the reduction association, hence the numerics) and
//! `data_manifest`, the CORPUS identity: a hash of the sorted shard
//! manifest (`.bshard` names + sizes, see
//! `data::pipeline::shard_manifest_hash`), so resuming the same config
//! over a DIFFERENT dataset now fails loudly — the v2.0 gate covered
//! config, not data.  A zero manifest means "unknown" (bare snapshots,
//! tests) and is never produced by a real corpus; the gate only fires
//! when both sides know their corpus.
//!
//! v1 files (`version = 1`: `step, scale, n, params, m, v`) still load;
//! they fall back to `data_step = step` and a fresh scaler at the saved
//! scale, and `load` logs a one-line warning that the data position is
//! inexact.
//!
//! Writes are always atomic (temp + rename), so a crash mid-save leaves
//! the previous checkpoint intact plus at most a stale `.tmp` that the
//! rotation layer ([`writer`]) cleans up.  Periodic hot-loop saving goes
//! through [`AsyncCheckpointWriter`]: the trainer memcpys its state into
//! a recycled snapshot buffer and a background thread does the write and
//! the keep-last-K rotation off the hot loop.  After every write the
//! background thread CRC re-reads the file ([`verify_checkpoint`]) and
//! records the verdict in `ledger.json` ([`ledger`]), so elastic
//! restarts always target the newest *known-good* checkpoint.
//!
//! ## Elastic (reshaped) restore
//!
//! The strict fingerprint gate refuses any topology change.  The
//! reshaped gate ([`Checkpoint::ensure_reshape_fingerprint`], CLI
//! `--resume-reshape`) relaxes exactly the world-shape fields —
//! topology, comm/intra-node mode, bucket/chunk layout, prefetch depth
//! — and keeps every stream-content field strict.  At restore, params /
//! m / v / scaler / step / data_step are bitwise-preserved; afterwards
//! the reduction association and the per-rank shard assignment +
//! masking streams legitimately diverge from the old world (the new
//! world re-derives them), while two runs on the SAME new world from
//! the same checkpoint remain bitwise-identical (see `docs/elastic.md`).
//!
//! ## Invariants
//!
//! * **Exact resume** — restoring a v2 checkpoint continues
//!   bitwise-identically to the run never having stopped (masking is
//!   position-keyed, the scaler state is complete, `data_step` is
//!   monotone across AMP skips); asserted at every boundary by
//!   `tests/checkpoint_resume.rs`.
//! * **Never partial state** — `load` validates magic, CRC, and every
//!   length before any field is parsed; a refused restore (fingerprint
//!   or corpus mismatch) leaves the trainer untouched.
//! * **Crash safety** — a crash can only lose the checkpoint being
//!   written, never damage an existing one (write temp + fsync +
//!   rename; stale `.tmp` files are pruned, never resumed from).
//! * **Off-loop cost** — the hot loop pays one recycled-buffer memcpy
//!   per periodic save; the only blocking case (writer a full write
//!   behind) is timed and reported (`TrainReport.checkpoint_s`).

pub mod ledger;
pub mod writer;

pub use ledger::{verify_checkpoint, Ledger, LedgerEntry, LEDGER_FILE};
pub use writer::{checkpoint_file_name, latest_checkpoint, list_checkpoints,
                 prune_checkpoints, prune_checkpoints_protecting,
                 AsyncCheckpointWriter, SaveStats};

use std::io::{Read, Write};
use std::ops::Range;
use std::path::Path;

use crate::collectives::pool::{CommMode, IntraNodeMode};
use crate::config::RunConfig;
use crate::grad::sparsify::Sparsify;
use crate::precision::ScalerState;
use crate::util::crc32::Crc32;

const MAGIC: &[u8; 4] = b"BCKP";
const VERSION: u32 = 2;

/// v1 fixed-header bytes (magic, version, step, scale, n) + trailing crc.
const V1_MIN_LEN: usize = 4 + 4 + 8 + 8 + 8 + 4;
/// v2 fixed-header bytes (everything before the params array) — see
/// [`v2_sections`] for the breakdown.
const V2_HEADER: usize = 252;
/// Smallest possible v2 file (`n = 0`, no error-feedback residuals):
/// header + the empty EF section's rank count + crc.
const V2_MIN_LEN: usize = V2_HEADER + 4 + 4;

/// Total v2 file size for `n` parameters and NO error-feedback
/// residuals (dense runs — the common case).
pub fn v2_file_len(n: usize) -> usize {
    v2_file_len_with_ef(n, &[])
}

/// Total v2 file size for `n` parameters plus one error-feedback
/// residual section per entry of `ef_lens` (element counts).
pub fn v2_file_len_with_ef(n: usize, ef_lens: &[usize]) -> usize {
    V2_HEADER + 12 * n + 4 + ef_lens.iter().map(|l| 4 + 4 * l).sum::<usize>()
        + 4
}

/// Named byte sections of the v2 layout, in file order — the corruption
/// test matrix truncates and bit-flips at exactly these boundaries.
/// Covers a file with no error-feedback residuals; see
/// [`v2_sections_with_ef`] (or [`Checkpoint::sections`]) for the
/// sparsified shape.
pub fn v2_sections(n: usize) -> Vec<(&'static str, Range<usize>)> {
    v2_sections_with_ef(n, &[])
}

/// [`v2_sections`] for a file carrying error-feedback residuals of the
/// given element counts (one per local rank, in rank order).
pub fn v2_sections_with_ef(n: usize, ef_lens: &[usize])
    -> Vec<(&'static str, Range<usize>)> {
    let p = V2_HEADER;
    let ef_end = p + 12 * n + 4
        + ef_lens.iter().map(|l| 4 + 4 * l).sum::<usize>();
    vec![
        ("magic", 0..4),
        ("version", 4..8),
        ("step", 8..16),
        ("data_step", 16..24),
        ("scaler", 24..112),
        ("fingerprint", 112..244),
        ("n", 244..252),
        ("params", p..p + 4 * n),
        ("m", p + 4 * n..p + 8 * n),
        ("v", p + 8 * n..p + 12 * n),
        ("ef", p + 12 * n..ef_end),
        ("crc", ef_end..ef_end + 4),
    ]
}

/// The run-configuration identity a checkpoint was produced under.
/// Restore validates it against the resuming run and refuses to
/// continue on any mismatch — every field here changes the training
/// stream (data order, exchange schedule, or step semantics), so a
/// silent mismatch means silent divergence.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Fingerprint {
    pub machines: u32,
    pub gpus_per_machine: u32,
    /// [`CommMode`] as configured (not as resolved): 0 flat,
    /// 1 hierarchical, 2 auto.
    pub comm_mode: u32,
    pub grad_wire_f16: bool,
    /// Per-rank micro-batch size.
    pub micro_batch: u32,
    pub seq_len: u32,
    /// Optimizer kind: 0 lamb, 1 adam (a swapped optimizer would
    /// silently reinterpret the m/v moment buffers).
    pub optimizer: u32,
    /// Compiled-artifact variant: 0 unfused_f32, 1 fused_f32, 2 bf16,
    /// 3 fused_bf16 (different kernels = different numerics).
    pub variant: u32,
    /// [`IntraNodeMode`] as configured: 0 serial, 1 ring, 2 auto,
    /// 3 rs (the chain, the serialized leader, and the 2-level
    /// reduce-scatter each associate the node sum differently, so the
    /// reduced low bits differ — v2.1 field).
    pub intra_node: u32,
    pub bucket_elems: u64,
    pub accum_steps: u64,
    pub prefetch_depth: u64,
    pub seed: u64,
    pub lr: f64,
    pub warmup_steps: u64,
    /// MLM mask probability — changes every batch's masked positions.
    pub mask_prob: f64,
    /// Max MLM predictions per sequence (paper Table 6: 20 @128,
    /// 80 @512 — this also disambiguates phase-1 vs phase-2 snapshots).
    pub max_predictions: u64,
    /// Pipelined-exchange chunk size (elements): chunk boundaries move
    /// elements between leader-ring plan chunks, changing the ring's
    /// reduction association (v2.1 field).
    pub chunk_elems: u64,
    /// CORPUS identity: hash of the sorted shard manifest (`.bshard`
    /// file names + sizes; `data::pipeline::shard_manifest_hash`).
    /// `0` = unknown — bare snapshots and data-less tests; the resume
    /// gate only fires when BOTH sides know their corpus (v2.1 field;
    /// the v2.0 gate covered config, not data).
    pub data_manifest: u64,
    /// Network-ring sparsification knob (`train.sparsify`, v2.2 field).
    /// Strict under BOTH resume gates — a different top-k ratio changes
    /// every exchanged gradient and the meaning of the error-feedback
    /// residuals, on any topology.
    pub sparsify: Sparsify,
}

fn comm_mode_code(m: CommMode) -> u32 {
    match m {
        CommMode::Flat => 0,
        CommMode::Hierarchical => 1,
        CommMode::Auto => 2,
    }
}

fn comm_mode_name(code: u32) -> &'static str {
    match code {
        0 => "flat",
        1 => "hierarchical",
        2 => "auto",
        _ => "unknown",
    }
}

fn intra_mode_code(m: IntraNodeMode) -> u32 {
    match m {
        IntraNodeMode::Serial => 0,
        IntraNodeMode::Ring => 1,
        IntraNodeMode::Auto => 2,
        IntraNodeMode::ReduceScatter => 3,
    }
}

fn intra_mode_name(code: u32) -> &'static str {
    match code {
        0 => "serial",
        1 => "ring",
        2 => "auto",
        3 => "rs",
        _ => "unknown",
    }
}

fn optimizer_code(name: &str) -> u32 {
    match name {
        "lamb" => 0,
        "adam" => 1,
        _ => u32::MAX,
    }
}

fn optimizer_name(code: u32) -> &'static str {
    match code {
        0 => "lamb",
        1 => "adam",
        _ => "unknown",
    }
}

fn variant_code(name: &str) -> u32 {
    match name {
        "unfused_f32" => 0,
        "fused_f32" => 1,
        "bf16" => 2,
        "fused_bf16" => 3,
        _ => u32::MAX,
    }
}

fn variant_name(code: u32) -> &'static str {
    match code {
        0 => "unfused_f32",
        1 => "fused_f32",
        2 => "bf16",
        3 => "fused_bf16",
        _ => "unknown",
    }
}

impl Fingerprint {
    /// The fingerprint of a run: config + the trainer's per-rank batch
    /// geometry (which is a constructor argument, not a config field).
    pub fn of(cfg: &RunConfig, micro_batch: usize, seq_len: usize)
        -> Fingerprint {
        Fingerprint {
            machines: cfg.cluster.topo.machines as u32,
            gpus_per_machine: cfg.cluster.topo.gpus_per_machine as u32,
            comm_mode: comm_mode_code(cfg.train.comm_mode),
            grad_wire_f16: cfg.train.grad_wire_f16,
            micro_batch: micro_batch as u32,
            seq_len: seq_len as u32,
            optimizer: optimizer_code(&cfg.train.optimizer),
            variant: variant_code(&cfg.train.variant),
            intra_node: intra_mode_code(cfg.train.intra_node),
            bucket_elems: cfg.train.bucket_elems as u64,
            accum_steps: cfg.train.accum_steps as u64,
            prefetch_depth: cfg.train.prefetch_depth as u64,
            seed: cfg.train.seed,
            lr: cfg.train.lr,
            warmup_steps: cfg.train.warmup_steps as u64,
            mask_prob: cfg.data.mask_prob,
            max_predictions: cfg.data.max_predictions as u64,
            chunk_elems: cfg.train.chunk_elems as u64,
            data_manifest: 0,
            sparsify: cfg.train.sparsify,
        }
    }

    pub fn world(&self) -> usize {
        (self.machines * self.gpus_per_machine) as usize
    }

    /// Human-readable list of differing fields (`checkpoint X, run Y`),
    /// empty when the fingerprints agree.
    pub fn mismatches(&self, run: &Fingerprint) -> Vec<String> {
        let mut out = Vec::new();
        if (self.machines, self.gpus_per_machine)
            != (run.machines, run.gpus_per_machine) {
            out.push(format!(
                "topology: checkpoint {}M{}G, run {}M{}G",
                self.machines, self.gpus_per_machine,
                run.machines, run.gpus_per_machine
            ));
        }
        if self.comm_mode != run.comm_mode {
            out.push(format!(
                "comm_mode: checkpoint {}, run {}",
                comm_mode_name(self.comm_mode),
                comm_mode_name(run.comm_mode)
            ));
        }
        if self.grad_wire_f16 != run.grad_wire_f16 {
            out.push(format!(
                "grad_wire_f16: checkpoint {}, run {}",
                self.grad_wire_f16, run.grad_wire_f16
            ));
        }
        if self.micro_batch != run.micro_batch {
            out.push(format!(
                "micro_batch: checkpoint {}, run {}",
                self.micro_batch, run.micro_batch
            ));
        }
        if self.seq_len != run.seq_len {
            out.push(format!("seq_len: checkpoint {}, run {}",
                             self.seq_len, run.seq_len));
        }
        if self.bucket_elems != run.bucket_elems {
            out.push(format!("bucket_elems: checkpoint {}, run {}",
                             self.bucket_elems, run.bucket_elems));
        }
        if self.accum_steps != run.accum_steps {
            out.push(format!("accum_steps: checkpoint {}, run {}",
                             self.accum_steps, run.accum_steps));
        }
        if self.prefetch_depth != run.prefetch_depth {
            out.push(format!("prefetch_depth: checkpoint {}, run {}",
                             self.prefetch_depth, run.prefetch_depth));
        }
        if self.seed != run.seed {
            out.push(format!("seed: checkpoint {}, run {}",
                             self.seed, run.seed));
        }
        if self.optimizer != run.optimizer {
            out.push(format!("optimizer: checkpoint {}, run {}",
                             optimizer_name(self.optimizer),
                             optimizer_name(run.optimizer)));
        }
        if self.variant != run.variant {
            out.push(format!("variant: checkpoint {}, run {}",
                             variant_name(self.variant),
                             variant_name(run.variant)));
        }
        if self.lr != run.lr {
            out.push(format!("lr: checkpoint {}, run {}", self.lr, run.lr));
        }
        if self.warmup_steps != run.warmup_steps {
            out.push(format!("warmup_steps: checkpoint {}, run {}",
                             self.warmup_steps, run.warmup_steps));
        }
        if self.mask_prob != run.mask_prob {
            out.push(format!("mask_prob: checkpoint {}, run {}",
                             self.mask_prob, run.mask_prob));
        }
        if self.max_predictions != run.max_predictions {
            out.push(format!("max_predictions: checkpoint {}, run {}",
                             self.max_predictions, run.max_predictions));
        }
        if self.intra_node != run.intra_node {
            out.push(format!("intra_node: checkpoint {}, run {}",
                             intra_mode_name(self.intra_node),
                             intra_mode_name(run.intra_node)));
        }
        if self.chunk_elems != run.chunk_elems {
            out.push(format!("chunk_elems: checkpoint {}, run {}",
                             self.chunk_elems, run.chunk_elems));
        }
        if self.sparsify != run.sparsify {
            out.push(format!("sparsify: checkpoint {}, run {}",
                             self.sparsify, run.sparsify));
        }
        // Corpus identity gates only when BOTH sides know theirs — a
        // zero manifest (bare snapshot, data-less test) never blocks.
        if self.data_manifest != 0
            && run.data_manifest != 0
            && self.data_manifest != run.data_manifest {
            out.push(format!(
                "corpus: checkpoint shard manifest {:016x}, run {:016x} \
                 (the dataset under the resume differs)",
                self.data_manifest, run.data_manifest
            ));
        }
        out
    }

    /// The mismatch list under a RESHAPED (elastic) restore.  The
    /// world-shape and exchange-association fields a reshape
    /// legitimately changes — topology, comm/intra-node mode,
    /// bucket/chunk layout, prefetch depth — are ignored; everything
    /// that defines the training-stream CONTENT (seed, per-rank batch
    /// geometry, accumulation, optimizer, variant, LR schedule,
    /// masking, corpus) stays exactly as strict as [`Self::mismatches`]:
    /// a reshape moves the same run to different hardware, it never
    /// quietly changes what is being trained.
    pub fn reshape_mismatches(&self, run: &Fingerprint) -> Vec<String> {
        let neutral = |fp: &Fingerprint| Fingerprint {
            machines: 0,
            gpus_per_machine: 0,
            comm_mode: 0,
            intra_node: 0,
            bucket_elems: 0,
            chunk_elems: 0,
            prefetch_depth: 0,
            ..*fp
        };
        neutral(self).mismatches(&neutral(run))
    }
}

/// Everything needed to resume training exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Optimizer steps applied.
    pub step: u64,
    /// Monotone data-consumption counter (includes AMP-skipped steps).
    pub data_step: u64,
    /// Complete dynamic-loss-scaler state.
    pub scaler: ScalerState,
    /// Config identity; `None` for v1 files and bare snapshots.
    pub fingerprint: Option<Fingerprint>,
    /// `false` when loaded from a v1 file: `data_step` is the legacy
    /// `step` fallback, so the resumed stream does not replay batches
    /// consumed by skipped steps.
    pub exact_data_position: bool,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Error-feedback residuals of the top-k sparsifier, one full-length
    /// vector per local rank (v2.2 section).  Empty for dense runs and
    /// for files written before v2.2 — restoring an empty set zeroes the
    /// live accumulators.
    pub ef_residuals: Vec<Vec<f32>>,
}

#[derive(thiserror::Error, Debug)]
pub enum CkptError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("not a bertdist checkpoint")]
    BadMagic,
    #[error("unsupported checkpoint version {0}")]
    BadVersion(u32),
    #[error("checkpoint corrupt (crc mismatch)")]
    Corrupt,
    #[error("state size mismatch")]
    SizeMismatch,
    #[error("config fingerprint mismatch — refusing inexact resume: {0}")]
    FingerprintMismatch(String),
    #[error("checkpoint writer: {0}")]
    Writer(String),
}

fn get_u32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(b[off..off + 4].try_into().unwrap())
}

fn get_u64(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

fn get_f64(b: &[u8], off: usize) -> f64 {
    f64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

impl Checkpoint {
    pub fn new(n: usize) -> Self {
        Self {
            step: 0,
            data_step: 0,
            scaler: ScalerState::default(),
            fingerprint: None,
            exact_data_position: true,
            params: vec![0.0; n],
            m: vec![0.0; n],
            v: vec![0.0; n],
            ef_residuals: Vec::new(),
        }
    }

    /// Current loss scale (convenience over `scaler.scale`).
    pub fn loss_scale(&self) -> f64 {
        self.scaler.scale
    }

    /// Copy a state triple into this (recycled) snapshot buffer —
    /// resize-then-memcpy, so steady-state saves allocate nothing.
    pub fn fill_arrays(&mut self, params: &[f32], m: &[f32], v: &[f32]) {
        for (dst, src) in [(&mut self.params, params), (&mut self.m, m),
                           (&mut self.v, v)] {
            dst.resize(src.len(), 0.0);
            dst.copy_from_slice(src);
        }
    }

    /// Hard gate for resume: error (listing every differing field) when
    /// this checkpoint carries a fingerprint that does not match the
    /// resuming run's.  Fingerprint-less checkpoints (v1, bare
    /// snapshots) pass — the caller decides how loudly to warn.
    pub fn ensure_fingerprint(&self, run: &Fingerprint)
        -> Result<(), CkptError> {
        match &self.fingerprint {
            None => Ok(()),
            Some(saved) => {
                let diffs = saved.mismatches(run);
                if diffs.is_empty() {
                    Ok(())
                } else {
                    Err(CkptError::FingerprintMismatch(diffs.join("; ")))
                }
            }
        }
    }

    /// The relaxed gate for a RESHAPED (elastic) restore: like
    /// [`Self::ensure_fingerprint`] but via
    /// [`Fingerprint::reshape_mismatches`], so a different (machines,
    /// gpus) topology — and the exchange-layout knobs that follow from
    /// it — passes, while any field that changes the training-stream
    /// content still refuses loudly.
    pub fn ensure_reshape_fingerprint(&self, run: &Fingerprint)
        -> Result<(), CkptError> {
        match &self.fingerprint {
            None => Ok(()),
            Some(saved) => {
                let diffs = saved.reshape_mismatches(run);
                if diffs.is_empty() {
                    Ok(())
                } else {
                    Err(CkptError::FingerprintMismatch(diffs.join("; ")))
                }
            }
        }
    }

    /// Save atomically (write temp + rename): a crash mid-save never
    /// damages an existing checkpoint at `path`.
    pub fn save(&self, path: &Path) -> Result<(), CkptError> {
        if self.m.len() != self.params.len()
            || self.v.len() != self.params.len() {
            return Err(CkptError::SizeMismatch);
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            let mut crc = Crc32::new();
            let w = |f: &mut dyn Write, crc: &mut Crc32, b: &[u8]|
                -> std::io::Result<()> {
                crc.update(b);
                f.write_all(b)
            };
            w(&mut f, &mut crc, MAGIC)?;
            w(&mut f, &mut crc, &VERSION.to_le_bytes())?;
            w(&mut f, &mut crc, &self.step.to_le_bytes())?;
            w(&mut f, &mut crc, &self.data_step.to_le_bytes())?;
            // scaler section (5 f64 + 6 u64)
            let s = &self.scaler;
            for x in [s.scale, s.growth_factor, s.backoff_factor,
                      s.max_scale, s.min_scale] {
                w(&mut f, &mut crc, &x.to_le_bytes())?;
            }
            for x in [s.growth_interval, s.good_steps, s.total_steps,
                      s.skipped_steps, s.growths, s.backoffs] {
                w(&mut f, &mut crc, &x.to_le_bytes())?;
            }
            // fingerprint section (10 u32, 4 u64, lr f64, warmup u64,
            // mask_prob f64, max_predictions/chunk_elems/data_manifest
            // u64; first u32 is a present flag, the 10th u32 carries
            // the intra-node mode — the v2.1 extensions).  An absent
            // fingerprint writes the all-zero Default placeholder.
            let fp = self.fingerprint;
            let p = fp.unwrap_or_default();
            for x in [fp.is_some() as u32, p.machines, p.gpus_per_machine,
                      p.comm_mode, p.grad_wire_f16 as u32, p.micro_batch,
                      p.seq_len, p.optimizer, p.variant, p.intra_node] {
                w(&mut f, &mut crc, &x.to_le_bytes())?;
            }
            for x in [p.bucket_elems, p.accum_steps, p.prefetch_depth,
                      p.seed] {
                w(&mut f, &mut crc, &x.to_le_bytes())?;
            }
            w(&mut f, &mut crc, &p.lr.to_le_bytes())?;
            w(&mut f, &mut crc, &p.warmup_steps.to_le_bytes())?;
            w(&mut f, &mut crc, &p.mask_prob.to_le_bytes())?;
            w(&mut f, &mut crc, &p.max_predictions.to_le_bytes())?;
            w(&mut f, &mut crc, &p.chunk_elems.to_le_bytes())?;
            w(&mut f, &mut crc, &p.data_manifest.to_le_bytes())?;
            // sparsify fingerprint block (v2.2): kind u32 + ratio f64.
            // The ratio is stored as the config's full f64 — an f32
            // round-trip would make the strict gate reject its own file.
            let (sp_kind, sp_ratio) = match p.sparsify {
                Sparsify::None => (0u32, 0.0f64),
                Sparsify::TopK(r) => (1u32, r),
            };
            w(&mut f, &mut crc, &sp_kind.to_le_bytes())?;
            w(&mut f, &mut crc, &sp_ratio.to_le_bytes())?;
            w(&mut f, &mut crc, &(self.params.len() as u64).to_le_bytes())?;
            for arr in [&self.params, &self.m, &self.v] {
                let bytes = unsafe {
                    std::slice::from_raw_parts(arr.as_ptr() as *const u8,
                                               arr.len() * 4)
                };
                w(&mut f, &mut crc, bytes)?;
            }
            // error-feedback section (v2.2, variable length):
            // `ef_ranks u32 | per rank: len u32 + residual f32*len`.
            // Dense runs write the 4-byte zero count.
            w(&mut f, &mut crc,
              &(self.ef_residuals.len() as u32).to_le_bytes())?;
            for res in &self.ef_residuals {
                w(&mut f, &mut crc, &(res.len() as u32).to_le_bytes())?;
                let bytes = unsafe {
                    std::slice::from_raw_parts(res.as_ptr() as *const u8,
                                               res.len() * 4)
                };
                w(&mut f, &mut crc, bytes)?;
            }
            f.write_all(&crc.finalize().to_le_bytes())?;
            f.flush()?;
            // flush to stable storage BEFORE the rename makes the file
            // visible: after a power loss the newest checkpoint must be
            // either absent or fully intact, never renamed-but-hollow
            f.get_ref().sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load and verify.  Never panics and never returns partial state:
    /// magic and CRC are checked before any field is parsed, and every
    /// length is validated with overflow-checked arithmetic, so a
    /// truncated or bit-flipped file surfaces as [`CkptError::BadMagic`]
    /// / [`CkptError::Corrupt`] / [`CkptError::SizeMismatch`].
    pub fn load(path: &Path) -> Result<Checkpoint, CkptError> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        if bytes.len() < 12 {
            return Err(CkptError::BadMagic);
        }
        if &bytes[0..4] != MAGIC {
            return Err(CkptError::BadMagic);
        }
        let body = &bytes[..bytes.len() - 4];
        let want_crc = u32::from_le_bytes(
            bytes[bytes.len() - 4..].try_into().unwrap());
        if crate::util::crc32(body) != want_crc {
            return Err(CkptError::Corrupt);
        }
        match get_u32(&bytes, 4) {
            1 => Self::load_v1(&bytes, path),
            2 => Self::load_v2(&bytes),
            v => Err(CkptError::BadVersion(v)),
        }
    }

    /// Legacy v1 layout: `step u64 | scale f64 | n u64 | arrays | crc`.
    fn load_v1(bytes: &[u8], path: &Path) -> Result<Checkpoint, CkptError> {
        if bytes.len() < V1_MIN_LEN {
            return Err(CkptError::SizeMismatch);
        }
        let step = get_u64(bytes, 8);
        let loss_scale = get_f64(bytes, 16);
        let n = get_u64(bytes, 24);
        let expect = n
            .checked_mul(12)
            .and_then(|b| b.checked_add(V1_MIN_LEN as u64))
            .ok_or(CkptError::SizeMismatch)?;
        if bytes.len() as u64 != expect {
            return Err(CkptError::SizeMismatch);
        }
        let n = n as usize;
        log::warn!(
            "v1 checkpoint {}: inexact data position — resume falls back \
             to data_step = step (batches consumed by AMP-skipped steps \
             are not replayed)",
            path.display()
        );
        Ok(Checkpoint {
            step,
            data_step: step,
            scaler: ScalerState::legacy(loss_scale),
            fingerprint: None,
            exact_data_position: false,
            params: read_arr(bytes, 32, n),
            m: read_arr(bytes, 32 + n * 4, n),
            v: read_arr(bytes, 32 + 2 * n * 4, n),
            ef_residuals: Vec::new(),
        })
    }

    fn load_v2(bytes: &[u8]) -> Result<Checkpoint, CkptError> {
        if bytes.len() < V2_MIN_LEN {
            return Err(CkptError::SizeMismatch);
        }
        let n = get_u64(bytes, 244);
        // The EF section is variable-length, so the array block gives a
        // LOWER bound; the section parse below must then land exactly on
        // the CRC.  Files from before v2.2 (240-byte header) fail here
        // cleanly: their `n` offset reads garbage that misses the bound.
        let base = n
            .checked_mul(12)
            .and_then(|b| b.checked_add(V2_MIN_LEN as u64))
            .ok_or(CkptError::SizeMismatch)?;
        if (bytes.len() as u64) < base {
            return Err(CkptError::SizeMismatch);
        }
        let n = n as usize;
        let scaler = ScalerState {
            scale: get_f64(bytes, 24),
            growth_factor: get_f64(bytes, 32),
            backoff_factor: get_f64(bytes, 40),
            max_scale: get_f64(bytes, 48),
            min_scale: get_f64(bytes, 56),
            growth_interval: get_u64(bytes, 64),
            good_steps: get_u64(bytes, 72),
            total_steps: get_u64(bytes, 80),
            skipped_steps: get_u64(bytes, 88),
            growths: get_u64(bytes, 96),
            backoffs: get_u64(bytes, 104),
        };
        let sparsify = match get_u32(bytes, 232) {
            1 => Sparsify::TopK(get_f64(bytes, 236)),
            _ => Sparsify::None,
        };
        let fingerprint = if get_u32(bytes, 112) != 0 {
            Some(Fingerprint {
                machines: get_u32(bytes, 116),
                gpus_per_machine: get_u32(bytes, 120),
                comm_mode: get_u32(bytes, 124),
                grad_wire_f16: get_u32(bytes, 128) != 0,
                micro_batch: get_u32(bytes, 132),
                seq_len: get_u32(bytes, 136),
                optimizer: get_u32(bytes, 140),
                variant: get_u32(bytes, 144),
                intra_node: get_u32(bytes, 148),
                bucket_elems: get_u64(bytes, 152),
                accum_steps: get_u64(bytes, 160),
                prefetch_depth: get_u64(bytes, 168),
                seed: get_u64(bytes, 176),
                lr: get_f64(bytes, 184),
                warmup_steps: get_u64(bytes, 192),
                mask_prob: get_f64(bytes, 200),
                max_predictions: get_u64(bytes, 208),
                chunk_elems: get_u64(bytes, 216),
                data_manifest: get_u64(bytes, 224),
                sparsify,
            })
        } else {
            None
        };
        let p = V2_HEADER;
        // error-feedback section: `ef_ranks u32 | per rank: len u32 +
        // f32*len`, ending exactly at the CRC.  Every length is
        // overflow-checked; a hostile count cannot index out of bounds
        // or pre-allocate unbounded memory (plain push, no reserve).
        let end = bytes.len() - 4;
        let mut at = p + 12 * n;
        if at + 4 > end {
            return Err(CkptError::SizeMismatch);
        }
        let ef_ranks = get_u32(bytes, at);
        at += 4;
        let mut ef_residuals: Vec<Vec<f32>> = Vec::new();
        for _ in 0..ef_ranks {
            if at + 4 > end {
                return Err(CkptError::SizeMismatch);
            }
            let len = get_u32(bytes, at) as usize;
            at += 4;
            let blen = len.checked_mul(4).ok_or(CkptError::SizeMismatch)?;
            if at.checked_add(blen).map_or(true, |e| e > end) {
                return Err(CkptError::SizeMismatch);
            }
            ef_residuals.push(read_arr(bytes, at, len));
            at += blen;
        }
        if at != end {
            return Err(CkptError::SizeMismatch);
        }
        Ok(Checkpoint {
            step: get_u64(bytes, 8),
            data_step: get_u64(bytes, 16),
            scaler,
            fingerprint,
            exact_data_position: true,
            params: read_arr(bytes, p, n),
            m: read_arr(bytes, p + n * 4, n),
            v: read_arr(bytes, p + 2 * n * 4, n),
            ef_residuals,
        })
    }
}

fn read_arr(bytes: &[u8], off: usize, n: usize) -> Vec<f32> {
    bytes[off..off + n * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;

    fn fp(seed: u64) -> Fingerprint {
        Fingerprint {
            machines: 2,
            gpus_per_machine: 4,
            comm_mode: 1,
            grad_wire_f16: true,
            micro_batch: 8,
            seq_len: 128,
            optimizer: 0,
            intra_node: 2,
            bucket_elems: 1 << 20,
            accum_steps: 4,
            prefetch_depth: 2,
            seed,
            lr: 1e-4,
            warmup_steps: 10,
            mask_prob: 0.15,
            max_predictions: 20,
            chunk_elems: 1 << 16,
            data_manifest: 0xFEED_0001,
            variant: 1,
            sparsify: Sparsify::TopK(0.25),
        }
    }

    fn full(n: usize) -> Checkpoint {
        let mut c = Checkpoint::new(n);
        c.step = 42;
        c.data_step = 45; // 3 AMP skips
        c.scaler = ScalerState {
            scale: 1024.0,
            good_steps: 17,
            total_steps: 45,
            skipped_steps: 3,
            growths: 1,
            backoffs: 3,
            ..ScalerState::default()
        };
        c.fingerprint = Some(fp(9));
        for i in 0..n {
            c.params[i] = i as f32 * 0.5;
            c.m[i] = -(i as f32);
            c.v[i] = i as f32 * i as f32;
        }
        c
    }

    #[test]
    fn roundtrip_v2_full_state() {
        let c = full(100);
        let path = std::env::temp_dir().join("bertdist_ckpt_rt.bin");
        c.save(&path).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(),
                   v2_file_len(100) as u64);
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l, c);
        assert!(l.exact_data_position);
        assert_eq!(l.loss_scale(), 1024.0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn prop_roundtrip_random_state() {
        let dir = std::env::temp_dir().join("bertdist_ckpt_prop");
        let _ = std::fs::create_dir_all(&dir);
        testkit::check_msg(
            "ckpt-roundtrip", 0xC4C4, 32,
            |r: &mut Pcg64| {
                let n = r.range_usize(0, 40);
                let mut c = Checkpoint::new(n);
                c.step = r.next_u64() >> 20;
                c.data_step = c.step + r.gen_range(50);
                c.scaler.scale = 2.0f64.powi(r.gen_range(24) as i32);
                c.scaler.good_steps = r.gen_range(2000);
                if r.chance(0.5) {
                    c.fingerprint = Some(fp(r.next_u64()));
                }
                for x in c.params.iter_mut() {
                    *x = r.next_f32() - 0.5;
                }
                (c, r.next_u64())
            },
            |(c, tag)| {
                let path = std::env::temp_dir()
                    .join("bertdist_ckpt_prop")
                    .join(format!("c{tag}.bckp"));
                c.save(&path).map_err(|e| e.to_string())?;
                let l = Checkpoint::load(&path).map_err(|e| e.to_string())?;
                let _ = std::fs::remove_file(&path);
                if &l == c { Ok(()) } else { Err("state drifted".into()) }
            },
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn v1_file_loads_with_legacy_fallback() {
        // Hand-rolled v1 bytes (the old layout) must still load, with
        // data_step falling back to step and a legacy scaler state.
        let n = 3usize;
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&7u64.to_le_bytes());
        body.extend_from_slice(&512.0f64.to_le_bytes());
        body.extend_from_slice(&(n as u64).to_le_bytes());
        for arr in [[1.0f32, 2.0, 3.0], [0.1, 0.2, 0.3], [9.0, 8.0, 7.0]] {
            for x in arr {
                body.extend_from_slice(&x.to_le_bytes());
            }
        }
        let crc = crate::util::crc32(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        let path = std::env::temp_dir().join("bertdist_ckpt_v1.bin");
        std::fs::write(&path, &body).unwrap();
        let c = Checkpoint::load(&path).unwrap();
        assert_eq!(c.step, 7);
        assert_eq!(c.data_step, 7);
        assert!(!c.exact_data_position);
        assert!(c.fingerprint.is_none());
        assert_eq!(c.scaler, ScalerState::legacy(512.0));
        assert_eq!(c.params, vec![1.0, 2.0, 3.0]);
        assert_eq!(c.v, vec![9.0, 8.0, 7.0]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let c = full(10);
        let path = std::env::temp_dir().join("bertdist_ckpt_corrupt.bin");
        c.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[40] ^= 1;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(Checkpoint::load(&path), Err(CkptError::Corrupt)));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn bad_magic_detected() {
        let path = std::env::temp_dir().join("bertdist_ckpt_magic.bin");
        std::fs::write(&path, b"garbage-not-a-checkpoint-xxxxxxxxxxxx")
            .unwrap();
        assert!(matches!(Checkpoint::load(&path), Err(CkptError::BadMagic)));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn size_mismatch_on_save() {
        let mut c = Checkpoint::new(10);
        c.m.pop();
        let path = std::env::temp_dir().join("bertdist_ckpt_size.bin");
        assert!(matches!(c.save(&path), Err(CkptError::SizeMismatch)));
    }

    #[test]
    fn fingerprint_mismatch_lists_every_divergence() {
        let mut c = Checkpoint::new(4);
        c.fingerprint = Some(fp(1));
        let mut run = fp(1);
        c.ensure_fingerprint(&run).unwrap();
        run.seed = 2;
        run.comm_mode = 0;
        run.machines = 1;
        run.optimizer = 1;
        run.lr = 3e-4;
        let err = c.ensure_fingerprint(&run).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("comm_mode"), "{msg}");
        assert!(msg.contains("topology"), "{msg}");
        assert!(msg.contains("optimizer: checkpoint lamb, run adam"),
                "{msg}");
        assert!(msg.contains("lr"), "{msg}");
        assert!(!msg.contains("bucket_elems"), "{msg}");
        // fingerprint-less checkpoints pass the gate
        c.fingerprint = None;
        c.ensure_fingerprint(&run).unwrap();
    }

    #[test]
    fn v21_fields_gate_intra_schedule_and_corpus() {
        let mut c = Checkpoint::new(4);
        c.fingerprint = Some(fp(1));
        // intra-node schedule + chunk size changes are loud (they change
        // the reduction association, hence the numerics)
        let mut run = fp(1);
        run.intra_node = 0;
        run.chunk_elems = 4096;
        let msg = c.ensure_fingerprint(&run).unwrap_err().to_string();
        assert!(msg.contains("intra_node: checkpoint auto, run serial"),
                "{msg}");
        assert!(msg.contains("chunk_elems"), "{msg}");
        // a different corpus (both manifests known) is loud
        let mut run = fp(1);
        run.data_manifest = 0xFEED_0002;
        let msg = c.ensure_fingerprint(&run).unwrap_err().to_string();
        assert!(msg.contains("corpus"), "{msg}");
        // ...but an UNKNOWN manifest on either side never blocks
        let mut run = fp(1);
        run.data_manifest = 0;
        c.ensure_fingerprint(&run).unwrap();
        let mut c0 = Checkpoint::new(4);
        let mut saved = fp(1);
        saved.data_manifest = 0;
        c0.fingerprint = Some(saved);
        c0.ensure_fingerprint(&fp(1)).unwrap();
    }

    #[test]
    fn reshape_gate_relaxes_world_shape_but_nothing_else() {
        let mut c = Checkpoint::new(4);
        c.fingerprint = Some(fp(1));
        // a pure topology change (and the exchange knobs that follow
        // from it) refuses a strict restore but passes a reshaped one
        let mut run = fp(1);
        run.machines = 1;
        run.gpus_per_machine = 2;
        run.comm_mode = 0;
        run.intra_node = 0;
        run.bucket_elems = 1 << 18;
        run.chunk_elems = 4096;
        run.prefetch_depth = 4;
        let strict = c.ensure_fingerprint(&run).unwrap_err().to_string();
        assert!(strict.contains("topology"), "{strict}");
        c.ensure_reshape_fingerprint(&run).unwrap();
        // ...but stream-content fields stay strict under reshape
        for (name, mutate) in [
            ("seed", (&|f: &mut Fingerprint| f.seed = 2)
                 as &dyn Fn(&mut Fingerprint)),
            ("micro_batch", &|f| f.micro_batch = 4),
            ("accum_steps", &|f| f.accum_steps = 8),
            ("optimizer", &|f| f.optimizer = 1),
            ("lr", &|f| f.lr = 3e-4),
            ("mask_prob", &|f| f.mask_prob = 0.2),
            ("corpus", &|f| f.data_manifest = 0xFEED_0002),
        ] {
            let mut run = run;
            mutate(&mut run);
            let msg = c.ensure_reshape_fingerprint(&run)
                .unwrap_err().to_string();
            assert!(msg.contains(name), "{name}: {msg}");
        }
        // fingerprint-less checkpoints pass both gates
        c.fingerprint = None;
        c.ensure_reshape_fingerprint(&run).unwrap();
    }

    #[test]
    fn sections_tile_the_file_exactly() {
        let n = 13;
        let secs = v2_sections(n);
        let mut pos = 0;
        for (name, r) in &secs {
            assert_eq!(r.start, pos, "gap before section {name}");
            pos = r.end;
        }
        assert_eq!(pos, v2_file_len(n));
        // ...and with a non-trivial EF section
        let lens = [13usize, 0, 7];
        let secs = v2_sections_with_ef(n, &lens);
        let mut pos = 0;
        for (name, r) in &secs {
            assert_eq!(r.start, pos, "gap before section {name}");
            pos = r.end;
        }
        assert_eq!(pos, v2_file_len_with_ef(n, &lens));
    }

    #[test]
    fn roundtrip_ef_residuals_bitwise() {
        let mut c = full(20);
        c.ef_residuals = vec![
            (0..20).map(|i| (i as f32) * 0.125 - 1.0).collect(),
            (0..20).map(|i| -(i as f32) * 0.0625).collect(),
        ];
        let path = std::env::temp_dir().join("bertdist_ckpt_ef_rt.bin");
        c.save(&path).unwrap();
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            v2_file_len_with_ef(20, &[20, 20]) as u64
        );
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l, c);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_ef_section_is_a_clean_size_mismatch() {
        // A file whose EF lengths claim more data than exists must fail
        // as SizeMismatch, never panic.  Rebuild the CRC so the length
        // check (not the CRC) is what fires.
        let mut c = full(8);
        c.ef_residuals = vec![vec![0.5f32; 8]];
        let path = std::env::temp_dir().join("bertdist_ckpt_ef_trunc.bin");
        c.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let ef_start = V2_HEADER + 12 * 8;
        for cut in [ef_start + 2, ef_start + 6, bytes.len() - 8] {
            let mut t = bytes[..cut].to_vec();
            let crc = crate::util::crc32(&t);
            t.extend_from_slice(&crc.to_le_bytes());
            std::fs::write(&path, &t).unwrap();
            assert!(
                matches!(Checkpoint::load(&path),
                         Err(CkptError::SizeMismatch)),
                "cut at {cut} not detected"
            );
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn sparsify_gates_resume_strictly_even_under_reshape() {
        let mut c = Checkpoint::new(4);
        c.fingerprint = Some(fp(1));
        // same ratio passes both gates
        c.ensure_fingerprint(&fp(1)).unwrap();
        // a different ratio — or dropping to dense — is loud, and stays
        // loud under the relaxed reshape gate: the knob changes every
        // exchanged gradient on any topology.
        for sp in [Sparsify::TopK(0.5), Sparsify::None] {
            let mut run = fp(1);
            run.sparsify = sp;
            let msg = c.ensure_fingerprint(&run).unwrap_err().to_string();
            assert!(msg.contains("sparsify"), "{msg}");
            let msg =
                c.ensure_reshape_fingerprint(&run).unwrap_err().to_string();
            assert!(msg.contains("sparsify"), "{msg}");
        }
        // the ratio survives the file round-trip at full f64 precision,
        // so a checkpoint gates cleanly against its own config
        let mut full_c = full(4);
        full_c.fingerprint = Some(Fingerprint {
            sparsify: Sparsify::TopK(0.1),
            ..fp(1)
        });
        let path = std::env::temp_dir().join("bertdist_ckpt_sp_gate.bin");
        full_c.save(&path).unwrap();
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l.fingerprint.unwrap().sparsify, Sparsify::TopK(0.1));
        l.ensure_fingerprint(&Fingerprint {
            sparsify: Sparsify::TopK(0.1),
            ..fp(9)
        })
        .unwrap();
        std::fs::remove_file(&path).unwrap();
    }
}
