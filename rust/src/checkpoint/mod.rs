//! Checkpointing: save/restore (params, optimizer moments, step, scaler)
//! to a single binary file with CRC integrity.  Own format — no serde
//! offline (DESIGN.md §10).
//!
//! Layout: `BCKP | version u32 | step u64 | scale f64 | n u64 |
//! params f32*n | m f32*n | v f32*n | crc32 u32`.

use std::io::{Read, Write};
use std::path::Path;

use crate::util::crc32::Crc32;

const MAGIC: &[u8; 4] = b"BCKP";
const VERSION: u32 = 1;

/// Everything needed to resume training.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub loss_scale: f64,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

#[derive(thiserror::Error, Debug)]
pub enum CkptError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("not a bertdist checkpoint")]
    BadMagic,
    #[error("unsupported checkpoint version {0}")]
    BadVersion(u32),
    #[error("checkpoint corrupt (crc mismatch)")]
    Corrupt,
    #[error("state size mismatch")]
    SizeMismatch,
}

impl Checkpoint {
    pub fn new(n: usize) -> Self {
        Self {
            step: 0,
            loss_scale: 65536.0,
            params: vec![0.0; n],
            m: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    /// Save atomically (write temp + rename).
    pub fn save(&self, path: &Path) -> Result<(), CkptError> {
        if self.m.len() != self.params.len()
            || self.v.len() != self.params.len() {
            return Err(CkptError::SizeMismatch);
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            let mut crc = Crc32::new();
            let w = |f: &mut dyn Write, crc: &mut Crc32, b: &[u8]|
                -> std::io::Result<()> {
                crc.update(b);
                f.write_all(b)
            };
            w(&mut f, &mut crc, MAGIC)?;
            w(&mut f, &mut crc, &VERSION.to_le_bytes())?;
            w(&mut f, &mut crc, &self.step.to_le_bytes())?;
            w(&mut f, &mut crc, &self.loss_scale.to_le_bytes())?;
            w(&mut f, &mut crc, &(self.params.len() as u64).to_le_bytes())?;
            for arr in [&self.params, &self.m, &self.v] {
                let bytes = unsafe {
                    std::slice::from_raw_parts(arr.as_ptr() as *const u8,
                                               arr.len() * 4)
                };
                w(&mut f, &mut crc, bytes)?;
            }
            f.write_all(&crc.finalize().to_le_bytes())?;
            f.flush()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load and verify.
    pub fn load(path: &Path) -> Result<Checkpoint, CkptError> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        if bytes.len() < 4 + 4 + 8 + 8 + 8 + 4 {
            return Err(CkptError::BadMagic);
        }
        if &bytes[0..4] != MAGIC {
            return Err(CkptError::BadMagic);
        }
        let body = &bytes[..bytes.len() - 4];
        let want_crc = u32::from_le_bytes(
            bytes[bytes.len() - 4..].try_into().unwrap());
        if crate::util::crc32(body) != want_crc {
            return Err(CkptError::Corrupt);
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(CkptError::BadVersion(version));
        }
        let step = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        let loss_scale =
            f64::from_le_bytes(bytes[16..24].try_into().unwrap());
        let n = u64::from_le_bytes(bytes[24..32].try_into().unwrap()) as usize;
        let expect = 32 + 3 * n * 4 + 4;
        if bytes.len() != expect {
            return Err(CkptError::SizeMismatch);
        }
        let read_arr = |off: usize| -> Vec<f32> {
            bytes[off..off + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        };
        Ok(Checkpoint {
            step,
            loss_scale,
            params: read_arr(32),
            m: read_arr(32 + n * 4),
            v: read_arr(32 + 2 * n * 4),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new(100);
        c.step = 42;
        c.loss_scale = 1024.0;
        for i in 0..100 {
            c.params[i] = i as f32 * 0.5;
            c.m[i] = -(i as f32);
            c.v[i] = i as f32 * i as f32;
        }
        let path = std::env::temp_dir().join("bertdist_ckpt_rt.bin");
        c.save(&path).unwrap();
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l, c);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let c = Checkpoint::new(10);
        let path = std::env::temp_dir().join("bertdist_ckpt_corrupt.bin");
        c.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[40] ^= 1;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(Checkpoint::load(&path), Err(CkptError::Corrupt)));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn bad_magic_detected() {
        let path = std::env::temp_dir().join("bertdist_ckpt_magic.bin");
        std::fs::write(&path, b"garbage-not-a-checkpoint-xxxxxxxxxxxx")
            .unwrap();
        assert!(matches!(Checkpoint::load(&path), Err(CkptError::BadMagic)));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn size_mismatch_on_save() {
        let mut c = Checkpoint::new(10);
        c.m.pop();
        let path = std::env::temp_dir().join("bertdist_ckpt_size.bin");
        assert!(matches!(c.save(&path), Err(CkptError::SizeMismatch)));
    }
}
