//! The verified-checkpoint ledger: `ledger.json` in the rotation
//! directory records, for every rotation file the writer produced,
//! whether a post-write CRC re-read proved the on-disk bytes
//! restorable.  Elastic restarts (`--max-restarts`, `--resume DIR`)
//! consult it to pick the newest *known-good* checkpoint, so a torn or
//! bit-flipped newest file degrades to the previous verified entry
//! instead of aborting the run.
//!
//! Format (version 1):
//!
//! ```json
//! {"version": 1, "entries": [
//!   {"file": "ckpt-0000000004.bckp", "step": 4, "data_step": 4,
//!    "bytes": 1244, "verified": true}
//! ]}
//! ```
//!
//! The ledger is advisory, never authoritative: losing or corrupting it
//! loses only the verify verdicts (resume falls back to trying files
//! newest-first), never the checkpoints themselves.  [`Ledger::load`]
//! therefore treats a missing or unparsable file as empty instead of
//! erroring.  Writes are atomic (temp + rename) with the same crash
//! contract as the checkpoints: a crash mid-save leaves the previous
//! ledger intact.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::CkptError;
use crate::jsonlite::Json;

/// Ledger file name inside a rotation directory.
pub const LEDGER_FILE: &str = "ledger.json";

/// One rotation checkpoint the writer produced, with its verify
/// verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct LedgerEntry {
    /// Rotation file name (`ckpt-{data_step:010}.bckp`), relative to
    /// the rotation directory.
    pub file: String,
    /// Optimizer steps applied at the snapshot.
    pub step: u64,
    /// Monotone data-consumption counter at the snapshot.
    pub data_step: u64,
    /// On-disk file size.
    pub bytes: u64,
    /// `true` when the post-write CRC re-read proved the bytes
    /// restorable; `false` when the re-read failed (torn write, disk
    /// error) — such a file is never selected for resume.
    pub verified: bool,
}

/// The verified-checkpoint ledger for one rotation directory, kept
/// sorted oldest → newest by `(data_step, file)`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Ledger {
    pub entries: Vec<LedgerEntry>,
}

impl Ledger {
    /// Path of the ledger file inside `dir`.
    pub fn path(dir: &Path) -> PathBuf {
        dir.join(LEDGER_FILE)
    }

    /// Load the ledger for `dir`.  Missing or unparsable files yield an
    /// EMPTY ledger (with a warning for the unparsable case): the
    /// ledger is advisory, and resume must keep working in a rotation
    /// directory that predates it.
    pub fn load(dir: &Path) -> Ledger {
        let path = Self::path(dir);
        let Ok(text) = std::fs::read_to_string(&path) else {
            return Ledger::default();
        };
        let Ok(doc) = Json::parse(&text) else {
            log::warn!("unparsable {} — starting a fresh ledger",
                       path.display());
            return Ledger::default();
        };
        let mut out = Ledger::default();
        if let Some(arr) = doc.get("entries").and_then(Json::as_arr) {
            for e in arr {
                let fields = (
                    e.get("file").and_then(Json::as_str),
                    e.get("step").and_then(Json::as_f64),
                    e.get("data_step").and_then(Json::as_f64),
                    e.get("bytes").and_then(Json::as_f64),
                );
                let (Some(file), Some(step), Some(data_step), Some(bytes)) =
                    fields else { continue };
                out.entries.push(LedgerEntry {
                    file: file.to_string(),
                    step: step as u64,
                    data_step: data_step as u64,
                    bytes: bytes as u64,
                    verified: matches!(e.get("verified"),
                                       Some(Json::Bool(true))),
                });
            }
        }
        out.sort();
        out
    }

    /// Save atomically (temp + rename) into `dir`.
    pub fn save(&self, dir: &Path) -> Result<(), CkptError> {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut m = BTreeMap::new();
                m.insert("file".into(), Json::Str(e.file.clone()));
                m.insert("step".into(), Json::Num(e.step as f64));
                m.insert("data_step".into(), Json::Num(e.data_step as f64));
                m.insert("bytes".into(), Json::Num(e.bytes as f64));
                m.insert("verified".into(), Json::Bool(e.verified));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("version".into(), Json::Num(1.0));
        root.insert("entries".into(), Json::Arr(entries));
        // NOT `ckpt-*.tmp`, so checkpoint rotation never touches it.
        let tmp = dir.join(format!("{LEDGER_FILE}.tmp"));
        std::fs::write(&tmp, Json::Obj(root).to_string())?;
        std::fs::rename(&tmp, Self::path(dir))?;
        Ok(())
    }

    fn sort(&mut self) {
        self.entries
            .sort_by(|a, b| (a.data_step, &a.file)
                .cmp(&(b.data_step, &b.file)));
    }

    /// Insert `entry`, replacing any existing entry for the same file
    /// (a re-written data_step keeps one verdict, the latest).
    pub fn record(&mut self, entry: LedgerEntry) {
        self.entries.retain(|e| e.file != entry.file);
        self.entries.push(entry);
        self.sort();
    }

    /// Drop entries whose file name fails `keep` (post-rotation sweep).
    pub fn retain_files<F: FnMut(&str) -> bool>(&mut self, mut keep: F) {
        self.entries.retain(|e| keep(&e.file));
    }

    /// The verify verdict for a rotation file name: `Some(true)`
    /// verified, `Some(false)` known-bad, `None` unknown to the ledger
    /// (pre-ledger file, foreign file — the caller decides).
    pub fn status(&self, file: &str) -> Option<bool> {
        self.entries.iter().find(|e| e.file == file).map(|e| e.verified)
    }

    /// The newest entry whose verify re-read passed — the elastic
    /// restart target.
    pub fn newest_verified(&self) -> Option<&LedgerEntry> {
        self.entries.iter().rev().find(|e| e.verified)
    }
}

/// CRC re-read of a just-written checkpoint: stream the file back from
/// disk and validate the framing (magic, version, size arithmetic) and
/// the trailing CRC-32 — the cheap proof that the bytes that actually
/// hit the disk are restorable, without parsing the arrays.  Returns
/// the verified byte count.
pub fn verify_checkpoint(path: &Path) -> Result<u64, CkptError> {
    let bytes = std::fs::read(path)?;
    if bytes.len() < 12 || &bytes[0..4] != super::MAGIC {
        return Err(CkptError::BadMagic);
    }
    let body = &bytes[..bytes.len() - 4];
    let want = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into()
        .unwrap());
    if crate::util::crc32(body) != want {
        return Err(CkptError::Corrupt);
    }
    let n_off = match u32::from_le_bytes(bytes[4..8].try_into().unwrap()) {
        1 => 24usize,
        2 => 232,
        v => return Err(CkptError::BadVersion(v)),
    };
    if bytes.len() < n_off + 8 {
        return Err(CkptError::SizeMismatch);
    }
    let n = u64::from_le_bytes(bytes[n_off..n_off + 8].try_into().unwrap());
    let expect = n
        .checked_mul(12)
        .and_then(|b| b.checked_add(n_off as u64 + 8 + 4))
        .ok_or(CkptError::SizeMismatch)?;
    if bytes.len() as u64 != expect {
        return Err(CkptError::SizeMismatch);
    }
    Ok(bytes.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::super::Checkpoint;
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "bertdist_ledger_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn entry(file: &str, data_step: u64, verified: bool) -> LedgerEntry {
        LedgerEntry {
            file: file.to_string(),
            step: data_step,
            data_step,
            bytes: 100,
            verified,
        }
    }

    #[test]
    fn round_trips_and_sorts_entries() {
        let dir = tmp("rt");
        let mut l = Ledger::default();
        l.record(entry("ckpt-0000000004.bckp", 4, true));
        l.record(entry("ckpt-0000000002.bckp", 2, true));
        l.record(entry("ckpt-0000000006.bckp", 6, false));
        l.save(&dir).unwrap();
        let back = Ledger::load(&dir);
        assert_eq!(back, l);
        let steps: Vec<u64> =
            back.entries.iter().map(|e| e.data_step).collect();
        assert_eq!(steps, vec![2, 4, 6]);
        // re-recording the same file replaces, not duplicates
        let mut l2 = back;
        l2.record(entry("ckpt-0000000006.bckp", 6, true));
        assert_eq!(l2.entries.len(), 3);
        assert_eq!(l2.status("ckpt-0000000006.bckp"), Some(true));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn newest_verified_skips_known_bad_tail() {
        let mut l = Ledger::default();
        l.record(entry("ckpt-0000000002.bckp", 2, true));
        l.record(entry("ckpt-0000000004.bckp", 4, true));
        l.record(entry("ckpt-0000000006.bckp", 6, false));
        assert_eq!(l.newest_verified().unwrap().data_step, 4);
        assert_eq!(l.status("ckpt-0000000006.bckp"), Some(false));
        assert_eq!(l.status("ckpt-9999999999.bckp"), None);
        // an all-bad ledger has no restore target
        let mut bad = Ledger::default();
        bad.record(entry("ckpt-0000000001.bckp", 1, false));
        assert!(bad.newest_verified().is_none());
    }

    #[test]
    fn missing_or_garbage_ledger_loads_empty() {
        let dir = tmp("garbage");
        assert_eq!(Ledger::load(&dir), Ledger::default());
        std::fs::write(Ledger::path(&dir), "{not json").unwrap();
        assert_eq!(Ledger::load(&dir), Ledger::default());
        // valid JSON with malformed entries: they are skipped, not fatal
        std::fs::write(
            Ledger::path(&dir),
            r#"{"version": 1, "entries": [{"file": 7},
                {"file": "ckpt-0000000003.bckp", "step": 3,
                 "data_step": 3, "bytes": 50, "verified": true}]}"#,
        ).unwrap();
        let l = Ledger::load(&dir);
        assert_eq!(l.entries.len(), 1);
        assert_eq!(l.newest_verified().unwrap().data_step, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_accepts_intact_and_rejects_flipped_bytes() {
        let dir = tmp("verify");
        let mut c = Checkpoint::new(16);
        c.step = 5;
        c.data_step = 7;
        let path = dir.join("v.bckp");
        c.save(&path).unwrap();
        let len = std::fs::metadata(&path).unwrap().len();
        assert_eq!(verify_checkpoint(&path).unwrap(), len);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x08;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(verify_checkpoint(&path),
                         Err(CkptError::Corrupt)));
        assert!(verify_checkpoint(&dir.join("absent.bckp")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
