//! Background checkpoint writing + keep-last-K rotation.
//!
//! The hot loop must never pay for a disk write: at an optimizer-step
//! boundary the trainer memcpys its state into a **recycled snapshot
//! buffer** ([`AsyncCheckpointWriter::save`] — the only on-loop cost),
//! and a long-lived writer thread performs the atomic temp+rename
//! write, then prunes the rotation directory down to the newest K
//! files.  Two snapshot buffers circulate (double buffering): the
//! trainer can capture step N+1 while step N is still being written;
//! only a writer that falls a full write behind ever blocks the loop,
//! and that wait is timed and reported (`TrainReport.checkpoint_s`).
//!
//! Rotation files are named `ckpt-{data_step:010}.bckp` — `data_step`
//! is the monotone attempted-step counter, so names are unique across
//! AMP-skipped stretches where `step` stands still, and the
//! lexicographically greatest file is always the newest.  A crash can
//! leave at most a stale `.tmp` (the rename never happened);
//! [`latest_checkpoint`] ignores those and [`prune_checkpoints`]
//! deletes them.
//!
//! After every write the worker CRC re-reads the file
//! ([`super::verify_checkpoint`]) and records the verdict in the
//! rotation directory's `ledger.json` ([`super::Ledger`]); rotation
//! then runs with the newest *verified* file protected, so keep-last-K
//! can never delete the only known-good restore target even when newer
//! writes came back torn.  No `.tmp` cleanup ever races the verify
//! re-read: the upfront sweep in [`AsyncCheckpointWriter::new`] runs
//! before the worker thread spawns, and every later sweep runs on the
//! worker thread itself, strictly after the save + verify of the file
//! in flight.

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

#[cfg(test)]
use super::v2_file_len;
use super::{v2_file_len_with_ef, verify_checkpoint, Checkpoint, CkptError,
            Ledger, LedgerEntry};

const FILE_PREFIX: &str = "ckpt-";
const FILE_SUFFIX: &str = ".bckp";

/// Rotation file name for a snapshot taken at `data_step`.
pub fn checkpoint_file_name(data_step: u64) -> String {
    format!("{FILE_PREFIX}{data_step:010}{FILE_SUFFIX}")
}

/// Parse a rotation file name back to its data_step.
fn parse_file_name(name: &str) -> Option<u64> {
    name.strip_prefix(FILE_PREFIX)?
        .strip_suffix(FILE_SUFFIX)?
        .parse()
        .ok()
}

/// All rotation checkpoints in `dir`, sorted oldest → newest.  Stale
/// `.tmp` files and foreign names are ignored (skipped, never an
/// error).  Two spellings of the same `data_step` (e.g. `ckpt-7.bckp`
/// next to `ckpt-0000000007.bckp`) tie-break by file name, so resume
/// selection and rotation order are deterministic regardless of
/// directory-iteration order.
pub fn list_checkpoints(dir: &Path)
    -> std::io::Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        if let Some(step) = entry
            .file_name()
            .to_str()
            .and_then(parse_file_name) {
            out.push((step, entry.path()));
        }
    }
    out.sort_unstable_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
    Ok(out)
}

/// The newest rotation checkpoint in `dir`, if any (`--resume DIR`).
pub fn latest_checkpoint(dir: &Path) -> std::io::Result<Option<PathBuf>> {
    Ok(list_checkpoints(dir)?.pop().map(|(_, p)| p))
}

/// Delete all but the newest `keep_last` rotation files, plus any stale
/// `ckpt-*.tmp` left behind by a crash between write and rename.
/// Returns how many files were removed.
pub fn prune_checkpoints(dir: &Path, keep_last: usize)
    -> std::io::Result<usize> {
    prune_checkpoints_protecting(dir, keep_last, None)
}

/// [`prune_checkpoints`] with one file name the rotation must never
/// delete, whatever its age: the writer passes the newest
/// ledger-VERIFIED checkpoint here, so even a run of torn newer writes
/// cannot rotate away the only known-good restore target.
pub fn prune_checkpoints_protecting(dir: &Path, keep_last: usize,
                                    protect: Option<&str>)
    -> std::io::Result<usize> {
    let mut removed = 0;
    let ckpts = list_checkpoints(dir)?;
    if ckpts.len() > keep_last {
        for (_, path) in &ckpts[..ckpts.len() - keep_last] {
            if protect.is_some()
                && path.file_name().and_then(|n| n.to_str()) == protect {
                continue;
            }
            std::fs::remove_file(path)?;
            removed += 1;
        }
    }
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if name.starts_with(FILE_PREFIX) && name.ends_with(".tmp") {
            std::fs::remove_file(entry.path())?;
            removed += 1;
        }
    }
    Ok(removed)
}

/// What the writer thread did over its lifetime (bench + log grist).
#[derive(Debug, Default, Clone, Copy)]
pub struct SaveStats {
    /// Checkpoints written.
    pub writes: u64,
    /// Bytes written (v2 file sizes).
    pub bytes: u64,
    /// Seconds the background thread spent inside atomic writes.
    pub write_s: f64,
    /// Old checkpoints / stale temp files removed by rotation.
    pub pruned: u64,
    /// Checkpoints whose post-write CRC re-read passed (ledger
    /// `verified: true`); `verified < writes` means torn/corrupt writes
    /// were detected and quarantined.
    pub verified: u64,
    /// Seconds spent in post-write verify re-reads (off-loop).
    pub verify_s: f64,
}

impl SaveStats {
    /// Off-loop write bandwidth.
    pub fn bytes_per_sec(&self) -> f64 {
        if self.write_s > 0.0 {
            self.bytes as f64 / self.write_s
        } else {
            0.0
        }
    }
}

/// Double-buffered background checkpoint writer (see module docs).
pub struct AsyncCheckpointWriter {
    job_tx: Option<Sender<Checkpoint>>,
    free_rx: Receiver<Checkpoint>,
    handle: Option<JoinHandle<Result<SaveStats, CkptError>>>,
}

impl AsyncCheckpointWriter {
    /// Open (creating) the rotation directory and start the writer
    /// thread, priming the ring with two empty snapshot buffers (they
    /// size themselves to the model on first use, then recycle).
    /// Stale `.tmp` crash leftovers in `dir` are removed up front.
    ///
    /// # Examples
    ///
    /// ```
    /// use bertdist::checkpoint::AsyncCheckpointWriter;
    ///
    /// let dir = std::env::temp_dir()
    ///     .join(format!("bertdist_doc_writer_{}", std::process::id()));
    /// # let _ = std::fs::remove_dir_all(&dir);
    /// let mut writer = AsyncCheckpointWriter::new(&dir, 3)?;
    /// // The hot loop pays only this memcpy into a recycled buffer;
    /// // the atomic write + rotation run on the writer thread.
    /// let exposed_s = writer.save(|c| {
    ///     c.step = 1;
    ///     c.data_step = 1;
    ///     c.fill_arrays(&[0.5; 4], &[0.0; 4], &[0.0; 4]);
    /// })?;
    /// assert!(exposed_s >= 0.0);
    /// let stats = writer.finish()?;
    /// assert_eq!(stats.writes, 1);
    /// # std::fs::remove_dir_all(&dir).ok();
    /// # Ok::<(), bertdist::checkpoint::CkptError>(())
    /// ```
    pub fn new(dir: &Path, keep_last: usize)
        -> Result<AsyncCheckpointWriter, CkptError> {
        std::fs::create_dir_all(dir)?;
        prune_checkpoints(dir, usize::MAX)?;
        let keep_last = keep_last.max(1);
        let (job_tx, job_rx) = channel::<Checkpoint>();
        let (free_tx, free_rx) = channel::<Checkpoint>();
        for _ in 0..2 {
            free_tx.send(Checkpoint::new(0)).expect("prime snapshot ring");
        }
        let dir = dir.to_path_buf();
        let handle = std::thread::Builder::new()
            .name("ckpt-writer".into())
            .spawn(move || worker(dir, keep_last, job_rx, free_tx))
            .map_err(|e| CkptError::Writer(e.to_string()))?;
        Ok(AsyncCheckpointWriter {
            job_tx: Some(job_tx),
            free_rx,
            handle: Some(handle),
        })
    }

    /// Snapshot on the hot loop: pop a recycled buffer (blocking only
    /// when the writer is a full write behind), let `fill` capture the
    /// trainer state into it, and hand it to the writer thread.
    /// Returns the seconds this call spent — the checkpoint cost that
    /// was actually exposed on the hot loop.
    pub fn save<F: FnOnce(&mut Checkpoint)>(&mut self, fill: F)
        -> Result<f64, CkptError> {
        let t0 = Instant::now();
        let mut snap = match self.free_rx.recv() {
            Ok(s) => s,
            Err(_) => return Err(self.worker_error()),
        };
        fill(&mut snap);
        let tx = self
            .job_tx
            .as_ref()
            .expect("save called after finish");
        if tx.send(snap).is_err() {
            return Err(self.worker_error());
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Close the ring, drain pending writes, join the writer thread,
    /// and return (or surface) what it did.
    pub fn finish(mut self) -> Result<SaveStats, CkptError> {
        self.job_tx = None;
        match self.handle.take() {
            // a prior save() already joined the failed worker
            None => Err(CkptError::Writer("writer already failed".into())),
            Some(h) => match h.join() {
                Ok(r) => r,
                Err(_) => {
                    Err(CkptError::Writer("writer thread panicked".into()))
                }
            },
        }
    }

    /// The ring closed under us: join the worker and surface its error.
    fn worker_error(&mut self) -> CkptError {
        self.job_tx = None;
        match self.handle.take().map(|h| h.join()) {
            Some(Ok(Err(e))) => e,
            Some(Ok(Ok(_))) | None => {
                CkptError::Writer("writer thread exited unexpectedly".into())
            }
            Some(Err(_)) => {
                CkptError::Writer("writer thread panicked".into())
            }
        }
    }
}

impl Drop for AsyncCheckpointWriter {
    fn drop(&mut self) {
        // Closing the job channel lets the worker drain and exit; join
        // so no write is abandoned mid-flight.
        self.job_tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker(dir: PathBuf, keep_last: usize, job_rx: Receiver<Checkpoint>,
          free_tx: Sender<Checkpoint>) -> Result<SaveStats, CkptError> {
    let mut stats = SaveStats::default();
    // Reload any existing ledger so a restarted run keeps the prior
    // verify verdicts for files it did not rewrite.
    let mut ledger = Ledger::load(&dir);
    while let Ok(snap) = job_rx.recv() {
        let name = checkpoint_file_name(snap.data_step);
        let path = dir.join(&name);
        let ef_lens: Vec<usize> =
            snap.ef_residuals.iter().map(|r| r.len()).collect();
        let file_bytes =
            v2_file_len_with_ef(snap.params.len(), &ef_lens) as u64;
        let t0 = Instant::now();
        snap.save(&path)?;
        stats.write_s += t0.elapsed().as_secs_f64();
        stats.writes += 1;
        stats.bytes += file_bytes;
        // Verify re-read: CRC the bytes that actually hit the disk.  A
        // torn or bit-flipped write is recorded as unverified — resume
        // selection skips it and rotation keeps the last good file.
        let tv = Instant::now();
        let verified = match verify_checkpoint(&path) {
            Ok(_) => true,
            Err(e) => {
                log::warn!("checkpoint {} failed post-write verify: {e} \
                            — marked unverified in the ledger",
                           path.display());
                false
            }
        };
        stats.verify_s += tv.elapsed().as_secs_f64();
        stats.verified += verified as u64;
        ledger.record(LedgerEntry {
            file: name,
            step: snap.step,
            data_step: snap.data_step,
            bytes: file_bytes,
            verified,
        });
        // Rotate AFTER the verify so the protection target is current:
        // the newest VERIFIED file survives keep-last-K regardless of
        // how many unverified writes sit above it.
        let protect = ledger.newest_verified().map(|e| e.file.clone());
        stats.pruned += prune_checkpoints_protecting(
            &dir, keep_last, protect.as_deref())? as u64;
        ledger.retain_files(|f| dir.join(f).exists());
        ledger.save(&dir)?;
        // Receiver gone during shutdown: the buffer just drops.
        let _ = free_tx.send(snap);
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("bertdist_ckpt_writer_{name}_{}",
                          std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    fn snap_filler(n: usize, step: u64) -> impl FnOnce(&mut Checkpoint) {
        move |c: &mut Checkpoint| {
            c.step = step;
            c.data_step = step;
            c.params.resize(n, 0.0);
            c.m.resize(n, 0.0);
            c.v.resize(n, 0.0);
            c.params.fill(step as f32);
        }
    }

    #[test]
    fn rotation_keeps_only_the_newest_k() {
        let dir = tmp("rotate");
        let mut w = AsyncCheckpointWriter::new(&dir, 2).unwrap();
        for step in 1..=5u64 {
            w.save(snap_filler(16, step)).unwrap();
        }
        let stats = w.finish().unwrap();
        assert_eq!(stats.writes, 5);
        assert_eq!(stats.bytes, 5 * v2_file_len(16) as u64);
        let left = list_checkpoints(&dir).unwrap();
        let steps: Vec<u64> = left.iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, vec![4, 5]);
        // the surviving newest file really holds the newest state
        let c = Checkpoint::load(&latest_checkpoint(&dir).unwrap().unwrap())
            .unwrap();
        assert_eq!(c.step, 5);
        assert!(c.params.iter().all(|&x| x == 5.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_tmp_is_ignored_and_cleaned() {
        let dir = tmp("staletmp");
        std::fs::create_dir_all(&dir).unwrap();
        // a valid checkpoint + a crash leftover with a HIGHER step
        let mut c = Checkpoint::new(4);
        c.step = 3;
        c.data_step = 3;
        c.save(&dir.join(checkpoint_file_name(3))).unwrap();
        std::fs::write(dir.join("ckpt-0000000009.tmp"), b"partial write")
            .unwrap();
        // resume resolution never sees the tmp
        let latest = latest_checkpoint(&dir).unwrap().unwrap();
        assert!(latest.ends_with(checkpoint_file_name(3)));
        assert_eq!(Checkpoint::load(&latest).unwrap().step, 3);
        // pruning removes it
        let removed = prune_checkpoints(&dir, 8).unwrap();
        assert_eq!(removed, 1);
        assert!(!dir.join("ckpt-0000000009.tmp").exists());
        assert!(dir.join(checkpoint_file_name(3)).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn writer_failure_surfaces_as_error_not_panic() {
        let dir = tmp("failure");
        let mut w = AsyncCheckpointWriter::new(&dir, 2).unwrap();
        // yank the directory out from under the worker
        std::fs::remove_dir_all(&dir).unwrap();
        // the enqueue may still succeed (the failure lands on the
        // worker thread); the error must surface by finish at latest
        let first = w.save(snap_filler(8, 1));
        let second = w.save(snap_filler(8, 2));
        let finished = w.finish();
        assert!(
            first.is_err() || second.is_err() || finished.is_err(),
            "a write into a deleted dir must fail loudly"
        );
    }

    #[test]
    fn worker_maintains_a_verified_ledger() {
        let dir = tmp("ledger");
        let mut w = AsyncCheckpointWriter::new(&dir, 2).unwrap();
        for step in 1..=3u64 {
            w.save(snap_filler(16, step)).unwrap();
        }
        let stats = w.finish().unwrap();
        assert_eq!(stats.writes, 3);
        assert_eq!(stats.verified, 3, "all intact writes verify");
        assert!(stats.verify_s >= 0.0);
        let ledger = Ledger::load(&dir);
        // rotation swept file 1 out of the ledger too
        let files: Vec<String> =
            ledger.entries.iter().map(|e| e.file.clone()).collect();
        assert_eq!(files, vec![checkpoint_file_name(2),
                               checkpoint_file_name(3)]);
        assert!(ledger.entries.iter().all(|e| e.verified));
        assert_eq!(ledger.newest_verified().unwrap().data_step, 3);
        assert_eq!(ledger.newest_verified().unwrap().bytes,
                   v2_file_len(16) as u64);
        // a fresh writer in the same dir resumes the ledger, keeping
        // the verdicts for files it did not rewrite
        let mut w = AsyncCheckpointWriter::new(&dir, 2).unwrap();
        w.save(snap_filler(16, 4)).unwrap();
        w.finish().unwrap();
        let ledger = Ledger::load(&dir);
        assert_eq!(ledger.newest_verified().unwrap().data_step, 4);
        assert_eq!(ledger.status(&checkpoint_file_name(3)), Some(true));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_never_deletes_the_protected_file() {
        let dir = tmp("protect");
        std::fs::create_dir_all(&dir).unwrap();
        for step in 1..=3u64 {
            let mut c = Checkpoint::new(4);
            c.data_step = step;
            c.save(&dir.join(checkpoint_file_name(step))).unwrap();
        }
        // protect the OLDEST file (as if 2 and 3 failed their verify)
        let name1 = checkpoint_file_name(1);
        let removed =
            prune_checkpoints_protecting(&dir, 1, Some(&name1)).unwrap();
        assert_eq!(removed, 1, "only the unprotected old file goes");
        assert!(dir.join(&name1).exists(), "protected file survives");
        assert!(!dir.join(checkpoint_file_name(2)).exists());
        assert!(dir.join(checkpoint_file_name(3)).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_step_spellings_order_deterministically() {
        let dir = tmp("ties");
        std::fs::create_dir_all(&dir).unwrap();
        // same data_step, two spellings, plus a foreign file to skip
        for name in ["ckpt-7.bckp", "ckpt-0000000007.bckp"] {
            let mut c = Checkpoint::new(2);
            c.data_step = 7;
            c.save(&dir.join(name)).unwrap();
        }
        std::fs::write(dir.join("notes.txt"), b"ignored").unwrap();
        let a = list_checkpoints(&dir).unwrap();
        let b = list_checkpoints(&dir).unwrap();
        assert_eq!(a, b, "listing order is stable");
        assert_eq!(a.len(), 2);
        assert_eq!((a[0].0, a[1].0), (7, 7));
        // ties break by name: zero-padded < short spelling, so latest
        // is deterministic too
        assert!(a[0].1 < a[1].1);
        assert!(latest_checkpoint(&dir).unwrap().unwrap()
            .ends_with("ckpt-7.bckp"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_names_sort_with_steps() {
        assert_eq!(checkpoint_file_name(7), "ckpt-0000000007.bckp");
        assert_eq!(parse_file_name("ckpt-0000000007.bckp"), Some(7));
        assert_eq!(parse_file_name("ckpt-0000000007.tmp"), None);
        assert_eq!(parse_file_name("other.bckp"), None);
        assert!(checkpoint_file_name(9) < checkpoint_file_name(10));
    }
}
