//! The BERT data pipeline, built from scratch (paper §3.1):
//! corpus → tokenize (WordPiece-lite) → sentence pairs (NSP) →
//! shard (bshard, §4.1) → per-epoch masking (15% MLM) → batches.
//!
//! * [`corpus`]   — synthetic Zipf corpus generator + real-text loader
//! * [`vocab`]    — frequency-based WordPiece-lite vocabulary builder
//! * [`tokenizer`]— greedy longest-match subword tokenizer
//! * [`example`]  — sentence-pair records, serialized for `bshard`
//! * [`masking`]  — MLM 80/10/10 masking + NSP batch assembly
//! * [`pipeline`] — end-to-end: corpus → shards; shards → batches
//! * [`prefetch`] — per-rank producer threads + bounded ring of reusable
//!                  batch buffers (§4.1: input prep overlaps training)

pub mod corpus;
pub mod example;
pub mod masking;
pub mod pipeline;
pub mod prefetch;
pub mod tokenizer;
pub mod vocab;

pub use corpus::SyntheticCorpus;
pub use example::PairExample;
pub use masking::{Batch, MaskingConfig};
pub use pipeline::{build_shards, ShardedDataset};
pub use prefetch::{BatchCursor, Prefetcher};
pub use tokenizer::Tokenizer;
pub use vocab::Vocab;

/// Reserved special token ids (fixed, vocabulary-independent).
pub mod special {
    pub const PAD: u32 = 0;
    pub const CLS: u32 = 1;
    pub const SEP: u32 = 2;
    pub const MASK: u32 = 3;
    pub const UNK: u32 = 4;
    /// First id available to learned vocabulary entries.
    pub const FIRST_FREE: u32 = 5;
}
