//! Per-rank batch prefetch (paper §4.1: data preparation must overlap
//! training, never serialize it).
//!
//! Two pieces:
//!
//! * [`BatchCursor`] — the deterministic batch stream of one rank: a
//!   pure function from the rank's monotone micro-batch counter to a
//!   masked [`Batch`], including the per-epoch reshuffle (the epoch
//!   order advances exactly when the counter wraps the rank's
//!   batches-per-epoch — fixing the stale `step / 100` epoch derivation
//!   the old trainer computed once before its step loop).  Both the
//!   synchronous fallback and the prefetch producer run this SAME
//!   cursor, which is what makes the two paths bitwise-identical.
//! * [`Prefetcher`] — one long-lived producer thread per rank feeding
//!   prebuilt batches over a bounded ring of reusable [`Batch`] buffers
//!   (depth 2 = classic double buffering).  The ring is two mpsc
//!   channels: `free` carries empty buffers back to the producer,
//!   `ready` carries filled ones forward; the bound is the number of
//!   buffers in circulation, so the producer can run at most `depth`
//!   batches ahead and the steady state allocates nothing.
//!
//! The consumer side reports how long it was *blocked* waiting for a
//! ready batch — the `input_stall_s` lane of the trainer's stall
//! accounting (zero when the producer keeps up; the whole build time
//! when running synchronously).
//!
//! ## Invariants
//!
//! * **Bitwise determinism** — every batch is a pure function of the
//!   cursor *position* (seed, rank, global micro index), never of run
//!   history: prefetched and synchronous streams are bitwise
//!   interchangeable, and a cursor opened at micro `k` (a resumed run)
//!   emits exactly what a from-zero cursor emits from `k` on.
//! * **Zero alloc, bounded memory** — `depth` recycled [`Batch`]
//!   buffers circulate per rank; the producer can run at most `depth`
//!   batches ahead and the steady state allocates nothing.
//! * **No lifetime erasure** — producers are scoped threads; the
//!   compiler proves the dataset borrows outlive them.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::thread::{Scope, ScopedJoinHandle};
use std::time::Instant;

use anyhow::Result;

use super::masking::{Batch, MaskingConfig};
use super::pipeline::ShardedDataset;
use crate::util::Pcg64;

/// Deterministic per-rank batch stream: `fill_next` builds the batch for
/// the cursor's current global micro-batch index and advances.  Epoch
/// `e` covers indices `[e * bpe, (e + 1) * bpe)` where `bpe` is the
/// rank's ceil batches-per-epoch (tail examples stay in rotation); the
/// epoch order is re-drawn from [`ShardedDataset::epoch_order`] at every
/// wrap, so long runs keep reshuffling deterministically.
///
/// The masking RNG is re-derived **per batch** from `(seed, rank, micro
/// index)` — like the epoch order, masking is a pure function of the
/// cursor *position*, never of run history.  This is what makes a
/// checkpoint-resumed stream bitwise-identical to the uninterrupted one
/// (the v2 resume-exactness guarantee): a cursor opened at micro `k`
/// emits exactly the batches a from-zero cursor emits at `k, k+1, ...`.
/// (The old sequentially-consumed stream made every restart replay
/// different masks.)
pub struct BatchCursor<'a> {
    ds: &'a ShardedDataset,
    cfg: MaskingConfig,
    seed: u64,
    batch: usize,
    seq: usize,
    epoch: usize,
    order: Vec<usize>,
    bpe: u64,
    next: u64,
}

impl<'a> BatchCursor<'a> {
    /// Cursor over `ds` starting at global micro-batch `start_micro`
    /// (the trainer passes `data_step * accum_steps` so a resumed run
    /// lands on the same epoch order it left off in).
    pub fn new(ds: &'a ShardedDataset, cfg: MaskingConfig, seed: u64,
               batch: usize, seq: usize, start_micro: u64)
               -> BatchCursor<'a> {
        let bpe = ((ds.len() + batch.max(1) - 1) / batch.max(1)).max(1)
            as u64;
        let epoch = (start_micro / bpe) as usize;
        BatchCursor {
            order: ds.epoch_order(epoch, seed),
            ds,
            cfg,
            seed,
            batch,
            seq,
            epoch,
            bpe,
            next: start_micro,
        }
    }

    /// Global micro-batch index the next `fill_next` will produce.
    pub fn position(&self) -> u64 {
        self.next
    }

    /// Epoch the cursor is currently drawing from.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Batches per epoch this cursor wraps on (ceil division — the tail
    /// batch that wraps to the head of the order still counts).
    pub fn batches_per_epoch(&self) -> u64 {
        self.bpe
    }

    /// The position-keyed masking RNG for global micro-batch `micro`
    /// (same idiom as [`ShardedDataset::epoch_order`]'s epoch keying).
    fn mask_rng(&self, micro: u64) -> Pcg64 {
        Pcg64::with_stream(
            self.seed ^ micro.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            0xDA7A + self.ds.rank() as u64,
        )
    }

    /// Build the next batch in the stream into `out` (recycled in
    /// place) and advance the cursor.
    pub fn fill_next(&mut self, out: &mut Batch) {
        let epoch = (self.next / self.bpe) as usize;
        if epoch != self.epoch {
            self.epoch = epoch;
            self.order = self.ds.epoch_order(epoch, self.seed);
        }
        let idx = (self.next % self.bpe) as usize;
        let mut rng = self.mask_rng(self.next);
        self.ds.batch_into(&self.order, idx, self.batch, self.seq,
                           &self.cfg, &mut rng, out);
        self.next += 1;
    }
}

/// One rank's consumer-side lane of the prefetch ring.  Endpoints sit
/// behind a `Mutex` because the pool's compute workers reach them
/// through a shared `&Prefetcher`; each lane is touched only by its own
/// rank's worker, so the locks are uncontended.
struct Lane {
    ready_rx: Receiver<Batch>,
    free_tx: Sender<Batch>,
}

/// One long-lived producer thread per rank, `depth` reusable batch
/// buffers per ring.  Producers are **scoped** threads
/// (`std::thread::scope`): the caller opens a scope around the
/// training loop, so the dataset borrows are enforced by the compiler
/// with no lifetime erasure — the scope cannot close until every
/// producer has exited.  Dropping the prefetcher closes the rings and
/// joins the producers right there; even a leaked prefetcher
/// (`mem::forget`) can at worst deadlock the scope exit, never leave a
/// thread reading freed data.
pub struct Prefetcher<'scope> {
    lanes: Vec<Mutex<Lane>>,
    handles: Vec<ScopedJoinHandle<'scope, ()>>,
    depth: usize,
}

impl<'scope> Prefetcher<'scope> {
    /// Spawn one producer per dataset (= per rank) inside `scope`, each
    /// primed with `depth >= 1` recycled [`Batch`] buffers and producing
    /// the exact [`BatchCursor`] stream from `start_micro`.
    pub fn spawn<'env>(scope: &'scope Scope<'scope, 'env>,
                       datasets: &'env [ShardedDataset],
                       cfg: &MaskingConfig, seed: u64, batch: usize,
                       seq: usize, start_micro: u64, depth: usize)
                       -> Prefetcher<'scope> {
        assert!(depth >= 1, "prefetch depth must be >= 1 (0 = run sync)");
        let mut lanes = Vec::with_capacity(datasets.len());
        let mut handles = Vec::with_capacity(datasets.len());
        for (r, ds) in datasets.iter().enumerate() {
            let (free_tx, free_rx) = channel::<Batch>();
            let (ready_tx, ready_rx) = channel::<Batch>();
            for _ in 0..depth {
                free_tx
                    .send(Batch::zeros(batch, seq))
                    .expect("prime prefetch ring");
            }
            let cfg = cfg.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("prefetch-{r}"))
                    .spawn_scoped(scope, move || {
                        let mut cursor = BatchCursor::new(
                            ds, cfg, seed, batch, seq, start_micro);
                        // Blocks on `free` until the consumer recycles a
                        // buffer (the ring bound) and exits when either
                        // channel closes (prefetcher dropped).
                        while let Ok(mut buf) = free_rx.recv() {
                            cursor.fill_next(&mut buf);
                            if ready_tx.send(buf).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawn prefetch producer"),
            );
            lanes.push(Mutex::new(Lane { ready_rx, free_tx }));
        }
        Prefetcher { lanes, handles, depth }
    }

    pub fn world(&self) -> usize {
        self.lanes.len()
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Pop rank `r`'s next ready batch, returning it together with the
    /// seconds this call spent *blocked* on the producer (the exposed
    /// input stall; ~0 when the producer keeps ahead).
    pub fn pop(&self, rank: usize) -> Result<(Batch, f64)> {
        let lane = self.lanes[rank].lock().expect("prefetch lane poisoned");
        let t0 = Instant::now();
        let b = lane.ready_rx.recv().map_err(|_| {
            anyhow::anyhow!("prefetch producer for rank {rank} exited")
        })?;
        Ok((b, t0.elapsed().as_secs_f64()))
    }

    /// Hand a consumed batch buffer back to rank `r`'s producer for
    /// reuse.  A producer that already exited (pool shutting down) just
    /// drops the buffer.
    pub fn recycle(&self, rank: usize, buf: Batch) {
        let lane = self.lanes[rank].lock().expect("prefetch lane poisoned");
        let _ = lane.free_tx.send(buf);
    }
}

impl Drop for Prefetcher<'_> {
    fn drop(&mut self) {
        // Closing both ring endpoints unblocks a producer whether it is
        // waiting on `free` or about to send on `ready`; then join.
        self.lanes.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticCorpus;
    use crate::data::{build_shards, Vocab};
    use std::path::Path;

    fn setup(dir: &Path) -> (Vocab, Vec<ShardedDataset>) {
        let _ = std::fs::remove_dir_all(dir);
        let docs = SyntheticCorpus::new(3, 900).documents(10, 6, 8);
        let vocab = Vocab::from_documents(&docs, 1024);
        build_shards(&docs, &vocab, 2, dir, "train", 7).unwrap();
        let ds = (0..2)
            .map(|r| ShardedDataset::open(dir, "train", r, 2).unwrap())
            .collect();
        (vocab, ds)
    }

    fn cfg(vocab: &Vocab) -> MaskingConfig {
        MaskingConfig { vocab_size: vocab.len() as u32, ..Default::default() }
    }

    #[test]
    fn cursor_is_deterministic_and_advances_epochs_on_wrap() {
        let dir = std::env::temp_dir().join("bertdist_prefetch_cursor");
        let (vocab, ds) = setup(&dir);
        let c = cfg(&vocab);
        let mut a = BatchCursor::new(&ds[0], c.clone(), 42, 4, 32, 0);
        let mut b = BatchCursor::new(&ds[0], c.clone(), 42, 4, 32, 0);
        let bpe = a.batches_per_epoch();
        assert_eq!(bpe, (ds[0].len() as u64 + 3) / 4);
        let mut buf_a = Batch::zeros(4, 32);
        let mut buf_b = Batch::zeros(4, 32);
        // two full epochs: identical twin streams, epoch wraps exactly
        // at bpe, and the order really is re-drawn (epoch() advances —
        // lazily, on the fill that crosses the boundary).
        for i in 0..(2 * bpe) {
            assert_eq!(a.position(), i);
            a.fill_next(&mut buf_a);
            b.fill_next(&mut buf_b);
            assert_eq!(a.epoch() as u64, i / bpe, "after filling micro {i}");
            assert_eq!(buf_a, buf_b, "micro {i} diverged");
        }
        assert_eq!(a.epoch(), 1);
        a.fill_next(&mut buf_a);
        assert_eq!(a.epoch(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cursor_resume_is_bitwise_identical_to_uninterrupted() {
        // The data-layer half of the exact-resume guarantee: a cursor
        // opened at micro k (what a restored trainer does) must emit
        // exactly the batches the from-zero cursor emits from k on —
        // masking included — across an epoch boundary.
        let dir = std::env::temp_dir().join("bertdist_prefetch_exact");
        let (vocab, ds) = setup(&dir);
        let c = cfg(&vocab);
        let mut full = BatchCursor::new(&ds[0], c.clone(), 77, 4, 32, 0);
        let bpe = full.batches_per_epoch();
        let n = 2 * bpe + 3;
        let mut buf = Batch::zeros(4, 32);
        let mut want: Vec<Batch> = Vec::new();
        for _ in 0..n {
            full.fill_next(&mut buf);
            want.push(buf.clone());
        }
        // resume at every boundary, including mid-epoch and at the wrap
        for k in [1, bpe - 1, bpe, bpe + 1, n - 1] {
            let mut resumed =
                BatchCursor::new(&ds[0], c.clone(), 77, 4, 32, k);
            for i in k..n {
                resumed.fill_next(&mut buf);
                assert_eq!(buf, want[i as usize],
                           "resume at {k}: micro {i} diverged");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cursor_resumes_at_the_right_epoch() {
        let dir = std::env::temp_dir().join("bertdist_prefetch_resume");
        let (vocab, ds) = setup(&dir);
        let c = cfg(&vocab);
        let probe = BatchCursor::new(&ds[1], c.clone(), 1, 4, 32, 0);
        let bpe = probe.batches_per_epoch();
        let resumed =
            BatchCursor::new(&ds[1], c.clone(), 1, 4, 32, bpe + 2);
        assert_eq!(resumed.epoch(), 1);
        assert_eq!(resumed.position(), bpe + 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prefetched_stream_matches_synchronous_bitwise() {
        // The acceptance invariant at the data layer: a depth-2
        // prefetcher must hand every rank the exact batches the
        // synchronous cursor builds, across epoch wraps.
        let dir = std::env::temp_dir().join("bertdist_prefetch_bitwise");
        let (vocab, ds) = setup(&dir);
        let c = cfg(&vocab);
        std::thread::scope(|scope| {
            let pf = Prefetcher::spawn(scope, &ds, &c, 99, 4, 32, 0, 2);
            assert_eq!(pf.world(), 2);
            assert_eq!(pf.depth(), 2);
            let mut cursors: Vec<BatchCursor> = ds
                .iter()
                .map(|d| BatchCursor::new(d, c.clone(), 99, 4, 32, 0))
                .collect();
            let steps = 2 * cursors[0].batches_per_epoch() + 3;
            let mut want = Batch::zeros(4, 32);
            for i in 0..steps {
                for r in 0..2 {
                    cursors[r].fill_next(&mut want);
                    let (got, stall) = pf.pop(r).unwrap();
                    assert!(stall >= 0.0);
                    assert_eq!(got, want, "rank {r} micro {i}");
                    pf.recycle(r, got);
                }
            }
            drop(pf); // joins producers cleanly mid-stream
        });
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dropping_an_idle_prefetcher_does_not_hang() {
        let dir = std::env::temp_dir().join("bertdist_prefetch_drop");
        let (vocab, ds) = setup(&dir);
        std::thread::scope(|scope| {
            let pf =
                Prefetcher::spawn(scope, &ds, &cfg(&vocab), 5, 2, 16, 0, 3);
            // never popped: producers are parked mid-ring; drop must
            // join (and the scope exit must not hang afterwards).
            drop(pf);
        });
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
