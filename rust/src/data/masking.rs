//! MLM masking + batch assembly (paper §3.1.1).
//!
//! Implements BERT's exact masking recipe: select 15% of non-special
//! positions (capped at `max_predictions`), of which 80% become `[MASK]`,
//! 10% a random token, 10% stay unchanged — labels carry the original id,
//! `IGNORE` (-1) elsewhere.  Batches are the i32 tensors the AOT train
//! step consumes (see python/compile/model.py `make_train_step`).

use super::example::PairExample;
use super::special;
use crate::util::Pcg64;

pub const IGNORE: i32 = -1;

/// Masking hyper-parameters (paper Table 6: 20 preds @128, 80 @512).
#[derive(Debug, Clone)]
pub struct MaskingConfig {
    pub mask_prob: f64,
    pub max_predictions: usize,
    /// Vocab size for random-replacement draws.
    pub vocab_size: u32,
    /// 80/10/10 split of selected positions.
    pub mask_frac: f64,
    pub random_frac: f64,
}

impl Default for MaskingConfig {
    fn default() -> Self {
        Self {
            mask_prob: 0.15,
            max_predictions: 20,
            vocab_size: 8192,
            mask_frac: 0.8,
            random_frac: 0.1,
        }
    }
}

/// A training batch in the AOT train-step layout (row-major [B, S]).
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub input_ids: Vec<i32>,
    pub token_type_ids: Vec<i32>,
    pub attention_mask: Vec<i32>,
    pub mlm_labels: Vec<i32>,
    pub nsp_labels: Vec<i32>,
}

impl Batch {
    pub fn zeros(batch: usize, seq: usize) -> Self {
        Self {
            batch,
            seq,
            input_ids: vec![special::PAD as i32; batch * seq],
            token_type_ids: vec![0; batch * seq],
            attention_mask: vec![0; batch * seq],
            mlm_labels: vec![IGNORE; batch * seq],
            nsp_labels: vec![0; batch],
        }
    }

    /// Re-initialize a reused buffer to the `zeros` state in place —
    /// the recycling half of the zero-copy batch path: capacity is kept,
    /// so a batch buffer cycling through the prefetch ring performs no
    /// heap allocation after its first use.
    pub fn reset(&mut self, batch: usize, seq: usize) {
        self.batch = batch;
        self.seq = seq;
        self.input_ids.clear();
        self.input_ids.resize(batch * seq, special::PAD as i32);
        self.token_type_ids.clear();
        self.token_type_ids.resize(batch * seq, 0);
        self.attention_mask.clear();
        self.attention_mask.resize(batch * seq, 0);
        self.mlm_labels.clear();
        self.mlm_labels.resize(batch * seq, IGNORE);
        self.nsp_labels.clear();
        self.nsp_labels.resize(batch, 0);
    }

    /// Number of prediction targets in the batch.
    pub fn num_predictions(&self) -> usize {
        self.mlm_labels.iter().filter(|&&l| l != IGNORE).count()
    }

    /// Number of real (non-pad) tokens.
    pub fn num_tokens(&self) -> usize {
        self.attention_mask.iter().filter(|&&m| m != 0).count()
    }
}

/// Assemble one sequence: [CLS] a [SEP] b [SEP], then apply MLM masking.
/// Writes into row `row` of `out`.  Deterministic given `rng` state.
///
/// Copy-free: the example is read through slices bounded by
/// [`PairExample::truncated_lens`] — the old clone-then-truncate of the
/// whole example (two token `Vec`s per row per micro-step) is gone, and
/// the emitted tokens are byte-identical (`truncate` pops from the tail,
/// so the surviving tokens are exactly these prefixes).
pub fn assemble_into(out: &mut Batch, row: usize, ex: &PairExample,
                     cfg: &MaskingConfig, rng: &mut Pcg64) {
    let seq = out.seq;
    let (la, lb) = ex.truncated_lens(seq);

    let base = row * seq;
    // layout: CLS a... SEP b... SEP PAD...
    let mut pos = 0usize;
    let put = |out: &mut Batch, id: u32, seg: i32, pos: &mut usize| {
        out.input_ids[base + *pos] = id as i32;
        out.token_type_ids[base + *pos] = seg;
        out.attention_mask[base + *pos] = 1;
        *pos += 1;
    };
    put(out, special::CLS, 0, &mut pos);
    for &t in &ex.tokens_a[..la] {
        put(out, t, 0, &mut pos);
    }
    put(out, special::SEP, 0, &mut pos);
    for &t in &ex.tokens_b[..lb] {
        put(out, t, 1, &mut pos);
    }
    put(out, special::SEP, 1, &mut pos);
    let used = pos;
    for p in used..seq {
        out.input_ids[base + p] = special::PAD as i32;
        out.token_type_ids[base + p] = 0;
        out.attention_mask[base + p] = 0;
        out.mlm_labels[base + p] = IGNORE;
    }
    out.nsp_labels[row] = ex.nsp_label();

    // --- MLM masking over maskable positions (not CLS/SEP/PAD) ---
    let maskable: Vec<usize> = (0..used)
        .filter(|&p| {
            let id = out.input_ids[base + p] as u32;
            id != special::CLS && id != special::SEP && id != special::PAD
        })
        .collect();
    let want = ((maskable.len() as f64 * cfg.mask_prob).round() as usize)
        .min(cfg.max_predictions)
        .min(maskable.len());
    // reset labels for the used region
    for p in 0..used {
        out.mlm_labels[base + p] = IGNORE;
    }
    if want == 0 {
        return;
    }
    let mut order = maskable;
    rng.shuffle(&mut order);
    for &p in order.iter().take(want) {
        let original = out.input_ids[base + p];
        out.mlm_labels[base + p] = original;
        let roll = rng.next_f64();
        if roll < cfg.mask_frac {
            out.input_ids[base + p] = special::MASK as i32;
        } else if roll < cfg.mask_frac + cfg.random_frac {
            let r = special::FIRST_FREE
                + rng.gen_range((cfg.vocab_size - special::FIRST_FREE) as u64)
                    as u32;
            out.input_ids[base + p] = r as i32;
        } // else: keep original token
    }
}

/// Build a full batch from `examples` (padded/truncated to `seq`).
pub fn build_batch(examples: &[PairExample], seq: usize, cfg: &MaskingConfig,
                   rng: &mut Pcg64) -> Batch {
    let mut out = Batch::zeros(examples.len(), seq);
    for (row, ex) in examples.iter().enumerate() {
        assemble_into(&mut out, row, ex, cfg, rng);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn ex(a: usize, b: usize, next: bool) -> PairExample {
        PairExample {
            tokens_a: (0..a as u32).map(|i| 10 + i).collect(),
            tokens_b: (0..b as u32).map(|i| 100 + i).collect(),
            is_next: next,
        }
    }

    fn cfg() -> MaskingConfig {
        MaskingConfig { vocab_size: 1000, ..Default::default() }
    }

    #[test]
    fn layout_cls_sep_segments() {
        let mut rng = Pcg64::new(0);
        let b = build_batch(&[ex(3, 2, true)], 16, &cfg(), &mut rng);
        assert_eq!(b.input_ids[0], special::CLS as i32);
        assert_eq!(b.input_ids[4], special::SEP as i32);
        assert_eq!(b.input_ids[7], special::SEP as i32);
        assert_eq!(&b.token_type_ids[..8], &[0, 0, 0, 0, 0, 1, 1, 1]);
        assert_eq!(&b.attention_mask[..9], &[1, 1, 1, 1, 1, 1, 1, 1, 0]);
        assert_eq!(b.nsp_labels[0], 0);
        // pad region
        assert!(b.input_ids[8..].iter().all(|&t| t == special::PAD as i32));
        assert!(b.mlm_labels[8..].iter().all(|&l| l == IGNORE));
    }

    #[test]
    fn masking_respects_budget_and_positions() {
        let mut rng = Pcg64::new(1);
        let c = MaskingConfig { max_predictions: 4, ..cfg() };
        let b = build_batch(&[ex(20, 20, false)], 64, &c, &mut rng);
        let preds = b.num_predictions();
        assert!(preds <= 4, "{preds}");
        assert!(preds >= 1);
        // labels only where attention is 1 and not special
        for p in 0..64 {
            if b.mlm_labels[p] != IGNORE {
                assert_eq!(b.attention_mask[p], 1);
                let orig = b.mlm_labels[p] as u32;
                assert!(orig >= special::FIRST_FREE);
            }
        }
    }

    #[test]
    fn mask_rate_near_15_percent() {
        let mut rng = Pcg64::new(2);
        let c = MaskingConfig { max_predictions: 1000, ..cfg() };
        let examples: Vec<PairExample> =
            (0..32).map(|_| ex(30, 28, true)).collect();
        let b = build_batch(&examples, 64, &c, &mut rng);
        let rate = b.num_predictions() as f64
            / (b.num_tokens() - 3 * 32) as f64; // minus CLS/SEP/SEP
        assert!((rate - 0.15).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn eighty_ten_ten_split() {
        let mut rng = Pcg64::new(3);
        let c = MaskingConfig { max_predictions: 10_000, ..cfg() };
        let examples: Vec<PairExample> =
            (0..64).map(|_| ex(30, 28, true)).collect();
        let b = build_batch(&examples, 64, &c, &mut rng);
        let mut masked = 0;
        let mut kept = 0;
        let mut random = 0;
        for p in 0..b.input_ids.len() {
            if b.mlm_labels[p] == IGNORE {
                continue;
            }
            let cur = b.input_ids[p];
            if cur == special::MASK as i32 {
                masked += 1;
            } else if cur == b.mlm_labels[p] {
                kept += 1;
            } else {
                random += 1;
            }
        }
        let total = (masked + kept + random) as f64;
        assert!(total > 100.0);
        assert!((masked as f64 / total - 0.8).abs() < 0.08,
                "mask frac {}", masked as f64 / total);
        assert!((kept as f64 / total - 0.1).abs() < 0.06);
        assert!((random as f64 / total - 0.1).abs() < 0.06);
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut rng = Pcg64::new(7);
            build_batch(&[ex(10, 10, true), ex(5, 8, false)], 32, &cfg(),
                        &mut rng)
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn truncation_fits_long_pairs() {
        let mut rng = Pcg64::new(4);
        let b = build_batch(&[ex(100, 100, true)], 32, &cfg(), &mut rng);
        assert_eq!(b.num_tokens(), 32); // fully used, no overflow
    }

    #[test]
    fn prop_batch_invariants() {
        testkit::check_msg(
            "batch-invariants", 0xBA, 32,
            |r| {
                let a = r.range_usize(1, 40);
                let b = r.range_usize(1, 40);
                let seq = [16, 32, 64][r.range_usize(0, 3)];
                (a, b, seq, r.next_u64())
            },
            |&(a, b, seq, seed)| {
                let mut rng = Pcg64::new(seed);
                let batch = build_batch(&[ex(a, b, true)], seq, &cfg(),
                                        &mut rng);
                // attention mask is a prefix of ones
                let row = &batch.attention_mask[..seq];
                let ones = row.iter().take_while(|&&m| m == 1).count();
                if row[ones..].iter().any(|&m| m != 0) {
                    return Err("mask not prefix".into());
                }
                // every id in range
                if batch.input_ids.iter().any(|&t| t < 0
                    || t as u32 >= 1000) {
                    return Err("id out of range".into());
                }
                Ok(())
            },
        );
    }
}
