//! WordPiece-lite vocabulary (paper §3.1.1: WordPiece tokenization).
//!
//! A full WordPiece trainer does likelihood-driven merges; the property
//! the rest of the pipeline needs is just: a frequency-ranked subword
//! vocabulary with whole-word entries, `##`-continuation pieces, and a
//! character-level fallback so tokenization is total.  This builder
//! delivers exactly that and serializes to/from a plain text file
//! (one token per line — the BERT `vocab.txt` convention).

use std::collections::HashMap;

use super::special;

/// A fixed vocabulary: token string <-> id.
#[derive(Debug, Clone)]
pub struct Vocab {
    token_to_id: HashMap<String, u32>,
    id_to_token: Vec<String>,
}

impl Vocab {
    /// Build from a corpus word-frequency map.
    ///
    /// Budget layout: 5 specials, all single characters seen (as both
    /// word-initial and `##` continuation pieces — the fallback), then
    /// the most frequent whole words, then frequent suffix pieces.
    pub fn build(word_freq: &HashMap<String, usize>, size: usize) -> Vocab {
        assert!(size > special::FIRST_FREE as usize + 2);
        let mut id_to_token: Vec<String> = vec![
            "[PAD]".into(), "[CLS]".into(), "[SEP]".into(),
            "[MASK]".into(), "[UNK]".into(),
        ];

        // character fallback pieces
        let mut chars: Vec<char> = word_freq
            .keys()
            .flat_map(|w| w.chars())
            .collect();
        chars.sort_unstable();
        chars.dedup();
        for c in &chars {
            id_to_token.push(c.to_string());
        }
        for c in &chars {
            id_to_token.push(format!("##{c}"));
        }

        // frequent whole words
        let mut words: Vec<(&String, &usize)> = word_freq.iter().collect();
        words.sort_by(|a, b| b.1.cmp(a.1).then(a.0.cmp(b.0)));
        let mut seen: std::collections::HashSet<String> =
            id_to_token.iter().cloned().collect();
        for (w, _) in &words {
            if id_to_token.len() >= size {
                break;
            }
            if w.chars().count() > 1 && seen.insert((*w).clone()) {
                id_to_token.push((*w).clone());
            }
        }

        // frequent suffixes as ## pieces (simple 2..4-char tails)
        if id_to_token.len() < size {
            let mut suffix_freq: HashMap<String, usize> = HashMap::new();
            for (w, f) in &words {
                let cs: Vec<char> = w.chars().collect();
                for tail in 2..=3.min(cs.len().saturating_sub(1)) {
                    let piece: String =
                        cs[cs.len() - tail..].iter().collect();
                    *suffix_freq.entry(format!("##{piece}")).or_insert(0) += **f;
                }
            }
            let mut suffixes: Vec<(String, usize)> =
                suffix_freq.into_iter().collect();
            suffixes.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            for (s, _) in suffixes {
                if id_to_token.len() >= size {
                    break;
                }
                if seen.insert(s.clone()) {
                    id_to_token.push(s);
                }
            }
        }

        let token_to_id = id_to_token
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as u32))
            .collect();
        Vocab { token_to_id, id_to_token }
    }

    /// Build directly from documents.
    pub fn from_documents(docs: &[super::corpus::Document], size: usize)
        -> Vocab {
        let mut freq: HashMap<String, usize> = HashMap::new();
        for s in docs.iter().flatten() {
            for w in s.split_whitespace() {
                let w = normalize(w);
                if !w.is_empty() {
                    *freq.entry(w).or_insert(0) += 1;
                }
            }
        }
        Self::build(&freq, size)
    }

    pub fn len(&self) -> usize {
        self.id_to_token.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id_to_token.is_empty()
    }

    pub fn id(&self, token: &str) -> Option<u32> {
        self.token_to_id.get(token).copied()
    }

    pub fn token(&self, id: u32) -> Option<&str> {
        self.id_to_token.get(id as usize).map(|s| s.as_str())
    }

    /// Serialize: one token per line (BERT vocab.txt convention).
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.id_to_token.join("\n"))
    }

    /// Load a vocab.txt.
    pub fn load(path: &std::path::Path) -> std::io::Result<Vocab> {
        let text = std::fs::read_to_string(path)?;
        let id_to_token: Vec<String> =
            text.lines().map(|l| l.to_string()).collect();
        let token_to_id = id_to_token
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as u32))
            .collect();
        Ok(Vocab { token_to_id, id_to_token })
    }
}

/// Lowercase and strip non-alphanumeric edges (uncased BERT-style).
pub fn normalize(word: &str) -> String {
    word.chars()
        .filter(|c| c.is_alphanumeric())
        .flat_map(|c| c.to_lowercase())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_freq() -> HashMap<String, usize> {
        let mut f = HashMap::new();
        for (w, n) in [("the", 100), ("cat", 50), ("sat", 40), ("mat", 30),
                       ("catalog", 5)] {
            f.insert(w.to_string(), n);
        }
        f
    }

    #[test]
    fn specials_have_fixed_ids() {
        let v = Vocab::build(&toy_freq(), 64);
        assert_eq!(v.id("[PAD]"), Some(special::PAD));
        assert_eq!(v.id("[CLS]"), Some(special::CLS));
        assert_eq!(v.id("[SEP]"), Some(special::SEP));
        assert_eq!(v.id("[MASK]"), Some(special::MASK));
        assert_eq!(v.id("[UNK]"), Some(special::UNK));
    }

    #[test]
    fn frequent_words_are_whole_entries() {
        let v = Vocab::build(&toy_freq(), 64);
        assert!(v.id("the").is_some());
        assert!(v.id("cat").is_some());
    }

    #[test]
    fn char_fallback_always_present() {
        let v = Vocab::build(&toy_freq(), 64);
        for c in "thecasmlog".chars() {
            assert!(v.id(&c.to_string()).is_some(), "{c}");
            assert!(v.id(&format!("##{c}")).is_some(), "##{c}");
        }
    }

    #[test]
    fn id_token_roundtrip() {
        let v = Vocab::build(&toy_freq(), 64);
        for id in 0..v.len() as u32 {
            let t = v.token(id).unwrap();
            assert_eq!(v.id(t), Some(id));
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let v = Vocab::build(&toy_freq(), 64);
        let path = std::env::temp_dir().join("bertdist_vocab_test.txt");
        v.save(&path).unwrap();
        let l = Vocab::load(&path).unwrap();
        assert_eq!(l.len(), v.len());
        assert_eq!(l.id("the"), v.id("the"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn size_budget_respected() {
        let v = Vocab::build(&toy_freq(), 40);
        assert!(v.len() <= 40);
    }

    #[test]
    fn normalize_strips_punctuation_and_case() {
        assert_eq!(normalize("Hello,"), "hello");
        assert_eq!(normalize("(world)"), "world");
        assert_eq!(normalize("--"), "");
    }
}
