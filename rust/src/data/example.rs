//! Sentence-pair pretraining examples and their `bshard` wire format
//! (paper §3.1.1: NSP pairs with 50% shuffled continuations).
//!
//! Wire format (little-endian):
//! ```text
//! [ is_next u8 | len_a u16 | len_b u16 | tokens_a: len_a x u32varish ]
//! ```
//! Token ids are stored as u16 when the vocab fits (<= 65535, true for
//! every preset incl. bert-large's 30522), guarded by a format flag byte.

use super::special;

/// One NSP example: two token sequences and the is-next label.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PairExample {
    pub tokens_a: Vec<u32>,
    pub tokens_b: Vec<u32>,
    /// true = b actually follows a (label 0 in the NSP head convention
    /// used by the model: 0 = IsNext, 1 = NotNext).
    pub is_next: bool,
}

const FMT_U16: u8 = 1;
const FMT_U32: u8 = 2;

impl PairExample {
    /// Serialize for `bshard`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let wide = self
            .tokens_a
            .iter()
            .chain(self.tokens_b.iter())
            .any(|&t| t > u16::MAX as u32);
        let mut out = Vec::with_capacity(
            8 + (self.tokens_a.len() + self.tokens_b.len())
                * if wide { 4 } else { 2 },
        );
        out.push(if wide { FMT_U32 } else { FMT_U16 });
        out.push(u8::from(self.is_next));
        out.extend((self.tokens_a.len() as u16).to_le_bytes());
        out.extend((self.tokens_b.len() as u16).to_le_bytes());
        for &t in self.tokens_a.iter().chain(self.tokens_b.iter()) {
            if wide {
                out.extend(t.to_le_bytes());
            } else {
                out.extend((t as u16).to_le_bytes());
            }
        }
        out
    }

    /// Deserialize from `bshard` bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<PairExample, String> {
        if bytes.len() < 6 {
            return Err("example record too short".into());
        }
        let fmt = bytes[0];
        let is_next = bytes[1] != 0;
        let len_a = u16::from_le_bytes([bytes[2], bytes[3]]) as usize;
        let len_b = u16::from_le_bytes([bytes[4], bytes[5]]) as usize;
        let width = match fmt {
            FMT_U16 => 2,
            FMT_U32 => 4,
            other => return Err(format!("bad example format {other}")),
        };
        let need = 6 + (len_a + len_b) * width;
        if bytes.len() != need {
            return Err(format!("example length {} != expected {need}",
                               bytes.len()));
        }
        let mut toks = Vec::with_capacity(len_a + len_b);
        let mut off = 6;
        for _ in 0..len_a + len_b {
            let t = match fmt {
                FMT_U16 => u16::from_le_bytes([bytes[off], bytes[off + 1]])
                    as u32,
                _ => u32::from_le_bytes([
                    bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3],
                ]),
            };
            toks.push(t);
            off += width;
        }
        let tokens_b = toks.split_off(len_a);
        Ok(PairExample { tokens_a: toks, tokens_b, is_next })
    }

    /// Total wordpiece tokens when assembled: [CLS] a [SEP] b [SEP].
    pub fn assembled_len(&self) -> usize {
        self.tokens_a.len() + self.tokens_b.len() + 3
    }

    /// Truncate the pair to fit `max_len` assembled tokens, trimming the
    /// longer side first (the BERT `truncate_seq_pair` heuristic).
    pub fn truncate(&mut self, max_len: usize) {
        let budget = max_len.saturating_sub(3);
        while self.tokens_a.len() + self.tokens_b.len() > budget {
            if self.tokens_a.len() >= self.tokens_b.len() {
                self.tokens_a.pop();
            } else {
                self.tokens_b.pop();
            }
        }
    }

    /// The `(tokens_a, tokens_b)` prefix lengths [`Self::truncate`]
    /// would keep for `max_len` assembled tokens — the allocation-free
    /// twin used by batch assembly: `truncate` only ever pops from the
    /// tail of the longer side, so the surviving tokens are exactly
    /// `tokens_a[..la]` / `tokens_b[..lb]`.
    pub fn truncated_lens(&self, max_len: usize) -> (usize, usize) {
        let budget = max_len.saturating_sub(3);
        let (mut a, mut b) = (self.tokens_a.len(), self.tokens_b.len());
        while a + b > budget {
            if a >= b {
                a -= 1;
            } else {
                b -= 1;
            }
        }
        (a, b)
    }

    /// NSP label in the model's convention: 0 = IsNext, 1 = NotNext.
    pub fn nsp_label(&self) -> i32 {
        if self.is_next {
            0
        } else {
            1
        }
    }

    /// True if no token collides with a reserved special id.
    pub fn ids_are_clean(&self) -> bool {
        self.tokens_a
            .iter()
            .chain(self.tokens_b.iter())
            .all(|&t| t >= special::FIRST_FREE || t == special::UNK)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip_simple() {
        let e = PairExample {
            tokens_a: vec![5, 6, 7],
            tokens_b: vec![8, 9],
            is_next: true,
        };
        let b = e.to_bytes();
        assert_eq!(PairExample::from_bytes(&b).unwrap(), e);
    }

    #[test]
    fn roundtrip_wide_ids() {
        let e = PairExample {
            tokens_a: vec![70_000, 5],
            tokens_b: vec![8],
            is_next: false,
        };
        let b = e.to_bytes();
        assert_eq!(b[0], super::FMT_U32);
        assert_eq!(PairExample::from_bytes(&b).unwrap(), e);
    }

    #[test]
    fn corrupt_length_rejected() {
        let e = PairExample {
            tokens_a: vec![5],
            tokens_b: vec![6],
            is_next: true,
        };
        let mut b = e.to_bytes();
        b.pop();
        assert!(PairExample::from_bytes(&b).is_err());
        assert!(PairExample::from_bytes(&[]).is_err());
    }

    #[test]
    fn truncation_balances_sides() {
        let mut e = PairExample {
            tokens_a: (0..20).map(|i| i + 5).collect(),
            tokens_b: (0..4).map(|i| i + 5).collect(),
            is_next: true,
        };
        e.truncate(16);
        assert_eq!(e.assembled_len(), 16);
        // longer side was trimmed
        assert_eq!(e.tokens_b.len(), 4);
        assert_eq!(e.tokens_a.len(), 9);
    }

    #[test]
    fn prop_truncated_lens_match_truncate() {
        testkit::check(
            "truncated-lens", 0xCC, 64,
            |r: &mut Pcg64| {
                (r.range_usize(0, 40), r.range_usize(0, 40),
                 r.range_usize(0, 64))
            },
            |&(a, b, max_len)| {
                let mut e = PairExample {
                    tokens_a: (0..a as u32).collect(),
                    tokens_b: (0..b as u32).collect(),
                    is_next: true,
                };
                let (la, lb) = e.truncated_lens(max_len);
                e.truncate(max_len);
                la == e.tokens_a.len() && lb == e.tokens_b.len()
            },
        );
    }

    #[test]
    fn nsp_label_convention() {
        let a = PairExample { tokens_a: vec![], tokens_b: vec![],
                              is_next: true };
        let b = PairExample { tokens_a: vec![], tokens_b: vec![],
                              is_next: false };
        assert_eq!(a.nsp_label(), 0);
        assert_eq!(b.nsp_label(), 1);
    }

    #[test]
    fn prop_roundtrip_random() {
        testkit::check(
            "example-roundtrip", 0xAB, 64,
            |r: &mut Pcg64| PairExample {
                tokens_a: testkit::gen_u32_vec(r, 0, 60, 40_000),
                tokens_b: testkit::gen_u32_vec(r, 0, 60, 40_000),
                is_next: r.chance(0.5),
            },
            |e| PairExample::from_bytes(&e.to_bytes()).as_ref() == Ok(e),
        );
    }
}
