//! Corpus sources (paper §3.1.1 substitute).
//!
//! The paper trains on Wikipedia (2.5 B words) + BookCorpus (0.8 B).
//! Neither is shippable here, so the default source is a **synthetic
//! Zipf corpus**: documents of sentences whose words are drawn from a
//! Zipf(1.1) distribution over a deterministic lexicon — matching the
//! statistical shape natural text presents to the tokenizer/masking
//! pipeline (a heavy-tailed unigram distribution).  A real-text loader
//! is provided for users with their own corpus files (one document per
//! blank-line-separated block, as in the BERT prep scripts).

use crate::util::Pcg64;

/// A corpus: documents -> sentences -> plain-text words.
pub type Document = Vec<String>;

/// Deterministic synthetic corpus generator.
pub struct SyntheticCorpus {
    lexicon: Vec<String>,
    zipf_s: f64,
    rng: Pcg64,
}

impl SyntheticCorpus {
    /// `lexicon_size` distinct word types; Zipf exponent ~1.1 mimics
    /// natural-language unigram statistics.
    pub fn new(seed: u64, lexicon_size: usize) -> Self {
        Self {
            lexicon: build_lexicon(lexicon_size),
            zipf_s: 1.1,
            rng: Pcg64::with_stream(seed, 0x5EED),
        }
    }

    /// Generate `n_docs` documents with `sentences_per_doc` sentences of
    /// `words_per_sentence ± spread` words.
    pub fn documents(&mut self, n_docs: usize, sentences_per_doc: usize,
                     words_per_sentence: usize) -> Vec<Document> {
        (0..n_docs)
            .map(|_| {
                (0..sentences_per_doc)
                    .map(|_| self.sentence(words_per_sentence))
                    .collect()
            })
            .collect()
    }

    /// One sentence of roughly `target_words` words.
    pub fn sentence(&mut self, target_words: usize) -> String {
        let jitter = (target_words / 3).max(1);
        let n = target_words.saturating_sub(jitter / 2)
            + self.rng.range_usize(0, jitter);
        let n = n.max(2);
        let words: Vec<&str> = (0..n)
            .map(|_| {
                let r = self.rng.next_zipf(self.lexicon.len(), self.zipf_s);
                self.lexicon[r].as_str()
            })
            .collect();
        words.join(" ")
    }
}

/// Deterministic pronounceable lexicon: CV-syllable words, rank-ordered
/// so low ranks are short (frequent words are short in natural language).
fn build_lexicon(size: usize) -> Vec<String> {
    const CONS: &[u8] = b"bcdfghjklmnprstvwz";
    const VOWS: &[u8] = b"aeiou";
    let mut out = Vec::with_capacity(size);
    let mut i = 0usize;
    'outer: for syllables in 1..=5usize {
        // enumerate all CV^k combinations for this syllable count
        let combos = (CONS.len() * VOWS.len()).pow(syllables as u32);
        for c in 0..combos {
            if out.len() >= size {
                break 'outer;
            }
            let mut word = String::with_capacity(syllables * 2);
            let mut rem = c;
            for _ in 0..syllables {
                let cv = rem % (CONS.len() * VOWS.len());
                rem /= CONS.len() * VOWS.len();
                word.push(CONS[cv / VOWS.len()] as char);
                word.push(VOWS[cv % VOWS.len()] as char);
            }
            out.push(word);
            i += 1;
        }
    }
    debug_assert!(i >= out.len());
    out
}

/// Load documents from a plain-text file: sentences are lines, documents
/// are blank-line-separated blocks (the standard BERT pretraining input
/// format).
pub fn load_text_file(path: &std::path::Path) -> std::io::Result<Vec<Document>> {
    let text = std::fs::read_to_string(path)?;
    Ok(parse_documents(&text))
}

/// Parse the blank-line-separated document format.
pub fn parse_documents(text: &str) -> Vec<Document> {
    let mut docs = Vec::new();
    let mut cur: Document = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            if !cur.is_empty() {
                docs.push(std::mem::take(&mut cur));
            }
        } else {
            cur.push(line.to_string());
        }
    }
    if !cur.is_empty() {
        docs.push(cur);
    }
    docs
}

/// Count words in a corpus (for tokens/epoch accounting à la Table 3).
pub fn word_count(docs: &[Document]) -> usize {
    docs.iter()
        .flat_map(|d| d.iter())
        .map(|s| s.split_whitespace().count())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexicon_is_deterministic_and_distinct() {
        let a = build_lexicon(500);
        let b = build_lexicon(500);
        assert_eq!(a, b);
        let mut c = a.clone();
        c.sort();
        c.dedup();
        assert_eq!(c.len(), 500);
        // short words first
        assert!(a[0].len() <= a[499].len());
    }

    #[test]
    fn corpus_is_seed_deterministic() {
        let d1 = SyntheticCorpus::new(7, 1000).documents(3, 4, 10);
        let d2 = SyntheticCorpus::new(7, 1000).documents(3, 4, 10);
        let d3 = SyntheticCorpus::new(8, 1000).documents(3, 4, 10);
        assert_eq!(d1, d2);
        assert_ne!(d1, d3);
    }

    #[test]
    fn corpus_shape() {
        let docs = SyntheticCorpus::new(1, 200).documents(5, 3, 8);
        assert_eq!(docs.len(), 5);
        assert!(docs.iter().all(|d| d.len() == 3));
        for s in docs.iter().flatten() {
            let n = s.split_whitespace().count();
            assert!(n >= 2, "sentence too short: '{s}'");
        }
    }

    #[test]
    fn zipf_words_repeat() {
        // A heavy-tailed distribution must reuse the head of the lexicon.
        let docs = SyntheticCorpus::new(2, 5000).documents(10, 10, 12);
        let mut counts = std::collections::HashMap::new();
        for s in docs.iter().flatten() {
            for w in s.split_whitespace() {
                *counts.entry(w.to_string()).or_insert(0usize) += 1;
            }
        }
        let max = counts.values().max().copied().unwrap_or(0);
        assert!(max > 10, "head word should repeat often (max={max})");
    }

    #[test]
    fn document_parsing() {
        let text = "s one\ns two\n\n\ndoc2 s1\n";
        let docs = parse_documents(text);
        assert_eq!(docs.len(), 2);
        assert_eq!(docs[0], vec!["s one", "s two"]);
        assert_eq!(docs[1], vec!["doc2 s1"]);
        assert_eq!(word_count(&docs), 6);
    }
}
