//! End-to-end data pipeline (paper §3.1 + §4.1).
//!
//! `build_shards`: corpus → tokenize → NSP pairs (50% shuffled) → N
//! `bshard` files (round-robin).  One shard set is built ONCE before
//! training; per-epoch work is index shuffling + masking only — this is
//! precisely the optimization of §4.1 (no monolithic load-and-scatter).
//!
//! [`ShardedDataset`]: a rank's view — it opens only the shard files
//! assigned to that rank and streams batches from them.

use std::path::{Path, PathBuf};

use super::corpus::Document;
use super::example::PairExample;
use super::masking::{Batch, MaskingConfig};
use super::tokenizer::Tokenizer;
use super::vocab::Vocab;
use crate::shard::{round_robin_assignment, shard_file_name, ShardReader,
                   ShardWriter};
use crate::util::Pcg64;

/// Statistics from a shard build.
#[derive(Debug, Clone, Default)]
pub struct BuildStats {
    pub documents: usize,
    pub examples: usize,
    pub tokens: usize,
    pub shards: usize,
}

/// Tokenize documents and emit NSP pair examples (50% IsNext, paper
/// §3.1.1), then distribute them round-robin over `n_shards` files.
pub fn build_shards(docs: &[Document], vocab: &Vocab, n_shards: usize,
                    dir: &Path, stem: &str, seed: u64)
                    -> anyhow::Result<BuildStats> {
    std::fs::create_dir_all(dir)?;
    let tok = Tokenizer::new(vocab);
    let mut rng = Pcg64::with_stream(seed, 0x9A17);

    // Tokenize every sentence once.
    let tokenized: Vec<Vec<Vec<u32>>> = docs
        .iter()
        .map(|d| d.iter().map(|s| tok.encode(s)).collect())
        .collect();

    // NSP pairing: adjacent sentences; half get a random "b" from a
    // different document.
    let mut examples: Vec<PairExample> = Vec::new();
    let mut tokens = 0usize;
    for (di, doc) in tokenized.iter().enumerate() {
        for si in 0..doc.len().saturating_sub(1) {
            let a = doc[si].clone();
            let (b, is_next) = if rng.chance(0.5) || tokenized.len() < 2 {
                (doc[si + 1].clone(), true)
            } else {
                // random sentence from a different document
                let mut dj = rng.range_usize(0, tokenized.len());
                if dj == di {
                    dj = (dj + 1) % tokenized.len();
                }
                let other = &tokenized[dj];
                if other.is_empty() {
                    (doc[si + 1].clone(), true)
                } else {
                    (other[rng.range_usize(0, other.len())].clone(), false)
                }
            };
            if a.is_empty() || b.is_empty() {
                continue;
            }
            tokens += a.len() + b.len();
            examples.push(PairExample { tokens_a: a, tokens_b: b, is_next });
        }
    }

    // Shuffle globally so shards are statistically identical, then
    // round-robin into shard files.
    rng.shuffle(&mut examples);
    let assignment = round_robin_assignment(examples.len(), n_shards);
    for (shard_idx, record_ids) in assignment.iter().enumerate() {
        let path = dir.join(shard_file_name(stem, shard_idx, n_shards));
        let mut w = ShardWriter::create(&path)?;
        for &i in record_ids {
            w.append(&examples[i].to_bytes())?;
        }
        w.finish()?;
    }
    Ok(BuildStats {
        documents: docs.len(),
        examples: examples.len(),
        tokens,
        shards: n_shards,
    })
}

/// One rank's dataset: the shard files it owns, with per-epoch shuffling
/// and batch assembly.
pub struct ShardedDataset {
    paths: Vec<PathBuf>,
    examples: Vec<PairExample>,
    rank: usize,
    world: usize,
}

/// Whether `name` is a shard file of exactly this `stem`, i.e. matches
/// the [`shard_file_name`] convention `<stem>-<idx>-of-<total>.bshard`.
/// A plain `starts_with(stem)` test would also swallow the shards of a
/// sibling dataset whose stem merely extends ours (`train` vs `train2`).
fn is_shard_of(name: &str, stem: &str) -> bool {
    let Some(rest) =
        name.strip_prefix(stem).and_then(|r| r.strip_prefix('-'))
    else {
        return false;
    };
    let Some(mid) = rest.strip_suffix(".bshard") else {
        return false;
    };
    match mid.split_once("-of-") {
        Some((idx, total)) => {
            !idx.is_empty()
                && !total.is_empty()
                && idx.bytes().all(|b| b.is_ascii_digit())
                && total.bytes().all(|b| b.is_ascii_digit())
        }
        None => false,
    }
}

/// Identity hash of a shard set: FNV-1a over the sorted
/// `<file name>, <byte size>` list of every `<stem>-N-of-M.bshard` in
/// `dir`.  World-size independent — every rank's round-robin view
/// partitions the same files — so it pins the CORPUS a run trains on.
/// The checkpoint [`crate::checkpoint::Fingerprint`] folds it in
/// (v2.1) so resuming the same config over a different dataset fails
/// loudly instead of silently diverging.  Never returns 0 (the
/// fingerprint's "unknown corpus" sentinel).
pub fn shard_manifest_hash(dir: &Path, stem: &str) -> anyhow::Result<u64> {
    let mut entries: Vec<(String, u64)> = Vec::new();
    for e in std::fs::read_dir(dir)? {
        let e = e?;
        let name = e.file_name();
        let Some(name) = name.to_str() else { continue };
        if is_shard_of(name, stem) {
            entries.push((name.to_string(), e.metadata()?.len()));
        }
    }
    anyhow::ensure!(!entries.is_empty(),
                    "no shards '{stem}-*' in {dir:?} to fingerprint");
    entries.sort();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for (name, size) in &entries {
        for &b in name.as_bytes() {
            eat(b);
        }
        for b in size.to_le_bytes() {
            eat(b);
        }
    }
    Ok(if h == 0 { 1 } else { h })
}

impl ShardedDataset {
    /// Open the shards assigned to `rank` out of `world` (shards are
    /// distributed round-robin over ranks).  Errors up front when the
    /// shard set cannot cover the world (fewer shard files than ranks),
    /// so every rank fails the same way instead of only the starved ones.
    pub fn open(dir: &Path, stem: &str, rank: usize, world: usize)
        -> anyhow::Result<ShardedDataset> {
        anyhow::ensure!(rank < world, "rank {rank} >= world {world}");
        anyhow::ensure!(world >= 1, "world must be >= 1");
        // Discover the shard set from the directory listing: exact-stem
        // matches only, sorted by file name (zero-padded indices, so the
        // lexicographic order IS the shard order).  Paths are moved —
        // never re-cloned — into the rank's slice.
        let mut all: Vec<PathBuf> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| is_shard_of(n, stem))
                    .unwrap_or(false)
            })
            .collect();
        all.sort();
        anyhow::ensure!(!all.is_empty(), "no shards '{stem}-*' in {dir:?}");
        anyhow::ensure!(
            all.len() >= world,
            "world {world} needs at least one shard per rank but only {} \
             '{stem}' shard files exist in {dir:?} — re-shard with more \
             files or shrink the topology",
            all.len()
        );
        let mine: Vec<PathBuf> = all
            .into_iter()
            .enumerate()
            .filter(|(i, _)| i % world == rank)
            .map(|(_, p)| p)
            .collect();

        // Load this rank's examples into memory (each shard is 1/world of
        // the data — exactly the paper's per-device stream).
        let mut examples = Vec::new();
        for p in &mine {
            let mut r = ShardReader::open(p)?;
            for rec in r.iter_all() {
                let rec = rec?;
                examples.push(
                    PairExample::from_bytes(&rec)
                        .map_err(|e| anyhow::anyhow!(e))?,
                );
            }
        }
        Ok(ShardedDataset { paths: mine, examples, rank, world })
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    pub fn shard_paths(&self) -> &[PathBuf] {
        &self.paths
    }

    /// Deterministic per-epoch example order (seeded by epoch + rank).
    pub fn epoch_order(&self, epoch: usize, seed: u64) -> Vec<usize> {
        let mut rng = Pcg64::with_stream(
            seed ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            self.rank as u64,
        );
        let mut order: Vec<usize> = (0..self.examples.len()).collect();
        rng.shuffle(&mut order);
        order
    }

    /// Build the `i`-th batch of an epoch (wraps around if needed).
    /// Convenience wrapper over [`Self::batch_into`] that allocates a
    /// fresh [`Batch`]; the hot path reuses one buffer instead.
    pub fn batch(&self, order: &[usize], i: usize, batch_size: usize,
                 seq: usize, cfg: &MaskingConfig, mask_rng: &mut Pcg64)
                 -> Batch {
        let mut out = Batch::zeros(batch_size, seq);
        self.batch_into(order, i, batch_size, seq, cfg, mask_rng, &mut out);
        out
    }

    /// Build the `i`-th batch of an epoch straight into a caller-owned
    /// buffer: no `PairExample` clones, no fresh `Batch` — each row is
    /// assembled from example slices in place (the §4.1 zero-copy batch
    /// path).  Bitwise-identical to [`Self::batch`] given the same rng.
    #[allow(clippy::too_many_arguments)]
    pub fn batch_into(&self, order: &[usize], i: usize, batch_size: usize,
                      seq: usize, cfg: &MaskingConfig, mask_rng: &mut Pcg64,
                      out: &mut Batch) {
        out.reset(batch_size, seq);
        let n = order.len().max(1);
        for row in 0..batch_size {
            let ex = &self.examples[order[(i * batch_size + row) % n]];
            crate::data::masking::assemble_into(out, row, ex, cfg, mask_rng);
        }
    }

    /// Batches per epoch at `batch_size`.
    pub fn batches_per_epoch(&self, batch_size: usize) -> usize {
        self.examples.len() / batch_size.max(1)
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// The rank this view belongs to (fixes the masking-RNG stream and
    /// the epoch-order seed in [`super::prefetch::BatchCursor`]).
    pub fn rank(&self) -> usize {
        self.rank
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticCorpus;

    fn setup(dir: &Path, n_shards: usize) -> (Vocab, BuildStats) {
        let docs = SyntheticCorpus::new(11, 800).documents(12, 6, 8);
        let vocab = Vocab::from_documents(&docs, 2048);
        let stats =
            build_shards(&docs, &vocab, n_shards, dir, "train", 5).unwrap();
        (vocab, stats)
    }

    #[test]
    fn build_creates_expected_files_and_counts() {
        let dir = std::env::temp_dir().join("bertdist_pipe_build");
        let _ = std::fs::remove_dir_all(&dir);
        let (_v, stats) = setup(&dir, 4);
        assert_eq!(stats.shards, 4);
        assert_eq!(stats.documents, 12);
        // 12 docs x 5 adjacent pairs
        assert_eq!(stats.examples, 60);
        for i in 0..4 {
            assert!(dir.join(shard_file_name("train", i, 4)).exists());
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ranks_partition_all_examples() {
        let dir = std::env::temp_dir().join("bertdist_pipe_part");
        let _ = std::fs::remove_dir_all(&dir);
        let (_v, stats) = setup(&dir, 4);
        let world = 2;
        let mut total = 0;
        for rank in 0..world {
            let ds = ShardedDataset::open(&dir, "train", rank, world).unwrap();
            assert_eq!(ds.shard_paths().len(), 2);
            total += ds.len();
        }
        assert_eq!(total, stats.examples);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn nsp_labels_are_roughly_balanced() {
        let dir = std::env::temp_dir().join("bertdist_pipe_nsp");
        let _ = std::fs::remove_dir_all(&dir);
        let (_v, _s) = setup(&dir, 1);
        let ds = ShardedDataset::open(&dir, "train", 0, 1).unwrap();
        let next = ds.examples.iter().filter(|e| e.is_next).count();
        let frac = next as f64 / ds.len() as f64;
        assert!((frac - 0.5).abs() < 0.25, "frac={frac}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn epoch_orders_differ_but_are_deterministic() {
        let dir = std::env::temp_dir().join("bertdist_pipe_epoch");
        let _ = std::fs::remove_dir_all(&dir);
        let (_v, _s) = setup(&dir, 2);
        let ds = ShardedDataset::open(&dir, "train", 0, 1).unwrap();
        let e0 = ds.epoch_order(0, 42);
        let e0b = ds.epoch_order(0, 42);
        let e1 = ds.epoch_order(1, 42);
        assert_eq!(e0, e0b);
        assert_ne!(e0, e1);
        let mut sorted = e0.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..ds.len()).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn batches_have_model_layout() {
        let dir = std::env::temp_dir().join("bertdist_pipe_batch");
        let _ = std::fs::remove_dir_all(&dir);
        let (vocab, _s) = setup(&dir, 2);
        let ds = ShardedDataset::open(&dir, "train", 0, 1).unwrap();
        let order = ds.epoch_order(0, 1);
        let cfg = MaskingConfig {
            vocab_size: vocab.len() as u32,
            ..Default::default()
        };
        let mut rng = Pcg64::new(9);
        let b = ds.batch(&order, 0, 4, 32, &cfg, &mut rng);
        assert_eq!(b.batch, 4);
        assert_eq!(b.seq, 32);
        assert_eq!(b.input_ids.len(), 128);
        assert!(b.num_predictions() > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_shards_error() {
        let dir = std::env::temp_dir().join("bertdist_pipe_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(ShardedDataset::open(&dir, "train", 0, 1).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stem_prefix_collision_is_excluded() {
        // `train` must not swallow `train2`'s shards: the old
        // starts_with(stem) filter mixed both datasets into one view.
        let dir = std::env::temp_dir().join("bertdist_pipe_stem");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let docs = SyntheticCorpus::new(11, 800).documents(12, 6, 8);
        let vocab = Vocab::from_documents(&docs, 2048);
        let a = build_shards(&docs, &vocab, 2, &dir, "train", 5).unwrap();
        let b = build_shards(&docs, &vocab, 2, &dir, "train2", 6).unwrap();
        let ds = ShardedDataset::open(&dir, "train", 0, 1).unwrap();
        assert_eq!(ds.shard_paths().len(), 2, "{:?}", ds.shard_paths());
        assert_eq!(ds.len(), a.examples);
        let ds2 = ShardedDataset::open(&dir, "train2", 0, 1).unwrap();
        assert_eq!(ds2.shard_paths().len(), 2);
        assert_eq!(ds2.len(), b.examples);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_hash_pins_the_corpus_identity() {
        let dir = std::env::temp_dir().join("bertdist_pipe_manifest");
        let _ = std::fs::remove_dir_all(&dir);
        let (_v, _s) = setup(&dir, 3);
        let a = shard_manifest_hash(&dir, "train").unwrap();
        let b = shard_manifest_hash(&dir, "train").unwrap();
        assert_eq!(a, b, "hash must be deterministic");
        assert_ne!(a, 0, "0 is the unknown-corpus sentinel");
        // a sibling stem's shards do not leak into the hash
        let docs = SyntheticCorpus::new(12, 800).documents(6, 6, 8);
        let vocab = Vocab::from_documents(&docs, 2048);
        build_shards(&docs, &vocab, 2, &dir, "train2", 6).unwrap();
        assert_eq!(shard_manifest_hash(&dir, "train").unwrap(), a);
        // growing a shard file changes the identity
        let path = dir.join(shard_file_name("train", 0, 3));
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.push(0);
        std::fs::write(&path, &bytes).unwrap();
        assert_ne!(shard_manifest_hash(&dir, "train").unwrap(), a);
        // empty / missing sets are loud
        let empty = std::env::temp_dir().join("bertdist_pipe_manifest_e");
        let _ = std::fs::remove_dir_all(&empty);
        std::fs::create_dir_all(&empty).unwrap();
        assert!(shard_manifest_hash(&empty, "train").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&empty).unwrap();
    }

    #[test]
    fn shard_name_filter_requires_exact_convention() {
        assert!(is_shard_of("train-00001-of-00004.bshard", "train"));
        assert!(!is_shard_of("train2-00001-of-00004.bshard", "train"));
        assert!(!is_shard_of("train-extra-00001-of-00004.bshard", "train"));
        assert!(!is_shard_of("train-00001-of-00004.bshard.bak", "train"));
        assert!(!is_shard_of("train-x-of-00004.bshard", "train"));
        assert!(!is_shard_of("train-00001.bshard", "train"));
    }

    #[test]
    fn world_larger_than_shard_count_errors_on_every_rank() {
        // 2 shard files cannot feed a 3-rank world; the old code only
        // failed on the starved ranks, leaving rank 0 silently oversized.
        let dir = std::env::temp_dir().join("bertdist_pipe_world");
        let _ = std::fs::remove_dir_all(&dir);
        let (_v, _s) = setup(&dir, 2);
        for rank in 0..3 {
            let err = ShardedDataset::open(&dir, "train", rank, 3)
                .err()
                .unwrap_or_else(|| panic!("rank {rank} must fail"));
            assert!(err.to_string().contains("world 3"), "{err}");
        }
        // exactly one shard per rank is still fine
        assert!(ShardedDataset::open(&dir, "train", 1, 2).is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn batch_into_reuse_is_bitwise_identical_to_fresh() {
        let dir = std::env::temp_dir().join("bertdist_pipe_binto");
        let _ = std::fs::remove_dir_all(&dir);
        let (vocab, _s) = setup(&dir, 2);
        let ds = ShardedDataset::open(&dir, "train", 0, 1).unwrap();
        let order = ds.epoch_order(0, 1);
        let cfg = MaskingConfig {
            vocab_size: vocab.len() as u32,
            ..Default::default()
        };
        let mut rng_a = Pcg64::new(9);
        let mut rng_b = Pcg64::new(9);
        // one buffer reused across batches vs a fresh Batch each time
        let mut reused = Batch::zeros(4, 32);
        for i in 0..6 {
            let fresh = ds.batch(&order, i, 4, 32, &cfg, &mut rng_a);
            ds.batch_into(&order, i, 4, 32, &cfg, &mut rng_b, &mut reused);
            assert_eq!(fresh, reused, "batch {i} diverged");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
