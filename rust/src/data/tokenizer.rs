//! Greedy longest-match-first WordPiece tokenizer (paper §3.1.1).
//!
//! Identical algorithm to BERT's `WordpieceTokenizer`: normalize, split
//! on whitespace, then for each word repeatedly take the longest vocab
//! entry that prefixes the remainder (continuations use the `##` prefix);
//! words with no decomposition become `[UNK]`.  The vocabulary guarantees
//! a character fallback, so `[UNK]` only appears for characters never
//! seen at vocab-build time.

use super::special;
use super::vocab::{normalize, Vocab};

/// Tokenizer over a fixed vocabulary.
pub struct Tokenizer<'v> {
    vocab: &'v Vocab,
    max_word_chars: usize,
}

impl<'v> Tokenizer<'v> {
    pub fn new(vocab: &'v Vocab) -> Self {
        Self { vocab, max_word_chars: 100 }
    }

    /// Tokenize a sentence to ids (no specials added).
    pub fn encode(&self, sentence: &str) -> Vec<u32> {
        let mut out = Vec::new();
        for raw in sentence.split_whitespace() {
            let word = normalize(raw);
            if word.is_empty() {
                continue;
            }
            self.encode_word(&word, &mut out);
        }
        out
    }

    fn encode_word(&self, word: &str, out: &mut Vec<u32>) {
        let chars: Vec<char> = word.chars().collect();
        if chars.len() > self.max_word_chars {
            out.push(special::UNK);
            return;
        }
        let mut start = 0usize;
        let mut pieces: Vec<u32> = Vec::new();
        while start < chars.len() {
            let mut end = chars.len();
            let mut found: Option<u32> = None;
            while end > start {
                let sub: String = chars[start..end].iter().collect();
                let cand = if start == 0 {
                    sub
                } else {
                    format!("##{sub}")
                };
                if let Some(id) = self.vocab.id(&cand) {
                    found = Some(id);
                    break;
                }
                end -= 1;
            }
            match found {
                Some(id) => {
                    pieces.push(id);
                    start = end;
                }
                None => {
                    out.push(special::UNK);
                    return;
                }
            }
        }
        out.extend(pieces);
    }

    /// Decode ids back to a readable string (## pieces joined).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut out = String::new();
        for &id in ids {
            let tok = self.vocab.token(id).unwrap_or("[UNK]");
            if let Some(cont) = tok.strip_prefix("##") {
                out.push_str(cont);
            } else {
                if !out.is_empty() {
                    out.push(' ');
                }
                out.push_str(tok);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticCorpus;
    use crate::testkit;
    use crate::util::Pcg64;
    use std::collections::HashMap;

    fn toy_vocab() -> Vocab {
        let mut f = HashMap::new();
        for (w, n) in [("unwanted", 50), ("running", 40), ("the", 100),
                       ("run", 60), ("want", 30), ("sat", 20)] {
            f.insert(w.to_string(), n as usize);
        }
        Vocab::build(&f, 128)
    }

    #[test]
    fn whole_words_match_directly() {
        let v = toy_vocab();
        let t = Tokenizer::new(&v);
        let ids = t.encode("the running");
        assert_eq!(ids.len(), 2);
        assert_eq!(v.token(ids[0]), Some("the"));
        assert_eq!(v.token(ids[1]), Some("running"));
    }

    #[test]
    fn greedy_longest_match_decomposes() {
        let v = toy_vocab();
        let t = Tokenizer::new(&v);
        // "runs" -> "run" + "##s"
        let ids = t.encode("runs");
        assert!(ids.len() >= 2);
        assert_eq!(v.token(ids[0]), Some("run"));
        assert_eq!(v.token(ids[1]), Some("##s"));
    }

    #[test]
    fn decode_rejoins_pieces() {
        let v = toy_vocab();
        let t = Tokenizer::new(&v);
        let ids = t.encode("the runs");
        assert_eq!(t.decode(&ids), "the runs");
    }

    #[test]
    fn normalization_applied() {
        let v = toy_vocab();
        let t = Tokenizer::new(&v);
        assert_eq!(t.encode("The THE the,"), t.encode("the the the"));
    }

    #[test]
    fn never_panics_and_rarely_unk_on_synthetic_corpus() {
        let mut c = SyntheticCorpus::new(3, 2000);
        let docs = c.documents(20, 5, 10);
        let v = Vocab::from_documents(&docs, 4096);
        let t = Tokenizer::new(&v);
        let mut total = 0usize;
        let mut unk = 0usize;
        for s in docs.iter().flatten() {
            for id in t.encode(s) {
                total += 1;
                if id == special::UNK {
                    unk += 1;
                }
            }
        }
        assert!(total > 500);
        // char fallback covers the corpus alphabet: no UNKs at all
        assert_eq!(unk, 0, "unk={unk}/{total}");
    }

    #[test]
    fn prop_encode_decode_word_identity_when_in_vocab() {
        // For corpus-drawn sentences, decode(encode(s)) == normalized s.
        let mut c = SyntheticCorpus::new(4, 1000);
        let docs = c.documents(10, 4, 8);
        let v = Vocab::from_documents(&docs, 4096);
        let t = Tokenizer::new(&v);
        testkit::check(
            "tokenizer-roundtrip", 0xF0, 32,
            |r: &mut Pcg64| {
                let d = r.range_usize(0, docs.len());
                let s = r.range_usize(0, docs[d].len());
                docs[d][s].clone()
            },
            |s| {
                let norm: Vec<String> = s
                    .split_whitespace()
                    .map(super::normalize)
                    .filter(|w| !w.is_empty())
                    .collect();
                Tokenizer::new(&v).decode(&t.encode(s)) == norm.join(" ")
            },
        );
    }

    #[test]
    fn ids_always_in_vocab_range() {
        let mut c = SyntheticCorpus::new(5, 500);
        let docs = c.documents(5, 3, 6);
        let v = Vocab::from_documents(&docs, 1024);
        let t = Tokenizer::new(&v);
        for s in docs.iter().flatten() {
            for id in t.encode(s) {
                assert!((id as usize) < v.len());
            }
        }
    }
}
