//! The data-parallel trainer (paper §3.2, §4.4): the coordinator's hot
//! loop gluing every piece together.
//!
//! Per optimizer step:
//! 1. each data-parallel rank runs `accum_steps` micro-steps of the AOT
//!    train step on its own shard stream (paper §4.1: data loading stays
//!    on the "PCIe" path, i.e. local), summing gradients locally
//!    (paper §4.4 gradient accumulation);
//! 2. the summed flat gradients are exchanged with a REAL ring allreduce
//!    across worker threads, bucket by bucket in backward order (paper
//!    Fig. 2 bucketed overlap schedule — on this 1-core testbed buckets
//!    pipeline the exchange, wall-clock overlap is studied in
//!    [`crate::simulator`]);
//! 3. the AMP loss scaler inspects the unscaled gradients: on overflow
//!    the step is skipped and the scale backs off (paper §4.2);
//! 4. the leader applies LAMB via the AOT apply step; all replicas share
//!    the post-update parameters (replicas are bitwise identical after
//!    every sync, so one master copy is kept — asserted in tests).
//!
//! Rank micro-steps execute sequentially on this single-core testbed
//! (parallel PJRT execution buys nothing at nproc=1); the ring exchange
//! runs on real threads.  See DESIGN.md §2 for the substitution table.

use std::path::Path;

use anyhow::Result;

use crate::collectives::CollectiveGroup;
use crate::config::RunConfig;
use crate::data::{MaskingConfig, ShardedDataset};
use crate::grad::{build_buckets, Bucket, GradAccumulator};
use crate::metrics::{LossCurve, ThroughputMeter};
use crate::optimizer::lr_schedule;
use crate::precision::{has_nonfinite, DynamicLossScaler, StepVerdict};
use crate::runtime::{ApplyStep, Engine, TrainStep};
use crate::util::{Pcg64, Stopwatch};

/// Outcome of a training run.
#[derive(Debug, Default)]
pub struct TrainReport {
    pub loss: LossCurve,
    pub mlm_loss: LossCurve,
    pub nsp_loss: LossCurve,
    pub mlm_acc: LossCurve,
    pub steps: usize,
    pub skipped_steps: usize,
    pub final_loss_scale: f64,
    pub tokens_per_sec: f64,
    pub total_tokens: u64,
    /// Per-phase wall-clock totals: (compute, allreduce, apply) seconds.
    pub compute_s: f64,
    pub allreduce_s: f64,
    pub apply_s: f64,
    pub wall_s: f64,
}

impl TrainReport {
    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "steps={} skipped={} final_loss={:.4} tokens/s={:.1} \
             compute={:.1}s allreduce={:.1}s apply={:.1}s wall={:.1}s",
            self.steps, self.skipped_steps, self.loss.tail_mean(5),
            self.tokens_per_sec, self.compute_s, self.allreduce_s,
            self.apply_s, self.wall_s
        )
    }
}

/// The trainer: compiled steps + distributed state.
pub struct Trainer {
    train_step: TrainStep,
    apply_step: ApplyStep,
    buckets: Vec<Bucket>,
    world: usize,
    cfg: RunConfig,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    pub scaler: DynamicLossScaler,
    pub step: usize,
    mask_cfg: MaskingConfig,
}

impl Trainer {
    /// Build a trainer for the given run config (artifacts must exist).
    pub fn new(engine: &Engine, cfg: RunConfig, seq: usize, batch: usize)
        -> Result<Trainer> {
        cfg.validate()?;
        let model = engine.model(&cfg.train.preset)?;
        let n = model.param_count;
        let train_step =
            engine.train_step(&cfg.train.preset, &cfg.train.variant, batch,
                              seq)?;
        let apply_step =
            engine.apply_step(&cfg.train.preset, &cfg.train.optimizer)?;
        let buckets = build_buckets(&model.layout, cfg.train.bucket_elems);
        let world = cfg.cluster.topo.world_size();
        let mask_cfg = MaskingConfig {
            mask_prob: cfg.data.mask_prob,
            max_predictions: cfg.data.max_predictions,
            vocab_size: model.config.vocab_size as u32,
            ..Default::default()
        };
        let mut init_rng = Pcg64::with_stream(cfg.train.seed, 0x1111);
        let params = init_params(&model.layout, &mut init_rng);
        Ok(Trainer {
            train_step,
            apply_step,
            buckets,
            world,
            scaler: DynamicLossScaler::new(cfg.train.init_loss_scale)
                .with_growth_interval(200),
            cfg,
            m: vec![0.0; n],
            v: vec![0.0; n],
            params,
            step: 0,
            mask_cfg,
        })
    }

    /// Restore parameters/optimizer state from a checkpoint.
    pub fn restore(&mut self, ckpt: crate::checkpoint::Checkpoint) -> Result<()> {
        anyhow::ensure!(ckpt.params.len() == self.params.len(),
                        "checkpoint size mismatch");
        self.params = ckpt.params;
        self.m = ckpt.m;
        self.v = ckpt.v;
        self.step = ckpt.step as usize;
        self.scaler = DynamicLossScaler::new(ckpt.loss_scale)
            .with_growth_interval(200);
        Ok(())
    }

    /// Snapshot current state.
    pub fn checkpoint(&self) -> crate::checkpoint::Checkpoint {
        crate::checkpoint::Checkpoint {
            step: self.step as u64,
            loss_scale: self.scaler.scale(),
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
        }
    }

    /// Save a checkpoint to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.checkpoint().save(path)?;
        Ok(())
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Run `steps` optimizer steps over the per-rank datasets.
    /// `datasets.len()` must equal the topology world size.
    pub fn run(&mut self, datasets: &[ShardedDataset], steps: usize,
               total_steps_for_lr: usize) -> Result<TrainReport> {
        anyhow::ensure!(
            datasets.len() == self.world,
            "need {} datasets (one per rank), got {}",
            self.world, datasets.len()
        );
        let n = self.params.len();
        let k = self.cfg.train.accum_steps;
        let batch = self.train_step.batch;
        let seq = self.train_step.seq;
        let mut report = TrainReport::default();
        let mut meter = ThroughputMeter::new();
        let mut sw = Stopwatch::new();
        let wall = Stopwatch::new();

        let orders: Vec<Vec<usize>> = datasets
            .iter()
            .map(|d| d.epoch_order(self.step / 100, self.cfg.train.seed))
            .collect();
        let mut mask_rngs: Vec<Pcg64> = (0..self.world)
            .map(|r| Pcg64::with_stream(self.cfg.train.seed, 0xDA7A + r as u64))
            .collect();

        let mut accs: Vec<GradAccumulator> =
            (0..self.world).map(|_| GradAccumulator::new(n)).collect();

        for local_step in 0..steps {
            sw.reset();
            // ---- 1. per-rank micro-steps (compute) ----
            let scale = self.scaler.scale() as f32;
            let mut loss_sum = 0.0f64;
            let mut mlm_sum = 0.0f64;
            let mut nsp_sum = 0.0f64;
            let mut acc_sum = 0.0f64;
            let mut saw_overflow = false;
            for r in 0..self.world {
                for micro in 0..k {
                    let b = datasets[r].batch(
                        &orders[r],
                        (self.step * k + micro) % usize::MAX,
                        batch, seq, &self.mask_cfg, &mut mask_rngs[r],
                    );
                    let out = self.train_step.run(&self.params, &b, scale)?;
                    if !out.grad_norm.is_finite() || !out.loss.is_finite() {
                        saw_overflow = true;
                    }
                    loss_sum += out.loss as f64;
                    mlm_sum += out.mlm_loss as f64;
                    nsp_sum += out.nsp_loss as f64;
                    acc_sum += out.mlm_acc as f64;
                    accs[r].add(&out.grads);
                    meter.add((batch * seq) as u64);
                }
            }
            report.compute_s += sw.lap("compute");

            // ---- 2. bucketed ring allreduce across ranks (real threads) --
            if self.world > 1 {
                allreduce_buckets(&mut accs, &self.buckets);
            }
            report.allreduce_s += sw.lap("allreduce");

            // ---- 3. AMP verdict + normalization ----
            let micro_total = (k * self.world).max(1) as f32;
            let grads: Vec<f32> = accs[0]
                .buffer()
                .iter()
                .map(|g| g / micro_total)
                .collect();
            saw_overflow |= has_nonfinite(&grads);
            for a in accs.iter_mut() {
                a.reset();
            }
            let verdict = self.scaler.update(saw_overflow);

            // ---- 4. optimizer apply (leader) ----
            if verdict == StepVerdict::Apply {
                self.step += 1;
                let lr = lr_schedule(self.cfg.train.lr, self.step,
                                     self.cfg.train.warmup_steps,
                                     total_steps_for_lr) as f32;
                self.apply_step.run(&mut self.params, &grads, &mut self.m,
                                    &mut self.v, self.step as f32, lr)?;
            } else {
                report.skipped_steps += 1;
            }
            report.apply_s += sw.lap("apply");

            // ---- metrics ----
            let denom = (k * self.world) as f64;
            report.loss.push(self.step, loss_sum / denom);
            report.mlm_loss.push(self.step, mlm_sum / denom);
            report.nsp_loss.push(self.step, nsp_sum / denom);
            report.mlm_acc.push(self.step, acc_sum / denom);
            if self.cfg.train.log_every > 0
                && (local_step + 1) % self.cfg.train.log_every == 0 {
                log::info!(
                    "step {:>5} loss {:.4} mlm {:.4} nsp {:.4} acc {:.3} \
                     scale {} tok/s {:.0}",
                    self.step, loss_sum / denom, mlm_sum / denom,
                    nsp_sum / denom, acc_sum / denom,
                    self.scaler.scale(), meter.recent()
                );
                println!(
                    "step {:>5} | loss {:.4} | mlm {:.4} | nsp {:.4} | \
                     acc {:.3} | scale {:>8} | tok/s {:.0}",
                    self.step, loss_sum / denom, mlm_sum / denom,
                    nsp_sum / denom, acc_sum / denom,
                    self.scaler.scale(), meter.recent()
                );
            }
        }

        report.steps = steps;
        report.final_loss_scale = self.scaler.scale();
        report.tokens_per_sec = meter.average();
        report.total_tokens = meter.total_tokens();
        report.wall_s = wall.elapsed();
        Ok(report)
    }
}

/// Initialize parameters like the Python side: N(0, 0.02) clipped at 2σ
/// for weights, ones for LayerNorm gammas, zeros for biases/betas.
pub fn init_params(layout: &crate::model::layout::ParamLayout,
                   rng: &mut Pcg64) -> Vec<f32> {
    let mut out = vec![0.0f32; layout.total_len()];
    for e in layout.entries() {
        let seg = &mut out[e.offset..e.offset + e.len()];
        if e.name.ends_with(".gamma") {
            seg.iter_mut().for_each(|x| *x = 1.0);
        } else if e.name.ends_with(".beta") || e.name.ends_with(".bias") {
            // zeros (already)
        } else {
            for x in seg.iter_mut() {
                let g = (rng.next_gaussian() * 0.02).clamp(-0.04, 0.04);
                *x = g as f32;
            }
        }
    }
    out
}

/// Run the real threaded ring allreduce over each rank's accumulator,
/// one bucket at a time in backward order (Fig. 2's schedule).
fn allreduce_buckets(accs: &mut [GradAccumulator], buckets: &[Bucket]) {
    let world = accs.len();
    // Move each rank's buffer out, run threads, move back.
    let mut bufs: Vec<Vec<f32>> = accs
        .iter_mut()
        .map(|a| std::mem::take(a.buffer_mut_vec()))
        .collect();
    let handles = CollectiveGroup::new(world);
    let buckets_owned: Vec<(usize, usize)> =
        buckets.iter().map(|b| (b.start, b.end)).collect();
    let joins: Vec<_> = handles
        .into_iter()
        .zip(bufs.drain(..))
        .map(|(mut h, mut buf)| {
            let bks = buckets_owned.clone();
            std::thread::spawn(move || {
                for (s, e) in bks {
                    h.allreduce(&mut buf[s..e]);
                }
                buf
            })
        })
        .collect();
    for (a, j) in accs.iter_mut().zip(joins) {
        *a.buffer_mut_vec() = j.join().expect("allreduce worker panicked");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BertConfig;

    #[test]
    fn init_params_structure() {
        let layout = BertConfig::preset("bert-micro").unwrap().param_layout();
        let mut rng = Pcg64::new(1);
        let p = init_params(&layout, &mut rng);
        assert_eq!(p.len(), 146_178);
        // gamma segment is ones
        let g = layout.find("embeddings.layernorm.gamma").unwrap();
        assert!(p[g.offset..g.offset + g.len()].iter().all(|&x| x == 1.0));
        // bias segment is zeros
        let b = layout.find("cls.pooler.bias").unwrap();
        assert!(p[b.offset..b.offset + b.len()].iter().all(|&x| x == 0.0));
        // weights are clipped gaussians
        let w = layout.find("embeddings.word_embeddings").unwrap();
        let seg = &p[w.offset..w.offset + w.len()];
        assert!(seg.iter().all(|&x| x.abs() <= 0.04));
        assert!(seg.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn allreduce_buckets_sums_across_ranks() {
        let layout = crate::model::layout::ParamLayout::from_shapes(&[
            ("a".into(), vec![100]),
            ("b".into(), vec![57]),
        ]);
        let buckets = build_buckets(&layout, 64);
        let mut accs: Vec<GradAccumulator> =
            (0..3).map(|_| GradAccumulator::new(157)).collect();
        for (r, acc) in accs.iter_mut().enumerate() {
            let g: Vec<f32> = (0..157).map(|i| (r * 200 + i) as f32).collect();
            acc.add(&g);
        }
        let want: Vec<f32> = (0..157)
            .map(|i| (0..3).map(|r| (r * 200 + i) as f32).sum())
            .collect();
        allreduce_buckets(&mut accs, &buckets);
        for acc in &accs {
            crate::testkit::assert_allclose(acc.buffer(), &want, 1e-4, 1e-5);
        }
    }
}
