//! The data-parallel trainer (paper §3.2, §4.4): the coordinator's hot
//! loop gluing every piece together.
//!
//! ## Hot-loop architecture (persistent step executor)
//!
//! All distributed machinery is wired ONCE at [`Trainer::new`]: a
//! [`CollectivePool`] spawns two long-lived threads per rank (compute +
//! comm) connected by reusable ring channels, and every scratch buffer —
//! per-rank gradient accumulators, per-bucket wire payloads, the
//! normalization vector — is preallocated and reused.  Per optimizer
//! step the loop is:
//!
//! 0. each rank's input batches are already waiting: one long-lived
//!    producer thread per rank (`data::prefetch`, paper §4.1) builds
//!    masked batches ahead of the compute workers over a bounded ring
//!    of recycled [`Batch`] buffers (`train.prefetch_depth`, default 2 =
//!    double buffering; 0 = build synchronously on the compute worker —
//!    bitwise-identical, just exposed on the critical path).  The time a
//!    compute worker does wait is reported as `input_stall_s` next to
//!    the PCIe/network exchange spans;
//! 1. the pool dispatches `accum_steps` micro-steps of the AOT train
//!    step to every rank's compute worker **in parallel** (one shared
//!    compiled executable, concurrent PJRT execute), each worker summing
//!    gradients locally (paper §4.4 gradient accumulation).  Marshaling
//!    rides the zero-copy path: the params literal is rebuilt once per
//!    optimizer step (not per micro) through a per-rank
//!    [`StepScratch`], and gradients are decoded straight into the
//!    pool's preallocated per-rank buffer;
//! 2. on the final micro-step each worker accumulates bucket-by-bucket
//!    in backward order and enqueues every bucket's REAL exchange
//!    **as soon as its accumulation completes**, overlapping exchange
//!    with the remaining accumulation — the paper's Fig. 2 schedule
//!    (`train.overlap = false` falls back to the barrier order, which is
//!    bitwise identical, just slower; `train.grad_wire_f16` ships ring
//!    payloads as IEEE f16, §4.4's FP16 exchange).  `train.comm_mode`
//!    picks the bucket route: a flat world ring, or the §4.4 hierarchy
//!    (PCIe leader accumulate → network leader ring → PCIe broadcast)
//!    whenever the topology has multiple machines AND multiple GPUs per
//!    machine (`auto`, the default);
//! 3. the AMP loss scaler inspects the unscaled gradients: on overflow
//!    the step is skipped and the scale backs off (paper §4.2);
//! 4. the leader applies LAMB via the AOT apply step; all replicas share
//!    the post-update parameters (replicas are bitwise identical after
//!    every sync — asserted in tests).
//!
//! 5. (optional) at the optimizer-step boundary the trainer snapshots
//!    its complete resumable state — params/m/v, `step`, the monotone
//!    `data_step`, the scaler's full state, and the config fingerprint —
//!    into a recycled buffer; the atomic write and keep-last-K rotation
//!    run on a background thread ([`crate::checkpoint`]).  Restoring a
//!    v2 checkpoint resumes bitwise-identically to never having stopped.
//!
//! [`TrainReport`] carries the per-phase wall-clock split plus the
//! pool's per-bucket exchange timings and the overlap-efficiency ratio
//! (fraction of exchange hidden behind compute).  See DESIGN.md §2 for
//! the substitution table.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::checkpoint::{AsyncCheckpointWriter, Checkpoint, Fingerprint};
use crate::collectives::pool::{CollectivePool, MicroStats, RankCompute,
                               WireFormat};
pub use crate::collectives::pool::CommMode;
use crate::collectives::{CollectiveGroup, InProcTransport, Transport};
use crate::config::RunConfig;
use crate::data::prefetch::{BatchCursor, Prefetcher};
use crate::data::{Batch, MaskingConfig, ShardedDataset};
use crate::grad::{bucket_ranges, build_buckets, Bucket, BucketRange,
                  GradAccumulator};
use crate::metrics::{ExchangeTimings, LossCurve, ThroughputMeter};
use crate::optimizer::lr_schedule;
use crate::precision::{has_nonfinite, DynamicLossScaler, StepVerdict};
use crate::runtime::{ApplyStep, Engine, StepScratch, StepStats, TrainStep};
use crate::util::{Pcg64, Stopwatch};

/// Outcome of a training run.
#[derive(Debug, Default)]
pub struct TrainReport {
    pub loss: LossCurve,
    pub mlm_loss: LossCurve,
    pub nsp_loss: LossCurve,
    pub mlm_acc: LossCurve,
    pub steps: usize,
    pub skipped_steps: usize,
    pub final_loss_scale: f64,
    pub tokens_per_sec: f64,
    pub total_tokens: u64,
    /// Per-phase wall-clock totals: (compute, allreduce, apply) seconds.
    /// `compute_s`/`allreduce_s` are critical-path times (max over the
    /// parallel rank workers), summed over steps.
    pub compute_s: f64,
    pub allreduce_s: f64,
    pub apply_s: f64,
    pub wall_s: f64,
    /// Per-bucket exchange timings + exposed-comm accounting from the
    /// persistent pool.
    pub exchange: ExchangeTimings,
    /// 1 - exposed/total exchange time: fraction of the allreduce hidden
    /// behind gradient accumulation (Fig. 2's win; 0 when world == 1 or
    /// overlap is off).
    pub overlap_efficiency: f64,
    /// Critical-path seconds compute workers spent blocked waiting on
    /// input batches (summed over steps; a subset of `compute_s`).
    pub input_stall_s: f64,
    /// 1 - input_stall/compute: fraction of the compute workers'
    /// critical-path time spent on real work rather than waiting for
    /// data (paper §4.1's target).  Always in `[0, 1]`; 1.0 when the
    /// prefetch ring keeps every worker fed.
    pub data_efficiency: f64,
    /// Periodic checkpoints snapshotted during the run (async rotation).
    pub checkpoints: usize,
    /// Hot-loop seconds those snapshots cost (recycled-buffer memcpy +
    /// any wait for the background writer to free a buffer) — the
    /// on-loop price of checkpointing; the writes themselves are off
    /// the loop.
    pub checkpoint_s: f64,
}

impl TrainReport {
    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "steps={} skipped={} final_loss={:.4} tokens/s={:.1} \
             compute={:.1}s allreduce={:.1}s apply={:.1}s wall={:.1}s \
             overlap_eff={:.0}% input_stall={:.2}s data_eff={:.0}%",
            self.steps, self.skipped_steps, self.loss.tail_mean(5),
            self.tokens_per_sec, self.compute_s, self.allreduce_s,
            self.apply_s, self.wall_s, self.overlap_efficiency * 100.0,
            self.input_stall_s, self.data_efficiency * 100.0
        );
        if self.checkpoints > 0 {
            s.push_str(&format!(" ckpt={}x (stall {:.3}s)",
                                self.checkpoints, self.checkpoint_s));
        }
        s
    }
}

/// Deterministic fault injection (CLI `--inject-fail [net:]step[:rank]`):
/// the elastic-restart test hook.  With a rank, the failure fires inside
/// that rank's compute worker at the FINAL micro-step of the given
/// `data_step` — after the healthy ranks have begun feeding their comm
/// workers, the worst spot for the exchange protocol (it exercises the
/// pool's failure surfacing exactly like a node dying mid-step).
/// Without a rank, the trainer itself fails just before dispatching
/// that step.  Either way no optimizer state for the step is applied,
/// so a supervised restart replays it from the last checkpoint.
///
/// The `net:` form cuts the **links** instead of the compute: at the
/// given step the pool drops every remote socket end owned by `rank`
/// (all local ranks without one) mid-exchange, so the peer process sees
/// a genuine disconnect — the hook behind the rejoin e2e tests.  It
/// requires a socket transport (`--listen`); the CLI rejects it
/// otherwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectFail {
    /// The `data_step` at which to fail.
    pub step: usize,
    /// The rank whose compute worker fails; `None` fails the trainer
    /// loop itself (or, with `net`, cuts every local rank's links).
    pub rank: Option<usize>,
    /// Cut the rank's remote transport links instead of failing compute.
    pub net: bool,
}

impl InjectFail {
    /// Parse the CLI form `[net:]step[:rank]` (e.g. `120`, `120:3`, or
    /// `net:120:3`).
    pub fn parse(s: &str) -> Result<InjectFail> {
        let bad = || anyhow::anyhow!(
            "--inject-fail: '{s}' is not of the form [net:]step[:rank]");
        let (net, rest) = match s.trim().strip_prefix("net:") {
            Some(r) => (true, r),
            None => (false, s.trim()),
        };
        let (step, rank) = match rest.split_once(':') {
            Some((a, b)) => (a, Some(b)),
            None => (rest, None),
        };
        let step = step.trim().parse::<usize>().map_err(|_| bad())?;
        let rank = match rank {
            Some(r) => Some(r.trim().parse::<usize>().map_err(|_| bad())?),
            None => None,
        };
        Ok(InjectFail { step, rank, net })
    }
}

/// The trainer: compiled steps + distributed state.
pub struct Trainer {
    // NOTE: `pool` is declared first so its Drop (which joins the worker
    // threads) runs before the buffers below are freed.
    pool: CollectivePool,
    train_step: TrainStep,
    apply_step: ApplyStep,
    buckets: Vec<Bucket>,
    bucket_ranges: Arc<[BucketRange]>,
    world: usize,
    cfg: RunConfig,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    /// Reused normalization scratch (reduced-sum grads / micro count).
    grad_scratch: Vec<f32>,
    pub scaler: DynamicLossScaler,
    pub step: usize,
    /// Monotone data-consumption counter: one per attempted optimizer
    /// step, *including* AMP-skipped steps (a skipped step consumed its
    /// batches).  Drives the batch cursors — epoch orders advance when a
    /// rank's batch index wraps its epoch length — and doubles as the
    /// params-literal version for the marshaling scratch.  Unlike
    /// `step`, it never stalls on overflow skips, so the data stream
    /// keeps moving.
    data_step: usize,
    /// Corpus identity ([`crate::data::pipeline::shard_manifest_hash`]),
    /// folded into [`Self::fingerprint`]; 0 = unknown (the CLI sets it
    /// before any restore, bare programmatic trainers may not).
    data_manifest: u64,
    mask_cfg: MaskingConfig,
    /// Deterministic fault injection for elastic-restart testing
    /// (`None` in production runs).
    inject_fail: Option<InjectFail>,
}

impl Trainer {
    /// Build a trainer for the given run config (artifacts must exist).
    /// This wires the persistent collective pool — worker threads and
    /// ring channels live for the trainer's lifetime; `run` never spawns.
    ///
    /// Ranks live in THIS process on in-memory channels; see
    /// [`Self::with_transport`] for the multi-process form.
    pub fn new(engine: &Engine, cfg: RunConfig, seq: usize, batch: usize)
        -> Result<Trainer> {
        let mut transport =
            InProcTransport::new(cfg.cluster.topo.world_size());
        Self::with_transport(engine, cfg, seq, batch, &mut transport)
    }

    /// [`Self::new`] over an explicit [`Transport`]: the pool's comm
    /// links are built by `transport`, so the world may span several
    /// processes (`SocketTransport`) — this trainer then hosts only
    /// `transport.local_ranks()` and exchanges with its peers over the
    /// transport's links.  Every process must run the SAME config in
    /// lockstep; the exchange keeps replicas bitwise identical exactly
    /// as in-process.
    pub fn with_transport(engine: &Engine, cfg: RunConfig, seq: usize,
                          batch: usize, transport: &mut dyn Transport)
        -> Result<Trainer> {
        cfg.validate()?;
        let model = engine.model(&cfg.train.preset)?;
        let n = model.param_count;
        let train_step =
            engine.train_step(&cfg.train.preset, &cfg.train.variant, batch,
                              seq)?;
        let apply_step =
            engine.apply_step(&cfg.train.preset, &cfg.train.optimizer)?;
        let buckets = build_buckets(&model.layout, cfg.train.bucket_elems);
        let ranges = bucket_ranges(&buckets);
        let world = cfg.cluster.topo.world_size();
        let wire = if cfg.train.grad_wire_f16 {
            WireFormat::F16
        } else {
            WireFormat::F32
        };
        let pool = CollectivePool::with_transport(cfg.cluster.topo, n,
                                                  ranges.clone(), wire,
                                                  cfg.train.comm_mode,
                                                  cfg.train.intra_node,
                                                  cfg.train.chunk_elems,
                                                  cfg.train.sparsify,
                                                  transport)?;
        let mask_cfg = MaskingConfig {
            mask_prob: cfg.data.mask_prob,
            max_predictions: cfg.data.max_predictions,
            vocab_size: model.config.vocab_size as u32,
            ..Default::default()
        };
        let mut init_rng = Pcg64::with_stream(cfg.train.seed, 0x1111);
        let params = init_params(&model.layout, &mut init_rng);
        Ok(Trainer {
            pool,
            train_step,
            apply_step,
            buckets,
            bucket_ranges: ranges,
            world,
            scaler: DynamicLossScaler::new(cfg.train.init_loss_scale)
                .with_growth_interval(200),
            cfg,
            m: vec![0.0; n],
            v: vec![0.0; n],
            grad_scratch: vec![0.0; n],
            params,
            step: 0,
            data_step: 0,
            data_manifest: 0,
            mask_cfg,
            inject_fail: None,
        })
    }

    /// Arm (or clear) deterministic fault injection — see
    /// [`InjectFail`].  Test/chaos hook; never set in production runs.
    /// The `net` form arms the pool's link-cut trigger instead of the
    /// trainer-side compute failure (a global `rank` whose links live
    /// in another process is that process's injection to run).
    pub fn set_inject_fail(&mut self, inject: Option<InjectFail>) {
        if let Some(f) = inject {
            if f.net {
                self.pool.arm_net_fault(f.step, f.rank);
                self.inject_fail = None;
                return;
            }
        }
        self.inject_fail = inject;
    }

    /// This run's config identity — saved into every checkpoint and
    /// validated against the checkpoint's on [`Self::restore`].
    pub fn fingerprint(&self) -> Fingerprint {
        let mut fp = Fingerprint::of(&self.cfg, self.train_step.batch,
                                     self.train_step.seq);
        fp.data_manifest = self.data_manifest;
        fp
    }

    /// Pin the corpus identity this trainer consumes (a
    /// `data::pipeline::shard_manifest_hash`): snapshots carry it and
    /// [`Self::restore`] refuses a checkpoint whose (known) manifest
    /// differs — resuming over a different dataset would silently
    /// diverge.  Call before any restore.
    pub fn set_data_manifest(&mut self, manifest: u64) {
        self.data_manifest = manifest;
    }

    /// Exact-state restore: continuing from here is bitwise-identical
    /// to the run that produced the checkpoint never having stopped.
    ///
    /// Fails loudly — BEFORE touching any trainer state — when the
    /// checkpoint's config fingerprint does not match this run (a
    /// mismatched resume would diverge silently).  v1 checkpoints have
    /// no fingerprint and no `data_step`; they restore with the legacy
    /// `data_step = step` fallback and a one-line warning (batches
    /// consumed by AMP-skipped steps are not replayed).
    pub fn restore(&mut self, ckpt: Checkpoint) -> Result<()> {
        anyhow::ensure!(
            ckpt.params.len() == self.params.len()
                && ckpt.m.len() == self.m.len()
                && ckpt.v.len() == self.v.len(),
            "checkpoint holds {} params, model has {}",
            ckpt.params.len(), self.params.len()
        );
        ckpt.ensure_fingerprint(&self.fingerprint())?;
        // Error-feedback residuals are part of the exact-resume state:
        // with sparsification active the dropped gradient mass lives in
        // per-rank accumulators that must round-trip bitwise.  (Only the
        // writing process's local ranks are captured, so exact EF resume
        // is an in-process-world contract; socket worlds restore every
        // peer from the same file and this count check trips for them.)
        if self.pool.sparsify_active() {
            self.pool.restore_ef(&ckpt.ef_residuals)?;
        }
        self.adopt(ckpt);
        Ok(())
    }

    /// Elastic (reshaped) restore: resume a checkpoint produced on a
    /// DIFFERENT (machines, gpus) topology — the lost-node path.
    ///
    /// The gate relaxes exactly the world-shape fields
    /// ([`Checkpoint::ensure_reshape_fingerprint`]); any stream-content
    /// mismatch (seed, batch geometry, accumulation, optimizer, LR
    /// schedule, masking, corpus) still refuses before touching trainer
    /// state.  The contract:
    ///
    /// * **bitwise-preserved at restore** — params, m, v, the scaler's
    ///   complete state, `step`, and `data_step`.  This trainer's own
    ///   bucket layout and per-rank cursor positions were already
    ///   derived for the NEW world at [`Trainer::new`]/`run` time, and
    ///   the stream restarts at the checkpointed `data_step`;
    /// * **legitimately diverges afterward** — the reduction
    ///   association (different bucket/ring schedule) and the per-rank
    ///   shard assignment + masking streams (rank r on the new world is
    ///   not rank r on the old one).  Two runs on the SAME new world
    ///   from the same checkpoint remain bitwise-identical — asserted
    ///   in `tests/checkpoint_resume.rs`.
    pub fn restore_reshape(&mut self, ckpt: Checkpoint) -> Result<()> {
        anyhow::ensure!(
            ckpt.params.len() == self.params.len()
                && ckpt.m.len() == self.m.len()
                && ckpt.v.len() == self.v.len(),
            "checkpoint holds {} params, model has {}",
            ckpt.params.len(), self.params.len()
        );
        ckpt.ensure_reshape_fingerprint(&self.fingerprint())?;
        if let Some(saved) = &ckpt.fingerprint {
            if saved.world() != self.world {
                log::info!(
                    "reshaped restore: checkpoint world {} ({}M{}G) -> \
                     run world {} — params/m/v/scaler restore bitwise; \
                     per-rank data streams and reduction association \
                     re-derive for the new world",
                    saved.world(), saved.machines, saved.gpus_per_machine,
                    self.world
                );
            }
        }
        // Per-rank error-feedback residuals cannot be remapped across a
        // world reshape (rank r on the new world is not rank r on the
        // old one); start them from zero like the legitimate stream
        // divergences above.
        if self.pool.sparsify_active() {
            self.pool.zero_ef();
        }
        self.adopt(ckpt);
        Ok(())
    }

    /// The state adoption shared by [`Self::restore`] and
    /// [`Self::restore_reshape`], after their gates have passed.
    fn adopt(&mut self, ckpt: Checkpoint) {
        self.data_step = if ckpt.exact_data_position {
            ckpt.data_step as usize
        } else {
            log::warn!(
                "v1 checkpoint: inexact data position — resuming the \
                 data stream at data_step = step = {}",
                ckpt.step
            );
            ckpt.step as usize
        };
        self.step = ckpt.step as usize;
        self.scaler = DynamicLossScaler::from_state(&ckpt.scaler);
        self.params = ckpt.params;
        self.m = ckpt.m;
        self.v = ckpt.v;
    }

    /// Phase-change restore (paper §3.3): carry params/moments/step/
    /// scaler into a trainer with a DIFFERENT batch geometry (phase 2
    /// switches seq/batch), skipping the fingerprint gate that pins a
    /// single training stream.  The monotone `data_step` counter is
    /// carried over so rotation file names stay unique across phases.
    pub fn restore_weights(&mut self, ckpt: Checkpoint) -> Result<()> {
        anyhow::ensure!(
            ckpt.params.len() == self.params.len()
                && ckpt.m.len() == self.m.len()
                && ckpt.v.len() == self.v.len(),
            "checkpoint holds {} params, model has {}",
            ckpt.params.len(), self.params.len()
        );
        self.step = ckpt.step as usize;
        self.data_step = ckpt.data_step as usize;
        self.scaler = DynamicLossScaler::from_state(&ckpt.scaler);
        self.params = ckpt.params;
        self.m = ckpt.m;
        self.v = ckpt.v;
        // A phase change is a new training stream: residual gradient
        // mass from the old geometry does not carry over.
        if self.pool.sparsify_active() {
            self.pool.zero_ef();
        }
        Ok(())
    }

    /// Capture the complete resumable state into a recycled checkpoint
    /// buffer (pure memcpy — what the hot loop pays per periodic save;
    /// the background writer does the disk work).
    pub fn snapshot_into(&self, out: &mut Checkpoint) {
        out.step = self.step as u64;
        out.data_step = self.data_step as u64;
        out.scaler = self.scaler.export();
        out.fingerprint = Some(self.fingerprint());
        out.exact_data_position = true;
        out.fill_arrays(&self.params, &self.m, &self.v);
        // With sparsification active, the per-rank error-feedback
        // residuals are live optimizer-adjacent state (empty Vec
        // otherwise — the v2.2 section costs 4 bytes when dense).
        out.ef_residuals = self.pool.ef_snapshot();
    }

    /// Snapshot current state into a fresh checkpoint.
    pub fn checkpoint(&self) -> Checkpoint {
        let mut out = Checkpoint::new(0);
        self.snapshot_into(&mut out);
        out
    }

    /// Save a checkpoint to `path` (synchronous atomic write).
    pub fn save(&self, path: &Path) -> Result<()> {
        self.checkpoint().save(path)?;
        Ok(())
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// The shared `(start, end)` bucket table the workers use.
    pub fn bucket_ranges(&self) -> &Arc<[BucketRange]> {
        &self.bucket_ranges
    }

    /// Whether the pool resolved `train.comm_mode` to the hierarchical
    /// (PCIe-then-network) exchange on this topology.
    pub fn is_hierarchical(&self) -> bool {
        self.pool.is_hierarchical()
    }

    /// Whether the hierarchical exchange runs the chunked pipelined
    /// intra-node chain (the resolved `train.intra_node`).
    pub fn is_intra_ring(&self) -> bool {
        self.pool.is_intra_ring()
    }

    /// Whether the exchange runs the bandwidth-optimal 2-level
    /// reduce-scatter schedule (`train.intra_node = rs`).
    pub fn is_intra_rs(&self) -> bool {
        self.pool.is_intra_rs()
    }

    /// Whether the pool's network-crossing rings ship top-k sparse
    /// frames (`train.sparsify = topk:RATIO` on a topology that spans
    /// machines; single-machine runs stay dense regardless).
    pub fn sparsify_active(&self) -> bool {
        self.pool.sparsify_active()
    }

    /// Monotone data-consumption counter (attempted optimizer steps,
    /// including AMP-skipped ones) — the exact stream position a v2
    /// checkpoint captures.
    pub fn data_step(&self) -> usize {
        self.data_step
    }

    /// The contiguous rank range this process hosts (the full world for
    /// in-process runs; one process's slice under a `SocketTransport`).
    pub fn local_ranks(&self) -> std::ops::Range<usize> {
        self.pool.local_ranks()
    }

    /// Whether this process hosts global rank 0 — the process that
    /// should own side effects done once per RUN (checkpoint writing,
    /// plots, progress lines), not once per process.
    pub fn is_lead(&self) -> bool {
        self.pool.is_lead()
    }

    /// Run `steps` optimizer steps over the per-rank datasets.
    /// `datasets.len()` must equal the topology world size.
    pub fn run(&mut self, datasets: &[ShardedDataset], steps: usize,
               total_steps_for_lr: usize) -> Result<TrainReport> {
        self.run_with_ckpt(datasets, steps, total_steps_for_lr, None)
    }

    /// [`Self::run`] with periodic async checkpointing: every
    /// `save_every` steps (the second tuple field) the trainer
    /// snapshots its state into one of the writer's recycled buffers at
    /// the optimizer-step boundary; the atomic write + keep-last
    /// rotation happen on the writer thread, off the hot loop.
    pub fn run_with_ckpt(&mut self, datasets: &[ShardedDataset],
                         steps: usize, total_steps_for_lr: usize,
                         mut ckpt: Option<(&mut AsyncCheckpointWriter,
                                           usize)>)
                         -> Result<TrainReport> {
        anyhow::ensure!(
            datasets.len() == self.world,
            "need {} datasets (one per rank), got {}",
            self.world, datasets.len()
        );
        // Under a multi-process transport this trainer only hosts a
        // contiguous rank slice: input lanes and marshaling scratches
        // are built for those ranks alone (the peers feed their own).
        // `datasets` stays world-sized so global rank r always maps to
        // the same shard assignment regardless of the process split.
        let local = self.pool.local_ranks();
        let local_n = local.len();
        let local_datasets = &datasets[local.clone()];
        let k = self.cfg.train.accum_steps;
        let batch = self.train_step.batch;
        let seq = self.train_step.seq;
        let overlap = self.cfg.train.overlap;

        // The whole step loop runs inside a thread scope so the
        // prefetch producers can borrow `datasets` soundly: the scope
        // cannot close (and this function cannot return) until every
        // producer has been joined.
        std::thread::scope(|scope| {
        let mut report = TrainReport::default();
        let mut meter = ThroughputMeter::new();
        let mut sw = Stopwatch::new();
        let wall = Stopwatch::new();
        // Chunk counts let `--trace` split the PCIe spans per chunk
        // when the pipelined intra-node schedule is active.
        report.exchange.bucket_chunks = self.pool.chunks_per_bucket();

        // ---- 0. input feed: per-rank prefetch producers over bounded
        //         rings of recycled batch buffers, or the synchronous
        //         fallback when `train.prefetch_depth` is 0.  Both paths
        //         run the SAME BatchCursor stream from the same start
        //         position, so they are bitwise-interchangeable. ----
        let start_micro = self.data_step as u64 * k as u64;
        let seed = self.cfg.train.seed;
        let feed = match self.cfg.train.prefetch_depth {
            0 => BatchFeed::Sync(
                local_datasets
                    .iter()
                    .map(|d| {
                        Mutex::new(SyncLane {
                            cursor: BatchCursor::new(
                                d, self.mask_cfg.clone(), seed, batch, seq,
                                start_micro),
                            buf: Batch::zeros(batch, seq),
                        })
                    })
                    .collect(),
            ),
            depth => BatchFeed::Prefetch(Prefetcher::spawn(
                scope, local_datasets, &self.mask_cfg, seed, batch, seq,
                start_micro, depth)),
        };
        let ctx = RankStepCtx {
            step: &self.train_step,
            feed,
            scratches: (0..local_n)
                .map(|_| Mutex::new(StepScratch::new()))
                .collect(),
            k,
            base: local.start,
            inject: self.inject_fail,
        };

        for local_step in 0..steps {
            sw.reset();
            // Deterministic rank-less fault injection: die before the
            // dispatch, like a coordinator crash between steps.  (The
            // rank form lives in RankStepCtx::micro and dies inside
            // the pool, like a node loss mid-exchange.)
            if let Some(f) = self.inject_fail {
                if f.rank.is_none() && self.data_step == f.step {
                    anyhow::bail!(
                        "injected failure at data_step {} (--inject-fail)",
                        f.step
                    );
                }
            }
            // ---- 1+2. parallel rank micro-steps + overlapped bucketed
            //           ring allreduce on the persistent pool ----
            let scale = self.scaler.scale() as f32;
            let out = self.pool.step(&self.params, scale, k,
                                     self.data_step, overlap, &ctx)?;
            self.data_step += 1;
            report.compute_s += out.compute_s + out.accum_s;
            report.input_stall_s += out.input_stall_s;
            report.allreduce_s += out.comm_s;
            report.exchange.record(&out.bucket_s, &out.bucket_pcie_s,
                                   &out.bucket_net_s, out.exposed_comm_s);
            report.exchange.record_input_stall(out.input_stall_s);
            report.exchange.record_net_backpressure(out.net_backpressure_s);
            meter.add((batch * seq * k * self.world) as u64);
            sw.lap("pool");

            // ---- 3. AMP verdict + normalization (reused scratch) ----
            let mut saw_overflow = out.saw_overflow;
            let micro_total = (k * self.world).max(1) as f32;
            {
                let acc0 = self.pool.leader_grads();
                for (dst, g) in
                    self.grad_scratch.iter_mut().zip(acc0.iter()) {
                    *dst = *g / micro_total;
                }
            }
            saw_overflow |= has_nonfinite(&self.grad_scratch);
            let verdict = self.scaler.update(saw_overflow);

            // ---- 4. optimizer apply (leader) ----
            if verdict == StepVerdict::Apply {
                self.step += 1;
                let lr = lr_schedule(self.cfg.train.lr, self.step,
                                     self.cfg.train.warmup_steps,
                                     total_steps_for_lr) as f32;
                self.apply_step.run(&mut self.params, &self.grad_scratch,
                                    &mut self.m, &mut self.v,
                                    self.step as f32, lr)?;
            } else {
                report.skipped_steps += 1;
            }
            report.apply_s += sw.lap("apply");

            // ---- metrics ----
            // Loss/accuracy sums only cover the ranks THIS process
            // hosts (peers average their own); gradients above are the
            // true global sums, normalized by k * world.
            let denom = (k * local_n) as f64;
            report.loss.push(self.step, out.loss_sum / denom);
            report.mlm_loss.push(self.step, out.mlm_sum / denom);
            report.nsp_loss.push(self.step, out.nsp_sum / denom);
            report.mlm_acc.push(self.step, out.acc_sum / denom);
            if self.cfg.train.log_every > 0
                && (local_step + 1) % self.cfg.train.log_every == 0
                && self.pool.is_lead() {
                log::info!(
                    "step {:>5} loss {:.4} mlm {:.4} nsp {:.4} acc {:.3} \
                     scale {} tok/s {:.0}",
                    self.step, out.loss_sum / denom, out.mlm_sum / denom,
                    out.nsp_sum / denom, out.acc_sum / denom,
                    self.scaler.scale(), meter.recent()
                );
                println!(
                    "step {:>5} | loss {:.4} | mlm {:.4} | nsp {:.4} | \
                     acc {:.3} | scale {:>8} | tok/s {:.0}",
                    self.step, out.loss_sum / denom, out.mlm_sum / denom,
                    out.nsp_sum / denom, out.acc_sum / denom,
                    self.scaler.scale(), meter.recent()
                );
            }

            // ---- 5. periodic async checkpoint at the optimizer-step
            //         boundary: memcpy into a recycled snapshot buffer;
            //         the atomic write runs on the writer thread ----
            if let Some((writer, every)) = ckpt.as_mut() {
                if *every > 0 && (local_step + 1) % *every == 0 {
                    let stall = writer.save(|c| self.snapshot_into(c))?;
                    report.checkpoint_s += stall;
                    report.checkpoints += 1;
                }
            }
        }

        report.steps = steps;
        report.final_loss_scale = self.scaler.scale();
        report.tokens_per_sec = meter.average();
        report.total_tokens = meter.total_tokens();
        report.wall_s = wall.elapsed();
        report.overlap_efficiency = report.exchange.overlap_efficiency();
        // The stall is timed inside the micro calls, so it is bounded by
        // compute_s and the ratio is a true fraction (clamped against
        // clock jitter).  No compute at all -> nothing stalled.
        report.data_efficiency = if report.compute_s > 0.0 {
            (1.0 - report.input_stall_s / report.compute_s).clamp(0.0, 1.0)
        } else {
            1.0
        };
        Ok(report)
        }) // thread scope: producers joined here at the latest
    }
}

/// One rank's synchronous input lane (`prefetch_depth = 0`): the batch
/// cursor runs in-line on the compute worker, writing into one recycled
/// buffer; the build time is the rank's exposed input stall.
struct SyncLane<'a> {
    cursor: BatchCursor<'a>,
    buf: Batch,
}

/// How rank batches reach the compute workers.
enum BatchFeed<'a> {
    /// Per-rank producer threads over bounded rings of recycled buffers
    /// (`train.prefetch_depth >= 1`) — batches are ready before the
    /// worker asks.
    Prefetch(Prefetcher<'a>),
    /// Build each batch on the compute worker, synchronously.
    Sync(Vec<Mutex<SyncLane<'a>>>),
}

/// The trainer's per-run [`RankCompute`]: feeds rank `r`'s next masked
/// batch (prefetched or built in-line — bitwise-identical streams) into
/// the shared compiled train step through the rank's recycled
/// [`StepScratch`], decoding gradients straight into the pool's
/// preallocated per-rank buffer.  Per-rank mutable state (cursor,
/// scratch) sits behind per-rank locks, each touched only by its own
/// worker, so the locks are uncontended.
struct RankStepCtx<'a> {
    step: &'a TrainStep,
    feed: BatchFeed<'a>,
    scratches: Vec<Mutex<StepScratch>>,
    k: usize,
    /// First GLOBAL rank this process hosts: lanes and scratches are
    /// indexed by `rank - base` (0 for in-process runs).
    base: usize,
    /// Rank-targeted deterministic fault injection ([`InjectFail`]).
    inject: Option<InjectFail>,
}

impl RankStepCtx<'_> {
    /// Run the compiled step on `b` through rank `r`'s marshaling
    /// scratch; `step_index` (the trainer's monotone data counter)
    /// versions the cached params literal.
    fn exec(&self, rank: usize, step_index: usize, params: &[f32],
            scale: f32, b: &Batch, grads_out: &mut [f32])
            -> Result<StepStats> {
        let mut scratch = self.scratches[rank - self.base]
            .lock()
            .expect("step scratch poisoned");
        self.step.run_scratch(&mut scratch, params, step_index as u64, b,
                              scale, grads_out)
    }
}

impl RankCompute for RankStepCtx<'_> {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             params: &[f32], scale: f32, grads_out: &mut Vec<f32>)
             -> Result<MicroStats> {
        // The pool's per-rank gradient scratch: sized on first use, then
        // decoded into in place forever (no per-micro Vec).
        if grads_out.len() != self.step.n_params {
            grads_out.resize(self.step.n_params, 0.0);
        }
        // Rank-targeted fault injection at the FINAL micro — after the
        // healthy ranks have started feeding their comm workers, the
        // worst spot for the exchange (a lost node mid-step).
        if let Some(f) = self.inject {
            if f.rank == Some(rank) && step_index == f.step
                && micro + 1 == self.k {
                anyhow::bail!(
                    "injected failure on rank {rank} at data_step \
                     {step_index} (--inject-fail)"
                );
            }
        }
        let lane_ix = rank - self.base;
        let (out, stall_s) = match &self.feed {
            BatchFeed::Prefetch(p) => {
                let (b, stall_s) = p.pop(lane_ix)?;
                let out = self.exec(rank, step_index, params, scale, &b,
                                    grads_out)?;
                p.recycle(lane_ix, b);
                (out, stall_s)
            }
            BatchFeed::Sync(lanes) => {
                let mut lane =
                    lanes[lane_ix].lock().expect("sync input lane poisoned");
                debug_assert_eq!(
                    lane.cursor.position(),
                    step_index as u64 * self.k as u64 + micro as u64,
                    "rank {rank} input stream out of step"
                );
                let t0 = Instant::now();
                let SyncLane { cursor, buf } = &mut *lane;
                cursor.fill_next(buf);
                let stall_s = t0.elapsed().as_secs_f64();
                let out = self.exec(rank, step_index, params, scale, buf,
                                    grads_out)?;
                (out, stall_s)
            }
        };
        let nonfinite =
            !out.grad_norm.is_finite() || !out.loss.is_finite();
        Ok(MicroStats {
            loss: out.loss as f64,
            mlm_loss: out.mlm_loss as f64,
            nsp_loss: out.nsp_loss as f64,
            mlm_acc: out.mlm_acc as f64,
            nonfinite,
            input_stall_s: stall_s,
        })
    }
}

/// Initialize parameters like the Python side: N(0, 0.02) clipped at 2σ
/// for weights, ones for LayerNorm gammas, zeros for biases/betas.
pub fn init_params(layout: &crate::model::layout::ParamLayout,
                   rng: &mut Pcg64) -> Vec<f32> {
    let mut out = vec![0.0f32; layout.total_len()];
    for e in layout.entries() {
        let seg = &mut out[e.offset..e.offset + e.len()];
        if e.name.ends_with(".gamma") {
            seg.iter_mut().for_each(|x| *x = 1.0);
        } else if e.name.ends_with(".beta") || e.name.ends_with(".bias") {
            // zeros (already)
        } else {
            for x in seg.iter_mut() {
                let g = (rng.next_gaussian() * 0.02).clamp(-0.04, 0.04);
                *x = g as f32;
            }
        }
    }
    out
}

/// The OLD hot-loop exchange, kept as the per-step-spawn baseline the
/// `perf_hotpath` bench compares the persistent pool against (and as a
/// second implementation the pool is cross-checked with in tests): build
/// a fresh [`CollectiveGroup`], spawn one thread per rank, run the
/// bucketed ring allreduce, join, tear everything down.
pub fn allreduce_buckets(accs: &mut [GradAccumulator], buckets: &[Bucket]) {
    let world = accs.len();
    // Move each rank's buffer out, run threads, move back.
    let mut bufs: Vec<Vec<f32>> = accs
        .iter_mut()
        .map(|a| std::mem::take(a.buffer_mut_vec()))
        .collect();
    let handles = CollectiveGroup::new(world);
    let buckets_owned: Vec<(usize, usize)> =
        buckets.iter().map(|b| (b.start, b.end)).collect();
    let joins: Vec<_> = handles
        .into_iter()
        .zip(bufs.drain(..))
        .map(|(mut h, mut buf)| {
            let bks = buckets_owned.clone();
            std::thread::spawn(move || {
                for (s, e) in bks {
                    h.allreduce(&mut buf[s..e]);
                }
                buf
            })
        })
        .collect();
    for (a, j) in accs.iter_mut().zip(joins) {
        *a.buffer_mut_vec() = j.join().expect("allreduce worker panicked");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BertConfig;

    #[test]
    fn init_params_structure() {
        let layout = BertConfig::preset("bert-micro").unwrap().param_layout();
        let mut rng = Pcg64::new(1);
        let p = init_params(&layout, &mut rng);
        assert_eq!(p.len(), 146_178);
        // gamma segment is ones
        let g = layout.find("embeddings.layernorm.gamma").unwrap();
        assert!(p[g.offset..g.offset + g.len()].iter().all(|&x| x == 1.0));
        // bias segment is zeros
        let b = layout.find("cls.pooler.bias").unwrap();
        assert!(p[b.offset..b.offset + b.len()].iter().all(|&x| x == 0.0));
        // weights are clipped gaussians
        let w = layout.find("embeddings.word_embeddings").unwrap();
        let seg = &p[w.offset..w.offset + w.len()];
        assert!(seg.iter().all(|&x| x.abs() <= 0.04));
        assert!(seg.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn allreduce_buckets_sums_across_ranks() {
        let layout = crate::model::layout::ParamLayout::from_shapes(&[
            ("a".into(), vec![100]),
            ("b".into(), vec![57]),
        ]);
        let buckets = build_buckets(&layout, 64);
        let mut accs: Vec<GradAccumulator> =
            (0..3).map(|_| GradAccumulator::new(157)).collect();
        for (r, acc) in accs.iter_mut().enumerate() {
            let g: Vec<f32> = (0..157).map(|i| (r * 200 + i) as f32).collect();
            acc.add(&g);
        }
        let want: Vec<f32> = (0..157)
            .map(|i| (0..3).map(|r| (r * 200 + i) as f32).sum())
            .collect();
        allreduce_buckets(&mut accs, &buckets);
        for acc in &accs {
            crate::testkit::assert_allclose(acc.buffer(), &want, 1e-4, 1e-5);
        }
    }

    #[test]
    fn pool_exchange_matches_per_step_spawn_baseline_bitwise() {
        // The persistent pool and the old spawn-per-step path execute
        // the SAME ring schedule, so their reduced gradients must agree
        // bitwise (not just within tolerance).
        use crate::collectives::pool::{CollectivePool, MicroStats,
                                       RankCompute, WireFormat};

        struct Fixed {
            grads: Vec<Vec<f32>>, // per rank
        }
        impl RankCompute for Fixed {
            fn micro(&self, rank: usize, _s: usize, _m: usize, _p: &[f32],
                     _sc: f32, out: &mut Vec<f32>)
                     -> anyhow::Result<MicroStats> {
                out.clear();
                out.extend_from_slice(&self.grads[rank]);
                Ok(MicroStats::default())
            }
        }

        let layout = crate::model::layout::ParamLayout::from_shapes(&[
            ("a".into(), vec![90]),
            ("b".into(), vec![67]),
        ]);
        let n = layout.total_len();
        let world = 3;
        let buckets = build_buckets(&layout, 64);
        let mut rng = Pcg64::new(0xF00D);
        let grads: Vec<Vec<f32>> = (0..world)
            .map(|_| (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
            .collect();

        // baseline: per-step spawn
        let mut accs: Vec<GradAccumulator> =
            (0..world).map(|_| GradAccumulator::new(n)).collect();
        for (a, g) in accs.iter_mut().zip(&grads) {
            a.add(g);
        }
        allreduce_buckets(&mut accs, &buckets);

        // persistent pool, overlap on
        let mut pool = CollectivePool::new(world, n, bucket_ranges(&buckets),
                                           WireFormat::F32);
        pool.step(&[], 1.0, 1, 0, true, &Fixed { grads }).unwrap();

        for r in 0..world {
            let got = pool.rank_grads(r);
            for (x, y) in got.iter().zip(accs[r].buffer().iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {r}");
            }
        }
    }
}
