//! Metrics: counters, tokens/s throughput meter, loss-curve recorder, and
//! a chrome-trace timeline exporter (load `chrome://tracing` /
//! ui.perfetto.dev on the emitted JSON to see the Figure-2/5 spans).

use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;
use std::time::Instant;

use crate::jsonlite::Json;

/// Throughput meter over a sliding window of (time, tokens) samples.
#[derive(Debug)]
pub struct ThroughputMeter {
    start: Instant,
    total_tokens: u64,
    /// Ring buffer: eviction is O(1) (`pop_front`) — this sits in the
    /// trainer's hot loop, where a `Vec::remove(0)` front-shift cost
    /// O(window) per sample.
    window: VecDeque<(f64, u64)>,
    window_cap: usize,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            total_tokens: 0,
            window: VecDeque::with_capacity(65),
            window_cap: 64,
        }
    }

    /// Record `tokens` processed now.
    pub fn add(&mut self, tokens: u64) {
        self.total_tokens += tokens;
        let t = self.start.elapsed().as_secs_f64();
        self.window.push_back((t, tokens));
        if self.window.len() > self.window_cap {
            self.window.pop_front();
        }
    }

    /// Lifetime average tokens/s.
    pub fn average(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            self.total_tokens as f64 / dt
        }
    }

    /// Tokens/s over the recent window.
    pub fn recent(&self) -> f64 {
        if self.window.len() < 2 {
            return self.average();
        }
        let t0 = self.window.front().unwrap().0;
        let t1 = self.window.back().unwrap().0;
        let toks: u64 = self.window.iter().skip(1).map(|(_, n)| n).sum();
        if t1 <= t0 {
            self.average()
        } else {
            toks as f64 / (t1 - t0)
        }
    }

    pub fn total_tokens(&self) -> u64 {
        self.total_tokens
    }
}

/// Loss-curve recorder: (step, loss) samples + CSV/summary export —
/// the data behind Figures 7/8.
#[derive(Debug, Default, Clone)]
pub struct LossCurve {
    pub points: Vec<(usize, f64)>,
}

impl LossCurve {
    pub fn push(&mut self, step: usize, loss: f64) {
        self.points.push((step, loss));
    }

    /// Mean loss over the last `n` points.
    pub fn tail_mean(&self, n: usize) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let tail = &self.points[self.points.len().saturating_sub(n)..];
        tail.iter().map(|(_, l)| l).sum::<f64>() / tail.len() as f64
    }

    /// Simple monotonic-trend check: mean of first k vs last k.
    pub fn improved(&self, k: usize) -> bool {
        if self.points.len() < 2 * k {
            return false;
        }
        let head: f64 = self.points[..k].iter().map(|(_, l)| l).sum::<f64>()
            / k as f64;
        let tail = self.tail_mean(k);
        tail < head
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss\n");
        for (step, loss) in &self.points {
            let _ = writeln!(s, "{step},{loss}");
        }
        s
    }

    /// Points as (x, y) f64 pairs for the ascii plotter.
    pub fn xy(&self) -> Vec<(f64, f64)> {
        self.points.iter().map(|&(s, l)| (s as f64, l)).collect()
    }
}

/// Exchange-timing accumulator for the persistent collective pool
/// (paper §4.4 / Fig. 2): per-bucket exchange seconds split into the
/// PCIe (intra-node) and network (inter-node) phases of the schedule,
/// plus the *exposed* communication — the pure time a step was blocked
/// waiting for reduced buckets after its gradient accumulation finished.
/// The headline derived metric is
/// [`ExchangeTimings::overlap_efficiency`], the fraction of exchange
/// wall-clock hidden behind compute.
#[derive(Debug, Default, Clone)]
pub struct ExchangeTimings {
    /// Summed exchange seconds per bucket (backward order, bucket 0
    /// first), accumulated over steps.
    pub bucket_s: Vec<f64>,
    /// Summed PCIe-phase seconds per bucket.  Each phase component is a
    /// per-rank maximum taken independently of the total, so
    /// `bucket_pcie_s[b] + bucket_net_s[b] >= bucket_s[b]` (the split
    /// never understates a phase).
    pub bucket_pcie_s: Vec<f64>,
    /// Summed network-phase seconds per bucket.
    pub bucket_net_s: Vec<f64>,
    /// Total exchange seconds across all buckets and steps.
    pub total_comm_s: f64,
    /// Network (inter-node) phase seconds.
    pub net_comm_s: f64,
    /// PCIe (intra-node) phase seconds.
    pub pcie_comm_s: f64,
    /// Total exposed (non-overlapped) communication seconds.
    pub exposed_comm_s: f64,
    /// Total seconds compute workers spent blocked waiting on input
    /// batches (critical-path max over ranks, summed over steps) — the
    /// data-pipeline twin of `exposed_comm_s`, recorded via
    /// [`Self::record_input_stall`] so data stalls render next to the
    /// PCIe/network spans in [`Self::to_timeline`].
    pub input_stall_s: f64,
    /// Total seconds socket sends spent stalled on a full per-link send
    /// queue (critical-path max over ranks, summed over steps) —
    /// backpressure from a slow or congested peer, recorded via
    /// [`Self::record_net_backpressure`].  Always 0 for in-process
    /// transports.
    pub net_backpressure_s: f64,
    /// Chunks each bucket's exchange splits into under the pipelined
    /// intra-node schedule (`CollectivePool::chunks_per_bucket`); empty
    /// or 1 = unchunked.  [`Self::to_timeline`] splits a chunked
    /// bucket's PCIe/network spans per chunk so the pipeline overlap is
    /// visible in the trace.
    pub bucket_chunks: Vec<usize>,
    /// Steps recorded.
    pub steps: usize,
}

impl ExchangeTimings {
    /// Record one step's per-bucket exchange seconds (total plus the
    /// PCIe and network phase components) and its exposed communication
    /// tail.
    pub fn record(&mut self, bucket_s: &[f64], bucket_pcie_s: &[f64],
                  bucket_net_s: &[f64], exposed_s: f64) {
        if self.bucket_s.len() < bucket_s.len() {
            self.bucket_s.resize(bucket_s.len(), 0.0);
        }
        if self.bucket_pcie_s.len() < bucket_pcie_s.len() {
            self.bucket_pcie_s.resize(bucket_pcie_s.len(), 0.0);
        }
        if self.bucket_net_s.len() < bucket_net_s.len() {
            self.bucket_net_s.resize(bucket_net_s.len(), 0.0);
        }
        for (t, b) in self.bucket_s.iter_mut().zip(bucket_s) {
            *t += *b;
        }
        for (t, b) in self.bucket_pcie_s.iter_mut().zip(bucket_pcie_s) {
            *t += *b;
        }
        for (t, b) in self.bucket_net_s.iter_mut().zip(bucket_net_s) {
            *t += *b;
        }
        self.total_comm_s += bucket_s.iter().sum::<f64>();
        self.pcie_comm_s += bucket_pcie_s.iter().sum::<f64>();
        self.net_comm_s += bucket_net_s.iter().sum::<f64>();
        self.exposed_comm_s += exposed_s;
        self.steps += 1;
    }

    /// Record one step's input-stall seconds (paired with the same
    /// step's [`Self::record`] call; kept separate so exchange-only
    /// callers like `profile-grads` stay unchanged).
    pub fn record_input_stall(&mut self, stall_s: f64) {
        self.input_stall_s += stall_s;
    }

    /// Record one step's send-queue backpressure seconds (paired with
    /// the same step's [`Self::record`] call, like
    /// [`Self::record_input_stall`]).
    pub fn record_net_backpressure(&mut self, stall_s: f64) {
        self.net_backpressure_s += stall_s;
    }

    /// `1 - exposed/total`: 1.0 means the exchange was fully hidden
    /// behind compute, 0.0 means it was fully serialized (or there was
    /// no communication at all).  Always in `[0, 1]`.
    pub fn overlap_efficiency(&self) -> f64 {
        if self.total_comm_s <= 0.0 {
            0.0
        } else {
            (1.0 - self.exposed_comm_s / self.total_comm_s).clamp(0.0, 1.0)
        }
    }

    /// Mean exchange seconds per step for bucket `b`.
    pub fn mean_bucket_s(&self, b: usize) -> f64 {
        if self.steps == 0 || b >= self.bucket_s.len() {
            0.0
        } else {
            self.bucket_s[b] / self.steps as f64
        }
    }

    /// Mean PCIe-phase seconds per step for bucket `b`.
    pub fn mean_bucket_pcie_s(&self, b: usize) -> f64 {
        if self.steps == 0 || b >= self.bucket_pcie_s.len() {
            0.0
        } else {
            self.bucket_pcie_s[b] / self.steps as f64
        }
    }

    /// Mean network-phase seconds per step for bucket `b`.
    pub fn mean_bucket_net_s(&self, b: usize) -> f64 {
        if self.steps == 0 || b >= self.bucket_net_s.len() {
            0.0
        } else {
            self.bucket_net_s[b] / self.steps as f64
        }
    }

    /// One-line log summary.
    pub fn summary(&self) -> String {
        format!(
            "buckets={} comm={:.3}s (pcie {:.3}s / net {:.3}s) \
             exposed={:.3}s overlap_eff={:.0}% input_stall={:.3}s \
             backpressure={:.3}s",
            self.bucket_s.len(), self.total_comm_s, self.pcie_comm_s,
            self.net_comm_s, self.exposed_comm_s,
            self.overlap_efficiency() * 100.0, self.input_stall_s,
            self.net_backpressure_s
        )
    }

    /// Render the mean per-step exchange as a span [`Timeline`] on
    /// "pcie" and "net" tracks, buckets laid out back-to-back in
    /// backward-readiness order — the chrome-trace artifact
    /// `cmd_profile`/`train --trace` export for ui.perfetto.dev.
    ///
    /// When a bucket has both phases (the hierarchical schedule), its
    /// PCIe time is drawn as `gather` and `bcast` spans AROUND the
    /// network span, matching the executed accumulate → leader-ring →
    /// broadcast order.  The two halves are depicted as equal — the
    /// phases execute the same `(g-1)` full-payload transfers, which is
    /// also how `netsim::hierarchical_allreduce_phases` prices them;
    /// only their sum is measured.
    pub fn to_timeline(&self) -> Timeline {
        let mut tl = Timeline::default();
        // Data-stall lane: the mean per-step seconds a compute worker sat
        // waiting on input batches, drawn from t=0 on its own "data"
        // track so input starvation reads side by side with the
        // PCIe/network exchange spans.
        if self.steps > 0 && self.input_stall_s > 0.0 {
            let stall = self.input_stall_s / self.steps as f64;
            tl.add("data", "input_stall", 0.0, stall);
        }
        // Backpressure lane: mean per-step seconds socket sends sat on
        // a full send queue, on its own "backpressure" track so peer
        // congestion reads side by side with the exchange spans.
        if self.steps > 0 && self.net_backpressure_s > 0.0 {
            let bp = self.net_backpressure_s / self.steps as f64;
            tl.add("backpressure", "send_queue_full", 0.0, bp);
        }
        let mut t = 0.0f64;
        for b in 0..self.bucket_s.len() {
            let pcie = self.mean_bucket_pcie_s(b);
            let net = self.mean_bucket_net_s(b);
            let chunks = self.bucket_chunks.get(b).copied().unwrap_or(1);
            t = add_bucket_exchange_spans(&mut tl, b, t, pcie, net, chunks);
        }
        tl
    }
}

/// Render one bucket's exchange onto `tl` starting at `start` and
/// return the bucket's end time — the span-naming convention shared by
/// the MEASURED trace ([`ExchangeTimings::to_timeline`], `train
/// --trace` / `profile-grads --trace`) and the MODELED one
/// (`cmd_simulate`), so the two line up in ui.perfetto.dev:
///
/// * flat (or single-phase) bucket — one `bucket{b}.net` (or
///   `bucket{b}.pcie`) span;
/// * hierarchical serialized bucket (`chunks <= 1`) — the executed
///   order `bucket{b}.pcie.gather` → `bucket{b}.net` →
///   `bucket{b}.pcie.bcast`, the two PCIe halves depicted equal (both
///   execute the same `(g-1)` transfers);
/// * hierarchical pipelined bucket (`chunks > 1`) — per-chunk spans
///   `bucket{b}.pcie.gather.c{k}` / `bucket{b}.net.c{k}` /
///   `bucket{b}.pcie.bcast.c{k}` laid out on the pipeline schedule:
///   chunk k's ring starts once its gather lands (and the NIC frees
///   up), its broadcast once its ring completes — so the gather of
///   chunk k+1 visibly overlaps the ring of chunk k.  The end time
///   never exceeds `start + pcie_s + net_s` (pipelining only shortens
///   the depicted bucket).
pub fn add_bucket_exchange_spans(tl: &mut Timeline, b: usize, start: f64,
                                 pcie_s: f64, net_s: f64, chunks: usize)
                                 -> f64 {
    if pcie_s > 0.0 && net_s > 0.0 {
        if chunks > 1 {
            let c = chunks as f64;
            let gc = pcie_s / 2.0 / c;
            let nc = net_s / c;
            let bc = pcie_s / 2.0 / c;
            let mut net_free = 0.0f64;
            let mut bcast_free = 0.0f64;
            for k in 0..chunks {
                let g0 = start + k as f64 * gc;
                tl.add("pcie", &format!("bucket{b}.pcie.gather.c{k}"), g0,
                       g0 + gc);
                let n0 = (g0 + gc).max(net_free);
                tl.add("net", &format!("bucket{b}.net.c{k}"), n0, n0 + nc);
                net_free = n0 + nc;
                let b0 = net_free.max(bcast_free);
                tl.add("pcie", &format!("bucket{b}.pcie.bcast.c{k}"), b0,
                       b0 + bc);
                bcast_free = b0 + bc;
            }
            bcast_free
        } else {
            let half = pcie_s / 2.0;
            tl.add("pcie", &format!("bucket{b}.pcie.gather"), start,
                   start + half);
            tl.add("net", &format!("bucket{b}.net"), start + half,
                   start + half + net_s);
            tl.add("pcie", &format!("bucket{b}.pcie.bcast"),
                   start + half + net_s, start + pcie_s + net_s);
            start + pcie_s + net_s
        }
    } else if pcie_s > 0.0 {
        tl.add("pcie", &format!("bucket{b}.pcie"), start, start + pcie_s);
        start + pcie_s
    } else if net_s > 0.0 {
        tl.add("net", &format!("bucket{b}.net"), start, start + net_s);
        start + net_s
    } else {
        start
    }
}

/// One span in a trace timeline (chrome trace "X" event).
#[derive(Debug, Clone)]
pub struct Span {
    pub name: String,
    /// Track id, e.g. the GPU rank or "net".
    pub track: String,
    /// Seconds.
    pub start: f64,
    pub end: f64,
}

/// Timeline of spans; exports chrome trace JSON and an ASCII gantt —
/// the Figure-2/5 artifact.
#[derive(Debug, Default, Clone)]
pub struct Timeline {
    pub spans: Vec<Span>,
}

impl Timeline {
    pub fn add(&mut self, track: &str, name: &str, start: f64, end: f64) {
        debug_assert!(end >= start, "{name}: end {end} < start {start}");
        self.spans.push(Span {
            name: name.to_string(),
            track: track.to_string(),
            start,
            end,
        });
    }

    /// Latest end time.
    pub fn horizon(&self) -> f64 {
        self.spans.iter().map(|s| s.end).fold(0.0, f64::max)
    }

    /// Busy time per (track, span-name prefix).
    pub fn busy(&self, track: &str, prefix: &str) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.track == track && s.name.starts_with(prefix))
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Chrome trace JSON ("traceEvents" array of X events, µs units).
    pub fn to_chrome_trace(&self) -> String {
        let events: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(s.name.clone()));
                m.insert("ph".to_string(), Json::Str("X".to_string()));
                m.insert("ts".to_string(), Json::Num(s.start * 1e6));
                m.insert("dur".to_string(),
                         Json::Num((s.end - s.start) * 1e6));
                m.insert("pid".to_string(), Json::Num(1.0));
                m.insert("tid".to_string(), Json::Str(s.track.clone()));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("traceEvents".to_string(), Json::Arr(events));
        Json::Obj(root).to_string()
    }

    /// ASCII gantt chart over `width` columns (the Figure-2/5 rendering).
    pub fn ascii_gantt(&self, width: usize) -> String {
        let horizon = self.horizon().max(1e-12);
        let mut tracks: Vec<String> = self
            .spans
            .iter()
            .map(|s| s.track.clone())
            .collect();
        tracks.sort();
        tracks.dedup();
        let lw = tracks.iter().map(|t| t.len()).max().unwrap_or(0);
        let mut out = String::new();
        for t in &tracks {
            let mut row = vec![' '; width];
            for s in self.spans.iter().filter(|s| &s.track == t) {
                let c0 = ((s.start / horizon) * width as f64) as usize;
                let c1 = (((s.end / horizon) * width as f64).ceil() as usize)
                    .min(width);
                let ch = s.name.chars().next().unwrap_or('?');
                for c in row.iter_mut().take(c1).skip(c0.min(width)) {
                    *c = ch;
                }
            }
            let _ = writeln!(out, "{:<lw$} |{}|", t,
                             row.iter().collect::<String>());
        }
        let _ = writeln!(out, "{:<lw$}  0{}{:.4}s", "",
                         " ".repeat(width.saturating_sub(8)), horizon);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_accumulates() {
        let mut m = ThroughputMeter::new();
        m.add(1000);
        m.add(1000);
        assert_eq!(m.total_tokens(), 2000);
        assert!(m.average() > 0.0);
        assert!(m.recent() >= 0.0);
    }

    #[test]
    fn loss_curve_trend() {
        let mut c = LossCurve::default();
        for i in 0..20 {
            c.push(i, 10.0 - i as f64 * 0.3);
        }
        assert!(c.improved(5));
        assert!(c.tail_mean(5) < 6.0);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,loss\n0,10\n"));
        assert_eq!(c.xy().len(), 20);
    }

    #[test]
    fn flat_curve_not_improved() {
        let mut c = LossCurve::default();
        for i in 0..10 {
            c.push(i, 5.0);
        }
        assert!(!c.improved(3));
    }

    #[test]
    fn exchange_timings_accumulate_and_rate() {
        let mut t = ExchangeTimings::default();
        // fully serialized step: everything exposed; 0.08s of the
        // exchange crossed the network, 0.22s rode PCIe
        t.record(&[0.2, 0.1], &[0.15, 0.07], &[0.05, 0.03], 0.3);
        assert_eq!(t.steps, 1);
        assert!((t.total_comm_s - 0.3).abs() < 1e-12);
        assert!((t.net_comm_s - 0.08).abs() < 1e-12);
        assert!((t.pcie_comm_s - 0.22).abs() < 1e-12);
        assert!(t.overlap_efficiency() < 1e-9);
        // fully hidden step
        t.record(&[0.2, 0.1], &[0.15, 0.07], &[0.05, 0.03], 0.0);
        assert!((t.overlap_efficiency() - 0.5).abs() < 1e-9);
        assert!((t.mean_bucket_s(0) - 0.2).abs() < 1e-12);
        assert!((t.mean_bucket_pcie_s(0) - 0.15).abs() < 1e-12);
        assert!((t.mean_bucket_net_s(0) - 0.05).abs() < 1e-12);
        assert_eq!(t.mean_bucket_s(9), 0.0);
        assert!(t.summary().contains("overlap_eff=50%"));
        assert!(t.summary().contains("pcie"));
    }

    #[test]
    fn exchange_timings_no_comm_is_zero_efficiency() {
        let mut t = ExchangeTimings::default();
        t.record(&[], &[], &[], 0.0);
        assert_eq!(t.overlap_efficiency(), 0.0);
    }

    #[test]
    fn exchange_timings_efficiency_clamped_to_unit_interval() {
        // exposed wait can exceed measured exchange by channel overhead;
        // the reported ratio must still land in [0, 1]
        let mut t = ExchangeTimings::default();
        t.record(&[0.1], &[0.0], &[0.1], 0.2);
        let e = t.overlap_efficiency();
        assert!((0.0..=1.0).contains(&e), "{e}");
    }

    #[test]
    fn exchange_timeline_splits_pcie_and_net_spans() {
        let mut t = ExchangeTimings::default();
        // two steps so the means are exercised: bucket 0 all-PCIe,
        // bucket 1 mixed, bucket 2 all-network
        t.record(&[0.2, 0.3, 0.1], &[0.2, 0.2, 0.0], &[0.0, 0.1, 0.1], 0.0);
        t.record(&[0.2, 0.3, 0.1], &[0.2, 0.2, 0.0], &[0.0, 0.1, 0.1], 0.0);
        let tl = t.to_timeline();
        assert!((tl.busy("pcie", "bucket0") - 0.2).abs() < 1e-12);
        assert!((tl.busy("pcie", "bucket1") - 0.2).abs() < 1e-12);
        assert!((tl.busy("net", "bucket1") - 0.1).abs() < 1e-12);
        assert_eq!(tl.busy("pcie", "bucket2"), 0.0);
        assert!((tl.busy("net", "bucket2") - 0.1).abs() < 1e-12);
        // spans tile the mean step back to back
        assert!((tl.horizon() - 0.6).abs() < 1e-12);
        // mixed bucket 1 renders the executed order:
        // gather -> leader ring -> broadcast
        let find = |name: &str| {
            tl.spans.iter().find(|s| s.name == name).unwrap()
        };
        let (g, n, bc) = (find("bucket1.pcie.gather"), find("bucket1.net"),
                         find("bucket1.pcie.bcast"));
        assert!(g.end <= n.start + 1e-12 && n.end <= bc.start + 1e-12,
                "phase order wrong: {g:?} {n:?} {bc:?}");
        // and the chrome trace renders
        let j = Json::parse(&tl.to_chrome_trace()).unwrap();
        assert!(j.get("traceEvents").unwrap().as_arr().unwrap().len() >= 4);
    }

    #[test]
    fn chunked_bucket_renders_per_chunk_pipeline_spans() {
        let mut t = ExchangeTimings::default();
        t.record(&[0.3], &[0.2], &[0.1], 0.0);
        t.bucket_chunks = vec![2];
        let tl = t.to_timeline();
        // the chunk spans partition the phase totals...
        assert!((tl.busy("pcie", "bucket0.pcie.gather") - 0.1).abs() < 1e-12);
        assert!((tl.busy("pcie", "bucket0.pcie.bcast") - 0.1).abs() < 1e-12);
        assert!((tl.busy("net", "bucket0.net") - 0.1).abs() < 1e-12);
        let find = |name: &str| {
            tl.spans.iter().find(|s| s.name == name).unwrap()
        };
        // ...and lay out the pipeline: chunk 1 gathers WHILE chunk 0
        // rings (the overlap the schedule exists for), each chunk's
        // ring after its gather, each broadcast after its ring.
        let (g0, g1) = (find("bucket0.pcie.gather.c0"),
                        find("bucket0.pcie.gather.c1"));
        let (n0, n1) = (find("bucket0.net.c0"), find("bucket0.net.c1"));
        let (b0, b1) = (find("bucket0.pcie.bcast.c0"),
                        find("bucket0.pcie.bcast.c1"));
        assert!(g0.end <= n0.start + 1e-12 && g1.end <= n1.start + 1e-12);
        assert!(n0.end <= b0.start + 1e-12 && n1.end <= b1.start + 1e-12);
        assert!(g1.start < n0.end, "gather.c1 must overlap net.c0");
        assert!(b1.end > b0.end);
        // pipelining never stretches the bucket past the serial depiction
        assert!(tl.horizon() <= 0.3 + 1e-12, "{}", tl.horizon());
        // a second (unchunked) record path still uses the serial naming
        let mut q = ExchangeTimings::default();
        q.record(&[0.3], &[0.2], &[0.1], 0.0);
        q.bucket_chunks = vec![1];
        let qt = q.to_timeline();
        assert!(qt.spans.iter().any(|s| s.name == "bucket0.pcie.gather"));
        assert!((qt.horizon() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn input_stall_records_and_renders_data_lane() {
        let mut t = ExchangeTimings::default();
        t.record(&[0.2], &[0.2], &[0.0], 0.0);
        t.record_input_stall(0.05);
        t.record(&[0.2], &[0.2], &[0.0], 0.0);
        t.record_input_stall(0.15);
        assert!((t.input_stall_s - 0.2).abs() < 1e-12);
        assert!(t.summary().contains("input_stall=0.200s"));
        let tl = t.to_timeline();
        // mean per-step stall on its own lane, next to the pcie span
        assert!((tl.busy("data", "input_stall") - 0.1).abs() < 1e-12);
        assert!((tl.busy("pcie", "bucket0") - 0.2).abs() < 1e-12);
        // no stall recorded -> no data lane
        let mut q = ExchangeTimings::default();
        q.record(&[0.1], &[0.1], &[0.0], 0.0);
        assert_eq!(q.to_timeline().busy("data", ""), 0.0);
    }

    #[test]
    fn net_backpressure_records_and_renders_its_own_lane() {
        let mut t = ExchangeTimings::default();
        t.record(&[0.2], &[0.0], &[0.2], 0.0);
        t.record_net_backpressure(0.04);
        t.record(&[0.2], &[0.0], &[0.2], 0.0);
        t.record_net_backpressure(0.06);
        assert!((t.net_backpressure_s - 0.1).abs() < 1e-12);
        assert!(t.summary().contains("backpressure=0.100s"));
        let tl = t.to_timeline();
        // mean per-step stall on its own lane
        assert!((tl.busy("backpressure", "send_queue_full") - 0.05).abs()
                < 1e-12);
        // no backpressure recorded -> no lane
        let mut q = ExchangeTimings::default();
        q.record(&[0.1], &[0.1], &[0.0], 0.0);
        assert_eq!(q.to_timeline().busy("backpressure", ""), 0.0);
    }

    #[test]
    fn timeline_accounting() {
        let mut t = Timeline::default();
        t.add("gpu0", "fwd", 0.0, 1.0);
        t.add("gpu0", "bwd", 1.0, 3.0);
        t.add("net", "allreduce", 1.5, 4.0);
        assert_eq!(t.horizon(), 4.0);
        assert_eq!(t.busy("gpu0", "fwd"), 1.0);
        assert_eq!(t.busy("gpu0", ""), 3.0);
        assert_eq!(t.busy("net", "allreduce"), 2.5);
    }

    #[test]
    fn chrome_trace_parses_as_json() {
        let mut t = Timeline::default();
        t.add("gpu0", "fwd", 0.0, 0.5);
        let j = Json::parse(&t.to_chrome_trace()).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(events[0].get("dur").unwrap().as_f64(), Some(0.5e6));
    }

    #[test]
    fn gantt_renders_tracks() {
        let mut t = Timeline::default();
        t.add("gpu0", "fwd", 0.0, 1.0);
        t.add("net", "allreduce", 1.0, 2.0);
        let g = t.ascii_gantt(40);
        assert!(g.contains("gpu0"));
        assert!(g.contains("net"));
        assert!(g.contains('f'));
        assert!(g.contains('a'));
    }
}
