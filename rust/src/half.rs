//! IEEE 754 binary16 ("FP16") implemented from scratch (paper §2.3, §4.2).
//!
//! The AMP engine needs real half-precision semantics — round-to-nearest-
//! even conversion, overflow to ±inf, gradual underflow to subnormals and
//! zero — to model exactly the phenomenon the paper's loss scaling fixes:
//! small-magnitude gradients rounding to zero in FP16's `[-14, 15]`
//! exponent range.  The `half` crate is unavailable offline; this is the
//! substrate replacement, fully tested against the IEEE rules.

/// A 16-bit IEEE 754 half-precision float (storage type).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct F16(pub u16);

/// Largest finite f16 value (65504.0).
pub const F16_MAX: f32 = 65504.0;
/// Smallest positive normal f16 (2^-14).
pub const F16_MIN_POSITIVE: f32 = 6.103_515_6e-5;
/// Smallest positive subnormal f16 (2^-24).
pub const F16_MIN_SUBNORMAL: f32 = 5.960_464_5e-8;

impl F16 {
    pub const ZERO: F16 = F16(0x0000);
    pub const NEG_ZERO: F16 = F16(0x8000);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    pub const NAN: F16 = F16(0x7E00);
    pub const MAX: F16 = F16(0x7BFF);

    /// Convert from f32 with round-to-nearest-even (IEEE default).
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let frac = bits & 0x007F_FFFF;

        if exp == 0xFF {
            // Inf or NaN
            return if frac == 0 {
                F16(sign | 0x7C00)
            } else {
                F16(sign | 0x7E00 | ((frac >> 13) as u16 & 0x03FF))
            };
        }

        // Unbiased exponent in f32, re-biased for f16 (bias 15).
        let e = exp - 127 + 15;
        if e >= 0x1F {
            // Overflow -> infinity (this is what zaps huge scaled grads).
            return F16(sign | 0x7C00);
        }
        if e <= 0 {
            // Subnormal or underflow to zero.
            if e < -10 {
                return F16(sign); // too small: signed zero
            }
            // Add the implicit leading 1, then shift right.
            let m = frac | 0x0080_0000;
            let shift = (14 - e) as u32;
            let half_ulp = 1u32 << (shift - 1);
            let mut sub = m >> shift;
            // round to nearest even
            let rem = m & ((1 << shift) - 1);
            if rem > half_ulp || (rem == half_ulp && (sub & 1) == 1) {
                sub += 1;
            }
            return F16(sign | sub as u16);
        }

        // Normal number: round 23-bit mantissa to 10 bits, nearest-even.
        let mut mant = (frac >> 13) as u16;
        let rem = frac & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (mant & 1) == 1) {
            mant += 1;
            if mant == 0x400 {
                // mantissa overflowed into the exponent
                return if e + 1 >= 0x1F {
                    F16(sign | 0x7C00)
                } else {
                    F16(sign | (((e + 1) as u16) << 10))
                };
            }
        }
        F16(sign | ((e as u16) << 10) | mant)
    }

    /// Convert to f32 (exact — every f16 is representable in f32).
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & 0x8000) as u32) << 16;
        let exp = ((self.0 >> 10) & 0x1F) as u32;
        let frac = (self.0 & 0x03FF) as u32;
        let bits = if exp == 0 {
            if frac == 0 {
                sign // signed zero
            } else {
                // subnormal: normalize
                let mut e = 127 - 15 - 10;
                let mut f = frac;
                while f & 0x0400 == 0 {
                    f <<= 1;
                    e -= 1;
                }
                f &= 0x03FF;
                sign | (((e + 10 + 1) as u32) << 23) | (f << 13)
            }
        } else if exp == 0x1F {
            sign | 0x7F80_0000 | (frac << 13) // inf / nan
        } else {
            sign | ((exp + 127 - 15) << 23) | (frac << 13)
        };
        f32::from_bits(bits)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x03FF) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }

    pub fn is_finite(self) -> bool {
        (self.0 & 0x7C00) != 0x7C00
    }

    pub fn is_subnormal(self) -> bool {
        (self.0 & 0x7C00) == 0 && (self.0 & 0x03FF) != 0
    }

    pub fn is_zero(self) -> bool {
        (self.0 & 0x7FFF) == 0
    }
}

/// Classify what happens to an f32 value when cast to f16 — the AMP
/// engine uses this to reason about gradient distributions (paper §2.3:
/// "many small-magnitude gradients are rounded to zero").
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CastFate {
    /// Representable as a normal f16 (possibly rounded).
    Normal,
    /// Lands in the subnormal range — precision loss.
    Subnormal,
    /// Flushes to zero — the gradient vanishes.
    Zero,
    /// Overflows to infinity — triggers loss-scale backoff.
    Overflow,
    /// NaN in, NaN out.
    Nan,
}

/// Determine the [`CastFate`] of an f32 under f16 conversion.
pub fn cast_fate(x: f32) -> CastFate {
    if x.is_nan() {
        return CastFate::Nan;
    }
    let a = x.abs();
    if a == 0.0 {
        return CastFate::Zero;
    }
    if a > F16_MAX {
        return CastFate::Overflow;
    }
    if a < F16_MIN_SUBNORMAL / 2.0 {
        return CastFate::Zero;
    }
    if a < F16_MIN_POSITIVE {
        // Might round to zero or to a subnormal.
        let f = F16::from_f32(x);
        if f.is_zero() {
            CastFate::Zero
        } else {
            CastFate::Subnormal
        }
    } else {
        CastFate::Normal
    }
}

/// Round-trip an f32 slice through f16 (what shipping FP16 gradients over
/// the wire would do); returns the number of values that flushed to zero
/// and how many overflowed.
pub fn simulate_f16_pass(xs: &mut [f32]) -> (usize, usize) {
    let mut zeroed = 0;
    let mut overflowed = 0;
    for v in xs.iter_mut() {
        let before = *v;
        let f = F16::from_f32(before);
        *v = f.to_f32();
        if before != 0.0 && *v == 0.0 {
            zeroed += 1;
        }
        if before.is_finite() && !v.is_finite() {
            overflowed += 1;
        }
    }
    (zeroed, overflowed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_round_trip() {
        for i in -2048i32..=2048 {
            let x = i as f32;
            assert_eq!(F16::from_f32(x).to_f32(), x, "i={i}");
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(F16::from_f32(1.0).0, 0x3C00);
        assert_eq!(F16::from_f32(-2.0).0, 0xC000);
        assert_eq!(F16::from_f32(65504.0).0, 0x7BFF);
        assert_eq!(F16::from_f32(0.5).0, 0x3800);
        assert_eq!(F16::from_f32(6.103_515_6e-5).0, 0x0400); // min normal
    }

    #[test]
    fn overflow_to_infinity() {
        assert!(F16::from_f32(65536.0).is_infinite());
        assert!(F16::from_f32(-1e9).is_infinite());
        assert_eq!(F16::from_f32(-1e9).0, 0xFC00);
    }

    #[test]
    fn underflow_to_zero_and_subnormals() {
        assert!(F16::from_f32(1e-9).is_zero());
        let sub = F16::from_f32(1e-5); // below min normal 6.1e-5
        assert!(sub.is_subnormal());
        let back = sub.to_f32();
        assert!((back - 1e-5).abs() / 1e-5 < 0.05, "{back}");
    }

    #[test]
    fn nan_propagates() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16;
        // nearest-even rounds down to 1.0.
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(F16::from_f32(x).to_f32(), 1.0);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9's midpoint...
        // nearest-even rounds up to even mantissa 2.
        let y = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(F16::from_f32(y).to_f32(), 1.0 + 2.0 * 2.0f32.powi(-10));
    }

    #[test]
    fn cast_fates() {
        assert_eq!(cast_fate(1.0), CastFate::Normal);
        assert_eq!(cast_fate(1e-5), CastFate::Subnormal);
        assert_eq!(cast_fate(1e-9), CastFate::Zero);
        assert_eq!(cast_fate(1e6), CastFate::Overflow);
        assert_eq!(cast_fate(f32::NAN), CastFate::Nan);
        assert_eq!(cast_fate(0.0), CastFate::Zero);
    }

    #[test]
    fn loss_scaling_rescues_small_gradients() {
        // The §4.2 story in miniature: tiny grads die in fp16, but scaling
        // by 1024 preserves them, and unscaling recovers the magnitude.
        // all below half of the smallest subnormal (2^-25 ~ 2.98e-8)
        let grads = [1e-8f32, 2.5e-8, -2e-8];
        let mut plain = grads;
        let (zeroed, _) = simulate_f16_pass(&mut plain);
        assert_eq!(zeroed, 3, "unscaled tiny grads must vanish");

        let scale = 65536.0f32;
        let mut scaled: Vec<f32> = grads.iter().map(|g| g * scale).collect();
        let (zeroed, overflowed) = simulate_f16_pass(&mut scaled);
        assert_eq!((zeroed, overflowed), (0, 0));
        for (orig, s) in grads.iter().zip(&scaled) {
            let recovered = s / scale;
            assert!((recovered - orig).abs() / orig.abs() < 0.01);
        }
    }

    #[test]
    fn monotonic_on_samples() {
        // f16 conversion preserves (non-strict) ordering.
        let mut prev = f32::NEG_INFINITY;
        let mut x = -70000.0f32;
        while x < 70000.0 {
            let h = F16::from_f32(x).to_f32();
            assert!(h >= prev, "x={x} h={h} prev={prev}");
            prev = h;
            x += 13.7;
        }
    }

    #[test]
    fn roundtrip_error_bounded_by_half_ulp() {
        // For normal-range values, |x - f16(x)| <= 2^-11 * |x| (half ULP).
        let mut x = 0.001f32;
        while x < 60000.0 {
            let h = F16::from_f32(x).to_f32();
            assert!((h - x).abs() <= x * 2.0f32.powi(-11) + f32::EPSILON,
                    "x={x} h={h}");
            x *= 1.37;
        }
    }
}
