//! `bshard` — the data-sharding substrate (paper §4.1).
//!
//! The paper pre-shards the tokenized corpus into per-device hdf5 files so
//! each worker streams only its own shard, turning the 8–10 minute
//! load-and-scatter stall into <2 minutes.  hdf5 is unavailable offline;
//! `bshard` is our container with the same system-level properties:
//!
//! * O(1) open (header + footer index, no full scan),
//! * random access by record index (=> cheap epoch shuffling),
//! * per-record CRC-32 integrity,
//! * even round-robin distribution of a dataset across shards.
//!
//! Layout:
//! ```text
//! [ MAGIC "BSHD" | version u32 | record_count u64 | reserved u64 ]
//! [ record 0: len u32 | crc u32 | bytes ] ... [ record N-1 ]
//! [ index: N x offset u64 ]
//! [ footer: index_offset u64 | MAGIC "DHSB" ]
//! ```

pub mod reader;
pub mod writer;

pub use reader::ShardReader;
pub use writer::ShardWriter;

pub const MAGIC: &[u8; 4] = b"BSHD";
pub const FOOTER_MAGIC: &[u8; 4] = b"DHSB";
pub const VERSION: u32 = 1;

#[derive(thiserror::Error, Debug)]
pub enum ShardError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("not a bshard file (bad magic)")]
    BadMagic,
    #[error("unsupported bshard version {0}")]
    BadVersion(u32),
    #[error("record {index} failed CRC check")]
    Corrupt { index: usize },
    #[error("record index {index} out of range (count {count})")]
    OutOfRange { index: usize, count: usize },
    #[error("truncated file")]
    Truncated,
}

/// Deterministic round-robin assignment of `n_records` to `n_shards`
/// (the paper's "evenly distributed segments").  Returns, per shard, the
/// record indices it owns.
pub fn round_robin_assignment(n_records: usize, n_shards: usize)
    -> Vec<Vec<usize>> {
    assert!(n_shards >= 1);
    let mut out = vec![Vec::new(); n_shards];
    for i in 0..n_records {
        out[i % n_shards].push(i);
    }
    out
}

/// Shard file name convention: `<stem>-00042-of-00256.bshard`.
pub fn shard_file_name(stem: &str, index: usize, total: usize) -> String {
    format!("{stem}-{index:05}-of-{total:05}.bshard")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;

    #[test]
    fn round_robin_is_even_partition() {
        let a = round_robin_assignment(10, 3);
        assert_eq!(a[0], vec![0, 3, 6, 9]);
        assert_eq!(a[1], vec![1, 4, 7]);
        assert_eq!(a[2], vec![2, 5, 8]);
        let sizes: Vec<usize> = a.iter().map(|v| v.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn prop_round_robin_partitions() {
        testkit::check(
            "round-robin-partition", 0xE0, 64,
            |r: &mut Pcg64| (r.range_usize(0, 500), r.range_usize(1, 64)),
            |&(n, s)| {
                let a = round_robin_assignment(n, s);
                let mut all: Vec<usize> = a.iter().flatten().copied().collect();
                all.sort_unstable();
                all == (0..n).collect::<Vec<_>>()
                    && a.iter().all(|v| {
                        v.len() >= n / s && v.len() <= n / s + 1
                    })
            },
        );
    }

    #[test]
    fn file_names_sort_lexicographically() {
        let a = shard_file_name("train", 2, 256);
        let b = shard_file_name("train", 10, 256);
        assert_eq!(a, "train-00002-of-00256.bshard");
        assert!(a < b);
    }

    #[test]
    fn write_read_roundtrip() {
        let dir = std::env::temp_dir().join("bshard_test_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bshard");
        let records: Vec<Vec<u8>> = vec![
            b"hello".to_vec(),
            Vec::new(), // empty record is legal
            vec![0xFF; 1000],
            b"world".to_vec(),
        ];
        {
            let mut w = ShardWriter::create(&path).unwrap();
            for r in &records {
                w.append(r).unwrap();
            }
            w.finish().unwrap();
        }
        let mut r = ShardReader::open(&path).unwrap();
        assert_eq!(r.len(), 4);
        for (i, want) in records.iter().enumerate() {
            assert_eq!(&r.read(i).unwrap(), want, "record {i}");
        }
        // random access out of order
        assert_eq!(r.read(3).unwrap(), b"world");
        assert_eq!(r.read(0).unwrap(), b"hello");
        assert!(matches!(r.read(4), Err(ShardError::OutOfRange { .. })));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corruption_is_detected() {
        let dir = std::env::temp_dir().join("bshard_test_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.bshard");
        {
            let mut w = ShardWriter::create(&path).unwrap();
            w.append(b"sensitive payload").unwrap();
            w.finish().unwrap();
        }
        // flip one payload byte on disk
        let mut bytes = std::fs::read(&path).unwrap();
        let hdr = 4 + 4 + 8 + 8 + 8; // header + len/crc of record 0
        bytes[hdr + 2] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        assert!(matches!(r.read(0), Err(ShardError::Corrupt { index: 0 })));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("bshard_test_magic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bshard");
        std::fs::write(&path, b"NOPE....this is not a shard").unwrap();
        assert!(matches!(ShardReader::open(&path), Err(ShardError::BadMagic)));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn prop_roundtrip_random_records() {
        let dir = std::env::temp_dir().join("bshard_test_prop");
        std::fs::create_dir_all(&dir).unwrap();
        testkit::check_msg(
            "bshard-roundtrip", 0xE1, 12,
            |r: &mut Pcg64| {
                let n = r.range_usize(1, 30);
                (0..n)
                    .map(|_| testkit::gen_bytes(r, 0, 300))
                    .collect::<Vec<_>>()
            },
            {
                let dir = dir.clone();
                let counter = std::cell::Cell::new(0usize);
                move |records: &Vec<Vec<u8>>| {
                    let path = dir.join(format!("p{}.bshard", counter.get()));
                    counter.set(counter.get() + 1);
                    let mut w = ShardWriter::create(&path)
                        .map_err(|e| e.to_string())?;
                    for rec in records {
                        w.append(rec).map_err(|e| e.to_string())?;
                    }
                    w.finish().map_err(|e| e.to_string())?;
                    let mut rd = ShardReader::open(&path)
                        .map_err(|e| e.to_string())?;
                    if rd.len() != records.len() {
                        return Err("count mismatch".into());
                    }
                    for (i, want) in records.iter().enumerate() {
                        let got = rd.read(i).map_err(|e| e.to_string())?;
                        if &got != want {
                            return Err(format!("record {i} mismatch"));
                        }
                    }
                    let _ = std::fs::remove_file(&path);
                    Ok(())
                }
            },
        );
    }
}
