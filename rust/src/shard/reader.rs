//! Random-access `bshard` reader (paper §4.1: each device streams only
//! its own shard; epoch reshuffles are index permutations, not data
//! movement).

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use super::{ShardError, FOOTER_MAGIC, MAGIC, VERSION};
use crate::util::crc32;

/// Reader with the record index resident; payloads are read on demand.
pub struct ShardReader {
    file: File,
    path: PathBuf,
    offsets: Vec<u64>,
}

impl ShardReader {
    /// Open and validate a shard file; loads the index (O(records), not
    /// O(bytes)).
    pub fn open(path: &Path) -> Result<Self, ShardError> {
        let mut file = File::open(path)?;
        let total = file.metadata()?.len();
        if total >= 4 {
            let mut magic = [0u8; 4];
            file.read_exact(&mut magic)?;
            if &magic != MAGIC {
                return Err(ShardError::BadMagic);
            }
            file.seek(SeekFrom::Start(0))?;
        }
        if total < 24 + 12 {
            // header + footer minimum
            return Err(if total >= 4 { ShardError::Truncated }
                       else { ShardError::BadMagic });
        }
        let mut header = [0u8; 24];
        file.read_exact(&mut header)?;
        if &header[0..4] != MAGIC {
            return Err(ShardError::BadMagic);
        }
        let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(ShardError::BadVersion(version));
        }
        let count = u64::from_le_bytes(header[8..16].try_into().unwrap())
            as usize;

        // footer: index_offset u64 + FOOTER_MAGIC
        file.seek(SeekFrom::End(-12))?;
        let mut footer = [0u8; 12];
        file.read_exact(&mut footer)?;
        if &footer[8..12] != FOOTER_MAGIC {
            return Err(ShardError::Truncated);
        }
        let index_offset = u64::from_le_bytes(footer[0..8].try_into().unwrap());
        if index_offset + (count as u64) * 8 + 12 != total {
            return Err(ShardError::Truncated);
        }

        file.seek(SeekFrom::Start(index_offset))?;
        let mut idx_bytes = vec![0u8; count * 8];
        file.read_exact(&mut idx_bytes)?;
        let offsets: Vec<u64> = idx_bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();

        Ok(Self { file, path: path.to_path_buf(), offsets })
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read and CRC-verify record `index`.
    pub fn read(&mut self, index: usize) -> Result<Vec<u8>, ShardError> {
        let count = self.offsets.len();
        let off = *self.offsets.get(index).ok_or(ShardError::OutOfRange {
            index,
            count,
        })?;
        self.file.seek(SeekFrom::Start(off))?;
        let mut hdr = [0u8; 8];
        self.file.read_exact(&mut hdr)?;
        let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let want_crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        let mut payload = vec![0u8; len];
        self.file.read_exact(&mut payload)?;
        if crc32(&payload) != want_crc {
            return Err(ShardError::Corrupt { index });
        }
        Ok(payload)
    }

    /// Iterate all records in index order (sequential scan).
    pub fn iter_all(&mut self) -> impl Iterator<Item = Result<Vec<u8>, ShardError>> + '_ {
        (0..self.len()).map(move |i| self.read(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::ShardWriter;

    #[test]
    fn sequential_iteration() {
        let path = std::env::temp_dir().join("bshard_reader_iter.bshard");
        {
            let mut w = ShardWriter::create(&path).unwrap();
            for i in 0..10u8 {
                w.append(&[i; 3]).unwrap();
            }
            w.finish().unwrap();
        }
        let mut r = ShardReader::open(&path).unwrap();
        let all: Vec<Vec<u8>> = r.iter_all().map(|x| x.unwrap()).collect();
        assert_eq!(all.len(), 10);
        assert_eq!(all[7], vec![7u8; 3]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_file_detected() {
        let path = std::env::temp_dir().join("bshard_reader_trunc.bshard");
        {
            let mut w = ShardWriter::create(&path).unwrap();
            w.append(b"datadata").unwrap();
            w.finish().unwrap();
        }
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        assert!(ShardReader::open(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
