//! Streaming `bshard` writer (paper §4.1 sharding pipeline output side).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use super::{ShardError, FOOTER_MAGIC, MAGIC, VERSION};
use crate::util::crc32;

/// Appends records to a shard file; `finish()` writes the index + footer.
pub struct ShardWriter {
    out: BufWriter<File>,
    offsets: Vec<u64>,
    pos: u64,
    finished: bool,
}

impl ShardWriter {
    /// Create a new shard at `path` (truncates any existing file).
    pub fn create(path: &Path) -> Result<Self, ShardError> {
        let file = File::create(path)?;
        let mut out = BufWriter::new(file);
        out.write_all(MAGIC)?;
        out.write_all(&VERSION.to_le_bytes())?;
        out.write_all(&0u64.to_le_bytes())?; // record_count placeholder
        out.write_all(&0u64.to_le_bytes())?; // reserved
        Ok(Self { out, offsets: Vec::new(), pos: 24, finished: false })
    }

    /// Append one record.
    pub fn append(&mut self, record: &[u8]) -> Result<(), ShardError> {
        assert!(!self.finished, "append after finish");
        self.offsets.push(self.pos);
        let len = record.len() as u32;
        let crc = crc32(record);
        self.out.write_all(&len.to_le_bytes())?;
        self.out.write_all(&crc.to_le_bytes())?;
        self.out.write_all(record)?;
        self.pos += 8 + record.len() as u64;
        Ok(())
    }

    /// Number of records appended so far.
    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    /// Write index + footer and patch the header record count.
    pub fn finish(mut self) -> Result<(), ShardError> {
        self.finished = true;
        let index_offset = self.pos;
        for off in &self.offsets {
            self.out.write_all(&off.to_le_bytes())?;
        }
        self.out.write_all(&index_offset.to_le_bytes())?;
        self.out.write_all(FOOTER_MAGIC)?;
        self.out.flush()?;
        // Patch record_count in the header.
        let mut file = self.out.into_inner().map_err(|e| e.into_error())?;
        use std::io::Seek;
        file.seek(std::io::SeekFrom::Start(8))?;
        file.write_all(&(self.offsets.len() as u64).to_le_bytes())?;
        file.sync_all()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_shard_is_valid() {
        let path = std::env::temp_dir().join("bshard_writer_empty.bshard");
        ShardWriter::create(&path).unwrap().finish().unwrap();
        let r = super::super::ShardReader::open(&path).unwrap();
        assert_eq!(r.len(), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn len_tracks_appends() {
        let path = std::env::temp_dir().join("bshard_writer_len.bshard");
        let mut w = ShardWriter::create(&path).unwrap();
        assert!(w.is_empty());
        w.append(b"a").unwrap();
        w.append(b"b").unwrap();
        assert_eq!(w.len(), 2);
        w.finish().unwrap();
        std::fs::remove_file(&path).unwrap();
    }
}
