//! Persistent collective worker pool (paper §4.4, Fig. 2): the step
//! executor behind the trainer's hot loop.
//!
//! The old hot loop ran every rank's compute sequentially on one thread,
//! then built a fresh [`super::CollectiveGroup`] plus one `thread::spawn`
//! per rank for EVERY optimizer step and barriered on the whole exchange.
//! This module replaces that with infrastructure wired exactly once:
//!
//! * **two long-lived threads per rank** — a *compute* worker that runs
//!   the rank's micro-steps and accumulates gradients, and a *comm*
//!   worker that owns the rank's endpoint in a reusable ring of mpsc
//!   channels (the in-process NCCL communicator, never re-created);
//! * **overlapped bucket exchange** — on the final micro-step the compute
//!   worker accumulates bucket-by-bucket in backward order and hands each
//!   bucket to its comm worker *as soon as its accumulation completes*,
//!   so the ring allreduce of bucket `b` overlaps the accumulation of
//!   buckets `> b` (the Fig. 2 schedule; `overlap = false` degrades to
//!   the accumulate-everything-then-exchange barrier order — bitwise
//!   identical results, only the timing differs);
//! * **preallocated, reused scratch** — per-rank gradient accumulators,
//!   per-bucket payload buffers, ring chunk plans, and wire message
//!   vectors (recycled through per-worker free lists) are all allocated
//!   once; the steady-state step performs no gradient-sized heap
//!   allocation and no thread spawn (only O(buckets) stats vectors);
//! * **optional f16 wire format** (paper §4.4 exchanges FP16 gradients):
//!   ring payloads are converted through [`crate::half::F16`] per hop,
//!   halving wire bytes at one rounding per hop.  Each rank quantizes the
//!   reduced chunk it owns before the all-gather so every replica still
//!   ends bitwise identical.
//!
//! Determinism: given a deterministic [`RankCompute`], the reduced
//! buffers are a pure function of the inputs — the eager (overlap) and
//! barrier schedules produce bitwise-identical results because the
//! element-wise accumulation order and the ring schedule are unchanged;
//! only *when* each bucket's exchange runs differs.  This is asserted by
//! `tests/pool_overlap.rs`.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::ring::RingPlan;
use crate::grad::BucketRange;
use crate::half::F16;

/// On-the-wire payload encoding for ring messages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireFormat {
    /// Full-precision f32 payloads (bitwise-faithful exchange).
    #[default]
    F32,
    /// IEEE binary16 payloads (paper §4.4): half the wire bytes, one
    /// round-to-nearest-even per hop.
    F16,
}

/// Per-micro-step scalar outputs a [`RankCompute`] reports back.
#[derive(Debug, Clone, Copy, Default)]
pub struct MicroStats {
    pub loss: f64,
    pub mlm_loss: f64,
    pub nsp_loss: f64,
    pub mlm_acc: f64,
    /// Any non-finite loss/grad-norm observed (AMP overflow signal).
    pub nonfinite: bool,
}

/// One rank's micro-step: fill `grads_out` with the flat gradient of this
/// (rank, step, micro) and report scalar stats.  Called concurrently from
/// every rank's compute worker, so implementations must be `Sync`
/// (per-rank mutable state goes behind per-rank locks).
pub trait RankCompute: Sync {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             params: &[f32], scale: f32, grads_out: &mut Vec<f32>)
             -> Result<MicroStats>;
}

/// Aggregated outcome of one pooled optimizer step.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    pub loss_sum: f64,
    pub mlm_sum: f64,
    pub nsp_sum: f64,
    pub acc_sum: f64,
    pub saw_overflow: bool,
    /// Critical-path (max over ranks) seconds in `RankCompute::micro`.
    pub compute_s: f64,
    /// Critical-path seconds accumulating gradients.
    pub accum_s: f64,
    /// Critical-path seconds of ring exchange (sum over buckets).
    pub comm_s: f64,
    /// Critical-path seconds the step actually WAITED on comm after its
    /// gradient accumulation finished — the exposed (non-overlapped)
    /// communication of Fig. 2.
    pub exposed_comm_s: f64,
    /// Per-bucket exchange seconds (max over ranks).
    pub bucket_s: Vec<f64>,
    /// Wall-clock of the whole pooled step.
    pub wall_s: f64,
}

// ------------------------------------------------------------ wiring --

/// Job dispatched to one compute worker.  The references are transmuted
/// to `'static` by [`CollectivePool::step`]; see the SAFETY note there.
struct Job {
    params: &'static [f32],
    compute: &'static (dyn RankCompute + 'static),
    scale: f32,
    micro_steps: usize,
    step_index: usize,
    overlap: bool,
}

/// Per-rank stats returned by a compute worker after each step.
#[derive(Debug, Clone, Default)]
struct RankStats {
    loss_sum: f64,
    mlm_sum: f64,
    nsp_sum: f64,
    acc_sum: f64,
    nonfinite: bool,
    compute_s: f64,
    accum_s: f64,
    comm_s: f64,
    exposed_comm_s: f64,
    bucket_s: Vec<f64>,
}

struct RankResult {
    rank: usize,
    res: std::result::Result<RankStats, String>,
}

/// Ring hop message: (step tag, wire payload).
enum RingMsg {
    F32(u32, Vec<f32>),
    F16(u32, Vec<u16>),
}

/// Reduced bucket handed back from a comm worker to its compute worker.
struct Reduced {
    idx: usize,
    data: Vec<f32>,
    exchange_s: f64,
}

/// The persistent pool: `2 * world` threads plus the channels between
/// them, created once and reused for every step until drop.
pub struct CollectivePool {
    world: usize,
    n_elems: usize,
    ranges: Arc<[BucketRange]>,
    wire: WireFormat,
    job_txs: Vec<Sender<Job>>,
    result_rx: Receiver<RankResult>,
    /// Per-rank accumulated (and, post-step, reduced) flat gradients.
    /// Locked by rank `r`'s compute worker for the duration of a step;
    /// free for inspection between steps.
    accs: Arc<Vec<Mutex<Vec<f32>>>>,
    compute_handles: Vec<JoinHandle<()>>,
    comm_handles: Vec<JoinHandle<()>>,
}

impl CollectivePool {
    /// Wire up the pool: `world` rank pairs (compute + comm worker), ring
    /// channels between the comm workers, and per-rank flat buffers of
    /// `n_elems`.  `ranges` is the shared bucket table (built once via
    /// [`crate::grad::bucket_ranges`] — no per-step cloning).
    pub fn new(world: usize, n_elems: usize, ranges: Arc<[BucketRange]>,
               wire: WireFormat) -> CollectivePool {
        assert!(world >= 1, "world must be >= 1");
        let accs: Arc<Vec<Mutex<Vec<f32>>>> = Arc::new(
            (0..world).map(|_| Mutex::new(vec![0.0f32; n_elems])).collect(),
        );
        // Ring channels: comm worker r sends to slot (r+1) % world and
        // receives from slot r (same wiring as CollectiveGroup).
        let mut ring_txs: Vec<Option<Sender<RingMsg>>> = Vec::new();
        let mut ring_rxs: Vec<Option<Receiver<RingMsg>>> = Vec::new();
        for _ in 0..world {
            let (tx, rx) = channel::<RingMsg>();
            ring_txs.push(Some(tx));
            ring_rxs.push(Some(rx));
        }
        let (result_tx, result_rx) = channel::<RankResult>();
        let mut job_txs = Vec::with_capacity(world);
        let mut compute_handles = Vec::with_capacity(world);
        let mut comm_handles = Vec::with_capacity(world);
        for r in 0..world {
            let (job_tx, job_rx) = channel::<Job>();
            let (bucket_tx, bucket_rx) = channel::<(usize, Vec<f32>)>();
            let (reduced_tx, reduced_rx) = channel::<Reduced>();
            let tx_next = ring_txs[(r + 1) % world].take().unwrap();
            let rx_prev = ring_rxs[r].take().unwrap();
            let ranges_comm = ranges.clone();
            comm_handles.push(
                std::thread::Builder::new()
                    .name(format!("pool-comm-{r}"))
                    .spawn(move || {
                        comm_worker(r, world, wire, &ranges_comm, bucket_rx,
                                    reduced_tx, tx_next, rx_prev);
                    })
                    .expect("spawn comm worker"),
            );
            let ranges_cmp = ranges.clone();
            let accs_cmp = accs.clone();
            let result_tx = result_tx.clone();
            compute_handles.push(
                std::thread::Builder::new()
                    .name(format!("pool-rank-{r}"))
                    .spawn(move || {
                        compute_worker(r, world, &ranges_cmp, &accs_cmp,
                                       job_rx, bucket_tx, reduced_rx,
                                       result_tx);
                    })
                    .expect("spawn compute worker"),
            );
            job_txs.push(job_tx);
        }
        drop(result_tx);
        CollectivePool {
            world,
            n_elems,
            ranges,
            wire,
            job_txs,
            result_rx,
            accs,
            compute_handles,
            comm_handles,
        }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn n_elems(&self) -> usize {
        self.n_elems
    }

    pub fn num_buckets(&self) -> usize {
        self.ranges.len()
    }

    pub fn wire(&self) -> WireFormat {
        self.wire
    }

    /// Run one optimizer step across all ranks: `micro_steps` calls to
    /// `compute.micro` per rank (in parallel across ranks on the
    /// persistent workers), local accumulation, then the bucketed ring
    /// allreduce — eagerly interleaved with the final accumulation when
    /// `overlap` is set, barrier-ordered otherwise.  After this returns,
    /// every rank's buffer (see [`Self::rank_grads`]) holds the summed
    /// gradients, bitwise identical across ranks.
    ///
    /// Blocks until every rank reported, so the borrows in the request
    /// never outlive the call (see SAFETY below).  A `RankCompute` error
    /// on any rank still completes the exchange protocol on every rank
    /// (no deadlock) and is then returned here.
    pub fn step(&mut self, params: &[f32], scale: f32, micro_steps: usize,
                step_index: usize, overlap: bool,
                compute: &dyn RankCompute) -> Result<StepOutcome> {
        // SAFETY: the transmutes only erase lifetimes.  Workers use the
        // references strictly between receiving the Job and sending
        // their RankResult, and this function does not return until it
        // has received exactly `world` results — so the borrows are live
        // for every use.  Channel failures below are programming errors
        // (a worker can only exit when the pool is dropped) and panic
        // rather than return, keeping the invariant.
        let params_static: &'static [f32] =
            unsafe { std::mem::transmute::<&[f32], &'static [f32]>(params) };
        let compute_static: &'static (dyn RankCompute + 'static) = unsafe {
            std::mem::transmute::<&(dyn RankCompute + '_),
                                  &'static (dyn RankCompute + 'static)>(
                compute,
            )
        };
        let t0 = Instant::now();
        for tx in &self.job_txs {
            tx.send(Job {
                params: params_static,
                compute: compute_static,
                scale,
                micro_steps,
                step_index,
                overlap,
            })
            .expect("collective pool worker exited (prior panic?)");
        }
        let mut out = StepOutcome {
            bucket_s: vec![0.0; self.ranges.len()],
            ..Default::default()
        };
        let mut errs: Vec<String> = Vec::new();
        for _ in 0..self.world {
            let r = self
                .result_rx
                .recv()
                .expect("collective pool workers died mid-step");
            match r.res {
                Ok(s) => {
                    out.loss_sum += s.loss_sum;
                    out.mlm_sum += s.mlm_sum;
                    out.nsp_sum += s.nsp_sum;
                    out.acc_sum += s.acc_sum;
                    out.saw_overflow |= s.nonfinite;
                    out.compute_s = out.compute_s.max(s.compute_s);
                    out.accum_s = out.accum_s.max(s.accum_s);
                    out.comm_s = out.comm_s.max(s.comm_s);
                    out.exposed_comm_s =
                        out.exposed_comm_s.max(s.exposed_comm_s);
                    for (t, b) in out.bucket_s.iter_mut().zip(&s.bucket_s) {
                        *t = t.max(*b);
                    }
                }
                Err(e) => errs.push(format!("rank {}: {e}", r.rank)),
            }
        }
        out.wall_s = t0.elapsed().as_secs_f64();
        anyhow::ensure!(errs.is_empty(), "pooled step failed: {}",
                        errs.join("; "));
        Ok(out)
    }

    /// Rank 0's buffer — the reduced gradients the leader normalizes and
    /// applies.  Only call between steps (a worker holds the lock during
    /// its step).
    pub fn leader_grads(&self) -> MutexGuard<'_, Vec<f32>> {
        self.rank_grads(0)
    }

    /// Any rank's buffer (tests assert cross-rank bitwise equality).
    pub fn rank_grads(&self, rank: usize) -> MutexGuard<'_, Vec<f32>> {
        self.accs[rank].lock().expect("pool rank buffer poisoned")
    }
}

impl Drop for CollectivePool {
    fn drop(&mut self) {
        // Closing the job channels unblocks the compute workers; their
        // bucket channels then close, unblocking the comm workers.
        self.job_txs.clear();
        for h in self.compute_handles.drain(..) {
            let _ = h.join();
        }
        for h in self.comm_handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ----------------------------------------------------- compute worker --

#[allow(clippy::too_many_arguments)]
fn compute_worker(rank: usize, world: usize, ranges: &Arc<[BucketRange]>,
                  accs: &Arc<Vec<Mutex<Vec<f32>>>>, job_rx: Receiver<Job>,
                  bucket_tx: Sender<(usize, Vec<f32>)>,
                  reduced_rx: Receiver<Reduced>,
                  result_tx: Sender<RankResult>) {
    // Persistent scratch: micro-step gradient vector and one payload
    // buffer per bucket, recycled every step.
    let mut grads: Vec<f32> = Vec::new();
    let mut bucket_bufs: Vec<Vec<f32>> =
        ranges.iter().map(|b| Vec::with_capacity(b.len())).collect();
    while let Ok(job) = job_rx.recv() {
        let res = run_rank_step(rank, world, ranges, accs, &job, &mut grads,
                                &mut bucket_bufs, &bucket_tx, &reduced_rx);
        let msg = RankResult { rank, res: res.map_err(|e| format!("{e:#}")) };
        if result_tx.send(msg).is_err() {
            break;
        }
    }
}

/// Copy a bucket's accumulated slice into its reusable payload buffer and
/// hand it to the comm worker.
fn send_bucket(idx: usize, src: &[f32], slot: &mut Vec<f32>,
               tx: &Sender<(usize, Vec<f32>)>) -> Result<()> {
    let mut v = std::mem::take(slot);
    v.clear();
    v.extend_from_slice(src);
    tx.send((idx, v))
        .map_err(|_| anyhow::anyhow!("comm worker gone (bucket {idx})"))
}

#[allow(clippy::too_many_arguments)]
fn run_rank_step(rank: usize, world: usize, ranges: &[BucketRange],
                 accs: &[Mutex<Vec<f32>>], job: &Job, grads: &mut Vec<f32>,
                 bucket_bufs: &mut [Vec<f32>],
                 bucket_tx: &Sender<(usize, Vec<f32>)>,
                 reduced_rx: &Receiver<Reduced>) -> Result<RankStats> {
    let mut acc = accs[rank].lock().expect("rank buffer poisoned");
    acc.fill(0.0);
    let mut stats = RankStats::default();
    let k = job.micro_steps.max(1);
    // On any failure we still complete the exchange protocol below so
    // peer ranks blocked in the ring are released; the error is
    // reported after.
    let mut failure: Option<anyhow::Error> = None;
    let mut sent_eagerly = false;
    for micro in 0..k {
        let t0 = Instant::now();
        // Catch panics from the user-supplied compute, not just Errs:
        // a vanished rank would otherwise desynchronize the ring and
        // hang every peer (and `step()`) forever.  A caught panic takes
        // the same still-complete-the-exchange path as an Err.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || job.compute.micro(rank, job.step_index, micro, job.params,
                                 job.scale, grads),
        ));
        let m = match caught {
            Ok(Ok(m)) => m,
            Ok(Err(e)) => {
                failure = Some(e);
                break;
            }
            Err(payload) => {
                let what = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic".to_string());
                failure = Some(anyhow::anyhow!(
                    "compute panicked at micro {micro}: {what}"
                ));
                break;
            }
        };
        stats.compute_s += t0.elapsed().as_secs_f64();
        if grads.len() != acc.len() {
            failure = Some(anyhow::anyhow!(
                "micro-step produced {} grads, buffer holds {}",
                grads.len(), acc.len()
            ));
            break;
        }
        stats.loss_sum += m.loss;
        stats.mlm_sum += m.mlm_loss;
        stats.nsp_sum += m.nsp_loss;
        stats.acc_sum += m.mlm_acc;
        stats.nonfinite |= m.nonfinite;
        let t1 = Instant::now();
        if micro + 1 < k {
            // Not the last micro-step: plain full-range accumulation.
            for (a, g) in acc.iter_mut().zip(grads.iter()) {
                *a += *g;
            }
            stats.accum_s += t1.elapsed().as_secs_f64();
        } else {
            // Final micro-step: accumulate bucket-by-bucket in backward
            // order; with overlap on, enqueue each bucket's exchange the
            // moment its accumulation completes (Fig. 2).
            for (idx, br) in ranges.iter().enumerate() {
                let tb = Instant::now();
                let (seg, gseg) = (&mut acc[br.start..br.end],
                                   &grads[br.start..br.end]);
                for (a, g) in seg.iter_mut().zip(gseg.iter()) {
                    *a += *g;
                }
                stats.accum_s += tb.elapsed().as_secs_f64();
                if world > 1 && job.overlap {
                    if let Err(e) = send_bucket(idx, &acc[br.start..br.end],
                                                &mut bucket_bufs[idx],
                                                bucket_tx) {
                        failure = Some(e);
                        break;
                    }
                    sent_eagerly = true;
                }
            }
        }
    }
    let acc_done = Instant::now();
    if world > 1 && !ranges.is_empty() {
        if !sent_eagerly {
            // Barrier mode — or the failure path, where we feed the ring
            // whatever is accumulated so peers can finish their step.
            for (idx, br) in ranges.iter().enumerate() {
                if let Err(e) = send_bucket(idx, &acc[br.start..br.end],
                                            &mut bucket_bufs[idx],
                                            bucket_tx) {
                    failure = failure.or(Some(e));
                    break;
                }
            }
        }
        stats.bucket_s = vec![0.0; ranges.len()];
        for idx in 0..ranges.len() {
            let red = match reduced_rx.recv() {
                Ok(r) => r,
                Err(_) => {
                    failure = failure.or_else(|| {
                        Some(anyhow::anyhow!("comm worker gone mid-exchange"))
                    });
                    break;
                }
            };
            debug_assert_eq!(red.idx, idx, "bucket reply out of order");
            let br = ranges[red.idx];
            acc[br.start..br.end].copy_from_slice(&red.data);
            stats.bucket_s[red.idx] = red.exchange_s;
            stats.comm_s += red.exchange_s;
            bucket_bufs[red.idx] = red.data;
        }
        stats.exposed_comm_s =
            acc_done.elapsed().as_secs_f64();
    }
    drop(acc);
    match failure {
        Some(e) => Err(e),
        None => Ok(stats),
    }
}

// -------------------------------------------------------- comm worker --

fn comm_worker(rank: usize, world: usize, wire: WireFormat,
               ranges: &[BucketRange], bucket_rx: Receiver<(usize, Vec<f32>)>,
               reduced_tx: Sender<Reduced>, tx_next: Sender<RingMsg>,
               rx_prev: Receiver<RingMsg>) {
    // Chunk plans are a pure function of (world, bucket length): build
    // them once and reuse forever.
    let plans: Vec<RingPlan> =
        ranges.iter().map(|b| RingPlan::new(world, b.len())).collect();
    // Free lists recycle wire message vectors: every exchange sends and
    // receives the same number of chunks, so after the first step the
    // lists are self-sustaining (steady-state zero allocation).
    let mut free_f32: Vec<Vec<f32>> = Vec::new();
    let mut free_u16: Vec<Vec<u16>> = Vec::new();
    while let Ok((idx, mut data)) = bucket_rx.recv() {
        let t0 = Instant::now();
        if world > 1 {
            ring_exchange(&mut data, &plans[idx], rank, wire, &tx_next,
                          &rx_prev, &mut free_f32, &mut free_u16);
        }
        let exchange_s = t0.elapsed().as_secs_f64();
        if reduced_tx.send(Reduced { idx, data, exchange_s }).is_err() {
            break;
        }
    }
}

/// In-place ring allreduce (sum) of `buf` across the comm workers, using
/// the NCCL reduce-scatter + all-gather schedule from [`RingPlan`].
#[allow(clippy::too_many_arguments)]
fn ring_exchange(buf: &mut [f32], plan: &RingPlan, rank: usize,
                 wire: WireFormat, tx: &Sender<RingMsg>,
                 rx: &Receiver<RingMsg>, free_f32: &mut Vec<Vec<f32>>,
                 free_u16: &mut Vec<Vec<u16>>) {
    let n = plan.n;
    if n <= 1 || buf.is_empty() {
        return;
    }
    // reduce-scatter
    for s in 0..n - 1 {
        let sc = plan.chunk(plan.send_chunk_rs(rank, s));
        send_wire(&buf[sc], s as u32, wire, tx, free_f32, free_u16);
        let rc = plan.chunk(plan.recv_chunk_rs(rank, s));
        recv_apply(&mut buf[rc], s as u32, true, rx, free_f32, free_u16);
    }
    if wire == WireFormat::F16 {
        // Quantize the fully-reduced chunk this rank owns before the
        // all-gather: every replica then holds f16-representable values
        // and stays bitwise identical (f16 round-trip is idempotent).
        let own = plan.chunk((rank + 1) % n);
        for v in buf[own].iter_mut() {
            *v = F16::from_f32(*v).to_f32();
        }
    }
    // all-gather
    for s in 0..n - 1 {
        let sc = plan.chunk(plan.send_chunk_ag(rank, s));
        send_wire(&buf[sc], 100 + s as u32, wire, tx, free_f32, free_u16);
        let rc = plan.chunk(plan.recv_chunk_ag(rank, s));
        recv_apply(&mut buf[rc], 100 + s as u32, false, rx, free_f32,
                   free_u16);
    }
}

fn send_wire(src: &[f32], tag: u32, wire: WireFormat, tx: &Sender<RingMsg>,
             free_f32: &mut Vec<Vec<f32>>, free_u16: &mut Vec<Vec<u16>>) {
    let msg = match wire {
        WireFormat::F32 => {
            let mut v = free_f32.pop().unwrap_or_default();
            v.clear();
            v.extend_from_slice(src);
            RingMsg::F32(tag, v)
        }
        WireFormat::F16 => {
            let mut v = free_u16.pop().unwrap_or_default();
            v.clear();
            v.extend(src.iter().map(|&x| F16::from_f32(x).0));
            RingMsg::F16(tag, v)
        }
    };
    tx.send(msg).expect("pool ring send");
}

/// Receive one ring hop and either reduce-add (`add = true`) or copy it
/// into `dst`; the payload vector goes back on the free list.
fn recv_apply(dst: &mut [f32], tag: u32, add: bool, rx: &Receiver<RingMsg>,
              free_f32: &mut Vec<Vec<f32>>, free_u16: &mut Vec<Vec<u16>>) {
    match rx.recv().expect("pool ring recv") {
        RingMsg::F32(t, v) => {
            debug_assert_eq!(t, tag, "ring schedule skew");
            if add {
                for (d, s) in dst.iter_mut().zip(v.iter()) {
                    *d += *s;
                }
            } else {
                dst.copy_from_slice(&v);
            }
            free_f32.push(v);
        }
        RingMsg::F16(t, v) => {
            debug_assert_eq!(t, tag, "ring schedule skew");
            if add {
                for (d, b) in dst.iter_mut().zip(v.iter()) {
                    *d += F16(*b).to_f32();
                }
            } else {
                for (d, b) in dst.iter_mut().zip(v.iter()) {
                    *d = F16(*b).to_f32();
                }
            }
            free_u16.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    /// Deterministic synthetic gradients: f(rank, step, micro, i).
    struct Synth {
        n: usize,
    }

    impl RankCompute for Synth {
        fn micro(&self, rank: usize, step_index: usize, micro: usize,
                 _params: &[f32], _scale: f32, out: &mut Vec<f32>)
                 -> Result<MicroStats> {
            out.resize(self.n, 0.0);
            for (i, v) in out.iter_mut().enumerate() {
                *v = (rank * 1000 + step_index * 100 + micro * 10) as f32
                    + (i % 13) as f32 * 0.25;
            }
            Ok(MicroStats { loss: 1.0, ..Default::default() })
        }
    }

    fn full_ranges(n: usize, pieces: usize) -> Arc<[BucketRange]> {
        BucketRange::even_split(n, pieces)
    }

    /// Serial oracle for the synthetic compute: sum over ranks & micros.
    fn expected(world: usize, n: usize, step_index: usize, k: usize)
                -> Vec<f32> {
        let mut want = vec![0.0f32; n];
        let synth = Synth { n };
        let mut g = Vec::new();
        for r in 0..world {
            for m in 0..k {
                synth.micro(r, step_index, m, &[], 1.0, &mut g).unwrap();
                for (w, x) in want.iter_mut().zip(&g) {
                    *w += *x;
                }
            }
        }
        want
    }

    #[test]
    fn pooled_step_sums_across_ranks_and_micros() {
        let (world, n, k) = (3, 157, 2);
        let ranges = full_ranges(n, 2);
        let mut pool =
            CollectivePool::new(world, n, ranges, WireFormat::F32);
        let synth = Synth { n };
        let out = pool.step(&[], 1.0, k, 7, true, &synth).unwrap();
        assert!((out.loss_sum - (world * k) as f64).abs() < 1e-9);
        let want = expected(world, n, 7, k);
        for r in 0..world {
            testkit::assert_allclose(&pool.rank_grads(r), &want, 1e-3, 1e-5);
        }
    }

    #[test]
    fn overlap_and_barrier_are_bitwise_identical() {
        let (world, n, k) = (4, 211, 3);
        for wire in [WireFormat::F32, WireFormat::F16] {
            let mut a = CollectivePool::new(world, n, full_ranges(n, 3),
                                            wire);
            let mut b = CollectivePool::new(world, n, full_ranges(n, 3),
                                            wire);
            let synth = Synth { n };
            a.step(&[], 1.0, k, 0, true, &synth).unwrap();
            b.step(&[], 1.0, k, 0, false, &synth).unwrap();
            for r in 0..world {
                let (ga, gb) = (a.rank_grads(r), b.rank_grads(r));
                for (x, y) in ga.iter().zip(gb.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{wire:?} rank {r}");
                }
            }
        }
    }

    #[test]
    fn world_one_needs_no_exchange() {
        let n = 64;
        let mut pool =
            CollectivePool::new(1, n, full_ranges(n, 1), WireFormat::F32);
        let synth = Synth { n };
        let out = pool.step(&[], 1.0, 2, 0, true, &synth).unwrap();
        assert_eq!(out.comm_s, 0.0);
        let want = expected(1, n, 0, 2);
        testkit::assert_allclose(&pool.leader_grads(), &want, 1e-4, 1e-5);
    }

    #[test]
    fn compute_error_is_reported_not_deadlocked() {
        struct Failing {
            n: usize,
        }
        impl RankCompute for Failing {
            fn micro(&self, rank: usize, _s: usize, _m: usize, _p: &[f32],
                     _sc: f32, out: &mut Vec<f32>) -> Result<MicroStats> {
                anyhow::ensure!(rank != 1, "injected failure on rank 1");
                out.resize(self.n, 0.0);
                out.fill(1.0);
                Ok(MicroStats::default())
            }
        }
        let n = 40;
        let mut pool =
            CollectivePool::new(3, n, full_ranges(n, 2), WireFormat::F32);
        let err = pool.step(&[], 1.0, 1, 0, true, &Failing { n })
            .unwrap_err();
        assert!(format!("{err:#}").contains("rank 1"));
        // the pool must still be usable afterwards
        let synth = Synth { n };
        pool.step(&[], 1.0, 1, 1, true, &synth).unwrap();
        let want = expected(3, n, 1, 1);
        testkit::assert_allclose(&pool.leader_grads(), &want, 1e-3, 1e-5);
    }

    #[test]
    fn compute_panic_is_reported_not_deadlocked() {
        struct Panicking {
            n: usize,
        }
        impl RankCompute for Panicking {
            fn micro(&self, rank: usize, _s: usize, _m: usize, _p: &[f32],
                     _sc: f32, out: &mut Vec<f32>) -> Result<MicroStats> {
                assert!(rank != 2, "injected panic on rank 2");
                out.resize(self.n, 0.0);
                out.fill(1.0);
                Ok(MicroStats::default())
            }
        }
        let n = 30;
        let mut pool =
            CollectivePool::new(3, n, full_ranges(n, 2), WireFormat::F32);
        let err = pool.step(&[], 1.0, 1, 0, true, &Panicking { n })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 2") && msg.contains("panicked"), "{msg}");
        // the pool survives the panic and keeps working
        let synth = Synth { n };
        pool.step(&[], 1.0, 1, 1, true, &synth).unwrap();
        let want = expected(3, n, 1, 1);
        testkit::assert_allclose(&pool.leader_grads(), &want, 1e-3, 1e-5);
    }

    #[test]
    fn f16_wire_quantizes_but_stays_close() {
        let (world, n) = (2, 100);
        let mut f32p =
            CollectivePool::new(world, n, full_ranges(n, 2), WireFormat::F32);
        let mut f16p =
            CollectivePool::new(world, n, full_ranges(n, 2), WireFormat::F16);
        let synth = Synth { n };
        f32p.step(&[], 1.0, 1, 3, true, &synth).unwrap();
        f16p.step(&[], 1.0, 1, 3, true, &synth).unwrap();
        let (a, b) = (f32p.leader_grads(), f16p.leader_grads());
        // one f16 rounding per hop: relative error bounded by ~2^-10
        testkit::assert_allclose(&a, &b, 1e-2, 4e-3);
        // and the f16 path still agrees bitwise across ranks
        let b1 = f16p.rank_grads(1);
        for (x, y) in b.iter().zip(b1.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
