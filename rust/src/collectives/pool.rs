//! Persistent collective worker pool (paper §4.4, Fig. 2): the step
//! executor behind the trainer's hot loop.
//!
//! The old hot loop ran every rank's compute sequentially on one thread,
//! then built a fresh [`super::CollectiveGroup`] plus one `thread::spawn`
//! per rank for EVERY optimizer step and barriered on the whole exchange.
//! This module replaces that with infrastructure wired exactly once:
//!
//! * **two long-lived threads per rank** — a *compute* worker that runs
//!   the rank's micro-steps and accumulates gradients, and a *comm*
//!   worker that owns the rank's [`CommEndpoints`] in a reusable comm
//!   graph wired once through a [`Transport`] (the communicator, never
//!   re-created): in-process channels by default
//!   ([`InProcTransport`]), or sockets to peer processes
//!   ([`super::socket::SocketTransport`]) — same protocols, same
//!   reduction order, bitwise-identical sums either way;
//! * **overlapped bucket exchange** — on the final micro-step the compute
//!   worker accumulates bucket-by-bucket in backward order and hands each
//!   bucket to its comm worker *as soon as its accumulation completes*,
//!   so the exchange of bucket `b` overlaps the accumulation of buckets
//!   `> b` (the Fig. 2 schedule; `overlap = false` degrades to the
//!   accumulate-everything-then-exchange barrier order — bitwise
//!   identical results, only the timing differs);
//! * **topology-aware exchange** ([`CommMode`], paper §4.4 resource
//!   separation): on a `<X>M<Y>G` topology with multiple machines AND
//!   multiple GPUs per machine, each bucket travels the hierarchical
//!   schedule instead of one flat world-sized ring — intra-node leader
//!   accumulate over per-node channels ("PCIe"), ring allreduce over the
//!   node-leader comm workers only (reusing [`RingPlan`] at size
//!   `machines`, the "network"), then intra-node broadcast back — so the
//!   payload crosses the slow inter-node fabric `2(M-1)/M` times instead
//!   of riding a 2(N-1)-step world ring in lockstep with the PCIe hops;
//! * **chunked pipelined intra-node exchange** ([`IntraNodeMode`],
//!   `train.intra_node`, the default on multi-GPU nodes): instead of
//!   `(g-1)` serialized whole-bucket transfers through the node leader
//!   each way, every bucket splits into `chunk_elems`-sized chunks that
//!   flow through a member chain — reduce-forward toward the leader,
//!   copy-forward back — so per-member transfers overlap on their own
//!   links, the leader ring starts on chunk 0 while chunk 1 is still
//!   gathering, and reduced chunks broadcast while later chunks are
//!   still ringing.  `intra_node = serial` keeps the old schedule (the
//!   perf baseline `perf_hotpath` compares against);
//! * **bandwidth-optimal 2-level reduce-scatter** (`intra_node = rs`,
//!   opt-in): drops the node leader entirely — intra-node ring
//!   reduce-scatter (each of the `g` ranks ends owning `1/g` of the
//!   bucket), cross-machine ring allreduce over each rank's owned shard
//!   (`g` parallel `m`-sized rings running concurrently), then
//!   intra-node ring allgather — so per-link bytes drop from `O(n)` to
//!   `O(n/g)` on BOTH the PCIe links and the network ring, the
//!   NCCL-style schedule *Scaling Performance of LLM Pretraining*
//!   motivates;
//! * **preallocated, reused scratch** — per-rank gradient accumulators,
//!   per-bucket payload buffers, ring chunk plans, and wire message
//!   vectors (recycled through per-worker free lists; the hierarchical
//!   broadcast recycles the member payload vectors) are all allocated
//!   once; the steady-state step performs no gradient-sized heap
//!   allocation and no thread spawn (only O(buckets) stats vectors);
//! * **optional f16 wire format** (paper §4.4 exchanges FP16 gradients):
//!   ring payloads are converted through [`crate::half::F16`] per hop,
//!   halving wire bytes at one rounding per hop.  Each rank quantizes the
//!   reduced chunk it owns before the all-gather so every replica still
//!   ends bitwise identical.  In hierarchical mode the f16 wire applies
//!   to the inter-node leader ring only — the intra-node "PCIe" channels
//!   stay f32, exactly the paper's placement of the FP16 exchange on the
//!   slow network.
//!
//! ## Invariants
//!
//! * **Bitwise determinism** — given a deterministic [`RankCompute`],
//!   the reduced buffers are a pure function of the inputs and of the
//!   exchange schedule: the eager (overlap) and barrier orders are
//!   bitwise-identical to each other because the element-wise
//!   accumulation order is unchanged; the hierarchical schedule sums in
//!   a different (machine-grouped) association than the flat ring, so
//!   the two agree bitwise exactly when the gradient sums are exactly
//!   representable (asserted in tests) and to rounding error otherwise.
//!   Every intra-node reduction order is fixed — serialized leader
//!   accumulate adds local ranks 1, 2, … g-1 in order; the pipelined
//!   chain reduces tail-to-head, `leader + (m1 + (m2 + …))`, with chunk
//!   boundaries that never change the element-wise order; the 2-level
//!   reduce-scatter sums every shard in fixed ring order at both levels
//!   — so results are reproducible run to run and bitwise identical
//!   across replicas in every mode.  Asserted by
//!   `tests/pool_overlap.rs`, `tests/intra_node.rs`, and
//!   `tests/exchange_rs.rs`.
//! * **Zero spawn, zero alloc** — the steady-state step spawns no
//!   thread and performs no gradient-sized heap allocation in any
//!   schedule (the chunk pipeline's payload vectors recycle through
//!   per-worker free lists exactly like the ring wire messages; only
//!   the first step primes them).
//! * **Overlap efficiency ∈ [0, 1]** — exposed communication is
//!   measured as pure `recv` wait, so the derived
//!   `1 - exposed / total` ratio
//!   ([`crate::metrics::ExchangeTimings::overlap_efficiency`]) is a
//!   true fraction in every mode and schedule.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::ring::RingPlan;
use super::transport::{
    build_endpoints, quantize_f16, CommEndpoints, Frame, FrameRx, FrameTx,
    InProcTransport, PayloadPool, Schedule, Transport, TransportError,
};
use crate::grad::sparsify::{top_k_into, Sparsify};
use crate::grad::BucketRange;
use crate::half::F16;
use crate::topology::Topology;

/// On-the-wire payload encoding for ring messages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireFormat {
    /// Full-precision f32 payloads (bitwise-faithful exchange).
    #[default]
    F32,
    /// IEEE binary16 payloads (paper §4.4): half the wire bytes, one
    /// round-to-nearest-even per hop.
    F16,
}

/// How each bucket's allreduce travels the cluster (`train.comm_mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CommMode {
    /// One flat world-sized ring regardless of topology (the PR-1
    /// schedule; bitwise reference for the spawn-per-step baseline).
    Flat,
    /// The §4.4 hierarchy: PCIe leader-accumulate, network leader ring,
    /// PCIe broadcast.  Falls back to flat on degenerate topologies
    /// (`machines == 1` or `gpus_per_machine == 1`, where the hierarchy
    /// IS a flat ring).
    Hierarchical,
    /// Hierarchical whenever the topology has both multiple machines and
    /// multiple GPUs per machine, flat otherwise.
    #[default]
    Auto,
}

impl CommMode {
    /// Parse the `flat | hierarchical | auto` config/CLI spelling.
    pub fn parse(s: &str) -> std::result::Result<CommMode, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "flat" => Ok(CommMode::Flat),
            "hierarchical" | "hier" => Ok(CommMode::Hierarchical),
            "auto" => Ok(CommMode::Auto),
            other => Err(format!(
                "'{other}': expected flat | hierarchical | auto"
            )),
        }
    }

    /// Whether this mode runs the hierarchical schedule on `topo`.
    pub fn resolves_hierarchical(self, topo: &Topology) -> bool {
        let multi = topo.machines > 1 && topo.gpus_per_machine > 1;
        match self {
            CommMode::Flat => false,
            CommMode::Hierarchical | CommMode::Auto => multi,
        }
    }
}

impl std::fmt::Display for CommMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            CommMode::Flat => "flat",
            CommMode::Hierarchical => "hierarchical",
            CommMode::Auto => "auto",
        })
    }
}

/// Default chunk size (elements) for the pipelined intra-node exchange:
/// 64 Ki f32 elements = 256 KiB per chunk, small enough that a bucket
/// splits into several pipeline stages, large enough that per-chunk
/// channel overhead stays negligible (`train.chunk_elems` overrides).
pub const DEFAULT_CHUNK_ELEMS: usize = 1 << 16;

/// How a bucket moves within a node under the hierarchical schedule
/// (`train.intra_node`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IntraNodeMode {
    /// The PR-2 schedule: `(g-1)` serialized whole-bucket transfers into
    /// the node leader (gather) and back out (broadcast) — every byte
    /// and every add funnels through the leader's port and thread.
    Serial,
    /// Chunked pipelined chain: each bucket splits into
    /// `chunk_elems`-sized chunks that flow member-to-member toward the
    /// leader (reduce-forward) and back (copy-forward), so per-member
    /// transfers overlap on their own links instead of serializing
    /// through the leader, and the inter-node ring starts on chunk 0
    /// while chunk 1 is still gathering.
    Ring,
    /// Bandwidth-optimal NCCL-style 2-level schedule (`rs`): intra-node
    /// ring reduce-scatter (each of the `g` ranks ends owning `1/g` of
    /// the bucket), cross-machine ring allreduce over each rank's owned
    /// shard (`g` parallel `m`-sized rings), then intra-node allgather
    /// — per-link bytes drop from `O(n)` to `O(n/g)` on PCIe AND on the
    /// network ring.
    ReduceScatter,
    /// Ring whenever the hierarchical schedule resolves (the topology
    /// has node members to chain), serial otherwise.
    #[default]
    Auto,
}

impl IntraNodeMode {
    /// Parse the `serial | ring | rs | auto` config/CLI spelling.
    pub fn parse(s: &str) -> std::result::Result<IntraNodeMode, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "serial" => Ok(IntraNodeMode::Serial),
            "ring" | "chain" | "pipelined" => Ok(IntraNodeMode::Ring),
            "rs" | "reduce-scatter" => Ok(IntraNodeMode::ReduceScatter),
            "auto" => Ok(IntraNodeMode::Auto),
            other => Err(format!(
                "'{other}': expected serial | ring | rs | auto"
            )),
        }
    }

    /// Whether this mode runs the chunked pipelined chain on `topo`
    /// (only meaningful when the hierarchical schedule resolves).
    pub fn resolves_ring(self, topo: &Topology) -> bool {
        match self {
            IntraNodeMode::Serial | IntraNodeMode::ReduceScatter => false,
            IntraNodeMode::Ring | IntraNodeMode::Auto => {
                topo.gpus_per_machine > 1
            }
        }
    }

    /// Whether this mode runs the 2-level reduce-scatter schedule on
    /// `topo` (only meaningful when the hierarchical schedule resolves;
    /// opt-in — `Auto` keeps resolving to the chain).
    pub fn resolves_rs(self, topo: &Topology) -> bool {
        self == IntraNodeMode::ReduceScatter && topo.gpus_per_machine > 1
    }
}

impl std::fmt::Display for IntraNodeMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            IntraNodeMode::Serial => "serial",
            IntraNodeMode::Ring => "ring",
            IntraNodeMode::ReduceScatter => "rs",
            IntraNodeMode::Auto => "auto",
        })
    }
}

/// Number of fixed-size chunks a bucket of `len` elements splits into
/// (always >= 1, so zero-length buckets still move one sync message).
fn num_chunks(len: usize, chunk_elems: usize) -> usize {
    if len == 0 {
        1
    } else {
        (len + chunk_elems - 1) / chunk_elems
    }
}

/// Element range of chunk `c` within a bucket of `len` elements.
fn chunk_span(len: usize, chunk_elems: usize, c: usize)
    -> std::ops::Range<usize> {
    let start = (c * chunk_elems).min(len);
    let end = ((c + 1) * chunk_elems).min(len);
    start..end
}

/// Per-micro-step scalar outputs a [`RankCompute`] reports back.
#[derive(Debug, Clone, Copy, Default)]
pub struct MicroStats {
    pub loss: f64,
    pub mlm_loss: f64,
    pub nsp_loss: f64,
    pub mlm_acc: f64,
    /// Any non-finite loss/grad-norm observed (AMP overflow signal).
    pub nonfinite: bool,
    /// Seconds this micro-step spent waiting on its input batch — the
    /// blocked `pop` on the prefetch ring, or the whole in-line batch
    /// build when running synchronously.  Part of the compute worker's
    /// wall (it happens inside `micro`), split out so data stalls can
    /// sit next to the PCIe/network spans in the trace.
    pub input_stall_s: f64,
}

/// One rank's micro-step: fill `grads_out` with the flat gradient of this
/// (rank, step, micro) and report scalar stats.  Called concurrently from
/// every rank's compute worker, so implementations must be `Sync`
/// (per-rank mutable state goes behind per-rank locks).
pub trait RankCompute: Sync {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             params: &[f32], scale: f32, grads_out: &mut Vec<f32>)
             -> Result<MicroStats>;
}

/// Aggregated outcome of one pooled optimizer step.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    pub loss_sum: f64,
    pub mlm_sum: f64,
    pub nsp_sum: f64,
    pub acc_sum: f64,
    pub saw_overflow: bool,
    /// Critical-path (max over ranks) seconds in `RankCompute::micro`.
    pub compute_s: f64,
    /// Critical-path seconds `RankCompute::micro` spent blocked on input
    /// batches (a subset of `compute_s` — the stall happens inside the
    /// timed micro call).
    pub input_stall_s: f64,
    /// Critical-path seconds accumulating gradients.
    pub accum_s: f64,
    /// Critical-path seconds of exchange (sum over buckets).
    pub comm_s: f64,
    /// Network (inter-node) seconds, max over ranks: the leader-ring
    /// phase in hierarchical mode; the whole exchange for a flat ring on
    /// a multi-machine topology; 0 within a single node.
    pub comm_net_s: f64,
    /// PCIe (intra-node) seconds, max over ranks — leader accumulate +
    /// broadcast in hierarchical mode.  Each component is a per-rank
    /// maximum taken independently, so `comm_pcie_s + comm_net_s >=
    /// comm_s` (never an understated split).
    pub comm_pcie_s: f64,
    /// Critical-path seconds the step spent *blocked* waiting for reduced
    /// buckets after its gradient accumulation finished — the exposed
    /// (non-overlapped) communication of Fig. 2.  Pure `recv` wait: the
    /// copy-back of reduced data and loop bookkeeping are excluded, so
    /// `1 - exposed/total` is a meaningful overlap ratio.
    pub exposed_comm_s: f64,
    /// Seconds (max over ranks) the step's socket sends spent stalled on
    /// a full per-link send queue — backpressure from a slow or
    /// congested peer.  Always 0 for in-process links (unbounded
    /// channels); a subset of `comm_s`, since the stall happens inside
    /// the timed exchange.
    pub net_backpressure_s: f64,
    /// Per-bucket exchange seconds (max over ranks).
    pub bucket_s: Vec<f64>,
    /// Per-bucket PCIe-phase seconds (max over ranks of each rank's
    /// `exchange - net` for that bucket).
    pub bucket_pcie_s: Vec<f64>,
    /// Per-bucket network-phase seconds (max over ranks).
    pub bucket_net_s: Vec<f64>,
    /// Wall-clock of the whole pooled step.
    pub wall_s: f64,
}

// ------------------------------------------------------------ wiring --

/// Job dispatched to one compute worker.  The references are transmuted
/// to `'static` by [`CollectivePool::step`]; see the SAFETY note there.
struct Job {
    params: &'static [f32],
    compute: &'static (dyn RankCompute + 'static),
    scale: f32,
    micro_steps: usize,
    step_index: usize,
    overlap: bool,
}

/// Per-rank stats returned by a compute worker after each step.
#[derive(Debug, Clone, Default)]
struct RankStats {
    loss_sum: f64,
    mlm_sum: f64,
    nsp_sum: f64,
    acc_sum: f64,
    nonfinite: bool,
    compute_s: f64,
    input_stall_s: f64,
    accum_s: f64,
    comm_s: f64,
    comm_pcie_s: f64,
    comm_net_s: f64,
    net_backpressure_s: f64,
    exposed_comm_s: f64,
    bucket_s: Vec<f64>,
    bucket_pcie_s: Vec<f64>,
    bucket_net_s: Vec<f64>,
}

struct RankResult {
    rank: usize,
    res: std::result::Result<RankStats, String>,
}

/// Reduced bucket handed back from a comm worker to its compute worker.
/// Intra-rank only (never crosses a transport); exchange failures travel
/// the same channel as `Err(reason)` so the compute worker can name the
/// step and bucket that lost the world.
struct Reduced {
    idx: usize,
    data: Vec<f32>,
    /// Total exchange seconds for this bucket at this rank.
    exchange_s: f64,
    /// Seconds of `exchange_s` spent in the inter-node (network) phase.
    net_s: f64,
    /// Seconds of `exchange_s` this rank's sends spent stalled on a full
    /// socket send queue (0 on in-process links).
    backpressure_s: f64,
}

/// What a comm worker hands back per bucket: the reduced payload, or the
/// reason the exchange died (a peer disconnect/timeout surfaced by the
/// transport).
type ReducedResult = std::result::Result<Reduced, String>;

/// Shared trigger for `--inject-fail net:step[:rank]`: drop a rank's
/// remote links at a chosen step so elasticity tests can exercise a
/// REAL mid-exchange link loss (the peer process observes an actual
/// socket close, not a simulated error).  `usize::MAX` means "disarmed"
/// for `step` and "any local rank" for `rank`; `current` is the step
/// index the pool is executing, stored by [`CollectivePool::step`].
struct NetFault {
    step: AtomicUsize,
    rank: AtomicUsize,
    current: AtomicUsize,
}

impl NetFault {
    fn new() -> NetFault {
        NetFault {
            step: AtomicUsize::new(usize::MAX),
            rank: AtomicUsize::new(usize::MAX),
            current: AtomicUsize::new(usize::MAX),
        }
    }

    /// Whether the fault fires for `rank` at the step now executing.
    fn tripped(&self, rank: usize) -> bool {
        let armed = self.step.load(Ordering::Relaxed);
        if armed == usize::MAX || self.current.load(Ordering::Relaxed) != armed
        {
            return false;
        }
        let r = self.rank.load(Ordering::Relaxed);
        r == usize::MAX || r == rank
    }
}

/// Message both fault wrappers surface once tripped, so the failing
/// rank's error names the injection rather than a mystery I/O fault.
const NET_FAULT_MSG: &str = "injected network fault (--inject-fail net)";

/// [`FrameTx`] wrapper that drops the wrapped socket end when its
/// [`NetFault`] trips.  Dropping closes the underlying stream, so the
/// remote peer sees a genuine disconnect — exactly what a killed
/// process would produce.
struct FaultTx {
    inner: Option<Box<dyn FrameTx>>,
    rank: usize,
    fault: Arc<NetFault>,
}

impl FrameTx for FaultTx {
    fn send(&mut self, frame: Frame, pool: &mut PayloadPool)
            -> std::result::Result<(), TransportError> {
        if self.fault.tripped(self.rank) {
            self.inner = None;
        }
        match self.inner.as_mut() {
            Some(tx) => tx.send(frame, pool),
            None => Err(TransportError::Io(NET_FAULT_MSG.into())),
        }
    }

    fn remote(&self) -> bool {
        true
    }

    fn take_backpressure_s(&mut self) -> f64 {
        self.inner.as_mut().map_or(0.0, |tx| tx.take_backpressure_s())
    }
}

/// [`FrameRx`] counterpart of [`FaultTx`].
struct FaultRx {
    inner: Option<Box<dyn FrameRx>>,
    rank: usize,
    fault: Arc<NetFault>,
}

impl FrameRx for FaultRx {
    fn recv(&mut self, pool: &mut PayloadPool)
            -> std::result::Result<Frame, TransportError> {
        if self.fault.tripped(self.rank) {
            self.inner = None;
        }
        match self.inner.as_mut() {
            Some(rx) => rx.recv(pool),
            None => Err(TransportError::Io(NET_FAULT_MSG.into())),
        }
    }

    fn remote(&self) -> bool {
        true
    }
}

/// Interpose the fault wrappers on every **remote** link end of `ep`
/// (in-process ends pass through untouched: the injection models a lost
/// network peer, and in-proc links cannot be "cut" realistically — nor
/// does `--inject-fail net` apply without a socket transport).
fn wrap_net_fault(ep: CommEndpoints, rank: usize, fault: &Arc<NetFault>)
                  -> CommEndpoints {
    let wtx = |tx: Box<dyn FrameTx>| -> Box<dyn FrameTx> {
        if tx.remote() {
            Box::new(FaultTx { inner: Some(tx), rank, fault: fault.clone() })
        } else {
            tx
        }
    };
    let wrx = |rx: Box<dyn FrameRx>| -> Box<dyn FrameRx> {
        if rx.remote() {
            Box::new(FaultRx { inner: Some(rx), rank, fault: fault.clone() })
        } else {
            rx
        }
    };
    match ep {
        CommEndpoints::Flat { rank: r, ring_size, net, tx_next, rx_prev } => {
            CommEndpoints::Flat {
                rank: r,
                ring_size,
                net,
                tx_next: wtx(tx_next),
                rx_prev: wrx(rx_prev),
            }
        }
        CommEndpoints::Leader { machine, machines, member_rxs, member_txs,
                                tx_next, rx_prev } => {
            CommEndpoints::Leader {
                machine,
                machines,
                member_rxs: member_rxs.into_iter().map(wrx).collect(),
                member_txs: member_txs.into_iter().map(wtx).collect(),
                tx_next: wtx(tx_next),
                rx_prev: wrx(rx_prev),
            }
        }
        CommEndpoints::Member { to_leader, from_leader } => {
            CommEndpoints::Member {
                to_leader: wtx(to_leader),
                from_leader: wrx(from_leader),
            }
        }
        CommEndpoints::ChainLeader { machine, machines, chunk_elems, up_rx,
                                     down_tx, tx_next, rx_prev } => {
            CommEndpoints::ChainLeader {
                machine,
                machines,
                chunk_elems,
                up_rx: wrx(up_rx),
                down_tx: wtx(down_tx),
                tx_next: wtx(tx_next),
                rx_prev: wrx(rx_prev),
            }
        }
        CommEndpoints::ChainMember { chunk_elems, up_rx, up_tx, down_rx,
                                     down_tx } => {
            CommEndpoints::ChainMember {
                chunk_elems,
                up_rx: up_rx.map(wrx),
                up_tx: wtx(up_tx),
                down_rx: wrx(down_rx),
                down_tx: down_tx.map(wtx),
            }
        }
        CommEndpoints::RsNode { machine, machines, gpus, local, intra_tx,
                                intra_rx, cross_tx, cross_rx } => {
            CommEndpoints::RsNode {
                machine,
                machines,
                gpus,
                local,
                intra_tx: wtx(intra_tx),
                intra_rx: wrx(intra_rx),
                cross_tx: wtx(cross_tx),
                cross_rx: wrx(cross_rx),
            }
        }
    }
}

/// The persistent pool: two threads per *local* rank plus the links
/// between them, created once and reused for every step until drop.  In
/// a single-process run every rank is local (`2 * world` threads); in a
/// multi-process run each process builds one pool over its contiguous
/// rank slice and the transport carries the cross-process edges.
pub struct CollectivePool {
    world: usize,
    /// Global ranks hosted by this process (== `0..world` in-process).
    local: Range<usize>,
    n_elems: usize,
    ranges: Arc<[BucketRange]>,
    wire: WireFormat,
    topo: Topology,
    hierarchical: bool,
    intra_ring: bool,
    intra_rs: bool,
    chunk_elems: usize,
    sparsify: Sparsify,
    job_txs: Vec<Sender<Job>>,
    result_rx: Receiver<RankResult>,
    /// Per-rank accumulated (and, post-step, reduced) flat gradients.
    /// Locked by rank `r`'s compute worker for the duration of a step;
    /// free for inspection between steps.
    accs: Arc<Vec<Mutex<Vec<f32>>>>,
    /// Per-rank error-feedback residuals for `train.sparsify` (empty
    /// vectors when sparsification is inactive, and for non-local
    /// ranks).  Locked by rank `r`'s comm worker per network exchange;
    /// free for snapshot/restore between steps.
    ef: Arc<Vec<Mutex<Vec<f32>>>>,
    compute_handles: Vec<JoinHandle<()>>,
    comm_handles: Vec<JoinHandle<()>>,
    /// Shared `--inject-fail net` trigger; disarmed unless
    /// [`Self::arm_net_fault`] is called.
    net_fault: Arc<NetFault>,
}

impl CollectivePool {
    /// Flat-ring pool over an anonymous `world` (single-node topology) —
    /// the PR-1 constructor, kept for benches/tests and for callers that
    /// have no cluster shape.
    pub fn new(world: usize, n_elems: usize, ranges: Arc<[BucketRange]>,
               wire: WireFormat) -> CollectivePool {
        assert!(world >= 1, "world must be >= 1");
        Self::with_topology(Topology::new(1, world), n_elems, ranges, wire,
                            CommMode::Flat)
    }

    /// Wire up the pool for a cluster topology: `world` rank pairs
    /// (compute + comm worker), the exchange channels dictated by
    /// `mode.resolves_hierarchical(&topo)` — either one flat world ring,
    /// or per-node member channels plus a `machines`-sized leader ring —
    /// and per-rank flat buffers of `n_elems`.  `ranges` is the shared
    /// bucket table (built once via [`crate::grad::bucket_ranges`] — no
    /// per-step cloning).  The intra-node schedule defaults to
    /// [`IntraNodeMode::Auto`] (the chunked pipelined chain whenever the
    /// hierarchy resolves) at [`DEFAULT_CHUNK_ELEMS`]; use
    /// [`Self::with_intra`] to pin it.
    ///
    /// # Examples
    ///
    /// ```
    /// use bertdist::collectives::pool::{CollectivePool, CommMode,
    ///                                   MicroStats, RankCompute,
    ///                                   WireFormat};
    /// use bertdist::grad::BucketRange;
    /// use bertdist::topology::Topology;
    ///
    /// /// Every rank contributes a vector of ones.
    /// struct Ones;
    /// impl RankCompute for Ones {
    ///     fn micro(&self, _rank: usize, _step: usize, _micro: usize,
    ///              _params: &[f32], _scale: f32, out: &mut Vec<f32>)
    ///              -> anyhow::Result<MicroStats> {
    ///         out.resize(8, 0.0);
    ///         out.fill(1.0);
    ///         Ok(MicroStats::default())
    ///     }
    /// }
    ///
    /// // Two ranks on one node; workers and channels are wired ONCE
    /// // here and reused by every subsequent `step`.
    /// let ranges = BucketRange::even_split(8, 2);
    /// let mut pool = CollectivePool::with_topology(
    ///     Topology::new(1, 2), 8, ranges, WireFormat::F32,
    ///     CommMode::Auto);
    /// pool.step(&[], 1.0, 1, 0, true, &Ones)?;
    /// // after the exchange every rank holds the cross-rank sum
    /// assert!(pool.leader_grads().iter().all(|&gr| gr == 2.0));
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn with_topology(topo: Topology, n_elems: usize,
                         ranges: Arc<[BucketRange]>, wire: WireFormat,
                         mode: CommMode) -> CollectivePool {
        Self::with_intra(topo, n_elems, ranges, wire, mode,
                         IntraNodeMode::Auto, DEFAULT_CHUNK_ELEMS)
    }

    /// [`Self::with_topology`] with the intra-node schedule pinned:
    /// `intra` picks serialized-leader vs chunked-pipelined-chain
    /// transfers inside each node (`train.intra_node`), `chunk_elems`
    /// the pipeline granularity (`train.chunk_elems`; values larger
    /// than every bucket degrade gracefully to one chunk per bucket).
    pub fn with_intra(topo: Topology, n_elems: usize,
                      ranges: Arc<[BucketRange]>, wire: WireFormat,
                      mode: CommMode, intra: IntraNodeMode,
                      chunk_elems: usize) -> CollectivePool {
        Self::with_sparsify(topo, n_elems, ranges, wire, mode, intra,
                            chunk_elems, Sparsify::None)
    }

    /// [`Self::with_intra`] with the network sparsification knob pinned
    /// (`train.sparsify`), over an in-process transport.
    #[allow(clippy::too_many_arguments)]
    pub fn with_sparsify(topo: Topology, n_elems: usize,
                         ranges: Arc<[BucketRange]>, wire: WireFormat,
                         mode: CommMode, intra: IntraNodeMode,
                         chunk_elems: usize, sparsify: Sparsify)
                         -> CollectivePool {
        let mut transport = InProcTransport::new(topo.world_size());
        Self::with_transport(topo, n_elems, ranges, wire, mode, intra,
                             chunk_elems, sparsify, &mut transport)
            .expect("in-process wiring cannot fail")
    }

    /// [`Self::with_intra`] over an explicit [`Transport`] — the
    /// out-of-process entry point.  The transport decides which global
    /// ranks live in THIS process ([`Transport::local_ranks`]); worker
    /// threads are spawned for those ranks only, and every comm-graph
    /// edge that crosses the process boundary rides the transport's
    /// links (sockets) instead of in-process channels.  Multi-process
    /// runs call this once per pool build and may reuse the same
    /// transport for a later build (the phase-2 trainer does).
    ///
    /// Fails if the transport cannot wire the topology — world mismatch,
    /// a process split that breaks machine alignment in hierarchical
    /// mode, or a peer that never answered its dial/accept.
    #[allow(clippy::too_many_arguments)]
    pub fn with_transport(topo: Topology, n_elems: usize,
                          ranges: Arc<[BucketRange]>, wire: WireFormat,
                          mode: CommMode, intra: IntraNodeMode,
                          chunk_elems: usize, sparsify: Sparsify,
                          transport: &mut dyn Transport)
                          -> Result<CollectivePool> {
        let world = topo.world_size();
        assert!(world >= 1, "world must be >= 1");
        let hierarchical = mode.resolves_hierarchical(&topo);
        let intra_rs = hierarchical && intra.resolves_rs(&topo);
        let intra_ring = hierarchical && intra.resolves_ring(&topo);
        let schedule = if !hierarchical {
            Schedule::Flat
        } else if intra_rs {
            Schedule::ReduceScatter
        } else if intra_ring {
            Schedule::Chain
        } else {
            Schedule::Leader
        };
        let chunk_elems = chunk_elems.max(1);
        let local = transport.local_ranks();
        // Non-local ranks get empty buffers: their gradients live in the
        // process that hosts them, and indexing stays global.
        let accs: Arc<Vec<Mutex<Vec<f32>>>> = Arc::new(
            (0..world)
                .map(|r| {
                    if local.contains(&r) {
                        Mutex::new(vec![0.0f32; n_elems])
                    } else {
                        Mutex::new(Vec::new())
                    }
                })
                .collect(),
        );
        // Sparsification lives on network-crossing rings only, and its
        // placement is a pure function of the TOPOLOGY (never of the
        // transport): a single-machine world has no network ring, so the
        // knob is inert there and both transports agree bitwise.
        let sparse_ratio = match sparsify {
            Sparsify::TopK(r) if topo.machines > 1 => Some(r),
            _ => None,
        };
        // Error-feedback residuals: one full-length vector per local
        // rank whenever sparsification is active (ranks whose role never
        // touches a network ring simply keep theirs at zero).
        let ef: Arc<Vec<Mutex<Vec<f32>>>> = Arc::new(
            (0..world)
                .map(|r| {
                    if sparse_ratio.is_some() && local.contains(&r) {
                        Mutex::new(vec![0.0f32; n_elems])
                    } else {
                        Mutex::new(Vec::new())
                    }
                })
                .collect(),
        );

        let endpoints =
            build_endpoints(&topo, schedule, chunk_elems, transport)
                .map_err(|e| anyhow::anyhow!("transport wiring: {e}"))?;

        let (result_tx, result_rx) = channel::<RankResult>();
        let net_fault = Arc::new(NetFault::new());
        let mut job_txs = Vec::with_capacity(local.len());
        let mut compute_handles = Vec::with_capacity(local.len());
        let mut comm_handles = Vec::with_capacity(local.len());
        for (r, endpoints) in endpoints {
            let endpoints = wrap_net_fault(endpoints, r, &net_fault);
            let (job_tx, job_rx) = channel::<Job>();
            let (bucket_tx, bucket_rx) = channel::<(usize, Vec<f32>)>();
            let (reduced_tx, reduced_rx) = channel::<ReducedResult>();
            let ranges_comm = ranges.clone();
            let sparse = SparseCtx {
                ratio: sparse_ratio,
                rank: r,
                ef: ef.clone(),
                scratch: SparseScratch::default(),
            };
            comm_handles.push(
                std::thread::Builder::new()
                    .name(format!("pool-comm-{r}"))
                    .spawn(move || {
                        comm_worker(wire, &ranges_comm, bucket_rx,
                                    reduced_tx, endpoints, sparse);
                    })
                    .expect("spawn comm worker"),
            );
            let ranges_cmp = ranges.clone();
            let accs_cmp = accs.clone();
            let result_tx = result_tx.clone();
            compute_handles.push(
                std::thread::Builder::new()
                    .name(format!("pool-rank-{r}"))
                    .spawn(move || {
                        compute_worker(r, world, &ranges_cmp, &accs_cmp,
                                       job_rx, bucket_tx, reduced_rx,
                                       result_tx);
                    })
                    .expect("spawn compute worker"),
            );
            job_txs.push(job_tx);
        }
        drop(result_tx);
        Ok(CollectivePool {
            world,
            local,
            n_elems,
            ranges,
            wire,
            topo,
            hierarchical,
            intra_ring,
            intra_rs,
            chunk_elems,
            sparsify,
            job_txs,
            result_rx,
            accs,
            ef,
            compute_handles,
            comm_handles,
            net_fault,
        })
    }

    /// Arm the `--inject-fail net:step[:rank]` trigger: when the pool
    /// executes `step`, every **remote** link end owned by `rank` (all
    /// local ranks when `None`) is dropped mid-exchange — the peer
    /// process observes a real socket close, and this rank's step fails
    /// with a named injection error.  A no-op on a pool with no remote
    /// links (in-process transport): there is no socket to cut, so
    /// callers gate the flag on a socket transport being configured.
    pub fn arm_net_fault(&mut self, step: usize, rank: Option<usize>) {
        self.net_fault.step.store(step, Ordering::Relaxed);
        self.net_fault
            .rank
            .store(rank.unwrap_or(usize::MAX), Ordering::Relaxed);
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Global ranks this process hosts workers (and gradients) for.
    pub fn local_ranks(&self) -> Range<usize> {
        self.local.clone()
    }

    /// Whether this process hosts global rank 0 — the process that owns
    /// checkpointing, logging, and the final save in multi-process runs.
    pub fn is_lead(&self) -> bool {
        self.local.start == 0
    }

    pub fn n_elems(&self) -> usize {
        self.n_elems
    }

    pub fn num_buckets(&self) -> usize {
        self.ranges.len()
    }

    pub fn wire(&self) -> WireFormat {
        self.wire
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// Whether the pool's exchange runs the PCIe-then-network hierarchy
    /// (the resolved [`CommMode`], not the requested one).
    pub fn is_hierarchical(&self) -> bool {
        self.hierarchical
    }

    /// Whether the hierarchical exchange runs the chunked pipelined
    /// chain inside each node (the resolved [`IntraNodeMode`]).
    pub fn is_intra_ring(&self) -> bool {
        self.intra_ring
    }

    /// Whether the exchange runs the bandwidth-optimal 2-level
    /// reduce-scatter schedule (the resolved [`IntraNodeMode`]):
    /// intra-node reduce-scatter, per-shard cross-machine rings,
    /// intra-node allgather.
    pub fn is_intra_rs(&self) -> bool {
        self.intra_rs
    }

    /// Pipeline granularity of the intra-node chain, in elements.
    pub fn chunk_elems(&self) -> usize {
        self.chunk_elems
    }

    /// The requested network sparsification knob (`train.sparsify`).
    pub fn sparsify(&self) -> Sparsify {
        self.sparsify
    }

    /// Whether sparsification actually runs on this pool's exchange —
    /// `topk` resolved against a topology that HAS a network ring
    /// (`machines > 1`).  Inert knobs keep residuals empty.
    pub fn sparsify_active(&self) -> bool {
        matches!(self.sparsify, Sparsify::TopK(_)) && self.topo.machines > 1
    }

    /// Clone every local rank's error-feedback residual, in local-rank
    /// order — the checkpoint payload that makes a sparsified run
    /// resumable bitwise.  Empty when sparsification is inactive.  Only
    /// call between steps (comm workers hold the locks mid-exchange).
    pub fn ef_snapshot(&self) -> Vec<Vec<f32>> {
        if !self.sparsify_active() {
            return Vec::new();
        }
        self.local
            .clone()
            .map(|r| {
                self.ef[r].lock().expect("ef residual poisoned").clone()
            })
            .collect()
    }

    /// Restore error-feedback residuals from a checkpoint, one vector
    /// per local rank in local-rank order.  An empty slice zeroes them
    /// (the reshape path — per-rank residuals cannot be remapped across
    /// world shapes).
    pub fn restore_ef(&self, residuals: &[Vec<f32>]) -> Result<()> {
        if residuals.is_empty() {
            self.zero_ef();
            return Ok(());
        }
        anyhow::ensure!(self.sparsify_active(),
                        "checkpoint carries {} error-feedback residuals \
                         but sparsification is inactive",
                        residuals.len());
        anyhow::ensure!(residuals.len() == self.local.len(),
                        "checkpoint carries {} error-feedback residuals, \
                         pool hosts {} local ranks",
                        residuals.len(), self.local.len());
        for (r, src) in self.local.clone().zip(residuals) {
            anyhow::ensure!(src.len() == self.n_elems,
                            "error-feedback residual for rank {r} has {} \
                             elems, model has {}",
                            src.len(), self.n_elems);
            let mut dst = self.ef[r].lock().expect("ef residual poisoned");
            dst.copy_from_slice(src);
        }
        Ok(())
    }

    /// Zero every local rank's error-feedback residual.
    pub fn zero_ef(&self) {
        for r in self.local.clone() {
            let mut v = self.ef[r].lock().expect("ef residual poisoned");
            v.fill(0.0);
        }
    }

    /// Chunks each bucket's exchange splits into: all 1 on a flat or
    /// serialized-leader schedule, `ceil(len / chunk_elems)` per bucket
    /// on the pipelined chain — what `--trace` uses to split the PCIe
    /// spans per chunk.
    pub fn chunks_per_bucket(&self) -> Vec<usize> {
        self.ranges
            .iter()
            .map(|b| {
                if self.intra_ring {
                    num_chunks(b.len(), self.chunk_elems)
                } else {
                    1
                }
            })
            .collect()
    }

    /// Run one optimizer step across all ranks: `micro_steps` calls to
    /// `compute.micro` per rank (in parallel across ranks on the
    /// persistent workers), local accumulation, then the bucketed
    /// exchange — eagerly interleaved with the final accumulation when
    /// `overlap` is set, barrier-ordered otherwise.  After this returns,
    /// every rank's buffer (see [`Self::rank_grads`]) holds the summed
    /// gradients, bitwise identical across ranks.
    ///
    /// Blocks until every rank reported, so the borrows in the request
    /// never outlive the call (see SAFETY below).  A `RankCompute` error
    /// on any rank still completes the exchange protocol on every rank
    /// (no deadlock) and is then returned here.
    pub fn step(&mut self, params: &[f32], scale: f32, micro_steps: usize,
                step_index: usize, overlap: bool,
                compute: &dyn RankCompute) -> Result<StepOutcome> {
        // SAFETY: the transmutes only erase lifetimes.  Workers use the
        // references strictly between receiving the Job and sending
        // their RankResult, and this function does not return until it
        // has received exactly one result per local rank — so the
        // borrows are live for every use.  Channel failures below are
        // programming errors (a worker can only exit when the pool is
        // dropped) and panic rather than return, keeping the invariant.
        let params_static: &'static [f32] =
            unsafe { std::mem::transmute::<&[f32], &'static [f32]>(params) };
        let compute_static: &'static (dyn RankCompute + 'static) = unsafe {
            std::mem::transmute::<&(dyn RankCompute + '_),
                                  &'static (dyn RankCompute + 'static)>(
                compute,
            )
        };
        let t0 = Instant::now();
        // Publish the executing step index so an armed net fault trips
        // exactly at its target step (comm workers read it lock-free).
        self.net_fault.current.store(step_index, Ordering::Relaxed);
        for tx in &self.job_txs {
            tx.send(Job {
                params: params_static,
                compute: compute_static,
                scale,
                micro_steps,
                step_index,
                overlap,
            })
            .expect("collective pool worker exited (prior panic?)");
        }
        let mut out = StepOutcome {
            bucket_s: vec![0.0; self.ranges.len()],
            bucket_pcie_s: vec![0.0; self.ranges.len()],
            bucket_net_s: vec![0.0; self.ranges.len()],
            ..Default::default()
        };
        // Collect every rank's result first, then fold in RANK order:
        // the scalar sums are f64 additions, and folding in arrival
        // order would make them depend on thread timing — the reduced
        // gradients are deterministic, the reported losses must be too.
        let mut results: Vec<Option<RankStats>> =
            (0..self.world).map(|_| None).collect();
        let mut errs: Vec<String> = Vec::new();
        for _ in 0..self.job_txs.len() {
            let r = self
                .result_rx
                .recv()
                .expect("collective pool workers died mid-step");
            match r.res {
                Ok(s) => results[r.rank] = Some(s),
                Err(e) => errs.push(format!("rank {}: {e}", r.rank)),
            }
        }
        for s in results.into_iter().flatten() {
            out.loss_sum += s.loss_sum;
            out.mlm_sum += s.mlm_sum;
            out.nsp_sum += s.nsp_sum;
            out.acc_sum += s.acc_sum;
            out.saw_overflow |= s.nonfinite;
            out.compute_s = out.compute_s.max(s.compute_s);
            out.input_stall_s = out.input_stall_s.max(s.input_stall_s);
            out.accum_s = out.accum_s.max(s.accum_s);
            out.comm_s = out.comm_s.max(s.comm_s);
            out.comm_pcie_s = out.comm_pcie_s.max(s.comm_pcie_s);
            out.comm_net_s = out.comm_net_s.max(s.comm_net_s);
            out.net_backpressure_s =
                out.net_backpressure_s.max(s.net_backpressure_s);
            out.exposed_comm_s = out.exposed_comm_s.max(s.exposed_comm_s);
            for (t, b) in out.bucket_s.iter_mut().zip(&s.bucket_s) {
                *t = t.max(*b);
            }
            for (t, b) in
                out.bucket_pcie_s.iter_mut().zip(&s.bucket_pcie_s) {
                *t = t.max(*b);
            }
            for (t, b) in out.bucket_net_s.iter_mut().zip(&s.bucket_net_s) {
                *t = t.max(*b);
            }
        }
        out.wall_s = t0.elapsed().as_secs_f64();
        // Name the step as well as the ranks: an elastic supervisor's
        // log must show WHERE the world was lost so "progress lost ≤
        // save_every" is auditable from the error alone.
        anyhow::ensure!(errs.is_empty(), "pooled step {step_index} \
                        failed: {}", errs.join("; "));
        Ok(out)
    }

    /// The lowest local rank's buffer — the reduced gradients this
    /// process's trainer normalizes and applies (global rank 0 in a
    /// single-process run; after the exchange every rank's buffer holds
    /// the same global sum).  Only call between steps (a worker holds
    /// the lock during its step).
    pub fn leader_grads(&self) -> MutexGuard<'_, Vec<f32>> {
        self.rank_grads(self.local.start)
    }

    /// Any *local* rank's buffer (tests assert cross-rank bitwise
    /// equality); non-local gradients live in the process hosting them.
    pub fn rank_grads(&self, rank: usize) -> MutexGuard<'_, Vec<f32>> {
        assert!(self.local.contains(&rank),
                "rank {rank} is not hosted by this process \
                 (local {:?})", self.local);
        self.accs[rank].lock().expect("pool rank buffer poisoned")
    }
}

impl Drop for CollectivePool {
    fn drop(&mut self) {
        // Closing the job channels unblocks the compute workers; their
        // bucket channels then close, unblocking the comm workers (a
        // hierarchical member's exit closes its leader-facing channels,
        // which the leader only reads mid-bucket, so teardown order is
        // safe in both modes).
        self.job_txs.clear();
        for h in self.compute_handles.drain(..) {
            let _ = h.join();
        }
        for h in self.comm_handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ----------------------------------------------------- compute worker --

#[allow(clippy::too_many_arguments)]
fn compute_worker(rank: usize, world: usize, ranges: &Arc<[BucketRange]>,
                  accs: &Arc<Vec<Mutex<Vec<f32>>>>, job_rx: Receiver<Job>,
                  bucket_tx: Sender<(usize, Vec<f32>)>,
                  reduced_rx: Receiver<ReducedResult>,
                  result_tx: Sender<RankResult>) {
    // Persistent scratch: micro-step gradient vector and one payload
    // buffer per bucket, recycled every step.
    let mut grads: Vec<f32> = Vec::new();
    let mut bucket_bufs: Vec<Vec<f32>> =
        ranges.iter().map(|b| Vec::with_capacity(b.len())).collect();
    while let Ok(job) = job_rx.recv() {
        let res = run_rank_step(rank, world, ranges, accs, &job, &mut grads,
                                &mut bucket_bufs, &bucket_tx, &reduced_rx);
        let msg = RankResult { rank, res: res.map_err(|e| format!("{e:#}")) };
        if result_tx.send(msg).is_err() {
            break;
        }
    }
}

/// Copy a bucket's accumulated slice into its reusable payload buffer and
/// hand it to the comm worker.
fn send_bucket(idx: usize, src: &[f32], slot: &mut Vec<f32>,
               tx: &Sender<(usize, Vec<f32>)>) -> Result<()> {
    let mut v = std::mem::take(slot);
    v.clear();
    v.extend_from_slice(src);
    tx.send((idx, v))
        .map_err(|_| anyhow::anyhow!("comm worker gone (bucket {idx})"))
}

#[allow(clippy::too_many_arguments)]
fn run_rank_step(rank: usize, world: usize, ranges: &[BucketRange],
                 accs: &[Mutex<Vec<f32>>], job: &Job, grads: &mut Vec<f32>,
                 bucket_bufs: &mut [Vec<f32>],
                 bucket_tx: &Sender<(usize, Vec<f32>)>,
                 reduced_rx: &Receiver<ReducedResult>) -> Result<RankStats> {
    let mut acc = accs[rank].lock().expect("rank buffer poisoned");
    acc.fill(0.0);
    let mut stats = RankStats::default();
    let k = job.micro_steps.max(1);
    // On any failure we still complete the exchange protocol below so
    // peer ranks blocked in the exchange are released; the error is
    // reported after.
    let mut failure: Option<anyhow::Error> = None;
    // Buckets actually handed to the comm worker so far.  The reply loop
    // below awaits exactly this many `Reduced` messages — never the full
    // bucket count — so a partial eager send can't leave this rank
    // waiting for replies its comm worker will never produce.
    let mut sent = 0usize;
    for micro in 0..k {
        let t0 = Instant::now();
        // Catch panics from the user-supplied compute, not just Errs:
        // a vanished rank would otherwise desynchronize the exchange and
        // hang every peer (and `step()`) forever.  A caught panic takes
        // the same still-complete-the-exchange path as an Err.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || job.compute.micro(rank, job.step_index, micro, job.params,
                                 job.scale, grads),
        ));
        let m = match caught {
            Ok(Ok(m)) => m,
            Ok(Err(e)) => {
                failure = Some(e);
                break;
            }
            Err(payload) => {
                let what = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic".to_string());
                failure = Some(anyhow::anyhow!(
                    "compute panicked at micro {micro}: {what}"
                ));
                break;
            }
        };
        stats.compute_s += t0.elapsed().as_secs_f64();
        if grads.len() != acc.len() {
            failure = Some(anyhow::anyhow!(
                "micro-step produced {} grads, buffer holds {}",
                grads.len(), acc.len()
            ));
            break;
        }
        stats.loss_sum += m.loss;
        stats.mlm_sum += m.mlm_loss;
        stats.nsp_sum += m.nsp_loss;
        stats.acc_sum += m.mlm_acc;
        stats.nonfinite |= m.nonfinite;
        stats.input_stall_s += m.input_stall_s;
        let t1 = Instant::now();
        if micro + 1 < k {
            // Not the last micro-step: plain full-range accumulation.
            for (a, g) in acc.iter_mut().zip(grads.iter()) {
                *a += *g;
            }
            stats.accum_s += t1.elapsed().as_secs_f64();
        } else {
            // Final micro-step: accumulate bucket-by-bucket in backward
            // order; with overlap on, enqueue each bucket's exchange the
            // moment its accumulation completes (Fig. 2).
            for (idx, br) in ranges.iter().enumerate() {
                let tb = Instant::now();
                let (seg, gseg) = (&mut acc[br.start..br.end],
                                   &grads[br.start..br.end]);
                for (a, g) in seg.iter_mut().zip(gseg.iter()) {
                    *a += *g;
                }
                stats.accum_s += tb.elapsed().as_secs_f64();
                if world > 1 && job.overlap {
                    match send_bucket(idx, &acc[br.start..br.end],
                                      &mut bucket_bufs[idx], bucket_tx) {
                        Ok(()) => sent += 1,
                        Err(e) => {
                            failure = Some(e);
                            break;
                        }
                    }
                }
            }
        }
    }
    if world > 1 && !ranges.is_empty() {
        // Feed every bucket not already enqueued: the barrier schedule
        // feeds all of them here; the failure paths (compute error, or a
        // send failure partway through the eager loop) feed the
        // remainder with whatever is accumulated, so peer ranks'
        // exchanges stay in lockstep instead of stranding mid-protocol.
        for idx in sent..ranges.len() {
            let br = ranges[idx];
            match send_bucket(idx, &acc[br.start..br.end],
                              &mut bucket_bufs[idx], bucket_tx) {
                Ok(()) => sent += 1,
                Err(e) => {
                    failure = failure.or(Some(e));
                    break;
                }
            }
        }
        stats.bucket_s = vec![0.0; ranges.len()];
        stats.bucket_pcie_s = vec![0.0; ranges.len()];
        stats.bucket_net_s = vec![0.0; ranges.len()];
        // Await exactly the replies our comm worker owes us.  Exposed
        // communication is the pure time spent BLOCKED in recv — the
        // copy-back of reduced data and the loop bookkeeping are real
        // work, not exposed exchange, and counting them used to push the
        // overlap ratio negative.
        for i in 0..sent {
            let tw = Instant::now();
            let red = match reduced_rx.recv() {
                Ok(Ok(r)) => r,
                Ok(Err(msg)) => {
                    // The comm worker named the transport failure (a
                    // remote peer disconnect or timeout) before exiting.
                    failure = failure.or_else(|| {
                        Some(anyhow::anyhow!("exchange failed: {msg}"))
                    });
                    break;
                }
                Err(_) => {
                    failure = failure.or_else(|| {
                        Some(anyhow::anyhow!("comm worker gone mid-exchange"))
                    });
                    break;
                }
            };
            stats.exposed_comm_s += tw.elapsed().as_secs_f64();
            debug_assert_eq!(red.idx, i, "bucket reply out of order");
            let br = ranges[red.idx];
            acc[br.start..br.end].copy_from_slice(&red.data);
            let pcie_s = (red.exchange_s - red.net_s).max(0.0);
            stats.bucket_s[red.idx] = red.exchange_s;
            stats.bucket_pcie_s[red.idx] = pcie_s;
            stats.bucket_net_s[red.idx] = red.net_s;
            stats.comm_s += red.exchange_s;
            stats.comm_pcie_s += pcie_s;
            stats.comm_net_s += red.net_s;
            stats.net_backpressure_s += red.backpressure_s;
            bucket_bufs[red.idx] = red.data;
        }
    }
    drop(acc);
    match failure {
        Some(e) => Err(e),
        None => Ok(stats),
    }
}

// -------------------------------------------------------- comm worker --

/// Dispatch a comm worker into its role-specific loop.  Every role
/// processes buckets strictly in the order its compute worker sends
/// them, so `Reduced` replies arrive in bucket order.
///
/// Failure policy (the transport refactor's contract): an error on a
/// link whose peer lives in THIS process is tolerated where the old
/// channel wiring tolerated it — the dead peer's own rank reports the
/// failure, so the protocol keeps moving.  An error on a **remote**
/// link always propagates as an `Err` on the reduced channel (then the
/// worker exits): the dead peer's process cannot report anything here,
/// and tolerating it would silently drop its gradients from the sum.
fn comm_worker(wire: WireFormat, ranges: &[BucketRange],
               bucket_rx: Receiver<(usize, Vec<f32>)>,
               reduced_tx: Sender<ReducedResult>, endpoints: CommEndpoints,
               mut sparse: SparseCtx) {
    match endpoints {
        CommEndpoints::Flat { rank, ring_size, net, tx_next, rx_prev } => {
            flat_comm_loop(rank, ring_size, wire, net, ranges, bucket_rx,
                           reduced_tx, tx_next, rx_prev, &mut sparse);
        }
        CommEndpoints::Leader { machine, machines, member_rxs, member_txs,
                                tx_next, rx_prev } => {
            leader_comm_loop(machine, machines, wire, ranges, bucket_rx,
                             reduced_tx, member_rxs, member_txs, tx_next,
                             rx_prev, &mut sparse);
        }
        CommEndpoints::Member { to_leader, from_leader } => {
            member_comm_loop(bucket_rx, reduced_tx, to_leader, from_leader);
        }
        CommEndpoints::ChainLeader { machine, machines, chunk_elems, up_rx,
                                     down_tx, tx_next, rx_prev } => {
            chain_leader_comm_loop(machine, machines, wire, chunk_elems,
                                   ranges, bucket_rx, reduced_tx, up_rx,
                                   down_tx, tx_next, rx_prev, &mut sparse);
        }
        CommEndpoints::ChainMember { chunk_elems, up_rx, up_tx, down_rx,
                                     down_tx } => {
            chain_member_comm_loop(chunk_elems, bucket_rx, reduced_tx,
                                   up_rx, up_tx, down_rx, down_tx);
        }
        CommEndpoints::RsNode { machine, machines, gpus, local, intra_tx,
                                intra_rx, cross_tx, cross_rx } => {
            rs_comm_loop(machine, machines, gpus, local, wire, ranges,
                         bucket_rx, reduced_tx, intra_tx, intra_rx,
                         cross_tx, cross_rx, &mut sparse);
        }
    }
}

/// Flat world-sized ring (the PR-1 schedule).
#[allow(clippy::too_many_arguments)]
fn flat_comm_loop(rank: usize, ring_size: usize, wire: WireFormat,
                  net: bool, ranges: &[BucketRange],
                  bucket_rx: Receiver<(usize, Vec<f32>)>,
                  reduced_tx: Sender<ReducedResult>,
                  mut tx_next: Box<dyn FrameTx>,
                  mut rx_prev: Box<dyn FrameRx>,
                  sparse: &mut SparseCtx) {
    // Chunk plans are a pure function of (ring size, bucket length):
    // build them once and reuse forever.
    let plans: Vec<RingPlan> = ranges
        .iter()
        .map(|b| RingPlan::new(ring_size, b.len()))
        .collect();
    // The payload pool recycles wire buffers: every exchange sends and
    // receives the same number of chunks, so after the first step the
    // pool is self-sustaining (steady-state zero allocation).
    let mut pool = PayloadPool::default();
    while let Ok((idx, mut data)) = bucket_rx.recv() {
        let t0 = Instant::now();
        if ring_size > 1 {
            // The flat ring is a network ring exactly when the topology
            // spans machines (the same condition that activates
            // sparsification in the pool constructor).
            if let Err(e) = sparse.net_exchange(&mut data,
                                                ranges[idx].start,
                                                &plans[idx], rank, wire,
                                                tx_next.as_mut(),
                                                rx_prev.as_mut(),
                                                &mut pool) {
                let _ = reduced_tx.send(Err(format!(
                    "ring peer lost on bucket {idx}: {e}"
                )));
                break;
            }
        }
        let exchange_s = t0.elapsed().as_secs_f64();
        // A flat ring on a multi-machine (or multi-process) topology is
        // paced by its network hops (paper §3.2), so the whole exchange
        // bills to the network; within one node it is all PCIe.
        let net_s = if net { exchange_s } else { 0.0 };
        let backpressure_s = tx_next.take_backpressure_s();
        if reduced_tx
            .send(Ok(Reduced { idx, data, exchange_s, net_s,
                               backpressure_s }))
            .is_err()
        {
            break;
        }
    }
}

/// Hierarchical node leader: gather (PCIe) -> leader ring (network) ->
/// broadcast (PCIe).
#[allow(clippy::too_many_arguments)]
fn leader_comm_loop(machine: usize, machines: usize, wire: WireFormat,
                    ranges: &[BucketRange],
                    bucket_rx: Receiver<(usize, Vec<f32>)>,
                    reduced_tx: Sender<ReducedResult>,
                    mut member_rxs: Vec<Box<dyn FrameRx>>,
                    mut member_txs: Vec<Box<dyn FrameTx>>,
                    mut tx_next: Box<dyn FrameTx>,
                    mut rx_prev: Box<dyn FrameRx>,
                    sparse: &mut SparseCtx) {
    // Leader-ring chunk plans at size `machines` — a pure function of
    // (machines, bucket length), built once and reused forever.
    let plans: Vec<RingPlan> = ranges
        .iter()
        .map(|b| RingPlan::new(machines, b.len()))
        .collect();
    let mut pool = PayloadPool::default();
    // Member payload vectors parked between gather and broadcast — the
    // broadcast copies are written into these, so the steady-state step
    // allocates nothing.
    let mut parked: Vec<Vec<f32>> = Vec::with_capacity(member_rxs.len());
    'buckets: while let Ok((idx, mut data)) = bucket_rx.recv() {
        let t0 = Instant::now();
        // Phase 1 — intra-node leader accumulate ("PCIe"): add each
        // member's bucket in fixed local-rank order (1, 2, … g-1) so the
        // node sum is deterministic.
        parked.clear();
        for rx in member_rxs.iter_mut() {
            match rx.recv(&mut pool) {
                Ok(Frame::Bucket { idx: midx, data: mv }) => {
                    // Skewed or short member payloads are a real protocol
                    // error, not a debug assert: a release build that
                    // summed the wrong bucket (or let the `zip` truncate)
                    // would corrupt the gradients silently.
                    if midx as usize != idx {
                        let _ = reduced_tx.send(Err(format!(
                            "member bucket skew: got bucket {midx}, \
                             expected {idx}"
                        )));
                        break 'buckets;
                    }
                    if mv.len() != data.len() {
                        let _ = reduced_tx.send(Err(format!(
                            "member payload length skew on bucket {idx}: \
                             got {} elems, expected {}",
                            mv.len(), data.len()
                        )));
                        break 'buckets;
                    }
                    for (d, s) in data.iter_mut().zip(mv.iter()) {
                        *d += *s;
                    }
                    parked.push(mv);
                }
                Ok(other) => {
                    pool.recycle(other);
                    let _ = reduced_tx.send(Err(format!(
                        "unexpected frame in member gather (bucket {idx})"
                    )));
                    break 'buckets;
                }
                Err(e) if rx.remote() => {
                    let _ = reduced_tx.send(Err(format!(
                        "node member lost mid-gather (bucket {idx}): {e}"
                    )));
                    break 'buckets;
                }
                Err(_) => {
                    // In-process member comm worker died; its own rank
                    // reports the failure — keep the protocol moving.
                }
            }
        }
        // Phase 2 — inter-node ring allreduce over the leaders only
        // ("network"): the §4.4 move that caps per-NIC traffic at
        // 2(M-1)/M of the payload.
        let tn = Instant::now();
        if let Err(e) = sparse.net_exchange(&mut data, ranges[idx].start,
                                            &plans[idx], machine, wire,
                                            tx_next.as_mut(),
                                            rx_prev.as_mut(), &mut pool) {
            let _ = reduced_tx.send(Err(format!(
                "leader ring peer lost on bucket {idx}: {e}"
            )));
            break 'buckets;
        }
        let net_s = tn.elapsed().as_secs_f64();
        // Phase 3 — intra-node broadcast ("PCIe"), recycling the parked
        // member vectors as the broadcast payloads.
        for tx in member_txs.iter_mut() {
            let mut buf = parked.pop().unwrap_or_default();
            buf.clear();
            buf.extend_from_slice(&data);
            let frame = Frame::Bcast { idx: idx as u32, net_s, data: buf };
            if let Err(e) = tx.send(frame, &mut pool) {
                if tx.remote() {
                    let _ = reduced_tx.send(Err(format!(
                        "node member lost mid-broadcast (bucket {idx}): {e}"
                    )));
                    break 'buckets;
                }
                // A dead in-process member is its own rank's failure.
            }
        }
        let exchange_s = t0.elapsed().as_secs_f64();
        let backpressure_s = tx_next.take_backpressure_s()
            + member_txs
                .iter_mut()
                .map(|tx| tx.take_backpressure_s())
                .sum::<f64>();
        if reduced_tx
            .send(Ok(Reduced { idx, data, exchange_s, net_s,
                               backpressure_s }))
            .is_err()
        {
            break;
        }
    }
}

/// Chunked pipelined node leader ([`IntraNodeMode::Ring`]): per chunk,
/// one pre-reduced partial arrives from the chain head (local rank 1,
/// already summing every tail-ward member), the chunk rings over the
/// other node leaders, and the reduced chunk goes back down the chain —
/// so the network starts on chunk 0 while the chain is still gathering
/// chunk 1, and the leader's own work per bucket drops from `(g-1)`
/// whole-bucket adds + copies to ONE add + ONE copy.
#[allow(clippy::too_many_arguments)]
fn chain_leader_comm_loop(machine: usize, machines: usize,
                          wire: WireFormat, chunk_elems: usize,
                          ranges: &[BucketRange],
                          bucket_rx: Receiver<(usize, Vec<f32>)>,
                          reduced_tx: Sender<ReducedResult>,
                          mut up_rx: Box<dyn FrameRx>,
                          mut down_tx: Box<dyn FrameTx>,
                          mut tx_next: Box<dyn FrameTx>,
                          mut rx_prev: Box<dyn FrameRx>,
                          sparse: &mut SparseCtx) {
    // Per-bucket chunk tables (range + leader-ring plan per chunk): a
    // pure function of (machines, bucket length, chunk_elems), built
    // once and reused forever.
    let chunk_plans: Vec<Vec<(std::ops::Range<usize>, RingPlan)>> = ranges
        .iter()
        .map(|b| {
            (0..num_chunks(b.len(), chunk_elems))
                .map(|c| {
                    let span = chunk_span(b.len(), chunk_elems, c);
                    let plan = RingPlan::new(machines, span.len());
                    (span, plan)
                })
                .collect()
        })
        .collect();
    let mut pool = PayloadPool::default();
    'buckets: while let Ok((idx, mut data)) = bucket_rx.recv() {
        let t0 = Instant::now();
        let mut net_s = 0.0f64;
        for (c, (span, plan)) in chunk_plans[idx].iter().enumerate() {
            // The gather payload parked across the ring phase: its
            // vector becomes this chunk's broadcast buffer, so the
            // steady-state step allocates nothing.
            let mut parked: Option<Vec<f32>> = None;
            // Phase 1 — chunk gather ("PCIe"): the chain already summed
            // local ranks g-1 .. 1 into this partial; adding our slice
            // completes the node sum for the chunk.
            match up_rx.recv(&mut pool) {
                Ok(Frame::Chunk { idx: midx, chunk: mc, data: mv, .. }) => {
                    if (midx as usize, mc as usize) != (idx, c) {
                        let _ = reduced_tx.send(Err(format!(
                            "chain chunk skew: got bucket {midx} chunk \
                             {mc}, expected bucket {idx} chunk {c}"
                        )));
                        break 'buckets;
                    }
                    if mv.len() != span.len() {
                        let _ = reduced_tx.send(Err(format!(
                            "chain payload length skew on bucket {idx} \
                             chunk {c}: got {} elems, expected {}",
                            mv.len(), span.len()
                        )));
                        break 'buckets;
                    }
                    for (d, s) in
                        data[span.clone()].iter_mut().zip(mv.iter()) {
                        *d += *s;
                    }
                    parked = Some(mv);
                }
                Ok(other) => {
                    pool.recycle(other);
                    let _ = reduced_tx.send(Err(format!(
                        "unexpected frame in chain gather (bucket {idx})"
                    )));
                    break 'buckets;
                }
                Err(e) if up_rx.remote() => {
                    let _ = reduced_tx.send(Err(format!(
                        "chain head lost mid-gather (bucket {idx} chunk \
                         {c}): {e}"
                    )));
                    break 'buckets;
                }
                Err(_) => {
                    // In-process chain head died; its own rank reports
                    // the failure — keep moving with our partial sum.
                }
            }
            // Phase 2 — inter-node ring on this chunk only ("network"):
            // starts while the chain is still gathering later chunks.
            let tn = Instant::now();
            if let Err(e) = sparse.net_exchange(
                &mut data[span.clone()],
                ranges[idx].start + span.start, plan, machine, wire,
                tx_next.as_mut(), rx_prev.as_mut(), &mut pool) {
                let _ = reduced_tx.send(Err(format!(
                    "leader ring peer lost on bucket {idx} chunk {c}: {e}"
                )));
                break 'buckets;
            }
            let chunk_net_s = tn.elapsed().as_secs_f64();
            net_s += chunk_net_s;
            // Phase 3 — chunk broadcast down the chain ("PCIe"),
            // recycling the parked gather payload.
            let mut buf = parked.unwrap_or_default();
            buf.clear();
            buf.extend_from_slice(&data[span.clone()]);
            let frame = Frame::Chunk {
                idx: idx as u32,
                chunk: c as u32,
                net_s: chunk_net_s,
                data: buf,
            };
            if let Err(e) = down_tx.send(frame, &mut pool) {
                if down_tx.remote() {
                    let _ = reduced_tx.send(Err(format!(
                        "chain head lost mid-broadcast (bucket {idx} chunk \
                         {c}): {e}"
                    )));
                    break 'buckets;
                }
                // A dead in-process chain is its own ranks' failure.
            }
        }
        let exchange_s = t0.elapsed().as_secs_f64();
        let backpressure_s =
            tx_next.take_backpressure_s() + down_tx.take_backpressure_s();
        if reduced_tx
            .send(Ok(Reduced { idx, data, exchange_s, net_s,
                               backpressure_s }))
            .is_err()
        {
            break;
        }
    }
}

/// Chunked pipelined node member: reduce-forward chunks toward the
/// leader (fixed tail-to-head order, so the node sum stays
/// deterministic: `leader + (m1 + (m2 + ... + m_{g-1}))` elementwise),
/// then copy-forward the reduced chunks away from it.  Each member's
/// sends ride its own link concurrently with every other member's —
/// the serialized leader port of [`IntraNodeMode::Serial`] is gone.
fn chain_member_comm_loop(chunk_elems: usize,
                          bucket_rx: Receiver<(usize, Vec<f32>)>,
                          reduced_tx: Sender<ReducedResult>,
                          mut up_rx: Option<Box<dyn FrameRx>>,
                          mut up_tx: Box<dyn FrameTx>,
                          mut down_rx: Box<dyn FrameRx>,
                          mut down_tx: Option<Box<dyn FrameTx>>) {
    // Chunk payloads recycle through the pool: primed by the first
    // bucket, then self-sustaining (up-pass takes are balanced by
    // received partials on inner members and by the down pass at the
    // chain tail).
    let mut pool = PayloadPool::default();
    'buckets: while let Ok((idx, mut data)) = bucket_rx.recv() {
        let t0 = Instant::now();
        let len = data.len();
        let nchunks = num_chunks(len, chunk_elems);
        // Up pass — reduce-forward toward the leader.
        for c in 0..nchunks {
            let span = chunk_span(len, chunk_elems, c);
            let mut buf = pool.take_f32();
            buf.extend_from_slice(&data[span]);
            if let Some(rx) = up_rx.as_mut() {
                match rx.recv(&mut pool) {
                    Ok(Frame::Chunk { idx: midx, chunk: mc,
                                      data: mv, .. }) => {
                        if (midx as usize, mc as usize) != (idx, c) {
                            let _ = reduced_tx.send(Err(format!(
                                "chain chunk skew: got bucket {midx} \
                                 chunk {mc}, expected bucket {idx} chunk \
                                 {c}"
                            )));
                            break 'buckets;
                        }
                        if mv.len() != buf.len() {
                            let _ = reduced_tx.send(Err(format!(
                                "chain payload length skew on bucket \
                                 {idx} chunk {c}: got {} elems, expected \
                                 {}", mv.len(), buf.len()
                            )));
                            break 'buckets;
                        }
                        for (d, s) in buf.iter_mut().zip(mv.iter()) {
                            *d += *s;
                        }
                        pool.put_f32(mv);
                    }
                    Ok(other) => {
                        pool.recycle(other);
                        let _ = reduced_tx.send(Err(format!(
                            "unexpected frame in chain gather (bucket \
                             {idx})"
                        )));
                        break 'buckets;
                    }
                    Err(e) if rx.remote() => {
                        let _ = reduced_tx.send(Err(format!(
                            "chain neighbour lost mid-gather (bucket {idx} \
                             chunk {c}): {e}"
                        )));
                        break 'buckets;
                    }
                    Err(_) => {
                        // In-process tail-ward neighbour died (its rank
                        // reports it); forward our partial so the leader
                        // side keeps moving.
                    }
                }
            }
            let frame = Frame::Chunk {
                idx: idx as u32,
                chunk: c as u32,
                net_s: 0.0,
                data: buf,
            };
            if let Err(e) = up_tx.send(frame, &mut pool) {
                let _ = reduced_tx.send(Err(format!(
                    "chain neighbour lost on bucket {idx} chunk {c} \
                     upload: {e}"
                )));
                break 'buckets;
            }
        }
        // Down pass — copy-forward the reduced chunks; the tail keeps
        // the payload vectors for the next bucket's up pass.
        let mut net_s = 0.0f64;
        for c in 0..nchunks {
            let span = chunk_span(len, chunk_elems, c);
            let (mc_net_s, mv) = match down_rx.recv(&mut pool) {
                Ok(Frame::Chunk { idx: midx, chunk: mc, net_s: ns,
                                  data: mv }) => {
                    if (midx as usize, mc as usize) != (idx, c) {
                        let _ = reduced_tx.send(Err(format!(
                            "chain chunk skew: got bucket {midx} chunk \
                             {mc}, expected bucket {idx} chunk {c}"
                        )));
                        break 'buckets;
                    }
                    if mv.len() != span.len() {
                        let _ = reduced_tx.send(Err(format!(
                            "chain payload length skew on bucket {idx} \
                             chunk {c}: got {} elems, expected {}",
                            mv.len(), span.len()
                        )));
                        break 'buckets;
                    }
                    (ns, mv)
                }
                Ok(other) => {
                    pool.recycle(other);
                    let _ = reduced_tx.send(Err(format!(
                        "unexpected frame in chain broadcast (bucket {idx})"
                    )));
                    break 'buckets;
                }
                Err(e) => {
                    let _ = reduced_tx.send(Err(format!(
                        "chain neighbour lost mid-broadcast (bucket {idx} \
                         chunk {c}): {e}"
                    )));
                    break 'buckets;
                }
            };
            data[span].copy_from_slice(&mv);
            net_s += mc_net_s;
            match down_tx.as_mut() {
                Some(tx) => {
                    let frame = Frame::Chunk {
                        idx: idx as u32,
                        chunk: c as u32,
                        net_s: mc_net_s,
                        data: mv,
                    };
                    if let Err(e) = tx.send(frame, &mut pool) {
                        if tx.remote() {
                            let _ = reduced_tx.send(Err(format!(
                                "chain neighbour lost mid-broadcast \
                                 (bucket {idx} chunk {c}): {e}"
                            )));
                            break 'buckets;
                        }
                        // A dead in-process tail is its own rank's
                        // failure.
                    }
                }
                None => pool.put_f32(mv),
            }
        }
        let exchange_s = t0.elapsed().as_secs_f64();
        // The member's wall covers the whole pipeline; the network
        // share is what the leader measured (capped by our wall).
        let net_s = net_s.min(exchange_s);
        let backpressure_s = up_tx.take_backpressure_s()
            + down_tx
                .as_mut()
                .map_or(0.0, |tx| tx.take_backpressure_s());
        if reduced_tx
            .send(Ok(Reduced { idx, data, exchange_s, net_s,
                               backpressure_s }))
            .is_err()
        {
            break;
        }
    }
}

/// Hierarchical node member: one PCIe hop up, one PCIe hop down.
fn member_comm_loop(bucket_rx: Receiver<(usize, Vec<f32>)>,
                    reduced_tx: Sender<ReducedResult>,
                    mut to_leader: Box<dyn FrameTx>,
                    mut from_leader: Box<dyn FrameRx>) {
    let mut pool = PayloadPool::default();
    while let Ok((idx, data)) = bucket_rx.recv() {
        let t0 = Instant::now();
        let bucket_len = data.len();
        let frame = Frame::Bucket { idx: idx as u32, data };
        if let Err(e) = to_leader.send(frame, &mut pool) {
            let _ = reduced_tx.send(Err(format!(
                "node leader lost on bucket {idx} upload: {e}"
            )));
            break;
        }
        let (bnet_s, bdata) = match from_leader.recv(&mut pool) {
            Ok(Frame::Bcast { idx: bidx, net_s, data }) => {
                if bidx as usize != idx {
                    let _ = reduced_tx.send(Err(format!(
                        "broadcast bucket skew: got bucket {bidx}, \
                         expected {idx}"
                    )));
                    break;
                }
                if data.len() != bucket_len {
                    let _ = reduced_tx.send(Err(format!(
                        "broadcast payload length skew on bucket {idx}: \
                         got {} elems, expected {bucket_len}", data.len()
                    )));
                    break;
                }
                (net_s, data)
            }
            Ok(other) => {
                pool.recycle(other);
                let _ = reduced_tx.send(Err(format!(
                    "unexpected frame in leader broadcast (bucket {idx})"
                )));
                break;
            }
            Err(e) => {
                let _ = reduced_tx.send(Err(format!(
                    "node leader lost mid-broadcast (bucket {idx}): {e}"
                )));
                break;
            }
        };
        let exchange_s = t0.elapsed().as_secs_f64();
        // The member's wall covers the whole hierarchy; the network
        // share is whatever the leader measured (capped by our wall).
        let net_s = bnet_s.min(exchange_s);
        let backpressure_s = to_leader.take_backpressure_s();
        if reduced_tx
            .send(Ok(Reduced { idx, data: bdata, exchange_s, net_s,
                               backpressure_s }))
            .is_err()
        {
            break;
        }
    }
}

/// Per-bucket plan for the 2-level reduce-scatter schedule: the
/// intra-node ring plan at size `g`, the shard of the bucket this rank
/// owns after the reduce-scatter (chunk `(local + 1) % g` of the intra
/// plan), and the cross-machine ring plan over that shard at size `m`.
/// A pure function of (topology, local index, bucket length) — built
/// once per comm worker and reused forever.
struct RsPlan {
    intra: RingPlan,
    own: std::ops::Range<usize>,
    cross: RingPlan,
}

/// Bandwidth-optimal NCCL-style 2-level schedule
/// ([`IntraNodeMode::ReduceScatter`]): every rank plays the same role —
/// there is no leader.  Per bucket:
///
/// 1. **intra-node ring reduce-scatter** ("PCIe", always f32): after
///    `g-1` hops this rank owns the node-summed shard `own`
///    (`~1/g` of the bucket — per-link bytes drop from the serialized
///    leader's `O(n)` to `O(n/g)`);
/// 2. **cross-machine ring allreduce over the owned shard only**
///    ("network"; the f16 wire applies here, exactly like the leader
///    ring): the `g` parallel `m`-sized rings together move the same
///    `O(n/g)` per link — and unlike the leader schedule, all `g` NICs'
///    worth of links carry traffic concurrently;
/// 3. **intra-node ring allgather** ("PCIe", f32): every rank
///    broadcasts its globally-reduced shard around the node ring, so
///    all replicas end bitwise identical.
///
/// Shard lengths are a pure function of (g, bucket length), so every
/// machine's ring at a given local index agrees on chunk sizes (and on
/// empty-shard early-returns) without coordination.  All link errors
/// are fatal, like every ring link: a lost peer cannot be summed
/// around.
#[allow(clippy::too_many_arguments)]
fn rs_comm_loop(machine: usize, machines: usize, gpus: usize, local: usize,
                wire: WireFormat, ranges: &[BucketRange],
                bucket_rx: Receiver<(usize, Vec<f32>)>,
                reduced_tx: Sender<ReducedResult>,
                mut intra_tx: Box<dyn FrameTx>,
                mut intra_rx: Box<dyn FrameRx>,
                mut cross_tx: Box<dyn FrameTx>,
                mut cross_rx: Box<dyn FrameRx>,
                sparse: &mut SparseCtx) {
    let plans: Vec<RsPlan> = ranges
        .iter()
        .map(|b| {
            let intra = RingPlan::new(gpus, b.len());
            let own = intra.chunk((local + 1) % gpus);
            let cross = RingPlan::new(machines, own.len());
            RsPlan { intra, own, cross }
        })
        .collect();
    let mut pool = PayloadPool::default();
    while let Ok((idx, mut data)) = bucket_rx.recv() {
        let t0 = Instant::now();
        let p = &plans[idx];
        // Phase 1 — intra-node reduce-scatter ("PCIe").
        if let Err(e) = ring_reduce_scatter(&mut data, &p.intra, local,
                                            WireFormat::F32,
                                            intra_tx.as_mut(),
                                            intra_rx.as_mut(), &mut pool) {
            let _ = reduced_tx.send(Err(format!(
                "intra reduce-scatter peer lost on bucket {idx}: {e}"
            )));
            break;
        }
        // Phase 2 — cross-machine ring allreduce over the owned shard
        // only ("network").
        let tn = Instant::now();
        if let Err(e) = sparse.net_exchange(
            &mut data[p.own.clone()], ranges[idx].start + p.own.start,
            &p.cross, machine, wire, cross_tx.as_mut(),
            cross_rx.as_mut(), &mut pool) {
            let _ = reduced_tx.send(Err(format!(
                "cross ring peer lost on bucket {idx}: {e}"
            )));
            break;
        }
        let net_s = tn.elapsed().as_secs_f64();
        // Phase 3 — intra-node allgather ("PCIe").
        if let Err(e) = ring_all_gather(&mut data, &p.intra, local,
                                        WireFormat::F32, intra_tx.as_mut(),
                                        intra_rx.as_mut(), &mut pool) {
            let _ = reduced_tx.send(Err(format!(
                "intra allgather peer lost on bucket {idx}: {e}"
            )));
            break;
        }
        let exchange_s = t0.elapsed().as_secs_f64();
        let backpressure_s = intra_tx.take_backpressure_s()
            + cross_tx.take_backpressure_s();
        if reduced_tx
            .send(Ok(Reduced { idx, data, exchange_s, net_s,
                               backpressure_s }))
            .is_err()
        {
            break;
        }
    }
}

/// In-place ring allreduce (sum) of `buf` across a set of comm workers,
/// using the NCCL reduce-scatter + all-gather schedule from [`RingPlan`]
/// (the flat world ring, or the leader ring at size `machines`).  A
/// link failure (peer disconnect, net timeout) returns the transport's
/// error instead of panicking, so the caller can name the bucket and
/// surface it on the reduced channel.
fn ring_exchange(buf: &mut [f32], plan: &RingPlan, rank: usize,
                 wire: WireFormat, tx: &mut dyn FrameTx,
                 rx: &mut dyn FrameRx, pool: &mut PayloadPool)
                 -> std::result::Result<(), TransportError> {
    ring_reduce_scatter(buf, plan, rank, wire, tx, rx, pool)?;
    ring_all_gather(buf, plan, rank, wire, tx, rx, pool)
}

/// The reduce-scatter half of the ring schedule: after `n-1` hops rank
/// `r` owns the fully-summed chunk `(r + 1) % n` (tags `0..n-1`).  The
/// 2-level schedule runs this alone at node scope; [`ring_exchange`]
/// composes it with [`ring_all_gather`].
fn ring_reduce_scatter(buf: &mut [f32], plan: &RingPlan, rank: usize,
                       wire: WireFormat, tx: &mut dyn FrameTx,
                       rx: &mut dyn FrameRx, pool: &mut PayloadPool)
                       -> std::result::Result<(), TransportError> {
    let n = plan.n;
    if n <= 1 || buf.is_empty() {
        return Ok(());
    }
    for s in 0..n - 1 {
        let sc = plan.chunk(plan.send_chunk_rs(rank, s));
        send_wire(&buf[sc], s as u32, wire, tx, pool)?;
        let rc = plan.chunk(plan.recv_chunk_rs(rank, s));
        recv_apply(&mut buf[rc], s as u32, true, rx, pool)?;
    }
    Ok(())
}

/// The all-gather half of the ring schedule (tags `100..100+n-1`):
/// circulates each rank's owned chunk until every rank holds all of
/// them.  Assumes the owned chunks are already reduced — the 2-level
/// schedule calls this after its cross-machine rings finish.
fn ring_all_gather(buf: &mut [f32], plan: &RingPlan, rank: usize,
                   wire: WireFormat, tx: &mut dyn FrameTx,
                   rx: &mut dyn FrameRx, pool: &mut PayloadPool)
                   -> std::result::Result<(), TransportError> {
    let n = plan.n;
    if n <= 1 || buf.is_empty() {
        return Ok(());
    }
    if wire == WireFormat::F16 {
        // Quantize the fully-reduced chunk this rank owns before the
        // all-gather: every replica then holds f16-representable values
        // and stays bitwise identical (f16 round-trip is idempotent).
        let own = plan.chunk((rank + 1) % n);
        for v in buf[own].iter_mut() {
            *v = F16::from_f32(*v).to_f32();
        }
    }
    for s in 0..n - 1 {
        let sc = plan.chunk(plan.send_chunk_ag(rank, s));
        send_wire(&buf[sc], 100 + s as u32, wire, tx, pool)?;
        let rc = plan.chunk(plan.recv_chunk_ag(rank, s));
        recv_apply(&mut buf[rc], 100 + s as u32, false, rx, pool)?;
    }
    Ok(())
}

fn send_wire(src: &[f32], tag: u32, wire: WireFormat, tx: &mut dyn FrameTx,
             pool: &mut PayloadPool)
             -> std::result::Result<(), TransportError> {
    let frame = match wire {
        WireFormat::F32 => {
            let mut v = pool.take_f32();
            v.extend_from_slice(src);
            Frame::RingF32 { tag, data: v }
        }
        WireFormat::F16 => {
            let mut v = pool.take_u16();
            quantize_f16(src, &mut v);
            Frame::RingF16 { tag, data: v }
        }
    };
    tx.send(frame, pool)
}

/// Receive one ring hop and either reduce-add (`add = true`) or copy it
/// into `dst`; the payload vector goes back on the pool.  A tag
/// mismatch OR a payload-length mismatch is a hard protocol error: a
/// desynchronized peer would corrupt the sum silently, and a truncated
/// payload would silently leave the tail of the chunk unreduced (the
/// `zip` below stops at the shorter side).
fn recv_apply(dst: &mut [f32], tag: u32, add: bool, rx: &mut dyn FrameRx,
              pool: &mut PayloadPool)
              -> std::result::Result<(), TransportError> {
    match rx.recv(pool)? {
        Frame::RingF32 { tag: t, data: v } => {
            if t != tag {
                return Err(TransportError::Protocol(format!(
                    "ring schedule skew: got tag {t}, expected {tag}"
                )));
            }
            if v.len() != dst.len() {
                return Err(TransportError::Protocol(format!(
                    "ring payload length skew: got {} elems, chunk holds \
                     {} (tag {tag})", v.len(), dst.len()
                )));
            }
            if add {
                for (d, s) in dst.iter_mut().zip(v.iter()) {
                    *d += *s;
                }
            } else {
                dst.copy_from_slice(&v);
            }
            pool.put_f32(v);
        }
        Frame::RingF16 { tag: t, data: v } => {
            if t != tag {
                return Err(TransportError::Protocol(format!(
                    "ring schedule skew: got tag {t}, expected {tag}"
                )));
            }
            if v.len() != dst.len() {
                return Err(TransportError::Protocol(format!(
                    "ring payload length skew: got {} elems, chunk holds \
                     {} (tag {tag})", v.len(), dst.len()
                )));
            }
            if add {
                for (d, b) in dst.iter_mut().zip(v.iter()) {
                    *d += F16(*b).to_f32();
                }
            } else {
                for (d, b) in dst.iter_mut().zip(v.iter()) {
                    *d = F16(*b).to_f32();
                }
            }
            pool.put_u16(v);
        }
        other => {
            pool.recycle(other);
            return Err(TransportError::Protocol(
                "unexpected frame kind on ring link".into(),
            ));
        }
    }
    Ok(())
}

// --------------------------------------------------- sparse exchange --

/// Reusable scratch for the sparse exchange: the top-k selection order
/// and one parked message slot per ring peer.  Owned by each comm
/// worker — primed on the first sparse bucket, then steady-state
/// allocation-free (message index/value buffers recycle through the
/// [`PayloadPool`]).
#[derive(Default)]
struct SparseScratch {
    order: Vec<u32>,
    msgs: Vec<Option<(Vec<u32>, Vec<f32>)>>,
}

/// Per-comm-worker sparsification context: the resolved top-k ratio
/// (`None` = dense wire on every link) and this rank's error-feedback
/// residual, indexed by global flat element offset.
struct SparseCtx {
    ratio: Option<f64>,
    rank: usize,
    ef: Arc<Vec<Mutex<Vec<f32>>>>,
    scratch: SparseScratch,
}

impl SparseCtx {
    /// Run the NETWORK ring exchange for `buf`, whose first element
    /// lives at global flat offset `at`: the sparse top-k allgather
    /// when sparsification is active, the dense ring allreduce
    /// otherwise.  Callers only route network-crossing rings here —
    /// PCIe-class intra-node links always stay dense.
    #[allow(clippy::too_many_arguments)]
    fn net_exchange(&mut self, buf: &mut [f32], at: usize, plan: &RingPlan,
                    ring_rank: usize, wire: WireFormat,
                    tx: &mut dyn FrameTx, rx: &mut dyn FrameRx,
                    pool: &mut PayloadPool)
                    -> std::result::Result<(), TransportError> {
        match self.ratio {
            None => ring_exchange(buf, plan, ring_rank, wire, tx, rx, pool),
            Some(ratio) => {
                let mut res = self.ef[self.rank]
                    .lock()
                    .expect("ef residual poisoned");
                sparse_exchange(buf, &mut res[at..at + buf.len()], plan.n,
                                ring_rank, ratio, wire, tx, rx, pool,
                                &mut self.scratch)
            }
        }
    }
}

/// Sparse top-k ring exchange (`train.sparsify = topk(ratio)`): the
/// lossy-compression counterpart of [`ring_exchange`] for
/// network-crossing rings.  Top-k does not commute with reduce-scatter
/// (summing two sparse messages densifies them), so the schedule is an
/// **allgather of sparse messages**: each of the `n` ring members folds
/// its error-feedback residual into its segment, selects the top
/// `k = max(1, ceil(ratio * len))` coordinates by magnitude, and
/// circulates the (index, value) message `n-1` hops (tags
/// `200..200+n-1`).  Every member then reconstructs the SAME sum —
/// `Σ over origins 0..n of densify(msg)` in fixed origin order — so
/// replicas stay bitwise identical on either transport.  The dropped
/// mass stays in `res` and rides into the next step (error feedback).
///
/// With the f16 wire the selected values are rounded through [`F16`]
/// before the send (they still ship as f32 — 8B per entry either way)
/// and the quantization error joins the residual.
///
/// `ratio = 1.0` sends every coordinate: the reconstruction equals the
/// rank-ordered dense sum and the residual stays zero, which is what
/// lets the property wall compare it bitwise against the dense path on
/// exactly-representable gradients.
#[allow(clippy::too_many_arguments)]
fn sparse_exchange(buf: &mut [f32], res: &mut [f32], n: usize, rank: usize,
                   ratio: f64, wire: WireFormat, tx: &mut dyn FrameTx,
                   rx: &mut dyn FrameRx, pool: &mut PayloadPool,
                   scratch: &mut SparseScratch)
                   -> std::result::Result<(), TransportError> {
    let len = buf.len();
    if n <= 1 || len == 0 {
        return Ok(());
    }
    debug_assert_eq!(res.len(), len, "residual segment skew");
    // 1. Error feedback: fold the mass dropped by earlier steps back in.
    for (b, r) in buf.iter_mut().zip(res.iter()) {
        *b += *r;
    }
    // 2. Top-k select into pool-recycled buffers (growth floor: at
    //    least one entry, so every hop always carries a frame).
    let k = ((ratio * len as f64).ceil() as usize).clamp(1, len);
    let mut idx = pool.take_u32();
    let mut val = pool.take_f32();
    top_k_into(buf, k, &mut scratch.order, &mut idx, &mut val);
    // 3. The f16 wire rounds the survivors exactly like the dense
    //    all-gather rounds owned chunks (idempotent round-trip, so
    //    replicas agree); the rounding error joins the residual below.
    if wire == WireFormat::F16 {
        for v in val.iter_mut() {
            *v = F16::from_f32(*v).to_f32();
        }
    }
    // 4. residual = corrected - sent: zero at the surviving indices on
    //    the f32 wire, the quantization error there on the f16 wire,
    //    the full corrected value everywhere else.
    res.copy_from_slice(buf);
    for (&i, &v) in idx.iter().zip(val.iter()) {
        res[i as usize] -= v;
    }
    // 5. Allgather: hop `s` forwards the message that originated at
    //    ring member `(rank - s) mod n` and receives the one from
    //    `(rank - s - 1) mod n`; messages park in origin-indexed slots
    //    until all `n` arrived.
    if scratch.msgs.len() < n {
        scratch.msgs.resize_with(n, || None);
    }
    scratch.msgs[rank] = Some((idx, val));
    for s in 0..n - 1 {
        let send_origin = (rank + n - s) % n;
        let (sidx, sval) = scratch.msgs[send_origin]
            .as_ref()
            .expect("sparse allgather slot empty (schedule bug)");
        // Sends consume their buffers (in-proc frames move), so the
        // parked copy forwards through fresh pool buffers.
        let mut fidx = pool.take_u32();
        fidx.extend_from_slice(sidx);
        let mut fval = pool.take_f32();
        fval.extend_from_slice(sval);
        let tag = 200 + s as u32;
        tx.send(Frame::Sparse { tag, n: len as u32, indices: fidx,
                                values: fval }, pool)?;
        let recv_origin = (rank + n - s - 1) % n;
        scratch.msgs[recv_origin] = Some(recv_sparse(tag, len, rx, pool)?);
    }
    // 6. Reconstruct the sum in fixed origin order 0..n — identical on
    //    every rank and every transport.
    buf.fill(0.0);
    for slot in scratch.msgs.iter_mut() {
        let (idx, val) = slot.take().expect("sparse allgather hole");
        for (&i, &v) in idx.iter().zip(val.iter()) {
            buf[i as usize] += v;
        }
        pool.put_u32(idx);
        pool.put_f32(val);
    }
    Ok(())
}

/// Receive one sparse allgather hop, with the loud-fail checks both
/// transports share: schedule tag, dense dimension, index/value
/// parallelism, and index bounds — each a named protocol error, because
/// a corrupt sparse frame applied silently would scatter garbage into
/// the gradient sum (or out of the segment entirely).
fn recv_sparse(tag: u32, len: usize, rx: &mut dyn FrameRx,
               pool: &mut PayloadPool)
               -> std::result::Result<(Vec<u32>, Vec<f32>), TransportError> {
    let (t, n, indices, values) = match rx.recv(pool)? {
        Frame::Sparse { tag: t, n, indices, values } => {
            (t, n, indices, values)
        }
        other => {
            pool.recycle(other);
            return Err(TransportError::Protocol(
                "unexpected frame kind on sparse ring link".into(),
            ));
        }
    };
    let err = if t != tag {
        Some(format!("sparse schedule skew: got tag {t}, expected {tag}"))
    } else if n as usize != len {
        Some(format!(
            "sparse payload dimension skew: message addresses {n} elems, \
             segment holds {len} (tag {tag})"
        ))
    } else if indices.len() != values.len() {
        Some(format!(
            "sparse index/value length skew: {} indices vs {} values \
             (tag {tag})",
            indices.len(),
            values.len()
        ))
    } else if let Some(&bad) =
        indices.iter().find(|&&i| i as usize >= len)
    {
        Some(format!(
            "sparse index out of bounds: index {bad} >= segment {len} \
             (tag {tag})"
        ))
    } else {
        None
    };
    if let Some(msg) = err {
        pool.put_u32(indices);
        pool.put_f32(values);
        return Err(TransportError::Protocol(msg));
    }
    Ok((indices, values))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    /// Deterministic synthetic gradients: f(rank, step, micro, i).  All
    /// values are multiples of 0.25 with small magnitude, so every
    /// partial sum over any association is exactly representable in f32
    /// — which is what lets the hierarchical and flat schedules be
    /// compared BITWISE below.
    struct Synth {
        n: usize,
    }

    impl RankCompute for Synth {
        fn micro(&self, rank: usize, step_index: usize, micro: usize,
                 _params: &[f32], _scale: f32, out: &mut Vec<f32>)
                 -> Result<MicroStats> {
            out.resize(self.n, 0.0);
            for (i, v) in out.iter_mut().enumerate() {
                *v = (rank * 1000 + step_index * 100 + micro * 10) as f32
                    + (i % 13) as f32 * 0.25;
            }
            Ok(MicroStats { loss: 1.0, ..Default::default() })
        }
    }

    fn full_ranges(n: usize, pieces: usize) -> Arc<[BucketRange]> {
        BucketRange::even_split(n, pieces)
    }

    /// Serial oracle for the synthetic compute: sum over ranks & micros.
    fn expected(world: usize, n: usize, step_index: usize, k: usize)
                -> Vec<f32> {
        let mut want = vec![0.0f32; n];
        let synth = Synth { n };
        let mut g = Vec::new();
        for r in 0..world {
            for m in 0..k {
                synth.micro(r, step_index, m, &[], 1.0, &mut g).unwrap();
                for (w, x) in want.iter_mut().zip(&g) {
                    *w += *x;
                }
            }
        }
        want
    }

    #[test]
    fn pooled_step_sums_across_ranks_and_micros() {
        let (world, n, k) = (3, 157, 2);
        let ranges = full_ranges(n, 2);
        let mut pool =
            CollectivePool::new(world, n, ranges, WireFormat::F32);
        let synth = Synth { n };
        let out = pool.step(&[], 1.0, k, 7, true, &synth).unwrap();
        assert!((out.loss_sum - (world * k) as f64).abs() < 1e-9);
        let want = expected(world, n, 7, k);
        for r in 0..world {
            testkit::assert_allclose(&pool.rank_grads(r), &want, 1e-3, 1e-5);
        }
    }

    #[test]
    fn overlap_and_barrier_are_bitwise_identical() {
        let (world, n, k) = (4, 211, 3);
        for wire in [WireFormat::F32, WireFormat::F16] {
            let mut a = CollectivePool::new(world, n, full_ranges(n, 3),
                                            wire);
            let mut b = CollectivePool::new(world, n, full_ranges(n, 3),
                                            wire);
            let synth = Synth { n };
            a.step(&[], 1.0, k, 0, true, &synth).unwrap();
            b.step(&[], 1.0, k, 0, false, &synth).unwrap();
            for r in 0..world {
                let (ga, gb) = (a.rank_grads(r), b.rank_grads(r));
                for (x, y) in ga.iter().zip(gb.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{wire:?} rank {r}");
                }
            }
        }
    }

    #[test]
    fn world_one_needs_no_exchange() {
        let n = 64;
        let mut pool =
            CollectivePool::new(1, n, full_ranges(n, 1), WireFormat::F32);
        let synth = Synth { n };
        let out = pool.step(&[], 1.0, 2, 0, true, &synth).unwrap();
        assert_eq!(out.comm_s, 0.0);
        assert_eq!(out.comm_net_s, 0.0);
        assert_eq!(out.exposed_comm_s, 0.0);
        let want = expected(1, n, 0, 2);
        testkit::assert_allclose(&pool.leader_grads(), &want, 1e-4, 1e-5);
    }

    #[test]
    fn compute_error_is_reported_not_deadlocked() {
        struct Failing {
            n: usize,
        }
        impl RankCompute for Failing {
            fn micro(&self, rank: usize, _s: usize, _m: usize, _p: &[f32],
                     _sc: f32, out: &mut Vec<f32>) -> Result<MicroStats> {
                anyhow::ensure!(rank != 1, "injected failure on rank 1");
                out.resize(self.n, 0.0);
                out.fill(1.0);
                Ok(MicroStats::default())
            }
        }
        let n = 40;
        let mut pool =
            CollectivePool::new(3, n, full_ranges(n, 2), WireFormat::F32);
        let err = pool.step(&[], 1.0, 1, 0, true, &Failing { n })
            .unwrap_err();
        assert!(format!("{err:#}").contains("rank 1"));
        // the pool must still be usable afterwards
        let synth = Synth { n };
        pool.step(&[], 1.0, 1, 1, true, &synth).unwrap();
        let want = expected(3, n, 1, 1);
        testkit::assert_allclose(&pool.leader_grads(), &want, 1e-3, 1e-5);
    }

    #[test]
    fn compute_panic_is_reported_not_deadlocked() {
        struct Panicking {
            n: usize,
        }
        impl RankCompute for Panicking {
            fn micro(&self, rank: usize, _s: usize, _m: usize, _p: &[f32],
                     _sc: f32, out: &mut Vec<f32>) -> Result<MicroStats> {
                assert!(rank != 2, "injected panic on rank 2");
                out.resize(self.n, 0.0);
                out.fill(1.0);
                Ok(MicroStats::default())
            }
        }
        let n = 30;
        let mut pool =
            CollectivePool::new(3, n, full_ranges(n, 2), WireFormat::F32);
        let err = pool.step(&[], 1.0, 1, 0, true, &Panicking { n })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 2") && msg.contains("panicked"), "{msg}");
        // the pool survives the panic and keeps working
        let synth = Synth { n };
        pool.step(&[], 1.0, 1, 1, true, &synth).unwrap();
        let want = expected(3, n, 1, 1);
        testkit::assert_allclose(&pool.leader_grads(), &want, 1e-3, 1e-5);
    }

    #[test]
    fn f16_wire_quantizes_but_stays_close() {
        let (world, n) = (2, 100);
        let mut f32p =
            CollectivePool::new(world, n, full_ranges(n, 2), WireFormat::F32);
        let mut f16p =
            CollectivePool::new(world, n, full_ranges(n, 2), WireFormat::F16);
        let synth = Synth { n };
        f32p.step(&[], 1.0, 1, 3, true, &synth).unwrap();
        f16p.step(&[], 1.0, 1, 3, true, &synth).unwrap();
        let (a, b) = (f32p.leader_grads(), f16p.leader_grads());
        // one f16 rounding per hop: relative error bounded by ~2^-10
        testkit::assert_allclose(&a, &b, 1e-2, 4e-3);
        // and the f16 path still agrees bitwise across ranks
        let b1 = f16p.rank_grads(1);
        for (x, y) in b.iter().zip(b1.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    // ------------------------------------------- hierarchical exchange --

    #[test]
    fn comm_mode_parses_and_resolves() {
        assert_eq!(CommMode::parse("flat").unwrap(), CommMode::Flat);
        assert_eq!(CommMode::parse(" Hierarchical ").unwrap(),
                   CommMode::Hierarchical);
        assert_eq!(CommMode::parse("auto").unwrap(), CommMode::Auto);
        assert!(CommMode::parse("ring-of-rings").is_err());
        assert_eq!(CommMode::Auto.to_string(), "auto");

        let multi = Topology::new(2, 4);
        let one_node = Topology::new(1, 8);
        let one_gpu = Topology::new(8, 1);
        assert!(CommMode::Auto.resolves_hierarchical(&multi));
        assert!(CommMode::Hierarchical.resolves_hierarchical(&multi));
        assert!(!CommMode::Flat.resolves_hierarchical(&multi));
        assert!(!CommMode::Auto.resolves_hierarchical(&one_node));
        assert!(!CommMode::Hierarchical.resolves_hierarchical(&one_gpu));
    }

    #[test]
    fn hierarchical_matches_flat_bitwise_on_exact_grads() {
        // The synthetic gradients sum exactly in f32, so the
        // machine-grouped association of the hierarchy and the flat
        // ring's fold must agree to the bit.
        let topo = Topology::new(2, 2);
        let (n, k) = (157, 2);
        let mut hier = CollectivePool::with_topology(
            topo, n, full_ranges(n, 3), WireFormat::F32,
            CommMode::Hierarchical);
        assert!(hier.is_hierarchical());
        let mut flat = CollectivePool::new(4, n, full_ranges(n, 3),
                                           WireFormat::F32);
        let synth = Synth { n };
        hier.step(&[], 1.0, k, 5, true, &synth).unwrap();
        flat.step(&[], 1.0, k, 5, true, &synth).unwrap();
        let want = expected(4, n, 5, k);
        for r in 0..4 {
            let (gh, gf) = (hier.rank_grads(r), flat.rank_grads(r));
            for (i, (x, y)) in gh.iter().zip(gf.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {r} [{i}]");
            }
            testkit::assert_allclose(&gh, &want, 1e-3, 1e-5);
        }
    }

    #[test]
    fn hierarchical_overlap_and_barrier_are_bitwise_identical() {
        let topo = Topology::new(3, 2);
        let (n, k) = (211, 2);
        for wire in [WireFormat::F32, WireFormat::F16] {
            let mut a = CollectivePool::with_topology(
                topo, n, full_ranges(n, 4), wire, CommMode::Auto);
            let mut b = CollectivePool::with_topology(
                topo, n, full_ranges(n, 4), wire, CommMode::Auto);
            assert!(a.is_hierarchical() && b.is_hierarchical());
            let synth = Synth { n };
            a.step(&[], 1.0, k, 1, true, &synth).unwrap();
            b.step(&[], 1.0, k, 1, false, &synth).unwrap();
            for r in 0..topo.world_size() {
                let (ga, gb) = (a.rank_grads(r), b.rank_grads(r));
                for (x, y) in ga.iter().zip(gb.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{wire:?} rank {r}");
                }
            }
        }
    }

    #[test]
    fn hierarchical_replicas_identical_and_f16_stays_close() {
        let topo = Topology::new(2, 3);
        let n = 120;
        let mut f32p = CollectivePool::with_topology(
            topo, n, full_ranges(n, 2), WireFormat::F32, CommMode::Auto);
        let mut f16p = CollectivePool::with_topology(
            topo, n, full_ranges(n, 2), WireFormat::F16, CommMode::Auto);
        let synth = Synth { n };
        f32p.step(&[], 1.0, 1, 3, true, &synth).unwrap();
        f16p.step(&[], 1.0, 1, 3, true, &synth).unwrap();
        let a = f32p.leader_grads();
        let b = f16p.leader_grads();
        testkit::assert_allclose(&a, &b, 1e-2, 4e-3);
        for r in 1..topo.world_size() {
            let br = f16p.rank_grads(r);
            for (x, y) in b.iter().zip(br.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {r}");
            }
        }
    }

    #[test]
    fn degenerate_topologies_fall_back_to_flat() {
        for topo in [Topology::new(1, 4), Topology::new(4, 1)] {
            let n = 64;
            let mut pool = CollectivePool::with_topology(
                topo, n, full_ranges(n, 2), WireFormat::F32,
                CommMode::Hierarchical);
            assert!(!pool.is_hierarchical(), "{topo}");
            let synth = Synth { n };
            pool.step(&[], 1.0, 1, 0, true, &synth).unwrap();
            let want = expected(4, n, 0, 1);
            testkit::assert_allclose(&pool.leader_grads(), &want, 1e-3,
                                     1e-5);
        }
    }

    #[test]
    fn hierarchical_timing_split_is_consistent() {
        let topo = Topology::new(2, 2);
        let n = 400;
        let mut pool = CollectivePool::with_topology(
            topo, n, full_ranges(n, 3), WireFormat::F32, CommMode::Auto);
        let synth = Synth { n };
        let out = pool.step(&[], 1.0, 2, 0, true, &synth).unwrap();
        assert_eq!(out.bucket_s.len(), 3);
        assert_eq!(out.bucket_net_s.len(), 3);
        for (t, nt) in out.bucket_s.iter().zip(&out.bucket_net_s) {
            assert!(*nt >= 0.0 && nt <= t, "net {nt} total {t}");
        }
        assert!(out.comm_net_s <= out.comm_s + 1e-12);
        assert!(out.comm_pcie_s >= 0.0);
        assert!(out.exposed_comm_s >= 0.0);
    }

    #[test]
    fn hierarchical_compute_error_is_reported_not_deadlocked() {
        struct Failing {
            n: usize,
        }
        impl RankCompute for Failing {
            fn micro(&self, rank: usize, _s: usize, _m: usize, _p: &[f32],
                     _sc: f32, out: &mut Vec<f32>) -> Result<MicroStats> {
                // rank 3 is a node MEMBER on 2M2G (machine 1, local 1)
                anyhow::ensure!(rank != 3, "injected failure on rank 3");
                out.resize(self.n, 0.0);
                out.fill(1.0);
                Ok(MicroStats::default())
            }
        }
        let topo = Topology::new(2, 2);
        let n = 48;
        let mut pool = CollectivePool::with_topology(
            topo, n, full_ranges(n, 2), WireFormat::F32, CommMode::Auto);
        let err = pool.step(&[], 1.0, 1, 0, true, &Failing { n })
            .unwrap_err();
        assert!(format!("{err:#}").contains("rank 3"));
        // the pool must still be usable afterwards
        let synth = Synth { n };
        pool.step(&[], 1.0, 1, 1, true, &synth).unwrap();
        let want = expected(4, n, 1, 1);
        testkit::assert_allclose(&pool.leader_grads(), &want, 1e-3, 1e-5);
    }

    // ------------------------------- chunked pipelined intra exchange --

    #[test]
    fn intra_mode_parses_and_resolves() {
        assert_eq!(IntraNodeMode::parse("serial").unwrap(),
                   IntraNodeMode::Serial);
        assert_eq!(IntraNodeMode::parse(" Ring ").unwrap(),
                   IntraNodeMode::Ring);
        assert_eq!(IntraNodeMode::parse("auto").unwrap(),
                   IntraNodeMode::Auto);
        assert_eq!(IntraNodeMode::parse("rs").unwrap(),
                   IntraNodeMode::ReduceScatter);
        assert_eq!(IntraNodeMode::parse("Reduce-Scatter").unwrap(),
                   IntraNodeMode::ReduceScatter);
        assert!(IntraNodeMode::parse("tree").is_err());
        assert_eq!(IntraNodeMode::Auto.to_string(), "auto");
        assert_eq!(IntraNodeMode::Ring.to_string(), "ring");
        assert_eq!(IntraNodeMode::ReduceScatter.to_string(), "rs");

        let multi = Topology::new(2, 4);
        let one_gpu = Topology::new(8, 1);
        assert!(IntraNodeMode::Auto.resolves_ring(&multi));
        assert!(IntraNodeMode::Ring.resolves_ring(&multi));
        assert!(!IntraNodeMode::Serial.resolves_ring(&multi));
        assert!(!IntraNodeMode::Auto.resolves_ring(&one_gpu));
        // rs is opt-in: Auto keeps resolving to the chain, and rs
        // itself never resolves the chain.
        assert!(IntraNodeMode::ReduceScatter.resolves_rs(&multi));
        assert!(!IntraNodeMode::ReduceScatter.resolves_ring(&multi));
        assert!(!IntraNodeMode::Auto.resolves_rs(&multi));
        assert!(!IntraNodeMode::ReduceScatter.resolves_rs(&one_gpu));
    }

    #[test]
    fn chunk_helpers_tile_buckets() {
        assert_eq!(num_chunks(0, 8), 1);
        assert_eq!(num_chunks(8, 8), 1);
        assert_eq!(num_chunks(9, 8), 2);
        assert_eq!(num_chunks(5, 100), 1); // chunk > bucket degenerate
        let len = 23;
        let chunk = 7;
        let mut covered = 0;
        for c in 0..num_chunks(len, chunk) {
            let s = chunk_span(len, chunk, c);
            assert_eq!(s.start, covered);
            covered = s.end;
        }
        assert_eq!(covered, len);
        assert_eq!(chunk_span(0, 8, 0), 0..0);
    }

    #[test]
    fn chain_matches_serial_bitwise_on_exact_grads_across_chunk_sizes() {
        // The Synth values are multiples of 0.25 with small magnitude,
        // so every partial sum is exactly representable — the chain's
        // tail-to-head association and the serialized leader's
        // head-to-tail association must agree to the bit, at any chunk
        // granularity (including 1 elem and chunk > bucket).
        let topo = Topology::new(2, 3);
        let (n, k) = (157, 2);
        let synth = Synth { n };
        let mut serial = CollectivePool::with_intra(
            topo, n, full_ranges(n, 3), WireFormat::F32,
            CommMode::Hierarchical, IntraNodeMode::Serial, 64);
        assert!(serial.is_hierarchical() && !serial.is_intra_ring());
        serial.step(&[], 1.0, k, 5, true, &synth).unwrap();
        for chunk in [1usize, 7, 64, 10_000] {
            let mut ring = CollectivePool::with_intra(
                topo, n, full_ranges(n, 3), WireFormat::F32,
                CommMode::Hierarchical, IntraNodeMode::Ring, chunk);
            assert!(ring.is_intra_ring());
            ring.step(&[], 1.0, k, 5, true, &synth).unwrap();
            for r in 0..topo.world_size() {
                let (a, b) = (serial.rank_grads(r), ring.rank_grads(r));
                for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "chunk={chunk} rank {r} [{i}]");
                }
            }
        }
    }

    #[test]
    fn chain_default_resolves_and_reports_chunks() {
        let topo = Topology::new(2, 2);
        let n = 300;
        let pool = CollectivePool::with_intra(
            topo, n, full_ranges(n, 2), WireFormat::F32, CommMode::Auto,
            IntraNodeMode::Auto, 64);
        assert!(pool.is_hierarchical() && pool.is_intra_ring());
        assert_eq!(pool.chunk_elems(), 64);
        // 2 buckets of 150 elems -> ceil(150/64) = 3 chunks each
        assert_eq!(pool.chunks_per_bucket(), vec![3, 3]);
        // serial mode reports 1 chunk per bucket
        let serial = CollectivePool::with_intra(
            topo, n, full_ranges(n, 2), WireFormat::F32, CommMode::Auto,
            IntraNodeMode::Serial, 64);
        assert_eq!(serial.chunks_per_bucket(), vec![1, 1]);
        // and so does a flat pool regardless of intra mode
        let flat = CollectivePool::with_intra(
            topo, n, full_ranges(n, 2), WireFormat::F32, CommMode::Flat,
            IntraNodeMode::Ring, 64);
        assert!(!flat.is_intra_ring());
        assert_eq!(flat.chunks_per_bucket(), vec![1, 1]);
    }

    #[test]
    fn chain_overlap_and_barrier_are_bitwise_identical() {
        let topo = Topology::new(2, 3);
        let (n, k) = (211, 2);
        for wire in [WireFormat::F32, WireFormat::F16] {
            let mut a = CollectivePool::with_intra(
                topo, n, full_ranges(n, 4), wire, CommMode::Auto,
                IntraNodeMode::Ring, 32);
            let mut b = CollectivePool::with_intra(
                topo, n, full_ranges(n, 4), wire, CommMode::Auto,
                IntraNodeMode::Ring, 32);
            let synth = Synth { n };
            a.step(&[], 1.0, k, 1, true, &synth).unwrap();
            b.step(&[], 1.0, k, 1, false, &synth).unwrap();
            for r in 0..topo.world_size() {
                let (ga, gb) = (a.rank_grads(r), b.rank_grads(r));
                for (x, y) in ga.iter().zip(gb.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{wire:?} rank {r}");
                }
            }
        }
    }

    #[test]
    fn chain_survives_reuse_and_stays_deterministic() {
        // 40 steps through one chain pool: stats intact, replicas
        // bitwise identical, results match the serial oracle.
        let topo = Topology::new(2, 4);
        let (n, k) = (523, 2);
        let mut pool = CollectivePool::with_intra(
            topo, n, full_ranges(n, 3), WireFormat::F32, CommMode::Auto,
            IntraNodeMode::Ring, 100);
        let synth = Synth { n };
        let world = topo.world_size();
        for s in 0..40 {
            let out = pool.step(&[], 1.0, k, s, true, &synth).unwrap();
            assert!((out.loss_sum - (world * k) as f64).abs() < 1e-9);
            assert!(out.comm_net_s <= out.comm_s + 1e-12);
            if s % 13 == 0 || s == 39 {
                let want = expected(world, n, s, k);
                testkit::assert_allclose(&pool.leader_grads(), &want, 1e-2,
                                         1e-4);
                let leader = pool.leader_grads().clone();
                for r in 1..world {
                    let other = pool.rank_grads(r);
                    for (x, y) in leader.iter().zip(other.iter()) {
                        assert_eq!(x.to_bits(), y.to_bits(),
                                   "step {s} rank {r}");
                    }
                }
            }
        }
    }

    #[test]
    fn chain_compute_error_is_reported_not_deadlocked() {
        struct Failing {
            n: usize,
        }
        impl RankCompute for Failing {
            fn micro(&self, rank: usize, _s: usize, _m: usize, _p: &[f32],
                     _sc: f32, out: &mut Vec<f32>) -> Result<MicroStats> {
                // rank 5 is the chain TAIL on 2M3G (machine 1, local 2)
                anyhow::ensure!(rank != 5, "injected failure on rank 5");
                out.resize(self.n, 0.0);
                out.fill(1.0);
                Ok(MicroStats::default())
            }
        }
        let topo = Topology::new(2, 3);
        let n = 96;
        let mut pool = CollectivePool::with_intra(
            topo, n, full_ranges(n, 2), WireFormat::F32, CommMode::Auto,
            IntraNodeMode::Ring, 16);
        let err = pool.step(&[], 1.0, 1, 0, true, &Failing { n })
            .unwrap_err();
        assert!(format!("{err:#}").contains("rank 5"));
        // the pool must still be usable afterwards
        let synth = Synth { n };
        pool.step(&[], 1.0, 1, 1, true, &synth).unwrap();
        let want = expected(topo.world_size(), n, 1, 1);
        testkit::assert_allclose(&pool.leader_grads(), &want, 1e-3, 1e-5);
    }

    // --------------------------------- 2-level reduce-scatter exchange --

    #[test]
    fn rs_matches_serial_and_flat_bitwise_on_exact_grads() {
        // The Synth values are multiples of 0.25 with small magnitude,
        // so every partial sum is exactly representable — the 2-level
        // schedule's shard association must agree to the bit with both
        // the serialized leader and the flat ring.
        let topo = Topology::new(2, 3);
        let (n, k) = (157, 2);
        let synth = Synth { n };
        let mut serial = CollectivePool::with_intra(
            topo, n, full_ranges(n, 3), WireFormat::F32,
            CommMode::Hierarchical, IntraNodeMode::Serial, 64);
        serial.step(&[], 1.0, k, 5, true, &synth).unwrap();
        let mut flat = CollectivePool::new(topo.world_size(), n,
                                           full_ranges(n, 3),
                                           WireFormat::F32);
        flat.step(&[], 1.0, k, 5, true, &synth).unwrap();
        let mut rs = CollectivePool::with_intra(
            topo, n, full_ranges(n, 3), WireFormat::F32,
            CommMode::Hierarchical, IntraNodeMode::ReduceScatter, 64);
        assert!(rs.is_hierarchical() && rs.is_intra_rs());
        assert!(!rs.is_intra_ring());
        // rs phases aren't chunk-pipelined: one span per bucket.
        assert_eq!(rs.chunks_per_bucket(), vec![1, 1, 1]);
        rs.step(&[], 1.0, k, 5, true, &synth).unwrap();
        let want = expected(topo.world_size(), n, 5, k);
        for r in 0..topo.world_size() {
            let (a, b, c) =
                (serial.rank_grads(r), rs.rank_grads(r), flat.rank_grads(r));
            for (i, ((x, y), z)) in
                a.iter().zip(b.iter()).zip(c.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "serial/rs r{r} [{i}]");
                assert_eq!(y.to_bits(), z.to_bits(), "rs/flat r{r} [{i}]");
            }
            testkit::assert_allclose(&b, &want, 1e-3, 1e-5);
        }
    }

    #[test]
    fn rs_handles_buckets_smaller_than_node_and_empty_shards() {
        // 2M4G with a 3-element bucket: the intra plan at g=4 leaves at
        // least one rank with an EMPTY shard, whose cross ring must
        // early-skip consistently on every machine.
        let topo = Topology::new(2, 4);
        let n = 67;
        // uneven split: one bucket is 3 elems (< g), one is 64
        let ranges: Arc<[BucketRange]> = vec![
            BucketRange { start: 0, end: 3 },
            BucketRange { start: 3, end: 67 },
        ]
        .into();
        let synth = Synth { n };
        let mut rs = CollectivePool::with_intra(
            topo, n, ranges.clone(), WireFormat::F32,
            CommMode::Hierarchical, IntraNodeMode::ReduceScatter, 64);
        let mut flat = CollectivePool::new(topo.world_size(), n, ranges,
                                           WireFormat::F32);
        rs.step(&[], 1.0, 1, 2, true, &synth).unwrap();
        flat.step(&[], 1.0, 1, 2, true, &synth).unwrap();
        for r in 0..topo.world_size() {
            let (a, b) = (rs.rank_grads(r), flat.rank_grads(r));
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {r} [{i}]");
            }
        }
    }

    #[test]
    fn rs_overlap_and_barrier_are_bitwise_identical() {
        let topo = Topology::new(3, 2);
        let (n, k) = (211, 2);
        for wire in [WireFormat::F32, WireFormat::F16] {
            let mut a = CollectivePool::with_intra(
                topo, n, full_ranges(n, 4), wire, CommMode::Auto,
                IntraNodeMode::ReduceScatter, 32);
            let mut b = CollectivePool::with_intra(
                topo, n, full_ranges(n, 4), wire, CommMode::Auto,
                IntraNodeMode::ReduceScatter, 32);
            assert!(a.is_intra_rs() && b.is_intra_rs());
            let synth = Synth { n };
            a.step(&[], 1.0, k, 1, true, &synth).unwrap();
            b.step(&[], 1.0, k, 1, false, &synth).unwrap();
            for r in 0..topo.world_size() {
                let (ga, gb) = (a.rank_grads(r), b.rank_grads(r));
                for (x, y) in ga.iter().zip(gb.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{wire:?} rank {r}");
                }
            }
        }
    }

    #[test]
    fn rs_f16_replicas_identical_and_close_to_f32() {
        let topo = Topology::new(2, 3);
        let n = 120;
        let mut f32p = CollectivePool::with_intra(
            topo, n, full_ranges(n, 2), WireFormat::F32, CommMode::Auto,
            IntraNodeMode::ReduceScatter, 64);
        let mut f16p = CollectivePool::with_intra(
            topo, n, full_ranges(n, 2), WireFormat::F16, CommMode::Auto,
            IntraNodeMode::ReduceScatter, 64);
        let synth = Synth { n };
        f32p.step(&[], 1.0, 1, 3, true, &synth).unwrap();
        f16p.step(&[], 1.0, 1, 3, true, &synth).unwrap();
        let a = f32p.leader_grads();
        let b = f16p.leader_grads();
        // the f16 wire rides the cross ring only — one rounding per hop
        testkit::assert_allclose(&a, &b, 1e-2, 4e-3);
        for r in 1..topo.world_size() {
            let br = f16p.rank_grads(r);
            for (x, y) in b.iter().zip(br.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {r}");
            }
        }
    }

    #[test]
    fn rs_degenerate_topologies_fall_back_to_flat() {
        for topo in [Topology::new(1, 4), Topology::new(4, 1)] {
            let n = 64;
            let mut pool = CollectivePool::with_intra(
                topo, n, full_ranges(n, 2), WireFormat::F32,
                CommMode::Hierarchical, IntraNodeMode::ReduceScatter, 64);
            assert!(!pool.is_hierarchical() && !pool.is_intra_rs(),
                    "{topo}");
            let synth = Synth { n };
            pool.step(&[], 1.0, 1, 0, true, &synth).unwrap();
            let want = expected(4, n, 0, 1);
            testkit::assert_allclose(&pool.leader_grads(), &want, 1e-3,
                                     1e-5);
        }
    }

    #[test]
    fn rs_compute_error_is_reported_not_deadlocked() {
        struct Failing {
            n: usize,
        }
        impl RankCompute for Failing {
            fn micro(&self, rank: usize, _s: usize, _m: usize, _p: &[f32],
                     _sc: f32, out: &mut Vec<f32>) -> Result<MicroStats> {
                // rank 4 sits mid-ring on 2M3G (machine 1, local 1)
                anyhow::ensure!(rank != 4, "injected failure on rank 4");
                out.resize(self.n, 0.0);
                out.fill(1.0);
                Ok(MicroStats::default())
            }
        }
        let topo = Topology::new(2, 3);
        let n = 96;
        let mut pool = CollectivePool::with_intra(
            topo, n, full_ranges(n, 2), WireFormat::F32, CommMode::Auto,
            IntraNodeMode::ReduceScatter, 16);
        let err = pool.step(&[], 1.0, 1, 0, true, &Failing { n })
            .unwrap_err();
        assert!(format!("{err:#}").contains("rank 4"));
        // the pool must still be usable afterwards
        let synth = Synth { n };
        pool.step(&[], 1.0, 1, 1, true, &synth).unwrap();
        let want = expected(topo.world_size(), n, 1, 1);
        testkit::assert_allclose(&pool.leader_grads(), &want, 1e-3, 1e-5);
    }

    // --------------------------------------- eager-send failure paths --

    /// Fixed-size deterministic fill for the hand-wired tests below.
    struct Fill30;
    impl RankCompute for Fill30 {
        fn micro(&self, _r: usize, _s: usize, _m: usize, _p: &[f32],
                 _sc: f32, out: &mut Vec<f32>) -> Result<MicroStats> {
            out.resize(30, 0.0);
            for (i, v) in out.iter_mut().enumerate() {
                *v = i as f32;
            }
            Ok(MicroStats::default())
        }
    }
    static FILL30: Fill30 = Fill30;

    fn test_job(overlap: bool) -> Job {
        Job {
            params: &[],
            compute: &FILL30,
            scale: 1.0,
            micro_steps: 1,
            step_index: 0,
            overlap,
        }
    }

    /// Regression for the eager-send bug: when every send fails (comm
    /// worker never ran), the reply loop must await ZERO replies instead
    /// of `ranges.len()` — the old code blocked forever here because the
    /// live `reduced_tx` in this scope would never produce a message.
    #[test]
    fn dead_comm_worker_fails_fast_without_awaiting_replies() {
        let ranges = BucketRange::even_split(30, 3);
        let accs = vec![Mutex::new(vec![0.0f32; 30])];
        let (bucket_tx, bucket_rx) = channel::<(usize, Vec<f32>)>();
        drop(bucket_rx); // comm worker "died" before the step
        let (_reduced_tx, reduced_rx) = channel::<ReducedResult>();
        let mut grads = Vec::new();
        let mut bucket_bufs: Vec<Vec<f32>> =
            ranges.iter().map(|b| Vec::with_capacity(b.len())).collect();
        let job = test_job(true);
        let err = run_rank_step(0, 2, &ranges, &accs, &job, &mut grads,
                                &mut bucket_bufs, &bucket_tx, &reduced_rx)
            .unwrap_err();
        assert!(format!("{err:#}").contains("comm worker gone"), "{err:#}");
    }

    /// A comm worker that dies partway through the eager schedule: the
    /// compute side must feed/await only what was actually enqueued,
    /// apply the replies it did get, and report the failure — never
    /// hang.  (Run under both schedules; the scripted peer serves one
    /// bucket then drops its channels.)
    #[test]
    fn partial_exchange_failure_is_reported_not_deadlocked() {
        for overlap in [true, false] {
            let ranges = BucketRange::even_split(30, 3);
            let accs = vec![Mutex::new(vec![0.0f32; 30])];
            let (bucket_tx, bucket_rx) = channel::<(usize, Vec<f32>)>();
            let (reduced_tx, reduced_rx) = channel::<ReducedResult>();
            let peer = std::thread::spawn(move || {
                // Serve bucket 0 with a recognizable "reduction"...
                let (idx, mut data) = bucket_rx.recv().unwrap();
                for v in data.iter_mut() {
                    *v += 1000.0;
                }
                reduced_tx
                    .send(Ok(Reduced {
                        idx,
                        data,
                        exchange_s: 0.0,
                        net_s: 0.0,
                        backpressure_s: 0.0,
                    }))
                    .unwrap();
                // ...then die mid-exchange (drops bucket_rx/reduced_tx).
            });
            let mut grads = Vec::new();
            let mut bucket_bufs: Vec<Vec<f32>> =
                ranges.iter().map(|b| Vec::with_capacity(b.len())).collect();
            let job = test_job(overlap);
            let res = run_rank_step(0, 2, &ranges, &accs, &job, &mut grads,
                                    &mut bucket_bufs, &bucket_tx,
                                    &reduced_rx);
            peer.join().unwrap();
            let err = res.unwrap_err();
            assert!(format!("{err:#}").contains("comm worker gone"),
                    "overlap={overlap}: {err:#}");
            // bucket 0's reply was applied before the failure surfaced
            let acc = accs[0].lock().unwrap();
            assert_eq!(acc[0], 1000.0, "overlap={overlap}");
            assert_eq!(acc[9], 1009.0, "overlap={overlap}");
        }
    }
}
