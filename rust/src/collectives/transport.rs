//! Pluggable transport for the collective pool (the "take the pool
//! out-of-process" refactor from the ROADMAP).
//!
//! The pool's comm workers used to talk over hard-wired
//! `std::sync::mpsc` channels carrying ad-hoc message enums.  This
//! module extracts that plumbing into three layers:
//!
//! 1. **[`Frame`] + wire codec** — the canonical on-the-wire unit.  One
//!    enum covers every payload the comm protocols exchange (ring
//!    reduce-scatter/all-gather hops in f32 or f16, member bucket
//!    uploads, leader broadcasts, chunked chain hops).  The v1 binary
//!    layout is little-endian and length-prefixed so a reader can frame
//!    a stream without knowing the kind in advance; it is pinned by the
//!    `golden_frame_v1.bin` fixture the same way `golden_v1.bckp` pins
//!    checkpoints.
//! 2. **[`FrameTx`]/[`FrameRx`] links** — one directed edge of the comm
//!    graph.  The in-process implementation wraps an mpsc channel and
//!    moves `Frame`s without serialization; the socket implementation
//!    (see [`super::socket`]) encodes to the v1 layout.  Both recycle
//!    payload buffers through a [`PayloadPool`] so the steady state
//!    stays free of gradient-sized allocation — the PR-1 invariant.
//! 3. **[`Transport`] + [`build_endpoints`]** — owns the mapping from
//!    topology to links.  [`build_endpoints`] enumerates every edge of
//!    the comm graph in one deterministic global order (flat ring, or
//!    the hierarchical member/leader/chain graph) and asks the
//!    transport for each link's ends, producing a [`CommEndpoints`]
//!    role bundle per *local* rank.  A transport that only hosts a
//!    slice of the world (a multi-process run) returns remote halves
//!    backed by sockets and simply skips links it does not touch.
//!
//! # Determinism
//!
//! Nothing in this module reorders arithmetic: the reduction order is
//! fixed by the ring/chain schedules in `pool.rs`, and a frame's
//! payload is bit-identical whether it crossed a channel or a socket
//! (f32/f16 little-endian round-trip is exact).  Pooled exchange over
//! `InProcTransport`, `SocketTransport`, and the spawn baseline is
//! asserted bitwise-equal in `tests/transport.rs`.
//!
//! # Failure surfaces
//!
//! Every send/recv returns [`TransportError`] instead of panicking.
//! Links also carry a [`FrameRx::remote`] bit: protocols may tolerate a
//! *local* peer's disconnect (its own rank reports the failure — the
//! PR-2 policy), but a **remote** disconnect must propagate, because
//! the dead peer's process can no longer report anything on our result
//! channel.

use std::collections::HashMap;
use std::fmt;
use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender};

use crate::half::F16;
use crate::topology::Topology;

/// Sanity cap on a decoded frame body; anything larger is a corrupt or
/// hostile length prefix, not a gradient bucket.
pub const MAX_FRAME: usize = 1 << 30;

/// Wire-format version emitted by [`encode_frame`]; bumped only with a
/// new golden fixture.
pub const WIRE_VERSION: u8 = 1;

/// Connection-handshake magic ("BDTP" little-endian) — lets a listener
/// reject strays that are not a bertdist peer before trusting a length
/// prefix.
pub const HANDSHAKE_MAGIC: u32 = 0x5054_4442;

// ---------------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------------

/// Failure surfaced by a transport link or by endpoint wiring.
#[derive(Debug, Clone, PartialEq)]
pub enum TransportError {
    /// Peer hung up (channel dropped, socket EOF/reset).
    Disconnected,
    /// No frame arrived within the configured receive window (seconds).
    Timeout(f64),
    /// The bytes/topology were structurally wrong (bad magic, unknown
    /// frame kind, oversized length, misaligned world split, ...).
    Protocol(String),
    /// An OS-level I/O error that is none of the above.
    Io(String),
    /// A rendezvous file stamped for a different run (or an older
    /// generation than this process's epoch) — a leftover that must be
    /// refused loudly instead of silently reused.
    StaleRendezvous(String),
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Disconnected => write!(f, "peer disconnected"),
            TransportError::Timeout(s) => {
                write!(f, "no frame within {s:.1}s (net timeout)")
            }
            TransportError::Protocol(m) => write!(f, "protocol error: {m}"),
            TransportError::Io(m) => write!(f, "io error: {m}"),
            TransportError::StaleRendezvous(m) => {
                write!(f, "stale rendezvous: {m}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

// ---------------------------------------------------------------------------
// frames + codec
// ---------------------------------------------------------------------------

/// One unit of comm-protocol traffic.  Variants mirror the messages the
/// pool's protocols exchange; `net_s` fields carry upstream link time
/// so downstream ranks can attribute network vs PCIe spans exactly as
/// the in-process path always has.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Ring hop (reduce-scatter tag `s`, all-gather tag `100+s`), f32.
    RingF32 { tag: u32, data: Vec<f32> },
    /// Ring hop with the f16 wire format.
    RingF16 { tag: u32, data: Vec<u16> },
    /// Member → leader bucket upload (hierarchical serial gather).
    Bucket { idx: u32, data: Vec<f32> },
    /// Leader → member bucket broadcast; `net_s` is the leader-ring
    /// time the member folds into its own net span.
    Bcast { idx: u32, net_s: f64, data: Vec<f32> },
    /// Chunked-chain hop (up = reduce-forward, down = copy-forward).
    Chunk { idx: u32, chunk: u32, net_s: f64, data: Vec<f32> },
    /// Sparse top-k hop on a network ring (`train.sparsify`): the
    /// surviving coordinates of one rank's segment, as parallel
    /// index/value arrays.  `n` is the dense segment length the indices
    /// address — receivers check every index against it before
    /// scattering, so a corrupt frame cannot write out of bounds.
    Sparse { tag: u32, n: u32, indices: Vec<u32>, values: Vec<f32> },
}

impl Frame {
    /// v1 kind byte.
    fn kind(&self) -> u8 {
        match self {
            Frame::RingF32 { .. } => 1,
            Frame::RingF16 { .. } => 2,
            Frame::Bucket { .. } => 3,
            Frame::Bcast { .. } => 4,
            Frame::Chunk { .. } => 5,
            Frame::Sparse { .. } => 6,
        }
    }
}

/// Free-list of payload buffers, one per element type.  Links take
/// buffers from here when materializing a received frame and protocols
/// return them via [`PayloadPool::recycle`]; after warm-up no
/// gradient-sized allocation happens on the hot path.
#[derive(Default)]
pub struct PayloadPool {
    f32s: Vec<Vec<f32>>,
    u16s: Vec<Vec<u16>>,
    u32s: Vec<Vec<u32>>,
}

impl PayloadPool {
    /// Pop a cleared f32 buffer (or allocate on a cold pool).
    pub fn take_f32(&mut self) -> Vec<f32> {
        let mut v = self.f32s.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Pop a cleared u16 buffer (or allocate on a cold pool).
    pub fn take_u16(&mut self) -> Vec<u16> {
        let mut v = self.u16s.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Pop a cleared u32 (sparse-index) buffer.
    pub fn take_u32(&mut self) -> Vec<u32> {
        let mut v = self.u32s.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return an f32 buffer to the free list.
    pub fn put_f32(&mut self, mut v: Vec<f32>) {
        v.clear();
        self.f32s.push(v);
    }

    /// Return a u16 buffer to the free list.
    pub fn put_u16(&mut self, mut v: Vec<u16>) {
        v.clear();
        self.u16s.push(v);
    }

    /// Return a u32 buffer to the free list.
    pub fn put_u32(&mut self, mut v: Vec<u32>) {
        v.clear();
        self.u32s.push(v);
    }

    /// Strip a frame and recycle its payload buffer.
    pub fn recycle(&mut self, frame: Frame) {
        match frame {
            Frame::RingF32 { data, .. }
            | Frame::Bucket { data, .. }
            | Frame::Bcast { data, .. }
            | Frame::Chunk { data, .. } => self.put_f32(data),
            Frame::RingF16 { data, .. } => self.put_u16(data),
            Frame::Sparse { indices, values, .. } => {
                self.put_u32(indices);
                self.put_f32(values);
            }
        }
    }
}

/// Serialize `frame` into `out` in the v1 layout:
/// `[body_len: u32][kind: u8][fields...][payload bytes]`, all
/// little-endian, where `body_len` counts everything after itself.
/// `out` is cleared first so callers can recycle byte buffers.
pub fn encode_frame(frame: &Frame, out: &mut Vec<u8>) {
    out.clear();
    out.extend_from_slice(&0u32.to_le_bytes()); // patched below
    out.push(frame.kind());
    match frame {
        Frame::RingF32 { tag, data } => {
            out.extend_from_slice(&tag.to_le_bytes());
            for x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Frame::RingF16 { tag, data } => {
            out.extend_from_slice(&tag.to_le_bytes());
            for x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Frame::Bucket { idx, data } => {
            out.extend_from_slice(&idx.to_le_bytes());
            for x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Frame::Bcast { idx, net_s, data } => {
            out.extend_from_slice(&idx.to_le_bytes());
            out.extend_from_slice(&net_s.to_le_bytes());
            for x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Frame::Chunk { idx, chunk, net_s, data } => {
            out.extend_from_slice(&idx.to_le_bytes());
            out.extend_from_slice(&chunk.to_le_bytes());
            out.extend_from_slice(&net_s.to_le_bytes());
            for x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Frame::Sparse { tag, n, indices, values } => {
            out.extend_from_slice(&tag.to_le_bytes());
            out.extend_from_slice(&n.to_le_bytes());
            out.extend_from_slice(&(indices.len() as u32).to_le_bytes());
            for x in indices {
                out.extend_from_slice(&x.to_le_bytes());
            }
            for x in values {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    let body = (out.len() - 4) as u32;
    out[0..4].copy_from_slice(&body.to_le_bytes());
}

fn need_bytes(body: &[u8], at: usize, n: usize)
              -> Result<(), TransportError> {
    if body.len() < at + n {
        return Err(TransportError::Protocol(format!(
            "frame body truncated: need {} bytes at offset {at}, have {}",
            n,
            body.len()
        )));
    }
    Ok(())
}

fn read_u32(body: &[u8], at: usize) -> Result<u32, TransportError> {
    need_bytes(body, at, 4)?;
    Ok(u32::from_le_bytes(body[at..at + 4].try_into().unwrap()))
}

fn read_f64(body: &[u8], at: usize) -> Result<f64, TransportError> {
    need_bytes(body, at, 8)?;
    Ok(f64::from_le_bytes(body[at..at + 8].try_into().unwrap()))
}

fn payload_f32(body: &[u8], at: usize, pool: &mut PayloadPool)
               -> Result<Vec<f32>, TransportError> {
    let rest = &body[at..];
    if rest.len() % 4 != 0 {
        return Err(TransportError::Protocol(format!(
            "f32 payload length {} not a multiple of 4",
            rest.len()
        )));
    }
    let mut v = pool.take_f32();
    v.reserve(rest.len() / 4);
    for c in rest.chunks_exact(4) {
        v.push(f32::from_le_bytes(c.try_into().unwrap()));
    }
    Ok(v)
}

fn payload_u16(body: &[u8], at: usize, pool: &mut PayloadPool)
               -> Result<Vec<u16>, TransportError> {
    let rest = &body[at..];
    if rest.len() % 2 != 0 {
        return Err(TransportError::Protocol(format!(
            "u16 payload length {} not a multiple of 2",
            rest.len()
        )));
    }
    let mut v = pool.take_u16();
    v.reserve(rest.len() / 2);
    for c in rest.chunks_exact(2) {
        v.push(u16::from_le_bytes(c.try_into().unwrap()));
    }
    Ok(v)
}

/// Decode one v1 frame *body* (everything after the 4-byte length
/// prefix).  Payload buffers come from `pool`.
pub fn decode_frame(body: &[u8], pool: &mut PayloadPool)
                    -> Result<Frame, TransportError> {
    need_bytes(body, 0, 1)?;
    match body[0] {
        1 => Ok(Frame::RingF32 {
            tag: read_u32(body, 1)?,
            data: payload_f32(body, 5, pool)?,
        }),
        2 => Ok(Frame::RingF16 {
            tag: read_u32(body, 1)?,
            data: payload_u16(body, 5, pool)?,
        }),
        3 => Ok(Frame::Bucket {
            idx: read_u32(body, 1)?,
            data: payload_f32(body, 5, pool)?,
        }),
        4 => Ok(Frame::Bcast {
            idx: read_u32(body, 1)?,
            net_s: read_f64(body, 5)?,
            data: payload_f32(body, 13, pool)?,
        }),
        5 => Ok(Frame::Chunk {
            idx: read_u32(body, 1)?,
            chunk: read_u32(body, 5)?,
            net_s: read_f64(body, 9)?,
            data: payload_f32(body, 17, pool)?,
        }),
        6 => {
            let tag = read_u32(body, 1)?;
            let n = read_u32(body, 5)?;
            let count = read_u32(body, 9)? as usize;
            // The count is the single source of truth for both array
            // lengths, so the body length must match it EXACTLY: a
            // short body is a truncated frame, a long one is a skewed
            // count — either would silently corrupt the scatter.
            let want = 13usize.saturating_add(count.saturating_mul(8));
            if body.len() != want {
                return Err(TransportError::Protocol(format!(
                    "sparse payload truncated or skewed: {} entries need \
                     {want} body bytes, have {}",
                    count,
                    body.len()
                )));
            }
            let mut indices = pool.take_u32();
            indices.reserve(count);
            for c in body[13..13 + count * 4].chunks_exact(4) {
                indices.push(u32::from_le_bytes(c.try_into().unwrap()));
            }
            let mut values = pool.take_f32();
            values.reserve(count);
            for c in body[13 + count * 4..].chunks_exact(4) {
                values.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            Ok(Frame::Sparse { tag, n, indices, values })
        }
        k => Err(TransportError::Protocol(format!("unknown frame kind {k}"))),
    }
}

/// Quantize a frame payload chunk to the f16 wire exactly as the
/// in-process path does; centralized here so both transports share one
/// rounding routine (bitwise determinism across transports).
pub fn quantize_f16(src: &[f32], out: &mut Vec<u16>) {
    out.clear();
    out.reserve(src.len());
    for &x in src {
        out.push(F16::from_f32(x).0);
    }
}

// ---------------------------------------------------------------------------
// links
// ---------------------------------------------------------------------------

/// Sending half of one directed comm-graph edge.
pub trait FrameTx: Send {
    /// Queue `frame` for delivery.  The payload buffer is recycled into
    /// `pool` when the transport is done with it (immediately for
    /// serializing transports; on the receiver side for in-process
    /// moves, so in-proc sends leave `pool` untouched).
    fn send(&mut self, frame: Frame, pool: &mut PayloadPool)
            -> Result<(), TransportError>;

    /// True when the other end lives in a different process.  Protocols
    /// use this to decide whether a peer failure can be tolerated
    /// locally (the peer's own rank reports it) or must propagate.
    fn remote(&self) -> bool {
        false
    }

    /// Seconds this link spent stalled on a full send queue since the
    /// last call, and reset the counter.  In-process links never stall
    /// (unbounded channels), so the default is 0; socket links report
    /// real backpressure — see `SocketTx`.
    fn take_backpressure_s(&mut self) -> f64 {
        0.0
    }
}

/// Receiving half of one directed comm-graph edge.
pub trait FrameRx: Send {
    /// Block until the next frame (or the configured timeout elapses).
    fn recv(&mut self, pool: &mut PayloadPool)
            -> Result<Frame, TransportError>;

    /// See [`FrameTx::remote`].
    fn remote(&self) -> bool {
        false
    }
}

/// In-process link: a zero-copy mpsc move, exactly the pre-refactor
/// wiring.
pub struct ChanTx(Sender<Frame>);

impl FrameTx for ChanTx {
    fn send(&mut self, frame: Frame, _pool: &mut PayloadPool)
            -> Result<(), TransportError> {
        self.0.send(frame).map_err(|_| TransportError::Disconnected)
    }
}

/// Receiving half of [`ChanTx`].
pub struct ChanRx(Receiver<Frame>);

impl FrameRx for ChanRx {
    fn recv(&mut self, _pool: &mut PayloadPool)
            -> Result<Frame, TransportError> {
        self.0.recv().map_err(|_| TransportError::Disconnected)
    }
}

/// Build one in-process link (unbounded, never blocks on send).
pub fn chan_link() -> (Box<dyn FrameTx>, Box<dyn FrameRx>) {
    let (tx, rx) = channel();
    (Box::new(ChanTx(tx)), Box::new(ChanRx(rx)))
}

// ---------------------------------------------------------------------------
// link identity + transport
// ---------------------------------------------------------------------------

/// Which protocol edge a link implements.  Part of the connection
/// handshake, so a transport can match incoming sockets to graph edges
/// regardless of connect order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// Flat-ring neighbor edge `r -> (r+1) % world`.
    FlatRing,
    /// Leader-ring neighbor edge between machine leaders.
    LeaderRing,
    /// Serial gather: member -> its leader.
    MemberUp,
    /// Serial broadcast: leader -> member.
    MemberDown,
    /// Chunked chain reduce-forward: local rank `l -> l-1`.
    ChainUp,
    /// Chunked chain copy-forward: local rank `l-1 -> l`.
    ChainDown,
    /// 2-level reduce-scatter intra-node ring: local rank
    /// `l -> (l+1) % g` within one machine.
    RsIntra,
    /// 2-level reduce-scatter cross-machine ring: same local index on
    /// machine `M -> (M+1) % m`.
    RsCross,
}

impl LinkKind {
    /// Handshake byte.
    pub fn to_u8(self) -> u8 {
        match self {
            LinkKind::FlatRing => 0,
            LinkKind::LeaderRing => 1,
            LinkKind::MemberUp => 2,
            LinkKind::MemberDown => 3,
            LinkKind::ChainUp => 4,
            LinkKind::ChainDown => 5,
            LinkKind::RsIntra => 6,
            LinkKind::RsCross => 7,
        }
    }

    /// Inverse of [`LinkKind::to_u8`].
    pub fn from_u8(b: u8) -> Result<Self, TransportError> {
        Ok(match b {
            0 => LinkKind::FlatRing,
            1 => LinkKind::LeaderRing,
            2 => LinkKind::MemberUp,
            3 => LinkKind::MemberDown,
            4 => LinkKind::ChainUp,
            5 => LinkKind::ChainDown,
            6 => LinkKind::RsIntra,
            7 => LinkKind::RsCross,
            k => {
                return Err(TransportError::Protocol(format!(
                    "unknown link kind {k}"
                )))
            }
        })
    }
}

/// One directed edge of the comm graph, named by global ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkId {
    /// Protocol role of the edge.
    pub kind: LinkKind,
    /// Sending global rank.
    pub from: u32,
    /// Receiving global rank.
    pub to: u32,
}

/// The ends of a link that this process hosts.  A fully-local transport
/// returns both; a multi-process transport returns only the half whose
/// rank is local.
pub struct LinkEnds {
    /// Present iff `from` is a local rank.
    pub tx: Option<Box<dyn FrameTx>>,
    /// Present iff `to` is a local rank.
    pub rx: Option<Box<dyn FrameRx>>,
}

/// Owns the mapping from comm-graph edges to concrete links.
///
/// `link` may be called more than once per edge across a transport's
/// lifetime (the trainer rebuilds pools between phases over ONE
/// transport); each call produces a fresh link.
pub trait Transport {
    /// Total ranks across all processes.
    fn world(&self) -> usize;

    /// Contiguous global-rank range hosted by this process.
    fn local_ranks(&self) -> Range<usize>;

    /// True when every rank is in-process (no socket ever involved).
    fn fully_local(&self) -> bool {
        self.local_ranks().len() == self.world()
    }

    /// Produce the local end(s) of `id`.  Called in the same
    /// deterministic global order by every process (see
    /// [`build_endpoints`]); edges with no local end are never passed.
    fn link(&mut self, id: LinkId) -> Result<LinkEnds, TransportError>;
}

/// Default transport: the whole world in one process, links are plain
/// channels — behaviorally identical to the pre-refactor pool.
pub struct InProcTransport {
    world: usize,
}

impl InProcTransport {
    /// A fully in-process world of `world` ranks.
    pub fn new(world: usize) -> Self {
        InProcTransport { world }
    }
}

impl Transport for InProcTransport {
    fn world(&self) -> usize {
        self.world
    }

    fn local_ranks(&self) -> Range<usize> {
        0..self.world
    }

    fn link(&mut self, _id: LinkId) -> Result<LinkEnds, TransportError> {
        let (tx, rx) = chan_link();
        Ok(LinkEnds { tx: Some(tx), rx: Some(rx) })
    }
}

// ---------------------------------------------------------------------------
// endpoint wiring
// ---------------------------------------------------------------------------

/// The RESOLVED exchange schedule [`build_endpoints`] wires — what the
/// pool decided from `CommMode`/`IntraNodeMode` and the topology, not
/// the raw knobs (degenerate topologies resolve to `Flat` before this
/// enum is built).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// One flat world-sized ring.
    Flat,
    /// Hierarchical serialized-leader gather / leader ring / broadcast.
    Leader,
    /// Hierarchical chunked member chain feeding the leader ring.
    Chain,
    /// Bandwidth-optimal 2-level reduce-scatter: intra-node ring
    /// reduce-scatter, per-local-index cross-machine rings, intra-node
    /// allgather.  Requires `machines > 1 && gpus_per_machine > 1`.
    ReduceScatter,
}

/// Per-rank bundle of link ends, one variant per comm-protocol role.
/// This is the boxed-transport successor of the pool's old private
/// `CommWiring` enum; `pool.rs` consumes it in `comm_worker`.
pub enum CommEndpoints {
    /// Flat-ring participant (also the world==1 degenerate case).
    Flat {
        /// Global rank.
        rank: usize,
        /// Ring size (== world).
        ring_size: usize,
        /// Whether ring hops count as network time for metrics.
        net: bool,
        /// To `(rank+1) % world`.
        tx_next: Box<dyn FrameTx>,
        /// From `(rank-1) % world`.
        rx_prev: Box<dyn FrameRx>,
    },
    /// Hierarchical serial-mode node leader.
    Leader {
        /// Machine index.
        machine: usize,
        /// Machine count (leader-ring size).
        machines: usize,
        /// From members, in local-rank order `1..g`.
        member_rxs: Vec<Box<dyn FrameRx>>,
        /// To members, same order.
        member_txs: Vec<Box<dyn FrameTx>>,
        /// Leader ring, to next machine's leader.
        tx_next: Box<dyn FrameTx>,
        /// Leader ring, from previous machine's leader.
        rx_prev: Box<dyn FrameRx>,
    },
    /// Hierarchical serial-mode member.
    Member {
        /// Bucket uploads to the leader.
        to_leader: Box<dyn FrameTx>,
        /// Broadcasts back from the leader.
        from_leader: Box<dyn FrameRx>,
    },
    /// Chunked-chain node leader (local rank 0).
    ChainLeader {
        /// Machine index.
        machine: usize,
        /// Machine count.
        machines: usize,
        /// Pipeline chunk size in elements.
        chunk_elems: usize,
        /// Reduce-forward chunks arriving from local rank 1.
        up_rx: Box<dyn FrameRx>,
        /// Copy-forward chunks departing to local rank 1.
        down_tx: Box<dyn FrameTx>,
        /// Leader ring, to next machine's leader.
        tx_next: Box<dyn FrameTx>,
        /// Leader ring, from previous machine's leader.
        rx_prev: Box<dyn FrameRx>,
    },
    /// Chunked-chain member (local rank `1..g`).
    ChainMember {
        /// Pipeline chunk size in elements.
        chunk_elems: usize,
        /// From local rank `l+1` (None at the chain tail).
        up_rx: Option<Box<dyn FrameRx>>,
        /// To local rank `l-1`.
        up_tx: Box<dyn FrameTx>,
        /// From local rank `l-1`.
        down_rx: Box<dyn FrameRx>,
        /// To local rank `l+1` (None at the chain tail).
        down_tx: Option<Box<dyn FrameTx>>,
    },
    /// 2-level reduce-scatter participant — EVERY rank plays the same
    /// role (there is no leader): it rides the intra-node ring for the
    /// reduce-scatter and allgather phases, and the cross-machine ring
    /// at its own local index for the shard allreduce in between.
    RsNode {
        /// Machine index (cross-ring rank).
        machine: usize,
        /// Machine count (cross-ring size).
        machines: usize,
        /// GPUs per machine (intra-ring size).
        gpus: usize,
        /// Local index within the node (intra-ring rank).
        local: usize,
        /// Intra-node ring, to local `(l+1) % g` ("PCIe").
        intra_tx: Box<dyn FrameTx>,
        /// Intra-node ring, from local `(l-1) % g`.
        intra_rx: Box<dyn FrameRx>,
        /// Cross-machine ring at this local index, to machine
        /// `(M+1) % m` ("network").
        cross_tx: Box<dyn FrameTx>,
        /// Cross-machine ring, from machine `(M-1) % m`.
        cross_rx: Box<dyn FrameRx>,
    },
}

/// Scratch used while distributing link ends to ranks.
#[derive(Default)]
struct Slots {
    tx_next: Option<Box<dyn FrameTx>>,
    rx_prev: Option<Box<dyn FrameRx>>,
    member_rxs: Vec<Box<dyn FrameRx>>,
    member_txs: Vec<Box<dyn FrameTx>>,
    to_leader: Option<Box<dyn FrameTx>>,
    from_leader: Option<Box<dyn FrameRx>>,
    up_rx: Option<Box<dyn FrameRx>>,
    up_tx: Option<Box<dyn FrameTx>>,
    down_rx: Option<Box<dyn FrameRx>>,
    down_tx: Option<Box<dyn FrameTx>>,
    cross_tx: Option<Box<dyn FrameTx>>,
    cross_rx: Option<Box<dyn FrameRx>>,
}

fn need<T>(slot: Option<T>, what: &str) -> Result<T, TransportError> {
    slot.ok_or_else(|| {
        TransportError::Protocol(format!("endpoint wiring missing {what}"))
    })
}

/// Ask `transport` for `id` and drop its ends into the right per-rank
/// slots.  `slots` is keyed by global rank; only local ranks have
/// entries.
fn place(slots: &mut HashMap<usize, Slots>, transport: &mut dyn Transport,
         id: LinkId, local: &Range<usize>) -> Result<(), TransportError> {
    let from_local = local.contains(&(id.from as usize));
    let to_local = local.contains(&(id.to as usize));
    if !from_local && !to_local {
        return Ok(());
    }
    let ends = transport.link(id)?;
    if from_local {
        let tx = need(ends.tx, "tx end of a local-from link")?;
        let s = slots.entry(id.from as usize).or_default();
        match id.kind {
            LinkKind::FlatRing | LinkKind::LeaderRing
            | LinkKind::RsIntra => s.tx_next = Some(tx),
            LinkKind::MemberUp => s.to_leader = Some(tx),
            LinkKind::MemberDown => s.member_txs.push(tx),
            LinkKind::ChainUp => s.up_tx = Some(tx),
            LinkKind::ChainDown => s.down_tx = Some(tx),
            LinkKind::RsCross => s.cross_tx = Some(tx),
        }
    }
    if to_local {
        let rx = need(ends.rx, "rx end of a local-to link")?;
        let s = slots.entry(id.to as usize).or_default();
        match id.kind {
            LinkKind::FlatRing | LinkKind::LeaderRing
            | LinkKind::RsIntra => s.rx_prev = Some(rx),
            LinkKind::MemberUp => s.member_rxs.push(rx),
            LinkKind::MemberDown => s.from_leader = Some(rx),
            LinkKind::ChainUp => s.up_rx = Some(rx),
            LinkKind::ChainDown => s.down_rx = Some(rx),
            LinkKind::RsCross => s.cross_rx = Some(rx),
        }
    }
    Ok(())
}

/// Enumerate the comm graph for `topo` in the canonical global order,
/// pull every link touching a local rank out of `transport`, and
/// assemble one [`CommEndpoints`] per local rank.  `schedule` is the
/// RESOLVED exchange shape (the pool maps `CommMode`/`IntraNodeMode`
/// and the topology to it before calling here).
///
/// The link order is part of the wire protocol: every process walks the
/// same sequence, so socket dial/accept pairs match up without any
/// out-of-band coordination (see `docs/transport.md` for the
/// deadlock-freedom argument).
pub fn build_endpoints(topo: &Topology, schedule: Schedule,
                       chunk_elems: usize, transport: &mut dyn Transport)
                       -> Result<Vec<(usize, CommEndpoints)>, TransportError> {
    let world = topo.world_size();
    if transport.world() != world {
        return Err(TransportError::Protocol(format!(
            "transport world {} != topology world {}",
            transport.world(),
            world
        )));
    }
    let local = transport.local_ranks();
    if local.is_empty() || local.end > world {
        return Err(TransportError::Protocol(format!(
            "transport local ranks {local:?} out of range for world {world}"
        )));
    }
    let g = topo.gpus_per_machine;
    let m = topo.machines;
    if schedule != Schedule::Flat
        && (local.start % g != 0 || local.len() % g != 0)
    {
        return Err(TransportError::Protocol(format!(
            "hierarchical comm needs machine-aligned process splits: \
             local ranks {local:?} vs {g} gpus/machine"
        )));
    }
    if schedule == Schedule::ReduceScatter && (m < 2 || g < 2) {
        // The pool resolves degenerate topologies to Flat before wiring;
        // reaching here with one is a caller bug worth failing loudly.
        return Err(TransportError::Protocol(format!(
            "reduce-scatter schedule needs machines > 1 and \
             gpus/machine > 1, got {m}M{g}G"
        )));
    }

    let mut slots: HashMap<usize, Slots> = HashMap::new();
    for r in local.clone() {
        slots.insert(r, Slots::default());
    }

    match schedule {
        Schedule::Flat => {
            if world > 1 {
                for r in 0..world {
                    let id = LinkId {
                        kind: LinkKind::FlatRing,
                        from: r as u32,
                        to: ((r + 1) % world) as u32,
                    };
                    place(&mut slots, transport, id, &local)?;
                }
            }
        }
        Schedule::Leader | Schedule::Chain => {
            for machine in 0..m {
                let leader = (machine * g) as u32;
                for l in 1..g {
                    let rank = (machine * g + l) as u32;
                    if schedule == Schedule::Leader {
                        place(&mut slots, transport,
                              LinkId { kind: LinkKind::MemberUp,
                                       from: rank, to: leader },
                              &local)?;
                        place(&mut slots, transport,
                              LinkId { kind: LinkKind::MemberDown,
                                       from: leader, to: rank },
                              &local)?;
                    } else {
                        // chain edges between local neighbors l and l-1
                        place(&mut slots, transport,
                              LinkId { kind: LinkKind::ChainUp,
                                       from: rank, to: rank - 1 },
                              &local)?;
                        place(&mut slots, transport,
                              LinkId { kind: LinkKind::ChainDown,
                                       from: rank - 1, to: rank },
                              &local)?;
                    }
                }
            }
            for machine in 0..m {
                let from = (machine * g) as u32;
                let to = (((machine + 1) % m) * g) as u32;
                place(&mut slots, transport,
                      LinkId { kind: LinkKind::LeaderRing, from, to },
                      &local)?;
            }
        }
        Schedule::ReduceScatter => {
            // Intra-node rings first (one g-sized ring per machine),
            // then the g cross-machine rings (one m-sized ring per
            // local index) — one deterministic global order, like every
            // other schedule.
            for machine in 0..m {
                for l in 0..g {
                    let from = (machine * g + l) as u32;
                    let to = (machine * g + (l + 1) % g) as u32;
                    place(&mut slots, transport,
                          LinkId { kind: LinkKind::RsIntra, from, to },
                          &local)?;
                }
            }
            for l in 0..g {
                for machine in 0..m {
                    let from = (machine * g + l) as u32;
                    let to = (((machine + 1) % m) * g + l) as u32;
                    place(&mut slots, transport,
                          LinkId { kind: LinkKind::RsCross, from, to },
                          &local)?;
                }
            }
        }
    }

    // Ring hops count as network time when machine boundaries (or
    // process boundaries) are crossed.
    let flat_net = m > 1 || !transport.fully_local();

    let mut out = Vec::with_capacity(local.len());
    for r in local.clone() {
        let mut s = slots.remove(&r).unwrap_or_default();
        let machine = r / g;
        let l = r % g;
        let ep = match schedule {
            Schedule::Flat => {
                let (tx_next, rx_prev) = if world == 1 {
                    // degenerate ring: never used, but keeps one code
                    // path
                    let (tx, _rx) = chan_link();
                    let (_tx2, rx) = chan_link();
                    (tx, rx)
                } else {
                    (need(s.tx_next.take(), "flat ring tx")?,
                     need(s.rx_prev.take(), "flat ring rx")?)
                };
                CommEndpoints::Flat {
                    rank: r,
                    ring_size: world,
                    net: flat_net,
                    tx_next,
                    rx_prev,
                }
            }
            Schedule::Leader if l == 0 => CommEndpoints::Leader {
                machine,
                machines: m,
                member_rxs: std::mem::take(&mut s.member_rxs),
                member_txs: std::mem::take(&mut s.member_txs),
                tx_next: need(s.tx_next.take(), "leader ring tx")?,
                rx_prev: need(s.rx_prev.take(), "leader ring rx")?,
            },
            Schedule::Leader => CommEndpoints::Member {
                to_leader: need(s.to_leader.take(), "member up tx")?,
                from_leader: need(s.from_leader.take(), "member down rx")?,
            },
            Schedule::Chain if l == 0 => CommEndpoints::ChainLeader {
                machine,
                machines: m,
                chunk_elems,
                up_rx: need(s.up_rx.take(), "chain leader up rx")?,
                down_tx: need(s.down_tx.take(), "chain leader down tx")?,
                tx_next: need(s.tx_next.take(), "leader ring tx")?,
                rx_prev: need(s.rx_prev.take(), "leader ring rx")?,
            },
            Schedule::Chain => CommEndpoints::ChainMember {
                chunk_elems,
                up_rx: s.up_rx.take(), // None at the chain tail
                up_tx: need(s.up_tx.take(), "chain member up tx")?,
                down_rx: need(s.down_rx.take(), "chain member down rx")?,
                down_tx: s.down_tx.take(), // None at the chain tail
            },
            Schedule::ReduceScatter => CommEndpoints::RsNode {
                machine,
                machines: m,
                gpus: g,
                local: l,
                intra_tx: need(s.tx_next.take(), "rs intra ring tx")?,
                intra_rx: need(s.rx_prev.take(), "rs intra ring rx")?,
                cross_tx: need(s.cross_tx.take(), "rs cross ring tx")?,
                cross_rx: need(s.cross_rx.take(), "rs cross ring rx")?,
            },
        };
        out.push((r, ep));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(f: &Frame) -> Frame {
        let mut bytes = Vec::new();
        encode_frame(f, &mut bytes);
        let body_len =
            u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        assert_eq!(body_len, bytes.len() - 4, "length prefix mismatch");
        let mut pool = PayloadPool::default();
        decode_frame(&bytes[4..], &mut pool).expect("decode")
    }

    #[test]
    fn codec_round_trips_every_kind() {
        let frames = vec![
            Frame::RingF32 { tag: 7, data: vec![0.5, -0.5, 3.0] },
            Frame::RingF16 { tag: 107, data: vec![0x3C00, 0xC100, 0] },
            Frame::Bucket { idx: 3, data: vec![0.0, -1.5, 3.25, 65504.0] },
            Frame::Bcast { idx: 2, net_s: 0.125, data: vec![1.0] },
            Frame::Chunk { idx: 3, chunk: 1, net_s: 0.25,
                           data: vec![1.0, -2.0] },
            Frame::Sparse { tag: 204, n: 64, indices: vec![0, 7, 63],
                            values: vec![1.5, -0.25, 8.0] },
        ];
        for f in &frames {
            assert_eq!(&round_trip(f), f);
        }
    }

    #[test]
    fn codec_round_trips_empty_sparse() {
        let f = Frame::Sparse { tag: 200, n: 0, indices: vec![],
                                values: vec![] };
        assert_eq!(round_trip(&f), f);
    }

    #[test]
    fn decode_rejects_truncated_or_skewed_sparse() {
        let mut pool = PayloadPool::default();
        let f = Frame::Sparse { tag: 1, n: 8, indices: vec![2, 5],
                                values: vec![0.5, -1.0] };
        let mut bytes = Vec::new();
        encode_frame(&f, &mut bytes);
        // body with the last value byte cut off: truncated payload
        let body = &bytes[4..];
        let err = decode_frame(&body[..body.len() - 1], &mut pool)
            .expect_err("truncated sparse body must fail");
        assert!(format!("{err}").contains("sparse payload truncated"),
                "got: {err}");
        // count claims one more entry than the body carries
        let mut skew = body.to_vec();
        skew[9..13].copy_from_slice(&3u32.to_le_bytes());
        let err = decode_frame(&skew, &mut pool)
            .expect_err("skewed sparse count must fail");
        assert!(format!("{err}").contains("sparse payload truncated"),
                "got: {err}");
        // a count so large it would overflow the length math
        let mut huge = body.to_vec();
        huge[9..13].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_frame(&huge, &mut pool).is_err());
    }

    #[test]
    fn codec_round_trips_empty_payloads() {
        for f in [
            Frame::RingF32 { tag: 0, data: vec![] },
            Frame::RingF16 { tag: 0, data: vec![] },
            Frame::Bucket { idx: 0, data: vec![] },
        ] {
            assert_eq!(round_trip(&f), f);
        }
    }

    #[test]
    fn codec_preserves_nan_and_inf_bits() {
        let weird = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0];
        let got = round_trip(&Frame::Bucket { idx: 9, data: weird.clone() });
        match got {
            Frame::Bucket { idx, data } => {
                assert_eq!(idx, 9);
                assert_eq!(data.len(), weird.len());
                for (a, b) in data.iter().zip(&weird) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        let mut pool = PayloadPool::default();
        assert!(matches!(decode_frame(&[], &mut pool),
                         Err(TransportError::Protocol(_))));
        assert!(matches!(decode_frame(&[42, 0, 0, 0, 0], &mut pool),
                         Err(TransportError::Protocol(_))));
        // truncated field
        assert!(matches!(decode_frame(&[1, 7], &mut pool),
                         Err(TransportError::Protocol(_))));
        // misaligned payload
        assert!(matches!(decode_frame(&[1, 7, 0, 0, 0, 1, 2, 3], &mut pool),
                         Err(TransportError::Protocol(_))));
    }

    #[test]
    fn link_kind_u8_round_trips() {
        for k in [LinkKind::FlatRing, LinkKind::LeaderRing,
                  LinkKind::MemberUp, LinkKind::MemberDown,
                  LinkKind::ChainUp, LinkKind::ChainDown,
                  LinkKind::RsIntra, LinkKind::RsCross] {
            assert_eq!(LinkKind::from_u8(k.to_u8()).unwrap(), k);
        }
        assert!(LinkKind::from_u8(99).is_err());
    }

    #[test]
    fn payload_pool_recycles_buffers() {
        let mut pool = PayloadPool::default();
        let mut v = pool.take_f32();
        v.extend_from_slice(&[1.0; 64]);
        let cap = v.capacity();
        pool.recycle(Frame::Bucket { idx: 0, data: v });
        let v2 = pool.take_f32();
        assert!(v2.is_empty());
        assert_eq!(v2.capacity(), cap, "buffer was not recycled");
    }

    #[test]
    fn chan_link_moves_frames_and_reports_disconnect() {
        let mut pool = PayloadPool::default();
        let (mut tx, mut rx) = chan_link();
        assert!(!tx.remote() && !rx.remote());
        tx.send(Frame::Bucket { idx: 1, data: vec![2.0] }, &mut pool)
            .unwrap();
        match rx.recv(&mut pool).unwrap() {
            Frame::Bucket { idx, data } => {
                assert_eq!(idx, 1);
                assert_eq!(data, vec![2.0]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        drop(tx);
        assert_eq!(rx.recv(&mut pool), Err(TransportError::Disconnected));
    }

    #[test]
    fn inproc_endpoints_match_flat_topology() {
        let topo = Topology::new(1, 4);
        let mut t = InProcTransport::new(4);
        let eps = build_endpoints(&topo, Schedule::Flat, 1 << 16, &mut t)
            .expect("wiring");
        assert_eq!(eps.len(), 4);
        for (i, (r, ep)) in eps.iter().enumerate() {
            assert_eq!(*r, i);
            match ep {
                CommEndpoints::Flat { rank, ring_size, net, .. } => {
                    assert_eq!(*rank, i);
                    assert_eq!(*ring_size, 4);
                    assert!(!net, "fully-local 1-machine ring is not net");
                }
                _ => panic!("expected flat endpoints"),
            }
        }
    }

    #[test]
    fn inproc_endpoints_match_hierarchical_topology() {
        let topo = Topology::new(2, 2);
        let mut t = InProcTransport::new(4);
        let eps = build_endpoints(&topo, Schedule::Leader, 1 << 16, &mut t)
            .expect("wiring");
        let mut leaders = 0;
        let mut members = 0;
        for (r, ep) in &eps {
            match ep {
                CommEndpoints::Leader { machine, machines,
                                        member_rxs, member_txs, .. } => {
                    assert_eq!(*machine, r / 2);
                    assert_eq!(*machines, 2);
                    assert_eq!(member_rxs.len(), 1);
                    assert_eq!(member_txs.len(), 1);
                    leaders += 1;
                }
                CommEndpoints::Member { .. } => members += 1,
                _ => panic!("unexpected endpoint role"),
            }
        }
        assert_eq!((leaders, members), (2, 2));
    }

    #[test]
    fn inproc_endpoints_match_chain_topology() {
        let topo = Topology::new(2, 3);
        let mut t = InProcTransport::new(6);
        let eps = build_endpoints(&topo, Schedule::Chain, 1 << 10, &mut t)
            .expect("wiring");
        for (r, ep) in &eps {
            match ep {
                CommEndpoints::ChainLeader { chunk_elems, .. } => {
                    assert_eq!(r % 3, 0);
                    assert_eq!(*chunk_elems, 1 << 10);
                }
                CommEndpoints::ChainMember { up_rx, down_tx, .. } => {
                    let tail = r % 3 == 2;
                    assert_eq!(up_rx.is_none(), tail);
                    assert_eq!(down_tx.is_none(), tail);
                }
                _ => panic!("unexpected endpoint role"),
            }
        }
    }

    #[test]
    fn misaligned_hierarchical_split_is_rejected() {
        struct Half;
        impl Transport for Half {
            fn world(&self) -> usize {
                4
            }
            fn local_ranks(&self) -> Range<usize> {
                0..3 // not a multiple of gpus_per_machine=2
            }
            fn link(&mut self, _id: LinkId)
                    -> Result<LinkEnds, TransportError> {
                let (tx, rx) = chan_link();
                Ok(LinkEnds { tx: Some(tx), rx: Some(rx) })
            }
        }
        let topo = Topology::new(2, 2);
        let err = build_endpoints(&topo, Schedule::Leader, 1, &mut Half)
            .err()
            .expect("misaligned split must fail");
        assert!(matches!(err, TransportError::Protocol(_)));
    }

    #[test]
    fn world_mismatch_is_rejected() {
        let topo = Topology::new(1, 4);
        let mut t = InProcTransport::new(2);
        assert!(build_endpoints(&topo, Schedule::Flat, 1, &mut t).is_err());
    }

    #[test]
    fn inproc_endpoints_match_rs_topology() {
        let topo = Topology::new(3, 2);
        let mut t = InProcTransport::new(6);
        let eps =
            build_endpoints(&topo, Schedule::ReduceScatter, 1 << 16, &mut t)
                .expect("wiring");
        assert_eq!(eps.len(), 6);
        for (r, ep) in &eps {
            match ep {
                CommEndpoints::RsNode { machine, machines, gpus,
                                        local, .. } => {
                    assert_eq!(*machine, r / 2);
                    assert_eq!(*machines, 3);
                    assert_eq!(*gpus, 2);
                    assert_eq!(*local, r % 2);
                }
                _ => panic!("expected RsNode endpoints"),
            }
        }
    }

    #[test]
    fn rs_schedule_rejects_degenerate_topologies() {
        // The pool resolves 1-machine / 1-GPU shapes to Flat before
        // wiring; asking for reduce-scatter on one is a loud error.
        for (m, g) in [(1, 4), (4, 1), (1, 1)] {
            let topo = Topology::new(m, g);
            let mut t = InProcTransport::new(m * g);
            let err =
                build_endpoints(&topo, Schedule::ReduceScatter, 1, &mut t)
                    .err()
                    .expect("degenerate rs must fail");
            assert!(matches!(err, TransportError::Protocol(_)));
        }
    }

    #[test]
    fn quantize_matches_f16_cast() {
        let src = [0.0f32, 1.5, -2.25, 65504.0, 1e-8];
        let mut out = Vec::new();
        quantize_f16(&src, &mut out);
        for (&x, &b) in src.iter().zip(&out) {
            assert_eq!(b, F16::from_f32(x).0);
        }
    }
}
