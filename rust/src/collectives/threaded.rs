//! Multi-threaded collectives: real data exchange between worker threads
//! over ring channels — the in-process stand-in for NCCL (DESIGN.md §2).
//!
//! [`CollectiveGroup::new(n)`] wires `n` ranks into a ring of mpsc
//! channels; each worker thread takes its [`GroupHandle`] and calls
//! `allreduce` / `broadcast` / `barrier` exactly like an NCCL
//! communicator.  Messages are chunk vectors; channels are unbounded so
//! the lock-step ring schedule cannot deadlock.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

use super::ring::RingPlan;

/// A message on the ring: (step tag, chunk payload).
type Msg = (u32, Vec<f32>);

/// Factory for the per-rank handles of one communicator group.
pub struct CollectiveGroup;

impl CollectiveGroup {
    /// Create `n` ring-connected handles (index = rank).
    pub fn new(n: usize) -> Vec<GroupHandle> {
        assert!(n >= 1);
        let mut txs: Vec<Option<Sender<Msg>>> = Vec::with_capacity(n);
        let mut rxs: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<Msg>();
            txs.push(Some(tx));
            rxs.push(Some(rx));
        }
        let barrier = Arc::new(Barrier::new(n));
        let mut handles = Vec::with_capacity(n);
        for r in 0..n {
            // rank r sends to (r+1)%n, receives from (r-1+n)%n.
            let tx_next = txs[(r + 1) % n].take().unwrap();
            let rx_prev = rxs[r].replace(unsafe_dummy_rx()).unwrap();
            handles.push(GroupHandle {
                rank: r,
                n,
                tx_next,
                rx_prev,
                barrier: barrier.clone(),
                bytes_sent: 0,
            });
        }
        handles
    }
}

// Placeholder receiver used only during construction (never read).
fn unsafe_dummy_rx() -> Receiver<Msg> {
    channel().1
}

/// One rank's endpoint in a collective group.
pub struct GroupHandle {
    pub rank: usize,
    pub n: usize,
    tx_next: Sender<Msg>,
    rx_prev: Receiver<Msg>,
    barrier: Arc<Barrier>,
    /// Total f32 elements this rank has transmitted (traffic accounting,
    /// checked against the 2(n-1)/n law in tests).
    bytes_sent: usize,
}

impl GroupHandle {
    /// Elementwise-sum allreduce over `buf`, in place.
    ///
    /// NCCL ring algorithm: `n-1` reduce-scatter steps then `n-1`
    /// all-gather steps.  Tags carry the step index as a sanity check
    /// against schedule skew.
    pub fn allreduce(&mut self, buf: &mut [f32]) {
        let n = self.n;
        if n == 1 {
            return;
        }
        let plan = RingPlan::new(n, buf.len());
        // reduce-scatter
        for s in 0..n - 1 {
            let c = plan.send_chunk_rs(self.rank, s);
            let payload = buf[plan.chunk(c)].to_vec();
            self.bytes_sent += payload.len();
            self.tx_next.send((s as u32, payload)).expect("ring send");
            let (tag, data) = self.rx_prev.recv().expect("ring recv");
            debug_assert_eq!(tag, s as u32, "reduce-scatter schedule skew");
            let rc = plan.recv_chunk_rs(self.rank, s);
            for (d, v) in buf[plan.chunk(rc)].iter_mut().zip(data) {
                *d += v;
            }
        }
        // all-gather
        for s in 0..n - 1 {
            let c = plan.send_chunk_ag(self.rank, s);
            let payload = buf[plan.chunk(c)].to_vec();
            self.bytes_sent += payload.len();
            self.tx_next.send((100 + s as u32, payload)).expect("ring send");
            let (tag, data) = self.rx_prev.recv().expect("ring recv");
            debug_assert_eq!(tag, 100 + s as u32, "all-gather schedule skew");
            let rc = plan.recv_chunk_ag(self.rank, s);
            buf[plan.chunk(rc)].copy_from_slice(&data);
        }
    }

    /// Mean-allreduce: sum then divide by world size (gradient averaging).
    pub fn allreduce_mean(&mut self, buf: &mut [f32]) {
        self.allreduce(buf);
        let inv = 1.0 / self.n as f32;
        for v in buf.iter_mut() {
            *v *= inv;
        }
    }

    /// Broadcast `buf` from `root` to all ranks (ring pipeline).
    pub fn broadcast(&mut self, buf: &mut [f32], root: usize) {
        let n = self.n;
        if n == 1 {
            return;
        }
        // Pipeline around the ring: each rank forwards once, the rank
        // just before root terminates.
        let dist = (self.rank + n - root) % n; // hops from root
        if dist == 0 {
            self.bytes_sent += buf.len();
            self.tx_next.send((200, buf.to_vec())).expect("bcast send");
        } else {
            let (_, data) = self.rx_prev.recv().expect("bcast recv");
            buf.copy_from_slice(&data);
            if dist != n - 1 {
                self.bytes_sent += buf.len();
                self.tx_next.send((200, data)).expect("bcast fwd");
            }
        }
        self.barrier.wait();
    }

    /// Block until every rank reaches the barrier.
    pub fn barrier(&self) {
        self.barrier.wait();
    }

    /// Elements transmitted so far by this rank.
    pub fn elements_sent(&self) -> usize {
        self.bytes_sent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;
    use std::thread;

    /// Run `n` worker threads, each applying `f` to its handle + buffer;
    /// returns the final buffers.
    fn run_group(bufs: Vec<Vec<f32>>,
                 f: impl Fn(&mut GroupHandle, &mut Vec<f32>) + Send + Sync
                     + 'static)
                 -> Vec<Vec<f32>> {
        let n = bufs.len();
        let handles = CollectiveGroup::new(n);
        let f = Arc::new(f);
        let joins: Vec<_> = handles
            .into_iter()
            .zip(bufs)
            .map(|(mut h, mut b)| {
                let f = f.clone();
                thread::spawn(move || {
                    f(&mut h, &mut b);
                    (b, h.elements_sent())
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap().0).collect()
    }

    #[test]
    fn threaded_allreduce_matches_serial_sum() {
        let bufs = vec![
            vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0],
            vec![0.5; 7],
        ];
        let mut want = vec![0.0f32; 7];
        for b in &bufs {
            for (w, v) in want.iter_mut().zip(b) {
                *w += v;
            }
        }
        let got = run_group(bufs, |h, b| h.allreduce(b));
        for g in got {
            testkit::assert_allclose(&g, &want, 1e-5, 1e-5);
        }
    }

    #[test]
    fn threaded_matches_reference_implementation() {
        let mut rng = Pcg64::new(0xD0);
        let n = 5;
        let len = 97;
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.next_f32() - 0.5).collect())
            .collect();
        let mut reference = bufs.clone();
        super::super::ring::ring_allreduce_inplace(&mut reference);
        let got = run_group(bufs, |h, b| h.allreduce(b));
        for (g, r) in got.iter().zip(&reference) {
            testkit::assert_allclose(g, r, 1e-5, 1e-5);
        }
    }

    #[test]
    fn allreduce_mean_averages() {
        let bufs = vec![vec![2.0f32; 10], vec![4.0; 10]];
        let got = run_group(bufs, |h, b| h.allreduce_mean(b));
        for g in got {
            testkit::assert_allclose(&g, &vec![3.0; 10], 1e-6, 0.0);
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..4 {
            let bufs: Vec<Vec<f32>> = (0..4)
                .map(|r| vec![r as f32 * 100.0; 6])
                .collect();
            let got = run_group(bufs, move |h, b| h.broadcast(b, root));
            for g in got {
                testkit::assert_allclose(&g, &vec![root as f32 * 100.0; 6],
                                         0.0, 0.0);
            }
        }
    }

    #[test]
    fn traffic_per_rank_follows_ring_law() {
        // 4 ranks, 400 elements: each rank must send exactly
        // 2*(n-1)/n * len = 600 elements for allreduce.
        let n = 4;
        let len = 400;
        let handles = CollectiveGroup::new(n);
        let joins: Vec<_> = handles
            .into_iter()
            .map(|mut h| {
                thread::spawn(move || {
                    let mut b = vec![1.0f32; len];
                    h.allreduce(&mut b);
                    h.elements_sent()
                })
            })
            .collect();
        for j in joins {
            assert_eq!(j.join().unwrap(), 600);
        }
    }

    #[test]
    fn prop_threaded_allreduce_random() {
        testkit::check_msg(
            "threaded-allreduce", 0xD1, 10,
            |r: &mut Pcg64| {
                let n = r.range_usize(2, 7);
                let len = r.range_usize(1, 150);
                (0..n)
                    .map(|_| (0..len).map(|_| r.next_f32() * 4.0 - 2.0)
                        .collect::<Vec<f32>>())
                    .collect::<Vec<_>>()
            },
            |bufs| {
                let mut want = vec![0.0f32; bufs[0].len()];
                for b in bufs {
                    for (w, v) in want.iter_mut().zip(b) {
                        *w += v;
                    }
                }
                let got = run_group(bufs.clone(), |h, b| h.allreduce(b));
                for (i, g) in got.iter().enumerate() {
                    let d = testkit::max_abs_diff(g, &want);
                    if d > 1e-3 {
                        return Err(format!("rank {i} diff {d}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let n = 4;
        let counter = Arc::new(AtomicUsize::new(0));
        let handles = CollectiveGroup::new(n);
        let joins: Vec<_> = handles
            .into_iter()
            .map(|h| {
                let c = counter.clone();
                thread::spawn(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    h.barrier();
                    // after the barrier, everyone must have incremented
                    c.load(Ordering::SeqCst)
                })
            })
            .collect();
        for j in joins {
            assert_eq!(j.join().unwrap(), n);
        }
    }
}
