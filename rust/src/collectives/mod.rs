//! Collective communication (paper §2.2, §4.4): ring allreduce implemented
//! for real over worker threads, plus broadcast/allgather, and the
//! hierarchical (PCIe-then-network) variant.
//!
//! The algorithm is NCCL's: reduce-scatter then all-gather around a ring.
//! Every rank sends exactly `2*(n-1)/n * M` elements, so any single link
//! carries at most one gradient's worth of traffic — the property the
//! paper relies on for linear bandwidth scaling (§2.2).
//!
//! Data movement here is REAL: shared-memory channels between threads
//! by default, and — through the pluggable [`transport`] layer — TCP or
//! Unix sockets between processes (`SocketTransport`), so comm workers
//! can ring across real process and machine boundaries.  Wall-clock
//! timing for cluster-scale runs comes from `netsim`'s analytic model,
//! which `cost` re-exports for the simulator.

pub mod hierarchical;
pub mod pool;
pub mod ring;
pub mod socket;
pub mod threaded;
pub mod transport;

pub use hierarchical::hierarchical_allreduce_inplace;
pub use pool::{CollectivePool, CommMode, MicroStats, RankCompute,
               StepOutcome, WireFormat};
pub use ring::{ring_allreduce_inplace, RingPlan};
pub use socket::{RendezvousStamp, SocketTransport};
pub use threaded::{CollectiveGroup, GroupHandle};
pub use transport::{Frame, InProcTransport, Transport, TransportError};

use crate::netsim::{Fabric, LinkModel};
use crate::topology::Topology;

/// Analytic cost of the collective used by the simulator; thin wrapper
/// over `netsim` so callers only import one module.
pub fn allreduce_cost(topo: &Topology, bytes: f64, fabric: &Fabric,
                      hierarchical: bool) -> f64 {
    if hierarchical && topo.machines > 1 && topo.gpus_per_machine > 1 {
        crate::netsim::hierarchical_allreduce_time(topo, bytes, fabric)
    } else {
        let link: LinkModel = fabric.ring_bottleneck(topo);
        crate::netsim::ring_allreduce_time(topo.world_size(), bytes, link)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_zero_for_single_device() {
        let topo = Topology::new(1, 1);
        assert_eq!(allreduce_cost(&topo, 1e9, &Fabric::paper(), false), 0.0);
    }

    #[test]
    fn cost_increases_with_world_size_payload() {
        let f = Fabric::paper();
        let t2 = allreduce_cost(&Topology::new(2, 1), 1e8, &f, false);
        let t2b = allreduce_cost(&Topology::new(2, 1), 2e8, &f, false);
        assert!(t2b > t2);
    }
}
