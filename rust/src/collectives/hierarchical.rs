//! Hierarchical allreduce executor (paper §4.4 resource separation):
//! reduce within each node over "PCIe", ring-allreduce across node
//! leaders over the "network", then broadcast back within each node.
//!
//! This is the data-movement schedule NCCL uses on multi-GPU nodes with
//! a single NIC; the traffic crossing the network is `2(M−1)/M · bytes`
//! regardless of the per-node GPU count — the property that makes the
//! 10 Gb/s bottleneck survivable.  The result must equal the flat ring
//! to rounding (property-tested below — the summation association is
//! machine-grouped, so bitwise equality holds exactly when the sums are
//! exactly representable); only *where bytes travel* differs, which
//! `netsim::hierarchical_allreduce_phases` prices phase by phase.
//!
//! This function is the offline single-threaded ORACLE.  The live,
//! pooled version of the same schedule — leader accumulate over
//! per-node channels, leader ring, broadcast — runs on the persistent
//! comm workers in [`super::pool`] (`CommMode::Hierarchical`), and is
//! property-tested against both this oracle's schedule and the flat
//! ring in `tests/pool_overlap.rs`.

use super::ring::ring_allreduce_inplace;
use crate::topology::Topology;

/// Execute hierarchical allreduce over per-device buffers laid out in
/// rank order (machine-major).  All buffers end up holding the global
/// elementwise sum.
pub fn hierarchical_allreduce_inplace(topo: &Topology,
                                      bufs: &mut [Vec<f32>]) {
    let world = topo.world_size();
    assert_eq!(bufs.len(), world, "need one buffer per device");
    if world <= 1 {
        return;
    }
    let g = topo.gpus_per_machine;
    let m = topo.machines;
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "ragged buffers");

    // Phase 1 — intra-node reduce to the local leader (PCIe traffic):
    // leader (local rank 0) accumulates its node's buffers.
    for machine in 0..m {
        let base = machine * g;
        for local in 1..g {
            let (head, tail) = bufs.split_at_mut(base + local);
            let leader = &mut head[base];
            for (d, s) in leader.iter_mut().zip(&tail[0]) {
                *d += s;
            }
        }
    }

    // Phase 2 — inter-node ring allreduce over the leaders (network).
    if m > 1 {
        let mut leader_bufs: Vec<Vec<f32>> = (0..m)
            .map(|machine| std::mem::take(&mut bufs[machine * g]))
            .collect();
        ring_allreduce_inplace(&mut leader_bufs);
        for (machine, lb) in leader_bufs.into_iter().enumerate() {
            bufs[machine * g] = lb;
        }
    }

    // Phase 3 — intra-node broadcast from the leader (PCIe traffic).
    for machine in 0..m {
        let base = machine * g;
        let (head, tail) = bufs.split_at_mut(base + 1);
        let leader = &head[base];
        for local in 0..g - 1 {
            tail[local].copy_from_slice(leader);
        }
    }
}

/// Bytes a single node's NIC carries under each scheme, for a payload
/// of `bytes` — the §4.4 accounting that justifies the hierarchy.
pub fn nic_bytes_per_node(topo: &Topology, bytes: f64,
                          hierarchical: bool) -> f64 {
    let m = topo.machines;
    if m <= 1 {
        return 0.0;
    }
    if hierarchical {
        // leader ring over m nodes: send 2(m-1)/m of the payload
        2.0 * (m as f64 - 1.0) / m as f64 * bytes
    } else {
        // flat ring over world ranks, machine-major: the single network
        // hop per node carries 2(n-1)/n of the payload too — same
        // bandwidth, but lockstep with (g-1) PCIe hops per step.
        let n = topo.world_size() as f64;
        2.0 * (n - 1.0) / n * bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;

    fn serial_sum(bufs: &[Vec<f32>]) -> Vec<f32> {
        let mut out = vec![0.0f32; bufs[0].len()];
        for b in bufs {
            for (o, v) in out.iter_mut().zip(b) {
                *o += v;
            }
        }
        out
    }

    #[test]
    fn matches_serial_sum_2m2g() {
        let topo = Topology::new(2, 2);
        let mut bufs: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..10).map(|i| (r * 100 + i) as f32).collect())
            .collect();
        let want = serial_sum(&bufs);
        hierarchical_allreduce_inplace(&topo, &mut bufs);
        for b in &bufs {
            testkit::assert_allclose(b, &want, 1e-5, 1e-6);
        }
    }

    #[test]
    fn single_device_noop() {
        let topo = Topology::new(1, 1);
        let mut bufs = vec![vec![1.0, 2.0]];
        hierarchical_allreduce_inplace(&topo, &mut bufs);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }

    #[test]
    fn single_node_many_gpus() {
        let topo = Topology::new(1, 4);
        let mut bufs: Vec<Vec<f32>> =
            (0..4).map(|r| vec![r as f32 + 1.0; 5]).collect();
        hierarchical_allreduce_inplace(&topo, &mut bufs);
        for b in &bufs {
            testkit::assert_allclose(b, &vec![10.0; 5], 1e-6, 0.0);
        }
    }

    #[test]
    fn prop_hierarchical_equals_flat_ring() {
        testkit::check_msg(
            "hier=flat", 0x41E2, 40,
            |r: &mut Pcg64| {
                let m = r.range_usize(1, 5);
                let g = r.range_usize(1, 5);
                let len = r.range_usize(1, 120);
                let bufs: Vec<Vec<f32>> = (0..m * g)
                    .map(|_| (0..len).map(|_| r.next_f32() * 2.0 - 1.0)
                        .collect())
                    .collect();
                (m, g, bufs)
            },
            |(m, g, bufs)| {
                let topo = Topology::new(*m, *g);
                let mut flat = bufs.clone();
                ring_allreduce_inplace(&mut flat);
                let mut hier = bufs.clone();
                hierarchical_allreduce_inplace(&topo, &mut hier);
                for (rank, (a, b)) in hier.iter().zip(&flat).enumerate() {
                    let d = testkit::max_abs_diff(a, b);
                    if d > 1e-3 {
                        return Err(format!("rank {rank} diff {d}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nic_traffic_accounting() {
        let topo = Topology::new(32, 8);
        let bytes = 1.345e9;
        let hier = nic_bytes_per_node(&topo, bytes, true);
        let flat = nic_bytes_per_node(&topo, bytes, false);
        // both ~2x payload; hierarchical is slightly lower (m vs n terms)
        assert!(hier < flat);
        assert!((hier / bytes - 2.0 * 31.0 / 32.0).abs() < 1e-9);
        assert_eq!(nic_bytes_per_node(&Topology::new(1, 8), bytes, true),
                   0.0);
    }
}
