//! Ring allreduce chunk plan + a single-threaded reference executor.
//!
//! [`RingPlan`] computes the chunk boundaries each rank owns; the
//! reduce-scatter phase walks `n-1` steps where rank r sends chunk
//! `(r - step) mod n` to its successor, the all-gather phase walks the
//! reduced chunks back around.  [`ring_allreduce_inplace`] executes the
//! schedule over borrowed buffers — it is the oracle the threaded
//! implementation is property-tested against, and doubles as the
//! in-process path when world_size == 1.

/// Chunk boundaries for a ring of `n` ranks over a buffer of `len`.
#[derive(Debug, Clone)]
pub struct RingPlan {
    pub n: usize,
    pub len: usize,
    bounds: Vec<usize>, // n+1 entries
}

impl RingPlan {
    pub fn new(n: usize, len: usize) -> Self {
        assert!(n >= 1);
        // Chunks are as even as possible; the first `len % n` chunks get
        // one extra element.
        let base = len / n;
        let extra = len % n;
        let mut bounds = Vec::with_capacity(n + 1);
        let mut off = 0;
        bounds.push(0);
        for i in 0..n {
            off += base + usize::from(i < extra);
            bounds.push(off);
        }
        Self { n, len, bounds }
    }

    /// Element range of chunk `c`.
    pub fn chunk(&self, c: usize) -> std::ops::Range<usize> {
        self.bounds[c]..self.bounds[c + 1]
    }

    /// Chunk index rank `r` SENDS at reduce-scatter step `s` (0-based).
    pub fn send_chunk_rs(&self, r: usize, s: usize) -> usize {
        (r + self.n - s) % self.n
    }

    /// Chunk index rank `r` RECEIVES (and reduces) at step `s`.
    pub fn recv_chunk_rs(&self, r: usize, s: usize) -> usize {
        // the predecessor's send chunk
        (r + self.n - 1 - s) % self.n
    }

    /// Chunk rank `r` sends at all-gather step `s`: the fully-reduced
    /// chunk it owns after reduce-scatter, rotating around.
    pub fn send_chunk_ag(&self, r: usize, s: usize) -> usize {
        (r + 1 + self.n - s) % self.n
    }

    /// Chunk rank `r` receives at all-gather step `s`.
    pub fn recv_chunk_ag(&self, r: usize, s: usize) -> usize {
        (r + self.n - s) % self.n
    }

    /// Total ELEMENTS rank `r` transmits over the full schedule —
    /// roughly `2*(n-1)/n * len`, but uneven chunk splits give ranks
    /// different totals (a rank repeatedly sending the `+1`-sized
    /// chunks transmits more).  Multiply by the element width to get
    /// bytes.
    pub fn elems_sent(&self, r: usize) -> usize {
        if self.n == 1 {
            return 0;
        }
        let mut total = 0;
        for s in 0..self.n - 1 {
            total += self.chunk(self.send_chunk_rs(r, s)).len();
            total += self.chunk(self.send_chunk_ag(r, s)).len();
        }
        total
    }
}

/// Execute ring allreduce (sum) over `bufs` in place — every buffer ends
/// up holding the elementwise sum.  Single-threaded reference: the
/// schedule is executed step-by-step exactly as the threaded version
/// does, including chunk ordering.
pub fn ring_allreduce_inplace(bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "ragged buffers");
    let plan = RingPlan::new(n, len);

    // reduce-scatter: after n-1 steps, rank r owns the full sum of chunk
    // (r+1) % n.
    for s in 0..n - 1 {
        // simultaneous exchange: gather all messages first, then apply.
        let msgs: Vec<(usize, usize, Vec<f32>)> = (0..n)
            .map(|r| {
                let c = plan.send_chunk_rs(r, s);
                let rng = plan.chunk(c);
                ((r + 1) % n, c, bufs[r][rng].to_vec())
            })
            .collect();
        for (dst, c, data) in msgs {
            let rng = plan.chunk(c);
            for (d, v) in bufs[dst][rng].iter_mut().zip(data) {
                *d += v;
            }
        }
    }
    // all-gather: rotate the reduced chunks around the ring.
    for s in 0..n - 1 {
        let msgs: Vec<(usize, usize, Vec<f32>)> = (0..n)
            .map(|r| {
                let c = plan.send_chunk_ag(r, s);
                let rng = plan.chunk(c);
                ((r + 1) % n, c, bufs[r][rng].to_vec())
            })
            .collect();
        for (dst, c, data) in msgs {
            let rng = plan.chunk(c);
            bufs[dst][rng].copy_from_slice(&data);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;

    fn serial_sum(bufs: &[Vec<f32>]) -> Vec<f32> {
        let len = bufs[0].len();
        let mut out = vec![0.0f32; len];
        for b in bufs {
            for (o, v) in out.iter_mut().zip(b) {
                *o += v;
            }
        }
        out
    }

    #[test]
    fn plan_chunks_partition_buffer() {
        for (n, len) in [(1, 10), (3, 10), (4, 4), (5, 23), (8, 8), (7, 3)] {
            let p = RingPlan::new(n, len);
            let mut covered = 0;
            for c in 0..n {
                covered += p.chunk(c).len();
            }
            assert_eq!(covered, len, "n={n} len={len}");
            assert_eq!(p.chunk(0).start, 0);
            assert_eq!(p.chunk(n - 1).end, len);
        }
    }

    #[test]
    fn schedule_send_recv_consistent() {
        // What rank r+1 receives at step s is what rank r sends.
        let p = RingPlan::new(5, 50);
        for s in 0..4 {
            for r in 0..5 {
                assert_eq!(p.send_chunk_rs(r, s), p.recv_chunk_rs((r + 1) % 5, s));
                assert_eq!(p.send_chunk_ag(r, s), p.recv_chunk_ag((r + 1) % 5, s));
            }
        }
    }

    #[test]
    fn traffic_matches_2nm1_over_n() {
        // Each rank transmits 2*(n-1)/n of the payload in ELEMENTS
        // (paper §2.2) when chunks divide evenly — and every rank the
        // same amount.
        let p = RingPlan::new(4, 400);
        for r in 0..4 {
            assert_eq!(p.elems_sent(r), 2 * 3 * 100);
        }
        let p1 = RingPlan::new(1, 100);
        assert_eq!(p1.elems_sent(0), 0);
        // Uneven split: per-rank totals differ but each stays within
        // one chunk of the even-share estimate, and the schedule-wide
        // total is exactly 2*(n-1)*len.
        let pu = RingPlan::new(4, 10); // chunks 3,3,2,2
        let total: usize = (0..4).map(|r| pu.elems_sent(r)).sum();
        assert_eq!(total, 2 * 3 * 10);
        assert!((0..4).any(|r| pu.elems_sent(r) != pu.elems_sent(0)));
    }

    #[test]
    fn allreduce_equals_serial_sum_basic() {
        let mut bufs = vec![
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0],
            vec![100.0, 200.0, 300.0, 400.0, 500.0],
        ];
        let want = serial_sum(&bufs);
        ring_allreduce_inplace(&mut bufs);
        for b in &bufs {
            testkit::assert_allclose(b, &want, 1e-6, 1e-6);
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let mut bufs = vec![vec![1.0, -2.0, 3.5]];
        ring_allreduce_inplace(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, -2.0, 3.5]);
    }

    #[test]
    fn prop_allreduce_equals_serial_sum() {
        testkit::check_msg(
            "ring-allreduce=sum", 0xC0, 48,
            |r: &mut Pcg64| {
                let n = r.range_usize(1, 9);
                let len = r.range_usize(1, 200);
                let bufs: Vec<Vec<f32>> = (0..n)
                    .map(|_| (0..len)
                        .map(|_| (r.next_f32() - 0.5) * 10.0)
                        .collect())
                    .collect();
                bufs
            },
            |bufs| {
                let want = serial_sum(bufs);
                let mut got = bufs.clone();
                ring_allreduce_inplace(&mut got);
                for (r, b) in got.iter().enumerate() {
                    let d = testkit::max_abs_diff(b, &want);
                    if d > 1e-3 {
                        return Err(format!("rank {r} off by {d}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_chunk_len_when_smaller_than_ranks() {
        // len < n: some chunks are empty but the sum must still be exact.
        testkit::check(
            "ring-small-buffers", 0xC1, 32,
            |r: &mut Pcg64| (r.range_usize(2, 12), r.range_usize(1, 6)),
            |&(n, len)| {
                let mut bufs: Vec<Vec<f32>> =
                    (0..n).map(|i| vec![i as f32 + 1.0; len]).collect();
                let want: f32 = (1..=n).map(|i| i as f32).sum();
                ring_allreduce_inplace(&mut bufs);
                bufs.iter().all(|b| b.iter().all(|&v| (v - want).abs() < 1e-4))
            },
        );
    }
}
