//! `SocketTransport`: the collective pool over real processes.
//!
//! Frames travel as the v1 length-prefixed binary layout from
//! [`super::transport`] over TCP (`host:port`) or Unix domain sockets
//! (`unix:/path` or any address containing `/`).  Each directed
//! comm-graph edge gets its own connection, opened lazily when
//! [`Transport::link`] reaches that edge and identified by a handshake
//! (`magic, version, kind, from, to`), so accept order never has to
//! match dial order.
//!
//! # Peer discovery
//!
//! Two modes, mirroring torchrun's static and rendezvous launch:
//!
//! * **host list** — every process is started with `--listen <addr>
//!   --connect <addr0,addr1,...>`; the position of its own listen
//!   address in the (identical) list is its process index.
//! * **rendezvous file** — every process is started with `--listen
//!   <addr> --rendezvous <file> --nprocs <n>`; each appends its own
//!   address (one line, `O_APPEND` so lines never interleave) and polls
//!   until `n` lines exist.  Line order assigns process indices.
//!
//! Rendezvous files carry a sidecar stamp (`<file>.run`) holding the
//! run fingerprint and a **generation** counter.  A leftover file from
//! a different run fails loudly ([`TransportError::StaleRendezvous`])
//! instead of being silently reused, and the supervised rejoin path
//! bumps the generation to republish the world at a restart boundary —
//! see [`SocketTransport::rejoin`].
//!
//! The world is split contiguously and evenly across processes:
//! process `i` of `p` hosts global ranks `i*world/p .. (i+1)*world/p`.
//!
//! # Handshake authentication (`--net-key`)
//!
//! With a key set ([`SocketTransport::set_auth`]), dials send the
//! authenticated v2 handshake: the 14 v1 fields (version byte 2), an
//! 8-byte per-run nonce, and a 16-byte keyed BLAKE2s MAC over both.
//! Accepts verify the MAC and nonce, so a stale process from an
//! earlier generation or a foreign job on a shared network is rejected
//! with a named error before it can touch an exchange.  Without a key,
//! the unauthenticated v1 handshake is sent and accepted as before.
//!
//! # Why sends go through a writer thread
//!
//! In-process links are unbounded channels, so a ring rank can send its
//! hop before blocking on its receive.  A naive blocking `write_all`
//! breaks that: with payloads larger than the kernel socket buffers,
//! every rank can block mid-send while its neighbor also blocks
//! mid-send — classic ring deadlock.  [`SocketTx`] therefore hands
//! serialized frames to a per-link writer thread over a **bounded**
//! queue of [`SEND_QUEUE_FRAMES`] frames.  The lock-step ring/chain
//! schedules keep only a handful of frames in flight per link, far
//! below the bound, so `send` stays non-blocking on the healthy path;
//! a full queue means a genuinely congested or stalled peer, and the
//! sender then waits in a polled loop whose time is charged to the
//! link's backpressure counter ([`FrameTx::take_backpressure_s`]) and
//! bounded by the net timeout — a congested peer stalls *visibly*
//! instead of growing the writer queue without bound.  Drained byte
//! buffers come back over a scratch channel so the steady state
//! allocates nothing.  Dropping a `SocketTx` closes the queue and
//! joins the writer, flushing any in-flight frames before process exit
//! (the final all-gather hop must not be lost).
//!
//! # Failure behavior
//!
//! Receives use `SO_RCVTIMEO` from `train.net_timeout_s`: a peer that
//! stops sending surfaces [`TransportError::Timeout`] instead of
//! hanging the survivor, and a closed connection surfaces
//! [`TransportError::Disconnected`].  Both `remote()` bits are true, so
//! the pool's protocols propagate (never tolerate) remote failures.
//! Dials retry on a deterministic bounded-exponential backoff schedule
//! (`--net-retries` / `--net-backoff-ms`) instead of a blind poll.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::mpsc::{
    channel, sync_channel, Receiver, SyncSender, TryRecvError, TrySendError,
};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::transport::{
    decode_frame, encode_frame, Frame, FrameRx, FrameTx, LinkEnds, LinkId,
    LinkKind, PayloadPool, Transport, TransportError, HANDSHAKE_MAGIC,
    MAX_FRAME, WIRE_VERSION,
};
use crate::util::blake2s;

/// Poll interval while waiting for accepts, rendezvous lines, or a
/// listening peer.
const POLL: Duration = Duration::from_millis(10);

/// Poll interval while a full send queue drains; backpressure events
/// are rare, so the granularity only bounds the accounting jitter.
const SEND_POLL: Duration = Duration::from_micros(500);

/// Floor on the connection-setup deadline: peers may start seconds
/// apart, so setup gets at least this long even with a tight frame
/// timeout.
const MIN_SETUP: Duration = Duration::from_secs(10);

/// Per-link bound on serialized-but-unwritten frames.  The ring/chain
/// schedules keep at most a few frames in flight per link, so the
/// healthy path never fills this; see the module docs.
const SEND_QUEUE_FRAMES: usize = 64;

/// Cap on one dial-backoff sleep, so the schedule stays responsive
/// even after many doublings.
const MAX_BACKOFF_MS: u64 = 500;

/// Version byte of the authenticated handshake.
const WIRE_VERSION_AUTH: u8 = 2;

fn io_err(e: std::io::Error) -> TransportError {
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
            // callers with a real timeout override this value
            TransportError::Timeout(0.0)
        }
        ErrorKind::UnexpectedEof
        | ErrorKind::ConnectionReset
        | ErrorKind::ConnectionAborted
        | ErrorKind::BrokenPipe => TransportError::Disconnected,
        _ => TransportError::Io(e.to_string()),
    }
}

/// True when `addr` names a Unix socket path rather than `host:port`.
fn is_unix(addr: &str) -> bool {
    addr.starts_with("unix:") || addr.contains('/')
}

/// Strip the optional `unix:` prefix.
fn unix_path(addr: &str) -> &str {
    addr.strip_prefix("unix:").unwrap_or(addr)
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn bind(addr: &str) -> Result<(Listener, String), TransportError> {
        if is_unix(addr) {
            #[cfg(unix)]
            {
                let path = unix_path(addr);
                // a stale socket file from a crashed run blocks bind
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path).map_err(|e| {
                    TransportError::Io(format!("bind {addr}: {e}"))
                })?;
                return Ok((Listener::Unix(l), format!("unix:{path}")));
            }
            #[cfg(not(unix))]
            return Err(TransportError::Protocol(format!(
                "unix socket address {addr} unsupported on this platform"
            )));
        }
        let l = TcpListener::bind(addr)
            .map_err(|e| TransportError::Io(format!("bind {addr}: {e}")))?;
        let actual = l
            .local_addr()
            .map_err(|e| TransportError::Io(e.to_string()))?;
        Ok((Listener::Tcp(l), actual.to_string()))
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                Ok(Stream::Unix(s))
            }
        }
    }
}

enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn connect(addr: &str) -> std::io::Result<Stream> {
        if is_unix(addr) {
            #[cfg(unix)]
            {
                return Ok(Stream::Unix(UnixStream::connect(unix_path(addr))?));
            }
            #[cfg(not(unix))]
            return Err(std::io::Error::new(
                ErrorKind::Unsupported,
                "unix sockets unsupported on this platform",
            ));
        }
        let s = TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        Ok(Stream::Tcp(s))
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nb),
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(d),
        }
    }

    fn read_exact(&mut self, buf: &mut [u8]) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.read_exact(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read_exact(buf),
        }
    }

    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.write_all(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write_all(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// `[magic u32][version u8][kind u8][from u32][to u32]`, little-endian.
const HANDSHAKE_LEN: usize = 14;

/// v2 adds `[nonce: 8 bytes][mac: 16 bytes]`; the MAC is keyed BLAKE2s
/// over the first 22 bytes (fields + nonce).
const HANDSHAKE_AUTH_LEN: usize = HANDSHAKE_LEN + 8 + 16;

/// Key + per-run nonce for the authenticated handshake.
struct HandshakeAuth {
    key: Vec<u8>,
    nonce: [u8; 8],
}

fn encode_handshake(id: LinkId) -> [u8; HANDSHAKE_LEN] {
    let mut b = [0u8; HANDSHAKE_LEN];
    b[0..4].copy_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    b[4] = WIRE_VERSION;
    b[5] = id.kind.to_u8();
    b[6..10].copy_from_slice(&id.from.to_le_bytes());
    b[10..14].copy_from_slice(&id.to.to_le_bytes());
    b
}

fn encode_handshake_auth(id: LinkId, auth: &HandshakeAuth)
                         -> [u8; HANDSHAKE_AUTH_LEN] {
    let mut b = [0u8; HANDSHAKE_AUTH_LEN];
    b[0..4].copy_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    b[4] = WIRE_VERSION_AUTH;
    b[5] = id.kind.to_u8();
    b[6..10].copy_from_slice(&id.from.to_le_bytes());
    b[10..14].copy_from_slice(&id.to.to_le_bytes());
    b[14..22].copy_from_slice(&auth.nonce);
    let mac = blake2s::mac16(&auth.key, &b[..22]);
    b[22..38].copy_from_slice(&mac);
    b
}

/// Parse the `kind, from, to` fields shared by both handshake versions.
fn decode_link_fields(b: &[u8]) -> Result<LinkId, TransportError> {
    Ok(LinkId {
        kind: LinkKind::from_u8(b[5])?,
        from: u32::from_le_bytes(b[6..10].try_into().unwrap()),
        to: u32::from_le_bytes(b[10..14].try_into().unwrap()),
    })
}

fn decode_handshake(b: &[u8; HANDSHAKE_LEN]) -> Result<LinkId, TransportError> {
    let magic = u32::from_le_bytes(b[0..4].try_into().unwrap());
    if magic != HANDSHAKE_MAGIC {
        return Err(TransportError::Protocol(format!(
            "bad handshake magic {magic:#x}"
        )));
    }
    if b[4] != WIRE_VERSION {
        return Err(TransportError::Protocol(format!(
            "wire version {} != {}",
            b[4], WIRE_VERSION
        )));
    }
    decode_link_fields(b)
}

/// The run fingerprint + generation a rendezvous file is stamped with.
///
/// `min_generation` is the lowest epoch this process will join: a fresh
/// process passes 0 and **adopts** whatever generation the sidecar
/// holds, while a survivor republishing after a peer loss passes the
/// bumped epoch so leftovers from earlier generations fail loudly.
/// `window_s` overrides the setup deadline during a rejoin (the
/// `--rejoin-window`); `None` keeps the normal setup deadline.
#[derive(Clone, Debug)]
pub struct RendezvousStamp {
    pub run_id: [u8; 8],
    pub min_generation: u64,
    pub window_s: Option<f64>,
}

fn hex8(b: &[u8; 8]) -> String {
    b.iter().map(|x| format!("{x:02x}")).collect()
}

fn parse_hex8(s: &str) -> Option<[u8; 8]> {
    if s.len() != 16 {
        return None;
    }
    let mut out = [0u8; 8];
    for (i, o) in out.iter_mut().enumerate() {
        *o = u8::from_str_radix(&s[2 * i..2 * i + 2], 16).ok()?;
    }
    Some(out)
}

/// Path of the sidecar stamp next to a rendezvous file.
pub fn stamp_path(file: &str) -> String {
    format!("{file}.run")
}

/// Read the `run=<hex> gen=<n>` sidecar stamp; `None` when absent.
pub fn read_stamp(file: &str)
                  -> Result<Option<([u8; 8], u64)>, TransportError> {
    let path = stamp_path(file);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(TransportError::Io(format!("stamp {path}: {e}")))
        }
    };
    let mut run = None;
    let mut gen = None;
    for tok in text.split_whitespace() {
        if let Some(v) = tok.strip_prefix("run=") {
            run = parse_hex8(v);
        } else if let Some(v) = tok.strip_prefix("gen=") {
            gen = v.parse::<u64>().ok();
        }
    }
    match (run, gen) {
        (Some(r), Some(g)) => Ok(Some((r, g))),
        _ => Err(TransportError::StaleRendezvous(format!(
            "malformed rendezvous stamp {path}: {text:?}"
        ))),
    }
}

/// Atomically (tmp + rename) write the sidecar stamp.
pub fn write_stamp(file: &str, run_id: [u8; 8], generation: u64)
                   -> Result<(), TransportError> {
    let path = stamp_path(file);
    let tmp = format!("{path}.tmp{}", std::process::id());
    let body = format!("run={} gen={generation}\n", hex8(&run_id));
    std::fs::write(&tmp, body)
        .map_err(|e| TransportError::Io(format!("stamp {tmp}: {e}")))?;
    std::fs::rename(&tmp, &path)
        .map_err(|e| TransportError::Io(format!("stamp {path}: {e}")))
}

/// Claim or validate the stamp, append our address, poll until the
/// world is full, and derive our process index.  Shared by first-time
/// construction and in-place [`SocketTransport::rejoin`].
fn rendezvous_join(file: &str, nprocs: usize, actual: &str, timeout_s: f64,
                   stamp: Option<&RendezvousStamp>)
                   -> Result<(Vec<String>, usize, u64), TransportError> {
    let generation = match stamp {
        None => 0,
        Some(st) => match read_stamp(file)? {
            None => {
                // first process of the run claims the file; a racing
                // same-run peer writes identical bytes, and a racing
                // foreign run is caught by the address-count check
                write_stamp(file, st.run_id, st.min_generation)?;
                st.min_generation
            }
            Some((run, gen)) => {
                if run != st.run_id {
                    return Err(TransportError::StaleRendezvous(format!(
                        "rendezvous file {file} is stamped for a different \
                         run (run {} != {}); delete it or pass a fresh \
                         --rendezvous path",
                        hex8(&run),
                        hex8(&st.run_id)
                    )));
                }
                if gen < st.min_generation {
                    return Err(TransportError::StaleRendezvous(format!(
                        "rendezvous file {file} is at generation {gen} but \
                         this process expects epoch {}; stale stamp from an \
                         earlier generation?",
                        st.min_generation
                    )));
                }
                gen
            }
        },
    };
    {
        use std::fs::OpenOptions;
        let mut f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(file)
            .map_err(|e| {
                TransportError::Io(format!("rendezvous {file}: {e}"))
            })?;
        // one O_APPEND write per process: lines never interleave
        writeln!(f, "{actual}").map_err(|e| {
            TransportError::Io(format!("rendezvous {file}: {e}"))
        })?;
    }
    let window = stamp.and_then(|s| s.window_s);
    let deadline = Instant::now()
        + match window {
            Some(w) => Duration::from_secs_f64(w.max(0.0)),
            None => Duration::from_secs_f64(timeout_s).max(MIN_SETUP),
        };
    let peers = loop {
        let text = std::fs::read_to_string(file).map_err(|e| {
            TransportError::Io(format!("rendezvous {file}: {e}"))
        })?;
        let lines: Vec<String> = text
            .lines()
            .map(|l| l.trim().to_string())
            .filter(|l| !l.is_empty())
            .collect();
        if lines.len() >= nprocs {
            break lines;
        }
        if Instant::now() > deadline {
            return Err(match window {
                Some(w) => TransportError::Protocol(format!(
                    "rejoin window expired after {w:.1}s: {}/{nprocs} peers \
                     republished to {file}",
                    lines.len()
                )),
                None => TransportError::Timeout(timeout_s),
            });
        }
        std::thread::sleep(POLL);
    };
    if peers.len() > nprocs {
        return Err(TransportError::Protocol(format!(
            "rendezvous file {file} has {} addresses for --nprocs \
             {nprocs}; stale file from a previous run?",
            peers.len()
        )));
    }
    let mine: Vec<usize> = peers
        .iter()
        .enumerate()
        .filter(|(_, p)| **p == actual)
        .map(|(i, _)| i)
        .collect();
    let index = match mine.as_slice() {
        [i] => *i,
        [] => {
            return Err(TransportError::Protocol(format!(
                "own address {actual} missing from rendezvous file \
                 {file}"
            )))
        }
        _ => {
            return Err(TransportError::Protocol(format!(
                "own address {actual} appears twice in rendezvous file \
                 {file}; stale file from a previous run?"
            )))
        }
    };
    Ok((peers, index, generation))
}

/// Sending half of a socket link; see the module docs for why writes
/// run on their own thread and when `send` may stall.
pub struct SocketTx {
    queue: Option<SyncSender<Vec<u8>>>,
    scratch: Receiver<Vec<u8>>,
    handle: Option<JoinHandle<()>>,
    timeout_s: f64,
    backpressure_s: f64,
}

impl SocketTx {
    fn spawn(mut stream: Stream, id: LinkId, timeout_s: f64) -> SocketTx {
        let (q_tx, q_rx) = sync_channel::<Vec<u8>>(SEND_QUEUE_FRAMES);
        let (back_tx, back_rx) = channel::<Vec<u8>>();
        let handle = std::thread::Builder::new()
            .name(format!("net-tx-{}-{}", id.from, id.to))
            .spawn(move || {
                while let Ok(buf) = q_rx.recv() {
                    if stream.write_all(&buf).is_err() {
                        // peer gone: drain silently; send() learns of
                        // the death when the queue closes on our exit
                        break;
                    }
                    let _ = back_tx.send(buf);
                }
                let _ = stream.flush();
            })
            .expect("spawn net-tx thread");
        SocketTx {
            queue: Some(q_tx),
            scratch: back_rx,
            handle: Some(handle),
            timeout_s,
            backpressure_s: 0.0,
        }
    }
}

impl FrameTx for SocketTx {
    fn send(&mut self, frame: Frame, pool: &mut PayloadPool)
            -> Result<(), TransportError> {
        let mut buf = match self.scratch.try_recv() {
            Ok(b) => b,
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => {
                Vec::new()
            }
        };
        encode_frame(&frame, &mut buf);
        pool.recycle(frame);
        let Some(q) = &self.queue else {
            return Err(TransportError::Disconnected);
        };
        let mut buf = match q.try_send(buf) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Disconnected(_)) => {
                return Err(TransportError::Disconnected)
            }
            Err(TrySendError::Full(b)) => b,
        };
        // Queue full: a congested or stalled peer.  Wait (visibly) for
        // the writer to drain, bounded by the net timeout so a dead
        // peer cannot park us here forever.
        let t0 = Instant::now();
        let deadline = (self.timeout_s > 0.0)
            .then(|| t0 + Duration::from_secs_f64(self.timeout_s));
        loop {
            match q.try_send(buf) {
                Ok(()) => {
                    self.backpressure_s += t0.elapsed().as_secs_f64();
                    return Ok(());
                }
                Err(TrySendError::Disconnected(_)) => {
                    self.backpressure_s += t0.elapsed().as_secs_f64();
                    return Err(TransportError::Disconnected);
                }
                Err(TrySendError::Full(b)) => {
                    buf = b;
                    if let Some(d) = deadline {
                        if Instant::now() > d {
                            self.backpressure_s +=
                                t0.elapsed().as_secs_f64();
                            return Err(TransportError::Timeout(
                                self.timeout_s,
                            ));
                        }
                    }
                    std::thread::sleep(SEND_POLL);
                }
            }
        }
    }

    fn remote(&self) -> bool {
        true
    }

    fn take_backpressure_s(&mut self) -> f64 {
        std::mem::take(&mut self.backpressure_s)
    }
}

impl Drop for SocketTx {
    fn drop(&mut self) {
        // closing the queue ends the writer loop; join so queued frames
        // reach the wire before the link (or process) goes away
        self.queue.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Receiving half of a socket link.
pub struct SocketRx {
    stream: Stream,
    timeout_s: f64,
    buf: Vec<u8>,
}

impl SocketRx {
    fn new(stream: Stream, timeout_s: f64) -> Result<SocketRx, TransportError> {
        let d = if timeout_s > 0.0 {
            Some(Duration::from_secs_f64(timeout_s))
        } else {
            None
        };
        stream.set_read_timeout(d).map_err(io_err)?;
        Ok(SocketRx { stream, timeout_s, buf: Vec::new() })
    }

    fn map(&self, e: std::io::Error) -> TransportError {
        match io_err(e) {
            TransportError::Timeout(_) => {
                TransportError::Timeout(self.timeout_s)
            }
            other => other,
        }
    }
}

impl FrameRx for SocketRx {
    fn recv(&mut self, pool: &mut PayloadPool)
            -> Result<Frame, TransportError> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len).map_err(|e| self.map(e))?;
        let body_len = u32::from_le_bytes(len) as usize;
        if body_len == 0 || body_len > MAX_FRAME {
            return Err(TransportError::Protocol(format!(
                "frame length {body_len} outside 1..={MAX_FRAME}"
            )));
        }
        self.buf.resize(body_len, 0);
        self.stream
            .read_exact(&mut self.buf)
            .map_err(|e| self.map(e))?;
        decode_frame(&self.buf, pool)
    }

    fn remote(&self) -> bool {
        true
    }
}

/// Multi-process transport over TCP or Unix sockets.
pub struct SocketTransport {
    world: usize,
    local: Range<usize>,
    per_proc: usize,
    index: usize,
    peers: Vec<String>,
    listener: Listener,
    /// The resolved address we published (and keep listening on).
    listen_actual: String,
    /// Accepted-but-not-yet-claimed connections, keyed by handshake.
    pending: HashMap<LinkId, Stream>,
    timeout_s: f64,
    /// Rendezvous generation this transport joined (0 in host-list
    /// mode and for unstamped rendezvous).
    generation: u64,
    /// Handshake authentication; `None` keeps the v1 handshake.
    auth: Option<HandshakeAuth>,
    /// Dial attempts before giving up; 0 retries until the deadline.
    net_retries: u32,
    /// First dial-backoff sleep; doubles per attempt, capped.
    net_backoff_ms: u64,
    /// Unix socket path to unlink on drop.
    sock_path: Option<PathBuf>,
}

impl SocketTransport {
    /// Static host-list discovery: `peers` is the identical ordered
    /// address list every process was launched with; `listen` must
    /// appear in it (that position is this process's index).
    pub fn with_hosts(world: usize, listen: &str, peers: Vec<String>,
                      timeout_s: f64)
                      -> Result<SocketTransport, TransportError> {
        let index = peers.iter().position(|p| p == listen).ok_or_else(|| {
            TransportError::Protocol(format!(
                "--listen {listen} does not appear in --connect list \
                 {peers:?}"
            ))
        })?;
        let (listener, _actual) = Listener::bind(listen)?;
        Self::finish(world, peers, index, listener, listen, timeout_s)
    }

    /// Rendezvous-file discovery: bind first (TCP port 0 is resolved to
    /// the real port before publishing), append our address, poll until
    /// `nprocs` lines exist; our line number is our process index.
    pub fn with_rendezvous(world: usize, listen: &str, file: &str,
                           nprocs: usize, timeout_s: f64)
                           -> Result<SocketTransport, TransportError> {
        Self::with_rendezvous_stamped(world, listen, file, nprocs, timeout_s,
                                      None)
    }

    /// [`Self::with_rendezvous`] plus stamp validation: with a
    /// [`RendezvousStamp`], a leftover file from a different run (or an
    /// older generation than `min_generation`) fails with
    /// [`TransportError::StaleRendezvous`], and the joined generation
    /// is readable via [`Self::generation`].
    pub fn with_rendezvous_stamped(world: usize, listen: &str, file: &str,
                                   nprocs: usize, timeout_s: f64,
                                   stamp: Option<&RendezvousStamp>)
                                   -> Result<SocketTransport, TransportError> {
        if nprocs == 0 {
            return Err(TransportError::Protocol(
                "--nprocs must be >= 1".into(),
            ));
        }
        let (listener, actual) = Listener::bind(listen)?;
        let (peers, index, generation) =
            rendezvous_join(file, nprocs, &actual, timeout_s, stamp)?;
        let mut t =
            Self::finish(world, peers, index, listener, &actual, timeout_s)?;
        t.generation = generation;
        Ok(t)
    }

    fn finish(world: usize, peers: Vec<String>, index: usize,
              listener: Listener, listen: &str, timeout_s: f64)
              -> Result<SocketTransport, TransportError> {
        let nprocs = peers.len();
        if world == 0 || nprocs == 0 || world % nprocs != 0 {
            return Err(TransportError::Protocol(format!(
                "world {world} does not split evenly over {nprocs} \
                 processes"
            )));
        }
        let per_proc = world / nprocs;
        let sock_path = if is_unix(listen) {
            Some(PathBuf::from(unix_path(listen)))
        } else {
            None
        };
        Ok(SocketTransport {
            world,
            local: index * per_proc..(index + 1) * per_proc,
            per_proc,
            index,
            peers,
            listener,
            listen_actual: listen.to_string(),
            pending: HashMap::new(),
            timeout_s,
            generation: 0,
            auth: None,
            net_retries: 0,
            net_backoff_ms: 20,
            sock_path,
        })
    }

    /// Re-enter a republished rendezvous world **in place**: the
    /// listener stays bound, strangers parked for the previous epoch
    /// are dropped, and the peer list / process index / hosted rank
    /// range are rebuilt from the file at `stamp.min_generation` (or
    /// newer).  Per-edge links of the old epoch must already be gone —
    /// dropping a pool joins every writer thread — so nothing leaks
    /// across epochs.
    pub fn rejoin(&mut self, file: &str, nprocs: usize,
                  stamp: &RendezvousStamp) -> Result<(), TransportError> {
        if nprocs == 0 {
            return Err(TransportError::Protocol(
                "--nprocs must be >= 1".into(),
            ));
        }
        self.pending.clear();
        let (peers, index, generation) = rendezvous_join(
            file, nprocs, &self.listen_actual, self.timeout_s, Some(stamp),
        )?;
        if self.world % peers.len() != 0 {
            return Err(TransportError::Protocol(format!(
                "world {} does not split evenly over {} processes",
                self.world,
                peers.len()
            )));
        }
        self.per_proc = self.world / peers.len();
        self.local = index * self.per_proc..(index + 1) * self.per_proc;
        self.index = index;
        self.peers = peers;
        self.generation = generation;
        Ok(())
    }

    /// Require the authenticated v2 handshake on every subsequent
    /// link: dials send it, accepts verify its MAC and nonce.  Both
    /// sides derive `nonce` from the run fingerprint and rendezvous
    /// generation, so a process from another run — or an earlier
    /// generation of this one — is rejected loudly.  Set before the
    /// first `link` call.
    pub fn set_auth(&mut self, key: &[u8], nonce: [u8; 8]) {
        self.auth = Some(HandshakeAuth { key: key.to_vec(), nonce });
    }

    /// Deterministic bounded-exponential dial backoff: sleep
    /// `backoff_ms << (attempt-1)` (capped at 500 ms) between connect
    /// attempts; `retries == 0` keeps retrying until the setup
    /// deadline.
    pub fn set_connect_backoff(&mut self, retries: u32, backoff_ms: u64) {
        self.net_retries = retries;
        self.net_backoff_ms = backoff_ms.max(1);
    }

    /// Rendezvous generation this transport joined (0 for host lists).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Which process hosts `rank`.
    fn process_of(&self, rank: u32) -> usize {
        rank as usize / self.per_proc
    }

    fn setup_deadline(&self) -> Instant {
        Instant::now() + Duration::from_secs_f64(self.timeout_s).max(MIN_SETUP)
    }

    /// Dial the process hosting `id.to` on the deterministic backoff
    /// schedule (the peer may still be starting up), then identify the
    /// edge with a handshake.
    fn dial(&self, id: LinkId) -> Result<Stream, TransportError> {
        let addr = &self.peers[self.process_of(id.to)];
        let deadline = self.setup_deadline();
        let mut attempt: u32 = 0;
        let mut stream = loop {
            match Stream::connect(addr) {
                Ok(s) => break s,
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::ConnectionRefused
                            | ErrorKind::NotFound
                            | ErrorKind::AddrNotAvailable
                    ) =>
                {
                    attempt += 1;
                    let out_of_retries =
                        self.net_retries > 0 && attempt >= self.net_retries;
                    if out_of_retries || Instant::now() > deadline {
                        return Err(TransportError::Io(format!(
                            "dial {addr} for {id:?}: {e} (gave up after \
                             {attempt} attempt(s))"
                        )));
                    }
                    let shift = (attempt - 1).min(16);
                    let ms = self
                        .net_backoff_ms
                        .saturating_mul(1 << shift)
                        .min(MAX_BACKOFF_MS);
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Err(e) => {
                    return Err(TransportError::Io(format!(
                        "dial {addr} for {id:?}: {e}"
                    )))
                }
            }
        };
        match &self.auth {
            Some(a) => stream
                .write_all(&encode_handshake_auth(id, a))
                .map_err(io_err)?,
            None => stream
                .write_all(&encode_handshake(id))
                .map_err(io_err)?,
        }
        stream.flush().map_err(io_err)?;
        Ok(stream)
    }

    /// Read and verify one handshake: v1 is accepted only when no key
    /// is set, v2 only when one is, and the v2 MAC + nonce must match.
    fn read_handshake(&self, s: &mut Stream) -> Result<LinkId, TransportError> {
        let mut head = [0u8; HANDSHAKE_LEN];
        s.read_exact(&mut head).map_err(io_err)?;
        let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
        if magic != HANDSHAKE_MAGIC {
            return Err(TransportError::Protocol(format!(
                "bad handshake magic {magic:#x}"
            )));
        }
        match (head[4], &self.auth) {
            (v, None) if v == WIRE_VERSION => decode_link_fields(&head),
            (v, Some(_)) if v == WIRE_VERSION => {
                Err(TransportError::Protocol(
                    "peer sent an unauthenticated v1 handshake but this \
                     process requires --net-key (stale or foreign process?)"
                        .into(),
                ))
            }
            (v, auth) if v == WIRE_VERSION_AUTH => {
                let mut tail = [0u8; HANDSHAKE_AUTH_LEN - HANDSHAKE_LEN];
                s.read_exact(&mut tail).map_err(io_err)?;
                let Some(auth) = auth else {
                    return Err(TransportError::Protocol(
                        "peer sent an authenticated handshake but no \
                         --net-key is set on this process"
                            .into(),
                    ));
                };
                let mut signed = [0u8; HANDSHAKE_LEN + 8];
                signed[..HANDSHAKE_LEN].copy_from_slice(&head);
                signed[HANDSHAKE_LEN..].copy_from_slice(&tail[..8]);
                let want = blake2s::mac16(&auth.key, &signed);
                if !blake2s::ct_eq(&want, &tail[8..24]) {
                    return Err(TransportError::Protocol(
                        "handshake MAC mismatch (wrong --net-key or \
                         foreign process)"
                            .into(),
                    ));
                }
                if tail[..8] != auth.nonce {
                    return Err(TransportError::Protocol(
                        "handshake nonce mismatch (stale generation or \
                         foreign run)"
                            .into(),
                    ));
                }
                decode_link_fields(&head)
            }
            (v, _) => Err(TransportError::Protocol(format!(
                "wire version {v} != {WIRE_VERSION} (or authenticated \
                 {WIRE_VERSION_AUTH})"
            ))),
        }
    }

    /// Accept until the connection whose handshake names `id` arrives;
    /// strangers for other edges are parked in `pending`.
    fn accept_match(&mut self, id: LinkId) -> Result<Stream, TransportError> {
        if let Some(s) = self.pending.remove(&id) {
            return Ok(s);
        }
        let deadline = self.setup_deadline();
        self.listener.set_nonblocking(true).map_err(io_err)?;
        loop {
            match self.listener.accept() {
                Ok(stream) => {
                    stream.set_nonblocking(false).map_err(io_err)?;
                    stream
                        .set_read_timeout(Some(
                            Duration::from_secs_f64(self.timeout_s)
                                .max(MIN_SETUP),
                        ))
                        .map_err(io_err)?;
                    let mut s = stream;
                    let got = self.read_handshake(&mut s)?;
                    if got == id {
                        return Ok(s);
                    }
                    self.pending.insert(got, s);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        return Err(TransportError::Timeout(self.timeout_s));
                    }
                    std::thread::sleep(POLL);
                }
                Err(e) => return Err(io_err(e)),
            }
        }
    }

    /// This process's index in the peer list.
    pub fn process_index(&self) -> usize {
        self.index
    }

    /// Total processes in the run.
    pub fn nprocs(&self) -> usize {
        self.peers.len()
    }
}

impl Transport for SocketTransport {
    fn world(&self) -> usize {
        self.world
    }

    fn local_ranks(&self) -> Range<usize> {
        self.local.clone()
    }

    fn link(&mut self, id: LinkId) -> Result<LinkEnds, TransportError> {
        let from_local = self.local.contains(&(id.from as usize));
        let to_local = self.local.contains(&(id.to as usize));
        if from_local && to_local {
            // both ends in-process: same zero-copy channel as InProc
            let (tx, rx) = super::transport::chan_link();
            return Ok(LinkEnds { tx: Some(tx), rx: Some(rx) });
        }
        if from_local {
            let stream = self.dial(id)?;
            return Ok(LinkEnds {
                tx: Some(Box::new(SocketTx::spawn(stream, id,
                                                  self.timeout_s))),
                rx: None,
            });
        }
        if to_local {
            let stream = self.accept_match(id)?;
            return Ok(LinkEnds {
                tx: None,
                rx: Some(Box::new(SocketRx::new(stream, self.timeout_s)?)),
            });
        }
        Err(TransportError::Protocol(format!(
            "link {id:?} touches no local rank \
             (local {:?})",
            self.local
        )))
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        if let Some(p) = &self.sock_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_classification() {
        assert!(is_unix("unix:/tmp/x.sock"));
        assert!(is_unix("/tmp/x.sock"));
        assert!(!is_unix("127.0.0.1:4000"));
        assert!(!is_unix("node7:4000"));
        assert_eq!(unix_path("unix:/tmp/x.sock"), "/tmp/x.sock");
        assert_eq!(unix_path("/tmp/x.sock"), "/tmp/x.sock");
    }

    #[test]
    fn handshake_round_trips() {
        let id = LinkId { kind: LinkKind::LeaderRing, from: 6, to: 2 };
        let b = encode_handshake(id);
        assert_eq!(decode_handshake(&b).unwrap(), id);
        let mut bad = b;
        bad[0] ^= 0xff;
        assert!(matches!(decode_handshake(&bad),
                         Err(TransportError::Protocol(_))));
    }

    #[test]
    fn auth_handshake_layout() {
        let id = LinkId { kind: LinkKind::FlatRing, from: 0, to: 1 };
        let auth = HandshakeAuth { key: b"k".to_vec(), nonce: [7u8; 8] };
        let b = encode_handshake_auth(id, &auth);
        assert_eq!(b.len(), HANDSHAKE_AUTH_LEN);
        assert_eq!(b[4], WIRE_VERSION_AUTH);
        assert_eq!(&b[0..4], &HANDSHAKE_MAGIC.to_le_bytes());
        assert_eq!(&b[14..22], &[7u8; 8]);
        assert_eq!(b[22..38], blake2s::mac16(b"k", &b[..22]));
        // the v1 fields decode identically from the shared prefix
        assert_eq!(decode_link_fields(&b).unwrap(), id);
    }

    #[test]
    fn stamp_round_trips() {
        let dir = crate::testkit::tmp_dir("stamp");
        let file = dir.join("peers.txt").to_string_lossy().to_string();
        assert_eq!(read_stamp(&file).unwrap(), None);
        let run = [1, 2, 3, 4, 5, 6, 7, 8];
        write_stamp(&file, run, 3).unwrap();
        assert_eq!(read_stamp(&file).unwrap(), Some((run, 3)));
        write_stamp(&file, run, 4).unwrap();
        assert_eq!(read_stamp(&file).unwrap(), Some((run, 4)));
        std::fs::write(stamp_path(&file), "not a stamp").unwrap();
        assert!(matches!(read_stamp(&file),
                         Err(TransportError::StaleRendezvous(_))));
    }

    #[test]
    fn stamped_rendezvous_rejects_foreign_run_and_old_generation() {
        let dir = crate::testkit::tmp_dir("stamp_rdzv");
        let file = dir.join("peers.txt").to_string_lossy().to_string();
        write_stamp(&file, [0xaa; 8], 0).unwrap();
        let stamp = RendezvousStamp {
            run_id: [0xbb; 8],
            min_generation: 0,
            window_s: None,
        };
        let err = SocketTransport::with_rendezvous_stamped(
            1, "127.0.0.1:0", &file, 1, 1.0, Some(&stamp),
        )
        .err()
        .expect("foreign run stamp must fail");
        match err {
            TransportError::StaleRendezvous(m) => {
                assert!(m.contains("different run"), "{m}");
            }
            other => panic!("expected StaleRendezvous, got {other:?}"),
        }

        let behind = RendezvousStamp {
            run_id: [0xaa; 8],
            min_generation: 2,
            window_s: None,
        };
        let err = SocketTransport::with_rendezvous_stamped(
            1, "127.0.0.1:0", &file, 1, 1.0, Some(&behind),
        )
        .err()
        .expect("older generation than the epoch must fail");
        assert!(matches!(err, TransportError::StaleRendezvous(_)));
    }

    #[test]
    fn fresh_process_adopts_the_stamped_generation() {
        let dir = crate::testkit::tmp_dir("stamp_adopt");
        let file = dir.join("peers.txt").to_string_lossy().to_string();
        write_stamp(&file, [0xcc; 8], 5).unwrap();
        let stamp = RendezvousStamp {
            run_id: [0xcc; 8],
            min_generation: 0,
            window_s: None,
        };
        let t = SocketTransport::with_rendezvous_stamped(
            1, "127.0.0.1:0", &file, 1, 1.0, Some(&stamp),
        )
        .expect("matching run at a newer generation must join");
        assert_eq!(t.generation(), 5);
    }

    #[test]
    fn dial_gives_up_after_net_retries() {
        // probe a port with nothing listening behind it
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let dead = l.local_addr().unwrap().to_string();
        drop(l);
        let me = TcpListener::bind("127.0.0.1:0").unwrap();
        let listen = me.local_addr().unwrap().to_string();
        drop(me);
        let mut t = SocketTransport::with_hosts(
            2,
            &listen,
            vec![listen.clone(), dead],
            5.0,
        )
        .expect("transport");
        t.set_connect_backoff(3, 1);
        let err = t
            .link(LinkId { kind: LinkKind::FlatRing, from: 0, to: 1 })
            .err()
            .expect("dialing a dead peer must fail");
        match err {
            TransportError::Io(m) => {
                assert!(m.contains("gave up after 3 attempt(s)"), "{m}");
            }
            other => panic!("expected Io, got {other:?}"),
        }
    }

    #[test]
    fn loopback_pair_exchanges_frames() {
        // Two single-rank "processes" on two threads: flat ring world=2.
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a0 = l0.local_addr().unwrap().to_string();
        let a1 = l1.local_addr().unwrap().to_string();
        drop(l0);
        drop(l1);
        let peers = vec![a0.clone(), a1.clone()];

        let mk = |listen: String, peers: Vec<String>| {
            move || -> Vec<f32> {
                let mut t =
                    SocketTransport::with_hosts(2, &listen, peers, 5.0)
                        .expect("transport");
                let me = t.process_index() as u32;
                let other = 1 - me;
                let mut pool = PayloadPool::default();
                // deterministic global link order: 0->1 then 1->0
                let ids = [
                    LinkId { kind: LinkKind::FlatRing, from: 0, to: 1 },
                    LinkId { kind: LinkKind::FlatRing, from: 1, to: 0 },
                ];
                let mut tx = None;
                let mut rx = None;
                for id in ids {
                    let ends = t.link(id).expect("link");
                    if id.from == me {
                        tx = ends.tx;
                    }
                    if id.to == me {
                        rx = ends.rx;
                    }
                }
                let (mut tx, mut rx) = (tx.unwrap(), rx.unwrap());
                assert!(tx.remote() && rx.remote());
                tx.send(
                    Frame::RingF32 {
                        tag: me,
                        data: vec![me as f32, 10.0 + me as f32],
                    },
                    &mut pool,
                )
                .expect("send");
                match rx.recv(&mut pool).expect("recv") {
                    Frame::RingF32 { tag, data } => {
                        assert_eq!(tag, other);
                        data
                    }
                    other => panic!("wrong frame {other:?}"),
                }
            }
        };

        let h0 = std::thread::spawn(mk(a0, peers.clone()));
        let h1 = std::thread::spawn(mk(a1, peers));
        let d0 = h0.join().expect("proc 0");
        let d1 = h1.join().expect("proc 1");
        assert_eq!(d0, vec![1.0, 11.0]);
        assert_eq!(d1, vec![0.0, 10.0]);
    }

    #[test]
    fn recv_times_out_when_peer_goes_quiet() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let dialer = std::thread::spawn(move || {
            // connect and then send nothing, keeping the socket open
            let s = TcpStream::connect(addr).unwrap();
            std::thread::sleep(Duration::from_millis(800));
            drop(s);
        });
        let (s, _) = l.accept().unwrap();
        let mut rx =
            SocketRx::new(Stream::Tcp(s), 0.2).expect("rx");
        let mut pool = PayloadPool::default();
        let t0 = Instant::now();
        match rx.recv(&mut pool) {
            Err(TransportError::Timeout(s)) => {
                assert!((s - 0.2).abs() < 1e-9);
            }
            other => panic!("expected timeout, got {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_millis(700));
        dialer.join().unwrap();
    }

    #[test]
    fn bounded_send_queue_times_out_against_a_stalled_peer() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let s = TcpStream::connect(addr).unwrap();
        // accept but never read: the writer thread eventually blocks
        // on a full kernel buffer, then the bounded queue fills
        let (peer, _) = l.accept().unwrap();
        let id = LinkId { kind: LinkKind::FlatRing, from: 0, to: 1 };
        let mut tx = SocketTx::spawn(Stream::Tcp(s), id, 0.2);
        let mut pool = PayloadPool::default();
        let mut hit = None;
        for tag in 0..500u32 {
            let frame = Frame::RingF32 {
                tag,
                data: vec![0.25f32; 16 * 1024],
            };
            if let Err(e) = tx.send(frame, &mut pool) {
                hit = Some(e);
                break;
            }
        }
        match hit.expect("send against a stalled peer must time out") {
            TransportError::Timeout(s) => assert!((s - 0.2).abs() < 1e-9),
            other => panic!("expected timeout, got {other:?}"),
        }
        // the stall was charged to the backpressure counter, and
        // take() drains it
        assert!(tx.take_backpressure_s() > 0.0);
        assert_eq!(tx.take_backpressure_s(), 0.0);
        drop(peer);
    }

    #[cfg(unix)]
    #[test]
    fn rendezvous_assigns_indices_by_line_order() {
        let dir = crate::testkit::tmp_dir("rdzv");
        let file = dir.join("peers.txt");
        let file_s = file.to_string_lossy().to_string();
        let mk = |sock: String, file: String| {
            move || {
                let t = SocketTransport::with_rendezvous(
                    2, &sock, &file, 2, 5.0,
                )
                .expect("rendezvous transport");
                (t.process_index(), t.local_ranks())
            }
        };
        let s0 = dir.join("p0.sock").to_string_lossy().to_string();
        let s1 = dir.join("p1.sock").to_string_lossy().to_string();
        let h0 = std::thread::spawn(mk(s0, file_s.clone()));
        let h1 = std::thread::spawn(mk(s1, file_s));
        let (i0, r0) = h0.join().unwrap();
        let (i1, r1) = h1.join().unwrap();
        assert_ne!(i0, i1);
        let mut ranges = [r0, r1];
        ranges.sort_by_key(|r| r.start);
        assert_eq!(ranges, [0..1, 1..2]);
    }

    #[test]
    fn rejoin_rebuilds_the_world_at_the_next_generation() {
        use std::sync::{Arc, Barrier};

        let dir = crate::testkit::tmp_dir("rejoin");
        let file = dir.join("peers.txt").to_string_lossy().to_string();
        let run = [0x42u8; 8];
        let gate = Arc::new(Barrier::new(2));

        let mk = |file: String, gate: Arc<Barrier>| {
            move || {
                let stamp = RendezvousStamp {
                    run_id: run,
                    min_generation: 0,
                    window_s: None,
                };
                let mut t = SocketTransport::with_rendezvous_stamped(
                    2, "127.0.0.1:0", &file, 2, 5.0, Some(&stamp),
                )
                .expect("epoch-0 transport");
                assert_eq!(t.generation(), 0);
                let exchange = |t: &mut SocketTransport| {
                    let me = t.process_index() as u32;
                    let mut pool = PayloadPool::default();
                    let ids = [
                        LinkId { kind: LinkKind::FlatRing, from: 0, to: 1 },
                        LinkId { kind: LinkKind::FlatRing, from: 1, to: 0 },
                    ];
                    let (mut tx, mut rx) = (None, None);
                    for id in ids {
                        let ends = t.link(id).expect("link");
                        if id.from == me {
                            tx = ends.tx;
                        }
                        if id.to == me {
                            rx = ends.rx;
                        }
                    }
                    let (mut tx, mut rx) = (tx.unwrap(), rx.unwrap());
                    tx.send(
                        Frame::RingF32 { tag: me, data: vec![me as f32] },
                        &mut pool,
                    )
                    .expect("send");
                    match rx.recv(&mut pool).expect("recv") {
                        Frame::RingF32 { tag, .. } => {
                            assert_eq!(tag, 1 - me);
                        }
                        other => panic!("wrong frame {other:?}"),
                    }
                    // dropping tx joins the writer; no threads leak
                    // into the next epoch
                };
                exchange(&mut t);
                let winner = t.process_index() == 0;
                gate.wait();
                if winner {
                    // republish epoch 1: truncate addresses, bump stamp
                    std::fs::write(&file, "").unwrap();
                    write_stamp(&file, run, 1).unwrap();
                }
                gate.wait();
                let next = RendezvousStamp {
                    run_id: run,
                    min_generation: 1,
                    window_s: Some(5.0),
                };
                t.rejoin(&file, 2, &next).expect("rejoin");
                assert_eq!(t.generation(), 1);
                exchange(&mut t);
            }
        };

        let h0 = std::thread::spawn(mk(file.clone(), gate.clone()));
        let h1 = std::thread::spawn(mk(file, gate));
        h0.join().expect("proc 0");
        h1.join().expect("proc 1");
    }
}
