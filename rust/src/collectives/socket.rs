//! `SocketTransport`: the collective pool over real processes.
//!
//! Frames travel as the v1 length-prefixed binary layout from
//! [`super::transport`] over TCP (`host:port`) or Unix domain sockets
//! (`unix:/path` or any address containing `/`).  Each directed
//! comm-graph edge gets its own connection, opened lazily when
//! [`Transport::link`] reaches that edge and identified by a 14-byte
//! handshake (`magic, version, kind, from, to`), so accept order never
//! has to match dial order.
//!
//! # Peer discovery
//!
//! Two modes, mirroring torchrun's static and rendezvous launch:
//!
//! * **host list** — every process is started with `--listen <addr>
//!   --connect <addr0,addr1,...>`; the position of its own listen
//!   address in the (identical) list is its process index.
//! * **rendezvous file** — every process is started with `--listen
//!   <addr> --rendezvous <file> --nprocs <n>`; each appends its own
//!   address (one line, `O_APPEND` so lines never interleave) and polls
//!   until `n` lines exist.  Line order assigns process indices.
//!
//! The world is split contiguously and evenly across processes:
//! process `i` of `p` hosts global ranks `i*world/p .. (i+1)*world/p`.
//!
//! # Why sends go through a writer thread
//!
//! In-process links are unbounded channels, so a ring rank can send its
//! hop before blocking on its receive.  A naive blocking `write_all`
//! breaks that: with payloads larger than the kernel socket buffers,
//! every rank can block mid-send while its neighbor also blocks
//! mid-send — classic ring deadlock.  [`SocketTx`] therefore hands
//! serialized frames to a per-link writer thread over an unbounded
//! queue; `send` never blocks, preserving the in-process progress
//! property.  Drained byte buffers come back over a scratch channel so
//! the steady state allocates nothing.  Dropping a `SocketTx` closes
//! the queue and joins the writer, flushing any in-flight frames before
//! process exit (the final all-gather hop must not be lost).
//!
//! # Failure behavior
//!
//! Receives use `SO_RCVTIMEO` from `train.net_timeout_s`: a peer that
//! stops sending surfaces [`TransportError::Timeout`] instead of
//! hanging the survivor, and a closed connection surfaces
//! [`TransportError::Disconnected`].  Both `remote()` bits are true, so
//! the pool's protocols propagate (never tolerate) remote failures.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::transport::{
    decode_frame, encode_frame, Frame, FrameRx, FrameTx, LinkEnds, LinkId,
    LinkKind, PayloadPool, Transport, TransportError, HANDSHAKE_MAGIC,
    MAX_FRAME, WIRE_VERSION,
};

/// Poll interval while waiting for accepts, rendezvous lines, or a
/// listening peer.
const POLL: Duration = Duration::from_millis(10);

/// Floor on the connection-setup deadline: peers may start seconds
/// apart, so setup gets at least this long even with a tight frame
/// timeout.
const MIN_SETUP: Duration = Duration::from_secs(10);

fn io_err(e: std::io::Error) -> TransportError {
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
            // callers with a real timeout override this value
            TransportError::Timeout(0.0)
        }
        ErrorKind::UnexpectedEof
        | ErrorKind::ConnectionReset
        | ErrorKind::ConnectionAborted
        | ErrorKind::BrokenPipe => TransportError::Disconnected,
        _ => TransportError::Io(e.to_string()),
    }
}

/// True when `addr` names a Unix socket path rather than `host:port`.
fn is_unix(addr: &str) -> bool {
    addr.starts_with("unix:") || addr.contains('/')
}

/// Strip the optional `unix:` prefix.
fn unix_path(addr: &str) -> &str {
    addr.strip_prefix("unix:").unwrap_or(addr)
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn bind(addr: &str) -> Result<(Listener, String), TransportError> {
        if is_unix(addr) {
            #[cfg(unix)]
            {
                let path = unix_path(addr);
                // a stale socket file from a crashed run blocks bind
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path).map_err(|e| {
                    TransportError::Io(format!("bind {addr}: {e}"))
                })?;
                return Ok((Listener::Unix(l), format!("unix:{path}")));
            }
            #[cfg(not(unix))]
            return Err(TransportError::Protocol(format!(
                "unix socket address {addr} unsupported on this platform"
            )));
        }
        let l = TcpListener::bind(addr)
            .map_err(|e| TransportError::Io(format!("bind {addr}: {e}")))?;
        let actual = l
            .local_addr()
            .map_err(|e| TransportError::Io(e.to_string()))?;
        Ok((Listener::Tcp(l), actual.to_string()))
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                Ok(Stream::Unix(s))
            }
        }
    }
}

enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn connect(addr: &str) -> std::io::Result<Stream> {
        if is_unix(addr) {
            #[cfg(unix)]
            {
                return Ok(Stream::Unix(UnixStream::connect(unix_path(addr))?));
            }
            #[cfg(not(unix))]
            return Err(std::io::Error::new(
                ErrorKind::Unsupported,
                "unix sockets unsupported on this platform",
            ));
        }
        let s = TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        Ok(Stream::Tcp(s))
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nb),
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(d),
        }
    }

    fn read_exact(&mut self, buf: &mut [u8]) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.read_exact(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read_exact(buf),
        }
    }

    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.write_all(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write_all(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// `[magic u32][version u8][kind u8][from u32][to u32]`, little-endian.
const HANDSHAKE_LEN: usize = 14;

fn encode_handshake(id: LinkId) -> [u8; HANDSHAKE_LEN] {
    let mut b = [0u8; HANDSHAKE_LEN];
    b[0..4].copy_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    b[4] = WIRE_VERSION;
    b[5] = id.kind.to_u8();
    b[6..10].copy_from_slice(&id.from.to_le_bytes());
    b[10..14].copy_from_slice(&id.to.to_le_bytes());
    b
}

fn decode_handshake(b: &[u8; HANDSHAKE_LEN]) -> Result<LinkId, TransportError> {
    let magic = u32::from_le_bytes(b[0..4].try_into().unwrap());
    if magic != HANDSHAKE_MAGIC {
        return Err(TransportError::Protocol(format!(
            "bad handshake magic {magic:#x}"
        )));
    }
    if b[4] != WIRE_VERSION {
        return Err(TransportError::Protocol(format!(
            "wire version {} != {}",
            b[4], WIRE_VERSION
        )));
    }
    Ok(LinkId {
        kind: LinkKind::from_u8(b[5])?,
        from: u32::from_le_bytes(b[6..10].try_into().unwrap()),
        to: u32::from_le_bytes(b[10..14].try_into().unwrap()),
    })
}

/// Sending half of a socket link; see the module docs for why writes
/// run on their own thread.
pub struct SocketTx {
    queue: Option<Sender<Vec<u8>>>,
    scratch: Receiver<Vec<u8>>,
    handle: Option<JoinHandle<()>>,
}

impl SocketTx {
    fn spawn(mut stream: Stream, id: LinkId) -> SocketTx {
        let (q_tx, q_rx) = channel::<Vec<u8>>();
        let (back_tx, back_rx) = channel::<Vec<u8>>();
        let handle = std::thread::Builder::new()
            .name(format!("net-tx-{}-{}", id.from, id.to))
            .spawn(move || {
                while let Ok(buf) = q_rx.recv() {
                    if stream.write_all(&buf).is_err() {
                        // peer gone: drain silently; send() learns of
                        // the death when the queue closes on our exit
                        break;
                    }
                    let _ = back_tx.send(buf);
                }
                let _ = stream.flush();
            })
            .expect("spawn net-tx thread");
        SocketTx {
            queue: Some(q_tx),
            scratch: back_rx,
            handle: Some(handle),
        }
    }
}

impl FrameTx for SocketTx {
    fn send(&mut self, frame: Frame, pool: &mut PayloadPool)
            -> Result<(), TransportError> {
        let mut buf = match self.scratch.try_recv() {
            Ok(b) => b,
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => {
                Vec::new()
            }
        };
        encode_frame(&frame, &mut buf);
        pool.recycle(frame);
        match &self.queue {
            Some(q) => {
                q.send(buf).map_err(|_| TransportError::Disconnected)
            }
            None => Err(TransportError::Disconnected),
        }
    }

    fn remote(&self) -> bool {
        true
    }
}

impl Drop for SocketTx {
    fn drop(&mut self) {
        // closing the queue ends the writer loop; join so queued frames
        // reach the wire before the link (or process) goes away
        self.queue.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Receiving half of a socket link.
pub struct SocketRx {
    stream: Stream,
    timeout_s: f64,
    buf: Vec<u8>,
}

impl SocketRx {
    fn new(stream: Stream, timeout_s: f64) -> Result<SocketRx, TransportError> {
        let d = if timeout_s > 0.0 {
            Some(Duration::from_secs_f64(timeout_s))
        } else {
            None
        };
        stream.set_read_timeout(d).map_err(io_err)?;
        Ok(SocketRx { stream, timeout_s, buf: Vec::new() })
    }

    fn map(&self, e: std::io::Error) -> TransportError {
        match io_err(e) {
            TransportError::Timeout(_) => {
                TransportError::Timeout(self.timeout_s)
            }
            other => other,
        }
    }
}

impl FrameRx for SocketRx {
    fn recv(&mut self, pool: &mut PayloadPool)
            -> Result<Frame, TransportError> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len).map_err(|e| self.map(e))?;
        let body_len = u32::from_le_bytes(len) as usize;
        if body_len == 0 || body_len > MAX_FRAME {
            return Err(TransportError::Protocol(format!(
                "frame length {body_len} outside 1..={MAX_FRAME}"
            )));
        }
        self.buf.resize(body_len, 0);
        self.stream
            .read_exact(&mut self.buf)
            .map_err(|e| self.map(e))?;
        decode_frame(&self.buf, pool)
    }

    fn remote(&self) -> bool {
        true
    }
}

/// Multi-process transport over TCP or Unix sockets.
pub struct SocketTransport {
    world: usize,
    local: Range<usize>,
    per_proc: usize,
    index: usize,
    peers: Vec<String>,
    listener: Listener,
    /// Accepted-but-not-yet-claimed connections, keyed by handshake.
    pending: HashMap<LinkId, Stream>,
    timeout_s: f64,
    /// Unix socket path to unlink on drop.
    sock_path: Option<PathBuf>,
}

impl SocketTransport {
    /// Static host-list discovery: `peers` is the identical ordered
    /// address list every process was launched with; `listen` must
    /// appear in it (that position is this process's index).
    pub fn with_hosts(world: usize, listen: &str, peers: Vec<String>,
                      timeout_s: f64)
                      -> Result<SocketTransport, TransportError> {
        let index = peers.iter().position(|p| p == listen).ok_or_else(|| {
            TransportError::Protocol(format!(
                "--listen {listen} does not appear in --connect list \
                 {peers:?}"
            ))
        })?;
        let (listener, _actual) = Listener::bind(listen)?;
        Self::finish(world, peers, index, listener, listen, timeout_s)
    }

    /// Rendezvous-file discovery: bind first (TCP port 0 is resolved to
    /// the real port before publishing), append our address, poll until
    /// `nprocs` lines exist; our line number is our process index.
    pub fn with_rendezvous(world: usize, listen: &str, file: &str,
                           nprocs: usize, timeout_s: f64)
                           -> Result<SocketTransport, TransportError> {
        if nprocs == 0 {
            return Err(TransportError::Protocol(
                "--nprocs must be >= 1".into(),
            ));
        }
        let (listener, actual) = Listener::bind(listen)?;
        {
            use std::fs::OpenOptions;
            let mut f = OpenOptions::new()
                .create(true)
                .append(true)
                .open(file)
                .map_err(|e| {
                    TransportError::Io(format!("rendezvous {file}: {e}"))
                })?;
            // one O_APPEND write per process: lines never interleave
            writeln!(f, "{actual}").map_err(|e| {
                TransportError::Io(format!("rendezvous {file}: {e}"))
            })?;
        }
        let deadline = Instant::now()
            + Duration::from_secs_f64(timeout_s).max(MIN_SETUP);
        let peers = loop {
            let text = std::fs::read_to_string(file).map_err(|e| {
                TransportError::Io(format!("rendezvous {file}: {e}"))
            })?;
            let lines: Vec<String> = text
                .lines()
                .map(|l| l.trim().to_string())
                .filter(|l| !l.is_empty())
                .collect();
            if lines.len() >= nprocs {
                break lines;
            }
            if Instant::now() > deadline {
                return Err(TransportError::Timeout(timeout_s));
            }
            std::thread::sleep(POLL);
        };
        if peers.len() > nprocs {
            return Err(TransportError::Protocol(format!(
                "rendezvous file {file} has {} addresses for --nprocs \
                 {nprocs}; stale file from a previous run?",
                peers.len()
            )));
        }
        let mine: Vec<usize> = peers
            .iter()
            .enumerate()
            .filter(|(_, p)| **p == actual)
            .map(|(i, _)| i)
            .collect();
        let index = match mine.as_slice() {
            [i] => *i,
            [] => {
                return Err(TransportError::Protocol(format!(
                    "own address {actual} missing from rendezvous file \
                     {file}"
                )))
            }
            _ => {
                return Err(TransportError::Protocol(format!(
                    "own address {actual} appears twice in rendezvous file \
                     {file}; stale file from a previous run?"
                )))
            }
        };
        Self::finish(world, peers, index, listener, &actual, timeout_s)
    }

    fn finish(world: usize, peers: Vec<String>, index: usize,
              listener: Listener, listen: &str, timeout_s: f64)
              -> Result<SocketTransport, TransportError> {
        let nprocs = peers.len();
        if world == 0 || nprocs == 0 || world % nprocs != 0 {
            return Err(TransportError::Protocol(format!(
                "world {world} does not split evenly over {nprocs} \
                 processes"
            )));
        }
        let per_proc = world / nprocs;
        let sock_path = if is_unix(listen) {
            Some(PathBuf::from(unix_path(listen)))
        } else {
            None
        };
        Ok(SocketTransport {
            world,
            local: index * per_proc..(index + 1) * per_proc,
            per_proc,
            index,
            peers,
            listener,
            pending: HashMap::new(),
            timeout_s,
            sock_path,
        })
    }

    /// Which process hosts `rank`.
    fn process_of(&self, rank: u32) -> usize {
        rank as usize / self.per_proc
    }

    fn setup_deadline(&self) -> Instant {
        Instant::now() + Duration::from_secs_f64(self.timeout_s).max(MIN_SETUP)
    }

    /// Dial the process hosting `id.to`, retrying while it may still be
    /// starting up, then identify the edge with a handshake.
    fn dial(&self, id: LinkId) -> Result<Stream, TransportError> {
        let addr = &self.peers[self.process_of(id.to)];
        let deadline = self.setup_deadline();
        let mut stream = loop {
            match Stream::connect(addr) {
                Ok(s) => break s,
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::ConnectionRefused
                            | ErrorKind::NotFound
                            | ErrorKind::AddrNotAvailable
                    ) =>
                {
                    if Instant::now() > deadline {
                        return Err(TransportError::Io(format!(
                            "dial {addr} for {id:?}: {e}"
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => {
                    return Err(TransportError::Io(format!(
                        "dial {addr} for {id:?}: {e}"
                    )))
                }
            }
        };
        stream
            .write_all(&encode_handshake(id))
            .map_err(io_err)?;
        stream.flush().map_err(io_err)?;
        Ok(stream)
    }

    /// Accept until the connection whose handshake names `id` arrives;
    /// strangers for other edges are parked in `pending`.
    fn accept_match(&mut self, id: LinkId) -> Result<Stream, TransportError> {
        if let Some(s) = self.pending.remove(&id) {
            return Ok(s);
        }
        let deadline = self.setup_deadline();
        self.listener.set_nonblocking(true).map_err(io_err)?;
        loop {
            match self.listener.accept() {
                Ok(stream) => {
                    stream.set_nonblocking(false).map_err(io_err)?;
                    stream
                        .set_read_timeout(Some(
                            Duration::from_secs_f64(self.timeout_s)
                                .max(MIN_SETUP),
                        ))
                        .map_err(io_err)?;
                    let mut hs = [0u8; HANDSHAKE_LEN];
                    let mut s = stream;
                    s.read_exact(&mut hs).map_err(io_err)?;
                    let got = decode_handshake(&hs)?;
                    if got == id {
                        return Ok(s);
                    }
                    self.pending.insert(got, s);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        return Err(TransportError::Timeout(self.timeout_s));
                    }
                    std::thread::sleep(POLL);
                }
                Err(e) => return Err(io_err(e)),
            }
        }
    }

    /// This process's index in the peer list.
    pub fn process_index(&self) -> usize {
        self.index
    }

    /// Total processes in the run.
    pub fn nprocs(&self) -> usize {
        self.peers.len()
    }
}

impl Transport for SocketTransport {
    fn world(&self) -> usize {
        self.world
    }

    fn local_ranks(&self) -> Range<usize> {
        self.local.clone()
    }

    fn link(&mut self, id: LinkId) -> Result<LinkEnds, TransportError> {
        let from_local = self.local.contains(&(id.from as usize));
        let to_local = self.local.contains(&(id.to as usize));
        if from_local && to_local {
            // both ends in-process: same zero-copy channel as InProc
            let (tx, rx) = super::transport::chan_link();
            return Ok(LinkEnds { tx: Some(tx), rx: Some(rx) });
        }
        if from_local {
            let stream = self.dial(id)?;
            return Ok(LinkEnds {
                tx: Some(Box::new(SocketTx::spawn(stream, id))),
                rx: None,
            });
        }
        if to_local {
            let stream = self.accept_match(id)?;
            return Ok(LinkEnds {
                tx: None,
                rx: Some(Box::new(SocketRx::new(stream, self.timeout_s)?)),
            });
        }
        Err(TransportError::Protocol(format!(
            "link {id:?} touches no local rank \
             (local {:?})",
            self.local
        )))
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        if let Some(p) = &self.sock_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_classification() {
        assert!(is_unix("unix:/tmp/x.sock"));
        assert!(is_unix("/tmp/x.sock"));
        assert!(!is_unix("127.0.0.1:4000"));
        assert!(!is_unix("node7:4000"));
        assert_eq!(unix_path("unix:/tmp/x.sock"), "/tmp/x.sock");
        assert_eq!(unix_path("/tmp/x.sock"), "/tmp/x.sock");
    }

    #[test]
    fn handshake_round_trips() {
        let id = LinkId { kind: LinkKind::LeaderRing, from: 6, to: 2 };
        let b = encode_handshake(id);
        assert_eq!(decode_handshake(&b).unwrap(), id);
        let mut bad = b;
        bad[0] ^= 0xff;
        assert!(matches!(decode_handshake(&bad),
                         Err(TransportError::Protocol(_))));
    }

    #[test]
    fn world_must_split_evenly() {
        let err = SocketTransport::with_hosts(
            3,
            "127.0.0.1:0",
            vec!["127.0.0.1:0".into(), "127.0.0.1:1".into()],
            1.0,
        )
        .err()
        .expect("3 ranks over 2 procs must fail");
        assert!(matches!(err, TransportError::Protocol(_)));
    }

    #[test]
    fn listen_must_appear_in_peer_list() {
        let err = SocketTransport::with_hosts(
            2,
            "127.0.0.1:59999",
            vec!["10.0.0.1:4000".into(), "10.0.0.2:4000".into()],
            1.0,
        )
        .err()
        .expect("listen addr absent from peers must fail");
        assert!(matches!(err, TransportError::Protocol(_)));
    }

    #[test]
    fn loopback_pair_exchanges_frames() {
        // Two single-rank "processes" on two threads: flat ring world=2.
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a0 = l0.local_addr().unwrap().to_string();
        let a1 = l1.local_addr().unwrap().to_string();
        drop(l0);
        drop(l1);
        let peers = vec![a0.clone(), a1.clone()];

        let mk = |listen: String, peers: Vec<String>| {
            move || -> Vec<f32> {
                let mut t =
                    SocketTransport::with_hosts(2, &listen, peers, 5.0)
                        .expect("transport");
                let me = t.process_index() as u32;
                let other = 1 - me;
                let mut pool = PayloadPool::default();
                // deterministic global link order: 0->1 then 1->0
                let ids = [
                    LinkId { kind: LinkKind::FlatRing, from: 0, to: 1 },
                    LinkId { kind: LinkKind::FlatRing, from: 1, to: 0 },
                ];
                let mut tx = None;
                let mut rx = None;
                for id in ids {
                    let ends = t.link(id).expect("link");
                    if id.from == me {
                        tx = ends.tx;
                    }
                    if id.to == me {
                        rx = ends.rx;
                    }
                }
                let (mut tx, mut rx) = (tx.unwrap(), rx.unwrap());
                assert!(tx.remote() && rx.remote());
                tx.send(
                    Frame::RingF32 {
                        tag: me,
                        data: vec![me as f32, 10.0 + me as f32],
                    },
                    &mut pool,
                )
                .expect("send");
                match rx.recv(&mut pool).expect("recv") {
                    Frame::RingF32 { tag, data } => {
                        assert_eq!(tag, other);
                        data
                    }
                    other => panic!("wrong frame {other:?}"),
                }
            }
        };

        let h0 = std::thread::spawn(mk(a0, peers.clone()));
        let h1 = std::thread::spawn(mk(a1, peers));
        let d0 = h0.join().expect("proc 0");
        let d1 = h1.join().expect("proc 1");
        assert_eq!(d0, vec![1.0, 11.0]);
        assert_eq!(d1, vec![0.0, 10.0]);
    }

    #[test]
    fn recv_times_out_when_peer_goes_quiet() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let dialer = std::thread::spawn(move || {
            // connect and then send nothing, keeping the socket open
            let s = TcpStream::connect(addr).unwrap();
            std::thread::sleep(Duration::from_millis(800));
            drop(s);
        });
        let (s, _) = l.accept().unwrap();
        let mut rx =
            SocketRx::new(Stream::Tcp(s), 0.2).expect("rx");
        let mut pool = PayloadPool::default();
        let t0 = Instant::now();
        match rx.recv(&mut pool) {
            Err(TransportError::Timeout(s)) => {
                assert!((s - 0.2).abs() < 1e-9);
            }
            other => panic!("expected timeout, got {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_millis(700));
        dialer.join().unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn rendezvous_assigns_indices_by_line_order() {
        let dir = crate::testkit::tmp_dir("rdzv");
        let file = dir.join("peers.txt");
        let file_s = file.to_string_lossy().to_string();
        let mk = |sock: String, file: String| {
            move || {
                let t = SocketTransport::with_rendezvous(
                    2, &sock, &file, 2, 5.0,
                )
                .expect("rendezvous transport");
                (t.process_index(), t.local_ranks())
            }
        };
        let s0 = dir.join("p0.sock").to_string_lossy().to_string();
        let s1 = dir.join("p1.sock").to_string_lossy().to_string();
        let h0 = std::thread::spawn(mk(s0, file_s.clone()));
        let h1 = std::thread::spawn(mk(s1, file_s));
        let (i0, r0) = h0.join().unwrap();
        let (i1, r1) = h1.join().unwrap();
        assert_ne!(i0, i1);
        let mut ranges = [r0, r1];
        ranges.sort_by_key(|r| r.start);
        assert_eq!(ranges, [0..1, 1..2]);
    }
}
