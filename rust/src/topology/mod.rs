//! Cluster topology: the paper's `<X>M<Y>G` encoding (§3.2), device
//! identities, link classification (PCIe intra-node vs network
//! inter-node), ring construction for allreduce, and hierarchical
//! grouping (intra-node group + inter-node leader ring).

use std::fmt;

/// A cluster of `machines` nodes with `gpus_per_machine` GPUs each —
/// the paper's "<X>M<Y>G" notation (e.g. 32M8G, Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub machines: usize,
    pub gpus_per_machine: usize,
}

/// A single GPU's identity within the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DeviceId {
    pub machine: usize,
    pub local: usize,
}

/// Link class between two devices (paper §4.4: two communication types).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// Same device (no transfer).
    Local,
    /// Intra-node over PCIe (paper: 64 Gb/s).
    Pcie,
    /// Inter-node over the network (paper: 10 Gb/s).
    Network,
}

impl Topology {
    pub fn new(machines: usize, gpus_per_machine: usize) -> Self {
        assert!(machines >= 1 && gpus_per_machine >= 1);
        Self { machines, gpus_per_machine }
    }

    /// Parse the paper's encoding: "32M8G" -> 32 machines x 8 GPUs.
    pub fn parse(s: &str) -> Result<Self, String> {
        let up = s.trim().to_ascii_uppercase();
        let m_pos = up.find('M').ok_or_else(|| format!("'{s}': missing M"))?;
        let g_pos = up.find('G').ok_or_else(|| format!("'{s}': missing G"))?;
        if g_pos < m_pos || g_pos != up.len() - 1 {
            return Err(format!("'{s}': expected <X>M<Y>G"));
        }
        let machines: usize = up[..m_pos]
            .parse()
            .map_err(|_| format!("'{s}': bad machine count"))?;
        let gpus: usize = up[m_pos + 1..g_pos]
            .parse()
            .map_err(|_| format!("'{s}': bad GPU count"))?;
        if machines == 0 || gpus == 0 {
            return Err(format!("'{s}': counts must be positive"));
        }
        Ok(Self::new(machines, gpus))
    }

    /// Total GPU count (paper Table 1: 256 for 32M8G).
    pub fn world_size(&self) -> usize {
        self.machines * self.gpus_per_machine
    }

    /// Flat rank of a device: machine-major order.
    pub fn rank(&self, dev: DeviceId) -> usize {
        debug_assert!(dev.machine < self.machines);
        debug_assert!(dev.local < self.gpus_per_machine);
        dev.machine * self.gpus_per_machine + dev.local
    }

    /// Device identity of a flat rank.
    pub fn device(&self, rank: usize) -> DeviceId {
        debug_assert!(rank < self.world_size());
        DeviceId {
            machine: rank / self.gpus_per_machine,
            local: rank % self.gpus_per_machine,
        }
    }

    /// All devices in rank order.
    pub fn devices(&self) -> Vec<DeviceId> {
        (0..self.world_size()).map(|r| self.device(r)).collect()
    }

    /// Classify the link between two devices.
    pub fn link(&self, a: DeviceId, b: DeviceId) -> LinkKind {
        if a == b {
            LinkKind::Local
        } else if a.machine == b.machine {
            LinkKind::Pcie
        } else {
            LinkKind::Network
        }
    }

    /// The flat ring order used by ring allreduce: rank i sends to
    /// rank (i+1) % n.  Machine-major order keeps most hops on PCIe —
    /// each machine's chain crosses the network exactly once, which is
    /// how NCCL forms rings on this topology (paper §3.2).
    pub fn ring_order(&self) -> Vec<DeviceId> {
        self.devices()
    }

    /// Count of network-crossing hops in the flat ring.
    pub fn ring_network_hops(&self) -> usize {
        let ring = self.ring_order();
        let n = ring.len();
        (0..n)
            .filter(|&i| {
                self.link(ring[i], ring[(i + 1) % n]) == LinkKind::Network
            })
            .count()
    }

    /// Hierarchical grouping (paper §4.4 resource separation):
    /// (intra-node groups in local-rank order, inter-node leader ring of
    /// the local-rank-0 devices).
    pub fn hierarchical_groups(&self) -> (Vec<Vec<DeviceId>>, Vec<DeviceId>) {
        let groups: Vec<Vec<DeviceId>> = (0..self.machines)
            .map(|m| {
                (0..self.gpus_per_machine)
                    .map(|l| DeviceId { machine: m, local: l })
                    .collect()
            })
            .collect();
        let leaders: Vec<DeviceId> = (0..self.machines)
            .map(|m| DeviceId { machine: m, local: 0 })
            .collect();
        (groups, leaders)
    }

    /// Render the Figure-1 style topology sketch.
    pub fn ascii_diagram(&self) -> String {
        let mut out = String::new();
        let show = self.machines.min(4);
        for m in 0..show {
            out.push_str(&format!("Node {m}: ["));
            let g = self.gpus_per_machine.min(8);
            for l in 0..g {
                out.push_str(&format!(" GPU{l}"));
            }
            if self.gpus_per_machine > 8 {
                out.push_str(" ...");
            }
            out.push_str(" ]  <-PCIe->\n");
            if m + 1 < show {
                out.push_str("    |  (10 Gb/s network)\n");
            }
        }
        if self.machines > show {
            out.push_str(&format!("    ... {} more nodes\n",
                                  self.machines - show));
        }
        out
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}M{}G", self.machines, self.gpus_per_machine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;

    #[test]
    fn parses_paper_topologies() {
        for (s, m, g) in [("1M1G", 1, 1), ("1M8G", 1, 8), ("2M1G", 2, 1),
                          ("32M8G", 32, 8), ("8m4g", 8, 4)] {
            let t = Topology::parse(s).unwrap();
            assert_eq!((t.machines, t.gpus_per_machine), (m, g), "{s}");
        }
        assert_eq!(Topology::parse("32M8G").unwrap().world_size(), 256);
    }

    #[test]
    fn rejects_malformed() {
        for s in ["", "M8G", "32M", "32G8M", "0M1G", "1M0G", "xMyG", "1M2G3"] {
            assert!(Topology::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn display_round_trips() {
        let t = Topology::new(32, 8);
        assert_eq!(Topology::parse(&t.to_string()).unwrap(), t);
    }

    #[test]
    fn rank_device_inverse() {
        let t = Topology::new(4, 8);
        for r in 0..t.world_size() {
            assert_eq!(t.rank(t.device(r)), r);
        }
    }

    #[test]
    fn link_classification() {
        let t = Topology::new(2, 2);
        let d = |m, l| DeviceId { machine: m, local: l };
        assert_eq!(t.link(d(0, 0), d(0, 0)), LinkKind::Local);
        assert_eq!(t.link(d(0, 0), d(0, 1)), LinkKind::Pcie);
        assert_eq!(t.link(d(0, 1), d(1, 0)), LinkKind::Network);
    }

    #[test]
    fn flat_ring_crosses_network_once_per_machine() {
        // Machine-major ring: exactly `machines` network hops (incl. the
        // wrap-around) when machines > 1.
        for (m, g) in [(2, 4), (4, 8), (32, 8)] {
            let t = Topology::new(m, g);
            assert_eq!(t.ring_network_hops(), m, "{t}");
        }
        assert_eq!(Topology::new(1, 8).ring_network_hops(), 0);
    }

    #[test]
    fn hierarchical_groups_partition_devices() {
        let t = Topology::new(3, 4);
        let (groups, leaders) = t.hierarchical_groups();
        assert_eq!(groups.len(), 3);
        assert_eq!(leaders.len(), 3);
        let mut all: Vec<usize> =
            groups.iter().flatten().map(|d| t.rank(*d)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
        assert!(leaders.iter().all(|d| d.local == 0));
    }

    #[test]
    fn prop_rank_bijective_random_topologies() {
        testkit::check(
            "rank-bijective", 0xB1, 64,
            |r: &mut Pcg64| (r.range_usize(1, 40), r.range_usize(1, 16)),
            |&(m, g)| {
                let t = Topology::new(m, g);
                let mut seen = vec![false; t.world_size()];
                for d in t.devices() {
                    let r = t.rank(d);
                    if seen[r] {
                        return false;
                    }
                    seen[r] = true;
                }
                seen.iter().all(|&x| x)
            },
        );
    }

    #[test]
    fn ascii_diagram_mentions_nodes() {
        let d = Topology::new(2, 4).ascii_diagram();
        assert!(d.contains("Node 0"));
        assert!(d.contains("GPU3"));
    }
}
