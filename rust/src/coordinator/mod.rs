//! The leader/coordinator: CLI subcommands wiring every module together.
//!
//! This is the deployment surface of the framework — the equivalent of
//! Megatron's `pretrain_bert.py` launcher, except everything downstream
//! of `make artifacts` is pure Rust.

mod cmd_amp;
mod cmd_cost;
mod cmd_info;
mod cmd_profile;
mod cmd_scaling;
mod cmd_shard;
mod cmd_simulate;
mod cmd_train;

pub use cmd_train::{prepare_datasets, train_run, train_run_with, CkptPlan,
                    NetPlan, TrainOutcome};

use crate::cliopt::Args;

const USAGE: &str = "\
bertdist — cost-efficient multi-node BERT pretraining (paper reproduction)

USAGE: bertdist <command> [options]

COMMANDS:
  train          data-parallel pretraining on the PJRT-CPU substrate
                   --preset bert-tiny --topo 1M2G --steps 50 --accum 4
                   --variant fused_f32 --optimizer lamb --lr 1e-4
                   --data-dir data/quickstart [--phase2] [--ckpt path]
                   [--overlap=false] [--wire-f16] [--bucket-elems N]
                   [--comm-mode flat|hierarchical|auto] [--topology 2M4G]
                   [--intra-node serial|ring|rs|auto]  intra-node
                                   schedule of the hierarchical
                                   exchange: ring = chunked pipelined
                                   member chain (the default on
                                   multi-GPU nodes), serial = (g-1)
                                   whole-bucket leader transfers, rs =
                                   bandwidth-optimal 2-level reduce-
                                   scatter (O(n/g) bytes per link on
                                   PCIe and the network)
                   [--chunk-elems N]  pipeline chunk size in elements
                                   (default 65536; > bucket = 1 chunk)
                   [--sparsify none|topk:RATIO]  top-k gradient
                                   sparsification on the NETWORK rings
                                   only (leader/flat/rs-cross; PCIe
                                   stays dense): each hop ships the
                                   top ceil(RATIO*len) coordinates as
                                   (index, value) frames, the dropped
                                   mass rides a per-rank error-feedback
                                   residual into the next step
                                   (checkpointed, so resume stays
                                   bitwise).  topk:1.0 is bitwise-equal
                                   to the dense exchange; inert on
                                   single-machine topologies
                   [--prefetch N]  per-rank batch-prefetch ring depth
                                   (default 2 = double buffer; 0 = build
                                   batches on the compute workers)
                   [--save-every N --ckpt-dir DIR [--keep-last K]]
                                   periodic v2 checkpoints: snapshot on
                                   the step boundary, atomic write +
                                   keep-newest-K rotation off the hot
                                   loop (background writer thread)
                   [--resume PATH] exact-state resume from a v2 file or
                                   a --ckpt-dir rotation dir (newest);
                                   bitwise-identical continuation —
                                   data position, loss-scaler state and
                                   config fingerprint are all restored,
                                   and any config mismatch fails loudly.
                                   Rerun the ORIGINAL command line plus
                                   --resume: completed steps are
                                   subtracted and the LR schedule keeps
                                   the original total; phase-2 snapshots
                                   of a --phase2 run resume into phase 2
                   [--resume-reshape PATH]  elastic resume: like --resume
                                   but relaxes the topology/exchange part
                                   of the fingerprint gate, so a v2
                                   checkpoint from one (machines, gpus)
                                   shape restores onto another.  Params,
                                   optimizer moments and the loss scaler
                                   restore bitwise; per-rank data streams
                                   and reduction association re-derive
                                   for the new world (docs/elastic.md)
                   [--max-restarts N]  supervise the run: on failure,
                                   relaunch up to N times from the newest
                                   ledger-verified checkpoint in
                                   --ckpt-dir (requires --save-every)
                   [--restart-topo 1M1G]  surviving-world topology for
                                   supervised relaunches (reshaped
                                   restore); default = keep the same
                   [--inject-fail [net:]S[:R]]  deterministic fault
                                   injection for tests: fail at
                                   data_step S, on rank R's last
                                   microbatch if given; the net: form
                                   cuts rank R's socket links mid-
                                   exchange instead (needs --listen)
                   [--listen ADDR]  make this process ONE participant of
                                   a multi-process world: ranks split
                                   evenly over the processes and bucket
                                   exchanges travel length-prefixed
                                   frames over TCP (host:port) or unix
                                   sockets (unix:/path) instead of
                                   in-memory channels.  Every process
                                   runs the same command line; results
                                   are bitwise-identical to the
                                   single-process run (docs/transport.md)
                   [--connect A,B,...]  static peer table: every
                                   process's listen address in RANK
                                   ORDER (must include this process's
                                   own --listen)
                   [--rendezvous FILE --nprocs N]  dynamic discovery à
                                   la torchrun: each process appends its
                                   bound address to FILE (so --listen
                                   host:0 works), first line = ranks
                                   0..world/N
                   [--net-timeout S]  socket recv timeout, seconds
                                   (default 30; <= 0 waits forever) —
                                   a quiet peer surfaces a transport
                                   timeout instead of hanging the run
                   [--net-key KEY]  authenticate the socket handshake
                                   with a shared secret (keyed BLAKE2s
                                   MAC over the handshake + a per-run
                                   nonce); every process must pass the
                                   same KEY, <= 32 bytes
                   [--net-retries N]  extra connect attempts per link
                                   before giving up (default 0)
                   [--net-backoff-ms MS]  base backoff between connect
                                   attempts, doubled per retry and
                                   capped at 500ms (default 20)
                   [--rejoin-window S]  with --max-restarts and
                                   --rendezvous: after a failure, keep
                                   the world SIZE and wait up to S
                                   seconds for the lost rank to be
                                   relaunched and re-admitted (grow-
                                   back) before degrading to the
                                   shrink/--restart-topo path
                   [--trace exchange.json]  exchange + data-stall spans
                 resume exit codes: 3 = checkpoint/config mismatch,
                 4 = corrupt and nothing older survived, 5 = nothing
                 restorable (missing file / empty dir / all unverified),
                 6 = stale rendezvous file (different run or older
                 generation — delete it or use a fresh path)
  shard-data     build bshard files from a synthetic or real corpus (§4.1)
                   --out data/quickstart --docs 64 --shards 8 [--text file]
  simulate       one-iteration timeline, overlap on/off (Figs. 2 & 5);
                 per-phase exchange spans (gather/ring/broadcast, split
                 per chunk under the pipelined intra-node schedule) and
                 a data-stall lane mirror the measured `train --trace`
                 (span naming: docs/tracing.md)
                   --topo 2M1G --accum 1 [--no-overlap] [--trace out.json]
                   [--comm-mode flat|hierarchical|auto]
                   [--intra-node serial|ring|rs|auto] [--chunk-elems N]
                   [--batch-build-ms X] [--no-prefetch]
  scaling        weak-scaling sweeps (Figs. 3 & 6)
                   --mode intra-inter | multinode  [--accum 4]
  profile-grads  gradient memory profile by layer group (Fig. 4); with
                 --trace, a measured bucket-exchange profile on the
                 persistent pool (PCIe/network chrome-trace spans).  The
                 trace runs REAL pooled steps, so use a small preset:
                   --preset bert-large                       (Fig. 4)
                   --preset bert-micro --trace exchange.json (profile)
                   [--topology 2M2G] [--comm-mode auto] [--steps 4]
                   [--intra-node serial|ring|rs|auto] [--chunk-elems N]
  cost           acquisition vs cloud cost tables (Tables 7 & 8)
                   [--days 12]
  amp-demo       mixed-precision walkthrough: op safety classes, loss
                 scaling dynamics on real f16 semantics (§4.2)
  info           inspect artifacts/manifest.json
                   [--artifacts artifacts]
";

/// CLI entrypoint; returns the process exit code.
pub fn cli_main() -> i32 {
    let args = match Args::parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let cmd = match args.command.as_deref() {
        Some(c) => c.to_string(),
        None => {
            print!("{USAGE}");
            return 0;
        }
    };
    let result = match cmd.as_str() {
        "train" => cmd_train::run(&args),
        "shard-data" => cmd_shard::run(&args),
        "simulate" => cmd_simulate::run(&args),
        "scaling" => cmd_scaling::run(&args),
        "profile-grads" => cmd_profile::run(&args),
        "cost" => cmd_cost::run(&args),
        "amp-demo" => cmd_amp::run(&args),
        "info" => cmd_info::run(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            // The resume-failure taxonomy (mismatch/corrupt/none) rides in
            // a CliExit anywhere in the chain; everything else exits 1.
            e.downcast_ref::<crate::cliopt::CliExit>().map_or(1, |x| x.code)
        }
    }
}
