//! `bertdist info` — inspect the AOT manifest and artifacts.

use std::path::PathBuf;

use crate::cliopt::Args;
use crate::runtime::Manifest;
use crate::util::{human_bytes, human_count};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let dir: PathBuf = args.get("artifacts", "artifacts").into();
    args.finish_strict()?;

    let m = Manifest::load(&dir)?;
    println!("artifacts dir: {}", dir.display());
    for (name, model) in &m.models {
        println!("\nmodel {name}:");
        println!("  params: {} ({})", human_count(model.param_count as f64),
                 human_bytes(model.param_count as f64 * 4.0));
        println!(
            "  config: hidden={} layers={} heads={} inter={} vocab={} seq<={}",
            model.config.hidden, model.config.layers, model.config.heads,
            model.config.intermediate, model.config.vocab_size,
            model.config.max_seq
        );
        println!("  tensors: {}", model.layout.entries().len());
        println!("  artifacts:");
        for (key, art) in &model.artifacts {
            let path = m.artifact_path(art);
            let size = std::fs::metadata(&path)
                .map(|md| human_bytes(md.len() as f64))
                .unwrap_or_else(|_| "MISSING".into());
            println!("    {key:<28} {size:>10}  ({} inputs)",
                     art.inputs.len());
        }
    }
    Ok(())
}
