//! `bertdist simulate` — one-iteration timeline on a modeled cluster
//! (Figures 1, 2 and 5).
//!
//! The modeled trace mirrors the measured `train --trace` artifact: a
//! hierarchical comm-mode resolve renders every bucket as the executed
//! gather → leader-ring → broadcast per-phase spans
//! (`bucket{i}.pcie.gather` / `bucket{i}.net` / `bucket{i}.pcie.bcast`,
//! with per-chunk `.c{k}` variants when the pipelined intra-node
//! schedule resolves — `--intra-node` / `--chunk-elems`), and the
//! modeled input pipeline gets its own data-stall lane
//! (`--batch-build-ms` + `--no-prefetch`).  See `docs/tracing.md` for
//! the full lane/span naming.

use crate::cliopt::Args;
use crate::collectives::pool::{CommMode, IntraNodeMode,
                               DEFAULT_CHUNK_ELEMS};
use crate::simulator::{simulate_iteration, IterationModel};
use crate::topology::Topology;
use crate::util::human_duration;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let topo = Topology::parse(&args.get("topo", "2M1G"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let accum = args.get_parse("accum", 1usize)?;
    let overlap = !args.flag("no-overlap");
    let buckets = args.get_parse("buckets", 8usize)?;
    let comm_mode = CommMode::parse(&args.get("comm-mode", "auto"))
        .map_err(|e| anyhow::anyhow!("--comm-mode: {e}"))?;
    let intra_node = IntraNodeMode::parse(&args.get("intra-node", "auto"))
        .map_err(|e| anyhow::anyhow!("--intra-node: {e}"))?;
    let chunk_elems = args.get_parse("chunk-elems", DEFAULT_CHUNK_ELEMS)?;
    let batch_build_ms = args.get_parse("batch-build-ms", 0.0f64)?;
    let prefetch = !args.flag("no-prefetch");
    let trace = args.get_opt("trace");
    let print_topo = args.flag("print-topology");
    args.finish_strict()?;

    if print_topo {
        println!("topology {topo} ({} GPUs):", topo.world_size());
        println!("{}", topo.ascii_diagram());
    }

    let mut model = IterationModel::paper(topo, accum, overlap);
    model.buckets = buckets;
    model.comm_mode = comm_mode;
    model.intra_node = intra_node;
    model.chunk_elems = chunk_elems;
    model.batch_build_s = batch_build_ms / 1e3;
    model.prefetch = prefetch;
    let r = simulate_iteration(&model);

    println!(
        "iteration on {topo}: k={accum} overlap={overlap} \
         buckets={buckets} comm={comm_mode} ({}) intra={intra_node} ({}) \
         prefetch={prefetch}",
        if model.is_hierarchical() { "hierarchical" } else { "flat" },
        if model.is_intra_rs() {
            "rs".to_string()
        } else if model.is_intra_ring() {
            format!("ring, {} chunks/bucket", model.bucket_chunks())
        } else {
            "serial".to_string()
        }
    );
    println!("  micro compute      : {}",
             human_duration(model.micro_compute_s()));
    if model.batch_build_s > 0.0 {
        println!("  micro batch build  : {}",
                 human_duration(model.batch_build_s));
    }
    println!("  allreduce (total)  : {}", human_duration(model.allreduce_s()));
    println!("  iteration time     : {}", human_duration(r.iteration_s));
    println!("  exposed comm       : {}", human_duration(r.exposed_comm_s));
    println!("  input stall        : {}", human_duration(r.input_stall_s));
    println!("  compute utilization: {:.1}%", r.compute_utilization * 100.0);
    println!("  tokens/s per GPU   : {:.1}", r.tokens_per_sec_per_gpu);
    println!("  cluster tokens/s   : {:.1}", r.cluster_tokens_per_sec);
    println!();
    println!("{}", r.timeline.ascii_gantt(100));

    if let Some(path) = trace {
        std::fs::write(&path, r.timeline.to_chrome_trace())?;
        println!("chrome trace -> {path} (open in ui.perfetto.dev)");
    }
    Ok(())
}
