//! `bertdist profile-grads` — Figure 4: gradient memory by layer group,
//! plus (with `--trace`) a MEASURED bucket-exchange profile on the
//! persistent collective pool: a few synthetic pooled steps on the
//! requested `--topology`/`--comm-mode`, exported as chrome-trace spans
//! split into PCIe and network phases (the `TrainReport.exchange`
//! artifact, viewable in ui.perfetto.dev).

use crate::cliopt::Args;
use crate::collectives::pool::{CollectivePool, CommMode, IntraNodeMode,
                               MicroStats, RankCompute, WireFormat,
                               DEFAULT_CHUNK_ELEMS};
use crate::grad::{bucket_ranges, build_buckets};
use crate::metrics::ExchangeTimings;
use crate::model::BertConfig;
use crate::topology::Topology;
use crate::util::ascii_plot::bar_chart;
use crate::util::human_bytes;

/// Deterministic synthetic gradients for the exchange profile: a pure
/// function of (rank, step, micro, i) — no XLA artifacts needed.
struct SynthGrads {
    n: usize,
}

impl RankCompute for SynthGrads {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             _params: &[f32], _scale: f32, out: &mut Vec<f32>)
             -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        for (i, v) in out.iter_mut().enumerate() {
            *v = ((rank * 31 + step_index * 7 + micro) % 13) as f32 * 0.25
                + (i % 17) as f32 * 0.125;
        }
        Ok(MicroStats::default())
    }
}

pub fn run(args: &Args) -> anyhow::Result<()> {
    let preset = args.get("preset", "bert-large");
    let trace = args.get_opt("trace");
    // `--topo` wins over its `--topology` alias — same precedence as
    // `bertdist train`, so both commands honor the same spelling.
    let topo_raw = args.get_opt_alias(&["topo", "topology"]);
    let comm_raw = args.get_opt("comm-mode");
    // These knobs only shape the --trace exchange profile; remember
    // whether any was given so we can say so instead of silently
    // ignoring them on a plain Figure-4 run.
    let intra_raw = args.get_opt("intra-node");
    let trace_knob_given = topo_raw.is_some() || comm_raw.is_some()
        || intra_raw.is_some()
        || args.get_opt("chunk-elems").is_some()
        || args.get_opt("steps").is_some()
        || args.get_opt("accum").is_some()
        || args.get_opt("bucket-elems").is_some();
    let topo =
        Topology::parse(&topo_raw.unwrap_or_else(|| "2M2G".into()))
            .map_err(|e| anyhow::anyhow!(e))?;
    let comm_mode = CommMode::parse(comm_raw.as_deref().unwrap_or("auto"))
        .map_err(|e| anyhow::anyhow!("--comm-mode: {e}"))?;
    let intra_mode =
        IntraNodeMode::parse(intra_raw.as_deref().unwrap_or("auto"))
            .map_err(|e| anyhow::anyhow!("--intra-node: {e}"))?;
    let chunk_elems = args.get_parse("chunk-elems", DEFAULT_CHUNK_ELEMS)?;
    let steps = args.get_parse("steps", 4usize)?;
    let accum = args.get_parse("accum", 2usize)?;
    let bucket_elems = args.get_parse("bucket-elems", 1usize << 20)?;
    args.finish_strict()?;
    if trace.is_none() && trace_knob_given {
        println!(
            "note: --topology/--comm-mode/--steps/--accum/--bucket-elems \
             only shape the measured exchange profile — pass --trace \
             <out.json> to run it\n"
        );
    }

    let cfg = BertConfig::preset(&preset)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {preset}"))?;
    let layout = cfg.param_layout();
    let profile = layout.gradient_profile();

    println!(
        "Figure 4 — gradient memory profile for {preset} \
         ({} params, {} of f32 gradients):\n",
        layout.total_len(), human_bytes(layout.total_bytes() as f64)
    );
    let rows: Vec<(String, f64)> = profile
        .sorted_rows()
        .into_iter()
        .map(|(name, bytes)| {
            (format!("{name:<13} {}", human_bytes(bytes)), bytes / 1e6)
        })
        .collect();
    println!("{}", bar_chart("MB of gradients per layer group", &rows, 50));
    println!(
        "dense (attention+intermediate+output) fraction: {:.1}%  — the \
         paper's argument against sparsification (§4.4)",
        profile.dense_fraction() * 100.0
    );

    // ---- measured bucket-exchange profile on the persistent pool ----
    if let Some(path) = trace {
        let n = layout.total_len();
        let world = topo.world_size();
        // One f32 accumulator per rank plus bucket scratch: refuse
        // worlds that would not fit an interactive profiling run.
        anyhow::ensure!(
            n.saturating_mul(world) <= 64 * 1024 * 1024,
            "exchange profile needs ~{} of rank buffers ({preset} x \
             {world} ranks) — use a smaller preset (bert-tiny/bert-micro) \
             or topology",
            human_bytes((n * world * 4) as f64)
        );
        let ranges = bucket_ranges(&build_buckets(&layout, bucket_elems));
        let mut pool = CollectivePool::with_intra(
            topo, n, ranges.clone(), WireFormat::F32, comm_mode,
            intra_mode, chunk_elems);
        println!(
            "\nexchange profile: topo={topo} world={world} comm={comm_mode} \
             ({}) intra={} buckets={} accum={accum} steps={steps}",
            if pool.is_hierarchical() { "hierarchical" } else { "flat" },
            if pool.is_intra_rs() {
                "rs".to_string()
            } else if pool.is_intra_ring() {
                format!("ring (chunk {chunk_elems})")
            } else {
                "serial".to_string()
            },
            ranges.len()
        );
        let synth = SynthGrads { n };
        let mut timings = ExchangeTimings {
            bucket_chunks: pool.chunks_per_bucket(),
            ..Default::default()
        };
        for s in 0..steps.max(1) {
            let out = pool.step(&[], 1.0, accum, s, true, &synth)?;
            timings.record(&out.bucket_s, &out.bucket_pcie_s,
                           &out.bucket_net_s, out.exposed_comm_s);
        }
        println!("{}", timings.summary());
        let tl = timings.to_timeline();
        std::fs::write(&path, tl.to_chrome_trace())?;
        println!("exchange trace -> {path} (open in ui.perfetto.dev)");
    }
    Ok(())
}
