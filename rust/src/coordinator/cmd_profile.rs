//! `bertdist profile-grads` — Figure 4: gradient memory by layer group.

use crate::cliopt::Args;
use crate::model::BertConfig;
use crate::util::ascii_plot::bar_chart;
use crate::util::human_bytes;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let preset = args.get("preset", "bert-large");
    args.finish_strict()?;

    let cfg = BertConfig::preset(&preset)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {preset}"))?;
    let layout = cfg.param_layout();
    let profile = layout.gradient_profile();

    println!(
        "Figure 4 — gradient memory profile for {preset} \
         ({} params, {} of f32 gradients):\n",
        layout.total_len(), human_bytes(layout.total_bytes() as f64)
    );
    let rows: Vec<(String, f64)> = profile
        .sorted_rows()
        .into_iter()
        .map(|(name, bytes)| {
            (format!("{name:<13} {}", human_bytes(bytes)), bytes / 1e6)
        })
        .collect();
    println!("{}", bar_chart("MB of gradients per layer group", &rows, 50));
    println!(
        "dense (attention+intermediate+output) fraction: {:.1}%  — the \
         paper's argument against sparsification (§4.4)",
        profile.dense_fraction() * 100.0
    );
    Ok(())
}
