//! `bertdist cost` — Tables 7 & 8: cloud vs acquisition cost estimation.

use crate::cliopt::Args;
use crate::costmodel;
use crate::util::fmt::render_table;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let days = args.get_parse("days", 12.0f64)?;
    args.finish_strict()?;

    println!("Table 7 — Google Cloud price estimation:\n");
    let cloud = costmodel::cloud_cost(256, days);
    println!("{}", render_table(
        &["Devices", "Count", "Price/hour", "Training time", "Total"],
        &[vec![
            "NVIDIA T4".into(), "256".into(),
            format!("${:.2}", costmodel::CLOUD_T4_PER_HOUR_USD),
            format!("{days} days"), format!("${cloud:.1}"),
        ]],
    ));

    println!("Table 8 — acquisition cost comparison:\n");
    let mut rows = vec![{
        let c = costmodel::paper_cluster();
        vec![c.name.clone(), format!("{}", c.units),
             format!("${:.0}", c.unit_cost_usd),
             format!("${:.0}", c.total())]
    }];
    for c in costmodel::dgx_clusters() {
        rows.push(vec![c.name.clone(), format!("{}", c.units),
                       format!("${:.0}", c.unit_cost_usd),
                       format!("${:.0}", c.total())]);
    }
    println!("{}", render_table(&["Cluster", "Units", "Unit price", "Total"],
                                &rows));

    let b = costmodel::break_even(days);
    println!("break-even (§6): a {:.0}-year replacement cycle fits {:.0} \
              {days}-day experiments;", costmodel::REPLACEMENT_YEARS,
             b.experiments_per_cycle);
    println!("  amortized ownership ${:.0}/experiment vs cloud \
              ${:.0}/experiment (own/cloud = {:.2})",
             b.own_cost_per_experiment, b.cloud_cost_per_experiment,
             b.own_over_cloud);
    Ok(())
}
