//! `bertdist amp-demo` — §4.2 walkthrough: op safety classification on
//! the BERT layer graph + dynamic loss scaling over real f16 semantics.

use crate::cliopt::Args;
use crate::half;
use crate::precision::{self, safety, DynamicLossScaler, StepVerdict};
use crate::util::Pcg64;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let steps = args.get_parse("steps", 200usize)?;
    args.finish_strict()?;

    // ---- 1. graph rewriting (the paper's plus/power/log example) ----
    println!("== op safety classification (paper §4.2) ==");
    for (name, kind) in [
        ("plus", safety::OpKind::Add),
        ("power", safety::OpKind::Pow),
        ("log", safety::OpKind::Log),
        ("matmul", safety::OpKind::MatMul),
        ("softmax", safety::OpKind::Softmax),
    ] {
        println!("  {name:<8} -> {:?}", safety::classify(kind));
    }
    let graph = safety::bert_layer_graph();
    let assign = safety::rewrite_graph(&graph);
    println!("\nBERT encoder layer rewrite:");
    for (op, &f16) in graph.iter().zip(&assign.f16) {
        println!("  {:<16} {}", op.name, if f16 { "fp16" } else { "fp32" });
    }
    println!("  => {}/{} ops in fp16, {} casts inserted\n",
             assign.count_f16(), graph.len(), assign.casts_inserted);

    // ---- 2. why scaling matters: f16 gradient fates ----
    println!("== gradient fate under f16 (real binary16 semantics) ==");
    let mut rng = Pcg64::new(7);
    let grads: Vec<f32> = (0..10_000)
        .map(|_| (rng.next_gaussian() * 1e-6) as f32)
        .collect();
    for scale in [1.0f32, 256.0, 65536.0] {
        let frac = precision::f16_zero_fraction(&grads, scale);
        println!("  scale {scale:>8}: {:.1}% of N(0, 1e-6) grads flush to 0",
                 frac * 100.0);
    }
    println!("  (f16 min subnormal = {:.3e})\n", half::F16_MIN_SUBNORMAL);

    // ---- 3. dynamic loss scaler trajectory ----
    println!("== dynamic loss scaler over {steps} steps ==");
    println!("   (overflow model: scale > 2^14 overflows)");
    let mut scaler = DynamicLossScaler::new(65536.0).with_growth_interval(20);
    let mut history = Vec::new();
    for s in 0..steps {
        let overflow = scaler.scale() > 16_384.0;
        let verdict = scaler.update(overflow);
        if s % (steps / 20).max(1) == 0 || verdict == StepVerdict::Skip {
            history.push((s, scaler.scale(), verdict));
        }
    }
    for (s, scale, verdict) in history.iter().take(25) {
        println!("  step {s:>4}: scale {scale:>10} {}",
                 if *verdict == StepVerdict::Skip { "SKIP (overflow)" }
                 else { "" });
    }
    println!("\n  final scale {}, skip rate {:.1}%",
             scaler.scale(), scaler.skip_rate() * 100.0);
    Ok(())
}
