//! `bertdist shard-data` — the §4.1 pre-sharding step: corpus →
//! tokenize → NSP pairs → N bshard files + vocab.txt.

use std::path::PathBuf;

use crate::cliopt::Args;
use crate::data::corpus::{self, SyntheticCorpus};
use crate::data::{build_shards, Vocab};
use crate::util::{human_count, Stopwatch};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let out: PathBuf = args.get("out", "data/quickstart").into();
    let n_docs = args.get_parse("docs", 64usize)?;
    let sentences = args.get_parse("sentences", 12usize)?;
    let words = args.get_parse("words", 12usize)?;
    let shards = args.get_parse("shards", 8usize)?;
    let vocab_size = args.get_parse("vocab-size", 8192usize)?;
    let seed = args.get_parse("seed", 42u64)?;
    let text = args.get_opt("text");
    args.finish_strict()?;

    let mut sw = Stopwatch::new();
    let docs = match text {
        Some(path) => {
            println!("loading corpus from {path} ...");
            corpus::load_text_file(std::path::Path::new(&path))?
        }
        None => {
            println!(
                "generating synthetic corpus: {n_docs} docs x {sentences} \
                 sentences x ~{words} words (seed {seed})"
            );
            SyntheticCorpus::new(seed, 20_000)
                .documents(n_docs, sentences, words)
        }
    };
    let n_words = corpus::word_count(&docs);
    sw.lap("corpus");

    let vocab = Vocab::from_documents(&docs, vocab_size);
    sw.lap("vocab");

    std::fs::create_dir_all(&out)?;
    vocab.save(&out.join("vocab.txt"))?;
    let stats = build_shards(&docs, &vocab, shards, &out, "train", seed)?;
    sw.lap("shard");

    println!(
        "corpus: {} documents, {} words -> {} examples ({} tokens)",
        stats.documents, human_count(n_words as f64), stats.examples,
        human_count(stats.tokens as f64)
    );
    println!("vocab: {} entries -> {}", vocab.len(),
             out.join("vocab.txt").display());
    println!("shards: {} files under {}", stats.shards, out.display());
    for (name, dt) in sw.laps() {
        println!("  {name:<8} {dt:.3}s");
    }
    Ok(())
}
