//! `bertdist scaling` — weak-scaling sweeps (Figures 3 and 6).

use crate::cliopt::Args;
use crate::simulator::scaling::{figure6_topologies, sweep_intra_vs_inter,
                                weak_scaling};
use crate::simulator::IterationModel;
use crate::topology::Topology;
use crate::util::ascii_plot::{plot_series, Series};
use crate::util::fmt::render_table;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let mode = args.get("mode", "multinode");
    let accum = args.get_parse("accum", 4usize)?;
    args.finish_strict()?;

    match mode.as_str() {
        "intra-inter" => intra_inter(),
        "multinode" => multinode(accum),
        other => anyhow::bail!("mode must be intra-inter|multinode, got {other}"),
    }
    Ok(())
}

fn intra_inter() {
    // Figure 3: no accumulation; overlap on.
    let template = IterationModel::paper(Topology::new(1, 1), 1, true);
    let (intra, inter) = sweep_intra_vs_inter(&template);
    let mut rows = Vec::new();
    for (a, b) in intra.iter().zip(&inter) {
        rows.push(vec![
            format!("{}", a.gpus),
            format!("{}", a.topo),
            format!("{:.2}x ({:.0}%)", a.scaling_factor,
                    a.efficiency * 100.0),
            format!("{}", b.topo),
            format!("{:.2}x ({:.0}%)", b.scaling_factor,
                    b.efficiency * 100.0),
        ]);
    }
    println!("Figure 3 — weak scaling, intra-node vs inter-node (k=1):\n");
    println!("{}", render_table(
        &["GPUs", "intra", "factor (eff)", "inter", "factor (eff)"], &rows));
    let ai: Vec<(f64, f64)> = intra.iter()
        .map(|p| (p.gpus as f64, p.scaling_factor)).collect();
    let bi: Vec<(f64, f64)> = inter.iter()
        .map(|p| (p.gpus as f64, p.scaling_factor)).collect();
    println!("{}", plot_series(
        "scaling factor vs GPUs",
        &[
            Series { name: "intra-node (PCIe 64Gb/s)", points: &ai,
                     marker: 'i' },
            Series { name: "inter-node (net 10Gb/s)", points: &bi,
                     marker: 'x' },
        ],
        60, 14));
}

fn multinode(accum: usize) {
    // Figure 6: k=4 by default, overlap on, xM8G.
    let template = IterationModel::paper(Topology::new(1, 1), accum, true);
    let pts = weak_scaling(&template, &figure6_topologies());
    let mut rows = Vec::new();
    for p in &pts {
        rows.push(vec![
            format!("{}", p.topo),
            format!("{}", p.gpus),
            format!("{:.0}", p.cluster_tokens_per_sec),
            format!("{:.1}x", p.scaling_factor),
            format!("{:.1}%", p.efficiency * 100.0),
            format!("{:.1}%", p.compute_utilization * 100.0),
        ]);
    }
    println!("Figure 6 — multi-node weak scaling (k={accum}, overlap on):\n");
    println!("{}", render_table(
        &["topo", "GPUs", "tokens/s", "factor", "efficiency", "util"],
        &rows));
    let xy: Vec<(f64, f64)> = pts.iter()
        .map(|p| (p.gpus as f64, p.scaling_factor)).collect();
    println!("{}", plot_series("scaling factor vs GPUs (paper: 165x @ 256)",
                               &[Series { name: "xM8G", points: &xy,
                                          marker: '*' }], 60, 14));
    if let Some(last) = pts.last() {
        println!("headline: {:.0}x at {} GPUs (paper reports 165x; \
                  abstract rounds efficiency to 70%)",
                 last.scaling_factor, last.gpus);
    }
}
