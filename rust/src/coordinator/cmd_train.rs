//! `bertdist train` — the end-to-end data-parallel pretraining command.
//!
//! Also exposes [`train_run`] / [`prepare_datasets`] so examples and
//! integration tests can drive the exact same path programmatically.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::checkpoint::{self, AsyncCheckpointWriter, Checkpoint,
                        Fingerprint, Ledger};
use crate::cliopt::{Args, CliExit, EXIT_RESUME_CORRUPT,
                    EXIT_RESUME_MISMATCH, EXIT_RESUME_NONE,
                    EXIT_STALE_RENDEZVOUS};
use crate::collectives::pool::{CommMode, IntraNodeMode};
use crate::collectives::{socket, InProcTransport, RendezvousStamp,
                         SocketTransport, Transport, TransportError};
use crate::config::{RunConfig, TwoPhaseSchedule};
use crate::data::pipeline::shard_manifest_hash;
use crate::data::ShardedDataset;
use crate::grad::sparsify::Sparsify;
use crate::runtime::Engine;
use crate::topology::Topology;
use crate::trainer::{InjectFail, TrainReport, Trainer};
use crate::util::ascii_plot::{plot_series, Series};

/// Outcome of a (possibly two-phase) training run.
pub struct TrainOutcome {
    pub phase1: TrainReport,
    pub phase2: Option<TrainReport>,
    pub trainer_step: usize,
    /// Whether this process hosted global rank 0 (always true for
    /// in-process runs).  Run-level side effects — plots, traces,
    /// schedule summaries — belong to the lead process only.
    pub lead: bool,
}

/// Multi-process run shape (CLI `--listen` + `--connect`/`--rendezvous`):
/// the world splits evenly over the participating processes and bucket
/// exchanges travel a [`SocketTransport`] instead of in-memory channels.
pub struct NetPlan {
    /// This process's listen address: `host:port` TCP (`:0` picks a
    /// free port under `--rendezvous`) or a `unix:/path` socket.
    pub listen: String,
    /// Static peer table, one listen address per process in RANK ORDER
    /// (`--connect`); must contain `listen`.  Mutually exclusive with
    /// `rendezvous`.
    pub peers: Option<Vec<String>>,
    /// Rendezvous file for dynamic discovery (`--rendezvous`): each
    /// process appends its address; first line = process 0.
    pub rendezvous: Option<String>,
    /// Expected process count under `rendezvous`.
    pub nprocs: usize,
    /// Shared handshake secret (`--net-key`); empty keeps the v1
    /// unauthenticated handshake.
    pub net_key: String,
    /// Dial-attempt cap (`--net-retries`; 0 = keep retrying on backoff
    /// until the setup deadline).
    pub net_retries: u32,
    /// Base dial backoff, milliseconds (`--net-backoff-ms`).
    pub net_backoff_ms: u64,
    /// Run fingerprint stamped into the rendezvous sidecar so a stale
    /// file from another run is refused instead of joined.
    pub run_id: [u8; 8],
    /// Stamp-generation floor for this attempt: the supervisor bumps it
    /// when it republishes a rejoin epoch, so a process cannot wire
    /// itself into a pre-failure address list.
    pub min_generation: u64,
    /// Rendezvous-wait override for a rejoin attempt (`--rejoin-window`
    /// seconds); `None` keeps the plain `net_timeout_s` deadline.
    pub window_s: Option<f64>,
}

/// Marker wrapping a socket-transport **setup** failure (bind, dial,
/// rendezvous timeout, rejoin-window expiry): the restart supervisor
/// distinguishes "the new world never formed" — where another grow-back
/// wait would just expire again, so it degrades to the shrink path —
/// from a mid-run exchange failure, where grow-back is worth trying.
#[derive(Debug)]
struct TransportSetupError(String);

impl std::fmt::Display for TransportSetupError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "socket transport setup: {}", self.0)
    }
}

impl std::error::Error for TransportSetupError {}

impl NetPlan {
    /// Open the socket transport this plan describes (binds the listen
    /// address; rendezvous waits for all peers to publish), then arm
    /// the connect backoff and — when a key is set — the authenticated
    /// handshake, BEFORE any link dials (links are wired lazily at pool
    /// build).  A stale rendezvous file maps to the
    /// [`EXIT_STALE_RENDEZVOUS`] taxonomy exit; every other setup
    /// failure wraps in [`TransportSetupError`] for the supervisor.
    fn open(&self, world: usize, timeout_s: f64)
        -> anyhow::Result<SocketTransport> {
        let t = match (&self.peers, &self.rendezvous) {
            (Some(peers), _) => SocketTransport::with_hosts(
                world, &self.listen, peers.clone(), timeout_s),
            (None, Some(file)) => {
                let stamp = RendezvousStamp {
                    run_id: self.run_id,
                    min_generation: self.min_generation,
                    window_s: self.window_s,
                };
                SocketTransport::with_rendezvous_stamped(
                    world, &self.listen, file, self.nprocs, timeout_s,
                    Some(&stamp))
            }
            (None, None) => anyhow::bail!(
                "--listen needs --connect HOSTS or --rendezvous FILE"),
        };
        let mut t = t.map_err(|e| match e {
            TransportError::StaleRendezvous(_) => CliExit::err(
                EXIT_STALE_RENDEZVOUS,
                format!("socket transport setup: {e}")),
            other => anyhow::Error::new(
                TransportSetupError(other.to_string())),
        })?;
        t.set_connect_backoff(self.net_retries, self.net_backoff_ms);
        if !self.net_key.is_empty() {
            // Nonce = MAC(key, run_id || generation): every process
            // derives the same value for the same epoch without it ever
            // crossing the wire, so a peer from another run OR an older
            // generation fails the handshake MAC/nonce check.
            let mut msg = [0u8; 16];
            msg[..8].copy_from_slice(&self.run_id);
            msg[8..].copy_from_slice(&t.generation().to_le_bytes());
            let mac = crate::util::blake2s::mac16(
                self.net_key.as_bytes(), &msg);
            let nonce: [u8; 8] = mac[..8].try_into().unwrap();
            t.set_auth(self.net_key.as_bytes(), nonce);
        }
        Ok(t)
    }
}

/// The fingerprint stamped into a rendezvous sidecar: an unkeyed 8-byte
/// digest of the run identity (config shape + corpus manifest), so two
/// launches of the SAME run agree on it without coordination while any
/// other run — or the same config over different data — differs.
fn derive_run_id(cfg: &RunConfig, batch: usize, seq: usize,
                 manifest: u64) -> [u8; 8] {
    let ident = format!(
        "{}|{}|{}|{}|{}|{}|{}|{}|{:016x}",
        cfg.train.preset, cfg.train.variant, cfg.train.seed,
        cfg.cluster.topo, batch, seq, cfg.train.steps,
        cfg.train.accum_steps, manifest
    );
    crate::util::blake2s::mac8(b"", ident.as_bytes())
}

/// Republish the rendezvous file for rejoin generation `gen`: exactly
/// one surviving process wins an O_EXCL election on a per-generation
/// marker, truncates the address list, and advances the stamp; the
/// losers wait for the stamp to reach `gen`.  Peers only append their
/// address AFTER validating the stamp, so the truncate cannot race a
/// concurrent join.  Returns once the file is ready for a fresh join
/// at the new generation.
fn republish_epoch(file: &str, gen: u64, run_id: [u8; 8], window_s: f64)
    -> anyhow::Result<()> {
    let marker = format!("{file}.epoch{gen}");
    match std::fs::OpenOptions::new()
        .write(true)
        .create_new(true)
        .open(&marker)
    {
        Ok(_) => {
            std::fs::write(file, b"")?;
            socket::write_stamp(file, run_id, gen)?;
            Ok(())
        }
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
            let deadline =
                Instant::now() + Duration::from_secs_f64(window_s.max(1.0));
            loop {
                if let Ok(Some((rid, g))) = socket::read_stamp(file) {
                    if rid == run_id && g >= gen {
                        return Ok(());
                    }
                }
                anyhow::ensure!(
                    Instant::now() <= deadline,
                    "rejoin: epoch {gen} was claimed but never \
                     republished to {file}"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
        }
        Err(e) => Err(anyhow::Error::new(e)
            .context(format!("rejoin: cannot claim epoch marker {marker}"))),
    }
}

/// How a run interacts with checkpoints (CLI `--ckpt`, `--resume`,
/// `--save-every` / `--keep-last` / `--ckpt-dir`).
#[derive(Default)]
pub struct CkptPlan<'a> {
    /// Final checkpoint written at each phase end (`--ckpt`).
    pub final_path: Option<&'a Path>,
    /// Legacy `--ckpt` convenience: restore from `final_path` when the
    /// file already exists.
    pub auto_resume: bool,
    /// Pre-loaded checkpoint to restore before phase 1 (`--resume` —
    /// already fingerprint-gated by the CLI layer; the trainer gates
    /// again on restore).
    pub resume: Option<Checkpoint>,
    /// Rotation directory for periodic async saves (`--ckpt-dir`);
    /// active when `cfg.train.save_every > 0`.
    pub rotate_dir: Option<&'a Path>,
    /// Elastic restore (`--resume-reshape` / a `--max-restarts`
    /// relaunch): `resume` may carry a DIFFERENT (machines, gpus)
    /// topology — world-invariant state restores bitwise, per-rank
    /// stream positions and bucket layout re-derive for this run's
    /// world.
    pub resume_reshape: bool,
    /// Deterministic fault injection threaded into the trainer
    /// (`--inject-fail step[:rank]` — the elastic-restart test hook).
    pub inject_fail: Option<InjectFail>,
}

/// Open one dataset view per rank.
pub fn prepare_datasets(dir: &Path, world: usize)
    -> anyhow::Result<Vec<ShardedDataset>> {
    (0..world)
        .map(|r| ShardedDataset::open(dir, "train", r, world))
        .collect()
}

/// The phase-2 run shape derived from a phase-1 config (paper Table 6
/// ratios): `(cfg2, batch2, seq2)`.  The single source both the CLI
/// resume pre-gate and [`train_run_with`]'s phase routing/trainer
/// construction use — they must agree or phase-2 resumes would be
/// rejected against a fingerprint no real snapshot carries.
fn phase2_shape(cfg: &RunConfig, batch1: usize)
    -> (RunConfig, usize, usize) {
    let mut cfg2 = cfg.clone();
    cfg2.data.seq_len = 512;
    cfg2.data.max_predictions = 80; // Table 6
    (cfg2, (batch1 / 8).max(1), 512)
}

/// Drive a run: phase 1 (and optionally phase 2) with a shared trainer
/// state, mirroring the paper's §3.3 schedule.  Legacy entry point:
/// `--ckpt` semantics only (final save + auto-resume when the file
/// exists); [`train_run_with`] exposes the full v2 checkpoint plan.
pub fn train_run(engine: &Engine, cfg: &RunConfig, data_dir: &Path,
                 steps1: usize, steps2: usize, batch1: usize, seq1: usize,
                 ckpt: Option<&Path>) -> anyhow::Result<TrainOutcome> {
    train_run_with(engine, cfg, data_dir, steps1, steps2, batch1, seq1,
                   CkptPlan {
                       final_path: ckpt,
                       auto_resume: true,
                       ..Default::default()
                   },
                   None)
}

/// [`train_run`] with the full checkpoint plan — exact `--resume`,
/// periodic async rotation, the legacy final-save path — and an
/// optional [`NetPlan`] that takes the exchange out-of-process over
/// sockets.  ONE transport serves both phases (links re-wire between
/// trainers, the listener stays bound), and run-level side effects
/// (checkpoint writes, plots, progress lines) happen only in the lead
/// process.
#[allow(clippy::too_many_arguments)]
pub fn train_run_with(engine: &Engine, cfg: &RunConfig, data_dir: &Path,
                      steps1: usize, steps2: usize, batch1: usize,
                      seq1: usize, mut plan: CkptPlan<'_>,
                      net: Option<&NetPlan>)
                      -> anyhow::Result<TrainOutcome> {
    let world = cfg.cluster.topo.world_size();
    let mut transport: Box<dyn Transport> = match net {
        None => Box::new(InProcTransport::new(world)),
        Some(n) => Box::new(n.open(world, cfg.train.net_timeout_s)?),
    };
    let lead = transport.local_ranks().start == 0;
    let datasets = prepare_datasets(data_dir, world)?;
    // Corpus identity: folded into every snapshot's fingerprint so a
    // resume over a different dataset fails loudly (v2.1).  The
    // datasets just opened, so the manifest cannot be missing.
    let manifest = shard_manifest_hash(data_dir, "train")?;

    // Periodic rotation writer, shared by both phases: snapshots happen
    // at step boundaries on the hot loop, writes on this background
    // thread.  Replicas are bitwise identical after every exchange, so
    // under a multi-process transport only the lead writes — peers
    // passing the same --save-every/--ckpt-dir stay inert instead of
    // racing the rotation.
    let mut writer = match (plan.rotate_dir, cfg.train.save_every) {
        (Some(dir), every) if every > 0 && lead => {
            Some(AsyncCheckpointWriter::new(dir, cfg.train.keep_last)?)
        }
        _ => None,
    };
    let save_every = cfg.train.save_every;

    // Route an exact --resume to its phase: a snapshot taken during
    // phase 2 carries the phase-2 batch geometry in its fingerprint (or,
    // for fingerprint-less files, a data_step past the phase-1 budget)
    // and must be restored into the phase-2 trainer — gating it against
    // the phase-1 config would make every phase-2 crash unrecoverable.
    let (cfg2, batch2, seq2) = phase2_shape(cfg, batch1);
    let mut resume1: Option<Checkpoint> = None;
    let mut resume2: Option<Checkpoint> = None;
    if let Some(ck) = plan.resume.take() {
        // A fingerprinted snapshot is routed by exact candidate match:
        // it goes to phase 2 when it matches the phase-2 fingerprint —
        // with the data_step counter as tie-break for configs where
        // the two phases share a fingerprint (e.g. batch 1, seq 512,
        // max_predictions forced to 80 in the TOML): a phase-2
        // snapshot's data_step always exceeds the phase-1 budget.
        // Anything matching neither candidate routes to phase 1, where
        // restore fails loudly with the field list.  Fingerprint-less
        // v1 files use the data_step heuristic alone.  (`steps` is
        // deliberately NOT fingerprinted, so a phase-1 snapshot whose
        // data_step exceeds a smaller --steps still routes to
        // phase 1 when the fingerprints are distinguishable.)
        let mut fp1 = Fingerprint::of(cfg, batch1, seq1);
        fp1.data_manifest = manifest;
        let mut fp2 = Fingerprint::of(&cfg2, batch2, seq2);
        fp2.data_manifest = manifest;
        let is_phase2 = steps2 > 0
            && match ck.fingerprint {
                Some(fp) => {
                    // under a reshaped restore the topology fields
                    // differ by design, so phase matching uses the
                    // relaxed comparison
                    let (m1, m2) = if plan.resume_reshape {
                        (fp.reshape_mismatches(&fp1).is_empty(),
                         fp.reshape_mismatches(&fp2).is_empty())
                    } else {
                        (fp == fp1, fp == fp2)
                    };
                    m2 && (!m1 || ck.data_step as usize > steps1)
                }
                None => ck.data_step as usize > steps1,
            };
        if is_phase2 {
            resume2 = Some(ck);
        } else {
            resume1 = Some(ck);
        }
    }
    let resuming_into_phase2 = resume2.is_some();

    // ---- phase 1 (skipped entirely — no trainer, no pool threads,
    //      no model-sized buffers — when resuming into phase 2) ----
    let mut trainer: Option<Trainer> = None;
    let report1 = if resuming_into_phase2 {
        println!("phase 1 already complete in the resumed run — skipping");
        TrainReport::default()
    } else {
        let mut t = Trainer::with_transport(engine, cfg.clone(), seq1,
                                            batch1, transport.as_mut())?;
        t.set_data_manifest(manifest);
        t.set_inject_fail(plan.inject_fail);
        // `--resume` finishes THE SAME run: already-consumed steps are
        // subtracted while total_steps_for_lr keeps the original
        // schedule, so the continuation is bitwise what the
        // uninterrupted run would have done.
        let mut run1 = steps1;
        if let Some(ck) = resume1.take() {
            if plan.resume_reshape {
                let from = ck.fingerprint.map_or("?".into(), |f| {
                    format!("{}M{}G", f.machines, f.gpus_per_machine)
                });
                println!(
                    "resuming reshaped: step {}, data_step {}, loss \
                     scale {} (checkpoint topology {from} -> run {})",
                    ck.step, ck.data_step, ck.loss_scale(),
                    cfg.cluster.topo
                );
                t.restore_reshape(ck)?;
            } else {
                println!(
                    "resuming exactly: step {}, data_step {}, loss \
                     scale {}",
                    ck.step, ck.data_step, ck.loss_scale()
                );
                t.restore(ck)?;
            }
            let done = t.data_step().min(steps1);
            run1 = steps1 - done;
            if done > 0 {
                println!(
                    "resume: {done}/{steps1} phase-1 steps already done \
                     — running {run1} more"
                );
            }
        } else if plan.auto_resume {
            if let Some(p) = plan.final_path.filter(|p| p.exists()) {
                println!("restoring checkpoint {}", p.display());
                let ck = Checkpoint::load(p)?;
                if ck.ensure_fingerprint(&t.fingerprint()).is_ok() {
                    t.restore(ck)?;
                } else {
                    // legacy convenience path: a --ckpt file saved
                    // under a different stream config (e.g. the
                    // phase-2 save of a finished two-phase run) still
                    // restarts — weights/step/scaler only, with the
                    // divergence made explicit.  Exact-or-fail
                    // semantics live behind --resume.
                    println!(
                        "note: checkpoint fingerprint differs from this \
                         run — restoring weights/step only (use --resume \
                         for exact-or-fail resume)"
                    );
                    t.restore_weights(ck)?;
                }
            }
        }
        println!(
            "phase 1: preset={} variant={} topo={} world={} ranks={:?} \
             batch={}x{} accum={} overlap={} wire={} comm={} ({}) \
             intra={} ({}) sparsify={} ({}) prefetch={}",
            cfg.train.preset, cfg.train.variant, cfg.cluster.topo, world,
            t.local_ranks(), batch1, seq1, cfg.train.accum_steps,
            cfg.train.overlap,
            if cfg.train.grad_wire_f16 { "f16" } else { "f32" },
            cfg.train.comm_mode,
            if t.is_hierarchical() { "hierarchical" } else { "flat" },
            cfg.train.intra_node,
            if t.is_intra_rs() {
                "rs".to_string()
            } else if t.is_intra_ring() {
                format!("ring, chunk {}", cfg.train.chunk_elems)
            } else {
                "serial".to_string()
            },
            cfg.train.sparsify,
            if t.sparsify_active() { "net rings" } else { "inert" },
            if cfg.train.prefetch_depth == 0 {
                "sync".to_string()
            } else {
                format!("x{}", cfg.train.prefetch_depth)
            }
        );
        let r = t.run_with_ckpt(
            &datasets, run1, steps1 + steps2,
            writer.as_mut().map(|w| (w, save_every)))?;
        println!("phase 1 done: {}", r.summary());
        println!("exchange: {}", r.exchange.summary());
        if let Some(p) = plan.final_path.filter(|_| lead) {
            t.save(p)?;
            println!("checkpoint -> {}", p.display());
        }
        trainer = Some(t);
        r
    };

    // ---- phase 2 (seq 512, smaller batch — Table 6 ratios) ----
    let report2 = if steps2 > 0 {
        // Same transport, new trainer: the links re-wire for the
        // phase-2 pool while the listener stays bound (no rebind race
        // with the peers' phase hand-off).
        let mut t2 = Trainer::with_transport(engine, cfg2, seq2, batch2,
                                             transport.as_mut())?;
        t2.set_data_manifest(manifest);
        t2.set_inject_fail(plan.inject_fail);
        let mut run2 = steps2;
        if let Some(ck) = resume2.take() {
            if plan.resume_reshape {
                println!(
                    "resuming reshaped into phase 2: step {}, data_step \
                     {}, loss scale {}",
                    ck.step, ck.data_step, ck.loss_scale()
                );
                t2.restore_reshape(ck)?;
            } else {
                println!(
                    "resuming exactly into phase 2: step {}, data_step \
                     {}, loss scale {}",
                    ck.step, ck.data_step, ck.loss_scale()
                );
                // strict gate against the PHASE-2 fingerprint
                t2.restore(ck)?;
            }
            let done = t2.data_step().saturating_sub(steps1).min(steps2);
            run2 = steps2 - done;
            if done > 0 {
                println!(
                    "resume: {done}/{steps2} phase-2 steps already done \
                     — running {run2} more"
                );
            }
        } else {
            // phase change: same weights/step/scaler, new batch
            // geometry — the fingerprint gate only pins a single
            // stream, so this goes through the weights-only restore.
            let t1 = trainer
                .as_ref()
                .expect("phase 1 ran (not resuming into phase 2)");
            t2.restore_weights(t1.checkpoint())?;
        }
        println!("phase 2: batch={batch2}x{seq2} (Table 6 ratios)");
        let r = t2.run_with_ckpt(&datasets, run2, steps1 + steps2,
                                 writer.as_mut().map(|w| (w, save_every)))?;
        println!("phase 2 done: {}", r.summary());
        println!("exchange: {}", r.exchange.summary());
        if let Some(p) = plan.final_path.filter(|_| lead) {
            t2.save(p)?;
        }
        trainer = Some(t2);
        Some(r)
    } else {
        None
    };

    if let Some(w) = writer {
        let stats = w.finish()?;
        let mib = stats.bytes as f64 / (1 << 20) as f64;
        println!(
            "async checkpoints: {} files, {} verified, {:.1} MiB at \
             {:.0} MiB/s off-loop (hot-loop stall {:.3}s, verify \
             {:.3}s off-loop)",
            stats.writes, stats.verified, mib,
            stats.bytes_per_sec() / (1 << 20) as f64,
            report1.checkpoint_s
                + report2.as_ref().map_or(0.0, |r| r.checkpoint_s),
            stats.verify_s
        );
    }

    Ok(TrainOutcome {
        phase1: report1,
        phase2: report2,
        // `trainer` is always Some here: phase 1 sets it unless we
        // resumed into phase 2, and that requires steps2 > 0, where
        // phase 2 sets it.
        trainer_step: trainer.map_or(0, |t| t.step),
        lead,
    })
}

/// Load + gate a `--resume` / `--resume-reshape` target: a checkpoint
/// file, or a rotation directory (tries its `ckpt-*.bckp` files NEWEST
/// FIRST — skipping any the directory's ledger marks unverified — and
/// falls back past unreadable/corrupt ones; that recovery depth is what
/// the keep-last-K rotation + post-write verify exist for).  Runs
/// BEFORE the engine/data setup so a missing file or a
/// config-fingerprint mismatch fails in milliseconds with a clear
/// message and a DISTINCT exit code ([`EXIT_RESUME_NONE`] /
/// [`EXIT_RESUME_CORRUPT`] / [`EXIT_RESUME_MISMATCH`]).  `candidates`
/// holds one expected fingerprint per phase of this run (two-phase runs
/// accept snapshots from either phase; routing happens in
/// [`train_run_with`]); `reshape` swaps in the relaxed topology gate.
fn load_resume(path: &Path, candidates: &[Fingerprint], reshape: bool)
    -> anyhow::Result<Checkpoint> {
    let files: Vec<std::path::PathBuf> = if path.is_dir() {
        let mut list: Vec<_> = checkpoint::list_checkpoints(path)?
            .into_iter()
            .map(|(_, p)| p)
            .collect();
        if list.is_empty() {
            return Err(CliExit::err(EXIT_RESUME_NONE, format!(
                "--resume {}: no ckpt-*.bckp files in directory",
                path.display()
            )));
        }
        // Never select a file the ledger KNOWS failed its post-write
        // verify.  Files unknown to the ledger (pre-ledger dirs,
        // hand-copied checkpoints) are still tried, newest first.
        let ledger = Ledger::load(path);
        let before = list.len();
        list.retain(|p| match p.file_name().and_then(|n| n.to_str()) {
            Some(n) => ledger.status(n) != Some(false),
            None => true,
        });
        if before > list.len() {
            eprintln!(
                "warning: ignoring {} checkpoint(s) marked unverified \
                 in {}",
                before - list.len(), Ledger::path(path).display()
            );
        }
        if list.is_empty() {
            return Err(CliExit::err(EXIT_RESUME_NONE, format!(
                "--resume {}: every checkpoint in the directory failed \
                 its post-write verify (see ledger.json) — nothing \
                 restorable",
                path.display()
            )));
        }
        list.reverse(); // newest first
        list
    } else {
        if !path.exists() {
            return Err(CliExit::err(EXIT_RESUME_NONE, format!(
                "cannot resume from {}: no such file", path.display()
            )));
        }
        vec![path.to_path_buf()]
    };
    let gate = |ck: &Checkpoint, fp: &Fingerprint| if reshape {
        ck.ensure_reshape_fingerprint(fp)
    } else {
        ck.ensure_fingerprint(fp)
    };
    let mut picked = None;
    for (i, file) in files.iter().enumerate() {
        match Checkpoint::load(file) {
            Ok(ck) => {
                if i > 0 {
                    eprintln!(
                        "warning: skipped {i} newer unreadable \
                         checkpoint(s); resuming from {}",
                        file.display()
                    );
                }
                picked = Some((ck, file));
                break;
            }
            Err(e) if i + 1 < files.len() => {
                eprintln!("warning: cannot read {}: {e} — trying the \
                           previous checkpoint", file.display());
            }
            Err(e) => return Err(CliExit::err(EXIT_RESUME_CORRUPT,
                format!("cannot resume from {}: {e}", file.display()))),
        }
    }
    let (ck, file) = picked.expect("loop either picked or errored");
    if !candidates.iter().any(|fp| gate(&ck, fp).is_ok()) {
        // report the mismatch against this run's primary (phase-1) shape
        let e = gate(&ck, &candidates[0])
            .expect_err("no candidate matched");
        return Err(CliExit::err(EXIT_RESUME_MISMATCH, format!(
            "--resume {}: {e}", file.display()
        )));
    }
    if !ck.exact_data_position {
        println!(
            "note: v1 checkpoint — data position is inexact \
             (data_step falls back to step)"
        );
    }
    println!(
        "resume checkpoint {}: step {}, data_step {}, loss scale {}",
        file.display(), ck.step, ck.data_step, ck.loss_scale()
    );
    Ok(ck)
}

pub fn run(args: &Args) -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.get_opt("config") {
        let doc = crate::config::TomlDoc::load(Path::new(&path))?;
        cfg = RunConfig::from_toml(&doc)?;
    }
    cfg.train.preset = args.get("preset", &cfg.train.preset);
    cfg.train.variant = args.get("variant", &cfg.train.variant);
    cfg.train.optimizer = args.get("optimizer", &cfg.train.optimizer);
    cfg.train.lr = args.get_parse("lr", cfg.train.lr)?;
    cfg.train.accum_steps = args.get_parse("accum", cfg.train.accum_steps)?;
    cfg.train.steps = args.get_parse("steps", cfg.train.steps)?;
    cfg.train.seed = args.get_parse("seed", cfg.train.seed)?;
    cfg.train.log_every = args.get_parse("log-every", cfg.train.log_every)?;
    cfg.train.warmup_steps =
        args.get_parse("warmup", cfg.train.warmup_steps)?;
    // Fig. 2 / §4.4 hot-loop knobs: `--overlap[=false]` toggles the
    // eager bucketed exchange, `--wire-f16` ships ring payloads as f16,
    // `--comm-mode flat|hierarchical|auto` picks the bucket route.
    if let Some(v) = args.flag_opt("overlap") {
        cfg.train.overlap = v;
    }
    if let Some(v) = args.flag_opt("wire-f16") {
        cfg.train.grad_wire_f16 = v;
    }
    if let Some(m) = args.get_opt("comm-mode") {
        cfg.train.comm_mode = CommMode::parse(&m)
            .map_err(|e| anyhow::anyhow!("--comm-mode: {e}"))?;
    }
    // Intra-node schedule of the hierarchical exchange (ISSUE 5, rs
    // added in ISSUE 9): `--intra-node serial|ring|rs|auto` picks
    // serialized-leader vs chunked-pipelined-chain vs 2-level
    // reduce-scatter transfers, `--chunk-elems N` the pipeline
    // granularity.
    if let Some(m) = args.get_opt("intra-node") {
        cfg.train.intra_node = IntraNodeMode::parse(&m)
            .map_err(|e| anyhow::anyhow!("--intra-node: {e}"))?;
    }
    // Top-k gradient sparsification of the NETWORK-crossing rings
    // (paper §4.4): `--sparsify none|topk:RATIO` — PCIe links stay
    // dense; dropped residual folds into the next step's gradient via
    // per-rank error-feedback accumulators.  Single-machine topologies
    // have no network link, so the knob is recorded but inert there.
    if let Some(s) = args.get_opt("sparsify") {
        cfg.train.sparsify = Sparsify::parse(&s)
            .map_err(|e| anyhow::anyhow!("--sparsify: {e}"))?;
    }
    cfg.train.chunk_elems =
        args.get_parse("chunk-elems", cfg.train.chunk_elems)?;
    cfg.train.bucket_elems =
        args.get_parse("bucket-elems", cfg.train.bucket_elems)?;
    // `--prefetch[=N]` (paper §4.1): N sets the per-rank batch-prefetch
    // ring depth (0 = build batches synchronously on the compute
    // workers); a bare `--prefetch` turns the default double buffer
    // back on when a config disabled it.
    if let Some(v) = args.get_opt("prefetch") {
        cfg.train.prefetch_depth = v.parse().map_err(|_| {
            anyhow::anyhow!("--prefetch: '{v}' is not a ring depth \
                             (expected an integer; 0 = synchronous)")
        })?;
    } else if args.flag("prefetch") {
        cfg.train.prefetch_depth = cfg.train.prefetch_depth.max(2);
    }
    // `--topology` is the paper-spelling alias of `--topo`.
    if let Some(t) = args.get_opt_alias(&["topo", "topology"]) {
        cfg.cluster.topo = Topology::parse(&t)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    let trace = args.get_opt("trace").map(PathBuf::from);
    let artifacts: PathBuf = args.get("artifacts", "artifacts").into();
    let data_dir: PathBuf = args.get("data-dir", "data/quickstart").into();
    let phase2_steps = args.get_parse(
        "phase2-steps",
        if args.flag("phase2") { cfg.train.steps / 5 } else { 0 },
    )?;
    let batch = args.get_parse("batch", 8usize)?;
    let seq = args.get_parse("seq", 128usize)?;
    let ckpt = args.get_opt("ckpt").map(PathBuf::from);
    // v2 checkpoint knobs: periodic async rotation + exact resume.
    cfg.train.save_every =
        args.get_parse("save-every", cfg.train.save_every)?;
    cfg.train.keep_last = args.get_parse("keep-last", cfg.train.keep_last)?;
    let ckpt_dir = args.get_opt("ckpt-dir").map(PathBuf::from);
    let resume = args.get_opt("resume").map(PathBuf::from);
    // Elastic-resume knobs: reshaped restore onto a different topology,
    // the supervised restart loop, and the deterministic fault hook.
    let resume_reshape = args.get_opt("resume-reshape").map(PathBuf::from);
    let max_restarts: usize = args.get_parse("max-restarts", 0usize)?;
    let restart_topo = match args.get_opt("restart-topo") {
        Some(t) => Some(Topology::parse(&t)
            .map_err(|e| anyhow::anyhow!("--restart-topo: {e}"))?),
        None => None,
    };
    let inject_fail = match args.get_opt("inject-fail") {
        Some(s) => Some(InjectFail::parse(&s)?),
        None => None,
    };
    // Socket-transport knobs (docs/transport.md): `--listen` makes this
    // process one participant of a multi-process world; peers come from
    // a static `--connect` table or a `--rendezvous` file.
    let listen = args.get_opt("listen");
    let connect = args.get_list_opt("connect");
    let rendezvous = args.get_opt("rendezvous");
    let nprocs: usize = args.get_parse("nprocs", 0usize)?;
    cfg.train.net_timeout_s =
        args.get_parse("net-timeout", cfg.train.net_timeout_s)?;
    // Elastic scale-UP knobs (docs/elastic.md): handshake auth, dial
    // backoff, and the supervised grow-back window.
    cfg.train.net_key = args.get("net-key", &cfg.train.net_key);
    cfg.train.net_retries =
        args.get_parse("net-retries", cfg.train.net_retries)?;
    cfg.train.net_backoff_ms =
        args.get_parse("net-backoff-ms", cfg.train.net_backoff_ms)?;
    cfg.train.rejoin_window_s =
        args.get_parse("rejoin-window", cfg.train.rejoin_window_s)?;
    args.finish_strict()?;
    cfg.validate()?;
    let mut net = match &listen {
        None => {
            anyhow::ensure!(
                connect.is_none() && rendezvous.is_none() && nprocs == 0,
                "--connect/--rendezvous/--nprocs need --listen ADDR (the \
                 address THIS process serves)"
            );
            None
        }
        Some(listen) => {
            anyhow::ensure!(
                connect.is_some() != rendezvous.is_some(),
                "--listen needs exactly one of --connect HOST,HOST,... \
                 (static peer table) or --rendezvous FILE (dynamic \
                 discovery)"
            );
            if let Some(peers) = &connect {
                anyhow::ensure!(
                    peers.contains(listen),
                    "--connect must list this process's own --listen \
                     address ({listen}); the list is the rank-ordered \
                     peer table"
                );
            }
            if rendezvous.is_some() {
                anyhow::ensure!(
                    nprocs >= 1,
                    "--rendezvous needs --nprocs N (how many processes \
                     share the world)"
                );
            }
            Some(NetPlan {
                listen: listen.clone(),
                peers: connect.clone(),
                rendezvous: rendezvous.clone(),
                nprocs,
                net_key: cfg.train.net_key.clone(),
                net_retries: cfg.train.net_retries,
                net_backoff_ms: cfg.train.net_backoff_ms,
                run_id: [0; 8], // derived below once the corpus is known
                min_generation: 0,
                window_s: None,
            })
        }
    };
    if cfg.train.rejoin_window_s > 0.0 {
        anyhow::ensure!(
            net.as_ref().map_or(false, |n| n.rendezvous.is_some()),
            "--rejoin-window needs --rendezvous FILE: grow-back re-admits \
             lost ranks through the republished rendezvous"
        );
        anyhow::ensure!(
            max_restarts > 0,
            "--rejoin-window does nothing without --max-restarts N"
        );
    }
    if let Some(f) = inject_fail {
        if f.net {
            anyhow::ensure!(
                listen.is_some(),
                "--inject-fail net:step[:rank] needs --listen: it cuts \
                 socket links, and the in-process transport has none"
            );
        }
    }
    if cfg.train.save_every > 0 && ckpt_dir.is_none() {
        anyhow::bail!(
            "--save-every needs --ckpt-dir DIR to hold the rotated files"
        );
    }
    if ckpt_dir.is_some() && cfg.train.save_every == 0 {
        // the converse would be silently inert: a rotation dir that
        // never receives a file, discovered only when --resume fails
        anyhow::bail!(
            "--ckpt-dir does nothing without --save-every N (or \
             train.save_every in the config TOML); to resume from an \
             existing rotation dir use --resume DIR"
        );
    }
    if resume.is_some() && resume_reshape.is_some() {
        anyhow::bail!(
            "--resume and --resume-reshape are mutually exclusive (one \
             exact restore target per run)"
        );
    }
    if max_restarts > 0 && (cfg.train.save_every == 0 || ckpt_dir.is_none())
    {
        anyhow::bail!(
            "--max-restarts needs --save-every N --ckpt-dir DIR: a \
             restart resumes from the newest ledger-verified rotation \
             checkpoint"
        );
    }
    if restart_topo.is_some() && max_restarts == 0 {
        anyhow::bail!(
            "--restart-topo does nothing without --max-restarts N"
        );
    }

    // --resume is validated (load + config fingerprint) BEFORE data and
    // engine setup: a bad resume must fail fast, loudly, and nonzero.
    // A two-phase run accepts snapshots from either phase's geometry.
    // The corpus manifest joins the gate when the data is readable —
    // a missing/empty data dir falls through to the friendlier "no
    // data at ..." error below rather than a corpus mismatch.
    let manifest = shard_manifest_hash(&data_dir, "train").unwrap_or(0);
    if let Some(n) = net.as_mut() {
        n.run_id = derive_run_id(&cfg, batch, seq, manifest);
    }
    let mut expected_fps = vec![Fingerprint::of(&cfg, batch, seq)];
    if phase2_steps > 0 {
        let (cfg2, batch2, seq2) = phase2_shape(&cfg, batch);
        expected_fps.push(Fingerprint::of(&cfg2, batch2, seq2));
    }
    for fp in &mut expected_fps {
        fp.data_manifest = manifest;
    }
    let reshape = resume_reshape.is_some();
    let resume_path = resume.or(resume_reshape);
    let resume_ckpt = match &resume_path {
        Some(p) => Some(load_resume(p, &expected_fps, reshape)?),
        None => None,
    };

    if !data_dir.join("vocab.txt").exists() {
        anyhow::bail!(
            "no data at {} — run `bertdist shard-data --out {}` first",
            data_dir.display(), data_dir.display()
        );
    }

    let engine = Engine::cpu(&artifacts)?;
    println!("engine: platform={}", engine.platform());
    // Guard: the data vocabulary must fit the model's embedding table,
    // or the gather produces garbage (NaN losses).
    let model = engine.model(&cfg.train.preset)?;
    let vocab = crate::data::Vocab::load(&data_dir.join("vocab.txt"))?;
    anyhow::ensure!(
        vocab.len() <= model.config.vocab_size,
        "data vocab has {} entries but {} supports only {} — re-run \
         `bertdist shard-data --vocab-size {}`",
        vocab.len(), cfg.train.preset, model.config.vocab_size,
        model.config.vocab_size
    );
    // ---- supervised restart loop (`--max-restarts`, the elastic
    //      workflow): on a mid-run failure with restarts left, reload
    //      the newest ledger-verified rotation checkpoint — losing at
    //      most save_every steps — optionally switch to the surviving
    //      topology (`--restart-topo`, via the reshaped restore), and
    //      relaunch.  max_restarts = 0 (the default) is the plain
    //      single-attempt run. ----
    let mut cur_cfg = cfg.clone();
    let mut pending_resume = resume_ckpt;
    let mut pending_reshape = reshape;
    let mut inject = inject_fail;
    let mut restarts_left = max_restarts;
    let auto_resume = resume_path.is_none();
    let mut attempt = 0usize;
    let mut cur_net = net;
    // Rendezvous generation counter for grow-back: bumped on every
    // republished epoch so stale peers (pre-failure world) cannot
    // re-wire themselves into the new one.
    let mut generation: u64 = 0;
    let outcome = loop {
        attempt += 1;
        let result = train_run_with(
            &engine, &cur_cfg, &data_dir, cur_cfg.train.steps,
            phase2_steps, batch, seq,
            CkptPlan {
                final_path: ckpt.as_deref(),
                auto_resume: auto_resume && attempt == 1,
                resume: pending_resume.take(),
                rotate_dir: ckpt_dir.as_deref(),
                resume_reshape: pending_reshape,
                inject_fail: inject,
            },
            cur_net.as_ref());
        match result {
            Ok(o) => break o,
            // Taxonomy exits (stale rendezvous, resume failures) are
            // deliberate refusals, not crashes: retrying would hit the
            // same wall, so they pass straight through to the caller.
            Err(e) if e.downcast_ref::<CliExit>().is_some() => {
                return Err(e)
            }
            Err(e) if restarts_left > 0 => {
                restarts_left -= 1;
                eprintln!("warning: training attempt {attempt} failed: \
                           {e:#}");
                // The injected fault is one-shot: the relaunch models
                // the world AFTER the node loss, where the fault (and
                // possibly the node) is gone.
                inject = None;
                // ---- grow-back first (`--rejoin-window`): keep the
                //      socket world, republish the rendezvous at the
                //      next generation, and wait for the lost rank to
                //      be relaunched and re-admitted at the SAME world
                //      size.  Skipped when the failed attempt never
                //      formed its world (TransportSetupError — e.g. a
                //      previous grow-back window expired): another
                //      wait would just expire again, so the supervisor
                //      degrades to the shrink path below. ----
                let grow_back = cur_cfg.train.rejoin_window_s > 0.0
                    && e.downcast_ref::<TransportSetupError>().is_none()
                    && cur_net
                        .as_ref()
                        .map_or(false, |n| n.rendezvous.is_some());
                if grow_back {
                    let window = cur_cfg.train.rejoin_window_s;
                    let n = cur_net.as_mut().expect("grow_back has a net");
                    generation += 1;
                    let file = n
                        .rendezvous
                        .clone()
                        .expect("grow_back is rendezvous-gated");
                    republish_epoch(&file, generation, n.run_id, window)?;
                    n.min_generation = generation;
                    n.window_s = Some(window);
                    println!(
                        "rejoin: republished rendezvous epoch \
                         {generation} to {file} — waiting up to \
                         {window:.0}s for {} process(es)",
                        n.nprocs
                    );
                } else {
                    // A socket-run restart means a peer is gone for
                    // good: the survivor relaunches alone, in-process,
                    // on the (usually shrunken) --restart-topo world —
                    // the lost-node elastic path of docs/elastic.md.
                    if cur_net.take().is_some() {
                        println!(
                            "restart: dropping the socket transport — \
                             relaunching single-process"
                        );
                    }
                    if let Some(t) = restart_topo {
                        if cur_cfg.cluster.topo != t {
                            cur_cfg.cluster.topo = t;
                            pending_reshape = true;
                        }
                    }
                }
                // Re-derive the expected fingerprints for the
                // (possibly reshaped) surviving topology, then pick
                // the newest ledger-verified rotation checkpoint.
                let dir = ckpt_dir.as_deref()
                    .expect("--max-restarts requires --ckpt-dir");
                let mut fps =
                    vec![Fingerprint::of(&cur_cfg, batch, seq)];
                if phase2_steps > 0 {
                    let (c2, b2, s2) = phase2_shape(&cur_cfg, batch);
                    fps.push(Fingerprint::of(&c2, b2, s2));
                }
                for fp in &mut fps {
                    fp.data_manifest = manifest;
                }
                let ck = load_resume(dir, &fps, pending_reshape)?;
                println!(
                    "restart {attempt}: relaunching on {} from \
                     data_step {} ({restarts_left} restart(s) left)",
                    cur_cfg.cluster.topo, ck.data_step
                );
                pending_resume = Some(ck);
            }
            Err(e) => return Err(e),
        }
    };

    // Run-level outputs below (trace files, plots, schedule summary)
    // belong to the lead process; a non-lead socket peer is done.
    if !outcome.lead {
        return Ok(());
    }

    // Exchange spans (TrainReport.exchange) as a chrome trace: the mean
    // per-step bucket exchange, split into PCIe and network phases.
    // Phase 2 (different batch/seq over the same payload) gets its own
    // sibling file rather than being silently dropped.
    if let Some(path) = &trace {
        std::fs::write(path,
                       outcome.phase1.exchange.to_timeline()
                           .to_chrome_trace())?;
        println!("exchange trace -> {} (open in ui.perfetto.dev)",
                 path.display());
        if let Some(r2) = &outcome.phase2 {
            let mut p2 = path.as_os_str().to_owned();
            p2.push(".phase2.json");
            let p2 = PathBuf::from(p2);
            std::fs::write(&p2,
                           r2.exchange.to_timeline().to_chrome_trace())?;
            println!("phase-2 exchange trace -> {}", p2.display());
        }
    }

    // Figure-7 style loss plot
    let p1 = outcome.phase1.loss.xy();
    let mut series = vec![Series { name: "phase1 loss", points: &p1,
                                   marker: '1' }];
    let p2xy = outcome.phase2.as_ref().map(|r| r.loss.xy());
    if let Some(ref p2) = p2xy {
        series.push(Series { name: "phase2 loss", points: p2, marker: '2' });
    }
    println!("{}", plot_series("pretraining loss (cf. paper Fig. 7)",
                               &series, 70, 16));
    if phase2_steps > 0 {
        let sched = TwoPhaseSchedule::paper();
        println!(
            "paper schedule reference: {} epochs phase1 + {} phase2 = {:.1} \
             days on 32M8G",
            sched.phase1.epochs, sched.phase2.epochs,
            sched.paper_total_days()
        );
    }
    Ok(())
}
