//! `bertdist train` — the end-to-end data-parallel pretraining command.
//!
//! Also exposes [`train_run`] / [`prepare_datasets`] so examples and
//! integration tests can drive the exact same path programmatically.

use std::path::{Path, PathBuf};

use crate::cliopt::Args;
use crate::collectives::pool::CommMode;
use crate::config::{RunConfig, TwoPhaseSchedule};
use crate::data::ShardedDataset;
use crate::runtime::Engine;
use crate::topology::Topology;
use crate::trainer::{TrainReport, Trainer};
use crate::util::ascii_plot::{plot_series, Series};

/// Outcome of a (possibly two-phase) training run.
pub struct TrainOutcome {
    pub phase1: TrainReport,
    pub phase2: Option<TrainReport>,
    pub trainer_step: usize,
}

/// Open one dataset view per rank.
pub fn prepare_datasets(dir: &Path, world: usize)
    -> anyhow::Result<Vec<ShardedDataset>> {
    (0..world)
        .map(|r| ShardedDataset::open(dir, "train", r, world))
        .collect()
}

/// Drive a run: phase 1 (and optionally phase 2) with a shared trainer
/// state, mirroring the paper's §3.3 schedule.
pub fn train_run(engine: &Engine, cfg: &RunConfig, data_dir: &Path,
                 steps1: usize, steps2: usize, batch1: usize, seq1: usize,
                 ckpt: Option<&Path>) -> anyhow::Result<TrainOutcome> {
    let world = cfg.cluster.topo.world_size();
    let datasets = prepare_datasets(data_dir, world)?;

    // ---- phase 1 ----
    let mut trainer = Trainer::new(engine, cfg.clone(), seq1, batch1)?;
    if let Some(p) = ckpt {
        if p.exists() {
            println!("restoring checkpoint {}", p.display());
            trainer.restore(crate::checkpoint::Checkpoint::load(p)?)?;
        }
    }
    println!(
        "phase 1: preset={} variant={} topo={} world={} batch={}x{} \
         accum={} overlap={} wire={} comm={} ({}) prefetch={}",
        cfg.train.preset, cfg.train.variant, cfg.cluster.topo, world,
        batch1, seq1, cfg.train.accum_steps, cfg.train.overlap,
        if cfg.train.grad_wire_f16 { "f16" } else { "f32" },
        cfg.train.comm_mode,
        if trainer.is_hierarchical() { "hierarchical" } else { "flat" },
        if cfg.train.prefetch_depth == 0 {
            "sync".to_string()
        } else {
            format!("x{}", cfg.train.prefetch_depth)
        }
    );
    let report1 = trainer.run(&datasets, steps1, steps1 + steps2)?;
    println!("phase 1 done: {}", report1.summary());
    println!("exchange: {}", report1.exchange.summary());
    if let Some(p) = ckpt {
        trainer.save(p)?;
        println!("checkpoint -> {}", p.display());
    }

    // ---- phase 2 (seq 512, smaller batch — Table 6 ratios) ----
    let report2 = if steps2 > 0 {
        let batch2 = (batch1 / 8).max(1);
        let seq2 = 512;
        let mut cfg2 = cfg.clone();
        cfg2.data.seq_len = seq2;
        cfg2.data.max_predictions = 80; // Table 6
        let mut t2 = Trainer::new(engine, cfg2, seq2, batch2)?;
        t2.restore(trainer.checkpoint())?;
        println!("phase 2: batch={batch2}x{seq2} (Table 6 ratios)");
        let r = t2.run(&datasets, steps2, steps1 + steps2)?;
        println!("phase 2 done: {}", r.summary());
        println!("exchange: {}", r.exchange.summary());
        if let Some(p) = ckpt {
            t2.save(p)?;
        }
        let step = t2.step;
        trainer = t2;
        let _ = step;
        Some(r)
    } else {
        None
    };

    Ok(TrainOutcome {
        phase1: report1,
        phase2: report2,
        trainer_step: trainer.step,
    })
}

pub fn run(args: &Args) -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.get_opt("config") {
        let doc = crate::config::TomlDoc::load(Path::new(&path))?;
        cfg = RunConfig::from_toml(&doc)?;
    }
    cfg.train.preset = args.get("preset", &cfg.train.preset);
    cfg.train.variant = args.get("variant", &cfg.train.variant);
    cfg.train.optimizer = args.get("optimizer", &cfg.train.optimizer);
    cfg.train.lr = args.get_parse("lr", cfg.train.lr)?;
    cfg.train.accum_steps = args.get_parse("accum", cfg.train.accum_steps)?;
    cfg.train.steps = args.get_parse("steps", cfg.train.steps)?;
    cfg.train.seed = args.get_parse("seed", cfg.train.seed)?;
    cfg.train.log_every = args.get_parse("log-every", cfg.train.log_every)?;
    cfg.train.warmup_steps =
        args.get_parse("warmup", cfg.train.warmup_steps)?;
    // Fig. 2 / §4.4 hot-loop knobs: `--overlap[=false]` toggles the
    // eager bucketed exchange, `--wire-f16` ships ring payloads as f16,
    // `--comm-mode flat|hierarchical|auto` picks the bucket route.
    if let Some(v) = args.flag_opt("overlap") {
        cfg.train.overlap = v;
    }
    if let Some(v) = args.flag_opt("wire-f16") {
        cfg.train.grad_wire_f16 = v;
    }
    if let Some(m) = args.get_opt("comm-mode") {
        cfg.train.comm_mode = CommMode::parse(&m)
            .map_err(|e| anyhow::anyhow!("--comm-mode: {e}"))?;
    }
    cfg.train.bucket_elems =
        args.get_parse("bucket-elems", cfg.train.bucket_elems)?;
    // `--prefetch[=N]` (paper §4.1): N sets the per-rank batch-prefetch
    // ring depth (0 = build batches synchronously on the compute
    // workers); a bare `--prefetch` turns the default double buffer
    // back on when a config disabled it.
    if let Some(v) = args.get_opt("prefetch") {
        cfg.train.prefetch_depth = v.parse().map_err(|_| {
            anyhow::anyhow!("--prefetch: '{v}' is not a ring depth \
                             (expected an integer; 0 = synchronous)")
        })?;
    } else if args.flag("prefetch") {
        cfg.train.prefetch_depth = cfg.train.prefetch_depth.max(2);
    }
    // `--topology` is the paper-spelling alias of `--topo`.
    if let Some(t) = args.get_opt_alias(&["topo", "topology"]) {
        cfg.cluster.topo = Topology::parse(&t)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    let trace = args.get_opt("trace").map(PathBuf::from);
    let artifacts: PathBuf = args.get("artifacts", "artifacts").into();
    let data_dir: PathBuf = args.get("data-dir", "data/quickstart").into();
    let phase2_steps = args.get_parse(
        "phase2-steps",
        if args.flag("phase2") { cfg.train.steps / 5 } else { 0 },
    )?;
    let batch = args.get_parse("batch", 8usize)?;
    let seq = args.get_parse("seq", 128usize)?;
    let ckpt = args.get_opt("ckpt").map(PathBuf::from);
    args.finish_strict()?;
    cfg.validate()?;

    if !data_dir.join("vocab.txt").exists() {
        anyhow::bail!(
            "no data at {} — run `bertdist shard-data --out {}` first",
            data_dir.display(), data_dir.display()
        );
    }

    let engine = Engine::cpu(&artifacts)?;
    println!("engine: platform={}", engine.platform());
    // Guard: the data vocabulary must fit the model's embedding table,
    // or the gather produces garbage (NaN losses).
    let model = engine.model(&cfg.train.preset)?;
    let vocab = crate::data::Vocab::load(&data_dir.join("vocab.txt"))?;
    anyhow::ensure!(
        vocab.len() <= model.config.vocab_size,
        "data vocab has {} entries but {} supports only {} — re-run \
         `bertdist shard-data --vocab-size {}`",
        vocab.len(), cfg.train.preset, model.config.vocab_size,
        model.config.vocab_size
    );
    let outcome = train_run(&engine, &cfg, &data_dir, cfg.train.steps,
                            phase2_steps, batch, seq, ckpt.as_deref())?;

    // Exchange spans (TrainReport.exchange) as a chrome trace: the mean
    // per-step bucket exchange, split into PCIe and network phases.
    // Phase 2 (different batch/seq over the same payload) gets its own
    // sibling file rather than being silently dropped.
    if let Some(path) = &trace {
        std::fs::write(path,
                       outcome.phase1.exchange.to_timeline()
                           .to_chrome_trace())?;
        println!("exchange trace -> {} (open in ui.perfetto.dev)",
                 path.display());
        if let Some(r2) = &outcome.phase2 {
            let mut p2 = path.as_os_str().to_owned();
            p2.push(".phase2.json");
            let p2 = PathBuf::from(p2);
            std::fs::write(&p2,
                           r2.exchange.to_timeline().to_chrome_trace())?;
            println!("phase-2 exchange trace -> {}", p2.display());
        }
    }

    // Figure-7 style loss plot
    let p1 = outcome.phase1.loss.xy();
    let mut series = vec![Series { name: "phase1 loss", points: &p1,
                                   marker: '1' }];
    let p2xy = outcome.phase2.as_ref().map(|r| r.loss.xy());
    if let Some(ref p2) = p2xy {
        series.push(Series { name: "phase2 loss", points: p2, marker: '2' });
    }
    println!("{}", plot_series("pretraining loss (cf. paper Fig. 7)",
                               &series, 70, 16));
    if phase2_steps > 0 {
        let sched = TwoPhaseSchedule::paper();
        println!(
            "paper schedule reference: {} epochs phase1 + {} phase2 = {:.1} \
             days on 32M8G",
            sched.phase1.epochs, sched.phase2.epochs,
            sched.paper_total_days()
        );
    }
    Ok(())
}
