//! Discrete-event cluster simulator (paper §5 evaluation substrate).
//!
//! Reproduces the paper's evaluation figures on modeled hardware:
//! * [`devices`] — per-device throughput models calibrated from the
//!   paper's own Table 4 measurements (P100 / T4 / 2080 Ti, with the
//!   FP16 and kernel-fusion multipliers);
//! * [`timeline`] — one data-parallel iteration as a span timeline:
//!   fwd/bwd compute, bucketed gradient exchange with or without
//!   communication/computation overlap, gradient accumulation (Figures
//!   2 and 5);
//! * [`scaling`] — weak-scaling sweeps over `<X>M<Y>G` topologies
//!   (Figures 3 and 6, Table 3).
//!
//! The model: compute time from the device token throughput; ring
//! allreduce time from `netsim`'s 2(n−1)/n law over the bottleneck
//! fabric; overlap hides at most the backward window of the last
//! micro-batch (buckets are exchanged as they become ready, §4.4).
//! Calibration checks in `scaling.rs` assert the paper's anchor points
//! (≈165× at 32M8G with k=4; ≈38% inter-node efficiency at 8M1G).

pub mod devices;
pub mod scaling;
pub mod timeline;

pub use devices::{DeviceModel, Variant, DEVICES, PAPER_TOKENS_PER_EPOCH};
pub use scaling::{sweep_intra_vs_inter, weak_scaling, ScalingPoint};
pub use timeline::{simulate_iteration, IterationModel, IterationResult};
