//! Iteration timeline model (paper Figures 2 and 5).
//!
//! One data-parallel iteration at a representative GPU:
//!
//! ```text
//! no overlap:   [fwd][bwd]                [====allreduce====][upd]
//! overlap:      [fwd][bwd]                                   [upd]
//!                     └ buckets fire as bwd passes them ┐
//!                      [ar b0][ar b1][ar b2]...─────────┘
//! grad accum:   [fwd][bwd][fwd][bwd][fwd][bwd][fwd][bwd]  (k=4)
//!                                            [====allreduce===][upd]
//! ```
//!
//! Gradients become ready progressively during backward; with overlap
//! the exchange of bucket `i` starts once backward has passed it, so at
//! most the backward window of the LAST micro-batch hides communication
//! (earlier micro-batches only produce partial sums — the exchange must
//! wait for the final accumulation, §4.4).

use crate::metrics::Timeline;
use crate::netsim::{ring_allreduce_time, Fabric};
use crate::topology::Topology;

/// Inputs of the iteration model.
#[derive(Debug, Clone)]
pub struct IterationModel {
    pub topo: Topology,
    pub fabric: Fabric,
    /// Per-GPU tokens per micro-batch (e.g. 32 sentences x 128 seq).
    pub tokens_per_micro: f64,
    /// Device throughput in tokens/s (from `devices`).
    pub device_tokens_per_sec: f64,
    /// Gradient payload in bytes (f32 model size).
    pub grad_bytes: f64,
    /// Gradient accumulation steps k (>= 1).
    pub accum_steps: usize,
    /// Overlap communication with the last backward (Fig. 2 right).
    pub overlap: bool,
    /// Number of gradient buckets (overlap granularity).
    pub buckets: usize,
    /// Weight-update time as a fraction of one micro-batch compute.
    pub update_frac: f64,
}

impl IterationModel {
    /// The paper's headline configuration on a given topology: T4
    /// fused-FP16 device, BERT-large gradients, phase-1 micro-batch.
    pub fn paper(topo: Topology, accum_steps: usize, overlap: bool) -> Self {
        IterationModel {
            topo,
            fabric: Fabric::paper(),
            tokens_per_micro: 32.0 * 128.0,
            device_tokens_per_sec: super::devices::t4()
                .throughput(super::devices::Variant::Fp16Fused),
            grad_bytes: 336_226_108.0 * 4.0, // BERT-large f32 grads
            accum_steps,
            overlap,
            buckets: 8,
            update_frac: 0.05,
        }
    }

    /// Compute time of one micro-batch (fwd+bwd) in seconds.
    pub fn micro_compute_s(&self) -> f64 {
        self.tokens_per_micro / self.device_tokens_per_sec
    }

    /// Full-gradient ring allreduce time on this topology.
    pub fn allreduce_s(&self) -> f64 {
        let n = self.topo.world_size();
        if n <= 1 {
            return 0.0;
        }
        let link = self.fabric.ring_bottleneck(&self.topo);
        // per-bucket exchanges: same total bytes, more latency terms
        let per_bucket = self.grad_bytes / self.buckets.max(1) as f64;
        (0..self.buckets.max(1))
            .map(|_| ring_allreduce_time(n, per_bucket, link))
            .sum()
    }
}

/// Output of the iteration simulation.
#[derive(Debug, Clone)]
pub struct IterationResult {
    /// Wall-clock seconds for one optimizer iteration.
    pub iteration_s: f64,
    /// Fraction of the iteration the GPU compute stream is busy.
    pub compute_utilization: f64,
    /// Seconds of communication NOT hidden by compute.
    pub exposed_comm_s: f64,
    /// Tokens processed per second per GPU.
    pub tokens_per_sec_per_gpu: f64,
    /// Cluster-wide tokens/s.
    pub cluster_tokens_per_sec: f64,
    /// The span timeline (Figure 2/5 artifact).
    pub timeline: Timeline,
}

/// Simulate one iteration (Figures 2 and 5).
pub fn simulate_iteration(m: &IterationModel) -> IterationResult {
    let c = m.micro_compute_s();
    let fwd = c / 3.0;
    let bwd = c - fwd;
    let k = m.accum_steps.max(1);
    let comm_total = m.allreduce_s();
    let update = m.update_frac * c;

    let mut tl = Timeline::default();
    let gpu = "gpu";
    let net = "net";

    // compute spans: k micro-batches back to back
    let mut t = 0.0;
    for i in 0..k {
        tl.add(gpu, &format!("fwd{i}"), t, t + fwd);
        tl.add(gpu, &format!("bwd{i}"), t + fwd, t + c);
        t += c;
    }
    let compute_end = t;

    // communication: once per iteration (after accumulation), bucketed.
    let comm_end = if m.topo.world_size() <= 1 {
        compute_end
    } else if m.overlap {
        // Bucket i becomes ready at the point backward of the LAST micro
        // has produced it: ready_i = last_bwd_start + (i+1)/B * bwd.
        let last_bwd_start = compute_end - bwd;
        let nb = m.buckets.max(1);
        let per_bucket = comm_total / nb as f64;
        let mut net_free = 0.0f64;
        let mut end = compute_end;
        for i in 0..nb {
            let ready = last_bwd_start + (i + 1) as f64 / nb as f64 * bwd;
            let start = ready.max(net_free);
            end = start + per_bucket;
            tl.add(net, &format!("allreduce_b{i}"), start, end);
            net_free = end;
        }
        end
    } else {
        tl.add(net, "allreduce", compute_end, compute_end + comm_total);
        compute_end + comm_total
    };

    let iter_end = comm_end.max(compute_end) + update;
    tl.add(gpu, "update", iter_end - update, iter_end);

    let tokens = m.tokens_per_micro * k as f64;
    let compute_busy = k as f64 * c + update;
    IterationResult {
        iteration_s: iter_end,
        compute_utilization: compute_busy / iter_end,
        exposed_comm_s: (iter_end - update - compute_end).max(0.0),
        tokens_per_sec_per_gpu: tokens / iter_end,
        cluster_tokens_per_sec: tokens * m.topo.world_size() as f64
            / iter_end,
        timeline: tl,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(topo: &str, k: usize, overlap: bool) -> IterationModel {
        IterationModel::paper(Topology::parse(topo).unwrap(), k, overlap)
    }

    #[test]
    fn single_gpu_has_no_comm() {
        let r = simulate_iteration(&base("1M1G", 1, true));
        assert_eq!(r.exposed_comm_s, 0.0);
        assert!(r.compute_utilization > 0.99);
        // tokens/s ~= device throughput (minus update overhead)
        let expect = 5429.1;
        assert!((r.tokens_per_sec_per_gpu - expect).abs() / expect < 0.06,
                "{}", r.tokens_per_sec_per_gpu);
    }

    #[test]
    fn figure2_overlap_beats_nonoverlap() {
        let no = simulate_iteration(&base("2M1G", 1, false));
        let yes = simulate_iteration(&base("2M1G", 1, true));
        assert!(yes.iteration_s < no.iteration_s);
        // hidden amount is bounded by the backward window
        let c = base("2M1G", 1, true).micro_compute_s();
        let hidden = no.iteration_s - yes.iteration_s;
        assert!(hidden <= c * 2.0 / 3.0 + 1e-9, "hidden={hidden}");
        assert!(hidden > 0.1 * c, "hidden={hidden}");
    }

    #[test]
    fn figure5_accumulation_raises_utilization() {
        // §4.4: accumulation reduces the comm:compute ratio.
        let u1 = simulate_iteration(&base("32M8G", 1, true))
            .compute_utilization;
        let u4 = simulate_iteration(&base("32M8G", 4, true))
            .compute_utilization;
        let u8 = simulate_iteration(&base("32M8G", 8, true))
            .compute_utilization;
        assert!(u4 > u1 * 1.5, "u1={u1} u4={u4}");
        assert!(u8 > u4, "u4={u4} u8={u8}");
    }

    #[test]
    fn paper_2node_observation_sync_comparable_to_compute() {
        // §4.4: on 2 nodes x 1 GPU, time on synchronization is comparable
        // to fwd+bwd+update combined (even after overlap).
        let r = simulate_iteration(&base("2M1G", 1, true));
        let compute = base("2M1G", 1, true).micro_compute_s();
        assert!(r.exposed_comm_s > 0.5 * compute,
                "exposed={} compute={compute}", r.exposed_comm_s);
        assert!(r.compute_utilization < 0.65, "{}", r.compute_utilization);
    }

    #[test]
    fn timeline_spans_are_consistent() {
        let r = simulate_iteration(&base("4M2G", 2, true));
        assert!(r.timeline.horizon() <= r.iteration_s + 1e-9);
        // one fwd+bwd pair per micro-step
        assert_eq!(r.timeline.busy("gpu", "fwd") > 0.0, true);
        let fwd_total = r.timeline.busy("gpu", "fwd");
        let bwd_total = r.timeline.busy("gpu", "bwd");
        assert!((bwd_total / fwd_total - 2.0).abs() < 1e-6);
    }

    #[test]
    fn more_buckets_do_not_change_total_traffic_much() {
        let few = IterationModel { buckets: 2, ..base("4M1G", 1, true) };
        let many = IterationModel { buckets: 32, ..base("4M1G", 1, true) };
        let t_few = simulate_iteration(&few).iteration_s;
        let t_many = simulate_iteration(&many).iteration_s;
        // finer buckets overlap earlier (start during backward), so many
        // buckets is never slower; total traffic is equal so the gain is
        // bounded by the backward window (<15% here).
        assert!(t_many <= t_few + 1e-9, "few={t_few} many={t_many}");
        assert!((t_few - t_many) / t_few < 0.15,
                "few={t_few} many={t_many}");
    }
}
