//! Iteration timeline model (paper Figures 2 and 5).
//!
//! One data-parallel iteration at a representative GPU:
//!
//! ```text
//! no overlap:   [fwd][bwd]                [====allreduce====][upd]
//! overlap:      [fwd][bwd]                                   [upd]
//!                     └ buckets fire as bwd passes them ┐
//!                      [ar b0][ar b1][ar b2]...─────────┘
//! grad accum:   [fwd][bwd][fwd][bwd][fwd][bwd][fwd][bwd]  (k=4)
//!                                            [====allreduce===][upd]
//! ```
//!
//! Gradients become ready progressively during backward; with overlap
//! the exchange of bucket `i` starts once backward has passed it, so at
//! most the backward window of the LAST micro-batch hides communication
//! (earlier micro-batches only produce partial sums — the exchange must
//! wait for the final accumulation, §4.4).

use crate::collectives::pool::{CommMode, IntraNodeMode,
                               DEFAULT_CHUNK_ELEMS};
use crate::metrics::{add_bucket_exchange_spans, Timeline};
use crate::netsim::{hierarchical_allreduce_phases,
                    hierarchical_pipelined_phases, hierarchical_rs_phases,
                    ring_allreduce_time, Fabric, HierPhases};
use crate::topology::Topology;

/// Inputs of the iteration model.
#[derive(Debug, Clone)]
pub struct IterationModel {
    pub topo: Topology,
    pub fabric: Fabric,
    /// Per-GPU tokens per micro-batch (e.g. 32 sentences x 128 seq).
    pub tokens_per_micro: f64,
    /// Device throughput in tokens/s (from `devices`).
    pub device_tokens_per_sec: f64,
    /// Gradient payload in bytes (f32 model size).
    pub grad_bytes: f64,
    /// Gradient accumulation steps k (>= 1).
    pub accum_steps: usize,
    /// Overlap communication with the last backward (Fig. 2 right).
    pub overlap: bool,
    /// Number of gradient buckets (overlap granularity).
    pub buckets: usize,
    /// Weight-update time as a fraction of one micro-batch compute.
    pub update_frac: f64,
    /// How each bucket travels the cluster, mirroring
    /// `train.comm_mode`: on a hierarchical resolve the bucket is priced
    /// by the executed gather → leader-ring → broadcast schedule and
    /// its timeline span splits into the same per-phase spans the
    /// measured `--trace` exports.  `Flat` keeps the PR-1 world-ring
    /// pricing (the paper-§5.2 calibration anchors).
    pub comm_mode: CommMode,
    /// Intra-node schedule under a hierarchical resolve, mirroring
    /// `train.intra_node`: `Ring` prices the chunked pipelined chain
    /// ([`hierarchical_pipelined_phases`]) and renders per-chunk spans;
    /// `Serial` prices the (g-1) serialized leader transfers;
    /// `ReduceScatter` prices the 2-level shard schedule
    /// ([`hierarchical_rs_phases`] — `O(n/g)` per-link bytes).
    pub intra_node: IntraNodeMode,
    /// Pipeline chunk size in f32 elements (`train.chunk_elems`).
    pub chunk_elems: usize,
    /// Modeled host-side batch build (tokenize+mask+pack) per
    /// micro-batch, seconds; 0 = free input.
    pub batch_build_s: f64,
    /// Whether the input pipeline is prefetched (§4.1 / the
    /// `train.prefetch_depth` producers): a micro stalls only for the
    /// build time not hidden behind the previous micro's compute.
    /// `false` = the build serializes before every micro-batch.
    pub prefetch: bool,
}

impl IterationModel {
    /// The paper's headline configuration on a given topology: T4
    /// fused-FP16 device, BERT-large gradients, phase-1 micro-batch.
    /// Comm mode is `Flat` — the §5.2 weak-scaling anchors are
    /// calibrated against the flat world ring.
    pub fn paper(topo: Topology, accum_steps: usize, overlap: bool) -> Self {
        IterationModel {
            topo,
            fabric: Fabric::paper(),
            tokens_per_micro: 32.0 * 128.0,
            device_tokens_per_sec: super::devices::t4()
                .throughput(super::devices::Variant::Fp16Fused),
            grad_bytes: 336_226_108.0 * 4.0, // BERT-large f32 grads
            accum_steps,
            overlap,
            buckets: 8,
            update_frac: 0.05,
            comm_mode: CommMode::Flat,
            intra_node: IntraNodeMode::Auto,
            chunk_elems: DEFAULT_CHUNK_ELEMS,
            batch_build_s: 0.0,
            prefetch: true,
        }
    }

    /// Compute time of one micro-batch (fwd+bwd) in seconds.
    pub fn micro_compute_s(&self) -> f64 {
        self.tokens_per_micro / self.device_tokens_per_sec
    }

    /// Whether the modeled exchange runs the §4.4 hierarchy on this
    /// topology (the resolved comm mode, as in the real pool).
    pub fn is_hierarchical(&self) -> bool {
        self.comm_mode.resolves_hierarchical(&self.topo)
    }

    /// Whether the modeled hierarchy runs the chunked pipelined
    /// intra-node chain (the resolved intra mode, as in the real pool).
    pub fn is_intra_ring(&self) -> bool {
        self.is_hierarchical() && self.intra_node.resolves_ring(&self.topo)
    }

    /// Whether the modeled exchange runs the 2-level reduce-scatter
    /// schedule (the resolved intra mode, as in the real pool).
    pub fn is_intra_rs(&self) -> bool {
        self.is_hierarchical() && self.intra_node.resolves_rs(&self.topo)
    }

    /// Chunks each modeled bucket splits into (1 unless the pipelined
    /// chain resolves) — drives the per-chunk trace spans.
    pub fn bucket_chunks(&self) -> usize {
        if !self.is_intra_ring() {
            return 1;
        }
        let per_bucket = self.grad_bytes / self.buckets.max(1) as f64;
        hierarchical_pipelined_phases(&self.topo, per_bucket, &self.fabric,
                                      self.chunk_elems as f64 * 4.0)
            .chunks
    }

    /// Per-bucket phase pricing of the modeled exchange.  Flat resolve:
    /// everything is one ring on the topology's bottleneck link, billed
    /// as the "net" phase (PCIe phases zero) — matching how the
    /// measured flat path bills its exchange.  Hierarchical resolve:
    /// the executed serialized gather/leader-ring/broadcast schedule
    /// ([`hierarchical_allreduce_phases`]) — or, when the pipelined
    /// chain resolves, [`hierarchical_pipelined_phases`] folded so that
    /// `net_s` is the NIC busy time and `pcie_s` the exposed remainder
    /// (so `total()` is the pipelined critical path) — or, when the
    /// 2-level reduce-scatter resolves, [`hierarchical_rs_phases`]
    /// (shard-sized transfers on both fabrics).
    pub fn bucket_phases(&self) -> HierPhases {
        let per_bucket = self.grad_bytes / self.buckets.max(1) as f64;
        if self.is_intra_rs() {
            hierarchical_rs_phases(&self.topo, per_bucket, &self.fabric)
        } else if self.is_intra_ring() {
            let p = hierarchical_pipelined_phases(
                &self.topo, per_bucket, &self.fabric,
                self.chunk_elems as f64 * 4.0);
            HierPhases { pcie_s: p.pcie_exposed_s(), net_s: p.net_busy_s }
        } else if self.is_hierarchical() {
            hierarchical_allreduce_phases(&self.topo, per_bucket,
                                          &self.fabric)
        } else {
            let link = self.fabric.ring_bottleneck(&self.topo);
            HierPhases {
                pcie_s: 0.0,
                net_s: ring_allreduce_time(self.topo.world_size(),
                                           per_bucket, link),
            }
        }
    }

    /// Full-gradient allreduce time on this topology (all buckets).
    pub fn allreduce_s(&self) -> f64 {
        if self.topo.world_size() <= 1 {
            return 0.0;
        }
        // per-bucket exchanges: same total bytes, more latency terms
        self.bucket_phases().total() * self.buckets.max(1) as f64
    }

    /// Exposed input stall per micro-batch: the whole build when the
    /// pipeline is synchronous, only the overhang past one micro's
    /// compute when prefetched (the producer builds batch `i + 1` while
    /// the device runs batch `i`).
    pub fn micro_input_stall_s(&self) -> f64 {
        if self.prefetch {
            (self.batch_build_s - self.micro_compute_s()).max(0.0)
        } else {
            self.batch_build_s.max(0.0)
        }
    }
}

/// Output of the iteration simulation.
#[derive(Debug, Clone)]
pub struct IterationResult {
    /// Wall-clock seconds for one optimizer iteration.
    pub iteration_s: f64,
    /// Fraction of the iteration the GPU compute stream is busy.
    pub compute_utilization: f64,
    /// Seconds of communication NOT hidden by compute.
    pub exposed_comm_s: f64,
    /// Seconds the compute stream sat waiting on input batches (the
    /// modeled data-stall lane; 0 when the prefetch producers keep up).
    pub input_stall_s: f64,
    /// Tokens processed per second per GPU.
    pub tokens_per_sec_per_gpu: f64,
    /// Cluster-wide tokens/s.
    pub cluster_tokens_per_sec: f64,
    /// The span timeline (Figure 2/5 artifact).
    pub timeline: Timeline,
}

/// Emit one bucket's exchange on the timeline, mirroring the span
/// naming of the MEASURED trace through the shared
/// [`add_bucket_exchange_spans`] renderer: a hierarchical bucket
/// splits into `bucket{i}.pcie.gather` → `bucket{i}.net` →
/// `bucket{i}.pcie.bcast` (per-chunk `.c{k}` variants on a pipelined
/// resolve), a flat bucket is one `bucket{i}.net` span.
fn add_bucket_spans(tl: &mut Timeline, i: usize, start: f64,
                    phases: &HierPhases, chunks: usize) {
    if phases.pcie_s > 0.0 && phases.net_s > 0.0 {
        add_bucket_exchange_spans(tl, i, start, phases.pcie_s,
                                  phases.net_s, chunks);
    } else {
        tl.add("net", &format!("bucket{i}.net"), start,
               start + phases.total());
    }
}

/// Simulate one iteration (Figures 2 and 5).
pub fn simulate_iteration(m: &IterationModel) -> IterationResult {
    let c = m.micro_compute_s();
    let fwd = c / 3.0;
    let bwd = c - fwd;
    let k = m.accum_steps.max(1);
    let update = m.update_frac * c;
    let stall = m.micro_input_stall_s();

    let mut tl = Timeline::default();
    let gpu = "gpu";

    // compute spans: k micro-batches back to back, each preceded by its
    // exposed input stall (the data lane; empty when prefetch hides the
    // batch build behind the previous micro's compute).
    let mut t = 0.0;
    let mut input_stall_s = 0.0;
    for i in 0..k {
        if stall > 0.0 {
            tl.add("data", &format!("micro{i}.input_stall"), t, t + stall);
            input_stall_s += stall;
            t += stall;
        }
        tl.add(gpu, &format!("fwd{i}"), t, t + fwd);
        tl.add(gpu, &format!("bwd{i}"), t + fwd, t + c);
        t += c;
    }
    let compute_end = t;

    // communication: once per iteration (after accumulation), bucketed;
    // each bucket priced and rendered per phase (gather/ring/broadcast
    // on a hierarchical resolve, one network span on a flat one).
    let nb = m.buckets.max(1);
    let phases = m.bucket_phases();
    let chunks = m.bucket_chunks();
    let per_bucket = phases.total();
    let comm_end = if m.topo.world_size() <= 1 {
        compute_end
    } else if m.overlap {
        // Bucket i becomes ready at the point backward of the LAST micro
        // has produced it: ready_i = last_bwd_start + (i+1)/B * bwd.
        let last_bwd_start = compute_end - bwd;
        let mut net_free = 0.0f64;
        let mut end = compute_end;
        for i in 0..nb {
            let ready = last_bwd_start + (i + 1) as f64 / nb as f64 * bwd;
            let start = ready.max(net_free);
            end = start + per_bucket;
            add_bucket_spans(&mut tl, i, start, &phases, chunks);
            net_free = end;
        }
        end
    } else {
        let mut tcur = compute_end;
        for i in 0..nb {
            add_bucket_spans(&mut tl, i, tcur, &phases, chunks);
            tcur += per_bucket;
        }
        tcur
    };

    let iter_end = comm_end.max(compute_end) + update;
    tl.add(gpu, "update", iter_end - update, iter_end);

    let tokens = m.tokens_per_micro * k as f64;
    let compute_busy = k as f64 * c + update;
    IterationResult {
        iteration_s: iter_end,
        compute_utilization: compute_busy / iter_end,
        exposed_comm_s: (iter_end - update - compute_end).max(0.0),
        input_stall_s,
        tokens_per_sec_per_gpu: tokens / iter_end,
        cluster_tokens_per_sec: tokens * m.topo.world_size() as f64
            / iter_end,
        timeline: tl,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(topo: &str, k: usize, overlap: bool) -> IterationModel {
        IterationModel::paper(Topology::parse(topo).unwrap(), k, overlap)
    }

    #[test]
    fn single_gpu_has_no_comm() {
        let r = simulate_iteration(&base("1M1G", 1, true));
        assert_eq!(r.exposed_comm_s, 0.0);
        assert!(r.compute_utilization > 0.99);
        // tokens/s ~= device throughput (minus update overhead)
        let expect = 5429.1;
        assert!((r.tokens_per_sec_per_gpu - expect).abs() / expect < 0.06,
                "{}", r.tokens_per_sec_per_gpu);
    }

    #[test]
    fn figure2_overlap_beats_nonoverlap() {
        let no = simulate_iteration(&base("2M1G", 1, false));
        let yes = simulate_iteration(&base("2M1G", 1, true));
        assert!(yes.iteration_s < no.iteration_s);
        // hidden amount is bounded by the backward window
        let c = base("2M1G", 1, true).micro_compute_s();
        let hidden = no.iteration_s - yes.iteration_s;
        assert!(hidden <= c * 2.0 / 3.0 + 1e-9, "hidden={hidden}");
        assert!(hidden > 0.1 * c, "hidden={hidden}");
    }

    #[test]
    fn figure5_accumulation_raises_utilization() {
        // §4.4: accumulation reduces the comm:compute ratio.
        let u1 = simulate_iteration(&base("32M8G", 1, true))
            .compute_utilization;
        let u4 = simulate_iteration(&base("32M8G", 4, true))
            .compute_utilization;
        let u8 = simulate_iteration(&base("32M8G", 8, true))
            .compute_utilization;
        assert!(u4 > u1 * 1.5, "u1={u1} u4={u4}");
        assert!(u8 > u4, "u4={u4} u8={u8}");
    }

    #[test]
    fn paper_2node_observation_sync_comparable_to_compute() {
        // §4.4: on 2 nodes x 1 GPU, time on synchronization is comparable
        // to fwd+bwd+update combined (even after overlap).
        let r = simulate_iteration(&base("2M1G", 1, true));
        let compute = base("2M1G", 1, true).micro_compute_s();
        assert!(r.exposed_comm_s > 0.5 * compute,
                "exposed={} compute={compute}", r.exposed_comm_s);
        assert!(r.compute_utilization < 0.65, "{}", r.compute_utilization);
    }

    #[test]
    fn timeline_spans_are_consistent() {
        let r = simulate_iteration(&base("4M2G", 2, true));
        assert!(r.timeline.horizon() <= r.iteration_s + 1e-9);
        // one fwd+bwd pair per micro-step
        assert_eq!(r.timeline.busy("gpu", "fwd") > 0.0, true);
        let fwd_total = r.timeline.busy("gpu", "fwd");
        let bwd_total = r.timeline.busy("gpu", "bwd");
        assert!((bwd_total / fwd_total - 2.0).abs() < 1e-6);
    }

    #[test]
    fn hierarchical_spans_mirror_measured_trace_naming() {
        // A hierarchical SERIAL resolve must render every bucket as the
        // executed gather -> leader ring -> broadcast, with the same
        // span names `ExchangeTimings::to_timeline` exports, so the
        // modeled and measured chrome traces line up in perfetto.
        let m = IterationModel {
            comm_mode: CommMode::Auto,
            intra_node: IntraNodeMode::Serial,
            ..base("2M4G", 1, true)
        };
        assert!(m.is_hierarchical());
        assert!(!m.is_intra_ring());
        assert_eq!(m.bucket_chunks(), 1);
        let r = simulate_iteration(&m);
        let find = |name: &str| {
            r.timeline.spans.iter().find(|s| s.name == name)
                .unwrap_or_else(|| panic!("missing span {name}"))
        };
        let g = find("bucket0.pcie.gather");
        let n = find("bucket0.net");
        let bc = find("bucket0.pcie.bcast");
        assert_eq!(g.track, "pcie");
        assert_eq!(n.track, "net");
        assert!(g.end <= n.start + 1e-12 && n.end <= bc.start + 1e-12,
                "phase order wrong: {g:?} {n:?} {bc:?}");
        // phase durations match the analytic pricing
        let phases = m.bucket_phases();
        assert!((r.timeline.busy("net", "bucket0")
                 - phases.net_s).abs() < 1e-12);
        assert!((r.timeline.busy("pcie", "bucket0")
                 - phases.pcie_s).abs() < 1e-12);
        // flat resolve on the same topology: single net span per bucket
        let flat = simulate_iteration(&base("2M4G", 1, true));
        assert!(flat.timeline.busy("pcie", "") == 0.0);
        assert!(flat.timeline.busy("net", "bucket0") > 0.0);
    }

    #[test]
    fn pipelined_resolve_renders_per_chunk_spans_and_shrinks_comm() {
        // The default intra mode on a multi-GPU-node hierarchy is the
        // chunked pipelined chain: buckets render as per-chunk spans
        // (the measured-trace naming) and the priced exchange beats the
        // serialized leader schedule.
        let chunked = IterationModel {
            comm_mode: CommMode::Auto,
            chunk_elems: 4 << 20, // keep the span count reviewable
            ..base("2M8G", 1, true)
        };
        assert!(chunked.is_intra_ring());
        let chunks = chunked.bucket_chunks();
        assert!(chunks > 1, "{chunks}");
        let serial = IterationModel {
            intra_node: IntraNodeMode::Serial,
            ..chunked.clone()
        };
        assert!(chunked.bucket_phases().total()
                    < serial.bucket_phases().total(),
                "pipelined pricing must beat serialized at g=8");
        let r = simulate_iteration(&chunked);
        assert!(r.iteration_s < simulate_iteration(&serial).iteration_s);
        // per-chunk naming, first and last chunk present
        let has = |name: &str| r.timeline.spans.iter()
            .any(|s| s.name == name);
        assert!(has("bucket0.pcie.gather.c0"));
        assert!(has("bucket0.net.c0"));
        assert!(has(&format!("bucket0.pcie.bcast.c{}", chunks - 1)));
        // chunk spans still sum to the bucket's phase totals
        let phases = chunked.bucket_phases();
        assert!((r.timeline.busy("net", "bucket0.net")
                 - phases.net_s).abs() < 1e-9);
        assert!((r.timeline.busy("pcie", "bucket0.pcie")
                 - phases.pcie_s).abs() < 1e-9);
        assert!(r.timeline.horizon() <= r.iteration_s + 1e-9);
    }

    #[test]
    fn rs_resolve_prices_shard_schedule_and_beats_serial() {
        // `--intra-node rs` on a multi-GPU hierarchy: bucket phases come
        // from the 2-level shard pricing (O(n/g) per link), buckets stay
        // single-span (no per-chunk naming — that's the chain's), and
        // the iteration beats the serialized-leader resolve.
        let rs = IterationModel {
            comm_mode: CommMode::Auto,
            intra_node: IntraNodeMode::ReduceScatter,
            ..base("2M4G", 1, true)
        };
        assert!(rs.is_hierarchical());
        assert!(rs.is_intra_rs());
        assert!(!rs.is_intra_ring());
        assert_eq!(rs.bucket_chunks(), 1);
        let phases = rs.bucket_phases();
        let want = crate::netsim::hierarchical_rs_phases(
            &rs.topo, rs.grad_bytes / rs.buckets as f64, &rs.fabric);
        assert!((phases.pcie_s - want.pcie_s).abs() < 1e-12);
        assert!((phases.net_s - want.net_s).abs() < 1e-12);
        let serial = IterationModel {
            intra_node: IntraNodeMode::Serial,
            ..rs.clone()
        };
        assert!(phases.total() < serial.bucket_phases().total(),
                "rs pricing must beat serialized leader at 2M4G");
        let r = simulate_iteration(&rs);
        assert!(r.iteration_s < simulate_iteration(&serial).iteration_s);
        // same gather/net/bcast span naming as the measured trace
        let has = |name: &str| r.timeline.spans.iter()
            .any(|s| s.name == name);
        assert!(has("bucket0.pcie.gather"));
        assert!(has("bucket0.net"));
        assert!(has("bucket0.pcie.bcast"));
        assert!((r.timeline.busy("net", "bucket0")
                 - phases.net_s).abs() < 1e-9);
        // degenerate g=1: rs falls back to the flat-equivalent leader
        // ring, not an intra schedule
        let g1 = IterationModel {
            comm_mode: CommMode::Auto,
            intra_node: IntraNodeMode::ReduceScatter,
            ..base("4M1G", 1, true)
        };
        assert!(!g1.is_intra_rs());
    }

    #[test]
    fn data_stall_lane_models_sync_vs_prefetched_input() {
        let c = base("1M1G", 2, true).micro_compute_s();
        // synchronous input: every micro pays the full build up front
        let sync = IterationModel {
            batch_build_s: 0.3 * c,
            prefetch: false,
            ..base("1M1G", 2, true)
        };
        let rs = simulate_iteration(&sync);
        assert!((rs.input_stall_s - 0.6 * c).abs() < 1e-9);
        assert!((rs.timeline.busy("data", "") - 0.6 * c).abs() < 1e-9);
        // prefetched and build < compute: fully hidden, no data lane
        let pf = IterationModel { prefetch: true, ..sync.clone() };
        let rp = simulate_iteration(&pf);
        assert_eq!(rp.input_stall_s, 0.0);
        assert_eq!(rp.timeline.busy("data", ""), 0.0);
        assert!(rp.iteration_s < rs.iteration_s);
        // prefetched but data-bound (build > compute): only the
        // overhang is exposed
        let bound = IterationModel {
            batch_build_s: 1.5 * c,
            ..pf.clone()
        };
        let rb = simulate_iteration(&bound);
        assert!((rb.input_stall_s - 2.0 * 0.5 * c).abs() < 1e-9);
        // no modeled build (the default) leaves the iteration untouched
        let r0 = simulate_iteration(&base("1M1G", 2, true));
        assert_eq!(r0.input_stall_s, 0.0);
        assert!((rp.iteration_s - r0.iteration_s).abs() < 1e-12);
    }

    #[test]
    fn more_buckets_do_not_change_total_traffic_much() {
        let few = IterationModel { buckets: 2, ..base("4M1G", 1, true) };
        let many = IterationModel { buckets: 32, ..base("4M1G", 1, true) };
        let t_few = simulate_iteration(&few).iteration_s;
        let t_many = simulate_iteration(&many).iteration_s;
        // finer buckets overlap earlier (start during backward), so many
        // buckets is never slower; total traffic is equal so the gain is
        // bounded by the backward window (<15% here).
        assert!(t_many <= t_few + 1e-9, "few={t_few} many={t_many}");
        assert!((t_few - t_many) / t_few < 0.15,
                "few={t_few} many={t_many}");
    }
}
