//! Weak-scaling sweeps (paper Figures 3 and 6, §5.2).
//!
//! Weak scaling: per-GPU batch fixed, GPUs added; the scaling factor is
//! cluster throughput over single-GPU throughput.  The paper's headline:
//! 165× at 256 GPUs (32M8G, k=4, overlap, 10 Gb/s) ≈ 64.5% efficiency.
//! Calibration tests below pin the model to that anchor and to the
//! Figure-3 observations (inter-node ≈ 38% cap without accumulation;
//! near-zero gain 1M1G → 2M1G).

use super::timeline::{simulate_iteration, IterationModel};
use crate::topology::Topology;

/// One point of a scaling curve.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub topo: Topology,
    pub gpus: usize,
    pub cluster_tokens_per_sec: f64,
    /// Throughput over the single-GPU baseline.
    pub scaling_factor: f64,
    /// scaling_factor / gpus.
    pub efficiency: f64,
    pub compute_utilization: f64,
}

/// Sweep a list of topologies with a model template; the template's
/// `topo` field is replaced per point.  Baseline = same model on 1M1G.
pub fn weak_scaling(template: &IterationModel, topos: &[Topology])
    -> Vec<ScalingPoint> {
    let base_model = IterationModel {
        topo: Topology::new(1, 1),
        ..template.clone()
    };
    let base = simulate_iteration(&base_model).cluster_tokens_per_sec;
    topos
        .iter()
        .map(|&topo| {
            let m = IterationModel { topo, ..template.clone() };
            let r = simulate_iteration(&m);
            let factor = r.cluster_tokens_per_sec / base;
            ScalingPoint {
                topo,
                gpus: topo.world_size(),
                cluster_tokens_per_sec: r.cluster_tokens_per_sec,
                scaling_factor: factor,
                efficiency: factor / topo.world_size() as f64,
                compute_utilization: r.compute_utilization,
            }
        })
        .collect()
}

/// Figure 3's two curves: intra-node (1M{1,2,4,8}G) vs inter-node
/// ({1,2,4,8}M1G), no gradient accumulation, overlap on.
pub fn sweep_intra_vs_inter(template: &IterationModel)
    -> (Vec<ScalingPoint>, Vec<ScalingPoint>) {
    let intra: Vec<Topology> =
        [1, 2, 4, 8].iter().map(|&g| Topology::new(1, g)).collect();
    let inter: Vec<Topology> =
        [1, 2, 4, 8].iter().map(|&m| Topology::new(m, 1)).collect();
    (weak_scaling(template, &intra), weak_scaling(template, &inter))
}

/// Figure 6's sweep: {1,2,4,8,16,32}M8G with the paper's k=4.
pub fn figure6_topologies() -> Vec<Topology> {
    [1usize, 2, 4, 8, 16, 32]
        .iter()
        .map(|&m| Topology::new(m, 8))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_template(k: usize) -> IterationModel {
        IterationModel::paper(Topology::new(1, 1), k, true)
    }

    #[test]
    fn anchor_165x_at_256_gpus_with_k4() {
        // The paper's headline (§5.2): weak scaling factor ~165 on 32M8G
        // with 4-step gradient accumulation and 10 Gb/s network.
        let pts = weak_scaling(&paper_template(4),
                               &[Topology::new(32, 8)]);
        let f = pts[0].scaling_factor;
        assert!((f - 165.0).abs() < 20.0, "scaling factor {f}");
        // efficiency ~64% (the abstract's "70%" rounds this up)
        assert!((pts[0].efficiency - 0.645).abs() < 0.08,
                "eff {}", pts[0].efficiency);
    }

    #[test]
    fn figure3_inter_node_caps_near_38_percent() {
        let (_intra, inter) = sweep_intra_vs_inter(&paper_template(1));
        // 8M1G without accumulation: ~35-38% efficiency
        let p8 = &inter[3];
        assert_eq!(p8.gpus, 8);
        assert!((0.30..0.45).contains(&p8.efficiency),
                "inter 8M1G eff {}", p8.efficiency);
        // 2M1G: "nearly zero throughput gain" => factor well under 1.5
        let p2 = &inter[1];
        assert!(p2.scaling_factor < 1.5, "{}", p2.scaling_factor);
    }

    #[test]
    fn figure3_intra_beats_inter() {
        let (intra, inter) = sweep_intra_vs_inter(&paper_template(1));
        for (a, b) in intra.iter().zip(&inter).skip(1) {
            assert!(a.scaling_factor > b.scaling_factor,
                    "{}G intra {} <= inter {}", a.gpus, a.scaling_factor,
                    b.scaling_factor);
        }
        // intra-node 8 GPUs over 64 Gb/s PCIe scales well
        assert!(intra[3].efficiency > 0.8, "{}", intra[3].efficiency);
    }

    #[test]
    fn figure6_efficiency_decreases_with_machines() {
        // §5.2: "scaling efficiency decreases as we continue to increase
        // the number of machines".
        let pts = weak_scaling(&paper_template(4), &figure6_topologies());
        for w in pts.windows(2) {
            assert!(w[1].efficiency <= w[0].efficiency + 1e-9,
                    "{} -> {}", w[0].efficiency, w[1].efficiency);
            assert!(w[1].scaling_factor > w[0].scaling_factor,
                    "throughput must still grow");
        }
    }

    #[test]
    fn accumulation_improves_scaling_factor() {
        let t32 = Topology::new(32, 8);
        let k1 = weak_scaling(&paper_template(1), &[t32])[0].scaling_factor;
        let k4 = weak_scaling(&paper_template(4), &[t32])[0].scaling_factor;
        let k8 = weak_scaling(&paper_template(8), &[t32])[0].scaling_factor;
        assert!(k4 > 1.8 * k1, "k1={k1} k4={k4}");
        assert!(k8 > k4, "k4={k4} k8={k8}");
    }

    #[test]
    fn single_gpu_point_is_identity() {
        let pts = weak_scaling(&paper_template(1), &[Topology::new(1, 1)]);
        assert!((pts[0].scaling_factor - 1.0).abs() < 1e-9);
        assert!((pts[0].efficiency - 1.0).abs() < 1e-9);
    }
}
