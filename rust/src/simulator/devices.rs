//! Device compute models, calibrated from the paper's Table 4 (measured
//! BERT-large seq-128 pretraining throughput in tokens/s).
//!
//! These are MEASURED anchor points from the paper, not our invention —
//! the simulator interpolates everything else from them, so Table 3/4/5
//! regenerate exactly and Figures 3/6 inherit the right absolute scale.

/// Single-GPU optimization variant (the Table 4/5 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// FP32, unfused kernels ("Non-Optimized").
    NonOptimized,
    /// Mixed precision only ("FP16").
    Fp16,
    /// Mixed precision + fused kernels ("FP16 & Fused Kernel").
    Fp16Fused,
}

impl Variant {
    pub const ALL: [Variant; 3] =
        [Variant::NonOptimized, Variant::Fp16, Variant::Fp16Fused];

    pub fn name(self) -> &'static str {
        match self {
            Variant::NonOptimized => "Non-Optimized",
            Variant::Fp16 => "FP16",
            Variant::Fp16Fused => "FP16 & Fused Kernel",
        }
    }
}

/// A GPU model with its measured seq-128 BERT-large throughputs.
#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    pub name: &'static str,
    /// tokens/s per Table 4 column.
    pub non_optimized: f64,
    pub fp16: f64,
    pub fp16_fused: f64,
    /// Whether the GPU has TensorCores (affects the FP16 multiplier).
    pub tensor_cores: bool,
}

impl DeviceModel {
    pub fn throughput(&self, v: Variant) -> f64 {
        match v {
            Variant::NonOptimized => self.non_optimized,
            Variant::Fp16 => self.fp16,
            Variant::Fp16Fused => self.fp16_fused,
        }
    }

    /// Speedup over the non-optimized baseline (Table 5).
    pub fn speedup(&self, v: Variant) -> f64 {
        self.throughput(v) / self.non_optimized
    }

    /// Hours per epoch at `tokens_per_epoch` (Table 3).
    pub fn epoch_hours(&self, v: Variant, tokens_per_epoch: f64) -> f64 {
        tokens_per_epoch / self.throughput(v) / 3600.0
    }

    /// Days for the full 40-epoch pretraining on ONE GPU (Table 3).
    pub fn forty_epoch_days(&self, v: Variant, tokens_per_epoch: f64) -> f64 {
        40.0 * self.epoch_hours(v, tokens_per_epoch) / 24.0
    }
}

/// Paper Table 4 rows (tokens/s, seq length 128).
pub const DEVICES: [DeviceModel; 3] = [
    DeviceModel {
        name: "P100",
        non_optimized: 1576.3,
        fp16: 2680.7,
        fp16_fused: 3228.8,
        tensor_cores: false,
    },
    DeviceModel {
        name: "T4 (TensorCore)",
        non_optimized: 1953.5,
        fp16: 4430.9,
        fp16_fused: 5429.1,
        tensor_cores: true,
    },
    DeviceModel {
        name: "2080Ti (TensorCore)",
        non_optimized: 3527.2,
        fp16: 8823.8,
        fp16_fused: 10765.8,
        tensor_cores: true,
    },
];

/// Paper Table 3: 16752.7 Million tokens per epoch (Wikipedia+Books).
pub const PAPER_TOKENS_PER_EPOCH: f64 = 16_752.7e6;

/// The T4 — the paper's cluster GPU (Table 1).
pub fn t4() -> DeviceModel {
    DEVICES[1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_speedups_match_paper() {
        // Table 5: P100 2.05x, T4 2.78x, 2080Ti 3.05x for FP16+fused.
        let wants = [(0, 1.70, 2.05), (1, 2.27, 2.78), (2, 2.50, 3.05)];
        for (i, fp16, fused) in wants {
            let d = DEVICES[i];
            assert!((d.speedup(Variant::Fp16) - fp16).abs() < 0.01,
                    "{}: {}", d.name, d.speedup(Variant::Fp16));
            assert!((d.speedup(Variant::Fp16Fused) - fused).abs() < 0.01,
                    "{}: {}", d.name, d.speedup(Variant::Fp16Fused));
        }
    }

    #[test]
    fn table3_epoch_times_match_paper() {
        // Table 3: P100 1441.6h, T4 857.1h, 2080Ti 432.3h per epoch.
        let wants = [(0, 1441.6, 2400.0), (1, 857.1, 1440.0),
                     (2, 432.3, 720.0)];
        for (i, hours, days40) in wants {
            let d = DEVICES[i];
            let h = d.epoch_hours(Variant::Fp16Fused, PAPER_TOKENS_PER_EPOCH);
            assert!((h - hours).abs() / hours < 0.01,
                    "{}: {h} vs {hours}", d.name);
            let dd = d.forty_epoch_days(Variant::Fp16Fused,
                                        PAPER_TOKENS_PER_EPOCH);
            assert!((dd - days40).abs() / days40 < 0.01,
                    "{}: {dd} vs {days40}", d.name);
        }
    }

    #[test]
    fn tensorcore_gpus_gain_more_from_fp16() {
        // §5.1: "FP16 is more effective on GPUs equipped with TensorCores".
        let p100 = DEVICES[0].speedup(Variant::Fp16);
        for d in &DEVICES[1..] {
            assert!(d.tensor_cores);
            assert!(d.speedup(Variant::Fp16) > p100);
        }
    }

    #[test]
    fn fusion_adds_roughly_20_percent() {
        // §5.1: kernel fusion gives ~1.2x on top of FP16 for all devices.
        for d in &DEVICES {
            let f = d.fp16_fused / d.fp16;
            assert!((1.15..1.30).contains(&f), "{}: {f}", d.name);
        }
    }
}
