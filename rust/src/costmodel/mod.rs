//! Cost model (paper §1, §6, Tables 7–8): acquisition cost of the
//! commodity cluster vs DGX clusters vs cloud rental.

/// Paper Table 1: per-node and total acquisition costs.
pub const NODE_COST_USD: f64 = 19_500.0;
pub const NODES: usize = 32;
pub const GPUS_PER_NODE: usize = 8;

/// Paper Table 8: DGX unit prices.
pub const DGX1_COST_USD: f64 = 149_000.0;
pub const DGX2_COST_USD: f64 = 399_000.0;

/// Paper Table 7: T4 cloud price per GPU-hour.
pub const CLOUD_T4_PER_HOUR_USD: f64 = 0.35;

/// Hardware replacement cycle the paper assumes (§6): 3 years.
pub const REPLACEMENT_YEARS: f64 = 3.0;

/// An acquisition option.
#[derive(Debug, Clone)]
pub struct ClusterCost {
    pub name: String,
    pub units: usize,
    pub unit_cost_usd: f64,
}

impl ClusterCost {
    pub fn total(&self) -> f64 {
        self.units as f64 * self.unit_cost_usd
    }
}

/// The paper's own cluster (Table 1): 32 nodes x $19.5K = $624K.
pub fn paper_cluster() -> ClusterCost {
    ClusterCost {
        name: "32-node T4 cluster (this paper)".into(),
        units: NODES,
        unit_cost_usd: NODE_COST_USD,
    }
}

/// Table 8 rows.
pub fn dgx_clusters() -> Vec<ClusterCost> {
    vec![
        ClusterCost { name: "NVIDIA DGX1 x32".into(), units: 32,
                      unit_cost_usd: DGX1_COST_USD },
        ClusterCost { name: "NVIDIA DGX2 x32".into(), units: 32,
                      unit_cost_usd: DGX2_COST_USD },
    ]
}

/// Table 7: cloud rental cost for `gpus` T4s over `days`.
pub fn cloud_cost(gpus: usize, days: f64) -> f64 {
    gpus as f64 * days * 24.0 * CLOUD_T4_PER_HOUR_USD
}

/// §6 break-even analysis: how many `days`-long experiments fit in the
/// replacement cycle, and the rent-vs-own multiple.
#[derive(Debug, Clone)]
pub struct BreakEven {
    pub experiments_per_cycle: f64,
    pub own_cost_per_experiment: f64,
    pub cloud_cost_per_experiment: f64,
    /// own / cloud per-experiment price ratio (>1 means renting one
    /// experiment is cheaper than the amortized ownership).
    pub own_over_cloud: f64,
}

pub fn break_even(days_per_experiment: f64) -> BreakEven {
    let cluster = paper_cluster();
    let experiments =
        REPLACEMENT_YEARS * 365.0 / days_per_experiment;
    let own_per = cluster.total() / experiments;
    let cloud_per = cloud_cost(NODES * GPUS_PER_NODE, days_per_experiment);
    BreakEven {
        experiments_per_cycle: experiments,
        own_cost_per_experiment: own_per,
        cloud_cost_per_experiment: cloud_per,
        own_over_cloud: own_per / cloud_per,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_total_624k() {
        assert_eq!(paper_cluster().total(), 624_000.0);
    }

    #[test]
    fn table8_dgx_totals() {
        let d = dgx_clusters();
        assert_eq!(d[0].total(), 4_768_000.0); // paper: $4.768M
        assert_eq!(d[1].total(), 12_768_000.0); // paper: $12.768M
    }

    #[test]
    fn table7_cloud_estimate() {
        // paper: 256 T4 x 12 days x $0.35/h = $25,804.80
        let c = cloud_cost(256, 12.0);
        assert!((c - 25_804.8).abs() < 0.01, "{c}");
    }

    #[test]
    fn paper_cost_ratios() {
        // §1/§6: DGX setup costs ~7.6-20x the commodity cluster.
        let own = paper_cluster().total();
        let d = dgx_clusters();
        assert!(d[0].total() / own > 7.0);
        assert!(d[1].total() / own > 20.0);
        // §6: cloud for one 12-day run is ~24x cheaper than buying
        let ratio = own / cloud_cost(256, 12.0);
        assert!((ratio - 24.0).abs() < 1.0, "{ratio}");
    }

    #[test]
    fn break_even_matches_section6() {
        // §6: 3-year cycle fits ~90 twelve-day experiments.
        let b = break_even(12.0);
        assert!((b.experiments_per_cycle - 91.25).abs() < 1.0);
        // amortized ownership beats cloud well before the cycle ends
        assert!(b.own_cost_per_experiment < b.cloud_cost_per_experiment,
                "own {} cloud {}", b.own_cost_per_experiment,
                b.cloud_cost_per_experiment);
    }
}
