//! `bertdist` CLI — the leader entrypoint.
//!
//! Subcommands (wired in [`bertdist::coordinator`]):
//!   train           data-parallel pretraining on the PJRT-CPU substrate
//!   shard-data      build `bshard` files from a corpus (paper §4.1)
//!   simulate        discrete-event cluster simulation (figs. 2/3/5/6)
//!   scaling         weak-scaling sweeps (figs. 3 and 6)
//!   profile-grads   gradient memory profile (fig. 4)
//!   cost            acquisition / cloud cost tables (tables 7–8)
//!   amp-demo        AMP loss-scaling walkthrough (§4.2)
//!   info            artifact + manifest inspection

fn main() {
    let code = bertdist::coordinator::cli_main();
    std::process::exit(code);
}
