//! Gradient plumbing (paper §4.4, Figures 2 & 5): flat buffers,
//! size-threshold bucketing for communication/computation overlap, and
//! local accumulation across micro-steps.
//!
//! * [`Bucket`]s partition the flat gradient vector into contiguous
//!   ranges of ~`threshold` elements, *in reverse layout order* — the
//!   order gradients become ready during backward (output layers first),
//!   so each bucket's allreduce can launch as soon as backprop passes it
//!   ("The gradients are exchanged as soon as they become available
//!   after passing some certain size threshold", §4.4).
//! * [`GradAccumulator`] sums micro-step gradients locally and tracks
//!   the normalization factor (§4.4 gradient accumulation).

pub mod sparsify;

use crate::model::layout::ParamLayout;

/// A contiguous flat-vector range exchanged as one allreduce message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bucket {
    /// Flat element range [start, end).
    pub start: usize,
    pub end: usize,
    /// Names of the parameter tensors whose gradients live here.
    pub tensors: Vec<String>,
    /// Backward readiness order: bucket 0 is ready FIRST (covers the
    /// layout tail = output-side layers).
    pub order: usize,
}

impl Bucket {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    pub fn bytes(&self) -> usize {
        self.len() * 4
    }
}

/// A bucket's flat range alone — the copy-free descriptor shared with
/// the persistent collective workers.  [`Bucket`] drags its tensor-name
/// `Vec<String>`s along; the hot path only ever needs `(start, end)`, so
/// the trainer builds this table ONCE (as an `Arc` slice) instead of
/// cloning per worker per step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketRange {
    pub start: usize,
    pub end: usize,
}

impl BucketRange {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    /// Split `[0, n)` into `pieces` contiguous ranges (the last one
    /// absorbs the remainder) — the synthetic bucket table used by
    /// benches, examples, and pool tests.
    pub fn even_split(n: usize, pieces: usize)
        -> std::sync::Arc<[BucketRange]> {
        assert!(pieces >= 1, "pieces must be >= 1");
        let base = n / pieces;
        let mut out = Vec::with_capacity(pieces);
        let mut start = 0;
        for p in 0..pieces {
            let end = if p + 1 == pieces { n } else { start + base };
            out.push(BucketRange { start, end });
            start = end;
        }
        out.into_iter().collect()
    }
}

/// Build the shared range table from a bucket plan (one allocation for
/// the lifetime of the trainer).
pub fn bucket_ranges(buckets: &[Bucket]) -> std::sync::Arc<[BucketRange]> {
    buckets
        .iter()
        .map(|b| BucketRange { start: b.start, end: b.end })
        .collect()
}

/// Partition a parameter layout into buckets of >= `threshold_elems`,
/// walking tensors from the END of the layout (backward order).  Tensor
/// boundaries are respected: a tensor is never split across buckets
/// (DDP semantics).
pub fn build_buckets(layout: &ParamLayout, threshold_elems: usize)
    -> Vec<Bucket> {
    let mut buckets = Vec::new();
    let mut cur_tensors: Vec<String> = Vec::new();
    let mut cur_end: Option<usize> = None;
    let mut cur_start = 0usize;
    for entry in layout.entries().iter().rev() {
        if cur_end.is_none() {
            cur_end = Some(entry.offset + entry.len());
        }
        cur_start = entry.offset;
        cur_tensors.push(entry.name.clone());
        if cur_end.unwrap() - cur_start >= threshold_elems {
            buckets.push(Bucket {
                start: cur_start,
                end: cur_end.unwrap(),
                tensors: std::mem::take(&mut cur_tensors),
                order: buckets.len(),
            });
            cur_end = None;
        }
    }
    if let Some(end) = cur_end {
        buckets.push(Bucket {
            start: cur_start,
            end,
            tensors: cur_tensors,
            order: buckets.len(),
        });
    }
    buckets
}

/// Local gradient accumulator (paper §4.4): sums `k` micro-batch
/// gradients before one global exchange; the trainer divides by the
/// TOTAL sample count (k * world) via `mean_factor`.
#[derive(Debug)]
pub struct GradAccumulator {
    sum: Vec<f32>,
    micro_steps: usize,
}

impl GradAccumulator {
    pub fn new(n: usize) -> Self {
        Self { sum: vec![0.0; n], micro_steps: 0 }
    }

    /// Add one micro-step's gradients.
    pub fn add(&mut self, grads: &[f32]) {
        assert_eq!(grads.len(), self.sum.len());
        for (s, g) in self.sum.iter_mut().zip(grads) {
            *s += g;
        }
        self.micro_steps += 1;
    }

    /// Micro-steps accumulated since the last drain.
    pub fn micro_steps(&self) -> usize {
        self.micro_steps
    }

    /// Factor that turns the (already allreduce-SUMMED) buffer into a
    /// mean over all contributing micro-batches.
    pub fn mean_factor(&self, world: usize) -> f32 {
        1.0 / (self.micro_steps.max(1) * world.max(1)) as f32
    }

    /// Mutable view for in-place allreduce.
    pub fn buffer_mut(&mut self) -> &mut [f32] {
        &mut self.sum
    }

    /// Owned-vector access (the trainer moves buffers into allreduce
    /// worker threads and back without copying).
    pub fn buffer_mut_vec(&mut self) -> &mut Vec<f32> {
        &mut self.sum
    }

    pub fn buffer(&self) -> &[f32] {
        &self.sum
    }

    /// Scale the buffer in place (applying `mean_factor`).
    pub fn scale(&mut self, factor: f32) {
        for v in self.sum.iter_mut() {
            *v *= factor;
        }
    }

    /// Reset for the next accumulation window.
    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|v| *v = 0.0);
        self.micro_steps = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::ParamLayout;
    use crate::testkit;
    use crate::util::Pcg64;

    fn toy_layout() -> ParamLayout {
        ParamLayout::from_shapes(&[
            ("embeddings.word".into(), vec![100, 8]),       // 800
            ("layer.0.attn.w".into(), vec![32, 32]),        // 1024
            ("layer.0.attn.b".into(), vec![32]),            // 32
            ("layer.0.out.w".into(), vec![64, 16]),         // 1024
            ("cls.bias".into(), vec![100]),                 // 100
        ])
    }

    #[test]
    fn buckets_cover_layout_disjointly_in_reverse() {
        let layout = toy_layout();
        let buckets = build_buckets(&layout, 1000);
        // coverage + disjointness
        let mut covered = vec![false; layout.total_len()];
        for b in &buckets {
            for c in &mut covered[b.start..b.end] {
                assert!(!*c, "overlap");
                *c = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
        // bucket 0 holds the layout tail (cls.bias)
        assert!(buckets[0].tensors.contains(&"cls.bias".to_string()));
        assert_eq!(buckets[0].end, layout.total_len());
        // orders are 0..n
        for (i, b) in buckets.iter().enumerate() {
            assert_eq!(b.order, i);
        }
    }

    #[test]
    fn threshold_respected_except_last() {
        let layout = toy_layout();
        let buckets = build_buckets(&layout, 1000);
        for b in &buckets[..buckets.len() - 1] {
            assert!(b.len() >= 1000, "{b:?}");
        }
    }

    #[test]
    fn huge_threshold_gives_single_bucket() {
        let layout = toy_layout();
        let buckets = build_buckets(&layout, usize::MAX);
        assert_eq!(buckets.len(), 1);
        assert_eq!(buckets[0].start, 0);
        assert_eq!(buckets[0].end, layout.total_len());
        assert_eq!(buckets[0].tensors.len(), 5);
    }

    #[test]
    fn tiny_threshold_gives_per_tensor_buckets() {
        let layout = toy_layout();
        let buckets = build_buckets(&layout, 1);
        assert_eq!(buckets.len(), 5);
    }

    #[test]
    fn prop_bucket_partition_random_layouts() {
        testkit::check_msg(
            "bucket-partition", 0xB0C, 48,
            |r: &mut Pcg64| {
                let n = r.range_usize(1, 30);
                let shapes: Vec<(String, Vec<usize>)> = (0..n)
                    .map(|i| {
                        (format!("t{i}"), vec![r.range_usize(1, 500)])
                    })
                    .collect();
                (shapes, r.range_usize(1, 2000))
            },
            |(shapes, threshold)| {
                let layout = ParamLayout::from_shapes(shapes);
                let buckets = build_buckets(&layout, *threshold);
                let total: usize = buckets.iter().map(|b| b.len()).sum();
                if total != layout.total_len() {
                    return Err(format!("covered {total} of {}",
                                       layout.total_len()));
                }
                // buckets in reverse-contiguous order
                for w in buckets.windows(2) {
                    if w[1].end != w[0].start {
                        return Err("buckets not reverse-contiguous".into());
                    }
                }
                // tensor count preserved
                let names: usize = buckets.iter().map(|b| b.tensors.len())
                    .sum();
                if names != shapes.len() {
                    return Err("tensor lost".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bucket_ranges_mirror_buckets_without_names() {
        let layout = toy_layout();
        let buckets = build_buckets(&layout, 1000);
        let ranges = bucket_ranges(&buckets);
        assert_eq!(ranges.len(), buckets.len());
        for (b, r) in buckets.iter().zip(ranges.iter()) {
            assert_eq!((b.start, b.end), (r.start, r.end));
            assert_eq!(b.len(), r.len());
        }
        // the Arc is cheaply cloneable for the worker threads
        let r2 = ranges.clone();
        assert_eq!(r2[0], ranges[0]);
    }

    #[test]
    fn even_split_tiles_the_range() {
        for (n, pieces) in [(100, 4), (7, 3), (5, 5), (9, 1), (3, 4)] {
            let r = BucketRange::even_split(n, pieces);
            assert_eq!(r.len(), pieces);
            assert_eq!(r[0].start, 0);
            assert_eq!(r[pieces - 1].end, n);
            for w in r.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn accumulator_sums_and_normalizes() {
        let mut acc = GradAccumulator::new(4);
        acc.add(&[1.0, 2.0, 3.0, 4.0]);
        acc.add(&[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(acc.micro_steps(), 2);
        assert_eq!(acc.buffer(), &[2.0, 2.0, 4.0, 4.0]);
        // mean over k=2 micro-steps x world=4 => /8
        let f = acc.mean_factor(4);
        assert!((f - 1.0 / 8.0).abs() < 1e-9);
        acc.scale(f);
        assert_eq!(acc.buffer()[0], 0.25);
        acc.reset();
        assert_eq!(acc.buffer(), &[0.0; 4]);
        assert_eq!(acc.micro_steps(), 0);
    }
}
